"""AOT pipeline: lower every (model, token-count) step variant to HLO text.

HLO *text* (not `.serialize()`d HloModuleProto) is the interchange format:
jax >= 0.5 emits protos with 64-bit instruction ids which xla_extension
0.5.1 (the version the `xla` 0.1.6 crate binds) rejects; the text parser
reassigns ids and round-trips cleanly. See /opt/xla-example/gen_hlo.py.

Usage: (cd python && python -m compile.aot --out-dir ../artifacts)

Outputs:
  artifacts/<model>/step_t<T>.hlo.txt   one per token-count variant
  artifacts/manifest.json               configs + shapes + variant paths,
                                        the Rust model registry's input
"""

import argparse
import hashlib
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from .configs import DECODE_TOKEN_VARIANTS, MODELS
from .model import example_args, make_param_step_fn, make_step_fn
from .weights import flatten_weights, make_weights

MANIFEST_VERSION = 3

# Fixed input for the cross-layer golden test: the Rust runtime executes the
# T=3 artifact with these inputs and must reproduce the eager-JAX outputs.
GOLDEN_T = 3
GOLDEN_TOKENS = [7, 42, 255]


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_variant(cfg, weights, t, impl):
    """Lower the param-step form: weights are runtime parameters (see
    weights.flatten_weights for why constants cannot be used)."""
    step = make_param_step_fn(cfg, t, impl=impl)
    lowered = jax.jit(step).lower(*example_args(cfg, t, weights=weights))
    return to_hlo_text(lowered)


def build_model(cfg, out_dir, impl, variants):
    weights = make_weights(cfg)
    model_dir = os.path.join(out_dir, cfg.name)
    os.makedirs(model_dir, exist_ok=True)

    # Weights: index-prefixed keys so lexicographic order == parameter order.
    flat = flatten_weights(weights)
    npz_path = os.path.join(model_dir, "weights.npz")
    np.savez(npz_path, **{f"{i:03d}.{name}": np.asarray(a)
                          for i, (name, a) in enumerate(flat)})
    entry = {
        "config": cfg.to_dict(),
        "impl": impl,
        "weights": {
            "path": os.path.join(cfg.name, "weights.npz"),
            "count": len(flat),
            "names": [name for name, _ in flat],
            "params": int(sum(int(np.prod(a.shape)) for _, a in flat)),
        },
        "variants": {},
        "io": {
            "inputs": [
                {"name": "tokens", "dtype": "i32", "shape": ["T"]},
                {"name": "cache_len", "dtype": "i32", "shape": []},
                {"name": "kv", "dtype": "f32",
                 "shape": [cfg.layers, 2, cfg.max_seq, cfg.kv_dim]},
                {"name": "router_state", "dtype": "f32",
                 "shape": [cfg.layers, cfg.hidden]},
            ],
            "outputs": [
                {"name": "logits", "dtype": "f32", "shape": ["T", cfg.vocab]},
                {"name": "topk_idx", "dtype": "i32",
                 "shape": [cfg.layers, "T", max(cfg.top_k, 1)]},
                {"name": "kv_out", "dtype": "f32",
                 "shape": [cfg.layers, 2, cfg.max_seq, cfg.kv_dim]},
                {"name": "router_state_seq", "dtype": "f32",
                 "shape": [cfg.layers, "T", cfg.hidden]},
            ],
        },
    }
    # Golden outputs: eager execution of the lowered step semantics on a
    # fixed input. Consumed by rust/tests/runtime_golden.rs to prove the
    # AOT artifact reproduces JAX numerics through the PJRT text path.
    step = jax.jit(make_step_fn(cfg, weights, GOLDEN_T, impl=impl))
    kv = jnp.zeros((cfg.layers, 2, cfg.max_seq, cfg.kv_dim), jnp.float32)
    rs = jnp.zeros((cfg.layers, cfg.hidden), jnp.float32)
    logits, topk, kv_out, rs_out = step(
        jnp.array(GOLDEN_TOKENS, jnp.int32), jnp.int32(0), kv, rs)
    entry["golden"] = {
        "tokens": GOLDEN_TOKENS,
        "t": GOLDEN_T,
        "logits_row0_head": np.asarray(logits)[0, :8].tolist(),
        "logits_sum": float(jnp.sum(logits)),
        "logits_abs_sum": float(jnp.sum(jnp.abs(logits))),
        "argmax": np.asarray(jnp.argmax(logits, axis=-1)).tolist(),
        "topk_idx": np.asarray(topk).tolist(),
        "kv_abs_sum": float(jnp.sum(jnp.abs(kv_out))),
        "rstate_abs_sum": float(jnp.sum(jnp.abs(rs_out))),
    }

    for t in variants:
        t0 = time.time()
        text = lower_variant(cfg, weights, t, impl)
        rel = os.path.join(cfg.name, f"step_t{t}.hlo.txt")
        with open(os.path.join(out_dir, rel), "w") as f:
            f.write(text)
        entry["variants"][str(t)] = {
            "path": rel,
            "tokens": t,
            "sha256": hashlib.sha256(text.encode()).hexdigest()[:16],
            "hlo_bytes": len(text),
        }
        print(f"  {cfg.name} T={t}: {len(text)/1e3:.0f} kB "
              f"({time.time()-t0:.1f}s)")
    return entry


def main() -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--out-dir", default="../artifacts")
    p.add_argument("--models", default="all",
                   help="comma-separated model names, or 'all'")
    p.add_argument("--impl", default="pallas", choices=["pallas", "ref"],
                   help="kernel implementation lowered into the HLO")
    p.add_argument("--max-t", type=int, default=max(DECODE_TOKEN_VARIANTS))
    args = p.parse_args()

    names = list(MODELS) if args.models == "all" else args.models.split(",")
    os.makedirs(args.out_dir, exist_ok=True)

    manifest = {"version": MANIFEST_VERSION, "impl": args.impl, "models": {}}
    for name in names:
        cfg = MODELS[name]
        variants = [t for t in DECODE_TOKEN_VARIANTS if t <= args.max_t]
        if cfg.prefill_chunk not in variants:
            variants = variants + [cfg.prefill_chunk]
        print(f"[aot] lowering {name} ({cfg.mirrors}) impl={args.impl}")
        manifest["models"][name] = build_model(cfg, args.out_dir, args.impl, variants)

    path = os.path.join(args.out_dir, "manifest.json")
    with open(path, "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"[aot] wrote {path}")


if __name__ == "__main__":
    main()
