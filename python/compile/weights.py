"""Deterministic seeded weight generation for the model zoo.

Weights are folded into the AOT HLO as constants, so the Rust request path
feeds only (tokens, cache_len, kv, router_state). Seeding is per-model-name
so artifacts are reproducible byte-for-byte across `make artifacts` runs.
"""

import jax
import jax.numpy as jnp

from .configs import ModelConfig


def _init(key, shape, scale):
    return (jax.random.normal(key, shape) * scale).astype(jnp.float32)


def flatten_weights(w):
    """Deterministic (name, array) flattening.

    Weights are passed to the AOT step function as *parameters* (not baked
    constants): `as_hlo_text` elides large constants as `{...}`, which the
    xla_extension 0.5.1 text parser silently reads as zeros. The Rust
    runtime loads `weights.npz` and feeds the arrays in exactly this order
    (keys are index-prefixed in the npz, so lexicographic order matches).
    """
    items = [("embed", w["embed"]), ("final_norm", w["final_norm"]),
             ("unembed", w["unembed"])]
    for li, layer in enumerate(w["layers"]):
        for key in sorted(layer.keys()):
            items.append((f"layer{li}.{key}", layer[key]))
    return items


def unflatten_weights(cfg: ModelConfig, arrays):
    """Inverse of `flatten_weights` given the model config."""
    arrays = list(arrays)
    w = {"embed": arrays[0], "final_norm": arrays[1], "unembed": arrays[2]}
    i = 3
    layers = []
    # Key order must match flatten_weights: sorted layer keys.
    template = _layer_keys(cfg)
    for _ in range(cfg.layers):
        layer = {}
        for key in template:
            layer[key] = arrays[i]
            i += 1
        layers.append(layer)
    w["layers"] = layers
    assert i == len(arrays), (i, len(arrays))
    return w


def _layer_keys(cfg: ModelConfig):
    keys = ["attn_norm", "ffn_norm", "wk", "wo", "wq", "wv", "w1", "w2"]
    if cfg.is_moe:
        keys.append("router")
        if cfg.n_shared > 0:
            keys.extend(["shared_w1", "shared_w2"])
    return sorted(keys)


def make_weights(cfg: ModelConfig):
    """Returns a pytree (dict) of all model parameters."""
    key = jax.random.PRNGKey(cfg.seed)
    ks = iter(jax.random.split(key, 16 + 8 * cfg.layers))
    h, f, v = cfg.hidden, cfg.ffn, cfg.vocab
    kvd = cfg.kv_dim
    s_attn = 0.6 / (h ** 0.5)
    s_ffn = 0.6 / (h ** 0.5)

    w = {
        "embed": _init(next(ks), (v, h), 1.0),
        "unembed": _init(next(ks), (h, v), 1.2 / (h ** 0.5)),
        "final_norm": jnp.ones((h,), jnp.float32),
        "layers": [],
    }
    for _ in range(cfg.layers):
        layer = {
            "attn_norm": jnp.ones((h,), jnp.float32),
            "ffn_norm": jnp.ones((h,), jnp.float32),
            "wq": _init(next(ks), (h, kvd), s_attn),
            "wk": _init(next(ks), (h, kvd), s_attn),
            "wv": _init(next(ks), (h, kvd), s_attn),
            "wo": _init(next(ks), (kvd, h), s_attn),
        }
        if cfg.is_moe:
            layer["router"] = _init(next(ks), (h, cfg.n_experts), 1.5 / (h ** 0.5))
            layer["w1"] = _init(next(ks), (cfg.n_experts, h, 2 * f), s_ffn)
            layer["w2"] = _init(next(ks), (cfg.n_experts, f, h), s_ffn)
            if cfg.n_shared > 0:
                layer["shared_w1"] = _init(next(ks), (cfg.n_shared, h, 2 * f), s_ffn)
                layer["shared_w2"] = _init(next(ks), (cfg.n_shared, f, h), s_ffn)
        else:
            layer["w1"] = _init(next(ks), (h, 2 * f), s_ffn)
            layer["w2"] = _init(next(ks), (f, h), s_ffn)
        w["layers"].append(layer)
    return w
