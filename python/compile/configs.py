"""Model zoo configs — "mini" mirrors of the paper's Table 1.

Routing topology (experts / top-k / shared experts) matches the paper
exactly; hidden sizes are scaled down so the CPU PJRT client can run them.
Paper-scale parameter counts live on the Rust side (`cost/` module), which
converts measured expert activations into GPU memory traffic.
"""

from dataclasses import dataclass, field, asdict


@dataclass(frozen=True)
class ModelConfig:
    name: str
    # mirrors of the paper's Table 1 rows (see DESIGN.md §3)
    mirrors: str
    hidden: int = 64
    layers: int = 2
    heads: int = 4
    head_dim: int = 16
    vocab: int = 320
    ffn: int = 128            # per-expert (or dense) FFN width
    n_experts: int = 0        # 0 => dense FFN
    top_k: int = 0
    n_shared: int = 0         # always-active shared experts (DeepSeek/Qwen)
    affinity: float = 0.0     # router EMA mixing weight (expert-token affinity)
    max_seq: int = 384
    prefill_chunk: int = 64
    seed: int = 0

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def kv_dim(self) -> int:
        return self.heads * self.head_dim

    def to_dict(self):
        d = asdict(self)
        d["is_moe"] = self.is_moe
        return d


# Decode/verify token-count variants: T = K+1 for speculation length K in 0..7,
# matching the paper's K sweep (Figs. 4, 8).
DECODE_TOKEN_VARIANTS = list(range(1, 9))

MODELS = {
    "mixtral": ModelConfig(
        name="mixtral", mirrors="Mixtral-8x7B FP8",
        n_experts=8, top_k=2, n_shared=0, affinity=0.0, seed=101,
    ),
    "phi": ModelConfig(
        name="phi", mirrors="Phi-3.5-MoE FP8",
        n_experts=16, top_k=2, n_shared=0, affinity=0.20, seed=102,
    ),
    "olmoe": ModelConfig(
        name="olmoe", mirrors="OLMoE FP8",
        n_experts=64, top_k=8, n_shared=0, affinity=0.75, ffn=64, seed=103,
    ),
    "deepseek": ModelConfig(
        name="deepseek", mirrors="DeepSeekMoE-16B FP16",
        n_experts=64, top_k=6, n_shared=2, affinity=0.40, ffn=64, seed=104,
    ),
    "qwen": ModelConfig(
        name="qwen", mirrors="Qwen1.5-MoE FP16",
        n_experts=60, top_k=4, n_shared=4, affinity=0.45, ffn=64, seed=105,
    ),
    # Dense baseline (paper Fig. 4, green curves).
    "llama": ModelConfig(
        name="llama", mirrors="LLaMA-3-8B dense FP16",
        n_experts=0, top_k=0, ffn=256, seed=106,
    ),
    # EAGLE-lite draft model (paper §7.3): small dense LM.
    "draft": ModelConfig(
        name="draft", mirrors="EAGLE drafter (Mixtral)",
        hidden=32, layers=1, heads=2, head_dim=16, ffn=64,
        n_experts=0, top_k=0, seed=107,
    ),
}
