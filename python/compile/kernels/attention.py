"""Pallas kernel: cached causal decode attention with online softmax.

Verification attends the T in-flight tokens (1 + K speculative) against the
full KV cache. The paper (§2.4) notes attention is ~8% of MoE iteration time
and stable with K; this kernel keeps it that way by streaming the KV cache
through VMEM in blocks with a flash-style online-softmax accumulator, so the
working set is independent of cache length.

Schedule: grid = (heads, S/BS). The query block q[T, D] for head h stays
VMEM-resident across all KV blocks; each step loads k/v[BS, D], updates the
running max m[T], denominator l[T], and accumulator acc[T, D] (stored in the
auxiliary outputs so the pattern is portable to interpret mode), and the
final KV step normalizes. Masking (causality + cache length) is precomputed
by the caller as bool[T, S] — on real TPU this would be fused via iota, but
the mask is T·S bits and T ≤ 64, so it is VMEM-trivial either way.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, mask_ref, o_ref, m_ref, l_ref, *, scale, nb):
    b = pl.program_id(1)

    @pl.when(b == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0]                      # [T, D] (head-blocked)
    k = k_ref[0]                      # [BS, D]
    v = v_ref[0]                      # [BS, D]
    mask = mask_ref[...]              # [T, BS]

    s = jnp.dot(q, k.T) * scale       # [T, BS]
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...][:, 0]         # [T]
    l_prev = l_ref[...][:, 0]         # [T]
    m_cur = jnp.maximum(m_prev, jnp.max(s, axis=1))
    # Guard fully-masked rows: exp(NEG_INF - NEG_INF) would be exp(0).
    alpha = jnp.where(m_prev == NEG_INF, 0.0, jnp.exp(m_prev - m_cur))
    p = jnp.where(mask, jnp.exp(s - m_cur[:, None]), 0.0)  # [T, BS]
    l_cur = alpha * l_prev + jnp.sum(p, axis=1)

    o_ref[0] = alpha[:, None] * o_ref[0] + jnp.dot(p, v)
    m_ref[...] = m_cur[:, None]
    l_ref[...] = l_cur[:, None]

    @pl.when(b == nb - 1)
    def _finalize():
        l = l_ref[...][:, 0]
        o_ref[0] = o_ref[0] / jnp.maximum(l, 1e-30)[:, None]


def attention(q, k, v, mask, scale, *, block_s=128, interpret=True):
    """Cached multi-head attention. See `ref.attention_ref` for semantics.

    Args:
      q:    f32[T, Hh, D]
      k:    f32[S, Hh, D]  (cache already updated with the new tokens)
      v:    f32[S, Hh, D]
      mask: bool[T, S]
      scale: float
    Returns:
      f32[T, Hh, D]
    """
    t, hh, d = q.shape
    s = k.shape[0]
    block_s = min(block_s, s)
    assert s % block_s == 0, f"S={s} must be a multiple of block_s={block_s}"
    nb = s // block_s

    qh = jnp.transpose(q, (1, 0, 2))  # [Hh, T, D]
    kh = jnp.transpose(k, (1, 0, 2))  # [Hh, S, D]
    vh = jnp.transpose(v, (1, 0, 2))

    out, _, _ = pl.pallas_call(
        functools.partial(_attn_kernel, scale=scale, nb=nb),
        grid=(hh, nb),
        in_specs=[
            pl.BlockSpec((1, t, d), lambda h, b: (h, 0, 0)),       # q resident
            pl.BlockSpec((1, block_s, d), lambda h, b: (h, b, 0)),  # k streamed
            pl.BlockSpec((1, block_s, d), lambda h, b: (h, b, 0)),  # v streamed
            pl.BlockSpec((t, block_s), lambda h, b: (0, b)),        # mask
        ],
        out_specs=[
            pl.BlockSpec((1, t, d), lambda h, b: (h, 0, 0)),  # acc / output
            pl.BlockSpec((t, 1), lambda h, b: (0, 0)),        # running max
            pl.BlockSpec((t, 1), lambda h, b: (0, 0)),        # running denom
        ],
        out_shape=[
            jax.ShapeDtypeStruct((hh, t, d), q.dtype),
            jax.ShapeDtypeStruct((t, 1), q.dtype),
            jax.ShapeDtypeStruct((t, 1), q.dtype),
        ],
        interpret=interpret,
    )(qh, kh, vh, mask)
    return jnp.transpose(out, (1, 0, 2))


def vmem_bytes(t, d, block_s, dtype_bytes=4):
    """VMEM working set per grid step (perf model, DESIGN §7)."""
    resident = (t * d * 2 + 2 * t) * dtype_bytes          # q, acc, m, l
    streamed = (2 * block_s * d) * dtype_bytes            # k, v block
    scratch = (2 * t * block_s) * dtype_bytes             # scores, p
    return resident + streamed + scratch
