"""Pallas kernel: top-k-routed Mixture-of-Experts FFN (SwiGLU).

This is the paper's verification hot-spot: for T in-flight tokens (1 original
+ K speculative), each token routes to `top_k` of E experts, and iteration
latency is governed by how many *unique* experts must be fetched (paper §2.4).

Kernel schedule (TPU mapping, see DESIGN.md §Hardware-Adaptation):
  grid = (E,) — one expert per grid step. Each step stages that expert's
  (W1[e], W2[e]) block HBM→VMEM (the expensive movement the paper counts),
  keeps the token block x[T,H] VMEM-resident across all steps, computes the
  SwiGLU FFN for every token, and accumulates `gate_weight * y` into the
  output block under the routing mask. Token counts are tiny (T ≤ 64) while
  expert weights dominate bytes — the weight-stationary-per-expert schedule
  is exactly how the data movement the paper models is laid out.

Runs with interpret=True: CPU PJRT cannot execute Mosaic custom-calls, so
the interpreter lowers the same schedule to portable HLO (a sequential scan
over the expert grid with dynamic slices — semantics preserved).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _moe_ffn_kernel(x_ref, idx_ref, gates_ref, w1_ref, w2_ref, o_ref, *, n_f):
    e = pl.program_id(0)

    # Zero the accumulator on the first expert step (the output block is
    # revisited by every grid step).
    @pl.when(e == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...]                        # [T, H]   (VMEM-resident)
    w1 = w1_ref[...]                      # [1, H, 2F] — this expert's block
    w2 = w2_ref[...]                      # [1, F, H]

    # Per-token routing weight for expert e: sum over the top-k slots.
    idx = idx_ref[...]                    # [T, K]
    gates = gates_ref[...]                # [T, K]
    weight = jnp.sum(jnp.where(idx == e, gates, 0.0), axis=1)  # [T]

    h = jnp.dot(x, w1[0])                 # [T, 2F] — MXU matmul
    gate, up = h[:, :n_f], h[:, n_f:]
    act = gate * (1.0 / (1.0 + jnp.exp(-gate))) * up  # SwiGLU
    y = jnp.dot(act, w2[0])               # [T, H]

    o_ref[...] += weight[:, None] * y


def moe_ffn(x, topk_idx, gates, w1, w2, *, interpret=True):
    """Routed expert FFN. See `ref.moe_ffn_ref` for the semantics.

    Args:
      x:        f32[T, H]
      topk_idx: i32[T, K]
      gates:    f32[T, K]
      w1:       f32[E, H, 2F]
      w2:       f32[E, F, H]
    Returns:
      f32[T, H]
    """
    t, h = x.shape
    e, _, f2 = w1.shape
    n_f = f2 // 2
    k = topk_idx.shape[1]
    return pl.pallas_call(
        functools.partial(_moe_ffn_kernel, n_f=n_f),
        grid=(e,),
        in_specs=[
            pl.BlockSpec((t, h), lambda i: (0, 0)),        # x: resident
            pl.BlockSpec((t, k), lambda i: (0, 0)),        # topk_idx
            pl.BlockSpec((t, k), lambda i: (0, 0)),        # gates
            pl.BlockSpec((1, h, f2), lambda i: (i, 0, 0)),  # W1[e] streamed
            pl.BlockSpec((1, n_f, h), lambda i: (i, 0, 0)),  # W2[e] streamed
        ],
        out_specs=pl.BlockSpec((t, h), lambda i: (0, 0)),  # accumulator
        out_shape=jax.ShapeDtypeStruct((t, h), x.dtype),
        interpret=interpret,
    )(x, topk_idx, gates, w1, w2)


def vmem_bytes(t, h, n_f, k, dtype_bytes=4):
    """Estimated VMEM working set of one grid step (perf model, DESIGN §7).

    Resident: x[T,H] + out[T,H] + idx/gates[T,K]*2; streamed per step:
    W1[1,H,2F] + W2[1,F,H]; intermediates h[T,2F], act[T,F], y[T,H].
    """
    resident = (2 * t * h + 2 * t * k) * dtype_bytes
    streamed = (h * 2 * n_f + n_f * h) * dtype_bytes
    scratch = (t * 2 * n_f + t * n_f + t * h) * dtype_bytes
    return resident + streamed + scratch
