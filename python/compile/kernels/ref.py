"""Pure-jnp correctness oracles for the Pallas kernels (L1).

These are the ground truth that `moe_ffn.py` and `attention.py` are tested
against (pytest + hypothesis in python/tests/). They are also a selectable
AOT implementation (`aot.py --impl ref`) used to cross-check whole-model
numerics and as the fast path for large experiment sweeps.
"""

import jax.numpy as jnp


def silu(x):
    return x * (1.0 / (1.0 + jnp.exp(-x)))


def moe_ffn_ref(x, topk_idx, gates, w1, w2):
    """Top-k routed expert FFN, SwiGLU activation.

    Args:
      x:        f32[T, H]   token activations
      topk_idx: i32[T, K]   selected expert ids per token
      gates:    f32[T, K]   routing weights per selected expert
      w1:       f32[E, H, 2F]  fused gate+up projections
      w2:       f32[E, F, H]   down projection
    Returns:
      f32[T, H]
    """
    E = w1.shape[0]
    F = w1.shape[2] // 2
    # Dense formulation: per-token per-expert weight (0 if not routed).
    # weight[t, e] = sum_k gates[t, k] * [topk_idx[t, k] == e]
    onehot = jnp.sum(
        (topk_idx[:, :, None] == jnp.arange(E)[None, None, :]) * gates[:, :, None],
        axis=1,
    )  # [T, E]
    h = jnp.einsum("th,ehf->etf", x, w1)  # [E, T, 2F]
    act = silu(h[..., :F]) * h[..., F:]   # [E, T, F]
    y = jnp.einsum("etf,efh->eth", act, w2)  # [E, T, H]
    return jnp.einsum("eth,te->th", y, onehot)


def attention_ref(q, k, v, mask, scale):
    """Multi-head causal cached attention.

    Args:
      q:     f32[T, Hh, D]  queries for the T in-flight tokens
      k:     f32[S, Hh, D]  full key cache (already updated with new tokens)
      v:     f32[S, Hh, D]  full value cache
      mask:  bool[T, S]     True where attention is allowed
      scale: float
    Returns:
      f32[T, Hh, D]
    """
    scores = jnp.einsum("thd,shd->hts", q, k) * scale
    scores = jnp.where(mask[None, :, :], scores, -1e30)
    p = jnp.exp(scores - jnp.max(scores, axis=-1, keepdims=True))
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    return jnp.einsum("hts,shd->thd", p, v)
