"""L2: the MoE transformer decode/verify step function (JAX).

One AOT-compiled `step` processes T in-flight tokens (T = 1 + K speculative
draft tokens during verification, or a prefill chunk) against a functional
KV cache. The router's top-k choices are *returned* so the Rust coordinator
can count unique activated experts — the quantity that drives MoE
verification cost in the paper (§2.4).

Expert-token affinity (paper §2.4, [22,24]) is modeled explicitly: the
router input mixes the current activation with a per-layer EMA of previous
activations (`router_state`) weighted by `cfg.affinity`. High affinity
(OLMoE) makes consecutive tokens route alike (cheap verification); zero
affinity (Mixtral) reproduces the balls-in-buckets worst case.

Step contract (all shapes static per (model, T) variant):
  inputs : tokens i32[T], cache_len i32[], kv f32[L,2,S,KVD], rstate f32[L,H]
  outputs: logits f32[T,V], topk_idx i32[L,T,Kr], kv_out, rstate_out
with Kr = max(top_k, 1) (dense models emit -1s so the output arity is
uniform across the zoo).

Writes to the KV cache land at positions [cache_len, cache_len+T); the
coordinator advances cache_len only by the number of *accepted* tokens, so
rejected speculative KV entries are overwritten by the next step — the same
lookahead-slot reuse vLLM's scheduler performs (paper Fig. 14).
"""

import jax
import jax.numpy as jnp

from .configs import ModelConfig
from .kernels import attention as attn_k
from .kernels import moe_ffn as moe_k
from .kernels import ref

ROUTER_EMA = 0.5  # per-token decay of the affinity EMA state


def rms_norm(x, g, eps=1e-5):
    return x * g * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)


def _rope(x, positions, head_dim):
    """Rotary position embedding over the last dim of [T, Hh, D]."""
    half = head_dim // 2
    freqs = 1.0 / (10000.0 ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions[:, None].astype(jnp.float32) * freqs[None, :]  # [T, half]
    cos, sin = jnp.cos(ang)[:, None, :], jnp.sin(ang)[:, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


def _router_inputs(x, state, affinity):
    """Sequential EMA over the T tokens: token t routes on a mix of its own
    activation and the EMA of activations before it.

    Returns (router_in [T,H], state_seq [T,H]) where state_seq[i] is the EMA
    *after* consuming token i. The full trajectory is returned (not just the
    final state) so the serving engine can roll the router state back to the
    last *accepted* speculative token — rejected drafts must not pollute
    future routing (see rust/tests/runtime_golden.rs).
    """

    def body(s, xt):
        r = (1.0 - affinity) * xt + affinity * s
        s_next = ROUTER_EMA * s + (1.0 - ROUTER_EMA) * xt
        return s_next, (r, s_next)

    _, (r, state_seq) = jax.lax.scan(body, state, x)
    return r, state_seq


def _ffn_dense(x, layer, impl):
    h = x @ layer["w1"]
    f = layer["w2"].shape[0]
    act = ref.silu(h[:, :f]) * h[:, f:]
    return act @ layer["w2"]


def _topk(logits, k):
    """Iterative argmax top-k.

    `jax.lax.top_k` lowers (jax >= 0.5) to a `topk(..., largest=true)` HLO
    instruction that the xla_extension 0.5.1 text parser rejects; k <= 8 here
    so k rounds of argmax+mask lower to plain reduces and parse everywhere.
    Ties resolve to the lowest index, matching lax.top_k.
    """
    vals, idxs = [], []
    masked = logits
    for _ in range(k):
        i = jnp.argmax(masked, axis=-1)                # [T]
        v = jnp.take_along_axis(masked, i[:, None], axis=-1)[:, 0]
        vals.append(v)
        idxs.append(i.astype(jnp.int32))
        masked = masked.at[jnp.arange(logits.shape[0]), i].set(-jnp.inf)
    return jnp.stack(vals, axis=-1), jnp.stack(idxs, axis=-1)


def _moe_block(x, layer, cfg: ModelConfig, state, impl):
    """Returns (y [T,H], topk_idx [T,k], state_seq [T,H])."""
    router_in, state_seq = _router_inputs(x, state, cfg.affinity)
    logits = router_in @ layer["router"]               # [T, E]
    gate_logits, topk_idx = _topk(logits, cfg.top_k)
    gates = jax.nn.softmax(gate_logits, axis=-1)       # [T, k]

    if impl == "pallas":
        y = moe_k.moe_ffn(x, topk_idx, gates, layer["w1"], layer["w2"])
    else:
        y = ref.moe_ffn_ref(x, topk_idx, gates, layer["w1"], layer["w2"])

    if cfg.n_shared > 0:
        # Shared experts are always active (DeepSeek/Qwen, Table 1): route
        # every token to each shared expert with unit gate.
        t = x.shape[0]
        sh_idx = jnp.tile(jnp.arange(cfg.n_shared, dtype=jnp.int32), (t, 1))
        sh_gates = jnp.ones((t, cfg.n_shared), jnp.float32)
        if impl == "pallas":
            y = y + moe_k.moe_ffn(x, sh_idx, sh_gates, layer["shared_w1"], layer["shared_w2"])
        else:
            y = y + ref.moe_ffn_ref(x, sh_idx, sh_gates, layer["shared_w1"], layer["shared_w2"])
    return y, topk_idx, state_seq


def make_step_fn(cfg: ModelConfig, weights, t: int, impl: str = "pallas"):
    """Builds step(tokens, cache_len, kv, rstate) for a fixed token count T."""
    s, hh, d = cfg.max_seq, cfg.heads, cfg.head_dim
    kr = max(cfg.top_k, 1)
    scale = 1.0 / (d ** 0.5)

    def step(tokens, cache_len, kv, rstate):
        positions = cache_len + jnp.arange(t, dtype=jnp.int32)  # [T]
        x = weights["embed"][tokens]                            # [T, H]
        all_topk = []
        new_rstate = []
        kv_out = kv

        for li, layer in enumerate(weights["layers"]):
            xn = rms_norm(x, layer["attn_norm"])
            q = _rope((xn @ layer["wq"]).reshape(t, hh, d), positions, d)
            k_new = _rope((xn @ layer["wk"]).reshape(t, hh, d), positions, d)
            v_new = (xn @ layer["wv"]).reshape(t, hh, d)

            # Functional cache update at [cache_len, cache_len+T).
            k_cache = jax.lax.dynamic_update_slice(
                kv_out[li, 0], k_new.reshape(t, -1), (cache_len, 0))
            v_cache = jax.lax.dynamic_update_slice(
                kv_out[li, 1], v_new.reshape(t, -1), (cache_len, 0))
            kv_out = kv_out.at[li, 0].set(k_cache).at[li, 1].set(v_cache)

            # Causality + cache-length mask: token t_q attends to positions
            # <= cache_len + t_q.
            key_pos = jnp.arange(s, dtype=jnp.int32)[None, :]
            mask = key_pos <= positions[:, None]                # [T, S]

            kf = k_cache.reshape(s, hh, d)
            vf = v_cache.reshape(s, hh, d)
            if impl == "pallas":
                o = attn_k.attention(q, kf, vf, mask, scale)
            else:
                o = ref.attention_ref(q, kf, vf, mask, scale)
            x = x + o.reshape(t, -1) @ layer["wo"]

            xn = rms_norm(x, layer["ffn_norm"])
            if cfg.is_moe:
                y, topk_idx, st = _moe_block(xn, layer, cfg, rstate[li], impl)
            else:
                y = _ffn_dense(xn, layer, impl)
                topk_idx = jnp.full((t, kr), -1, jnp.int32)
                st = jnp.tile(rstate[li][None, :], (t, 1))  # unchanged
            x = x + y
            all_topk.append(topk_idx)
            new_rstate.append(st)

        logits = rms_norm(x, weights["final_norm"]) @ weights["unembed"]
        return (
            logits,                                   # f32[T, V]
            jnp.stack(all_topk),                      # i32[L, T, Kr]
            kv_out,                                   # f32[L, 2, S, KVD]
            # Per-token router-state trajectory: the engine commits the row
            # at the last accepted position (rejected drafts roll back).
            jnp.stack(new_rstate),                    # f32[L, T, H]
        )

    return step


def make_param_step_fn(cfg: ModelConfig, t: int, impl: str = "pallas"):
    """Step function taking flattened weights as leading parameters.

    Weights must be arguments (not baked constants) for the AOT path:
    `as_hlo_text` elides large constants, which the old XLA text parser
    reads back as zeros. The Rust runtime uploads `weights.npz` once and
    passes device buffers on every step.
    """
    from .weights import unflatten_weights

    def step(flat_weights, tokens, cache_len, kv, rstate):
        w = unflatten_weights(cfg, flat_weights)
        return make_step_fn(cfg, w, t, impl=impl)(tokens, cache_len, kv, rstate)

    return step


def example_args(cfg: ModelConfig, t: int, weights=None):
    """ShapeDtypeStructs for lowering; prepends flattened weight specs when
    `weights` is given (the param-step form)."""
    base = (
        jax.ShapeDtypeStruct((t,), jnp.int32),
        jax.ShapeDtypeStruct((), jnp.int32),
        jax.ShapeDtypeStruct((cfg.layers, 2, cfg.max_seq, cfg.kv_dim), jnp.float32),
        jax.ShapeDtypeStruct((cfg.layers, cfg.hidden), jnp.float32),
    )
    if weights is None:
        return base
    from .weights import flatten_weights

    flat = tuple(
        jax.ShapeDtypeStruct(a.shape, a.dtype) for _, a in flatten_weights(weights)
    )
    return (flat,) + base
