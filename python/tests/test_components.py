"""Unit tests for L2 building blocks: custom top-k (the lax.top_k
replacement that must parse under XLA 0.5.1), RoPE, RMSNorm, and the
router-affinity EMA."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.model import ROUTER_EMA, _router_inputs, _rope, _topk, rms_norm


class TestTopK:
    @settings(max_examples=40, deadline=None)
    @given(
        t=st.integers(1, 8),
        e=st.sampled_from([4, 8, 16, 64]),
        k=st.integers(1, 8),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_matches_lax_top_k(self, t, e, k, seed):
        k = min(k, e)
        rng = np.random.default_rng(seed)
        logits = jnp.asarray(rng.normal(size=(t, e)), jnp.float32)
        vals, idxs = _topk(logits, k)
        lvals, lidxs = jax.lax.top_k(logits, k)
        np.testing.assert_allclose(vals, lvals, rtol=1e-6)
        np.testing.assert_array_equal(idxs, lidxs)

    def test_ties_pick_lowest_index(self):
        logits = jnp.array([[1.0, 1.0, 0.5]], jnp.float32)
        _, idxs = _topk(logits, 2)
        assert idxs[0, 0] == 0 and idxs[0, 1] == 1

    def test_k_equals_e(self):
        logits = jnp.array([[0.3, 0.1, 0.2]], jnp.float32)
        _, idxs = _topk(logits, 3)
        assert set(np.asarray(idxs[0]).tolist()) == {0, 1, 2}


class TestRmsNorm:
    def test_unit_scale_normalizes(self):
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.normal(size=(4, 32)) * 7.0, jnp.float32)
        y = rms_norm(x, jnp.ones((32,), jnp.float32))
        rms = jnp.sqrt(jnp.mean(y * y, axis=-1))
        np.testing.assert_allclose(rms, 1.0, rtol=1e-3)

    def test_scale_invariance(self):
        rng = np.random.default_rng(1)
        x = jnp.asarray(rng.normal(size=(2, 16)), jnp.float32)
        g = jnp.ones((16,), jnp.float32)
        a = rms_norm(x, g)
        b = rms_norm(5.0 * x, g)
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-5)


class TestRope:
    def test_preserves_norm(self):
        rng = np.random.default_rng(2)
        x = jnp.asarray(rng.normal(size=(3, 2, 16)), jnp.float32)
        pos = jnp.array([0, 5, 77], jnp.int32)
        y = _rope(x, pos, 16)
        np.testing.assert_allclose(
            jnp.linalg.norm(x, axis=-1), jnp.linalg.norm(y, axis=-1), rtol=1e-5
        )

    def test_position_zero_is_identity(self):
        rng = np.random.default_rng(3)
        x = jnp.asarray(rng.normal(size=(1, 2, 16)), jnp.float32)
        y = _rope(x, jnp.array([0], jnp.int32), 16)
        np.testing.assert_allclose(x, y, atol=1e-6)

    def test_relative_property(self):
        """<rope(q,m), rope(k,n)> depends only on m - n."""
        rng = np.random.default_rng(4)
        q = jnp.asarray(rng.normal(size=(1, 1, 16)), jnp.float32)
        k = jnp.asarray(rng.normal(size=(1, 1, 16)), jnp.float32)

        def dot(m, n):
            qm = _rope(q, jnp.array([m], jnp.int32), 16)
            kn = _rope(k, jnp.array([n], jnp.int32), 16)
            return float(jnp.sum(qm * kn))

        np.testing.assert_allclose(dot(3, 1), dot(10, 8), rtol=1e-4)
        np.testing.assert_allclose(dot(7, 7), dot(0, 0), rtol=1e-4)


class TestRouterEma:
    def test_zero_affinity_routes_on_activation(self):
        rng = np.random.default_rng(5)
        x = jnp.asarray(rng.normal(size=(4, 8)), jnp.float32)
        s0 = jnp.zeros((8,), jnp.float32)
        r, _ = _router_inputs(x, s0, 0.0)
        np.testing.assert_allclose(r, x, atol=1e-7)

    def test_state_seq_matches_manual_recurrence(self):
        rng = np.random.default_rng(6)
        x = jnp.asarray(rng.normal(size=(3, 4)), jnp.float32)
        s0 = jnp.asarray(rng.normal(size=(4,)), jnp.float32)
        _, seq = _router_inputs(x, s0, 0.5)
        s = s0
        for i in range(3):
            s = ROUTER_EMA * s + (1.0 - ROUTER_EMA) * x[i]
            np.testing.assert_allclose(seq[i], s, rtol=1e-6)

    def test_full_affinity_ignores_current_token(self):
        rng = np.random.default_rng(7)
        x = jnp.asarray(rng.normal(size=(2, 4)), jnp.float32)
        s0 = jnp.asarray(rng.normal(size=(4,)), jnp.float32)
        r, _ = _router_inputs(x, s0, 1.0)
        np.testing.assert_allclose(r[0], s0, atol=1e-7)
