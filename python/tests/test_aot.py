"""AOT interchange: HLO text must round-trip through the XLA text parser
(the exact path the Rust runtime takes) and reproduce eager numerics."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax._src.lib import xla_client as xc

from compile.aot import lower_variant, to_hlo_text
from compile.configs import DECODE_TOKEN_VARIANTS, MODELS, ModelConfig
from compile.model import example_args, make_step_fn
from compile.weights import make_weights

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")

TINY = ModelConfig(name="tiny", mirrors="test", hidden=32, layers=1, heads=2,
                   head_dim=8, vocab=64, ffn=32, n_experts=4, top_k=2,
                   max_seq=64, prefill_chunk=8, seed=13)


class TestHloText:
    def test_text_parses(self):
        """The text must round-trip through XLA's HLO parser — the exact
        entry point the Rust runtime uses (HloModuleProto::from_text_file).
        End-to-end numerics through xla_extension 0.5.1 are covered by
        rust/tests/runtime_golden.rs against the manifest golden outputs."""
        w = make_weights(TINY)
        text = lower_variant(TINY, w, 2, "ref")
        mod = xc._xla.hlo_module_from_text(text)
        assert mod is not None

    def test_entry_signature(self):
        """4 params (tokens, cache_len, kv, rstate) → 4-leaf tuple root."""
        w = make_weights(TINY)
        text = lower_variant(TINY, w, 2, "ref")
        head = text[:4000]
        assert "ENTRY" in text
        assert "s32[2]" in head            # tokens
        assert f"f32[{TINY.layers},2,{TINY.max_seq},{TINY.kv_dim}]" in text

    def test_pallas_and_ref_lower_to_same_signature(self):
        w = make_weights(TINY)
        a = lower_variant(TINY, w, 2, "ref")
        b = lower_variant(TINY, w, 2, "pallas")

        def sig(s):
            # module header: HloModule ..., entry_computation_layout={(...)->(...)}
            line = next(l for l in s.splitlines() if "entry_computation_layout" in l)
            return line.split("entry_computation_layout=", 1)[1]

        assert sig(a) == sig(b)

    def test_lowering_deterministic(self):
        w = make_weights(TINY)
        a = lower_variant(TINY, w, 1, "ref")
        b = lower_variant(TINY, w, 1, "ref")
        assert a == b


@pytest.mark.skipif(not os.path.exists(os.path.join(ART, "manifest.json")),
                    reason="artifacts not built (run `make artifacts`)")
class TestManifest:
    @pytest.fixture(scope="class")
    def manifest(self):
        with open(os.path.join(ART, "manifest.json")) as f:
            return json.load(f)

    def test_all_zoo_models_present(self, manifest):
        assert set(MODELS) <= set(manifest["models"])

    def test_variant_files_exist(self, manifest):
        for name, entry in manifest["models"].items():
            for t, var in entry["variants"].items():
                path = os.path.join(ART, var["path"])
                assert os.path.exists(path), path
                assert os.path.getsize(path) == var["hlo_bytes"]

    def test_decode_variants_complete(self, manifest):
        for name, entry in manifest["models"].items():
            ts = {int(t) for t in entry["variants"]}
            assert set(DECODE_TOKEN_VARIANTS) <= ts, name

    def test_config_matches_zoo(self, manifest):
        for name, cfg in MODELS.items():
            got = manifest["models"][name]["config"]
            assert got["n_experts"] == cfg.n_experts
            assert got["top_k"] == cfg.top_k
            assert got["n_shared"] == cfg.n_shared
            assert got["max_seq"] == cfg.max_seq

    def test_golden_present_and_finite(self, manifest):
        for name, entry in manifest["models"].items():
            g = entry["golden"]
            assert len(g["logits_row0_head"]) == 8
            assert np.isfinite(g["logits_sum"])
            assert g["logits_abs_sum"] > 0

    def test_golden_reproducible(self, manifest):
        """Re-deriving the golden eagerly must match the manifest values —
        guards against weight/seed drift between aot runs."""
        name = "mixtral"
        cfg = MODELS[name]
        entry = manifest["models"][name]
        w = make_weights(cfg)
        step = jax.jit(make_step_fn(cfg, w, entry["golden"]["t"],
                                    impl=entry["impl"]))
        kv = jnp.zeros((cfg.layers, 2, cfg.max_seq, cfg.kv_dim), jnp.float32)
        rs = jnp.zeros((cfg.layers, cfg.hidden), jnp.float32)
        logits, topk, _, _ = step(
            jnp.array(entry["golden"]["tokens"], jnp.int32), jnp.int32(0), kv, rs)
        np.testing.assert_allclose(
            np.asarray(logits)[0, :8], entry["golden"]["logits_row0_head"],
            rtol=1e-5, atol=1e-5)
        assert np.asarray(topk).tolist() == entry["golden"]["topk_idx"]
