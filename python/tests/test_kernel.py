"""L1 correctness: Pallas kernels vs pure-jnp oracles (the CORE signal).

Hypothesis sweeps shapes/ranks; fixed cases pin the degenerate corners
(single token, unanimous routing, zero gates, fully-masked rows, block
boundaries). Tolerances are f32 accumulation-order tolerances.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import attention as attn_k
from compile.kernels import moe_ffn as moe_k
from compile.kernels import ref

RTOL, ATOL = 2e-5, 2e-5


def _moe_case(rng, t, e, k, h=32, f=16, gate_scale=1.0):
    x = jnp.asarray(rng.normal(size=(t, h)), jnp.float32)
    idx = jnp.asarray(rng.integers(0, e, size=(t, k)), jnp.int32)
    gates = jnp.asarray(rng.random(size=(t, k)) * gate_scale, jnp.float32)
    w1 = jnp.asarray(rng.normal(size=(e, h, 2 * f)) * 0.2, jnp.float32)
    w2 = jnp.asarray(rng.normal(size=(e, f, h)) * 0.2, jnp.float32)
    return x, idx, gates, w1, w2


class TestMoeFfn:
    @settings(max_examples=25, deadline=None)
    @given(
        t=st.integers(1, 16),
        e=st.sampled_from([1, 2, 8, 16, 64]),
        k=st.integers(1, 8),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_matches_ref(self, t, e, k, seed):
        k = min(k, e)
        rng = np.random.default_rng(seed)
        args = _moe_case(rng, t, e, k)
        out = moe_k.moe_ffn(*args)
        want = ref.moe_ffn_ref(*args)
        np.testing.assert_allclose(out, want, rtol=RTOL, atol=ATOL)

    def test_single_token_single_expert(self):
        rng = np.random.default_rng(0)
        args = _moe_case(rng, 1, 1, 1)
        np.testing.assert_allclose(
            moe_k.moe_ffn(*args), ref.moe_ffn_ref(*args), rtol=RTOL, atol=ATOL)

    def test_zero_gates_give_zero_output(self):
        rng = np.random.default_rng(1)
        x, idx, _, w1, w2 = _moe_case(rng, 4, 8, 2)
        gates = jnp.zeros_like(idx, dtype=jnp.float32)
        out = moe_k.moe_ffn(x, idx, gates, w1, w2)
        np.testing.assert_allclose(out, jnp.zeros_like(x), atol=1e-7)

    def test_all_tokens_one_expert(self):
        """Unanimous routing == plain dense SwiGLU through that expert."""
        rng = np.random.default_rng(2)
        x, _, _, w1, w2 = _moe_case(rng, 6, 8, 2)
        idx = jnp.full((6, 2), 3, jnp.int32)
        gates = jnp.concatenate(
            [jnp.full((6, 1), 0.25), jnp.full((6, 1), 0.75)], axis=1
        ).astype(jnp.float32)
        out = moe_k.moe_ffn(x, idx, gates, w1, w2)
        h = x @ w1[3]
        f = w1.shape[2] // 2
        dense = (ref.silu(h[:, :f]) * h[:, f:]) @ w2[3]
        np.testing.assert_allclose(out, dense, rtol=RTOL, atol=ATOL)

    def test_duplicate_expert_in_topk_sums_gates(self):
        """idx [e, e] with gates [a, b] must equal idx [e] with gate a+b."""
        rng = np.random.default_rng(3)
        x, _, _, w1, w2 = _moe_case(rng, 3, 4, 2)
        idx2 = jnp.full((3, 2), 1, jnp.int32)
        g2 = jnp.asarray(rng.random(size=(3, 2)), jnp.float32)
        idx1 = jnp.full((3, 1), 1, jnp.int32)
        g1 = jnp.sum(g2, axis=1, keepdims=True)
        np.testing.assert_allclose(
            moe_k.moe_ffn(x, idx2, g2, w1, w2),
            moe_k.moe_ffn(x, idx1, g1, w1, w2),
            rtol=RTOL, atol=ATOL)

    def test_linearity_in_gates(self):
        rng = np.random.default_rng(4)
        x, idx, gates, w1, w2 = _moe_case(rng, 5, 8, 2)
        np.testing.assert_allclose(
            moe_k.moe_ffn(x, idx, 2.0 * gates, w1, w2),
            2.0 * moe_k.moe_ffn(x, idx, gates, w1, w2),
            rtol=RTOL, atol=ATOL)


def _attn_case(rng, t, s, hh=2, d=8, cache_len=None):
    q = jnp.asarray(rng.normal(size=(t, hh, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(s, hh, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(s, hh, d)), jnp.float32)
    if cache_len is None:
        cache_len = int(rng.integers(0, s - t + 1))
    pos = cache_len + jnp.arange(t)
    mask = jnp.arange(s)[None, :] <= pos[:, None]
    return q, k, v, mask, 1.0 / (d ** 0.5)


class TestAttention:
    @settings(max_examples=25, deadline=None)
    @given(
        t=st.integers(1, 8),
        s=st.sampled_from([64, 128, 256, 384]),
        hh=st.sampled_from([1, 2, 4]),
        block_s=st.sampled_from([32, 64, 128]),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_matches_ref(self, t, s, hh, block_s, seed):
        rng = np.random.default_rng(seed)
        q, k, v, mask, scale = _attn_case(rng, t, s, hh=hh)
        out = attn_k.attention(q, k, v, mask, scale, block_s=block_s)
        want = ref.attention_ref(q, k, v, mask, scale)
        np.testing.assert_allclose(out, want, rtol=RTOL, atol=ATOL)

    def test_cache_len_zero(self):
        """First decode step: only position 0 is attendable."""
        rng = np.random.default_rng(5)
        q, k, v, mask, scale = _attn_case(rng, 1, 128, cache_len=0)
        out = attn_k.attention(q, k, v, mask, scale)
        np.testing.assert_allclose(out, v[0][None], rtol=RTOL, atol=ATOL)

    def test_block_boundary_mask(self):
        """cache_len exactly at a KV-block boundary."""
        rng = np.random.default_rng(6)
        q, k, v, mask, scale = _attn_case(rng, 4, 256, cache_len=128)
        out = attn_k.attention(q, k, v, mask, scale, block_s=128)
        want = ref.attention_ref(q, k, v, mask, scale)
        np.testing.assert_allclose(out, want, rtol=RTOL, atol=ATOL)

    def test_mask_excludes_stale_cache(self):
        """Entries beyond the causal horizon must not affect the output."""
        rng = np.random.default_rng(7)
        q, k, v, mask, scale = _attn_case(rng, 2, 64, cache_len=10)
        out1 = attn_k.attention(q, k, v, mask, scale)
        k2 = k.at[20:].set(999.0)
        v2 = v.at[20:].set(-999.0)
        out2 = attn_k.attention(q, k2, v2, mask, scale)
        np.testing.assert_allclose(out1, out2, rtol=RTOL, atol=ATOL)

    def test_full_mask_row_is_finite(self):
        """A fully-masked query row must not produce NaNs (guarded norm)."""
        rng = np.random.default_rng(8)
        q, k, v, _, scale = _attn_case(rng, 2, 64, cache_len=0)
        mask = jnp.zeros((2, 64), bool).at[1, :4].set(True)
        out = attn_k.attention(q, k, v, mask, scale)
        assert bool(jnp.all(jnp.isfinite(out)))
