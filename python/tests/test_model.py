"""L2 contracts: step-function shapes, KV-cache consistency, impl parity,
router affinity behaviour, shared experts, dense baseline."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.configs import MODELS, ModelConfig
from compile.model import make_step_fn
from compile.weights import make_weights

SMALL = ModelConfig(name="tiny", mirrors="test", hidden=32, layers=2, heads=2,
                    head_dim=8, vocab=64, ffn=32, n_experts=4, top_k=2,
                    max_seq=64, prefill_chunk=8, seed=7)


def _fresh_state(cfg):
    kv = jnp.zeros((cfg.layers, 2, cfg.max_seq, cfg.kv_dim), jnp.float32)
    rs = jnp.zeros((cfg.layers, cfg.hidden), jnp.float32)
    return kv, rs


@pytest.fixture(scope="module")
def tiny_weights():
    return make_weights(SMALL)


class TestStepContract:
    def test_output_shapes(self, tiny_weights):
        step = jax.jit(make_step_fn(SMALL, tiny_weights, 3, impl="ref"))
        kv, rs = _fresh_state(SMALL)
        logits, topk, kv2, rs2 = step(jnp.array([1, 2, 3], jnp.int32), jnp.int32(0), kv, rs)
        assert logits.shape == (3, SMALL.vocab)
        assert topk.shape == (SMALL.layers, 3, SMALL.top_k)
        assert kv2.shape == kv.shape
        assert rs2.shape == (SMALL.layers, 3, SMALL.hidden)  # per-token trajectory
        assert topk.dtype == jnp.int32

    def test_topk_in_range(self, tiny_weights):
        step = jax.jit(make_step_fn(SMALL, tiny_weights, 4, impl="ref"))
        kv, rs = _fresh_state(SMALL)
        _, topk, _, _ = step(jnp.array([5, 6, 7, 8], jnp.int32), jnp.int32(0), kv, rs)
        assert bool(jnp.all((topk >= 0) & (topk < SMALL.n_experts)))

    def test_pallas_matches_ref(self, tiny_weights):
        kv, rs = _fresh_state(SMALL)
        toks = jnp.array([3, 1, 4, 1, 5], jnp.int32)
        outs = {}
        for impl in ("ref", "pallas"):
            step = jax.jit(make_step_fn(SMALL, tiny_weights, 5, impl=impl))
            outs[impl] = step(toks, jnp.int32(0), kv, rs)
        np.testing.assert_allclose(outs["ref"][0], outs["pallas"][0], rtol=3e-5, atol=3e-5)
        np.testing.assert_allclose(outs["ref"][1], outs["pallas"][1])

    def test_incremental_equals_batch(self, tiny_weights):
        """Feeding tokens one at a time through the KV cache must reproduce
        the one-shot batch logits — the invariant speculation relies on."""
        toks = [2, 9, 17, 33, 40, 41]
        batch = jax.jit(make_step_fn(SMALL, tiny_weights, len(toks), impl="ref"))
        kv, rs = _fresh_state(SMALL)
        blogits, btopk, _, _ = batch(jnp.array(toks, jnp.int32), jnp.int32(0), kv, rs)

        one = jax.jit(make_step_fn(SMALL, tiny_weights, 1, impl="ref"))
        kv, rs = _fresh_state(SMALL)
        for i, tk in enumerate(toks):
            lg, tp, kv, rsq = one(jnp.array([tk], jnp.int32), jnp.int32(i), kv, rs)
            rs = rsq[:, 0, :]
            np.testing.assert_allclose(lg[0], blogits[i], rtol=3e-5, atol=3e-5)
            np.testing.assert_allclose(tp[:, 0], btopk[:, i])

    def test_rejected_tokens_overwritten(self, tiny_weights):
        """Speculative KV slots written past cache_len must be harmlessly
        overwritten when the next step reuses those positions."""
        one = jax.jit(make_step_fn(SMALL, tiny_weights, 1, impl="ref"))
        three = jax.jit(make_step_fn(SMALL, tiny_weights, 3, impl="ref"))
        # Run A: verify 3 tokens at cache_len=2, accept only the first,
        # then decode token X at cache_len=3.
        kv, rs = _fresh_state(SMALL)
        for i, tk in enumerate([1, 2]):
            _, _, kv, rsq = one(jnp.array([tk], jnp.int32), jnp.int32(i), kv, rs)
            rs = rsq[:, 0, :]
        kv_a, rs_a = kv, rs
        _, _, kv_spec, _ = three(jnp.array([7, 8, 9], jnp.int32), jnp.int32(2), kv_a, rs_a)
        lg_a, _, _, _ = one(jnp.array([7], jnp.int32), jnp.int32(2), kv_spec, rs_a)
        # Run B: same prefix, no speculation ever happened.
        lg_b, _, _, _ = one(jnp.array([7], jnp.int32), jnp.int32(2), kv_a, rs_a)
        np.testing.assert_allclose(lg_a[0], lg_b[0], rtol=3e-5, atol=3e-5)

    def test_determinism(self, tiny_weights):
        step = jax.jit(make_step_fn(SMALL, tiny_weights, 2, impl="ref"))
        kv, rs = _fresh_state(SMALL)
        a = step(jnp.array([1, 2], jnp.int32), jnp.int32(0), kv, rs)
        b = step(jnp.array([1, 2], jnp.int32), jnp.int32(0), kv, rs)
        np.testing.assert_array_equal(a[0], b[0])


class TestAffinity:
    def _unique_expert_rate(self, affinity, seed=11, steps=48):
        cfg = ModelConfig(name="aff", mirrors="test", hidden=32, layers=1,
                          heads=2, head_dim=8, vocab=64, ffn=32, n_experts=16,
                          top_k=2, max_seq=64, prefill_chunk=8,
                          affinity=affinity, seed=seed)
        w = make_weights(cfg)
        step = jax.jit(make_step_fn(cfg, w, 1, impl="ref"))
        kv, rs = _fresh_state(cfg)
        rng = np.random.default_rng(seed)
        picks = []
        for i in range(steps):
            tk = int(rng.integers(0, cfg.vocab))
            _, topk, kv, rsq = step(jnp.array([tk], jnp.int32), jnp.int32(i), kv, rs)
            rs = rsq[:, 0, :]
            picks.append(set(np.asarray(topk[0, 0]).tolist()))
        # fraction of experts reused from the immediately previous token
        reuse = [len(a & b) / cfg.top_k for a, b in zip(picks, picks[1:])]
        return float(np.mean(reuse))

    def test_affinity_increases_expert_reuse(self):
        """The paper's expert-token affinity knob: higher affinity ⇒
        consecutive tokens reuse experts more (cheaper verification)."""
        low = self._unique_expert_rate(0.0)
        high = self._unique_expert_rate(0.9)
        assert high > low + 0.2, (low, high)


class TestZoo:
    @pytest.mark.parametrize("name", ["deepseek", "qwen"])
    def test_shared_experts_contribute(self, name):
        """Zeroing shared-expert weights must change the output."""
        cfg = MODELS[name]
        w = make_weights(cfg)
        step = jax.jit(make_step_fn(cfg, w, 1, impl="ref"))
        kv, rs = _fresh_state(cfg)
        lg, _, _, _ = step(jnp.array([9], jnp.int32), jnp.int32(0), kv, rs)

        w2 = jax.tree_util.tree_map(lambda x: x, w)
        for layer in w2["layers"]:
            layer["shared_w2"] = jnp.zeros_like(layer["shared_w2"])
        step2 = jax.jit(make_step_fn(cfg, w2, 1, impl="ref"))
        lg2, _, _, _ = step2(jnp.array([9], jnp.int32), jnp.int32(0), kv, rs)
        assert float(jnp.max(jnp.abs(lg - lg2))) > 1e-4

    def test_dense_model_emits_sentinel_topk(self):
        cfg = MODELS["llama"]
        w = make_weights(cfg)
        step = jax.jit(make_step_fn(cfg, w, 2, impl="ref"))
        kv, rs = _fresh_state(cfg)
        _, topk, _, _ = step(jnp.array([1, 2], jnp.int32), jnp.int32(0), kv, rs)
        assert bool(jnp.all(topk == -1))

    @pytest.mark.parametrize("name", list(MODELS))
    def test_zoo_step_runs(self, name):
        cfg = MODELS[name]
        w = make_weights(cfg)
        step = jax.jit(make_step_fn(cfg, w, 2, impl="ref"))
        kv, rs = _fresh_state(cfg)
        lg, topk, _, _ = step(jnp.array([1, 2], jnp.int32), jnp.int32(0), kv, rs)
        assert bool(jnp.all(jnp.isfinite(lg)))
        kr = max(cfg.top_k, 1)
        assert topk.shape == (cfg.layers, 2, kr)

    def test_weights_deterministic(self):
        a = make_weights(MODELS["mixtral"])
        b = make_weights(MODELS["mixtral"])
        np.testing.assert_array_equal(a["embed"], b["embed"])
        np.testing.assert_array_equal(a["layers"][0]["router"], b["layers"][0]["router"])
