//! Quickstart: load an AOT-compiled MoE, serve one request with Cascade,
//! and print the decode trace.
//!
//!     make artifacts && cargo run --release --example quickstart
//!
//! This is the smallest end-to-end path through all three layers: the
//! Pallas/JAX artifacts (L1/L2) execute under PJRT while the Rust
//! coordinator (L3) drafts, verifies, rejection-samples, and lets the
//! Cascade manager tune the speculation length from measured utility.

use cascade::config::EngineConfig;
use cascade::coordinator::engine::Engine;
use cascade::models::{default_artifacts_dir, Registry};
use cascade::spec::policy::PolicyKind;
use cascade::workload::{RequestStream, Task, Workload};

fn main() -> anyhow::Result<()> {
    let registry = Registry::load(default_artifacts_dir())?;

    // A Mixtral-topology MoE (8 experts, top-2) with the Cascade policy.
    let cfg = EngineConfig { model: "mixtral".into(), ..Default::default() };
    let mut engine = Engine::real(&registry, cfg, PolicyKind::parse("cascade")?.build())?;

    // One code-generation request (synthetic HumanEval-like workload).
    let mut stream = RequestStream::new(Workload::single(Task::Code), 7, 200);
    let request = stream.next_request();
    println!(
        "prompt ({} tokens):\n{}",
        request.prompt.len(),
        cascade::tokenizer::decode(&request.prompt)
    );

    let metrics = engine.serve_request(&request)?;

    println!("--- decode trace (first 24 iterations) ---");
    println!("{:>4} {:>6} {:>8} {:>9} {:>9} {:>10}", "iter", "K", "drafted", "accepted", "phase", "iter-time");
    for (i, it) in metrics.iters.iter().take(24).enumerate() {
        println!(
            "{:>4} {:>6} {:>8} {:>9} {:>9?} {:>9.2}ms",
            i,
            it.k_chosen,
            it.drafted,
            it.accepted,
            it.phase,
            it.cost.total() * 1e3
        );
    }

    println!("\n--- summary ---");
    println!("tokens emitted     : {}", metrics.tokens_emitted());
    println!("iterations         : {}", metrics.iters.len());
    println!("effective token rate: {:.2} tok/iter", metrics.etr());
    println!("TPOT (simulated GPU): {:.2} ms", metrics.tpot_s() * 1e3);
    println!(
        "speedup vs 1 tok/iter at baseline cost: {:.2}x",
        (engine.cost.baseline_cost().total() / metrics.tpot_s())
    );
    Ok(())
}
