//! Draft-model speculation (paper §7.3): EAGLE-lite on Mixtral.
//!
//! Runs the real AOT `draft` model as the drafter: the drafter keeps its
//! own KV cache in sync with the target (ingesting emitted tokens even
//! when speculation is disabled — the dynamic-disable support the paper
//! added to vLLM, §6), proposes K tokens by K single-token draft steps,
//! and the target verifies. Compare the utility landscape against n-gram:
//! higher drafting cost (~5%/K) but higher acceptance, so K=1 becomes the
//! sweet spot and static-K stops losing (paper Fig. 17).
//!
//!     make artifacts && cargo run --release --example eagle_speculation

use cascade::config::{DrafterKind, EngineConfig};
use cascade::coordinator::engine::Engine;
use cascade::coordinator::scheduler::{Budget, Scheduler};
use cascade::models::{default_artifacts_dir, Registry};
use cascade::spec::policy::PolicyKind;
use cascade::util::table::Table;
use cascade::workload::{RequestStream, Workload};

fn main() -> anyhow::Result<()> {
    let registry = Registry::load(default_artifacts_dir())?;

    let mut table = Table::new(
        "mixtral + EAGLE-lite vs n-gram (real backend, math task)",
        &["drafter", "policy", "TPOT(sim)", "ETR", "speedup vs k0"],
    );

    for drafter in [DrafterKind::Ngram, DrafterKind::EagleLite] {
        let mut base_tpot = None;
        for policy in ["k0", "k1", "k3", "cascade"] {
            let cfg = EngineConfig { model: "mixtral".into(), drafter, ..Default::default() };
            let mut engine = Engine::real(&registry, cfg, PolicyKind::parse(policy)?.build())?;
            let stream =
                RequestStream::new(Workload::by_name("math").unwrap(), 0xEA61E, 200);
            let mut sched =
                Scheduler::new(stream, Budget { max_tokens: 400, max_requests: 100 });
            let run = sched.run(&mut engine)?;
            let tpot = run.tpot_s();
            if policy == "k0" {
                base_tpot = Some(tpot);
            }
            table.row(vec![
                format!("{drafter:?}"),
                policy.into(),
                format!("{:.2}ms", tpot * 1e3),
                format!("{:.2}", run.mean_etr()),
                format!("{:.2}x", base_tpot.unwrap() / tpot),
            ]);
        }
    }
    println!("{}", table.render());
    println!(
        "Expected shape (paper 7.3): with n-gram, math loses at every static K;\n\
         with the higher-accuracy draft model the losses shrink or flip, and\n\
         Cascade matches the best column in both drafter regimes."
    );
    Ok(())
}
