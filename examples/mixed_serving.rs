//! End-to-end serving driver (the EXPERIMENTS.md validation run).
//!
//! Serves a realistic *mixed* request stream — the paper's all-3 workload
//! (33% code / 33% math / 33% extraction) — on the real AOT-compiled
//! Mixtral-topology MoE, comparing a static-K baseline against Cascade,
//! and reports latency + throughput on both the simulated-GPU clock and
//! the host wall clock.
//!
//!     make artifacts && cargo run --release --example mixed_serving

use cascade::config::EngineConfig;
use cascade::coordinator::engine::Engine;
use cascade::coordinator::scheduler::{Budget, Scheduler};
use cascade::models::{default_artifacts_dir, Registry};
use cascade::spec::policy::PolicyKind;
use cascade::util::table::Table;
use cascade::workload::{RequestStream, Workload};
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    let registry = Registry::load(default_artifacts_dir())?;
    let workload = Workload::by_name("all-3").unwrap();
    let budget = Budget { max_tokens: 600, max_requests: 100 };

    let mut table = Table::new(
        "mixed serving: mixtral + all-3 (real backend)",
        &["policy", "requests", "tokens", "TPOT(sim)", "tok/s(sim)", "ETR", "test%", "wall s", "tok/s(host)"],
    );

    for policy in ["k0", "k1", "k3", "cascade"] {
        let cfg = EngineConfig { model: "mixtral".into(), ..Default::default() };
        let mut engine = Engine::real(&registry, cfg, PolicyKind::parse(policy)?.build())?;
        let stream = RequestStream::new(workload.clone(), 0xA113, 200);
        let mut sched = Scheduler::new(stream, budget);

        let t0 = Instant::now();
        let run = sched.run(&mut engine)?;
        let wall = t0.elapsed().as_secs_f64();

        table.row(vec![
            policy.into(),
            run.requests.len().to_string(),
            run.total_tokens().to_string(),
            format!("{:.2}ms", run.tpot_s() * 1e3),
            format!("{:.1}", run.throughput()),
            format!("{:.2}", run.mean_etr()),
            format!("{:.1}%", 100.0 * run.test_phase_fraction()),
            format!("{wall:.1}"),
            format!("{:.0}", run.total_tokens() as f64 / wall),
        ]);
    }
    println!("{}", table.render());
    println!(
        "Cascade should match or beat the best static K overall while never\n\
         suffering the math-task slowdown the static rows show (paper Fig. 13)."
    );
    Ok(())
}
