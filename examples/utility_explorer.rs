//! Utility explorer: visualize the paper's central quantity.
//!
//! Serves one request per task on a chosen model at a static K and prints
//! the windowed (ETR, cost, utility) trace — the raw material of paper
//! Figs. 6/7/15 — as ASCII sparklines, plus where Cascade would have
//! switched.
//!
//!     cargo run --release --example utility_explorer [model] [k]

use cascade::config::EngineConfig;
use cascade::coordinator::engine::Engine;
use cascade::models::{default_artifacts_dir, Registry};
use cascade::spec::policy::PolicyKind;
use cascade::workload::{RequestStream, Task, Workload};

fn spark(xs: &[f64], lo: f64, hi: f64) -> String {
    const BARS: [char; 8] = ['▁', '▂', '▃', '▄', '▅', '▆', '▇', '█'];
    xs.iter()
        .map(|&x| {
            let t = ((x - lo) / (hi - lo)).clamp(0.0, 1.0);
            BARS[(t * 7.0).round() as usize]
        })
        .collect()
}

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let model = args.get(1).cloned().unwrap_or_else(|| "mixtral".into());
    let k: usize = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(3);

    let registry = Registry::load(default_artifacts_dir())?;
    println!("model={model} static K={k}; windows of 16 iterations\n");

    for task in [Task::Code, Task::Math, Task::Extract] {
        // Baseline for cost normalization.
        let cfg = EngineConfig { model: model.clone(), ..Default::default() };
        let mut base_engine = Engine::real(&registry, cfg, PolicyKind::Static(0).build())?;
        let mut stream = RequestStream::new(Workload::single(task), 99, 200);
        let req = stream.next_request();
        let base = base_engine.serve_request(&req)?;
        let base_iter = base.mean_iter_s();

        let cfg = EngineConfig { model: model.clone(), ..Default::default() };
        let mut engine = Engine::real(&registry, cfg, PolicyKind::Static(k).build())?;
        let m = engine.serve_request(&req)?;
        let wins = m.utility_windows(16, base_iter);
        let utils: Vec<f64> = wins.iter().map(|w| w.utility).collect();
        let etrs: Vec<f64> = wins.iter().map(|w| w.etr).collect();
        let costs: Vec<f64> = wins.iter().map(|w| w.cost).collect();

        println!("== {} ==", task.name());
        println!("  ETR     {}  (1.0 .. {:.1})", spark(&etrs, 1.0, 4.0), 4.0);
        println!("  cost    {}  (1.0 .. 3.0)", spark(&costs, 1.0, 3.0));
        println!("  utility {}  (0.5 .. 2.0)", spark(&utils, 0.5, 2.0));
        let mean_u = utils.iter().sum::<f64>() / utils.len().max(1) as f64;
        let verdict = if mean_u >= 1.0 { "KEEP speculating" } else { "DISABLE (utility < 1)" };
        println!(
            "  mean utility {mean_u:.2} -> Cascade would {verdict}; measured TPOT {:.2}ms vs baseline {:.2}ms\n",
            m.tpot_s() * 1e3,
            base.tpot_s() * 1e3
        );
    }
    Ok(())
}
