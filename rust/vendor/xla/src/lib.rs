//! Offline stub of the `xla` PJRT bindings.
//!
//! The real crate wraps the PJRT C API (client, compiled executables,
//! device buffers). This offline build carries no native XLA runtime, so:
//!
//! * Host-side containers ([`Literal`]) are fully functional — shapes,
//!   zero-init, typed reads — because request-state bookkeeping uses them.
//! * Everything that would touch the PJRT C API ([`PjRtClient::cpu`],
//!   compilation, npz reading) returns [`XlaError::Unavailable`]. The
//!   serving stack's *real* backend surfaces that error cleanly at startup;
//!   the *sim* backend never reaches this crate.
//!
//! The API mirrors the subset of the bindings the workspace uses, so a
//! PJRT-enabled build can swap the real crate back in without source edits.

use std::fmt;
use std::path::Path;

/// Errors surfaced by the (stubbed) XLA layer.
#[derive(Debug, Clone)]
pub enum XlaError {
    /// The native PJRT runtime is not part of this build.
    Unavailable(String),
    /// The operation is not meaningful on a host-only literal.
    Unsupported(String),
}

impl fmt::Display for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            XlaError::Unavailable(m) => write!(f, "xla unavailable: {m}"),
            XlaError::Unsupported(m) => write!(f, "xla unsupported: {m}"),
        }
    }
}

impl std::error::Error for XlaError {}

pub type Result<T> = std::result::Result<T, XlaError>;

fn unavailable<T>(what: &str) -> Result<T> {
    Err(XlaError::Unavailable(format!(
        "{what}: this build has no native PJRT runtime (offline stub); \
         the sim backend (`--backend sim`) runs without it"
    )))
}

/// Element dtypes the workspace stores in literals.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PrimitiveType {
    F32,
    S32,
}

/// The bindings expose the same enum under both names.
pub type ElementType = PrimitiveType;

impl PrimitiveType {
    fn byte_size(self) -> usize {
        4
    }
}

/// Typed element access for [`Literal::to_vec`].
pub trait NativeType: Sized + Copy {
    fn read_le(bytes: &[u8]) -> Self;
}

impl NativeType for f32 {
    fn read_le(bytes: &[u8]) -> Self {
        f32::from_le_bytes([bytes[0], bytes[1], bytes[2], bytes[3]])
    }
}

impl NativeType for i32 {
    fn read_le(bytes: &[u8]) -> Self {
        i32::from_le_bytes([bytes[0], bytes[1], bytes[2], bytes[3]])
    }
}

/// Host-side tensor: dtype + dims + raw little-endian bytes.
#[derive(Debug, Clone)]
pub struct Literal {
    pub ty: PrimitiveType,
    pub dims: Vec<usize>,
    pub data: Vec<u8>,
}

impl Literal {
    /// Rank-1 i32 literal.
    pub fn vec1(v: &[i32]) -> Self {
        let mut data = Vec::with_capacity(v.len() * 4);
        for x in v {
            data.extend_from_slice(&x.to_le_bytes());
        }
        Self { ty: PrimitiveType::S32, dims: vec![v.len()], data }
    }

    /// Rank-0 i32 literal.
    pub fn scalar(v: i32) -> Self {
        Self { ty: PrimitiveType::S32, dims: Vec::new(), data: v.to_le_bytes().to_vec() }
    }

    /// Zero-initialized literal of the given shape.
    pub fn create_from_shape(ty: PrimitiveType, dims: &[usize]) -> Self {
        let elems: usize = dims.iter().product();
        Self { ty, dims: dims.to_vec(), data: vec![0u8; elems * ty.byte_size()] }
    }

    /// Literal over caller-provided raw bytes.
    pub fn create_from_shape_and_untyped_data(
        ty: ElementType,
        dims: &[usize],
        data: &[u8],
    ) -> Result<Self> {
        let elems: usize = dims.iter().product();
        if data.len() != elems * ty.byte_size() {
            return Err(XlaError::Unsupported(format!(
                "shape {dims:?} needs {} bytes, got {}",
                elems * ty.byte_size(),
                data.len()
            )));
        }
        Ok(Self { ty, dims: dims.to_vec(), data: data.to_vec() })
    }

    /// Copy out as a typed host vector.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        Ok(self.data.chunks_exact(4).map(T::read_le).collect())
    }

    /// Decompose a tuple literal — only produced by executions, which this
    /// stub cannot perform.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Err(XlaError::Unsupported("host literals are not tuples".into()))
    }
}

/// Deserialization hooks (the real crate reads npz archives through this).
pub trait FromRawBytes: Sized {
    type Context: ?Sized;
    fn read_npz(path: impl AsRef<Path>, ctx: &Self::Context) -> Result<Vec<(String, Self)>>;
}

impl FromRawBytes for Literal {
    type Context = ();

    fn read_npz(path: impl AsRef<Path>, _ctx: &Self::Context) -> Result<Vec<(String, Self)>> {
        unavailable(&format!("reading npz {:?}", path.as_ref()))
    }
}

/// Parsed HLO module (opaque here).
#[derive(Debug, Clone)]
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file(path: impl AsRef<Path>) -> Result<Self> {
        unavailable(&format!("parsing HLO text {:?}", path.as_ref()))
    }
}

/// A computation handed to the compiler (opaque here).
#[derive(Debug, Clone)]
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        Self { _private: () }
    }
}

/// Device-resident buffer (never constructible in the stub).
#[derive(Debug)]
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("fetching device buffer")
    }
}

/// Compiled executable (never constructible in the stub).
#[derive(Debug)]
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute_b<B>(&self, _args: &[B]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("executing compiled HLO")
    }
}

/// PJRT client handle.
#[derive(Debug, Clone)]
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    /// In the real bindings this starts the CPU PJRT plugin; the stub
    /// reports the runtime as absent.
    pub fn cpu() -> Result<Self> {
        unavailable("creating PJRT CPU client")
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable("compiling HLO")
    }

    pub fn buffer_from_host_literal(
        &self,
        _device: Option<usize>,
        _lit: &Literal,
    ) -> Result<PjRtBuffer> {
        unavailable("uploading literal")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_i32() {
        let l = Literal::vec1(&[1, -2, 3]);
        assert_eq!(l.dims, vec![3]);
        assert_eq!(l.to_vec::<i32>().unwrap(), vec![1, -2, 3]);
    }

    #[test]
    fn zero_literal_shape() {
        let l = Literal::create_from_shape(PrimitiveType::F32, &[2, 3]);
        assert_eq!(l.data.len(), 24);
        assert!(l.to_vec::<f32>().unwrap().iter().all(|&x| x == 0.0));
    }

    #[test]
    fn untyped_data_size_checked() {
        assert!(Literal::create_from_shape_and_untyped_data(
            ElementType::F32,
            &[2],
            &[0u8; 8]
        )
        .is_ok());
        assert!(Literal::create_from_shape_and_untyped_data(
            ElementType::F32,
            &[2],
            &[0u8; 7]
        )
        .is_err());
    }

    #[test]
    fn pjrt_reports_unavailable() {
        let e = PjRtClient::cpu().unwrap_err();
        assert!(e.to_string().contains("PJRT"));
    }
}
