//! Offline in-tree substrate of the `anyhow` crate.
//!
//! The vendor set carries no crates.io sources, so this crate implements the
//! subset of anyhow the workspace actually uses: a string-chaining [`Error`],
//! the [`Result`] alias, the `anyhow!` / `bail!` / `ensure!` macros, and the
//! [`Context`] extension trait for `Result` and `Option`.

use std::fmt;

/// A flattened error: the original message plus any context lines prepended
/// by [`Context`].
pub struct Error {
    msg: String,
}

impl Error {
    /// Build an error from anything displayable.
    pub fn msg<M: fmt::Display>(m: M) -> Self {
        Self { msg: m.to_string() }
    }

    /// Prepend a higher-level context line.
    pub fn context<C: fmt::Display>(self, c: C) -> Self {
        Self { msg: format!("{c}: {}", self.msg) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

// Like real anyhow: `Error` deliberately does not implement
// `std::error::Error`, which keeps this blanket conversion coherent.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        Self::msg(e)
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to failures (`Result`) or absences (`Option`).
pub trait Context<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.map_err(|e| e.into().context(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error when a condition does not hold.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: {}", stringify!($cond));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<()> {
        std::fs::read_to_string("/definitely/not/a/file").context("reading config")?;
        Ok(())
    }

    #[test]
    fn context_chains() {
        let e = io_fail().unwrap_err();
        assert!(e.to_string().starts_with("reading config: "));
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = Context::context(v, "missing").unwrap_err();
        assert_eq!(e.to_string(), "missing");
    }

    #[test]
    fn macros() {
        fn f(x: usize) -> Result<usize> {
            ensure!(x < 10, "x too big: {x}");
            if x == 3 {
                bail!("three is right out");
            }
            Ok(x)
        }
        assert_eq!(f(2).unwrap(), 2);
        assert_eq!(f(12).unwrap_err().to_string(), "x too big: 12");
        assert_eq!(f(3).unwrap_err().to_string(), "three is right out");
        let e = anyhow!("code {}", 7);
        assert_eq!(e.to_string(), "code 7");
    }

    #[test]
    fn anyhow_result_takes_more_context() {
        let r: Result<()> = Err(anyhow!("inner"));
        let e = r.with_context(|| "outer").unwrap_err();
        assert_eq!(e.to_string(), "outer: inner");
    }
}
