//! Continuous-batching integration (sim backend; no artifacts needed):
//!
//! * batch=1 `BatchEngine` reproduces `Engine::serve_request` **token for
//!   token** (and iteration for iteration) — batching must never change
//!   outputs, only latency;
//! * batch=4 runs report occupancy and cross-request expert overlap, and
//!   per-iteration expert cost grows sub-linearly in batch size;
//! * the shared KV pool stays within budget under engine load;
//! * pipelined drafting (draft i+1 under verify i) is lossless: identical
//!   outputs and iteration structure across drafters and batch sizes, a
//!   simulated clock never slower than serial, and a strict TPOT win
//!   wherever the lookahead hits;
//! * regression: guided sampling past the reference end is unguided, not
//!   steered to EOS (long generations must not silently truncate).

use cascade::config::{DrafterKind, EngineConfig};
use cascade::coordinator::batch::BatchEngine;
use cascade::coordinator::engine::Engine;
use cascade::metrics::BatchRunMetrics;
use cascade::models::{default_artifacts_dir, Registry};
use cascade::spec::policy::PolicyKind;
use cascade::workload::{Request, RequestStream, Task, Workload};

fn registry() -> Registry {
    Registry::load_or_builtin(default_artifacts_dir())
}

fn requests(task: &str, n: usize, max_new: usize) -> Vec<Request> {
    let w = Workload::by_name(task).unwrap();
    RequestStream::new(w, 0xCA5CADE, max_new).take(n)
}

fn batch_serve_cfg(cfg: EngineConfig, policy: PolicyKind, reqs: &[Request]) -> BatchRunMetrics {
    let reg = registry();
    let mut engine = BatchEngine::sim(&reg, cfg, policy).unwrap();
    engine.serve_all(reqs).unwrap()
}

fn batch_serve(
    model: &str,
    policy: PolicyKind,
    drafter: DrafterKind,
    batch: usize,
    reqs: &[Request],
) -> BatchRunMetrics {
    let cfg = EngineConfig {
        model: model.into(),
        drafter,
        max_batch: batch,
        ..Default::default()
    };
    batch_serve_cfg(cfg, policy, reqs)
}

/// Simulated decode clock of a batched run: Σ fused iteration cost.
fn batch_clock_s(m: &BatchRunMetrics) -> f64 {
    m.iters.iter().map(|r| r.cost.total()).sum()
}

#[test]
fn batch1_matches_single_request_engine_token_for_token() {
    let reg = registry();
    for (model, policy, drafter) in [
        ("mixtral", PolicyKind::Static(3), DrafterKind::Ngram),
        ("mixtral", PolicyKind::Cascade(Default::default()), DrafterKind::Ngram),
        ("olmoe", PolicyKind::Static(2), DrafterKind::EagleLite),
        ("llama", PolicyKind::Static(3), DrafterKind::Ngram),
    ] {
        let reqs = requests("code+math", 3, 120);

        let cfg = EngineConfig { model: model.into(), drafter, ..Default::default() };
        let mut single = Engine::sim(&reg, cfg, policy.build()).unwrap();
        let single_run = single.serve_all(&reqs).unwrap();

        let batched = batch_serve(model, policy.clone(), drafter, 1, &reqs);

        assert_eq!(single_run.requests.len(), batched.run.requests.len());
        for (s, b) in single_run.requests.iter().zip(&batched.run.requests) {
            assert_eq!(s.id, b.id);
            assert_eq!(
                s.output, b.output,
                "{model}/{}: batch=1 output diverged from the single-request engine",
                policy.label()
            );
            assert_eq!(s.iters.len(), b.iters.len(), "{model}: iteration count");
            for (si, bi) in s.iters.iter().zip(&b.iters) {
                assert_eq!(si.k_chosen, bi.k_chosen);
                assert_eq!(si.drafted, bi.drafted);
                assert_eq!(si.accepted, bi.accepted);
                assert_eq!(si.emitted, bi.emitted);
                assert!(
                    (si.cost.total() - bi.cost.total()).abs() < 1e-15,
                    "{model}: fused cost at batch=1 must equal the single-request cost"
                );
            }
        }
    }
}

#[test]
fn batch4_reports_occupancy_and_overlap() {
    let reqs = requests("code+math", 8, 120);
    let m = batch_serve(
        "mixtral",
        PolicyKind::Cascade(Default::default()),
        DrafterKind::Ngram,
        4,
        &reqs,
    );
    assert_eq!(m.run.requests.len(), 8);
    assert_eq!(m.max_batch, 4);
    assert!(m.iters.iter().any(|r| r.n_active > 1), "batching never engaged");
    assert!(m.mean_occupancy() > 0.3, "occupancy {}", m.mean_occupancy());
    // With >1 request in flight on an 8-expert model, dedup must bite.
    assert!(
        m.overlap_savings() > 0.0,
        "no cross-request expert overlap observed: {}",
        m.overlap_savings()
    );
    assert!(m.mean_batch_unique() <= 8.0 + 1e-9);
    assert!(m.mean_batch_unique() < m.mean_summed_unique());
}

#[test]
fn batch4_expert_cost_sublinear_in_batch_size() {
    // The acceptance criterion: per-iteration routed-expert cost at
    // batch=4 is far below 4x the batch=1 cost (cross-request dedup).
    let reqs = requests("code+math", 8, 120);
    for model in ["mixtral", "deepseek"] {
        let m1 = batch_serve(model, PolicyKind::Static(3), DrafterKind::Ngram, 1, &reqs);
        let m4 = batch_serve(model, PolicyKind::Static(3), DrafterKind::Ngram, 4, &reqs);
        let (e1, e4) = (m1.mean_expert_s(), m4.mean_expert_s());
        assert!(e1 > 0.0 && e4 > 0.0, "{model}: expert costs missing");
        // Sub-linear: the fused step fetches the cross-request union, so
        // 4 requests cost well under 4x one request's experts.
        assert!(
            e4 < 3.5 * e1,
            "{model}: batch=4 expert cost {e4} not sub-linear vs batch=1 {e1}"
        );
        // And batching serves the same tokens in fewer fused iterations.
        assert_eq!(m1.run.total_tokens(), m4.run.total_tokens(), "{model}: outputs changed");
        assert!(m4.iters.len() < m1.iters.len(), "{model}: no iteration fusion");
    }
}

#[test]
fn batched_outputs_identical_across_batch_sizes() {
    // Batching reorders *scheduling*, never *outputs*: each request's
    // token stream must be byte-identical at batch 1, 2, and 4.
    let reqs = requests("all-3", 6, 100);
    let runs: Vec<BatchRunMetrics> = [1usize, 2, 4]
        .iter()
        .map(|&b| {
            batch_serve("mixtral", PolicyKind::Static(2), DrafterKind::Ngram, b, &reqs)
        })
        .collect();
    for m in &runs[1..] {
        assert_eq!(m.run.requests.len(), runs[0].run.requests.len());
        for (a, b) in runs[0].run.requests.iter().zip(&m.run.requests) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.output, b.output, "request {} diverged across batch sizes", a.id);
        }
    }
}

#[test]
fn shared_pool_stays_within_budget_under_load() {
    let reg = registry();
    let cfg = EngineConfig { model: "qwen".into(), max_batch: 4, ..Default::default() };
    let mut engine = BatchEngine::sim(&reg, cfg, PolicyKind::Static(3)).unwrap();
    let reqs = requests("code+math", 6, 100);
    let mut queue: std::collections::VecDeque<Request> = reqs.into_iter().collect();
    loop {
        while engine.has_free_slot() {
            match queue.front() {
                Some(r) if engine.can_admit(r) => {
                    let r = queue.pop_front().unwrap();
                    engine.admit(r).unwrap();
                }
                _ => break,
            }
        }
        engine.pool.check_invariants().unwrap();
        assert!(engine.pool.blocks_in_use() <= engine.pool.total_blocks());
        if !engine.step_iteration().unwrap() && queue.is_empty() {
            break;
        }
    }
    let m = engine.finish();
    assert_eq!(m.run.requests.len(), 6);
    assert!(engine.pool.blocks_in_use() == 0, "all blocks released at drain");
}

#[test]
fn undersized_pool_defers_admission_but_serves_everything() {
    // Oversubscribed shared pool: 4 slots, but fewer blocks than 4 prompts
    // need — admission must wait on *blocks*, not just slots, and every
    // request must still complete without the pool exceeding its budget.
    // Sized from the actual requests: under 4 resident prompts, but with
    // room for 3 requests' full decode spans (no preemption yet, so a
    // pool below the concurrent worst case could reject mid-decode).
    let reg = registry();
    let block = 16usize; // BatchEngine's kv_block page size
    let max_new = 40usize;
    let reqs = requests("code", 6, max_new);
    let prompt_blocks = |r: &Request| r.prompt.len().div_ceil(block);
    let min_prompt = reqs.iter().map(prompt_blocks).min().unwrap();
    let span_blocks = reqs
        .iter()
        .map(|r| (r.prompt.len() + 1 + max_new).div_ceil(block) + 1)
        .max()
        .unwrap();
    let pool_blocks = (4 * min_prompt - 1).max(3 * span_blocks);
    assert!(
        pool_blocks < 4 * min_prompt,
        "test setup: pool ({pool_blocks} blocks) must not fit 4 prompts ({min_prompt} each)"
    );

    let cfg = EngineConfig {
        model: "mixtral".into(),
        max_batch: 4,
        kv_pool_blocks: pool_blocks,
        ..Default::default()
    };
    let mut engine = BatchEngine::sim(&reg, cfg, PolicyKind::Static(2)).unwrap();
    let m = engine.serve_all(&reqs).unwrap();
    assert_eq!(m.run.requests.len(), 6);
    assert_eq!(engine.pool.total_blocks(), pool_blocks);
    assert!(engine.pool.peak_blocks <= pool_blocks, "pool exceeded its budget");
    // With at most 3 prompts resident, the 4-slot batch can never fill.
    assert!(
        m.iters.iter().all(|r| r.n_active <= 3),
        "pool pressure should cap concurrency below the slot count"
    );
    assert!(m.iters.iter().any(|r| r.n_active > 1), "batching never engaged");
}

#[test]
fn generation_continues_past_reference_end() {
    // Regression for the guide bug: `ref_at` used to return Some(EOS) once
    // the reference was exhausted, so guided sampling steered every later
    // position to EOS and silently truncated long generations at
    // reference.len() + 1 tokens. Past the reference, sampling (and
    // drafting) must be unguided instead.
    let reg = registry();
    let ref_len = 20usize;
    let max_new = 80usize;
    let mut longest = 0usize;
    for id in 0..5u64 {
        let w = Workload::single(Task::Code);
        let mut stream = RequestStream::new(w, 100 + id, max_new);
        let mut req = stream.next_request();
        req.reference.truncate(ref_len);
        req.max_new_tokens = max_new;

        let cfg = EngineConfig { model: "mixtral".into(), ..Default::default() };
        let mut engine = Engine::sim(&reg, cfg, PolicyKind::Static(3).build()).unwrap();
        let m = engine.serve_request(&req).unwrap();
        // Under the old bug every run stopped at exactly ref_len + 1
        // output tokens (reference + forced EOS).
        assert!(
            m.output.len() > 10,
            "request {id} suspiciously short: {} tokens",
            m.output.len()
        );
        longest = longest.max(m.output.len());
    }
    assert!(
        longest > ref_len + 5,
        "no generation continued past the {ref_len}-token reference (longest {longest}); \
         guides past the reference must be None, not EOS"
    );
}

#[test]
fn batched_run_also_continues_past_reference_end() {
    // Same regression through the batched path (shared guide logic).
    let mut reqs = requests("code", 4, 60);
    for r in &mut reqs {
        r.reference.truncate(15);
    }
    let m = batch_serve("mixtral", PolicyKind::Static(2), DrafterKind::Ngram, 4, &reqs);
    let longest = m.run.requests.iter().map(|r| r.output.len()).max().unwrap();
    assert!(longest > 20, "batched generations truncated at the reference end: {longest}");
}

// ---------------------------------------------------------------------------
// Pipelined drafting (draft i+1 under verify i)
// ---------------------------------------------------------------------------

fn cfg_pipe(model: &str, drafter: DrafterKind, batch: usize, pipeline: bool) -> EngineConfig {
    EngineConfig {
        model: model.into(),
        drafter,
        max_batch: batch,
        pipeline,
        ..Default::default()
    }
}

#[test]
fn pipelined_outputs_identical_to_serial_across_drafters_and_batches() {
    // Losslessness: with a fixed (static) K schedule, pipelining may only
    // change *when* drafting work happens, never what tokens come out —
    // token-for-token, iteration-for-iteration.
    for (model, drafter) in [
        ("mixtral", DrafterKind::Ngram),
        ("mixtral", DrafterKind::EagleLite),
        ("qwen", DrafterKind::Ngram),
        ("llama", DrafterKind::Ngram),
    ] {
        for batch in [1usize, 2, 4] {
            let reqs = requests("code+math", 6, 100);
            let policy = PolicyKind::Static(3);
            let serial =
                batch_serve_cfg(cfg_pipe(model, drafter, batch, false), policy.clone(), &reqs);
            let piped =
                batch_serve_cfg(cfg_pipe(model, drafter, batch, true), policy.clone(), &reqs);
            assert_eq!(serial.run.requests.len(), piped.run.requests.len());
            for (s, p) in serial.run.requests.iter().zip(&piped.run.requests) {
                assert_eq!(s.id, p.id);
                assert_eq!(
                    s.output, p.output,
                    "{model}/{drafter:?}@b{batch}: pipelined output diverged from serial"
                );
                assert_eq!(
                    s.iters.len(),
                    p.iters.len(),
                    "{model}/{drafter:?}@b{batch}: iteration structure changed"
                );
                for (si, pi) in s.iters.iter().zip(&p.iters) {
                    assert_eq!(si.k_chosen, pi.k_chosen);
                    assert_eq!(si.drafted, pi.drafted);
                    assert_eq!(si.accepted, pi.accepted);
                    assert_eq!(si.emitted, pi.emitted);
                }
            }
        }
    }
}

#[test]
fn pipelined_clock_never_exceeds_serial() {
    // Property: with identical token streams (static K), the pipelined
    // simulated clock is the serial clock minus hidden drafting — it can
    // never be slower, on any seed, model, K, or batch size.
    for seed in [1u64, 7, 42, 0xCA5CADE] {
        for (model, k, batch) in [
            ("mixtral", 2usize, 1usize),
            ("mixtral", 3, 4),
            ("deepseek", 3, 2),
            ("qwen", 1, 4),
        ] {
            let w = Workload::by_name("code+math").unwrap();
            let reqs: Vec<Request> = RequestStream::new(w, seed, 80).take(5);
            let policy = PolicyKind::Static(k);
            let serial = batch_serve_cfg(
                cfg_pipe(model, DrafterKind::Ngram, batch, false),
                policy.clone(),
                &reqs,
            );
            let piped = batch_serve_cfg(
                cfg_pipe(model, DrafterKind::Ngram, batch, true),
                policy.clone(),
                &reqs,
            );
            assert_eq!(
                serial.run.total_tokens(),
                piped.run.total_tokens(),
                "{model}/k{k}@b{batch}/seed{seed}: outputs changed"
            );
            let (cs, cp) = (batch_clock_s(&serial), batch_clock_s(&piped));
            assert!(
                cp <= cs + 1e-12,
                "{model}/k{k}@b{batch}/seed{seed}: pipelined clock {cp} > serial {cs}"
            );
            // The clocks differ by exactly the hidden drafting time.
            assert!(
                (cs - cp - piped.draft_hidden_s()).abs() < 1e-12,
                "{model}/k{k}@b{batch}/seed{seed}: clock gap != hidden drafting"
            );
        }
    }
}

#[test]
fn pipelined_strictly_improves_tpot_when_lookahead_hits() {
    // Acceptance criterion: at batch >= 2 with the n-gram drafter, the
    // pipeline must land hits on the repetitive code workload and strictly
    // improve the batch-clock TPOT — with zero output divergence.
    let reqs = requests("code", 8, 120);
    let policy = PolicyKind::Static(3);
    for batch in [2usize, 4] {
        let serial = batch_serve_cfg(
            cfg_pipe("mixtral", DrafterKind::Ngram, batch, false),
            policy.clone(),
            &reqs,
        );
        let piped = batch_serve_cfg(
            cfg_pipe("mixtral", DrafterKind::Ngram, batch, true),
            policy.clone(),
            &reqs,
        );
        for (s, p) in serial.run.requests.iter().zip(&piped.run.requests) {
            assert_eq!(s.output, p.output, "b{batch}: output divergence");
        }
        assert!(piped.pipeline_hits() > 0, "b{batch}: lookahead never hit");
        assert!(piped.draft_hidden_s() > 0.0, "b{batch}: nothing hidden");
        assert!(
            piped.tpot_s() < serial.tpot_s(),
            "b{batch}: pipelined TPOT {} not strictly below serial {}",
            piped.tpot_s(),
            serial.tpot_s()
        );
    }
}

#[test]
fn pipelined_batch1_matches_single_request_engine() {
    // Engine parity: the single-request engine runs the same two-stage
    // pipeline, so batch=1 pipelined must reproduce it exactly — outputs,
    // iteration structure, and overlap-adjusted costs.
    let reg = registry();
    for (policy, drafter) in [
        (PolicyKind::Static(3), DrafterKind::Ngram),
        (PolicyKind::Cascade(Default::default()), DrafterKind::Ngram),
        (PolicyKind::Static(2), DrafterKind::EagleLite),
    ] {
        let reqs = requests("code+math", 3, 120);
        let cfg = cfg_pipe("mixtral", drafter, 1, true);
        let mut single = Engine::sim(&reg, cfg.clone(), policy.build()).unwrap();
        let single_run = single.serve_all(&reqs).unwrap();
        let batched = batch_serve_cfg(cfg, policy.clone(), &reqs);

        assert_eq!(single_run.requests.len(), batched.run.requests.len());
        for (s, b) in single_run.requests.iter().zip(&batched.run.requests) {
            assert_eq!(s.id, b.id);
            assert_eq!(
                s.output, b.output,
                "{}: pipelined batch=1 output diverged from the single engine",
                policy.label()
            );
            assert_eq!(s.iters.len(), b.iters.len());
            for (si, bi) in s.iters.iter().zip(&b.iters) {
                assert_eq!(si.k_chosen, bi.k_chosen);
                assert_eq!(si.drafted, bi.drafted);
                assert_eq!(si.emitted, bi.emitted);
                assert!(
                    (si.cost.total() - bi.cost.total()).abs() < 1e-15,
                    "{}: overlap-adjusted cost diverged",
                    policy.label()
                );
                assert!((si.cost.draft_hidden_s - bi.cost.draft_hidden_s).abs() < 1e-15);
            }
        }
    }
}

#[test]
fn pipelined_survives_pool_pressure_losslessly() {
    // Pool-shrunk K breaks the lookahead's K assumption — those drafts
    // must be recomputed, not misused. Same undersized pool as the serial
    // pressure test; outputs must match serial exactly.
    let block = 16usize;
    let max_new = 40usize;
    let reqs = requests("code", 6, max_new);
    let prompt_blocks = |r: &Request| r.prompt.len().div_ceil(block);
    let min_prompt = reqs.iter().map(prompt_blocks).min().unwrap();
    let span_blocks = reqs
        .iter()
        .map(|r| (r.prompt.len() + 1 + max_new).div_ceil(block) + 1)
        .max()
        .unwrap();
    let pool_blocks = (4 * min_prompt - 1).max(3 * span_blocks);
    let mk = |pipeline: bool| EngineConfig {
        model: "mixtral".into(),
        max_batch: 4,
        kv_pool_blocks: pool_blocks,
        pipeline,
        ..Default::default()
    };
    let serial = batch_serve_cfg(mk(false), PolicyKind::Static(2), &reqs);
    let piped = batch_serve_cfg(mk(true), PolicyKind::Static(2), &reqs);
    assert_eq!(serial.run.requests.len(), piped.run.requests.len());
    for (s, p) in serial.run.requests.iter().zip(&piped.run.requests) {
        assert_eq!(s.output, p.output, "pool pressure broke pipelined losslessness");
    }
    assert!(batch_clock_s(&piped) <= batch_clock_s(&serial) + 1e-12);
}

#[test]
fn pipelined_cascade_telemetry_is_consistent() {
    // Cascade + pipeline: K decisions see pipeline-true (marginal,
    // overlap-adjusted) utility, so trajectories may legitimately differ
    // from serial — but the run must complete and the telemetry must be
    // internally consistent.
    let reqs = requests("code+math", 8, 100);
    let m = batch_serve_cfg(
        cfg_pipe("mixtral", DrafterKind::Ngram, 4, true),
        PolicyKind::Cascade(Default::default()),
        &reqs,
    );
    assert_eq!(m.run.requests.len(), 8);
    assert!(m.run.total_tokens() > 0);
    let (hits, misses) = (m.pipeline_hits(), m.pipeline_misses());
    assert!(hits + misses > 0, "no drafting spans observed");
    assert!((0.0..=1.0).contains(&m.bubble_fraction()));
    assert!(m.draft_wall_hidden_ns() <= m.draft_wall_ns());
    assert!(m.draft_hidden_s() >= 0.0);
    // Hidden drafting can never exceed what was drafted at all.
    for r in &m.iters {
        assert!(r.cost.draft_hidden_s <= r.cost.draft_s + 1e-15);
        assert!(r.pipeline_hits + r.pipeline_misses <= r.n_active);
    }
}

#[test]
fn serial_mode_reports_draft_wall_baseline_without_pipeline_counters() {
    // The satellite wiring: serial runs surface total drafting wall time
    // (the baseline the pipeline is judged against) with zero hits,
    // bubbles, or hidden time.
    let reqs = requests("code", 4, 80);
    let m = batch_serve("mixtral", PolicyKind::Static(3), DrafterKind::Ngram, 4, &reqs);
    assert!(m.draft_wall_ns() > 0, "no draft wall time measured");
    assert_eq!(m.draft_wall_hidden_ns(), 0);
    assert_eq!(m.pipeline_hits(), 0);
    assert_eq!(m.pipeline_misses(), 0);
    assert_eq!(m.draft_recomputes(), 0);
    assert_eq!(m.bubble_fraction(), 0.0);
    assert_eq!(m.draft_hidden_s(), 0.0);
}
