//! Expert-parallel sharding integration (sim backend; no artifacts needed):
//!
//! * sharding moves **cost only, never tokens**: static-K outputs are
//!   byte-identical across shard counts and placements;
//! * `shards=1` is bit-exact with the unsharded cost model (the engine
//!   takes the legacy `batch_verify_cost` path);
//! * balanced-placement expert cost is monotonically non-increasing over
//!   doubling shard counts (per-shard load sets are refinements);
//! * pipelined vs serial losslessness still holds at shards > 1;
//! * the acceptance criterion: 4-way co-activation sharding strictly
//!   lowers mean verify time vs 1 shard, and Cascade's median K does not
//!   shrink.

use cascade::config::{DrafterKind, EngineConfig, PlacementKind};
use cascade::coordinator::batch::BatchEngine;
use cascade::cost::{ExpertBitmap, ExpertPlacement, GpuCostModel};
use cascade::metrics::BatchRunMetrics;
use cascade::models::{default_artifacts_dir, paper_spec, Registry};
use cascade::spec::policy::PolicyKind;
use cascade::workload::{Request, RequestStream, Workload};

fn registry() -> Registry {
    Registry::load_or_builtin(default_artifacts_dir())
}

fn requests(task: &str, n: usize, max_new: usize) -> Vec<Request> {
    let w = Workload::by_name(task).unwrap();
    RequestStream::new(w, 0xCA5CADE, max_new).take(n)
}

fn cfg_shard(model: &str, batch: usize, shards: usize, placement: PlacementKind) -> EngineConfig {
    EngineConfig {
        model: model.into(),
        max_batch: batch,
        shards,
        placement,
        ..Default::default()
    }
}

fn serve(cfg: EngineConfig, policy: PolicyKind, reqs: &[Request]) -> BatchRunMetrics {
    let reg = registry();
    let mut engine = BatchEngine::sim(&reg, cfg, policy).unwrap();
    engine.serve_all(reqs).unwrap()
}

#[test]
fn static_k_outputs_identical_across_shard_counts_and_placements() {
    // Sharding reprices iterations; it must never touch the token stream
    // (with a fixed K schedule the policy ignores cost entirely).
    let reqs = requests("code+math", 6, 100);
    let base = serve(
        cfg_shard("mixtral", 4, 1, PlacementKind::Balanced),
        PolicyKind::Static(3),
        &reqs,
    );
    for (shards, placement) in [
        (2, PlacementKind::Balanced),
        (4, PlacementKind::Balanced),
        (4, PlacementKind::CoActivation),
        (8, PlacementKind::CoActivation),
    ] {
        let m = serve(cfg_shard("mixtral", 4, shards, placement), PolicyKind::Static(3), &reqs);
        assert_eq!(m.n_shards, shards.min(8));
        assert_eq!(base.run.requests.len(), m.run.requests.len());
        for (a, b) in base.run.requests.iter().zip(&m.run.requests) {
            assert_eq!(a.id, b.id);
            assert_eq!(
                a.output, b.output,
                "shards={shards}/{placement:?}: sharding changed the token stream"
            );
        }
        // Same fused iteration structure, repriced.
        assert_eq!(base.iters.len(), m.iters.len());
    }
}

#[test]
fn one_shard_engine_is_bitexact_with_default() {
    // `--shards 1` must take the legacy cost path: identical costs, not
    // merely identical tokens, against a default (unsharded) config.
    let reqs = requests("code+math", 5, 80);
    let default_cfg = EngineConfig { model: "mixtral".into(), max_batch: 4, ..Default::default() };
    let a = serve(default_cfg, PolicyKind::Cascade(Default::default()), &reqs);
    let b = serve(
        cfg_shard("mixtral", 4, 1, PlacementKind::CoActivation),
        PolicyKind::Cascade(Default::default()),
        &reqs,
    );
    assert_eq!(a.iters.len(), b.iters.len());
    for (x, y) in a.iters.iter().zip(&b.iters) {
        assert!((x.cost.total() - y.cost.total()).abs() < 1e-18);
        assert_eq!(x.cost.alltoall_s, 0.0);
        assert_eq!(y.cost.alltoall_s, 0.0);
    }
    for (x, y) in a.run.requests.iter().zip(&b.run.requests) {
        assert_eq!(x.output, y.output);
    }
}

#[test]
fn balanced_expert_cost_monotone_nonincreasing_over_doubling_shards() {
    // Property: under round-robin placement, each shard at 2S is a subset
    // of a shard at S (e % 2S refines e % S), so the per-layer max load —
    // and with it the expert term — can only fall or hold when doubling
    // the shard count. (All-to-all moves the other way; this pins the
    // expert-movement term the tentpole is about.)
    let spec = paper_spec("deepseek").unwrap(); // 64 experts
    let m = GpuCostModel::new(spec, 2);
    // Deterministic pseudo-random per-layer id sets (LCG), 2 layers.
    let mut state = 0x1234_5678u64;
    let mut next = || {
        state = state.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
        (state >> 33) as usize % 64
    };
    for _ in 0..20 {
        let per_layer: Vec<ExpertBitmap> = (0..2)
            .map(|_| (0..24).map(|_| next()).collect::<ExpertBitmap>())
            .collect();
        let mut prev = f64::INFINITY;
        for shards in [1usize, 2, 4, 8] {
            let placement = ExpertPlacement::balanced(64, shards);
            let maxes = placement.max_loads(&per_layer);
            let c = m.sharded_batch_verify_cost(&maxes, shards, 16, 12, 4, DrafterKind::Ngram);
            assert!(
                c.expert_s <= prev + 1e-15,
                "expert_s rose from {prev} at {shards} shards: {}",
                c.expert_s
            );
            prev = c.expert_s;
        }
    }
}

#[test]
fn pipelined_vs_serial_lossless_at_shards_gt1() {
    // PR 2's losslessness law must survive sharding: identical outputs,
    // and the sharded pipelined clock never exceeds the sharded serial
    // clock (the gap is exactly the hidden drafting).
    let reqs = requests("code", 6, 100);
    let mk = |pipeline: bool| EngineConfig {
        model: "mixtral".into(),
        max_batch: 4,
        shards: 4,
        placement: PlacementKind::CoActivation,
        pipeline,
        ..Default::default()
    };
    let serial = serve(mk(false), PolicyKind::Static(3), &reqs);
    let piped = serve(mk(true), PolicyKind::Static(3), &reqs);
    assert_eq!(serial.run.requests.len(), piped.run.requests.len());
    for (s, p) in serial.run.requests.iter().zip(&piped.run.requests) {
        assert_eq!(s.output, p.output, "sharded pipelining changed outputs");
    }
    let clock = |m: &BatchRunMetrics| m.iters.iter().map(|r| r.cost.total()).sum::<f64>();
    let (cs, cp) = (clock(&serial), clock(&piped));
    assert!(cp <= cs + 1e-12, "sharded pipelined clock {cp} > serial {cs}");
    assert!((cs - cp - piped.draft_hidden_s()).abs() < 1e-12, "clock gap != hidden drafting");
}

#[test]
fn four_way_sharding_strictly_lowers_verify_time() {
    // Acceptance criterion: identical workload/seed, shards=4 with
    // co-activation placement → strictly lower mean verify time than
    // shards=1, despite paying the all-to-all.
    let reqs = requests("code+math", 8, 120);
    for model in ["mixtral", "deepseek"] {
        let m1 =
            serve(cfg_shard(model, 4, 1, PlacementKind::Balanced), PolicyKind::Static(3), &reqs);
        let m4 = serve(
            cfg_shard(model, 4, 4, PlacementKind::CoActivation),
            PolicyKind::Static(3),
            &reqs,
        );
        // Static K ⇒ same tokens, so verify times compare like for like.
        assert_eq!(m1.run.total_tokens(), m4.run.total_tokens());
        assert!(
            m4.mean_verify_s() < m1.mean_verify_s(),
            "{model}: sharded verify {} !< unsharded {}",
            m4.mean_verify_s(),
            m1.mean_verify_s()
        );
        assert!(m4.alltoall_share() > 0.0, "{model}: no all-to-all charged");
        assert_eq!(m1.alltoall_share(), 0.0);
        // The critical path is the max shard, well under the full union.
        assert!(m4.mean_max_shard_unique() < m1.mean_batch_unique());
        // Imbalance is sane: between perfectly balanced and worst case.
        let imb = m4.mean_shard_imbalance();
        assert!((1.0..=4.0 + 1e-9).contains(&imb), "{model}: imbalance {imb}");
    }
}

#[test]
fn cascade_k_does_not_shrink_under_sharding() {
    // Acceptance criterion: cheaper speculative expert mass ⇒ in at least
    // one workload row, Cascade's median K at shards=4 is at least its
    // shards=1 choice — and verify time drops in every row (Cascade may
    // spend some of the win on larger K, never on a slower verify).
    let mut k_held = false;
    for task in ["code+math", "code"] {
        let reqs = requests(task, 10, 150);
        let m1 = serve(
            cfg_shard("mixtral", 4, 1, PlacementKind::Balanced),
            PolicyKind::Cascade(Default::default()),
            &reqs,
        );
        let m4 = serve(
            cfg_shard("mixtral", 4, 4, PlacementKind::CoActivation),
            PolicyKind::Cascade(Default::default()),
            &reqs,
        );
        let (k1, k4) = (m1.run.k_chosen_p50(), m4.run.k_chosen_p50());
        if k4 >= k1 {
            k_held = true;
        }
        assert!(
            m4.mean_verify_s() < m1.mean_verify_s(),
            "{task}: sharded Cascade verify {} !< unsharded {}",
            m4.mean_verify_s(),
            m1.mean_verify_s()
        );
    }
    assert!(k_held, "Cascade's median K shrank under sharding in every row");
}

#[test]
fn fairness_floor_reaches_the_policy_signal() {
    // Engine-level companion to the cost-model fairness test: at batch=1
    // there is no shared mass, so the floor must be inert — the batched
    // engine still reproduces the single-request engine token-for-token
    // (covered in batching.rs) and charges zero all-to-all at shards=1.
    let reqs = requests("code", 3, 60);
    let m =
        serve(cfg_shard("mixtral", 1, 1, PlacementKind::Balanced), PolicyKind::Static(2), &reqs);
    for it in &m.iters {
        assert_eq!(it.cost.alltoall_s, 0.0);
        assert_eq!(it.shard_imbalance, 1.0);
        assert!(it.shard_unique.is_empty());
        assert!((it.max_shard_unique - it.batch_unique_experts).abs() < 1e-12);
    }
}

#[test]
fn dense_models_ignore_sharding() {
    // A dense model has no experts to shard: shards clamps to 1 and the
    // run is bit-identical to the unsharded one.
    let reqs = requests("code", 4, 60);
    let a = serve(cfg_shard("llama", 2, 1, PlacementKind::Balanced), PolicyKind::Static(3), &reqs);
    let b = serve(
        cfg_shard("llama", 2, 4, PlacementKind::CoActivation),
        PolicyKind::Static(3),
        &reqs,
    );
    assert_eq!(b.n_shards, 1);
    assert_eq!(a.iters.len(), b.iters.len());
    for (x, y) in a.iters.iter().zip(&b.iters) {
        assert!((x.cost.total() - y.cost.total()).abs() < 1e-18);
    }
    for (x, y) in a.run.requests.iter().zip(&b.run.requests) {
        assert_eq!(x.output, y.output);
    }
}
