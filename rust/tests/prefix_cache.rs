//! Copy-on-write prefix sharing (sim backend; no artifacts needed):
//!
//! * **losslessness battery** — template-heavy streams served with sharing
//!   on emit exactly the token streams (and per-iteration accept
//!   structure) of the same list served with sharing off, across eviction
//!   (off / lru / cost-aware) × the drafting pipeline (on / off) × expert
//!   shards (1 / 2). Sharing changes only block accounting and
//!   virtual-clock charges, never backend calls, so static-K streams are
//!   bit-exact by construction (rust/docs/prefix_cache.md) — this battery
//!   is the regression net over that claim;
//! * the battery also proves it is **exercising the cache** (≥ 1 trie hit
//!   per uncontended run, non-zero hits overall) and **exercising
//!   preemption under sharing** (evictions > 0 in the contended cells);
//! * **all-shared pools skip eviction** — when every candidate victim's
//!   blocks are shared (refcount > 1), evicting would free nothing: the
//!   feasibility pre-check must go straight to the deadlock bail with
//!   `total_evicted == 0`, never trash a victim's state for zero relief.
//!
//! Losslessness is asserted for static-K policies only: Cascade
//! legitimately adapts K to the (honest, hit-discounted) costs, so its
//! trajectories may differ — by design, not by accident.

use cascade::config::{DrafterKind, EngineConfig, EvictionKind};
use cascade::coordinator::batch::{BatchEngine, KV_BLOCK};
use cascade::metrics::BatchRunMetrics;
use cascade::models::{default_artifacts_dir, Registry};
use cascade::spec::policy::PolicyKind;
use cascade::workload::{template_preamble, Request, RequestStream, Task, Workload};

fn registry() -> Registry {
    Registry::load_or_builtin(default_artifacts_dir())
}

/// Template-heavy corpus stream for the uncontended cells: every request
/// opens with a preamble from the shared template pool (share = 1.0) over
/// real code+math prompts, so trie hits are guaranteed by the pigeonhole
/// principle (8 requests, 4 templates) while outputs stay corpus-driven.
fn stream_requests(n: usize, max_new: usize) -> Vec<Request> {
    let w = Workload::by_name("code+math").unwrap();
    RequestStream::with_prefix_templates(w, 0xCA5CADE, max_new, 1.0).take(n)
}

/// Deterministic template-headed requests for the contended cells: a
/// shared 128-token preamble (8 full blocks, alternating between two
/// templates) plus a request-unique 40-token tail, eps = 0 and a reference
/// longer than the budget so every token is guided, nothing hits EOS
/// early, and two concurrent 20-block spans must overflow the 24-block
/// pool — eviction under sharing is guaranteed by construction.
fn crafted_template_requests(n: usize, max_new: usize) -> Vec<Request> {
    (0..n)
        .map(|i| {
            let mut prompt = template_preamble(i % 2);
            prompt.extend((0..40).map(|p| 1 + ((p + 3 * i) % 200) as u32));
            // Non-trivially periodic, EOS/PAD-free reference (EOS = 258;
            // these stay in [1, 200]).
            let reference: Vec<u32> =
                (0..max_new + 16).map(|p| 1 + ((p * 7 + i) % 200) as u32).collect();
            Request {
                id: i as u64,
                task: Task::Code,
                prompt,
                reference,
                eps: 0.0,
                max_new_tokens: max_new,
            }
        })
        .collect()
}

fn cfg(
    pool_blocks: usize,
    eviction: EvictionKind,
    pipeline: bool,
    shards: usize,
    prefix_share: f64,
) -> EngineConfig {
    EngineConfig {
        model: "mixtral".into(),
        drafter: DrafterKind::Ngram,
        max_batch: 4,
        kv_pool_blocks: pool_blocks,
        eviction,
        max_preemptions_per_req: 100,
        pipeline,
        shards,
        prefix_share,
        ..Default::default()
    }
}

fn serve(cfg: EngineConfig, policy: PolicyKind, reqs: &[Request]) -> (BatchRunMetrics, u64) {
    let reg = registry();
    let mut engine = BatchEngine::sim(&reg, cfg, policy).unwrap();
    let m = engine.serve_all(reqs).unwrap();
    (m, engine.pool.total_evicted)
}

/// The losslessness battery: eviction {off, lru, cost-aware} × pipeline
/// {off, on} × shards {1, 2} — 12 cells, each serving the identical
/// request list with sharing on (`prefix_share = 1.0`) and off (`0.0`) and
/// asserting bit-exact per-request streams and iteration structure.
/// Eviction-off cells run uncontended (corpus requests, real eps);
/// eviction-on cells run a 24-block pool that must preempt mid-battery.
#[test]
fn sharing_is_lossless_across_eviction_pipeline_and_shards() {
    let policy = PolicyKind::Static(3);
    let mut total_hits = 0usize;
    let mut contended_evictions = 0u64;
    for eviction in [EvictionKind::Off, EvictionKind::Lru, EvictionKind::CostAware] {
        let contended = eviction.is_on();
        let (pool_blocks, reqs) = if contended {
            (1, crafted_template_requests(8, 150))
        } else {
            (0, stream_requests(8, 48))
        };
        for pipeline in [false, true] {
            for shards in [1usize, 2] {
                let label = format!("{eviction:?} pipeline={pipeline} shards={shards}");
                let (base, base_evicted) = serve(
                    cfg(pool_blocks, eviction, pipeline, shards, 0.0),
                    policy.clone(),
                    &reqs,
                );
                let (shared, shared_evicted) = serve(
                    cfg(pool_blocks, eviction, pipeline, shards, 1.0),
                    policy.clone(),
                    &reqs,
                );
                assert_eq!(
                    base.prefix_hits, 0,
                    "{label}: sharing off must never report trie hits"
                );
                assert_eq!(base.run.requests.len(), shared.run.requests.len());
                for (b, s) in base.run.requests.iter().zip(&shared.run.requests) {
                    assert_eq!(b.id, s.id);
                    assert_eq!(
                        b.output, s.output,
                        "{label}: request {} diverged between sharing off and on",
                        b.id
                    );
                    assert_eq!(
                        b.iters.len(),
                        s.iters.len(),
                        "{label}: request {} iteration structure changed",
                        b.id
                    );
                    for (bi, si) in b.iters.iter().zip(&s.iters) {
                        assert_eq!(bi.k_chosen, si.k_chosen);
                        assert_eq!(bi.drafted, si.drafted);
                        assert_eq!(bi.accepted, si.accepted);
                        assert_eq!(bi.emitted, si.emitted);
                    }
                }
                total_hits += shared.prefix_hits;
                if contended {
                    // The contended cells must actually preempt — with the
                    // 8-block shared preambles resident, victims are priced
                    // at *exclusive* blocks and still must be worth paying.
                    assert!(
                        shared_evicted > 0,
                        "{label}: the oversubscribed sharing pool never evicted — \
                         the cell is not exercising preemption under sharing"
                    );
                    contended_evictions += base_evicted + shared_evicted;
                } else {
                    assert_eq!(
                        base_evicted + shared_evicted,
                        0,
                        "{label}: the uncontended cells must never evict"
                    );
                    // 8 single-template-pool requests over 4 templates:
                    // repeats are guaranteed, so the trie must hit.
                    assert!(
                        shared.prefix_hits > 0,
                        "{label}: template-heavy stream never hit the cache"
                    );
                }
            }
        }
    }
    assert!(total_hits > 0, "battery finished without a single cache hit");
    assert!(contended_evictions > 0, "battery finished without a single eviction");
}

/// Hit accounting is block-granular: attached tokens are whole cached
/// blocks, so `prefix_hit_tokens` is always a multiple of the block size
/// and never exceeds what the hitting prompts could share.
#[test]
fn hit_token_accounting_is_block_granular() {
    let reqs = stream_requests(8, 48);
    let (m, _) = serve(cfg(0, EvictionKind::Off, false, 1, 1.0), PolicyKind::Static(3), &reqs);
    assert!(m.prefix_hits > 0);
    assert_eq!(m.prefix_hits + m.prefix_misses, reqs.len());
    assert_eq!(
        m.prefix_hit_tokens % KV_BLOCK as u64,
        0,
        "attached prefixes must cover whole blocks"
    );
    assert!(m.prefix_hit_tokens >= (m.prefix_hits * KV_BLOCK) as u64);
    assert!(m.shared_blocks_peak > 0, "hits without shared residency make no sense");
}

/// Feasibility under total sharing: four slots whose every mapped block is
/// shared (two requests per prompt, plus the trie's pins) fill the
/// 24-block pool exactly; the first decode token then needs a fresh block
/// in every slot, but evicting any victim frees *nothing* — all candidate
/// blocks have refcount > 1. The engine must recognize the zero-relief
/// victim set, skip eviction entirely, and surface the deadlock bail.
#[test]
fn all_shared_pool_skips_eviction_and_surfaces_deadlock() {
    let max_new = 64;
    // Two prompt families sized in whole blocks: 16 + 8 = 24 = the whole
    // pool once each family is mapped exactly once and shared by its pair.
    let long: Vec<u32> = (0..16 * KV_BLOCK).map(|p| 1 + ((p * 5) % 200) as u32).collect();
    let short: Vec<u32> = (0..8 * KV_BLOCK).map(|p| 1 + ((p * 11 + 7) % 200) as u32).collect();
    let reqs: Vec<Request> = (0..4usize)
        .map(|i| Request {
            id: i as u64,
            task: Task::Code,
            prompt: if i < 2 { long.clone() } else { short.clone() },
            reference: (0..max_new + 16).map(|p| 1 + ((p * 7 + i) % 200) as u32).collect(),
            eps: 0.0,
            max_new_tokens: max_new,
        })
        .collect();
    let reg = registry();
    let mut engine = BatchEngine::sim(
        &reg,
        cfg(1, EvictionKind::Lru, false, 1, 1.0),
        PolicyKind::Static(3),
    )
    .unwrap();
    let err = engine.serve_all(&reqs).expect_err(
        "a pool whose every block is shared has no victim worth evicting \
         and must deadlock, not complete",
    );
    let msg = err.to_string();
    assert!(msg.contains("KV pool deadlock"), "unexpected error: {msg}");
    assert_eq!(
        engine.pool.total_evicted, 0,
        "evicting an all-shared victim frees nothing — eviction must be skipped"
    );
    assert_eq!(
        engine.pool.shared_blocks(),
        engine.pool.total_blocks(),
        "the scenario is meant to share every block in the pool"
    );
}
