//! KV-pool preemption / eviction integration (sim backend; no artifacts
//! needed):
//!
//! * **losslessness** — an evicted-then-readmitted request emits exactly
//!   the token stream (and per-iteration accept structure) of an
//!   uncontended run, across victim policies, drafters, and the drafting
//!   pipeline. With eviction on, pool pressure is all-or-nothing per slot
//!   (defer or evict, never shrink K), so executed spans — and with them
//!   the sim backend's per-slot rng process — are contention-independent;
//!   replay-based re-admission reconstructs backend state bit-exactly;
//! * **pool invariants** hold across evict/re-admit cycles, and victim
//!   accounting (`total_evicted`, per-request preemption counts) is
//!   consistent with the engine's metrics;
//! * `eviction = off` still **reproduces the deadlock error** on an
//!   oversubscribed pool (bit-compatible bail semantics), while the same
//!   scenario with eviction on completes every request;
//! * `max_preemptions_per_req` **bounds thrash**: no request is ever
//!   evicted more than the cap allows;
//! * the **sole active slot is never evicted** (a lone request always
//!   fits a pool clamped to one full window, so serving at batch 1 never
//!   preempts at all);
//! * re-prefill is **charged into TPOT** (`IterCost::reprefill_s`): a
//!   thrashing run's batch clock is strictly slower than uncontended.
//!
//! Losslessness is asserted for static-K policies: Cascade legitimately
//! adapts K to the (honest, reprefill-inclusive) contended costs, so its
//! trajectories may differ — by design, not by accident.

use cascade::config::{DrafterKind, EngineConfig, EvictionKind};
use cascade::coordinator::batch::{BatchEngine, KV_BLOCK};
use cascade::metrics::BatchRunMetrics;
use cascade::models::{default_artifacts_dir, Registry};
use cascade::spec::policy::PolicyKind;
use cascade::workload::{Request, RequestStream, Task, Workload};

fn registry() -> Registry {
    Registry::load_or_builtin(default_artifacts_dir())
}

fn requests(task: &str, n: usize, max_new: usize) -> Vec<Request> {
    let w = Workload::by_name(task).unwrap();
    RequestStream::new(w, 0xCA5CADE, max_new).take(n)
}

/// Deterministic long-decode requests: eps = 0 and a reference longer than
/// the budget, so every token is guided (the stream is exactly the
/// reference prefix), nothing hits EOS early, and pool exhaustion is
/// guaranteed by construction. 4 concurrent spans need far more than one
/// window (24 blocks for mixtral's 384-token window), so an oversubscribed
/// pool must either preempt or deadlock.
fn crafted_requests(n: usize, max_new: usize) -> Vec<Request> {
    (0..n)
        .map(|i| {
            let prompt: Vec<u32> = (0..40).map(|p| 1 + ((p + 3 * i) % 200) as u32).collect();
            // Non-trivially periodic, EOS/PAD-free reference (EOS = 258;
            // these stay in [1, 200]).
            let reference: Vec<u32> =
                (0..max_new + 16).map(|p| 1 + ((p * 7 + i) % 200) as u32).collect();
            Request {
                id: i as u64,
                task: Task::Code,
                prompt,
                reference,
                eps: 0.0,
                max_new_tokens: max_new,
            }
        })
        .collect()
}

fn cfg(
    pool_blocks: usize,
    eviction: EvictionKind,
    cap: usize,
    drafter: DrafterKind,
    pipeline: bool,
) -> EngineConfig {
    EngineConfig {
        model: "mixtral".into(),
        drafter,
        max_batch: 4,
        kv_pool_blocks: pool_blocks,
        eviction,
        max_preemptions_per_req: cap,
        pipeline,
        ..Default::default()
    }
}

fn serve(cfg: EngineConfig, policy: PolicyKind, reqs: &[Request]) -> (BatchRunMetrics, u64) {
    let reg = registry();
    let mut engine = BatchEngine::sim(&reg, cfg, policy).unwrap();
    let m = engine.serve_all(reqs).unwrap();
    (m, engine.pool.total_evicted)
}

/// The whole point of the subsystem: under a pool squeezed to one window
/// (kv_pool_blocks = 1 clamps up to max_seq/block = 24 blocks, ~¼ of the
/// 4-slot working set), every victim policy completes every request with
/// token streams — and per-iteration accept structure — bit-exact against
/// the uncontended run.
#[test]
fn evicted_requests_emit_identical_streams_to_uncontended_run() {
    for (policy, drafter, pipeline) in [
        (PolicyKind::Static(3), DrafterKind::Ngram, false),
        (PolicyKind::Static(3), DrafterKind::Ngram, true),
        (PolicyKind::Static(2), DrafterKind::EagleLite, false),
    ] {
        let reqs = requests("code+math", 8, 150);
        let (base, base_evicted) = serve(
            cfg(0, EvictionKind::Off, 8, drafter, pipeline),
            policy.clone(),
            &reqs,
        );
        assert_eq!(base_evicted, 0);
        for eviction in
            [EvictionKind::Lru, EvictionKind::MostLookahead, EvictionKind::CostAware]
        {
            let (m, evicted) = serve(
                cfg(1, eviction, 100, drafter, pipeline),
                policy.clone(),
                &reqs,
            );
            assert!(
                evicted > 0,
                "{eviction:?}/{drafter:?}: the oversubscribed pool never evicted — \
                 the scenario is not exercising preemption"
            );
            assert_eq!(base.run.requests.len(), m.run.requests.len());
            for (b, c) in base.run.requests.iter().zip(&m.run.requests) {
                assert_eq!(b.id, c.id);
                assert_eq!(
                    b.output, c.output,
                    "{eviction:?}/{drafter:?} pipeline={pipeline}: request {} diverged \
                     from the uncontended run",
                    b.id
                );
                assert_eq!(
                    b.iters.len(),
                    c.iters.len(),
                    "{eviction:?}: request {} iteration structure changed",
                    b.id
                );
                for (bi, ci) in b.iters.iter().zip(&c.iters) {
                    assert_eq!(bi.k_chosen, ci.k_chosen);
                    assert_eq!(bi.drafted, ci.drafted);
                    assert_eq!(bi.accepted, ci.accepted);
                    assert_eq!(bi.emitted, ci.emitted);
                }
            }
        }
    }
}

#[test]
fn pool_invariants_hold_across_evict_readmit_cycles() {
    let reg = registry();
    let reqs = crafted_requests(6, 150);
    let mut engine = BatchEngine::sim(
        &reg,
        cfg(1, EvictionKind::Lru, 100, DrafterKind::Ngram, false),
        PolicyKind::Static(3),
    )
    .unwrap();
    let mut queue: std::collections::VecDeque<Request> = reqs.into_iter().collect();
    loop {
        while engine.has_free_slot() {
            match queue.front() {
                Some(r) if engine.can_admit(r) => {
                    let r = queue.pop_front().unwrap();
                    engine.admit(r).unwrap();
                }
                _ => break,
            }
        }
        engine.pool.check_invariants().unwrap();
        assert!(engine.pool.blocks_in_use() <= engine.pool.total_blocks());
        if !engine.step_iteration().unwrap() && queue.is_empty() {
            break;
        }
    }
    assert_eq!(engine.parked_requests(), 0, "run drained with requests still parked");
    assert!(engine.pool.total_evicted > 0, "scenario never evicted");
    assert!(engine.pool.preempted_requests() > 0);
    assert_eq!(engine.pool.blocks_in_use(), 0, "all blocks released at drain");
    let m = engine.finish();
    assert_eq!(m.run.requests.len(), 6);
    // Engine-side and pool-side victim accounting must agree.
    let metric_preemptions: usize = m.run.requests.iter().map(|r| r.preemptions).sum();
    assert_eq!(metric_preemptions as u64, engine.pool.total_evicted);
    assert_eq!(m.evictions() as u64, engine.pool.total_evicted);
    assert_eq!(m.evictions(), m.readmissions(), "every victim must come back");
}

#[test]
fn eviction_off_reproduces_pool_deadlock() {
    let reg = registry();
    let reqs = crafted_requests(6, 150);
    let mut engine = BatchEngine::sim(
        &reg,
        cfg(1, EvictionKind::Off, 8, DrafterKind::Ngram, false),
        PolicyKind::Static(3),
    )
    .unwrap();
    let err = engine.serve_all(&reqs).expect_err("an oversubscribed pool without \
         eviction must deadlock, not complete");
    let msg = err.to_string();
    assert!(msg.contains("KV pool deadlock"), "unexpected error: {msg}");
    assert_eq!(engine.pool.total_evicted, 0, "off mode must never evict");
}

#[test]
fn infeasible_reservation_defers_without_paying_evictions() {
    // Feasibility pre-check: with `max_preemptions_per_req = 0`, every
    // candidate is pinned, so no victim set can cover any shortfall. The
    // engine must recognize the reservation as infeasible and go straight
    // to defer/deadlock — paying *zero* evictions along the way, rather
    // than trashing a victim's state only to defer anyway.
    let reg = registry();
    let reqs = crafted_requests(6, 150);
    let mut engine = BatchEngine::sim(
        &reg,
        cfg(1, EvictionKind::Lru, 0, DrafterKind::Ngram, false),
        PolicyKind::Static(3),
    )
    .unwrap();
    let err = engine
        .serve_all(&reqs)
        .expect_err("an oversubscribed pool with every candidate pinned must deadlock");
    let msg = err.to_string();
    assert!(msg.contains("KV pool deadlock"), "unexpected error: {msg}");
    assert_eq!(
        engine.pool.total_evicted, 0,
        "infeasible reservations must not pay evictions before deferring"
    );
}

#[test]
fn eviction_serves_oversubscribed_pool_where_off_deadlocks() {
    // Same deterministic scenario as the deadlock test, but with a victim
    // policy: every request completes, and (eps = 0) every stream is
    // exactly its reference prefix — losslessness verified against ground
    // truth rather than another engine run.
    let reqs = crafted_requests(6, 150);
    for eviction in [EvictionKind::Lru, EvictionKind::MostLookahead, EvictionKind::CostAware]
    {
        let (m, evicted) = serve(
            cfg(1, eviction, 100, DrafterKind::Ngram, false),
            PolicyKind::Static(3),
            &reqs,
        );
        assert_eq!(m.run.requests.len(), 6, "{eviction:?}: not all requests completed");
        assert!(evicted > 0, "{eviction:?}: never evicted");
        for (req, done) in reqs.iter().zip(&m.run.requests) {
            assert_eq!(req.id, done.id);
            assert_eq!(
                done.output,
                req.reference[..done.output.len()].to_vec(),
                "{eviction:?}: request {} deviated from its fully-guided reference",
                req.id
            );
            assert!(done.output.len() >= req.max_new_tokens - 1);
        }
        // The thrash is accounted, not hidden: re-prefill shows up in the
        // batch clock and in the per-request records.
        assert!(m.reprefill_s() > 0.0, "{eviction:?}: free re-prefill");
        assert!(m.thrash_fraction() > 0.0 && m.thrash_fraction() < 1.0);
        assert_eq!(m.evictions(), m.readmissions());
        let preempted: usize =
            m.run.requests.iter().filter(|r| r.preemptions > 0).count();
        assert!(preempted > 0);
        assert!(m.run.requests.iter().all(|r| (r.preemptions > 0) == (r.reprefill_s > 0.0)));
    }
}

#[test]
fn reprefill_is_charged_into_the_batch_clock() {
    let reqs = crafted_requests(6, 150);
    let (base, _) = serve(
        cfg(0, EvictionKind::Off, 8, DrafterKind::Ngram, false),
        PolicyKind::Static(3),
        &reqs,
    );
    let (contended, evicted) = serve(
        cfg(1, EvictionKind::Lru, 100, DrafterKind::Ngram, false),
        PolicyKind::Static(3),
        &reqs,
    );
    assert!(evicted > 0);
    assert_eq!(base.run.total_tokens(), contended.run.total_tokens());
    let clock = |m: &BatchRunMetrics| m.iters.iter().map(|r| r.cost.total()).sum::<f64>();
    // Same tokens, extra recompute + deferral iterations: the contended
    // clock (and with it TPOT) must be strictly slower, and the re-prefill
    // charge must be visible in it (Σ cost.reprefill_s > 0 implies the
    // charge is inside total(), unit-tested in cost::tests).
    assert!(contended.reprefill_s() > 0.0, "no re-prefill charged");
    assert!(
        clock(&contended) > clock(&base),
        "thrash not reflected in the batch clock: contended {} <= base {}",
        clock(&contended),
        clock(&base)
    );
    assert!(contended.tpot_s() > base.tpot_s());
}

#[test]
fn max_preemptions_per_req_bounds_thrash() {
    let reg = registry();
    let reqs = crafted_requests(6, 150);
    for cap in [1usize, 2] {
        let mut engine = BatchEngine::sim(
            &reg,
            cfg(1, EvictionKind::Lru, cap, DrafterKind::Ngram, false),
            PolicyKind::Static(3),
        )
        .unwrap();
        match engine.serve_all(&reqs) {
            Ok(m) => assert_eq!(m.run.requests.len(), 6),
            // A tight cap may pin every candidate and legitimately
            // deadlock; the *bound* is the guarantee either way.
            Err(e) => {
                let msg = e.to_string();
                assert!(msg.contains("KV pool deadlock"), "cap {cap}: {msg}");
                assert!(msg.contains("max_preemptions_per_req"), "cap {cap}: {msg}");
            }
        }
        for r in &reqs {
            assert!(
                engine.pool.preemptions(r.id) <= cap as u32,
                "cap {cap}: request {} evicted {} times",
                r.id,
                engine.pool.preemptions(r.id)
            );
        }
    }
}

#[test]
fn sole_active_slot_is_never_evicted() {
    // Batch 1 with the pool squeezed to its floor (one full window): a lone
    // request always fits, is never stuck, and must never be preempted —
    // the engine-level face of the "never evict the sole active slot"
    // rule (the selection-level face is unit-tested in
    // coordinator::eviction).
    let reqs = requests("code", 4, 150);
    let reg = registry();
    let mut engine_cfg = cfg(1, EvictionKind::CostAware, 8, DrafterKind::Ngram, false);
    engine_cfg.max_batch = 1;
    let mut engine = BatchEngine::sim(&reg, engine_cfg, PolicyKind::Static(3)).unwrap();
    let m = engine.serve_all(&reqs).unwrap();
    assert_eq!(m.run.requests.len(), 4);
    assert_eq!(engine.pool.total_evicted, 0);
    assert!(m.run.requests.iter().all(|r| r.preemptions == 0));
    assert_eq!(m.evictions(), 0);
    assert_eq!(m.reprefill_s(), 0.0);
}

#[test]
fn eviction_off_with_roomy_pool_is_bit_exact_with_default_engine() {
    // `eviction = off` must keep today's behavior exactly — including on a
    // pool that defers but never deadlocks (the PR 1 pressure test's
    // sizing): same outputs, same costs as the same run before this
    // subsystem existed (represented by the off-mode run itself being the
    // comparison baseline for the eviction-on run at the same pool size —
    // and by tier-1's pre-existing batching tests staying green).
    let block = KV_BLOCK;
    let max_new = 40usize;
    let reqs = requests("code", 6, max_new);
    let prompt_blocks = |r: &Request| r.prompt.len().div_ceil(block);
    let min_prompt = reqs.iter().map(prompt_blocks).min().unwrap();
    let span_blocks = reqs
        .iter()
        .map(|r| (r.prompt.len() + 1 + max_new).div_ceil(block) + 1)
        .max()
        .unwrap();
    let pool_blocks = (4 * min_prompt - 1).max(3 * span_blocks);
    let (off, off_evicted) = serve(
        cfg(pool_blocks, EvictionKind::Off, 8, DrafterKind::Ngram, false),
        PolicyKind::Static(2),
        &reqs,
    );
    assert_eq!(off_evicted, 0);
    assert_eq!(off.run.requests.len(), 6);
    // The same deferring-but-not-deadlocking pool with eviction on still
    // serves everything and stays lossless vs the off run (this pool is
    // roomy enough that spans are never shrunk in off mode either, so the
    // two modes execute identical spans).
    let (on, _) = serve(
        cfg(pool_blocks, EvictionKind::Lru, 100, DrafterKind::Ngram, false),
        PolicyKind::Static(2),
        &reqs,
    );
    assert_eq!(on.run.requests.len(), 6);
    for (a, b) in off.run.requests.iter().zip(&on.run.requests) {
        assert_eq!(a.output, b.output, "eviction=on diverged on a non-thrashing pool");
    }
}
