//! Property tests over coordinator invariants (in-tree random-case harness;
//! the offline vendor set has no proptest). Each property runs hundreds of
//! randomized cases through the *sim* engine — no HLO needed — plus pure
//! component properties.

use cascade::config::{CascadeParams, EngineConfig, MAX_K};
use cascade::coordinator::engine::Engine;
use cascade::metrics::IterPhase;
use cascade::models::{default_artifacts_dir, Registry};
use cascade::rng::Rng;
use cascade::spec::manager::CascadeManager;
use cascade::spec::policy::PolicyKind;
use cascade::spec::NgramDrafter;
use cascade::workload::{RequestStream, Task, Workload};

fn registry() -> Registry {
    // Sim-only properties: the builtin registry suffices (no artifacts).
    Registry::load_or_builtin(default_artifacts_dir())
}

/// Random (model, task, policy, seed) sim runs; checks engine-wide
/// conservation laws on every iteration record.
#[test]
fn prop_engine_conservation_laws() {
    let reg = registry();
    let mut rng = Rng::new(0xE27);
    let models = ["mixtral", "phi", "olmoe", "deepseek", "qwen", "llama"];
    let tasks = [Task::Code, Task::Math, Task::Extract];
    for case in 0..40 {
        let model = models[rng.below(models.len())];
        let task = tasks[rng.below(tasks.len())];
        let policy = match rng.below(3) {
            0 => PolicyKind::Static(rng.below(MAX_K + 1)),
            1 => PolicyKind::Cascade(CascadeParams::default()),
            _ => PolicyKind::Cascade(CascadeParams::ablation(rng.below(4))),
        };
        let cfg = EngineConfig { model: model.into(), seed: rng.next_u64(), ..Default::default() };
        let mut engine = Engine::sim(&reg, cfg, policy.build()).unwrap();
        let mut stream = RequestStream::new(Workload::single(task), rng.next_u64(), 120);
        let req = stream.next_request();
        let m = engine.serve_request(&req).unwrap();

        let mini = reg.model(model).unwrap().mini;
        for (i, it) in m.iters.iter().enumerate() {
            // Emission law: 1 <= emitted <= accepted + 1 <= drafted + 1 <= K+1.
            assert!(it.emitted >= 1, "case {case} iter {i}");
            assert!(it.accepted <= it.drafted, "case {case} iter {i}");
            assert!(it.emitted <= it.accepted + 1, "case {case} iter {i}");
            assert!(it.drafted <= it.k_chosen, "case {case} iter {i}");
            assert!(it.k_chosen <= MAX_K);
            // Cost components are nonnegative and total adds up.
            let c = it.cost;
            for part in [c.base_s, c.expert_s, c.draft_s, c.reject_s, c.overhead_s] {
                assert!(part >= 0.0);
            }
            assert!((c.total() - (c.base_s + c.expert_s + c.draft_s + c.reject_s + c.overhead_s)).abs() < 1e-15);
            // Expert counts bounded by architecture.
            if mini.is_moe {
                assert!(it.unique_experts <= mini.n_experts as f64);
            } else {
                assert_eq!(it.unique_experts, 0.0);
            }
        }
        // Token conservation: sum(emitted) == tokens_emitted <= max_new + K.
        assert_eq!(
            m.iters.iter().map(|r| r.emitted).sum::<usize>(),
            m.tokens_emitted()
        );
        assert!(m.tokens_emitted() <= 120 + MAX_K + 1);
    }
}

/// Cascade's phase machine obeys its contract under random utility
/// landscapes: K bounded, baseline first, K=0 only when disable is on.
#[test]
fn prop_manager_state_machine() {
    let mut rng = Rng::new(0x517A7E);
    for case in 0..300 {
        let level = rng.below(4);
        let params = CascadeParams::ablation(level);
        let mut mgr = CascadeManager::new(params.clone());
        // Random piecewise-stationary landscape.
        let mut etr_k = [0.0f64; MAX_K + 1];
        for (k, e) in etr_k.iter_mut().enumerate() {
            *e = 1.0 + rng.f64() * k as f64;
        }
        let base = 0.005 + rng.f64() * 0.03;
        for i in 0..rng.range(40, 400) {
            let k = mgr.next_k();
            assert!(k <= MAX_K, "case {case}");
            if i < params.baseline_iters {
                assert_eq!(mgr.phase_label(), IterPhase::Baseline, "case {case} iter {i}");
                assert_eq!(k, 0);
            }
            if k == 0 && mgr.phase_label() == IterPhase::Set {
                assert!(
                    params.enable_disable,
                    "case {case}: K=0 set phase without disable enabled"
                );
            }
            let cost = base * (1.0 + 0.4 * k as f64 * rng.f64());
            mgr.observe(etr_k[k], cost);
        }
        // Back-off never exceeds the cap and never shrinks below S0.
        assert!(mgr.current_set_len() >= params.set_iters);
        assert!(mgr.current_set_len() <= params.max_set_iters.max(params.set_iters));
    }
}

/// The n-gram drafter never proposes more than k tokens and every proposal
/// is a contiguous span of the context that continues a suffix match.
#[test]
fn prop_ngram_contract() {
    let mut rng = Rng::new(0x9624);
    for _ in 0..800 {
        let min_n = rng.range(1, 3);
        let max_n = min_n + rng.below(4);
        let d = NgramDrafter::new(min_n, max_n);
        let len = rng.range(2, 120);
        let alphabet = rng.range(2, 12);
        let ctx: Vec<u32> = (0..len).map(|_| rng.below(alphabet) as u32).collect();
        let k = rng.below(MAX_K + 1);
        let prop = d.propose(&ctx, k);
        assert!(prop.len() <= k);
        if !prop.is_empty() {
            assert!(ctx.windows(prop.len()).any(|w| w == &prop[..]));
        }
    }
}

/// Utility algebra (Theorem 4.2) holds for arbitrary runs of the sim
/// engine: TPOT == baseline_TPOT / utility when both are measured from the
/// same trace.
#[test]
fn prop_theorem_4_2_on_engine_traces() {
    let reg = registry();
    let mut rng = Rng::new(0x742);
    for _ in 0..20 {
        let k = 1 + rng.below(MAX_K);
        let cfg = EngineConfig { model: "mixtral".into(), seed: rng.next_u64(), ..Default::default() };
        let mut engine = Engine::sim(&reg, cfg, PolicyKind::Static(k).build()).unwrap();
        let mut stream = RequestStream::new(Workload::single(Task::Code), rng.next_u64(), 150);
        let m = engine.serve_request(&stream.next_request()).unwrap();

        // Baseline run on the same request with K=0.
        let cfg0 = EngineConfig { model: "mixtral".into(), seed: 1, ..Default::default() };
        let mut engine0 = Engine::sim(&reg, cfg0, PolicyKind::Static(0).build()).unwrap();
        let mut stream0 = RequestStream::new(Workload::single(Task::Code), 99, 150);
        let m0 = engine0.serve_request(&stream0.next_request()).unwrap();

        let base_iter = m0.mean_iter_s();
        let utility = m.etr() / (m.mean_iter_s() / base_iter);
        let tpot_pred = m0.tpot_s() * (m0.etr() / 1.0) / utility; // m0.etr()==1
        assert!(
            (m.tpot_s() - tpot_pred).abs() / m.tpot_s() < 1e-9,
            "theorem 4.2 identity violated: {} vs {}",
            m.tpot_s(),
            tpot_pred
        );
    }
}

/// Scheduler conservation: the sum of per-request tokens equals the run
/// total and respects the budget within one request's overshoot.
#[test]
fn prop_scheduler_budget() {
    use cascade::coordinator::scheduler::{Budget, Scheduler};
    let reg = registry();
    let mut rng = Rng::new(0xBAD6E);
    for _ in 0..10 {
        let budget = Budget { max_tokens: rng.range(100, 600), max_requests: 50 };
        let cfg = EngineConfig { model: "phi".into(), seed: rng.next_u64(), ..Default::default() };
        let mut engine = Engine::sim(&reg, cfg, PolicyKind::Static(2).build()).unwrap();
        let stream = RequestStream::new(Workload::by_name("all-3").unwrap(), rng.next_u64(), 150);
        let mut sched = Scheduler::new(stream, budget);
        let m = sched.run(&mut engine).unwrap();
        let total: usize = m.requests.iter().map(|r| r.tokens_emitted()).sum();
        assert_eq!(total, m.total_tokens());
        assert!(total >= budget.max_tokens.min(1));
        // The scheduler clamps the tail request: no overshoot at all.
        assert!(total <= budget.max_tokens, "budget {} overshot: {total}", budget.max_tokens);
    }
}
