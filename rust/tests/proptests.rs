//! Property tests over coordinator invariants (in-tree random-case harness;
//! the offline vendor set has no proptest). Each property runs hundreds of
//! randomized cases through the *sim* engine — no HLO needed — plus pure
//! component properties.

use cascade::config::{CascadeParams, EngineConfig, MAX_K};
use cascade::coordinator::engine::Engine;
use cascade::metrics::IterPhase;
use cascade::models::{default_artifacts_dir, Registry};
use cascade::rng::Rng;
use cascade::spec::manager::CascadeManager;
use cascade::spec::policy::PolicyKind;
use cascade::spec::NgramDrafter;
use cascade::workload::{RequestStream, Task, Workload};

fn registry() -> Registry {
    // Sim-only properties: the builtin registry suffices (no artifacts).
    Registry::load_or_builtin(default_artifacts_dir())
}

/// Random (model, task, policy, seed) sim runs; checks engine-wide
/// conservation laws on every iteration record.
#[test]
fn prop_engine_conservation_laws() {
    let reg = registry();
    let mut rng = Rng::new(0xE27);
    let models = ["mixtral", "phi", "olmoe", "deepseek", "qwen", "llama"];
    let tasks = [Task::Code, Task::Math, Task::Extract];
    for case in 0..40 {
        let model = models[rng.below(models.len())];
        let task = tasks[rng.below(tasks.len())];
        let policy = match rng.below(3) {
            0 => PolicyKind::Static(rng.below(MAX_K + 1)),
            1 => PolicyKind::Cascade(CascadeParams::default()),
            _ => PolicyKind::Cascade(CascadeParams::ablation(rng.below(4))),
        };
        let cfg = EngineConfig { model: model.into(), seed: rng.next_u64(), ..Default::default() };
        let mut engine = Engine::sim(&reg, cfg, policy.build()).unwrap();
        let mut stream = RequestStream::new(Workload::single(task), rng.next_u64(), 120);
        let req = stream.next_request();
        let m = engine.serve_request(&req).unwrap();

        let mini = reg.model(model).unwrap().mini;
        for (i, it) in m.iters.iter().enumerate() {
            // Emission law: 1 <= emitted <= accepted + 1 <= drafted + 1 <= K+1.
            assert!(it.emitted >= 1, "case {case} iter {i}");
            assert!(it.accepted <= it.drafted, "case {case} iter {i}");
            assert!(it.emitted <= it.accepted + 1, "case {case} iter {i}");
            assert!(it.drafted <= it.k_chosen, "case {case} iter {i}");
            assert!(it.k_chosen <= MAX_K);
            // Cost components are nonnegative and total adds up.
            let c = it.cost;
            for part in [c.base_s, c.expert_s, c.draft_s, c.reject_s, c.overhead_s] {
                assert!(part >= 0.0);
            }
            assert!((c.total() - (c.base_s + c.expert_s + c.draft_s + c.reject_s + c.overhead_s)).abs() < 1e-15);
            // Expert counts bounded by architecture.
            if mini.is_moe {
                assert!(it.unique_experts <= mini.n_experts as f64);
            } else {
                assert_eq!(it.unique_experts, 0.0);
            }
        }
        // Token conservation: sum(emitted) == tokens_emitted <= max_new + K.
        assert_eq!(
            m.iters.iter().map(|r| r.emitted).sum::<usize>(),
            m.tokens_emitted()
        );
        assert!(m.tokens_emitted() <= 120 + MAX_K + 1);
    }
}

/// Cascade's phase machine obeys its contract under random utility
/// landscapes: K bounded, baseline first, K=0 only when disable is on.
#[test]
fn prop_manager_state_machine() {
    let mut rng = Rng::new(0x517A7E);
    for case in 0..300 {
        let level = rng.below(4);
        let params = CascadeParams::ablation(level);
        let mut mgr = CascadeManager::new(params.clone());
        // Random piecewise-stationary landscape.
        let mut etr_k = [0.0f64; MAX_K + 1];
        for (k, e) in etr_k.iter_mut().enumerate() {
            *e = 1.0 + rng.f64() * k as f64;
        }
        let base = 0.005 + rng.f64() * 0.03;
        for i in 0..rng.range(40, 400) {
            let k = mgr.next_k();
            assert!(k <= MAX_K, "case {case}");
            if i < params.baseline_iters {
                assert_eq!(mgr.phase_label(), IterPhase::Baseline, "case {case} iter {i}");
                assert_eq!(k, 0);
            }
            if k == 0 && mgr.phase_label() == IterPhase::Set {
                assert!(
                    params.enable_disable,
                    "case {case}: K=0 set phase without disable enabled"
                );
            }
            let cost = base * (1.0 + 0.4 * k as f64 * rng.f64());
            mgr.observe(etr_k[k], cost);
        }
        // Back-off never exceeds the cap and never shrinks below S0.
        assert!(mgr.current_set_len() >= params.set_iters);
        assert!(mgr.current_set_len() <= params.max_set_iters.max(params.set_iters));
    }
}

/// The n-gram drafter never proposes more than k tokens and every proposal
/// is a contiguous span of the context that continues a suffix match.
#[test]
fn prop_ngram_contract() {
    let mut rng = Rng::new(0x9624);
    for _ in 0..800 {
        let min_n = rng.range(1, 3);
        let max_n = min_n + rng.below(4);
        let d = NgramDrafter::new(min_n, max_n);
        let len = rng.range(2, 120);
        let alphabet = rng.range(2, 12);
        let ctx: Vec<u32> = (0..len).map(|_| rng.below(alphabet) as u32).collect();
        let k = rng.below(MAX_K + 1);
        let prop = d.propose(&ctx, k);
        assert!(prop.len() <= k);
        if !prop.is_empty() {
            assert!(ctx.windows(prop.len()).any(|w| w == &prop[..]));
        }
    }
}

/// Utility algebra (Theorem 4.2) holds for arbitrary runs of the sim
/// engine: TPOT == baseline_TPOT / utility when both are measured from the
/// same trace.
#[test]
fn prop_theorem_4_2_on_engine_traces() {
    let reg = registry();
    let mut rng = Rng::new(0x742);
    for _ in 0..20 {
        let k = 1 + rng.below(MAX_K);
        let cfg = EngineConfig { model: "mixtral".into(), seed: rng.next_u64(), ..Default::default() };
        let mut engine = Engine::sim(&reg, cfg, PolicyKind::Static(k).build()).unwrap();
        let mut stream = RequestStream::new(Workload::single(Task::Code), rng.next_u64(), 150);
        let m = engine.serve_request(&stream.next_request()).unwrap();

        // Baseline run on the same request with K=0.
        let cfg0 = EngineConfig { model: "mixtral".into(), seed: 1, ..Default::default() };
        let mut engine0 = Engine::sim(&reg, cfg0, PolicyKind::Static(0).build()).unwrap();
        let mut stream0 = RequestStream::new(Workload::single(Task::Code), 99, 150);
        let m0 = engine0.serve_request(&stream0.next_request()).unwrap();

        let base_iter = m0.mean_iter_s();
        let utility = m.etr() / (m.mean_iter_s() / base_iter);
        let tpot_pred = m0.tpot_s() * (m0.etr() / 1.0) / utility; // m0.etr()==1
        assert!(
            (m.tpot_s() - tpot_pred).abs() / m.tpot_s() < 1e-9,
            "theorem 4.2 identity violated: {} vs {}",
            m.tpot_s(),
            tpot_pred
        );
    }
}

/// Copy-on-write sharing pool state machine: hundreds of random
/// admit / admit-with-shared-prefix (the fork-on-write attach) /
/// reserve+partial-commit / release / evict / trie-style pin-unpin
/// sequences, with [`cascade::kv::KvBlockPool::check_invariants`] —
/// budget, span coverage, and exact refcount conservation
/// (Σ mapped + external pins == Σ refcounts) — asserted after every op,
/// and a drained pool at the end of every case.
#[test]
fn prop_sharing_pool_state_machine() {
    use cascade::kv::KvBlockPool;
    let block = 16usize;
    let mut rng = Rng::new(0xC0117);
    for case in 0..80 {
        let total = rng.range(8, 40);
        let mut pool = KvBlockPool::new(total, block);
        pool.enable_sharing();
        let mut live: Vec<u64> = Vec::new();
        let mut pins: Vec<u64> = Vec::new();
        let mut next_id = 0u64;
        for step in 0..160 {
            match rng.below(6) {
                // Admit, forking off a random live donor's mapped prefix
                // with high probability (the copy-on-write attach).
                0 | 1 => {
                    let committed = rng.range(1, 4 * block);
                    let span = committed.div_ceil(block);
                    let mut shared: Vec<u64> = Vec::new();
                    if !live.is_empty() && rng.chance(0.7) {
                        let donor = live[rng.below(live.len())];
                        let mapped = pool.mapped_blocks(donor);
                        let take = rng.below(mapped.len().min(span) + 1);
                        shared.extend_from_slice(&mapped[..take]);
                    }
                    if span - shared.len() <= pool.free_blocks() {
                        pool.admit_shared(next_id, committed, &shared).unwrap();
                        live.push(next_id);
                        next_id += 1;
                    }
                }
                // Reserve a verify step, then commit a random part of it
                // (speculative tail blocks roll back to the free budget).
                2 => {
                    if !live.is_empty() {
                        let id = live[rng.below(live.len())];
                        let t = 1 + rng.below(8);
                        if pool.can_reserve(id, t) {
                            pool.reserve(id, t).unwrap();
                            pool.commit(id, rng.below(t + 1)).unwrap();
                        }
                    }
                }
                // Finish a request: shared blocks must survive while any
                // other holder (request or pin) still maps them.
                3 => {
                    if !live.is_empty() {
                        let id = live.swap_remove(rng.below(live.len()));
                        pool.release(id);
                    }
                }
                // Preempt a request: only its exclusive blocks come back.
                4 => {
                    if !live.is_empty() {
                        let id = live.swap_remove(rng.below(live.len()));
                        let in_use = pool.blocks_in_use();
                        let exclusive = pool.exclusive_blocks_of(id);
                        let freed = pool.evict(id).unwrap();
                        assert_eq!(
                            freed, exclusive,
                            "case {case} step {step}: eviction freed {freed} blocks, \
                             not the victim's {exclusive} exclusive ones"
                        );
                        assert_eq!(pool.blocks_in_use(), in_use - freed);
                    }
                }
                // Trie-style external pin or unpin of a mapped block.
                _ => {
                    if rng.chance(0.5) && !live.is_empty() {
                        let id = live[rng.below(live.len())];
                        let mapped = pool.mapped_blocks(id);
                        if !mapped.is_empty() {
                            let b = mapped[rng.below(mapped.len())];
                            pool.retain_block(b).unwrap();
                            pins.push(b);
                        }
                    } else if !pins.is_empty() {
                        let b = pins.swap_remove(rng.below(pins.len()));
                        pool.release_block(b).unwrap();
                    }
                }
            }
            pool.check_invariants()
                .unwrap_or_else(|e| panic!("case {case} step {step}: {e}"));
            assert!(pool.blocks_in_use() <= pool.total_blocks());
        }
        // Drain: every holder gone means every block gone.
        for id in live.drain(..) {
            pool.release(id);
        }
        for b in pins.drain(..) {
            pool.release_block(b).unwrap();
        }
        pool.check_invariants().unwrap();
        assert_eq!(pool.blocks_in_use(), 0, "case {case}: drained pool still holds blocks");
    }
}

/// The conservation check has teeth: corrupting one refcount via the
/// test-only tamper hook must trip `check_invariants` with the exact
/// conservation message.
#[test]
fn sharing_invariants_catch_refcount_tampering() {
    use cascade::kv::KvBlockPool;
    let mut pool = KvBlockPool::new(8, 16);
    pool.enable_sharing();
    pool.admit(1, 20).unwrap();
    pool.check_invariants().unwrap();
    assert!(pool.debug_inflate_refcount(), "a live block must exist to corrupt");
    let msg = pool
        .check_invariants()
        .expect_err("an inflated refcount must trip conservation")
        .to_string();
    assert!(msg.contains("refcount conservation violated"), "unexpected error: {msg}");
}

/// Scheduler conservation: the sum of per-request tokens equals the run
/// total and respects the budget within one request's overshoot.
#[test]
fn prop_scheduler_budget() {
    use cascade::coordinator::scheduler::{Budget, Scheduler};
    let reg = registry();
    let mut rng = Rng::new(0xBAD6E);
    for _ in 0..10 {
        let budget = Budget { max_tokens: rng.range(100, 600), max_requests: 50 };
        let cfg = EngineConfig { model: "phi".into(), seed: rng.next_u64(), ..Default::default() };
        let mut engine = Engine::sim(&reg, cfg, PolicyKind::Static(2).build()).unwrap();
        let stream = RequestStream::new(Workload::by_name("all-3").unwrap(), rng.next_u64(), 150);
        let mut sched = Scheduler::new(stream, budget);
        let m = sched.run(&mut engine).unwrap();
        let total: usize = m.requests.iter().map(|r| r.tokens_emitted()).sum();
        assert_eq!(total, m.total_tokens());
        assert!(total >= budget.max_tokens.min(1));
        // The scheduler clamps the tail request: no overshoot at all.
        assert!(total <= budget.max_tokens, "budget {} overshot: {total}", budget.max_tokens);
    }
}
