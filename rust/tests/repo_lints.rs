//! Tier-1 gate for the repo-native lint suite (`cascade::analysis`): the
//! checked-in tree must be violation-free, and the suite must actually
//! catch the regressions it exists for — a reintroduced hash collection,
//! a cost field leaking out of `total()`, a dead metrics field. See
//! rust/docs/lints.md.

use cascade::analysis::{self, RepoTree, SourceFile};

/// Repo root = parent of the crate manifest dir (`rust/`).
fn load() -> RepoTree {
    let manifest = std::path::Path::new(env!("CARGO_MANIFEST_DIR"));
    let root = manifest.parent().expect("rust/ lives under the repo root");
    let tree = analysis::load_repo(root).expect("loading repo snapshot");
    assert!(tree.get("rust/src/lib.rs").is_some(), "snapshot missed crate sources");
    assert!(tree.get("README.md").is_some(), "snapshot missed the README");
    tree
}

#[test]
fn repo_is_lint_clean() {
    let violations = analysis::run_all(&load());
    assert!(violations.is_empty(), "\n{}", analysis::report(&violations));
}

#[test]
fn reintroducing_a_hash_collection_fails_with_rule_and_location() {
    let mut tree = load();
    tree.files.push(SourceFile {
        path: "rust/src/tampered.rs".into(),
        text: format!("use std::collections::{};\n", concat!("Hash", "Map")),
    });
    let v = analysis::run_all(&tree);
    assert!(
        v.iter().any(|v| v.rule == "hash-collection"
            && v.path == "rust/src/tampered.rs"
            && v.line == 1),
        "{}",
        analysis::report(&v)
    );
}

#[test]
fn dropping_a_cost_field_from_total_fails() {
    let mut tree = load();
    let cost = tree
        .files
        .iter_mut()
        .find(|f| f.path == "rust/src/cost/mod.rs")
        .expect("cost module in snapshot");
    let patched = cost.text.replace("+ self.reprefill_s", "");
    assert_ne!(patched, cost.text, "expected the reprefill_s term in total()");
    cost.text = patched;
    let v = analysis::run_all(&tree);
    assert!(
        v.iter().any(|v| v.rule == "cost-conservation"
            && v.msg.contains("`reprefill_s`")
            && v.msg.contains("total()")),
        "{}",
        analysis::report(&v)
    );
}

#[test]
fn a_dead_metrics_field_fails() {
    let mut tree = load();
    let metrics = tree
        .files
        .iter_mut()
        .find(|f| f.path == "rust/src/metrics/mod.rs")
        .expect("metrics module in snapshot");
    let patched = metrics.text.replace(
        "pub struct BatchRunMetrics {",
        "pub struct BatchRunMetrics {\n    pub dead_knob_xyz: usize,",
    );
    assert_ne!(patched, metrics.text, "expected the BatchRunMetrics declaration");
    metrics.text = patched;
    let v = analysis::run_all(&tree);
    assert!(
        v.iter().any(|v| v.rule == "telemetry-dead-field"
            && v.msg.contains("`dead_knob_xyz`")),
        "{}",
        analysis::report(&v)
    );
}

#[test]
fn a_tree_set_on_the_hot_path_fails_with_rule_and_location() {
    let mut tree = load();
    tree.files.push(SourceFile {
        path: "rust/src/coordinator/tampered.rs".into(),
        text: format!(
            "fn f() {{ let s: std::collections::{}<usize> = Default::default(); }}\n",
            concat!("BTree", "Set")
        ),
    });
    let v = analysis::run_all(&tree);
    assert!(
        v.iter().any(|v| v.rule == "hot-path-set"
            && v.path == "rust/src/coordinator/tampered.rs"
            && v.line == 1),
        "{}",
        analysis::report(&v)
    );
}

#[test]
fn the_bitmap_reference_model_stays_exempt() {
    // The differential tests in cost/bitmap.rs hold the tree set as the
    // reference model on purpose; the rule must never flag them.
    let v = analysis::run_all(&load());
    assert!(
        !v.iter().any(|v| v.rule == "hot-path-set"),
        "{}",
        analysis::report(&v)
    );
}

#[test]
fn a_blanket_allow_fails() {
    let mut tree = load();
    tree.files.push(SourceFile {
        path: "rust/src/tampered.rs".into(),
        text: format!("fn f() {{}} // {}: everything\n", analysis::ALLOW_TOKEN),
    });
    let v = analysis::run_all(&tree);
    assert!(
        v.iter().any(|v| v.rule == "lint-allow" && v.path == "rust/src/tampered.rs"),
        "{}",
        analysis::report(&v)
    );
}
