//! Determinism regression (sim backend; no artifacts needed): two serve
//! runs with identical configs and seeds must produce **byte-identical**
//! metrics JSON — outputs, costs, and every derived aggregate.
//!
//! This is the runtime counterpart of the repo lint suite's static
//! determinism rules (`rust/docs/lints.md`): the lints ban unordered
//! collections, host clocks, and foreign RNGs from the virtual-clock
//! path; this test catches whatever slips past them (iteration-order
//! dependence smuggled through an allow, float reassociation, a stray
//! ambient seed). The serialized view deliberately runs through the
//! crate's own JSON writer so map ordering is part of the contract.

use cascade::config::{DrafterKind, EngineConfig};
use cascade::coordinator::batch::BatchEngine;
use cascade::metrics::BatchRunMetrics;
use cascade::models::{default_artifacts_dir, Registry};
use cascade::spec::policy::PolicyKind;
use cascade::util::json::{arr, num, obj, str as jstr, write, Value};
use cascade::workload::{RequestStream, Workload};

/// Serialize everything downstream consumers read off a batched run:
/// per-request token streams, per-request latency, and the aggregate
/// table the CLI prints. Any nondeterminism in engine state shows up
/// here as a byte difference.
fn metrics_json(m: &BatchRunMetrics) -> String {
    let requests: Vec<Value> = m
        .run
        .requests
        .iter()
        .map(|r| {
            obj(vec![
                ("id", num(r.id as f64)),
                ("output", arr(r.output.iter().map(|&t| num(t as f64)).collect())),
                ("tpot_s", num(r.tpot_s())),
                ("preemptions", num(r.preemptions as f64)),
            ])
        })
        .collect();
    let v = obj(vec![
        ("tpot_s", num(m.tpot_s())),
        ("clock_s", num(m.clock_s)),
        ("mean_etr", num(m.run.mean_etr())),
        ("mean_span_tokens", num(m.mean_span_tokens())),
        ("draft_share", num(m.draft_share())),
        ("mean_batch_unique", num(m.mean_batch_unique())),
        ("overlap_savings", num(m.overlap_savings())),
        ("iters", num(m.iters.len() as f64)),
        // Prefix-cache telemetry (all zero with sharing off): the sharing
        // runs below fold hit/miss accounting and shared-block residency
        // into the byte-identity contract.
        ("prefix_hits", num(m.prefix_hits as f64)),
        ("prefix_misses", num(m.prefix_misses as f64)),
        ("prefix_hit_tokens", num(m.prefix_hit_tokens as f64)),
        ("shared_blocks_peak", num(m.shared_blocks_peak as f64)),
        ("prefix_reclaimed_blocks", num(m.prefix_reclaimed_blocks as f64)),
        ("backend", jstr("sim")),
        ("requests", arr(requests)),
    ]);
    write(&v)
}

fn serve_once(seed: u64) -> String {
    let reg = Registry::load_or_builtin(default_artifacts_dir());
    let cfg = EngineConfig {
        model: "mixtral".into(),
        drafter: DrafterKind::Ngram,
        seed,
        max_batch: 4,
        pipeline: true,
        shards: 2,
        ..EngineConfig::default()
    };
    let mut engine = BatchEngine::sim(&reg, cfg, PolicyKind::Cascade(Default::default())).unwrap();
    let w = Workload::by_name("code+math").unwrap();
    let reqs = RequestStream::new(w, seed, 120).take(8);
    let m = engine.serve_all(&reqs).unwrap();
    metrics_json(&m)
}

/// Same contract with the copy-on-write prefix cache on: a template-heavy
/// stream (`--prefix-share 0.6`) through the trie-backed sharing pool.
/// Admission order, trie walks, refcount bookkeeping, and hit-discounted
/// prefill charges all sit on the virtual-clock path, so any unordered
/// structure or ambient seed in them shows up as a byte difference here.
fn serve_prefix_once(seed: u64) -> String {
    let reg = Registry::load_or_builtin(default_artifacts_dir());
    let cfg = EngineConfig {
        model: "mixtral".into(),
        drafter: DrafterKind::Ngram,
        seed,
        max_batch: 4,
        pipeline: true,
        shards: 2,
        prefix_share: 0.6,
        ..EngineConfig::default()
    };
    let mut engine = BatchEngine::sim(&reg, cfg, PolicyKind::Cascade(Default::default())).unwrap();
    let w = Workload::by_name("code+math").unwrap();
    let reqs = RequestStream::with_prefix_templates(w, seed, 48, 0.6).take(8);
    let m = engine.serve_all(&reqs).unwrap();
    // Guard against the vacuous pass where sharing never engaged: with the
    // trie on, every admission is a hit or a miss. (Hit coverage itself is
    // asserted in rust/tests/prefix_cache.rs, which forces repeats.)
    assert_eq!(m.prefix_hits + m.prefix_misses, reqs.len(), "the sharing path never engaged");
    metrics_json(&m)
}

#[test]
fn identical_seeds_produce_byte_identical_metrics() {
    let a = serve_once(0xCA5CADE);
    let b = serve_once(0xCA5CADE);
    assert_eq!(a, b, "two identical-seed runs diverged — nondeterminism in the engine");
}

#[test]
fn different_seeds_actually_change_the_run() {
    // Guard against the vacuous pass where the serialization ignores the
    // run: a different seed must move at least the token streams.
    let a = serve_once(0xCA5CADE);
    let b = serve_once(0xBEEF);
    assert_ne!(a, b, "seed does not reach the served stream");
}

#[test]
fn identical_seeds_with_prefix_sharing_are_byte_identical() {
    let a = serve_prefix_once(0xCA5CADE);
    let b = serve_prefix_once(0xCA5CADE);
    assert_eq!(
        a, b,
        "two identical-seed sharing runs diverged — nondeterminism in the prefix cache"
    );
}

#[test]
fn different_seeds_change_the_prefix_sharing_run() {
    let a = serve_prefix_once(0xCA5CADE);
    let b = serve_prefix_once(0xBEEF);
    assert_ne!(a, b, "seed does not reach the template stream or the served output");
}
