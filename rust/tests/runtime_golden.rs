//! Cross-layer golden test: the AOT HLO artifacts, executed through the
//! Rust PJRT runtime, must reproduce the eager-JAX outputs recorded in the
//! manifest by python/compile/aot.py. This is the end-to-end proof that
//! L1 (Pallas kernels) + L2 (JAX model) + AOT text interchange + L3 runtime
//! compose correctly.
//!
//! Requires `make artifacts`.

use cascade::models::{artifacts_available, default_artifacts_dir, Registry, ALL_MODELS};
use cascade::runtime::ModelRuntime;
use cascade::sampling::argmax;

/// These tests execute AOT HLO through PJRT: both the artifacts directory
/// and a PJRT-enabled build are required. Without them, skip with a note.
fn stack() -> Option<(Registry, xla::PjRtClient)> {
    if !artifacts_available() {
        eprintln!("skipping: AOT artifacts not built (run `make artifacts`)");
        return None;
    }
    let reg = Registry::load(default_artifacts_dir()).expect("valid artifacts");
    match xla::PjRtClient::cpu() {
        Ok(client) => Some((reg, client)),
        Err(e) => {
            eprintln!("skipping: PJRT unavailable in this build: {e}");
            None
        }
    }
}

#[test]
fn golden_outputs_match_eager_jax() {
    let Some((reg, client)) = stack() else { return };
    for name in ALL_MODELS {
        let mut rt = ModelRuntime::with_client(&reg, name, client.clone()).unwrap();
        let golden = rt.model.golden.clone();
        let mut state = rt.fresh_state();
        let out = rt.step(&mut state, &golden.tokens).unwrap();

        // Logits head (relative tolerance: f32 accumulation order).
        for (i, (a, b)) in out.logits_row(0)[..8]
            .iter()
            .zip(&golden.logits_row0_head)
            .enumerate()
        {
            assert!(
                (a - b).abs() <= 1e-3 * (1.0 + b.abs()),
                "{name}: logits[0][{i}] {a} vs golden {b}"
            );
        }
        // Greedy argmax must match exactly (what serving consumes).
        let am: Vec<usize> = (0..golden.t)
            .map(|i| argmax(out.logits_row(i)) as usize)
            .collect();
        assert_eq!(am, golden.argmax, "{name}: argmax mismatch");

        // Router decisions must match exactly (what the cost model consumes).
        for (l, layer) in golden.topk_idx.iter().enumerate() {
            for (t, toks) in layer.iter().enumerate() {
                assert_eq!(out.topk_at(l, t), &toks[..], "{name}: topk[{l}][{t}]");
            }
        }
    }
}

#[test]
fn all_token_variants_compile_and_run() {
    let Some((reg, client)) = stack() else { return };
    // One MoE + the dense baseline covers both code paths;
    // golden_outputs_match_eager_jax covers every model at T=3.
    for name in ["mixtral", "llama"] {
        let mut rt = ModelRuntime::with_client(&reg, name, client.clone()).unwrap();
        rt.warmup().unwrap();
        for t in rt.model.token_variants() {
            let mut state = rt.fresh_state();
            let tokens: Vec<u32> = (0..t as u32).map(|i| i % 256).collect();
            let out = rt.step(&mut state, &tokens).unwrap();
            assert_eq!(out.t, t, "{name} T={t}");
            assert!(
                out.logits_row(t - 1).iter().all(|x| x.is_finite()),
                "{name} T={t}: non-finite logits"
            );
        }
    }
}

#[test]
fn kv_cache_incremental_equals_batch() {
    // Feeding tokens one-at-a-time through the KV cache must reproduce the
    // one-shot logits — the invariant speculative verification relies on.
    let Some((reg, client)) = stack() else { return };
    let mut rt = ModelRuntime::with_client(&reg, "mixtral", client).unwrap();
    let tokens = [5u32, 17, 99, 200];

    let mut batch_state = rt.fresh_state();
    let batch = rt.step(&mut batch_state, &tokens).unwrap();

    let mut state = rt.fresh_state();
    let mut last = None;
    for (i, &tk) in tokens.iter().enumerate() {
        let out = rt.step(&mut state, &[tk]).unwrap();
        state.cache_len = i + 1;
        last = Some(out);
    }
    let last = last.unwrap();
    let a = last.logits_row(0);
    let b = batch.logits_row(tokens.len() - 1);
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert!((x - y).abs() < 1e-3 * (1.0 + y.abs()), "logit {i}: {x} vs {y}");
    }
}

#[test]
fn rejected_speculative_kv_is_harmless() {
    // Write 3 speculative tokens, accept none, decode again: logits must
    // match the never-speculated run (stale KV slots get overwritten).
    let Some((reg, client)) = stack() else { return };
    let mut rt = ModelRuntime::with_client(&reg, "qwen", client).unwrap();

    let mut s1 = rt.fresh_state();
    rt.step(&mut s1, &[1]).unwrap();
    s1.cache_len = 1;
    // speculative step: tokens at positions 1..4, drafts rejected
    rt.step(&mut s1, &[50, 60, 70]).unwrap();
    s1.cache_len = 2; // commit only the first (the "x0" input)
    let spec_out = rt.step(&mut s1, &[42]).unwrap();

    let mut s2 = rt.fresh_state();
    rt.step(&mut s2, &[1]).unwrap();
    s2.cache_len = 1;
    rt.step(&mut s2, &[50]).unwrap();
    s2.cache_len = 2;
    let clean_out = rt.step(&mut s2, &[42]).unwrap();

    for (x, y) in spec_out.logits_row(0).iter().zip(clean_out.logits_row(0)) {
        assert!((x - y).abs() < 1e-3 * (1.0 + y.abs()), "{x} vs {y}");
    }
}

#[test]
fn unique_expert_counts_plausible() {
    // T=1 must activate exactly top_k experts per layer; T=8 must activate
    // more (up to the architecture cap) on a low-affinity model.
    let Some((reg, client)) = stack() else { return };
    let mut rt = ModelRuntime::with_client(&reg, "mixtral", client).unwrap();
    let topk = rt.model.mini.top_k;

    let mut state = rt.fresh_state();
    let out1 = rt.step(&mut state, &[7]).unwrap();
    assert!(out1.unique_experts_per_layer(1).iter().all(|&u| u == topk));

    let mut state = rt.fresh_state();
    let tokens: Vec<u32> = vec![3, 50, 97, 140, 180, 220, 250, 31];
    let out8 = rt.step(&mut state, &tokens).unwrap();
    let uniq = out8.unique_experts_per_layer(8);
    assert!(
        uniq.iter().all(|&u| u >= topk && u <= rt.model.mini.n_experts),
        "{uniq:?}"
    );
    let mean: f64 = uniq.iter().sum::<usize>() as f64 / uniq.len() as f64;
    assert!(mean > topk as f64 * 1.3, "verification should spread experts: {uniq:?}");
}

#[test]
fn affinity_models_reuse_experts_more() {
    // OLMoE (affinity 0.75) must reuse experts across consecutive tokens
    // more than its uniform-routing bound; this is the paper's §2.4
    // expert-affinity effect and the reason OLMoE loves speculation (§7).
    let Some((reg, client)) = stack() else { return };
    let mut rt = ModelRuntime::with_client(&reg, "olmoe", client).unwrap();
    let mini = rt.model.mini.clone();
    let mut state = rt.fresh_state();
    let tokens: Vec<u32> = vec![10, 65, 120, 175, 230, 29, 84, 139];
    let out = rt.step(&mut state, &tokens).unwrap();
    let uniq = out.unique_experts_per_layer(8);
    let mean: f64 = uniq.iter().sum::<usize>() as f64 / uniq.len() as f64;
    // Uniform top-8-of-64 over 8 tokens would give ~41 unique experts.
    let uniform = mini.n_experts as f64
        * (1.0 - (1.0 - mini.top_k as f64 / mini.n_experts as f64).powi(8));
    assert!(
        mean < uniform * 0.8,
        "affinity should cut unique experts: mean {mean:.1} vs uniform {uniform:.1}"
    );
}
