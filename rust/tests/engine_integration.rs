//! Engine-level integration: real backend end-to-end behaviour, Cascade
//! policy dynamics on the real stack, and real-vs-sim cross-validation.
//!
//! The real-backend tests require `make artifacts` (AOT HLO + weights) and
//! a PJRT-enabled build; without them they skip with a note. The sim-only
//! tests run everywhere on the builtin registry.

use cascade::config::EngineConfig;
use cascade::coordinator::engine::Engine;
use cascade::coordinator::scheduler::{Budget, Scheduler};
use cascade::metrics::IterPhase;
use cascade::models::{artifacts_available, default_artifacts_dir, Registry};
use cascade::spec::policy::PolicyKind;
use cascade::workload::{RequestStream, Task, Workload};

fn registry() -> Registry {
    Registry::load_or_builtin(default_artifacts_dir())
}

/// Real-backend preflight: false (with a note) when artifacts are missing.
fn real_stack_ready(test: &str) -> bool {
    if !artifacts_available() {
        eprintln!("skipping {test}: AOT artifacts not built (run `make artifacts`)");
        return false;
    }
    true
}

fn run(
    model: &str,
    task: &str,
    policy: PolicyKind,
    tokens: usize,
    sim: bool,
) -> cascade::metrics::RunMetrics {
    let reg = registry();
    let cfg = EngineConfig { model: model.into(), ..Default::default() };
    let mut engine = if sim {
        Engine::sim(&reg, cfg, policy.build()).unwrap()
    } else {
        Engine::real(&reg, cfg, policy.build()).unwrap()
    };
    let stream = RequestStream::new(Workload::by_name(task).unwrap(), 7, 150);
    let mut sched = Scheduler::new(stream, Budget { max_tokens: tokens, max_requests: 100 });
    sched.run(&mut engine).unwrap()
}

#[test]
fn serves_requests_to_completion() {
    if !real_stack_ready("serves_requests_to_completion") {
        return;
    }
    let m = run("mixtral", "code", PolicyKind::Static(2), 250, false);
    assert!(m.total_tokens() >= 250);
    assert!(m.requests.len() >= 2);
    for r in &m.requests {
        assert!(r.iters.len() > 10);
        assert!(r.tpot_s() > 0.0 && r.tpot_s().is_finite());
    }
}

#[test]
fn sim_serves_requests_to_completion() {
    // Sim-backend twin of the test above; runs without artifacts.
    let m = run("mixtral", "code", PolicyKind::Static(2), 250, true);
    assert!(m.total_tokens() >= 250);
    assert!(m.requests.len() >= 2);
    for r in &m.requests {
        assert!(r.iters.len() > 10);
        assert!(r.tpot_s() > 0.0 && r.tpot_s().is_finite());
        assert_eq!(r.output.len(), r.tokens_emitted() + 1, "output = prefill + emissions");
    }
}

#[test]
fn speculation_improves_code_tpot_on_real_stack() {
    if !real_stack_ready("speculation_improves_code_tpot_on_real_stack") {
        return;
    }
    let base = run("mixtral", "code", PolicyKind::Static(0), 250, false);
    let spec = run("mixtral", "code", PolicyKind::Static(3), 250, false);
    let speedup = base.tpot_s() / spec.tpot_s();
    assert!(speedup > 1.1, "code K=3 speedup {speedup}");
}

#[test]
fn speculation_hurts_math_on_real_stack() {
    if !real_stack_ready("speculation_hurts_math_on_real_stack") {
        return;
    }
    // The paper's core observation (Fig. 1c): math + MoE + static K loses.
    let base = run("mixtral", "math", PolicyKind::Static(0), 250, false);
    let spec = run("mixtral", "math", PolicyKind::Static(3), 250, false);
    let speedup = base.tpot_s() / spec.tpot_s();
    assert!(speedup < 0.95, "math K=3 should slow down, got {speedup}");
}

#[test]
fn cascade_bounds_math_slowdown() {
    if !real_stack_ready("cascade_bounds_math_slowdown") {
        return;
    }
    // Headline behaviour: Cascade turns the math slowdown into ~break-even
    // (paper: worst case -5%).
    let base = run("mixtral", "math", PolicyKind::Static(0), 350, false);
    let casc = run("mixtral", "math", PolicyKind::Cascade(Default::default()), 350, false);
    let speedup = base.tpot_s() / casc.tpot_s();
    assert!(speedup > 0.88, "cascade math speedup {speedup} (want > 0.88)");
    // And it must actually disable: most set-phase iterations at K=0.
    let set_k: Vec<usize> = casc
        .requests
        .iter()
        .flat_map(|r| &r.iters)
        .filter(|r| r.phase == IterPhase::Set)
        .map(|r| r.k_chosen)
        .collect();
    let zeros = set_k.iter().filter(|&&k| k == 0).count();
    assert!(
        zeros * 2 > set_k.len(),
        "cascade should disable speculation on math: {zeros}/{}",
        set_k.len()
    );
}

#[test]
fn cascade_keeps_code_speedup() {
    if !real_stack_ready("cascade_keeps_code_speedup") {
        return;
    }
    let base = run("mixtral", "code", PolicyKind::Static(0), 350, false);
    let casc = run("mixtral", "code", PolicyKind::Cascade(Default::default()), 350, false);
    let speedup = base.tpot_s() / casc.tpot_s();
    assert!(speedup > 1.1, "cascade code speedup {speedup}");
}

#[test]
fn olmoe_affinity_makes_speculation_cheap() {
    if !real_stack_ready("olmoe_affinity_makes_speculation_cheap") {
        return;
    }
    // OLMoE (high expert-token affinity) gains the most from speculation
    // in the paper (Fig. 13: ~1.3x at K=3).
    let base = run("olmoe", "code", PolicyKind::Static(0), 250, false);
    let spec = run("olmoe", "code", PolicyKind::Static(3), 250, false);
    let speedup = base.tpot_s() / spec.tpot_s();
    assert!(speedup > 1.2, "olmoe code K=3 speedup {speedup}");
}

#[test]
fn dense_model_never_slows_down() {
    if !real_stack_ready("dense_model_never_slows_down") {
        return;
    }
    // Fig. 4 green: dense verification is free, so even math gains.
    let base = run("llama", "math", PolicyKind::Static(0), 250, false);
    let spec = run("llama", "math", PolicyKind::Static(3), 250, false);
    let speedup = base.tpot_s() / spec.tpot_s();
    assert!(speedup > 1.0, "dense math K=3 speedup {speedup}");
}

#[test]
fn phases_follow_cascade_lifecycle() {
    // Policy lifecycle is backend-agnostic; drive it on the sim stack so
    // the test runs without artifacts.
    let m = run("mixtral", "extract", PolicyKind::Cascade(Default::default()), 200, true);
    let r = &m.requests[0];
    // First iterations are the K=0 baseline measurement.
    for it in r.iters.iter().take(4) {
        assert_eq!(it.phase, IterPhase::Baseline);
        assert_eq!(it.k_chosen, 0);
    }
    // A test phase must follow.
    assert_eq!(r.iters[4].phase, IterPhase::Test);
    // And set phases must exist.
    assert!(r.iters.iter().any(|it| it.phase == IterPhase::Set));
}

#[test]
fn real_and_sim_engines_agree_on_etr() {
    if !real_stack_ready("real_and_sim_engines_agree_on_etr") {
        return;
    }
    // The sim backend replaces HLO execution; acceptance statistics are
    // driven by the same workload + guided process, so ETR must agree
    // within a loose band. (Expert counts differ more: real routing vs the
    // parameterized process.)
    for task in ["code", "math"] {
        let real = run("mixtral", task, PolicyKind::Static(3), 300, false);
        let sim = run("mixtral", task, PolicyKind::Static(3), 300, true);
        let (a, b) = (real.mean_etr(), sim.mean_etr());
        assert!(
            (a - b).abs() / a < 0.35,
            "{task}: real etr {a:.2} vs sim etr {b:.2}"
        );
    }
}

#[test]
fn real_and_sim_agree_on_math_slowdown_direction() {
    let base = run("mixtral", "math", PolicyKind::Static(0), 300, true);
    let spec = run("mixtral", "math", PolicyKind::Static(3), 300, true);
    assert!(base.tpot_s() / spec.tpot_s() < 1.0, "sim should also show math slowdown");
}

#[test]
fn mixed_workload_interleaves_tasks() {
    let m = run("mixtral", "all-3", PolicyKind::Cascade(Default::default()), 400, true);
    let tasks: std::collections::BTreeSet<String> =
        m.requests.iter().map(|r| r.task.clone()).collect();
    assert!(tasks.len() >= 2, "mixed stream must interleave tasks: {tasks:?}");
}

#[test]
fn kv_window_bounds_respected() {
    // A long request must stop at the KV window, not crash. Backend-
    // agnostic: run on sim so it needs no artifacts.
    let reg = registry();
    let cfg = EngineConfig { model: "mixtral".into(), max_new_tokens: 100_000, ..Default::default() };
    let mut engine = Engine::sim(&reg, cfg, PolicyKind::Static(3).build()).unwrap();
    let mut stream = RequestStream::new(Workload::single(Task::Code), 3, 100_000);
    let req = stream.next_request();
    let m = engine.serve_request(&req).unwrap();
    assert!(m.prompt_tokens + m.tokens_emitted() <= 384 + 8);
}
