//! Open-loop serving integration (sim backend; no artifacts needed):
//!
//! * **determinism guard** — the refactored event-loop scheduler with
//!   `--arrivals closed --admission fcfs` (the defaults) reproduces the
//!   pre-refactor closed-loop scheduler token-for-token and
//!   metric-for-metric, across eviction on/off × pipeline on/off. The
//!   legacy loop is replicated inline below (it was small) and driven
//!   against a second engine built identically;
//! * **budget law** — the PR-1 token-budget clamp, now folded into the
//!   admission layer (`AdmissionQueue::clamp`), still holds exactly:
//!   batched runs never overshoot `max_tokens`;
//! * **latency stamps** are ordered (arrival ≤ admitted ≤ first token ≤
//!   finish) and open-loop runs are bit-reproducible;
//! * **trace replay** serves requests at trace times: the engine idles
//!   between spaced arrivals (`idle_s > 0`, a state the closed loop cannot
//!   express) and completes every traced request;
//! * **overload builds a queue** — bursty arrivals beyond service capacity
//!   leave arrived requests waiting (`mean_queue_depth > 0`);
//! * the **contended bursty cell** (the `figure arrivals` / bench cell)
//!   genuinely evicts and still completes under every admission policy.

use cascade::config::{EngineConfig, EvictionKind};
use cascade::coordinator::batch::BatchEngine;
use cascade::coordinator::scheduler::{Budget, Scheduler};
use cascade::experiments::arrivals::{contended_cell, run_cell, ADMISSIONS};
use cascade::experiments::runner::{BackendKind, ExpCtx};
use cascade::metrics::BatchRunMetrics;
use cascade::models::{default_artifacts_dir, Registry};
use cascade::spec::policy::PolicyKind;
use cascade::workload::arrivals::{ArrivalKind, ArrivalProcess};
use cascade::workload::{Request, RequestStream, Workload};
use std::collections::VecDeque;

fn registry() -> Registry {
    Registry::load_or_builtin(default_artifacts_dir())
}

fn workload() -> Workload {
    Workload::by_name("code+math").unwrap()
}

fn engine(cfg: &EngineConfig, policy: &PolicyKind) -> BatchEngine {
    BatchEngine::sim(&registry(), cfg.clone(), policy.clone()).unwrap()
}

/// The PR-4 closed-loop scheduler, replicated verbatim (pull → clamp →
/// requeue-on-pressure → step): the reference the refactored event loop
/// must match bit-exactly under closed+fcfs.
fn legacy_run_batched(
    engine: &mut BatchEngine,
    stream: &mut RequestStream,
    max_tokens: usize,
    max_requests: usize,
) -> anyhow::Result<BatchRunMetrics> {
    let mut queue: VecDeque<Request> = VecDeque::new();
    let mut served = 0usize;
    loop {
        loop {
            let bound = engine.output_bound();
            if !engine.has_free_slot() || bound >= max_tokens || served >= max_requests {
                break;
            }
            let mut req = queue.pop_front().unwrap_or_else(|| stream.next_request());
            let remaining = max_tokens - bound;
            req.max_new_tokens = req.max_new_tokens.min(remaining + 1);
            if !engine.can_admit(&req) {
                queue.push_front(req);
                break;
            }
            served += 1;
            engine.admit(req)?;
        }
        if !engine.step_iteration()? {
            if engine.output_bound() >= max_tokens || served >= max_requests {
                break;
            }
            if let Some(req) = queue.front() {
                anyhow::ensure!(
                    engine.can_admit(req),
                    "request {} cannot fit the KV pool",
                    req.id
                );
            }
        }
    }
    Ok(engine.finish())
}

/// Assert two runs agree token-for-token and in iteration structure.
fn assert_runs_identical(a: &BatchRunMetrics, b: &BatchRunMetrics, label: &str) {
    assert_eq!(a.run.requests.len(), b.run.requests.len(), "{label}: request count");
    for (x, y) in a.run.requests.iter().zip(&b.run.requests) {
        assert_eq!(x.id, y.id, "{label}: request order");
        assert_eq!(x.output, y.output, "{label}: token stream of request {}", x.id);
        assert_eq!(x.iters.len(), y.iters.len(), "{label}: iterations of request {}", x.id);
        for (i, (ix, iy)) in x.iters.iter().zip(&y.iters).enumerate() {
            assert_eq!(
                (ix.k_chosen, ix.drafted, ix.accepted, ix.emitted),
                (iy.k_chosen, iy.drafted, iy.accepted, iy.emitted),
                "{label}: iteration {i} structure of request {}",
                x.id
            );
        }
        assert_eq!(x.preemptions, y.preemptions, "{label}: preemptions of request {}", x.id);
    }
    assert_eq!(a.iters.len(), b.iters.len(), "{label}: fused iteration count");
    for (i, (ix, iy)) in a.iters.iter().zip(&b.iters).enumerate() {
        assert_eq!(
            (ix.n_active, ix.total_tokens, ix.total_drafted, ix.emitted),
            (iy.n_active, iy.total_tokens, iy.total_drafted, iy.emitted),
            "{label}: fused iteration {i}"
        );
        assert_eq!(
            (ix.evictions, ix.readmissions),
            (iy.evictions, iy.readmissions),
            "{label}: preemption telemetry at fused iteration {i}"
        );
    }
}

/// Satellite: the refactored scheduler's default path is bit-exact with
/// PR-4 serving across the eviction × pipeline matrix.
#[test]
fn closed_fcfs_reproduces_legacy_scheduler() {
    let budget = Budget { max_tokens: 1_000, max_requests: 10_000 };
    for (eviction, kv_pool_blocks) in
        [(EvictionKind::Off, 0usize), (EvictionKind::Lru, 32)]
    {
        for pipeline in [false, true] {
            let label = format!(
                "eviction={} pool={kv_pool_blocks} pipeline={pipeline}",
                eviction.label()
            );
            let cfg = EngineConfig {
                model: "mixtral".into(),
                max_batch: 4,
                kv_pool_blocks,
                eviction,
                max_preemptions_per_req: 64,
                pipeline,
                ..EngineConfig::default()
            };
            let policy = PolicyKind::Static(3);

            let mut legacy_engine = engine(&cfg, &policy);
            let mut legacy_stream = RequestStream::new(workload(), 0xCA5CADE, 200);
            let legacy = legacy_run_batched(
                &mut legacy_engine,
                &mut legacy_stream,
                budget.max_tokens,
                budget.max_requests,
            );

            let mut new_engine = engine(&cfg, &policy);
            let stream = RequestStream::new(workload(), 0xCA5CADE, 200);
            let mut sched = Scheduler::new(stream, budget);
            let fresh = sched.run_batched(&mut new_engine);

            match (legacy, fresh) {
                (Ok(a), Ok(b)) => assert_runs_identical(&a, &b, &label),
                (Err(a), Err(b)) => {
                    assert_eq!(a.to_string(), b.to_string(), "{label}: error divergence")
                }
                (a, b) => panic!(
                    "{label}: outcome divergence (legacy ok={}, refactor ok={})",
                    a.is_ok(),
                    b.is_ok()
                ),
            }
        }
    }
}

/// Satellite: the PR-1 token-budget clamp holds exactly through the
/// admission layer (never overshoots, and matches the legacy totals).
#[test]
fn budget_clamp_holds_through_admission_layer() {
    for budget_tokens in [130usize, 250, 777] {
        let cfg = EngineConfig { model: "mixtral".into(), max_batch: 4, ..Default::default() };
        let policy = PolicyKind::Static(2);

        let mut new_engine = engine(&cfg, &policy);
        let stream = RequestStream::new(workload(), 5, 100);
        let mut sched = Scheduler::new(
            stream,
            Budget { max_tokens: budget_tokens, max_requests: 10_000 },
        );
        let m = sched.run_batched(&mut new_engine).unwrap();
        assert!(
            m.run.total_tokens() <= budget_tokens,
            "budget {budget_tokens} overshot: {}",
            m.run.total_tokens()
        );
        assert!(m.run.total_tokens() > 0);

        let mut legacy_engine = engine(&cfg, &policy);
        let mut legacy_stream = RequestStream::new(workload(), 5, 100);
        let legacy = legacy_run_batched(
            &mut legacy_engine,
            &mut legacy_stream,
            budget_tokens,
            10_000,
        )
        .unwrap();
        assert_eq!(
            m.run.total_tokens(),
            legacy.run.total_tokens(),
            "budget {budget_tokens}: clamp semantics drifted from the legacy scheduler"
        );
    }
}

fn open_loop_run(kind: ArrivalKind, cfg: &EngineConfig, tokens: usize) -> BatchRunMetrics {
    let policy = PolicyKind::Static(3);
    let mut eng = engine(cfg, &policy);
    let stream = RequestStream::new(workload(), cfg.seed, cfg.max_new_tokens);
    let arrivals = ArrivalProcess::new(kind, stream, cfg.seed).unwrap();
    let mut sched = Scheduler::with_arrivals(
        arrivals,
        Budget { max_tokens: tokens, max_requests: 10_000 },
    );
    sched.run_batched(&mut eng).unwrap()
}

#[test]
fn open_loop_latency_stamps_are_ordered_and_deterministic() {
    let cfg = EngineConfig {
        model: "mixtral".into(),
        max_batch: 4,
        max_new_tokens: 120,
        ..Default::default()
    };
    let kind = ArrivalKind::Poisson { rate: 2.0 };
    let m = open_loop_run(kind.clone(), &cfg, 600);
    assert!(!m.run.requests.is_empty());
    for r in &m.run.requests {
        assert!(r.arrival_s >= 0.0, "request {}: negative arrival", r.id);
        assert!(
            r.admitted_s >= r.arrival_s,
            "request {}: admitted before arrival",
            r.id
        );
        assert!(
            r.first_token_s >= r.admitted_s,
            "request {}: first token before admission",
            r.id
        );
        assert!(r.finish_s >= r.first_token_s, "request {}: finished before TTFT", r.id);
        assert!(r.queue_wait_s >= r.admitted_s - r.arrival_s - 1e-12);
        assert!(r.ttft_s() >= 0.0 && r.e2e_s() >= r.ttft_s());
    }
    assert!(m.clock_s > 0.0);
    // The percentile views are finite and ordered.
    assert!(m.run.ttft_percentile(0.5) <= m.run.ttft_percentile(0.95));
    assert!(m.run.e2e_percentile(0.5) <= m.run.e2e_percentile(0.95));

    // Bit-reproducible: the virtual clock and streams are deterministic.
    let m2 = open_loop_run(kind, &cfg, 600);
    assert_runs_identical(&m, &m2, "open-loop determinism");
    assert_eq!(m.clock_s.to_bits(), m2.clock_s.to_bits(), "virtual clock drifted");
    for (a, b) in m.run.requests.iter().zip(&m2.run.requests) {
        assert_eq!(a.ttft_s().to_bits(), b.ttft_s().to_bits());
        assert_eq!(a.queue_wait_s.to_bits(), b.queue_wait_s.to_bits());
    }
}

#[test]
fn trace_replay_idles_between_spaced_arrivals() {
    // Three arrivals 50 virtual seconds apart: each request finishes long
    // before the next arrives, so the engine must idle — the state the
    // closed loop could never express.
    let path = std::env::temp_dir().join("cascade_arrivals_idle_trace.jsonl");
    let text = "{\"t\": 0.5, \"task\": \"code\", \"max_new\": 40}\n\
                {\"t\": 50.5, \"task\": \"math\", \"max_new\": 40}\n\
                {\"t\": 100.5, \"task\": \"code\", \"max_new\": 40}\n";
    std::fs::write(&path, text).unwrap();
    let cfg = EngineConfig {
        model: "mixtral".into(),
        max_batch: 4,
        max_new_tokens: 40,
        ..Default::default()
    };
    let kind = ArrivalKind::Trace { path: path.to_string_lossy().into_owned() };
    let m = open_loop_run(kind, &cfg, 10_000);
    let _ = std::fs::remove_file(&path);
    assert_eq!(m.run.requests.len(), 3, "every traced request must complete");
    assert!(m.idle_s > 0.0, "spaced arrivals must leave the engine idle");
    assert!(m.slot_idle_fraction() > 0.5, "idle gaps dominate this trace");
    assert!(m.clock_s >= 100.5, "the clock must reach the last arrival");
    // Requests arrive (and are admitted) in trace order, uncontended:
    // queueing delay is (near) zero and TTFT ≈ prefill.
    for r in &m.run.requests {
        assert!(r.queue_wait_s < 1e-9, "request {} queued unexpectedly", r.id);
    }
}

#[test]
fn bursty_overload_builds_a_queue() {
    let cfg = EngineConfig {
        model: "mixtral".into(),
        max_batch: 4,
        max_new_tokens: 120,
        ..Default::default()
    };
    // Mean 50 req/s into a ~4-slot engine: the wait queue must be occupied
    // while the first batch decodes.
    let m = open_loop_run(ArrivalKind::bursty(50.0), &cfg, 600);
    assert!(m.run.requests.len() >= 4);
    assert!(
        m.mean_queue_depth() > 0.0,
        "overload must leave arrived requests waiting (depth {})",
        m.mean_queue_depth()
    );
    assert!(
        m.iters.iter().any(|r| r.queue_depth > 0),
        "no iteration ever observed a waiting request"
    );
}

/// The contended bursty cell behind `figure arrivals` and
/// BENCH_arrivals.json: every admission policy completes the run, and the
/// pool pressure is real (victims actually get evicted, so admission
/// *ordering* is actually exercised).
#[test]
fn contended_cells_evict_and_complete_under_every_policy() {
    let reg = registry();
    let ctx = ExpCtx::new(reg, BackendKind::Sim, 300);
    for admission in ADMISSIONS {
        let cell = contended_cell(admission, 2.0, ctx.seed);
        let m = run_cell(&ctx, "mixtral", &PolicyKind::Static(3), &cell).unwrap();
        assert!(
            m.run.requests.len() >= 8,
            "{}: too few completions ({})",
            admission.label(),
            m.run.requests.len()
        );
        assert!(
            m.evictions() > 0,
            "{}: the contended cell never evicted — pool sizing is too loose",
            admission.label()
        );
        assert!(
            m.readmissions() > 0 && m.readmissions() <= m.evictions(),
            "{}: victims must come back (evict {} readmit {})",
            admission.label(),
            m.evictions(),
            m.readmissions()
        );
        for r in &m.run.requests {
            assert!(r.finish_s >= r.first_token_s && r.first_token_s >= r.arrival_s);
        }
    }
}
