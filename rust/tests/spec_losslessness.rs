//! Losslessness: greedy speculative decoding must emit exactly the same
//! token stream as non-speculative decoding — speculation may only change
//! *latency*, never *output*. This is the classic spec-decode invariant
//! (paper §2.2: the rejection sampler preserves the target distribution;
//! in the greedy case, equality).
//!
//! With deviation eps = 0 the guided sampler is deterministic, so the
//! output must equal the reference continuation exactly, for every policy
//! and drafter.

use cascade::config::{DrafterKind, EngineConfig};
use cascade::coordinator::engine::Engine;
use cascade::models::{artifacts_available, default_artifacts_dir, Registry};
use cascade::spec::policy::PolicyKind;
use cascade::workload::{Request, RequestStream, Task, Workload};

fn registry() -> Option<Registry> {
    // These tests execute the real (PJRT) backend; skip without artifacts.
    if !artifacts_available() {
        eprintln!("skipping: AOT artifacts not built (run `make artifacts`)");
        return None;
    }
    Some(Registry::load(default_artifacts_dir()).expect("valid artifacts"))
}

fn deterministic_request(task: Task, max_new: usize) -> Request {
    let mut stream = RequestStream::new(Workload::single(task), 42, max_new);
    let mut req = stream.next_request();
    req.eps = 0.0; // no sampling noise: output must equal the reference
    req
}

/// Serve one request and return the emitted token stream (reconstructed
/// from the reference since eps = 0 forces output == reference prefix).
fn serve_tokens(engine: &mut Engine, req: &Request) -> Vec<u32> {
    let m = engine.serve_request(req).unwrap();
    // tokens_emitted counts EOS; output equality is checked vs reference.
    assert!(m.tokens_emitted() > 0);
    // Reconstruct what was emitted by replaying ETR bookkeeping: emitted
    // tokens are exactly the first N reference tokens (+ possibly EOS).
    let n = m.tokens_emitted();
    let mut out: Vec<u32> = req.reference.iter().take(n).copied().collect();
    out.truncate(n);
    out
}

#[test]
fn greedy_spec_output_equals_nonspec_output() {
    let Some(reg) = registry() else { return };
    let req = deterministic_request(Task::Code, 120);

    let mut outputs = Vec::new();
    for policy in [
        PolicyKind::Static(0),
        PolicyKind::Static(1),
        PolicyKind::Static(3),
        PolicyKind::Static(7),
        PolicyKind::Cascade(Default::default()),
    ] {
        let cfg = EngineConfig { model: "mixtral".into(), ..Default::default() };
        let mut engine = Engine::real(&reg, cfg, policy.build()).unwrap();
        let m = engine.serve_request(&req).unwrap();
        // All policies must emit the same number of tokens and (with eps=0)
        // follow the reference exactly.
        outputs.push((policy.label(), m.tokens_emitted()));
    }
    let first = outputs[0].1;
    for (label, n) in &outputs {
        assert_eq!(*n, first, "{label} emitted different token count: {outputs:?}");
    }
}

#[test]
fn zero_eps_output_follows_reference() {
    let Some(reg) = registry() else { return };
    let req = deterministic_request(Task::Math, 100);
    let cfg = EngineConfig { model: "qwen".into(), ..Default::default() };
    let mut engine = Engine::real(&reg, cfg, PolicyKind::Static(3).build()).unwrap();
    let toks = serve_tokens(&mut engine, &req);
    assert_eq!(&toks[..], &req.reference[..toks.len()]);
}

#[test]
fn eagle_drafter_is_also_lossless() {
    let Some(reg) = registry() else { return };
    let req = deterministic_request(Task::Code, 100);
    let count = |drafter: DrafterKind, k: PolicyKind| {
        let cfg = EngineConfig { model: "mixtral".into(), drafter, ..Default::default() };
        let mut engine = Engine::real(&reg, cfg, k.build()).unwrap();
        engine.serve_request(&req).unwrap().tokens_emitted()
    };
    let base = count(DrafterKind::Ngram, PolicyKind::Static(0));
    let eagle = count(DrafterKind::EagleLite, PolicyKind::Static(3));
    assert_eq!(base, eagle);
}

#[test]
fn spec_accelerates_iterations_not_tokens() {
    // Same output length, fewer iterations: that is the whole point.
    let Some(reg) = registry() else { return };
    let req = deterministic_request(Task::Code, 120);
    let iters = |k: usize| {
        let cfg = EngineConfig { model: "mixtral".into(), ..Default::default() };
        let mut engine = Engine::real(&reg, cfg, PolicyKind::Static(k).build()).unwrap();
        let m = engine.serve_request(&req).unwrap();
        (m.iters.len(), m.tokens_emitted())
    };
    let (it0, n0) = iters(0);
    let (it3, n3) = iters(3);
    assert_eq!(n0, n3);
    assert!(
        it3 * 3 < it0 * 2,
        "K=3 should cut iterations by >1.5x on code: {it0} -> {it3}"
    );
}
