//! Fault injection & graceful degradation integration (sim backend; no
//! artifacts needed). The headline contract is **losslessness under
//! chaos**: faults and the degradation controller move *time* and
//! *scheduling* — never token values — so every request that completes
//! under a fault plan emits a token stream bit-exact with the fault-free
//! run (rust/docs/faults.md).
//!
//! * **ground truth under every plan** — fully-guided (eps = 0) requests
//!   emit exactly their reference prefix under every builtin fault plan,
//!   with and without the drafting pipeline and the eviction subsystem;
//! * **bit-exactness at default eps** — with a static-K policy and an
//!   uncontended pool, time-only faults (stragglers, stalls) and
//!   replay-recovered faults (shard kills) reproduce the fault-free
//!   streams and per-iteration accept structure exactly; pool shrinks
//!   stay lossless under the eviction subsystem's all-or-nothing rule;
//! * **determinism** — the same seed and plan through the open-loop
//!   scheduler (arrivals, shedding, controller verdicts and all) yields
//!   byte-identical metrics JSON;
//! * **shedding** — the controller's load shedding only ever drops
//!   requests *before* admission: shed requests never appear in the
//!   completed set, so they are never counted in `slo_goodput`; with the
//!   controller off, nothing is ever shed;
//! * **inertness** — `faults = off` + `controller = off` is byte-exact
//!   with a default-config engine (the fault path costs nothing when
//!   disabled).
//!
//! Losslessness is asserted for static-K policies: Cascade legitimately
//! adapts K to the (honest, stall- and reprefill-inclusive) degraded
//! costs, so its trajectories may differ — by design, not by accident.

use cascade::config::{
    AdmissionKind, ControllerKind, DrafterKind, EngineConfig, EvictionKind, HealKind,
};
use cascade::coordinator::batch::BatchEngine;
use cascade::coordinator::faults::{FaultPlan, FaultProcess, BUILTIN_PLANS};
use cascade::coordinator::scheduler::{Budget, Scheduler};
use cascade::experiments::preemption::constrained_pool_blocks;
use cascade::metrics::BatchRunMetrics;
use cascade::models::{default_artifacts_dir, Registry};
use cascade::spec::policy::PolicyKind;
use cascade::util::json::{arr, num, obj, str as jstr, write, Value};
use cascade::workload::arrivals::{ArrivalKind, ArrivalProcess};
use cascade::workload::{Request, RequestStream, Task, Workload};

fn registry() -> Registry {
    Registry::load_or_builtin(default_artifacts_dir())
}

fn requests(task: &str, n: usize, max_new: usize) -> Vec<Request> {
    let w = Workload::by_name(task).unwrap();
    RequestStream::new(w, 0xCA5CADE, max_new).take(n)
}

/// Deterministic fully-guided requests (eps = 0, reference longer than the
/// budget): the served stream is exactly the reference prefix no matter
/// what the scheduler, the pool, or the fault plan does — ground truth
/// that needs no second engine run (same construction as
/// rust/tests/preemption.rs).
fn crafted_requests(n: usize, max_new: usize) -> Vec<Request> {
    (0..n)
        .map(|i| {
            let prompt: Vec<u32> = (0..40).map(|p| 1 + ((p + 3 * i) % 200) as u32).collect();
            let reference: Vec<u32> =
                (0..max_new + 16).map(|p| 1 + ((p * 7 + i) % 200) as u32).collect();
            Request {
                id: i as u64,
                task: Task::Code,
                prompt,
                reference,
                eps: 0.0,
                max_new_tokens: max_new,
            }
        })
        .collect()
}

/// Batch-4, 2-shard engine config (shard-scoped faults need a topology to
/// act on) over the default uncontended pool.
fn cfg(faults: &str, eviction: EvictionKind, pipeline: bool) -> EngineConfig {
    EngineConfig {
        model: "mixtral".into(),
        drafter: DrafterKind::Ngram,
        max_batch: 4,
        shards: 2,
        eviction,
        max_preemptions_per_req: 100,
        pipeline,
        faults: faults.into(),
        ..Default::default()
    }
}

fn serve(cfg: EngineConfig, policy: PolicyKind, reqs: &[Request]) -> BatchRunMetrics {
    let reg = registry();
    let mut engine = BatchEngine::sim(&reg, cfg, policy).unwrap();
    engine.serve_all(reqs).unwrap()
}

/// Does this builtin plan contain a pool-shrink clause? Shrinks are only
/// lossless under the eviction subsystem (the legacy `eviction = off`
/// pressure response shrinks K, which legitimately moves the stream), so
/// the off-mode matrices skip them.
fn has_pool_shrink(plan: &str) -> bool {
    plan == "pool-shrink" || plan == "chaos"
}

/// Plan-specific telemetry: a run under a fault plan must show the plan
/// actually fired — otherwise the losslessness assertions are vacuous.
fn assert_plan_fired(plan: &str, m: &BatchRunMetrics) {
    assert!(m.fault_events > 0, "{plan}: no fault event ever fired");
    if plan == "stall" || plan == "chaos" {
        assert!(m.total_stall_retries() >= 2, "{plan}: stall never fired");
        assert!(m.stall_s() > 0.0, "{plan}: stall retries charged no time");
    }
    if plan == "shard-kill" || plan == "chaos" {
        assert!(m.evictions() > 0, "{plan}: shard kill evicted nobody");
        assert_eq!(
            m.evictions(),
            m.readmissions(),
            "{plan}: a kill victim never came back"
        );
        assert!(m.reprefill_s() > 0.0, "{plan}: recovery re-prefill was free");
    }
}

/// Every builtin plan, with and without the pipeline and the eviction
/// subsystem: fully-guided requests complete and emit exactly their
/// reference prefix. This is losslessness against ground truth — no
/// baseline run, so no way for a shared bug to cancel out.
#[test]
fn guided_streams_survive_every_builtin_plan() {
    let reqs = crafted_requests(6, 150);
    for &(plan, _) in BUILTIN_PLANS {
        for pipeline in [false, true] {
            for eviction in [EvictionKind::Off, EvictionKind::Lru] {
                if eviction == EvictionKind::Off && has_pool_shrink(plan) {
                    continue;
                }
                let m = serve(cfg(plan, eviction, pipeline), PolicyKind::Static(3), &reqs);
                assert_eq!(
                    m.run.requests.len(),
                    6,
                    "{plan}/{eviction:?} pipeline={pipeline}: not all requests completed"
                );
                for (req, done) in reqs.iter().zip(&m.run.requests) {
                    assert_eq!(req.id, done.id);
                    assert_eq!(
                        done.output,
                        req.reference[..done.output.len()].to_vec(),
                        "{plan}/{eviction:?} pipeline={pipeline}: request {} deviated \
                         from its fully-guided reference",
                        req.id
                    );
                    assert!(done.output.len() >= req.max_new_tokens - 1);
                }
                assert_plan_fired(plan, &m);
            }
        }
    }
}

/// Default-eps (sampled) streams under a static-K policy: time-only and
/// replay-recovered faults reproduce the fault-free token streams and
/// per-iteration accept structure bit-exactly, pipeline on or off,
/// eviction on or off. Pool shrinks join the matrix under eviction mode,
/// where pool pressure is all-or-nothing per slot (defer or evict, never
/// shrink K) and replay re-admission reconstructs backend state exactly.
#[test]
fn completed_streams_bit_exact_with_fault_free_run() {
    let reqs = requests("code+math", 8, 150);
    for pipeline in [false, true] {
        for eviction in [EvictionKind::Off, EvictionKind::Lru] {
            let base = serve(
                cfg("off", eviction, pipeline),
                PolicyKind::Static(3),
                &reqs,
            );
            assert_eq!(base.run.requests.len(), 8);
            assert_eq!(base.fault_events, 0, "fault-free run fired a fault event");
            for &(plan, _) in BUILTIN_PLANS {
                if eviction == EvictionKind::Off && has_pool_shrink(plan) {
                    continue;
                }
                let m = serve(cfg(plan, eviction, pipeline), PolicyKind::Static(3), &reqs);
                assert_eq!(base.run.requests.len(), m.run.requests.len());
                for (b, c) in base.run.requests.iter().zip(&m.run.requests) {
                    assert_eq!(b.id, c.id);
                    assert_eq!(
                        b.output, c.output,
                        "{plan}/{eviction:?} pipeline={pipeline}: request {} diverged \
                         from the fault-free run",
                        b.id
                    );
                    assert_eq!(
                        b.iters.len(),
                        c.iters.len(),
                        "{plan}: request {} iteration structure changed",
                        b.id
                    );
                    for (bi, ci) in b.iters.iter().zip(&c.iters) {
                        assert_eq!(bi.k_chosen, ci.k_chosen);
                        assert_eq!(bi.drafted, ci.drafted);
                        assert_eq!(bi.accepted, ci.accepted);
                        assert_eq!(bi.emitted, ci.emitted);
                    }
                }
                assert_plan_fired(plan, &m);
            }
        }
    }
}

/// Faults are charged, not free: a straggler plan's batch clock is
/// strictly slower than fault-free on the same requests, and a stall
/// plan's slowdown is at least its injected stall time.
#[test]
fn faults_slow_the_batch_clock_honestly() {
    let reqs = requests("code+math", 8, 150);
    let clock = |m: &BatchRunMetrics| m.iters.iter().map(|r| r.cost.total()).sum::<f64>();
    let base = serve(cfg("off", EvictionKind::Off, false), PolicyKind::Static(3), &reqs);
    for plan in ["straggler", "stall", "shard-kill"] {
        let m = serve(cfg(plan, EvictionKind::Off, false), PolicyKind::Static(3), &reqs);
        assert_eq!(base.run.total_tokens(), m.run.total_tokens(), "{plan}: tokens moved");
        assert!(
            clock(&m) > clock(&base),
            "{plan}: fault not reflected in the batch clock ({} <= {})",
            clock(&m),
            clock(&base)
        );
    }
    let stalled = serve(cfg("stall", EvictionKind::Off, false), PolicyKind::Static(3), &reqs);
    assert!(
        clock(&stalled) >= clock(&base) + stalled.stall_s(),
        "stall time missing from the clock"
    );
}

/// Serialize everything downstream consumers read off a chaos run —
/// including the fault/controller telemetry this PR adds — through the
/// crate's own JSON writer, so map ordering is part of the contract
/// (same discipline as rust/tests/determinism.rs).
fn chaos_metrics_json(m: &BatchRunMetrics, slo_s: f64) -> String {
    let requests: Vec<Value> = m
        .run
        .requests
        .iter()
        .map(|r| {
            obj(vec![
                ("id", num(r.id as f64)),
                ("output", arr(r.output.iter().map(|&t| num(t as f64)).collect())),
                ("tpot_s", num(r.tpot_s())),
                ("preemptions", num(r.preemptions as f64)),
            ])
        })
        .collect();
    let v = obj(vec![
        ("tpot_s", num(m.tpot_s())),
        ("clock_s", num(m.clock_s)),
        ("iters", num(m.iters.len() as f64)),
        ("sheds", num(m.sheds as f64)),
        ("fault_events", num(m.fault_events as f64)),
        ("recovery_s", num(m.recovery_s)),
        ("stall_retries", num(m.total_stall_retries() as f64)),
        ("stall_s", num(m.stall_s())),
        ("degraded_fraction", num(m.degraded_fraction())),
        ("slo_goodput", num(m.run.slo_goodput(slo_s))),
        ("ttft_p95_s", num(m.run.ttft_percentile(0.95))),
        ("backend", jstr("sim")),
        ("requests", arr(requests)),
    ]);
    write(&v)
}

/// One contended open-loop chaos run: bursty arrivals into a
/// half-working-set pool with LRU eviction and EDF admission, 2 shards,
/// a fault plan, and a TTFT SLO for the controller/shedder.
fn sched_run(
    seed: u64,
    faults: &str,
    controller: ControllerKind,
    slo_s: f64,
    rate: f64,
) -> BatchRunMetrics {
    sched_run_with_process(seed, faults, "off", controller, slo_s, rate)
}

/// [`sched_run`] with a `--fault-process` spec layered on the plan.
fn sched_run_with_process(
    seed: u64,
    faults: &str,
    process: &str,
    controller: ControllerKind,
    slo_s: f64,
    rate: f64,
) -> BatchRunMetrics {
    let max_new = 120usize;
    let w = Workload::by_name("code+math").unwrap();
    let sample = RequestStream::new(w.clone(), seed, max_new).take(8);
    let mut cfg = cfg(faults, EvictionKind::Lru, false);
    cfg.seed = seed;
    cfg.fault_process = process.into();
    cfg.max_new_tokens = max_new;
    cfg.kv_pool_blocks = constrained_pool_blocks(&sample, 4);
    cfg.max_preemptions_per_req = 64;
    cfg.admission = AdmissionKind::Edf;
    cfg.slo_s = slo_s;
    cfg.controller = controller;
    let reg = registry();
    let mut engine = BatchEngine::sim(&reg, cfg, PolicyKind::Static(3)).unwrap();
    let stream = RequestStream::new(w, seed, max_new);
    let arrivals = ArrivalProcess::new(ArrivalKind::bursty(rate), stream, seed).unwrap();
    let mut sched = Scheduler::with_arrivals(
        arrivals,
        Budget { max_tokens: 12 * max_new, max_requests: 10_000 },
    );
    sched.run_batched(&mut engine).unwrap()
}

/// Same seed + same plan ⇒ byte-identical metrics JSON, through the full
/// open-loop path: arrivals, admission, shedding, controller verdicts,
/// fault scheduling — all on the virtual clock, no ambient entropy.
#[test]
fn same_seed_and_plan_produce_byte_identical_metrics() {
    let a = sched_run(0xCA5CADE, "chaos", ControllerKind::Adaptive, 0.5, 2.0);
    let b = sched_run(0xCA5CADE, "chaos", ControllerKind::Adaptive, 0.5, 2.0);
    assert_eq!(
        chaos_metrics_json(&a, 0.5),
        chaos_metrics_json(&b, 0.5),
        "two identical-seed chaos runs diverged — nondeterminism in the fault path"
    );
    // Guard against the vacuous pass where the serialization ignores the
    // run: a different seed must move the metrics.
    let c = sched_run(0xBEEF, "chaos", ControllerKind::Adaptive, 0.5, 2.0);
    assert_ne!(
        chaos_metrics_json(&a, 0.5),
        chaos_metrics_json(&c, 0.5),
        "seed does not reach the chaos run"
    );
}

/// Load shedding drops unmeetable requests *before* admission: they never
/// appear in the completed set, so `slo_goodput` (a fraction of completed
/// requests) never counts them — and with the controller off, nothing is
/// ever shed no matter how hopeless the SLO.
#[test]
fn shed_requests_never_reach_the_completed_set() {
    // An aggressive burst into a tight 50 ms TTFT SLO: the queue builds
    // faster than batch-4 service drains it, so EDF slack goes negative
    // and the shedder must fire.
    let off = sched_run(0xCA5CADE, "chaos", ControllerKind::Off, 0.05, 4.0);
    assert_eq!(off.sheds, 0, "controller off must never shed");
    let on = sched_run(0xCA5CADE, "chaos", ControllerKind::Adaptive, 0.05, 4.0);
    assert!(on.sheds > 0, "tight-SLO burst never triggered the shedder");
    assert!(!on.run.requests.is_empty(), "everything was shed");
    // Every completed request actually served tokens (a shed request
    // would appear here as an empty husk) and ids are unique.
    let mut ids: Vec<u64> = on.run.requests.iter().map(|r| r.id).collect();
    assert!(on.run.requests.iter().all(|r| !r.output.is_empty()));
    ids.dedup();
    assert_eq!(ids.len(), on.run.requests.len(), "duplicate completed request");
    let goodput = on.run.slo_goodput(0.05);
    assert!((0.0..=1.0).contains(&goodput));
}

/// The controller actually degrades under pressure: some iterations run
/// throttled (the per-iteration `degraded` flag reaches telemetry), and
/// with the controller off the flag never fires.
#[test]
fn controller_degrades_under_pressure_and_is_inert_when_off() {
    let off = sched_run(0xCA5CADE, "chaos", ControllerKind::Off, 0.5, 2.0);
    assert_eq!(off.degraded_fraction(), 0.0, "controller off marked iterations degraded");
    let on = sched_run(0xCA5CADE, "chaos", ControllerKind::Adaptive, 0.5, 2.0);
    assert!(
        on.degraded_fraction() > 0.0,
        "contended chaos never tripped the degradation controller"
    );
}

/// `--faults off --controller off` is bit-exact with a default-config
/// engine: the fault plan parses to the empty plan, every fault query
/// short-circuits, and the controller never overrides the policy — the
/// subsystem costs nothing when disabled.
#[test]
fn faults_off_controller_off_is_bit_exact_with_default_engine() {
    let reqs = requests("code+math", 8, 120);
    let default_cfg = EngineConfig {
        model: "mixtral".into(),
        drafter: DrafterKind::Ngram,
        max_batch: 4,
        shards: 2,
        pipeline: true,
        ..Default::default()
    };
    let mut explicit = default_cfg.clone();
    explicit.faults = "off".into();
    explicit.controller = ControllerKind::Off;
    let a = serve(default_cfg, PolicyKind::Static(3), &reqs);
    let b = serve(explicit, PolicyKind::Static(3), &reqs);
    assert_eq!(
        chaos_metrics_json(&a, 0.5),
        chaos_metrics_json(&b, 0.5),
        "explicit --faults off --controller off diverged from the default engine"
    );
    assert_eq!(a.fault_events, 0);
    assert_eq!(a.sheds, 0);
    assert_eq!(a.stall_s(), 0.0);
    assert_eq!(a.recovery_s, 0.0);
}

/// A correlated fault domain (`host=0:shards=0,1`) takes out both member
/// shards with one clause, and the run stays lossless: every completed
/// stream is bit-exact with the fault-free run, the victims replay back,
/// and the recovery time is charged. The domain declaration also survives
/// the `parse -> to_spec -> parse` round trip.
#[test]
fn correlated_host_kill_is_lossless() {
    let reqs = requests("code+math", 8, 150);
    let spec = "host=0:shards=0,1;shard-kill@0.4+1:host=0";
    // 4 shards so the killed host (shards 0 and 1) leaves survivors.
    let mut base_cfg = cfg("off", EvictionKind::Lru, false);
    base_cfg.shards = 4;
    let mut kill_cfg = cfg(spec, EvictionKind::Lru, false);
    kill_cfg.shards = 4;
    let base = serve(base_cfg, PolicyKind::Static(3), &reqs);
    let m = serve(kill_cfg, PolicyKind::Static(3), &reqs);
    assert_eq!(base.run.requests.len(), m.run.requests.len());
    for (b, c) in base.run.requests.iter().zip(&m.run.requests) {
        assert_eq!(b.id, c.id);
        assert_eq!(b.output, c.output, "host kill moved tokens of request {}", b.id);
    }
    assert!(m.fault_events > 0, "host kill never fired");
    assert!(m.evictions() > 0, "host kill evicted nobody");
    assert_eq!(m.evictions(), m.readmissions(), "a host-kill victim never came back");
    assert!(m.recovery_s > 0.0, "kill recovery was free");
    // Parse-level: the host clause expanded into one kill per member
    // shard (the correlation), and the spec round-trips.
    let plan = FaultPlan::parse(spec).unwrap();
    assert_eq!(plan.events.len(), 2, "host=0 must expand into 2 shard kills");
    assert_eq!(plan.domains.len(), 1);
    assert_eq!(FaultPlan::parse(&plan.to_spec()).unwrap(), plan);
}

/// The stochastic MTBF/MTTR process is seed-deterministic end to end: the
/// same (spec, seed) draws the same schedule (which round-trips through
/// the plan grammar) and replays the full open-loop run byte-identically;
/// a different seed moves the schedule.
#[test]
fn mtbf_process_materializes_and_replays_deterministically() {
    let spec = "mtbf=1.5,mttr=0.4,kind=straggler";
    let p = FaultProcess::parse(spec).unwrap().expect("spec is not off");
    let a = p.materialize(0xCA5CADE, 2, 30.0);
    let b = p.materialize(0xCA5CADE, 2, 30.0);
    assert_eq!(a, b, "same seed drew a different fault schedule");
    assert!(!a.events.is_empty(), "30 s horizon at 1.5 s MTBF drew nothing");
    assert_ne!(
        a,
        p.materialize(0xBEEF, 2, 30.0),
        "seed does not reach the process schedule"
    );
    assert_eq!(
        FaultPlan::parse(&a.to_spec()).unwrap(),
        a,
        "materialized schedule must round-trip through the plan grammar"
    );
    // Engine level: the process merges into the plan inside the engine,
    // fires real events, and two identically-seeded runs are byte-equal.
    let run = |seed: u64| {
        sched_run_with_process(seed, "off", spec, ControllerKind::Adaptive, 0.5, 2.0)
    };
    let x = run(0xCA5CADE);
    let y = run(0xCA5CADE);
    assert_eq!(
        chaos_metrics_json(&x, 0.5),
        chaos_metrics_json(&y, 0.5),
        "identical-seed MTBF runs diverged"
    );
    assert!(x.fault_events > 0, "the materialized process never fired in the engine");
}

/// Straggler-aware self-healing placement: under a persistent straggler,
/// `--heal detect` migrates hot experts off the slow shard. Token streams
/// stay bit-identical to the no-detection run (placement moves cost,
/// never tokens), the migration is detected, counted, and charged, and
/// the verify clock from the first migration onward is strictly cheaper
/// than the unhealed run's over the same iterations.
#[test]
fn self_healing_migrates_off_the_straggler_without_moving_tokens() {
    let reqs = requests("code+math", 8, 150);
    // One long straggle covering the whole run: shard 1 at 6x.
    let spec = "straggler@0.1+30:shard=1,factor=6";
    let base_cfg = cfg(spec, EvictionKind::Off, false);
    let mut heal_cfg = base_cfg.clone();
    heal_cfg.heal = HealKind::Detect;
    let base = serve(base_cfg, PolicyKind::Static(3), &reqs);
    let heal = serve(heal_cfg, PolicyKind::Static(3), &reqs);
    assert_eq!(base.run.requests.len(), heal.run.requests.len());
    for (b, h) in base.run.requests.iter().zip(&heal.run.requests) {
        assert_eq!(b.id, h.id);
        assert_eq!(b.output, h.output, "self-healing moved tokens of request {}", b.id);
    }
    assert_eq!(base.heal_rebuilds, 0, "heal off must never rebuild");
    assert!(heal.heal_rebuilds >= 1, "persistent straggler never detected");
    assert!(heal.migrated_experts() > 0, "rebuild moved no experts");
    assert!(heal.migration_s() > 0.0, "expert migration was free");
    // Identical tokens + static K => identical iteration structure, so
    // the runs compare verify-for-verify.
    assert_eq!(base.iters.len(), heal.iters.len(), "iteration structure changed");
    let first = heal
        .iters
        .iter()
        .position(|r| r.migrated_experts > 0)
        .expect("a rebuild must mark its iteration");
    let tail_verify = |m: &BatchRunMetrics| {
        m.iters[first..].iter().map(|r| r.cost.verify_s()).sum::<f64>()
    };
    assert!(
        tail_verify(&heal) < tail_verify(&base),
        "migration did not cut the straggled verify clock ({} >= {})",
        tail_verify(&heal),
        tail_verify(&base)
    );
}

/// Hysteresis: one straggle/recover cycle causes at most two placement
/// rebuilds (migrate off the slow shard, migrate back after recovery) —
/// the dead band between the mark and clear thresholds prevents flapping.
#[test]
fn hysteresis_bounds_rebuilds_across_a_straggle_recover_cycle() {
    let reqs = requests("code+math", 8, 150);
    let mut heal_cfg = cfg("straggler@0.2+1.5:shard=1,factor=6", EvictionKind::Off, false);
    heal_cfg.heal = HealKind::Detect;
    let m = serve(heal_cfg, PolicyKind::Static(3), &reqs);
    assert!(m.heal_rebuilds >= 1, "straggle window never detected");
    assert!(
        m.heal_rebuilds <= 2,
        "hysteresis failed: {} rebuilds across one straggle/recover cycle",
        m.heal_rebuilds
    );
}
