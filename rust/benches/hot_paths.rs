//! Hot-path micro-benchmarks (in-tree harness; see `cascade::bench`).
//!
//! Covers every component on the per-iteration request path, plus the raw
//! PJRT step for each verify width. These are the numbers the §Perf pass in
//! EXPERIMENTS.md optimizes.

use cascade::bench::Bench;
use cascade::config::{CascadeParams, DrafterKind, EngineConfig};
use cascade::coordinator::batch::BatchEngine;
use cascade::coordinator::engine::Engine;
use cascade::coordinator::scheduler::{Budget, Scheduler};
use cascade::cost::{ExpertBitmap, GpuCostModel};
use cascade::kv::KvBlockManager;
use cascade::models::{artifacts_available, default_artifacts_dir, paper_spec, Registry};
use cascade::rng::Rng;
use cascade::runtime::ModelRuntime;
use cascade::sampling::sample_guided;
use cascade::spec::manager::CascadeManager;
use cascade::spec::{greedy_verify, NgramDrafter};
use cascade::spec::policy::PolicyKind;
use cascade::tokenizer;
use cascade::workload::arrivals::{ArrivalKind, ArrivalProcess};
use cascade::workload::{RequestStream, Task, Workload};
use std::collections::BTreeSet;

fn main() -> anyhow::Result<()> {
    // Builtin specs keep every non-PJRT cell runnable without artifacts.
    let reg = Registry::load_or_builtin(default_artifacts_dir());

    // ---- pure components -------------------------------------------------
    let mut b = Bench::new("component");

    let code_text = {
        let mut s = RequestStream::new(Workload::single(Task::Code), 1, 200);
        let r = s.next_request();
        let mut ctx = r.prompt.clone();
        ctx.extend_from_slice(&r.reference);
        ctx
    };
    let drafter = NgramDrafter::new(1, 4);
    b.bench("ngram_propose_k3_ctx400", || drafter.propose(&code_text, 3));
    b.bench("ngram_propose_k7_ctx400", || drafter.propose(&code_text, 7));

    let drafts = [1u32, 2, 3, 4, 5, 6, 7];
    let targets = [1u32, 2, 3, 9, 5, 6, 7, 8];
    b.bench("rejection_verify_k7", || greedy_verify(&drafts, &targets));

    let cost = GpuCostModel::new(paper_spec("mixtral")?, 2);
    let uniq = [6usize, 7];
    b.bench("cost_model_verify", || {
        cost.verify_cost(&uniq, 8, 7, DrafterKind::Ngram).total()
    });

    let logits: Vec<f32> = (0..320).map(|i| (i as f32 * 0.37).sin()).collect();
    let mut rng = Rng::new(7);
    b.bench("guided_sample_v320", || {
        sample_guided(&logits, Some(42), 48.0, 0.05, &mut rng)
    });

    b.bench("kv_reserve_commit", || {
        let mut kv = KvBlockManager::new(384, 16);
        for _ in 0..40 {
            kv.reserve(4).unwrap();
            kv.commit(2).unwrap();
        }
        kv.committed()
    });

    b.bench("cascade_manager_observe", || {
        let mut mgr = CascadeManager::new(CascadeParams::default());
        for _ in 0..64 {
            let k = mgr.next_k();
            mgr.observe(1.0 + k as f64 * 0.4, 0.02 * (1.0 + 0.3 * k as f64));
        }
        mgr.next_k()
    });

    b.bench("tokenizer_encode_1k", || {
        tokenizer::encode("let x = 42; // the quick brown fox\n").len()
    });

    // ---- expert-set kernels ----------------------------------------------
    // The bitmap cells time the rebuilt hot-path set algebra; the BTreeSet
    // cells time the representation it replaced, on identical id streams
    // (benches sit outside the hot-path-set lint scope on purpose — the
    // legacy kernel lives on here as the speedup baseline).
    let mut b = Bench::new("expert_set");
    let id_sets: Vec<Vec<usize>> = {
        let mut rng = Rng::new(0x5E7_B17);
        (0..8).map(|_| (0..16).map(|_| rng.below(64)).collect()).collect()
    };
    b.bench("bitmap_union_marginal_8x16", || {
        let mut once = ExpertBitmap::new();
        let mut twice = ExpertBitmap::new();
        for ids in &id_sets {
            let set = ExpertBitmap::from_ids(ids);
            twice.union_with(&set.and(&once));
            once.union_with(&set);
        }
        once.and_not(&twice).count() + twice.count()
    });
    b.bench("btreeset_union_marginal_8x16", || {
        let mut once: BTreeSet<usize> = BTreeSet::new();
        let mut twice: BTreeSet<usize> = BTreeSet::new();
        for ids in &id_sets {
            let set: BTreeSet<usize> = ids.iter().copied().collect();
            for &e in set.intersection(&once) {
                twice.insert(e);
            }
            for &e in &set {
                once.insert(e);
            }
        }
        once.difference(&twice).count() + twice.len()
    });

    // ---- sim engine ------------------------------------------------------
    let mut b = Bench::new("sim");
    b.bench("sim_iteration_mixtral_code_k3", || {
        // One short request through the sim engine (amortized per call).
        let cfg = EngineConfig { model: "mixtral".into(), ..Default::default() };
        let mut engine = Engine::sim(&reg, cfg, PolicyKind::Static(3).build()).unwrap();
        let mut s = RequestStream::new(Workload::single(Task::Code), 3, 40);
        engine.serve_request(&s.next_request()).unwrap().tokens_emitted()
    });

    // Full batched serving loop — the end-to-end cell the simspeed artifact
    // (BENCH_simspeed.json, rust/docs/perf.md) tracks: open-loop Poisson
    // arrivals into batch 4, 2 expert shards, pipelined drafting,
    // everything on the rebuilt arena path.
    let serve_cell = || {
        let cfg = EngineConfig {
            model: "mixtral".into(),
            max_batch: 4,
            shards: 2,
            pipeline: true,
            max_new_tokens: 48,
            ..Default::default()
        };
        let mut engine = BatchEngine::sim(&reg, cfg, PolicyKind::Static(3)).unwrap();
        let stream = RequestStream::new(Workload::single(Task::Code), 9, 48);
        let arrivals =
            ArrivalProcess::new(ArrivalKind::Poisson { rate: 64.0 }, stream, 9).unwrap();
        let mut sched =
            Scheduler::with_arrivals(arrivals, Budget { max_tokens: 192, max_requests: 12 });
        sched.run_batched(&mut engine).unwrap()
    };
    let iters_per_serve = serve_cell().iters.len().max(1);
    let mean_ns = b.bench("batch_serve_b4_s2_pipeline_4x48tok", serve_cell).mean_ns();
    b.report(
        "batch_engine_iterations_per_sec",
        iters_per_serve as f64 / (mean_ns / 1e9),
        "iters/s",
    );

    if !artifacts_available() {
        println!("pjrt/e2e cells skipped: no model artifacts in this environment");
        return Ok(());
    }

    // ---- real runtime (PJRT) ----------------------------------------------
    let mut b = Bench::new("pjrt");
    let mut rt = ModelRuntime::load(&reg, "mixtral")?;
    rt.warmup()?;
    for t in [1usize, 4, 8] {
        let tokens: Vec<u32> = (0..t as u32).collect();
        let mut state = rt.fresh_state();
        b.bench(&format!("step_t{t}_mixtral"), || {
            rt.step(&mut state, &tokens).unwrap().t
        });
    }
    let mut rt = ModelRuntime::with_client(&reg, "olmoe", rt.client())?;
    let mut state = rt.fresh_state();
    b.bench("step_t8_olmoe_64exp", || {
        rt.step(&mut state, &[0, 1, 2, 3, 4, 5, 6, 7]).unwrap().t
    });

    // ---- end-to-end serving iteration --------------------------------------
    let mut b = Bench::new("e2e");
    let cfg = EngineConfig { model: "mixtral".into(), ..Default::default() };
    let mut engine = Engine::real(&reg, cfg, PolicyKind::Cascade(CascadeParams::default()).build())?;
    let mut stream = RequestStream::new(Workload::single(Task::Code), 11, 60);
    let reqs: Vec<_> = (0..3).map(|_| stream.next_request()).collect();
    let mut i = 0usize;
    b.bench("serve_request_60tok_cascade", || {
        let r = &reqs[i % reqs.len()];
        i += 1;
        engine.serve_request(r).unwrap().tokens_emitted()
    });
    let wall_per_tok = engine.label();
    b.report(&format!("engine {wall_per_tok}"), 1.0, "ok");

    Ok(())
}
