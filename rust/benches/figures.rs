//! End-to-end figure benches: regenerates a compact version of every paper
//! table/figure (sim backend for the full matrix sweeps, real backend for
//! the headline row) and reports the wall cost of each harness.
//!
//! `cargo bench --bench figures` — pass CASCADE_BENCH_FAST=1 for a smoke
//! run. Full-budget regeneration is `make figures` (real backend).

use cascade::experiments::{self, BackendKind, ExpCtx};
use cascade::models::{default_artifacts_dir, Registry};
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    let fast = std::env::var("CASCADE_BENCH_FAST").is_ok();
    let tokens = if fast { 120 } else { 250 };

    // Full matrix on the sim backend (covers every figure quickly).
    let reg = Registry::load(default_artifacts_dir())?;
    let mut ctx = ExpCtx::new(reg, BackendKind::Sim, tokens);
    println!("== figure regeneration (sim backend, {tokens} tok/cell) ==");
    for exp in experiments::all() {
        let t0 = Instant::now();
        let tables = (exp.run)(&mut ctx)?;
        println!("\n--- {} ({:.1}s) ---", exp.id, t0.elapsed().as_secs_f64());
        for t in tables {
            println!("{}", t.render());
        }
    }

    // Headline row (Fig. 13, mixtral) on the real backend for the record.
    if !fast {
        let reg = Registry::load(default_artifacts_dir())?;
        let mut ctx = ExpCtx::new(reg, BackendKind::Real, 200);
        println!("\n== headline check (real backend): mixtral row of Fig. 13 ==");
        use cascade::experiments::RunSpec;
        use cascade::spec::policy::PolicyKind;
        use cascade::workload::Workload;
        for w in ["code", "math"] {
            let wl = Workload::by_name(w).unwrap();
            for (label, p) in [
                ("k3", PolicyKind::Static(3)),
                ("cascade", PolicyKind::Cascade(Default::default())),
            ] {
                let t0 = Instant::now();
                let s = ctx.speedup(&RunSpec::new("mixtral", wl.clone(), p))?;
                println!(
                    "mixtral/{w}/{label}: {s:.2}x vs no-spec  ({:.1}s wall)",
                    t0.elapsed().as_secs_f64()
                );
            }
        }
    }
    Ok(())
}
