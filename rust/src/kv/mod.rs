//! Block-based KV-cache manager with speculative lookahead slots.
//!
//! Mirrors vLLM's paged KV management at the granularity this stack needs
//! (paper Fig. 14: the lookahead scheduler "reserves speculative generated
//! token KV-states"). The device tensor is the fixed window `[0, max_seq)`
//! owned by `runtime::RequestState`; this module tracks which positions are
//! *committed* vs *speculative*, maps them onto fixed-size blocks, and
//! accounts allocation/rollback so the engine can enforce capacity and
//! report cache pressure.

use anyhow::{bail, Result};

/// Allocation state of one request's KV window.
#[derive(Debug, Clone)]
struct KvAllocation {
    /// Committed tokens (== `RequestState::cache_len`).
    committed: usize,
    /// Speculative positions currently reserved beyond `committed`.
    lookahead: usize,
    /// Blocks currently allocated.
    blocks: usize,
}

/// Block-based manager for a fixed `max_seq` window.
#[derive(Debug, Clone)]
pub struct KvBlockManager {
    pub block_size: usize,
    pub max_seq: usize,
    alloc: KvAllocation,
    /// Stats for telemetry / tests.
    pub peak_blocks: usize,
    pub total_reserved: u64,
    pub total_rolled_back: u64,
}

impl KvBlockManager {
    pub fn new(max_seq: usize, block_size: usize) -> Self {
        assert!(block_size > 0 && max_seq % block_size == 0);
        Self {
            block_size,
            max_seq,
            alloc: KvAllocation { committed: 0, lookahead: 0, blocks: 0 },
            peak_blocks: 0,
            total_reserved: 0,
            total_rolled_back: 0,
        }
    }

    fn blocks_for(&self, tokens: usize) -> usize {
        tokens.div_ceil(self.block_size)
    }

    pub fn committed(&self) -> usize {
        self.alloc.committed
    }

    pub fn blocks_in_use(&self) -> usize {
        self.alloc.blocks
    }

    /// Total capacity in blocks.
    pub fn total_blocks(&self) -> usize {
        self.max_seq / self.block_size
    }

    /// Can a step of `t` tokens (1 original + lookahead) be admitted?
    pub fn can_reserve(&self, t: usize) -> bool {
        self.alloc.committed + t <= self.max_seq
    }

    /// Reserve slots for a step of `t` in-flight tokens (vLLM lookahead).
    /// Allocates any new blocks the speculative span touches.
    pub fn reserve(&mut self, t: usize) -> Result<()> {
        if !self.can_reserve(t) {
            bail!(
                "KV overflow: committed {} + in-flight {t} > max_seq {}",
                self.alloc.committed,
                self.max_seq
            );
        }
        self.alloc.lookahead = t;
        let needed = self.blocks_for(self.alloc.committed + t);
        if needed > self.alloc.blocks {
            self.alloc.blocks = needed;
        }
        self.peak_blocks = self.peak_blocks.max(self.alloc.blocks);
        self.total_reserved += t as u64;
        Ok(())
    }

    /// Commit `advance` of the reserved in-flight tokens; the rest of the
    /// lookahead is rolled back (rejected speculative tokens). Blocks that
    /// only held rejected tokens are freed for reuse — their device slots
    /// get overwritten by the next step at the same positions.
    pub fn commit(&mut self, advance: usize) -> Result<()> {
        if advance > self.alloc.lookahead {
            bail!("commit {advance} exceeds reserved lookahead {}", self.alloc.lookahead);
        }
        self.total_rolled_back += (self.alloc.lookahead - advance) as u64;
        self.alloc.committed += advance;
        self.alloc.lookahead = 0;
        self.alloc.blocks = self.blocks_for(self.alloc.committed);
        Ok(())
    }

    /// Release everything (request finished).
    pub fn release(&mut self) {
        self.alloc = KvAllocation { committed: 0, lookahead: 0, blocks: 0 };
    }

    /// Fraction of the window committed.
    pub fn utilization(&self) -> f64 {
        self.alloc.committed as f64 / self.max_seq as f64
    }

    /// Invariant check used by tests: the span fits the window, blocks cover
    /// exactly the committed span after commit, and never exceed capacity.
    pub fn check_invariants(&self) -> Result<()> {
        if self.alloc.committed + self.alloc.lookahead > self.max_seq {
            bail!("span exceeds window");
        }
        if self.alloc.blocks > self.total_blocks() {
            bail!("blocks exceed capacity");
        }
        if self.alloc.blocks < self.blocks_for(self.alloc.committed) {
            bail!("committed tokens not covered by blocks");
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn reserve_commit_cycle() {
        let mut kv = KvBlockManager::new(64, 16);
        kv.reserve(4).unwrap(); // 1 token + 3 drafts
        assert_eq!(kv.blocks_in_use(), 1);
        kv.commit(2).unwrap(); // 1 accepted draft + 1 corrected token
        assert_eq!(kv.committed(), 2);
        assert_eq!(kv.total_rolled_back, 2);
        kv.check_invariants().unwrap();
    }

    #[test]
    fn blocks_grow_with_span() {
        let mut kv = KvBlockManager::new(64, 16);
        for _ in 0..20 {
            kv.reserve(1).unwrap();
            kv.commit(1).unwrap();
        }
        assert_eq!(kv.committed(), 20);
        assert_eq!(kv.blocks_in_use(), 2); // ceil(20/16)
    }

    #[test]
    fn overflow_rejected() {
        let mut kv = KvBlockManager::new(32, 16);
        for _ in 0..32 {
            kv.reserve(1).unwrap();
            kv.commit(1).unwrap();
        }
        assert!(kv.reserve(1).is_err());
        assert!(!kv.can_reserve(1));
    }

    #[test]
    fn commit_more_than_reserved_rejected() {
        let mut kv = KvBlockManager::new(64, 16);
        kv.reserve(3).unwrap();
        assert!(kv.commit(4).is_err());
    }

    #[test]
    fn rollback_frees_speculative_blocks() {
        let mut kv = KvBlockManager::new(64, 16);
        // Commit 15 tokens, then reserve 8 speculative (crosses a block).
        for _ in 0..15 {
            kv.reserve(1).unwrap();
            kv.commit(1).unwrap();
        }
        kv.reserve(8).unwrap();
        assert_eq!(kv.blocks_in_use(), 2);
        kv.commit(1).unwrap(); // reject all drafts
        assert_eq!(kv.committed(), 16);
        assert_eq!(kv.blocks_in_use(), 1); // speculative-only block freed
        kv.check_invariants().unwrap();
    }

    #[test]
    fn release_resets() {
        let mut kv = KvBlockManager::new(64, 16);
        kv.reserve(4).unwrap();
        kv.commit(4).unwrap();
        kv.release();
        assert_eq!(kv.committed(), 0);
        assert_eq!(kv.blocks_in_use(), 0);
    }

    /// Property test (in-tree harness): random reserve/commit traces keep
    /// invariants and conserve token accounting.
    #[test]
    fn prop_random_traces_keep_invariants() {
        let mut rng = Rng::new(0x6B76);
        for case in 0..200 {
            let mut kv = KvBlockManager::new(384, 16);
            let mut committed = 0usize;
            for _ in 0..rng.range(1, 120) {
                let t = rng.range(1, 8);
                if !kv.can_reserve(t) {
                    break;
                }
                kv.reserve(t).unwrap();
                let adv = rng.range(1, t);
                kv.commit(adv).unwrap();
                committed += adv;
                kv.check_invariants().unwrap_or_else(|e| panic!("case {case}: {e}"));
                assert_eq!(kv.committed(), committed);
            }
        }
    }

    #[test]
    fn prop_reserved_minus_rolled_back_equals_committed() {
        let mut rng = Rng::new(0x6B77);
        for _ in 0..100 {
            let mut kv = KvBlockManager::new(384, 16);
            loop {
                let t = rng.range(1, 8);
                if !kv.can_reserve(t) {
                    break;
                }
                kv.reserve(t).unwrap();
                kv.commit(rng.range(1, t)).unwrap();
            }
            assert_eq!(
                kv.total_reserved - kv.total_rolled_back,
                kv.committed() as u64
            );
        }
    }
}
