//! Block-based KV-cache manager with speculative lookahead slots.
//!
//! Mirrors vLLM's paged KV management at the granularity this stack needs
//! (paper Fig. 14: the lookahead scheduler "reserves speculative generated
//! token KV-states"). The device tensor is the fixed window `[0, max_seq)`
//! owned by `runtime::RequestState`; this module tracks which positions are
//! *committed* vs *speculative*, maps them onto fixed-size blocks, and
//! accounts allocation/rollback so the engine can enforce capacity and
//! report cache pressure.
//!
//! The shared [`KvBlockPool`] additionally supports **eviction**: a victim
//! request's blocks can be released mid-decode ([`KvBlockPool::evict`]) so
//! another request can keep decoding under an oversubscribed pool; the pool
//! keeps the victim accounting (`total_evicted`, per-request preemption
//! counts) that the engine's preemption cap and telemetry read. The evicted
//! request itself is parked by the engine and later re-admitted with a
//! recomputed (re-prefilled) KV span — see `coordinator::batch` and
//! rust/docs/preemption.md.
//!
//! With **sharing mode** enabled ([`KvBlockPool::enable_sharing`], the
//! `--prefix-share` path), every block additionally carries a physical
//! identity and a refcount, so multiple requests (and the prefix trie,
//! [`prefix::PrefixTrie`]) can map the same committed prefix block
//! copy-on-write: admission maps resident prefix blocks instead of
//! allocating fresh ones, divergence past the shared prefix allocates
//! private blocks (blocks are append-only, so a shared block is never
//! mutated), and a block is returned to the free budget only when its last
//! holder lets go — see rust/docs/prefix_cache.md. Sharing off keeps the
//! original counts-only accounting bit-exactly.

pub mod prefix;

use anyhow::{bail, Result};

/// Allocation state of one request's KV window.
#[derive(Debug, Clone)]
struct KvAllocation {
    /// Committed tokens (== `RequestState::cache_len`).
    committed: usize,
    /// Speculative positions currently reserved beyond `committed`.
    lookahead: usize,
    /// Blocks currently allocated.
    blocks: usize,
}

/// Block-based manager for a fixed `max_seq` window.
#[derive(Debug, Clone)]
pub struct KvBlockManager {
    pub block_size: usize,
    pub max_seq: usize,
    alloc: KvAllocation,
    /// Stats for telemetry / tests.
    pub peak_blocks: usize,
    pub total_reserved: u64,
    pub total_rolled_back: u64,
}

impl KvBlockManager {
    pub fn new(max_seq: usize, block_size: usize) -> Self {
        assert!(block_size > 0 && max_seq % block_size == 0);
        Self {
            block_size,
            max_seq,
            alloc: KvAllocation { committed: 0, lookahead: 0, blocks: 0 },
            peak_blocks: 0,
            total_reserved: 0,
            total_rolled_back: 0,
        }
    }

    fn blocks_for(&self, tokens: usize) -> usize {
        tokens.div_ceil(self.block_size)
    }

    pub fn committed(&self) -> usize {
        self.alloc.committed
    }

    pub fn blocks_in_use(&self) -> usize {
        self.alloc.blocks
    }

    /// Total capacity in blocks.
    pub fn total_blocks(&self) -> usize {
        self.max_seq / self.block_size
    }

    /// Can a step of `t` tokens (1 original + lookahead) be admitted?
    pub fn can_reserve(&self, t: usize) -> bool {
        self.alloc.committed + t <= self.max_seq
    }

    /// Reserve slots for a step of `t` in-flight tokens (vLLM lookahead).
    /// Allocates any new blocks the speculative span touches.
    pub fn reserve(&mut self, t: usize) -> Result<()> {
        if !self.can_reserve(t) {
            bail!(
                "KV overflow: committed {} + in-flight {t} > max_seq {}",
                self.alloc.committed,
                self.max_seq
            );
        }
        self.alloc.lookahead = t;
        let needed = self.blocks_for(self.alloc.committed + t);
        if needed > self.alloc.blocks {
            self.alloc.blocks = needed;
        }
        self.peak_blocks = self.peak_blocks.max(self.alloc.blocks);
        self.total_reserved += t as u64;
        Ok(())
    }

    /// Commit `advance` of the reserved in-flight tokens; the rest of the
    /// lookahead is rolled back (rejected speculative tokens). Blocks that
    /// only held rejected tokens are freed for reuse — their device slots
    /// get overwritten by the next step at the same positions.
    pub fn commit(&mut self, advance: usize) -> Result<()> {
        if advance > self.alloc.lookahead {
            bail!("commit {advance} exceeds reserved lookahead {}", self.alloc.lookahead);
        }
        self.total_rolled_back += (self.alloc.lookahead - advance) as u64;
        self.alloc.committed += advance;
        self.alloc.lookahead = 0;
        self.alloc.blocks = self.blocks_for(self.alloc.committed);
        Ok(())
    }

    /// Release everything (request finished).
    pub fn release(&mut self) {
        self.alloc = KvAllocation { committed: 0, lookahead: 0, blocks: 0 };
    }

    /// Fraction of the window in use: committed tokens *plus* the reserved
    /// speculative lookahead. Mid-speculation the lookahead rows are real
    /// cache pressure (they occupy device slots until rolled back), which
    /// is exactly when admission control needs an honest number.
    pub fn utilization(&self) -> f64 {
        (self.alloc.committed + self.alloc.lookahead) as f64 / self.max_seq as f64
    }

    /// Speculative positions currently reserved beyond the committed span.
    pub fn lookahead(&self) -> usize {
        self.alloc.lookahead
    }

    /// Invariant check used by tests: the span fits the window, blocks cover
    /// exactly the committed span after commit, and never exceed capacity.
    pub fn check_invariants(&self) -> Result<()> {
        if self.alloc.committed + self.alloc.lookahead > self.max_seq {
            bail!("span exceeds window");
        }
        if self.alloc.blocks > self.total_blocks() {
            bail!("blocks exceed capacity");
        }
        if self.alloc.blocks < self.blocks_for(self.alloc.committed) {
            bail!("committed tokens not covered by blocks");
        }
        Ok(())
    }
}

/// Per-request accounting inside the shared pool.
#[derive(Debug, Clone)]
struct PoolAlloc {
    committed: usize,
    lookahead: usize,
    blocks: usize,
    /// Sharing mode only: the physical block ids this request maps, in
    /// span order (shared prefix blocks first, then privately allocated
    /// ones); `mapped.len() == blocks`. Empty in counts-only mode.
    mapped: Vec<u64>,
}

/// Multi-request block pool for continuous batching.
///
/// All in-flight requests draw KV blocks from one fixed budget of
/// `total_blocks` — the admission-control surface of `BatchEngine`.
/// Per-request accounting mirrors [`KvBlockManager`] (committed span +
/// speculative lookahead; rollback frees speculative-only blocks), but
/// block allocation is charged against the shared budget, so one request's
/// speculation can crowd out another's admission — the batching-era cache
/// pressure the paper's single-batch setting never sees.
#[derive(Debug, Clone)]
pub struct KvBlockPool {
    pub block_size: usize,
    total_blocks: usize,
    allocs: std::collections::BTreeMap<u64, PoolAlloc>,
    /// Stats for telemetry / tests.
    pub peak_blocks: usize,
    pub total_reserved: u64,
    pub total_rolled_back: u64,
    /// Eviction events across the run (victim accounting).
    pub total_evicted: u64,
    /// Blocks released by evictions across the run.
    pub total_evicted_blocks: u64,
    /// Per-request preemption counts. Survives release/re-admission cycles
    /// (unlike `allocs`), so the engine's `max_preemptions_per_req` cap has
    /// a durable source of truth.
    preemptions: std::collections::BTreeMap<u64, u32>,
    /// Copy-on-write sharing mode (prefix cache). Off by default: the pool
    /// stays counts-only and bit-exact with the pre-sharing engine.
    sharing: bool,
    /// Sharing mode: physical block id → holder count (mapping requests
    /// plus external trie pins). A block exists iff its refcount ≥ 1.
    refcounts: std::collections::BTreeMap<u64, u32>,
    /// Sharing mode: monotone physical block id source.
    next_block_id: u64,
    /// Sharing mode: references held outside any request allocation (the
    /// prefix trie's pins), tracked so refcount conservation is exact:
    /// Σ mapped + external_refs == Σ refcounts.
    external_refs: u64,
    /// Sharing telemetry: peak count of blocks with refcount ≥ 2 (mapped
    /// by more than one holder at once).
    pub shared_blocks_peak: usize,
}

impl KvBlockPool {
    pub fn new(total_blocks: usize, block_size: usize) -> Self {
        assert!(block_size > 0 && total_blocks > 0);
        Self {
            block_size,
            total_blocks,
            allocs: std::collections::BTreeMap::new(),
            peak_blocks: 0,
            total_reserved: 0,
            total_rolled_back: 0,
            total_evicted: 0,
            total_evicted_blocks: 0,
            preemptions: std::collections::BTreeMap::new(),
            sharing: false,
            refcounts: std::collections::BTreeMap::new(),
            next_block_id: 0,
            external_refs: 0,
            shared_blocks_peak: 0,
        }
    }

    /// Switch the pool into copy-on-write sharing mode. Must happen before
    /// any admission: retrofitting identities onto counts-only allocations
    /// would have to invent block ids nobody else can already map.
    pub fn enable_sharing(&mut self) {
        assert!(self.allocs.is_empty(), "sharing must be enabled before any admission");
        self.sharing = true;
    }

    pub fn sharing(&self) -> bool {
        self.sharing
    }

    fn blocks_for(&self, tokens: usize) -> usize {
        tokens.div_ceil(self.block_size)
    }

    pub fn total_blocks(&self) -> usize {
        self.total_blocks
    }

    pub fn blocks_in_use(&self) -> usize {
        if self.sharing {
            // Physical occupancy: each live block once, however many
            // holders map it (including trie-only pins).
            self.refcounts.len()
        } else {
            self.allocs.values().map(|a| a.blocks).sum()
        }
    }

    pub fn free_blocks(&self) -> usize {
        self.total_blocks - self.blocks_in_use()
    }

    pub fn active_requests(&self) -> usize {
        self.allocs.len()
    }

    /// Committed tokens of one request (0 if unknown).
    pub fn committed(&self, id: u64) -> usize {
        self.allocs.get(&id).map_or(0, |a| a.committed)
    }

    /// Blocks currently held by one request (0 if unknown). In sharing
    /// mode this counts mapped blocks, shared or not — see
    /// [`Self::exclusive_blocks_of`] for what an eviction would free.
    pub fn blocks_of(&self, id: u64) -> usize {
        self.allocs.get(&id).map_or(0, |a| a.blocks)
    }

    /// Blocks only this request holds (refcount 1) — exactly what evicting
    /// it would return to the free budget. Counts-only mode has no sharing,
    /// so every block is exclusive and this equals [`Self::blocks_of`].
    pub fn exclusive_blocks_of(&self, id: u64) -> usize {
        match self.allocs.get(&id) {
            None => 0,
            Some(a) if !self.sharing => a.blocks,
            Some(a) => a.mapped.iter().filter(|b| self.refcount(**b) == 1).count(),
        }
    }

    /// The physical block ids request `id` maps, in span order (empty when
    /// unknown or in counts-only mode) — what the prefix trie records.
    pub fn mapped_blocks(&self, id: u64) -> Vec<u64> {
        self.allocs.get(&id).map_or_else(Vec::new, |a| a.mapped.clone())
    }

    /// Current holder count of a physical block (0 = freed/unknown).
    pub fn refcount(&self, block: u64) -> u32 {
        self.refcounts.get(&block).copied().unwrap_or(0)
    }

    /// Blocks currently mapped by more than one holder.
    pub fn shared_blocks(&self) -> usize {
        self.refcounts.values().filter(|&&rc| rc >= 2).count()
    }

    fn alloc_block(&mut self) -> u64 {
        let id = self.next_block_id;
        self.next_block_id += 1;
        self.refcounts.insert(id, 1);
        id
    }

    fn incref(&mut self, block: u64) -> Result<()> {
        match self.refcounts.get_mut(&block) {
            Some(rc) => {
                *rc += 1;
                Ok(())
            }
            None => bail!("incref of unknown block {block}"),
        }
    }

    /// Drop one reference; returns whether the block was freed (refcount
    /// reached 0 and its slot returned to the shared budget).
    fn decref(&mut self, block: u64) -> Result<bool> {
        let Some(rc) = self.refcounts.get_mut(&block) else {
            bail!("decref of unknown block {block}");
        };
        *rc -= 1;
        if *rc == 0 {
            self.refcounts.remove(&block);
            Ok(true)
        } else {
            Ok(false)
        }
    }

    fn note_shared_peak(&mut self) {
        let shared = self.shared_blocks();
        if shared > self.shared_blocks_peak {
            self.shared_blocks_peak = shared;
        }
    }

    /// Pin a block from outside any request allocation (the prefix trie's
    /// hold, which keeps cached prefixes resident across request
    /// lifetimes). Sharing mode only.
    pub fn retain_block(&mut self, block: u64) -> Result<()> {
        if !self.sharing {
            bail!("retain_block requires sharing mode");
        }
        self.incref(block)?;
        self.external_refs += 1;
        self.note_shared_peak();
        Ok(())
    }

    /// Drop an external (trie) pin; returns whether the block was freed.
    pub fn release_block(&mut self, block: u64) -> Result<bool> {
        if !self.sharing {
            bail!("release_block requires sharing mode");
        }
        self.external_refs = self
            .external_refs
            .checked_sub(1)
            .ok_or_else(|| anyhow::anyhow!("external ref underflow on block {block}"))?;
        self.decref(block)
    }

    /// Can a request with `prompt_tokens` committed tokens be admitted now?
    pub fn can_admit(&self, prompt_tokens: usize) -> bool {
        self.blocks_for(prompt_tokens.max(1)) <= self.free_blocks()
    }

    /// Admit a request, allocating blocks for its (already prefilled)
    /// prompt span. In sharing mode this is a prefix-less
    /// [`Self::admit_shared`].
    pub fn admit(&mut self, id: u64, prompt_tokens: usize) -> Result<()> {
        if self.sharing {
            return self.admit_shared(id, prompt_tokens, &[]);
        }
        if self.allocs.contains_key(&id) {
            bail!("request {id} already admitted");
        }
        let blocks = self.blocks_for(prompt_tokens.max(1));
        if blocks > self.free_blocks() {
            bail!(
                "pool exhausted: request {id} needs {blocks} blocks, {} free of {}",
                self.free_blocks(),
                self.total_blocks
            );
        }
        self.allocs.insert(
            id,
            PoolAlloc { committed: prompt_tokens, lookahead: 0, blocks, mapped: Vec::new() },
        );
        self.peak_blocks = self.peak_blocks.max(self.blocks_in_use());
        Ok(())
    }

    /// Admit a request whose leading full blocks are already resident:
    /// map `shared` (incrementing each block's refcount — the copy-on-write
    /// attach) and allocate fresh blocks only for the remainder of the
    /// `committed_tokens` span. Only the fresh remainder is charged against
    /// the free budget. Sharing mode only.
    pub fn admit_shared(&mut self, id: u64, committed_tokens: usize, shared: &[u64]) -> Result<()> {
        if !self.sharing {
            bail!("admit_shared requires sharing mode");
        }
        if self.allocs.contains_key(&id) {
            bail!("request {id} already admitted");
        }
        let total = self.blocks_for(committed_tokens.max(1));
        if shared.len() > total {
            bail!(
                "request {id}: {} shared prefix blocks exceed its {total}-block span",
                shared.len()
            );
        }
        for &b in shared {
            if self.refcount(b) == 0 {
                bail!("request {id}: shared prefix block {b} is not resident");
            }
        }
        let fresh = total - shared.len();
        if fresh > self.free_blocks() {
            bail!(
                "pool exhausted: request {id} needs {fresh} fresh blocks, {} free of {}",
                self.free_blocks(),
                self.total_blocks
            );
        }
        let mut mapped = Vec::with_capacity(total);
        for &b in shared {
            self.incref(b).expect("residency checked above");
            mapped.push(b);
        }
        for _ in 0..fresh {
            let b = self.alloc_block();
            mapped.push(b);
        }
        self.allocs.insert(
            id,
            PoolAlloc { committed: committed_tokens, lookahead: 0, blocks: total, mapped },
        );
        self.peak_blocks = self.peak_blocks.max(self.blocks_in_use());
        self.note_shared_peak();
        Ok(())
    }

    /// Can request `id` reserve a step of `t` in-flight tokens?
    pub fn can_reserve(&self, id: u64, t: usize) -> bool {
        match self.allocs.get(&id) {
            None => false,
            Some(a) => {
                let needed = self.blocks_for(a.committed + t);
                needed.saturating_sub(a.blocks) <= self.free_blocks()
            }
        }
    }

    /// Blocks still missing before `can_reserve(id, t)` would hold: the
    /// eviction feasibility pre-check's demand signal. 0 means the
    /// reservation fits as-is; an unknown request reports `usize::MAX`
    /// because no amount of eviction admits a request that is not in the
    /// pool.
    pub fn reserve_shortfall(&self, id: u64, t: usize) -> usize {
        match self.allocs.get(&id) {
            None => usize::MAX,
            Some(a) => self
                .blocks_for(a.committed + t)
                .saturating_sub(a.blocks)
                .saturating_sub(self.free_blocks()),
        }
    }

    /// Reserve lookahead slots for one request's verify step. In sharing
    /// mode the speculative growth is always freshly allocated (fork on
    /// write: positions past the shared prefix are private to the request).
    pub fn reserve(&mut self, id: u64, t: usize) -> Result<()> {
        if !self.can_reserve(id, t) {
            bail!(
                "pool reserve failed: request {id}, t={t}, {} blocks free",
                self.free_blocks()
            );
        }
        let (needed, grow) = {
            let a = self.allocs.get(&id).expect("checked by can_reserve");
            let needed = self.blocks_for(a.committed + t).max(a.blocks);
            (needed, needed - a.blocks)
        };
        let fresh: Vec<u64> =
            if self.sharing { (0..grow).map(|_| self.alloc_block()).collect() } else { Vec::new() };
        let a = self.allocs.get_mut(&id).expect("checked by can_reserve");
        a.lookahead = t;
        a.blocks = needed;
        a.mapped.extend(fresh);
        self.total_reserved += t as u64;
        self.peak_blocks = self.peak_blocks.max(self.blocks_in_use());
        Ok(())
    }

    /// Commit `advance` of the reserved tokens; roll the rest back and
    /// return speculative-only blocks to the shared budget. The sharing
    /// path pops mapped ids from the private tail — the committed span can
    /// never shrink below the shared prefix (committed tokens only grow),
    /// so a shared block is never dropped here; the decref is still the
    /// honest operation in case the tail block happens to be pinned.
    pub fn commit(&mut self, id: u64, advance: usize) -> Result<()> {
        let block_size = self.block_size;
        let sharing = self.sharing;
        let (rolled_back, to_drop) = {
            let a = self
                .allocs
                .get_mut(&id)
                .ok_or_else(|| anyhow::anyhow!("commit for unknown request {id}"))?;
            if advance > a.lookahead {
                bail!("commit {advance} exceeds reserved lookahead {}", a.lookahead);
            }
            let rolled_back = (a.lookahead - advance) as u64;
            a.committed += advance;
            a.lookahead = 0;
            let new_blocks = a.committed.max(1).div_ceil(block_size);
            let mut to_drop = Vec::new();
            if sharing {
                while a.blocks > new_blocks {
                    to_drop.push(a.mapped.pop().expect("mapped covers blocks"));
                    a.blocks -= 1;
                }
            } else {
                a.blocks = new_blocks;
            }
            (rolled_back, to_drop)
        };
        self.total_rolled_back += rolled_back;
        for b in to_drop {
            self.decref(b)?;
        }
        Ok(())
    }

    /// Release a finished request's blocks (sharing mode: drop its refs;
    /// blocks survive while the trie or another request still maps them).
    pub fn release(&mut self, id: u64) {
        if let Some(a) = self.allocs.remove(&id) {
            for b in a.mapped {
                self.decref(b).expect("mapped block has a refcount");
            }
        }
    }

    /// Evict a live request: release its blocks back to the shared budget
    /// and record the preemption. Returns the number of blocks freed — in
    /// sharing mode only the *exclusive* ones actually come back (blocks
    /// another holder maps merely lose one reference), and the eviction
    /// ledger counts the same honest number. The caller owns the rest of
    /// the preemption protocol (parking the request, invalidating its
    /// lookahead, re-prefilling on re-admission).
    pub fn evict(&mut self, id: u64) -> Result<usize> {
        let a = self
            .allocs
            .remove(&id)
            .ok_or_else(|| anyhow::anyhow!("evict for unknown request {id}"))?;
        // Any outstanding speculative reservation dies with the victim:
        // credit the rollback ledger so `total_reserved − total_rolled_back`
        // keeps meaning "tokens that ended up committed".
        self.total_rolled_back += a.lookahead as u64;
        self.total_evicted += 1;
        let freed = if self.sharing {
            let mut freed = 0usize;
            for b in a.mapped {
                if self.decref(b)? {
                    freed += 1;
                }
            }
            freed
        } else {
            a.blocks
        };
        self.total_evicted_blocks += freed as u64;
        *self.preemptions.entry(id).or_insert(0) += 1;
        Ok(freed)
    }

    /// How many times request `id` has been evicted so far (0 if never).
    pub fn preemptions(&self, id: u64) -> u32 {
        self.preemptions.get(&id).copied().unwrap_or(0)
    }

    /// Requests that were preempted at least once over the run.
    pub fn preempted_requests(&self) -> usize {
        self.preemptions.len()
    }

    /// Retarget the pool's capacity mid-run (fault injection's pool-shrink
    /// pressure spike, rust/docs/faults.md). Committed state is never
    /// revoked: the capacity is clamped to at least the blocks currently
    /// in use (and at least 1), so `free_blocks` cannot underflow and
    /// `check_invariants` keeps holding — a shrink below the working set
    /// takes effect progressively as requests finish or are evicted.
    /// Returns the capacity actually applied.
    pub fn set_capacity(&mut self, blocks: usize) -> usize {
        self.total_blocks = blocks.max(self.blocks_in_use()).max(1);
        self.total_blocks
    }

    /// Fraction of pool capacity in use. Counts-only mode reports the
    /// token-level view (committed + lookahead tokens over capacity);
    /// sharing mode reports physical block occupancy, because Σ per-request
    /// tokens double-counts shared prefixes and could exceed 1.0.
    pub fn utilization(&self) -> f64 {
        if self.sharing {
            return self.blocks_in_use() as f64 / self.total_blocks as f64;
        }
        let used: usize = self.allocs.values().map(|a| a.committed + a.lookahead).sum();
        used as f64 / (self.total_blocks * self.block_size) as f64
    }

    /// Invariants the property tests drive: the shared budget is never
    /// exceeded, and every request's span is covered by its blocks. In
    /// sharing mode, refcount conservation on top: every live block has
    /// refcount ≥ 1, no request maps a freed block, every mapping is
    /// block-backed (`mapped.len() == blocks`, so Σ per-request mapped
    /// blocks ≥ blocks_in_use once trie pins are netted out), and the
    /// reference ledger balances exactly —
    /// Σ mapped + external pins == Σ refcounts.
    pub fn check_invariants(&self) -> Result<()> {
        if self.blocks_in_use() > self.total_blocks {
            bail!(
                "pool over budget: {} blocks in use of {}",
                self.blocks_in_use(),
                self.total_blocks
            );
        }
        for (id, a) in &self.allocs {
            if a.blocks < self.blocks_for(a.committed + a.lookahead) {
                bail!("request {id}: span not covered by blocks");
            }
        }
        if self.sharing {
            let mut sum_mapped = 0u64;
            for (id, a) in &self.allocs {
                if a.mapped.len() != a.blocks {
                    bail!(
                        "request {id}: {} mapped block ids cover {} blocks",
                        a.mapped.len(),
                        a.blocks
                    );
                }
                for &b in &a.mapped {
                    if self.refcount(b) == 0 {
                        bail!("request {id} maps freed block {b}");
                    }
                }
                sum_mapped += a.mapped.len() as u64;
            }
            for (b, &rc) in &self.refcounts {
                if rc == 0 {
                    bail!("block {b} is live with refcount 0");
                }
            }
            let sum_refs: u64 = self.refcounts.values().map(|&rc| rc as u64).sum();
            if sum_mapped + self.external_refs != sum_refs {
                bail!(
                    "refcount conservation violated: {sum_mapped} mapped + {} external != {sum_refs} refs",
                    self.external_refs
                );
            }
        }
        Ok(())
    }

    /// Test-only tamper hook: inflate one live block's refcount so the
    /// conservation invariant must trip (proves `check_invariants` has
    /// teeth — rust/tests/proptests.rs). Returns false when no block is
    /// live to corrupt.
    #[doc(hidden)]
    pub fn debug_inflate_refcount(&mut self) -> bool {
        match self.refcounts.values_mut().next() {
            Some(rc) => {
                *rc += 1;
                true
            }
            None => false,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn reserve_commit_cycle() {
        let mut kv = KvBlockManager::new(64, 16);
        kv.reserve(4).unwrap(); // 1 token + 3 drafts
        assert_eq!(kv.blocks_in_use(), 1);
        kv.commit(2).unwrap(); // 1 accepted draft + 1 corrected token
        assert_eq!(kv.committed(), 2);
        assert_eq!(kv.total_rolled_back, 2);
        kv.check_invariants().unwrap();
    }

    #[test]
    fn blocks_grow_with_span() {
        let mut kv = KvBlockManager::new(64, 16);
        for _ in 0..20 {
            kv.reserve(1).unwrap();
            kv.commit(1).unwrap();
        }
        assert_eq!(kv.committed(), 20);
        assert_eq!(kv.blocks_in_use(), 2); // ceil(20/16)
    }

    #[test]
    fn overflow_rejected() {
        let mut kv = KvBlockManager::new(32, 16);
        for _ in 0..32 {
            kv.reserve(1).unwrap();
            kv.commit(1).unwrap();
        }
        assert!(kv.reserve(1).is_err());
        assert!(!kv.can_reserve(1));
    }

    #[test]
    fn commit_more_than_reserved_rejected() {
        let mut kv = KvBlockManager::new(64, 16);
        kv.reserve(3).unwrap();
        assert!(kv.commit(4).is_err());
    }

    #[test]
    fn rollback_frees_speculative_blocks() {
        let mut kv = KvBlockManager::new(64, 16);
        // Commit 15 tokens, then reserve 8 speculative (crosses a block).
        for _ in 0..15 {
            kv.reserve(1).unwrap();
            kv.commit(1).unwrap();
        }
        kv.reserve(8).unwrap();
        assert_eq!(kv.blocks_in_use(), 2);
        kv.commit(1).unwrap(); // reject all drafts
        assert_eq!(kv.committed(), 16);
        assert_eq!(kv.blocks_in_use(), 1); // speculative-only block freed
        kv.check_invariants().unwrap();
    }

    #[test]
    fn release_resets() {
        let mut kv = KvBlockManager::new(64, 16);
        kv.reserve(4).unwrap();
        kv.commit(4).unwrap();
        kv.release();
        assert_eq!(kv.committed(), 0);
        assert_eq!(kv.blocks_in_use(), 0);
    }

    /// Property test (in-tree harness): random reserve/commit traces keep
    /// invariants and conserve token accounting; utilization reflects the
    /// full (committed + lookahead) span at every point.
    #[test]
    fn prop_random_traces_keep_invariants() {
        let mut rng = Rng::new(0x6B76);
        for case in 0..200 {
            let mut kv = KvBlockManager::new(384, 16);
            let mut committed = 0usize;
            for _ in 0..rng.range(1, 120) {
                let t = rng.range(1, 8);
                if !kv.can_reserve(t) {
                    break;
                }
                kv.reserve(t).unwrap();
                // Mid-speculation: utilization must count the reserved
                // lookahead, not just the committed span.
                let expect = (committed + t) as f64 / 384.0;
                assert!(
                    (kv.utilization() - expect).abs() < 1e-12,
                    "case {case}: utilization {} != {expect}",
                    kv.utilization()
                );
                let adv = rng.range(1, t);
                kv.commit(adv).unwrap();
                committed += adv;
                kv.check_invariants().unwrap_or_else(|e| panic!("case {case}: {e}"));
                assert_eq!(kv.committed(), committed);
                assert!((kv.utilization() - committed as f64 / 384.0).abs() < 1e-12);
                assert!(kv.utilization() <= 1.0);
            }
        }
    }

    #[test]
    fn utilization_counts_lookahead() {
        let mut kv = KvBlockManager::new(64, 16);
        kv.reserve(8).unwrap();
        kv.commit(8).unwrap();
        assert!((kv.utilization() - 8.0 / 64.0).abs() < 1e-12);
        kv.reserve(6).unwrap();
        assert_eq!(kv.lookahead(), 6);
        assert!((kv.utilization() - 14.0 / 64.0).abs() < 1e-12);
        kv.commit(1).unwrap();
        assert!((kv.utilization() - 9.0 / 64.0).abs() < 1e-12);
    }

    #[test]
    fn pool_admit_reserve_commit_release() {
        let mut pool = KvBlockPool::new(8, 16); // 128 token-slots shared
        pool.admit(1, 30).unwrap(); // 2 blocks
        pool.admit(2, 17).unwrap(); // 2 blocks
        assert_eq!(pool.blocks_in_use(), 4);
        assert_eq!(pool.active_requests(), 2);
        pool.reserve(1, 4).unwrap(); // 30+4 -> 3 blocks
        assert_eq!(pool.blocks_in_use(), 5);
        pool.commit(1, 1).unwrap(); // 31 -> back to 2 blocks
        assert_eq!(pool.blocks_in_use(), 4);
        assert_eq!(pool.committed(1), 31);
        pool.release(1);
        assert_eq!(pool.blocks_in_use(), 2);
        pool.check_invariants().unwrap();
    }

    #[test]
    fn pool_admission_bounded_by_budget() {
        let mut pool = KvBlockPool::new(4, 16);
        pool.admit(1, 33).unwrap(); // 3 blocks
        assert!(!pool.can_admit(17)); // would need 2 more
        assert!(pool.can_admit(16));
        assert!(pool.admit(2, 40).is_err());
        pool.admit(2, 10).unwrap();
        assert_eq!(pool.free_blocks(), 0);
        // No room left for lookahead growth past the current block.
        assert!(!pool.can_reserve(1, 16));
        assert!(pool.reserve(1, 16).is_err());
    }

    #[test]
    fn reserve_shortfall_measures_missing_blocks() {
        let mut pool = KvBlockPool::new(4, 16);
        pool.admit(1, 33).unwrap(); // 3 blocks
        pool.admit(2, 16).unwrap(); // 1 block, pool full
        // Request 2's next token spills into a new block: 1 short.
        assert_eq!(pool.reserve_shortfall(2, 1), 1);
        // A 17-token span needs two new blocks.
        assert_eq!(pool.reserve_shortfall(2, 17), 2);
        // An unknown request can never be satisfied by eviction.
        assert_eq!(pool.reserve_shortfall(99, 1), usize::MAX);
        pool.release(1);
        assert_eq!(pool.reserve_shortfall(2, 1), 0);
        assert!(pool.can_reserve(2, 1));
    }

    #[test]
    fn set_capacity_shrinks_without_revoking_committed_state() {
        let mut pool = KvBlockPool::new(8, 16);
        pool.admit(1, 33).unwrap(); // 3 blocks
        pool.admit(2, 17).unwrap(); // 2 blocks
        assert_eq!(pool.blocks_in_use(), 5);
        // Shrink below the working set: clamps to blocks_in_use, so
        // free_blocks cannot underflow and invariants keep holding.
        assert_eq!(pool.set_capacity(2), 5);
        assert_eq!(pool.total_blocks(), 5);
        assert_eq!(pool.free_blocks(), 0);
        pool.check_invariants().unwrap();
        assert!(!pool.can_admit(1));
        // The shrink tightens as requests drain…
        pool.release(1);
        assert_eq!(pool.set_capacity(2), 2);
        assert_eq!(pool.free_blocks(), 0);
        pool.check_invariants().unwrap();
        // …and growing back restores admission headroom.
        assert_eq!(pool.set_capacity(8), 8);
        assert!(pool.can_admit(16));
        assert_eq!(pool.free_blocks(), 6);
        // Capacity never drops to zero even on an empty pool.
        pool.release(2);
        assert_eq!(pool.set_capacity(0), 1);
        pool.check_invariants().unwrap();
    }

    #[test]
    fn pool_rejects_double_admit_and_unknown_ids() {
        let mut pool = KvBlockPool::new(8, 16);
        pool.admit(7, 5).unwrap();
        assert!(pool.admit(7, 5).is_err());
        assert!(pool.reserve(9, 1).is_err());
        assert!(pool.commit(9, 0).is_err());
    }

    /// Shared-pool property: random admit/reserve/commit/release/evict
    /// traces never exceed `total_blocks`, keep every request's span
    /// covered, and keep the victim accounting consistent.
    #[test]
    fn prop_pool_never_exceeds_budget() {
        let mut rng = Rng::new(0x100F);
        for case in 0..150 {
            let total_blocks = rng.range(4, 24);
            let mut pool = KvBlockPool::new(total_blocks, 16);
            let mut live: Vec<u64> = Vec::new();
            let mut next_id = 0u64;
            let mut evictions = 0u64;
            for _ in 0..rng.range(10, 200) {
                match rng.below(5) {
                    0 => {
                        let prompt = rng.range(1, 64);
                        if pool.can_admit(prompt) {
                            pool.admit(next_id, prompt).unwrap();
                            live.push(next_id);
                            next_id += 1;
                        }
                    }
                    1 | 2 if !live.is_empty() => {
                        let id = live[rng.below(live.len())];
                        let t = rng.range(1, 8);
                        // Shortfall and can_reserve must agree: 0 missing
                        // blocks iff the reservation fits right now.
                        assert_eq!(
                            pool.reserve_shortfall(id, t) == 0,
                            pool.can_reserve(id, t),
                            "case {case}: shortfall / can_reserve disagree"
                        );
                        if pool.can_reserve(id, t) {
                            pool.reserve(id, t).unwrap();
                            pool.commit(id, rng.range(0, t)).unwrap();
                        }
                    }
                    3 if !live.is_empty() => {
                        let idx = rng.below(live.len());
                        pool.release(live.swap_remove(idx));
                    }
                    4 if !live.is_empty() => {
                        // Evict a live request, then sometimes re-admit it
                        // immediately (the park/readmit cycle's pool view).
                        let idx = rng.below(live.len());
                        let id = live[idx];
                        let before = pool.preemptions(id);
                        let free_before = pool.free_blocks();
                        let freed = pool.evict(id).unwrap();
                        evictions += 1;
                        assert_eq!(pool.preemptions(id), before + 1);
                        assert_eq!(pool.free_blocks(), free_before + freed);
                        let committed = rng.range(1, 48);
                        if pool.can_admit(committed) && rng.chance(0.5) {
                            pool.admit(id, committed).unwrap();
                        } else {
                            live.swap_remove(idx);
                        }
                    }
                    _ => {}
                }
                assert!(
                    pool.blocks_in_use() <= pool.total_blocks(),
                    "case {case}: pool over budget"
                );
                assert!(pool.utilization() <= 1.0 + 1e-12);
                pool.check_invariants()
                    .unwrap_or_else(|e| panic!("case {case}: {e}"));
            }
            assert_eq!(pool.total_evicted, evictions, "case {case}: eviction count drift");
        }
    }

    #[test]
    fn evict_frees_blocks_and_counts_victims() {
        let mut pool = KvBlockPool::new(8, 16);
        pool.admit(1, 30).unwrap(); // 2 blocks
        pool.admit(2, 17).unwrap(); // 2 blocks
        assert_eq!(pool.blocks_in_use(), 4);
        let freed = pool.evict(1).unwrap();
        assert_eq!(freed, 2);
        assert_eq!(pool.blocks_in_use(), 2);
        assert_eq!(pool.total_evicted, 1);
        assert_eq!(pool.total_evicted_blocks, 2);
        assert_eq!(pool.preemptions(1), 1);
        assert_eq!(pool.preemptions(2), 0);
        assert_eq!(pool.preempted_requests(), 1);
        // An evicted request is gone from the live set…
        assert!(pool.evict(1).is_err());
        assert!(!pool.can_reserve(1, 1));
        // …but can be re-admitted with its committed span, and its
        // preemption count survives the cycle.
        pool.admit(1, 31).unwrap();
        assert_eq!(pool.preemptions(1), 1);
        pool.evict(1).unwrap();
        assert_eq!(pool.preemptions(1), 2);
        pool.check_invariants().unwrap();
    }

    #[test]
    fn evict_releases_lookahead_backed_blocks_too() {
        let mut pool = KvBlockPool::new(8, 16);
        pool.admit(1, 10).unwrap(); // 1 block
        pool.reserve(1, 8).unwrap(); // 10+8 crosses into block 2
        assert_eq!(pool.blocks_in_use(), 2);
        let freed = pool.evict(1).unwrap();
        assert_eq!(freed, 2, "speculative blocks must return with the victim");
        assert_eq!(pool.blocks_in_use(), 0);
        // The outstanding reservation died with the victim: the ledger
        // rolls it back, keeping reserved − rolled_back == committed mass.
        assert_eq!(pool.total_reserved, 8);
        assert_eq!(pool.total_rolled_back, 8);
    }

    #[test]
    fn sharing_admit_maps_prefix_and_charges_only_the_remainder() {
        let mut pool = KvBlockPool::new(8, 16);
        pool.enable_sharing();
        assert!(pool.sharing());
        pool.admit(1, 40).unwrap(); // 3 blocks, all fresh
        assert_eq!(pool.blocks_in_use(), 3);
        let mapped = pool.mapped_blocks(1);
        assert_eq!(mapped.len(), 3);
        // A second request shares the first two blocks: one fresh block.
        pool.admit_shared(2, 40, &mapped[..2]).unwrap();
        assert_eq!(pool.blocks_in_use(), 4);
        assert_eq!(pool.shared_blocks(), 2);
        assert_eq!(pool.shared_blocks_peak, 2);
        // Exclusive views: each request exclusively holds only its tail.
        assert_eq!(pool.blocks_of(1), 3);
        assert_eq!(pool.exclusive_blocks_of(1), 1);
        assert_eq!(pool.exclusive_blocks_of(2), 1);
        pool.check_invariants().unwrap();
        // Evicting request 2 frees only its exclusive block.
        let freed = pool.evict(2).unwrap();
        assert_eq!(freed, 1);
        assert_eq!(pool.total_evicted_blocks, 1);
        assert_eq!(pool.blocks_in_use(), 3);
        assert_eq!(pool.exclusive_blocks_of(1), 3);
        pool.check_invariants().unwrap();
    }

    #[test]
    fn sharing_fork_on_write_allocates_private_growth() {
        let mut pool = KvBlockPool::new(8, 16);
        pool.enable_sharing();
        pool.admit(1, 32).unwrap(); // 2 full blocks
        let mapped = pool.mapped_blocks(1);
        pool.admit_shared(2, 32, &mapped).unwrap(); // full attach, 0 fresh
        assert_eq!(pool.blocks_in_use(), 2);
        // Request 2 decodes past the shared prefix: growth is private.
        pool.reserve(2, 4).unwrap();
        assert_eq!(pool.blocks_in_use(), 3);
        let forked = pool.mapped_blocks(2);
        assert_eq!(forked[..2], mapped[..]);
        assert_eq!(pool.refcount(forked[2]), 1, "fork block is private");
        pool.commit(2, 1).unwrap(); // 33 committed: keeps the fork block
        assert_eq!(pool.blocks_in_use(), 3);
        // Rolling back a speculative-only block returns it to the budget.
        pool.reserve(2, 16).unwrap(); // 33+16 → 4 blocks
        assert_eq!(pool.blocks_in_use(), 4);
        pool.commit(2, 0).unwrap();
        assert_eq!(pool.blocks_in_use(), 3);
        assert_eq!(pool.mapped_blocks(2).len(), 3);
        // Request 1's view never changed under request 2's writes.
        assert_eq!(pool.mapped_blocks(1), mapped);
        pool.check_invariants().unwrap();
    }

    #[test]
    fn sharing_external_pins_keep_blocks_resident() {
        let mut pool = KvBlockPool::new(4, 16);
        pool.enable_sharing();
        pool.admit(1, 20).unwrap(); // 2 blocks
        let mapped = pool.mapped_blocks(1);
        pool.retain_block(mapped[0]).unwrap();
        pool.release(1);
        // The pinned block survives the release; the other came back.
        assert_eq!(pool.blocks_in_use(), 1);
        assert_eq!(pool.refcount(mapped[0]), 1);
        assert_eq!(pool.refcount(mapped[1]), 0);
        pool.check_invariants().unwrap();
        // Re-attach to the surviving block, then drop the pin.
        pool.admit_shared(2, 16, &mapped[..1]).unwrap();
        assert!(!pool.release_block(mapped[0]).unwrap(), "request 2 still maps it");
        pool.release(2);
        assert_eq!(pool.blocks_in_use(), 0);
        assert!(pool.retain_block(mapped[0]).is_err(), "freed blocks cannot be pinned");
        pool.check_invariants().unwrap();
    }

    #[test]
    fn sharing_invariant_tamper_trips_conservation() {
        let mut pool = KvBlockPool::new(4, 16);
        pool.enable_sharing();
        assert!(!pool.debug_inflate_refcount(), "no live block yet");
        pool.admit(1, 16).unwrap();
        pool.check_invariants().unwrap();
        assert!(pool.debug_inflate_refcount());
        let err = pool.check_invariants().unwrap_err().to_string();
        assert!(err.contains("refcount conservation"), "{err}");
    }

    #[test]
    fn prop_reserved_minus_rolled_back_equals_committed() {
        let mut rng = Rng::new(0x6B77);
        for _ in 0..100 {
            let mut kv = KvBlockManager::new(384, 16);
            loop {
                let t = rng.range(1, 8);
                if !kv.can_reserve(t) {
                    break;
                }
                kv.reserve(t).unwrap();
                kv.commit(rng.range(1, t)).unwrap();
            }
            assert_eq!(
                kv.total_reserved - kv.total_rolled_back,
                kv.committed() as u64
            );
        }
    }
}
