//! Block-based KV-cache manager with speculative lookahead slots.
//!
//! Mirrors vLLM's paged KV management at the granularity this stack needs
//! (paper Fig. 14: the lookahead scheduler "reserves speculative generated
//! token KV-states"). The device tensor is the fixed window `[0, max_seq)`
//! owned by `runtime::RequestState`; this module tracks which positions are
//! *committed* vs *speculative*, maps them onto fixed-size blocks, and
//! accounts allocation/rollback so the engine can enforce capacity and
//! report cache pressure.
//!
//! The shared [`KvBlockPool`] additionally supports **eviction**: a victim
//! request's blocks can be released mid-decode ([`KvBlockPool::evict`]) so
//! another request can keep decoding under an oversubscribed pool; the pool
//! keeps the victim accounting (`total_evicted`, per-request preemption
//! counts) that the engine's preemption cap and telemetry read. The evicted
//! request itself is parked by the engine and later re-admitted with a
//! recomputed (re-prefilled) KV span — see `coordinator::batch` and
//! rust/docs/preemption.md.

use anyhow::{bail, Result};

/// Allocation state of one request's KV window.
#[derive(Debug, Clone)]
struct KvAllocation {
    /// Committed tokens (== `RequestState::cache_len`).
    committed: usize,
    /// Speculative positions currently reserved beyond `committed`.
    lookahead: usize,
    /// Blocks currently allocated.
    blocks: usize,
}

/// Block-based manager for a fixed `max_seq` window.
#[derive(Debug, Clone)]
pub struct KvBlockManager {
    pub block_size: usize,
    pub max_seq: usize,
    alloc: KvAllocation,
    /// Stats for telemetry / tests.
    pub peak_blocks: usize,
    pub total_reserved: u64,
    pub total_rolled_back: u64,
}

impl KvBlockManager {
    pub fn new(max_seq: usize, block_size: usize) -> Self {
        assert!(block_size > 0 && max_seq % block_size == 0);
        Self {
            block_size,
            max_seq,
            alloc: KvAllocation { committed: 0, lookahead: 0, blocks: 0 },
            peak_blocks: 0,
            total_reserved: 0,
            total_rolled_back: 0,
        }
    }

    fn blocks_for(&self, tokens: usize) -> usize {
        tokens.div_ceil(self.block_size)
    }

    pub fn committed(&self) -> usize {
        self.alloc.committed
    }

    pub fn blocks_in_use(&self) -> usize {
        self.alloc.blocks
    }

    /// Total capacity in blocks.
    pub fn total_blocks(&self) -> usize {
        self.max_seq / self.block_size
    }

    /// Can a step of `t` tokens (1 original + lookahead) be admitted?
    pub fn can_reserve(&self, t: usize) -> bool {
        self.alloc.committed + t <= self.max_seq
    }

    /// Reserve slots for a step of `t` in-flight tokens (vLLM lookahead).
    /// Allocates any new blocks the speculative span touches.
    pub fn reserve(&mut self, t: usize) -> Result<()> {
        if !self.can_reserve(t) {
            bail!(
                "KV overflow: committed {} + in-flight {t} > max_seq {}",
                self.alloc.committed,
                self.max_seq
            );
        }
        self.alloc.lookahead = t;
        let needed = self.blocks_for(self.alloc.committed + t);
        if needed > self.alloc.blocks {
            self.alloc.blocks = needed;
        }
        self.peak_blocks = self.peak_blocks.max(self.alloc.blocks);
        self.total_reserved += t as u64;
        Ok(())
    }

    /// Commit `advance` of the reserved in-flight tokens; the rest of the
    /// lookahead is rolled back (rejected speculative tokens). Blocks that
    /// only held rejected tokens are freed for reuse — their device slots
    /// get overwritten by the next step at the same positions.
    pub fn commit(&mut self, advance: usize) -> Result<()> {
        if advance > self.alloc.lookahead {
            bail!("commit {advance} exceeds reserved lookahead {}", self.alloc.lookahead);
        }
        self.total_rolled_back += (self.alloc.lookahead - advance) as u64;
        self.alloc.committed += advance;
        self.alloc.lookahead = 0;
        self.alloc.blocks = self.blocks_for(self.alloc.committed);
        Ok(())
    }

    /// Release everything (request finished).
    pub fn release(&mut self) {
        self.alloc = KvAllocation { committed: 0, lookahead: 0, blocks: 0 };
    }

    /// Fraction of the window in use: committed tokens *plus* the reserved
    /// speculative lookahead. Mid-speculation the lookahead rows are real
    /// cache pressure (they occupy device slots until rolled back), which
    /// is exactly when admission control needs an honest number.
    pub fn utilization(&self) -> f64 {
        (self.alloc.committed + self.alloc.lookahead) as f64 / self.max_seq as f64
    }

    /// Speculative positions currently reserved beyond the committed span.
    pub fn lookahead(&self) -> usize {
        self.alloc.lookahead
    }

    /// Invariant check used by tests: the span fits the window, blocks cover
    /// exactly the committed span after commit, and never exceed capacity.
    pub fn check_invariants(&self) -> Result<()> {
        if self.alloc.committed + self.alloc.lookahead > self.max_seq {
            bail!("span exceeds window");
        }
        if self.alloc.blocks > self.total_blocks() {
            bail!("blocks exceed capacity");
        }
        if self.alloc.blocks < self.blocks_for(self.alloc.committed) {
            bail!("committed tokens not covered by blocks");
        }
        Ok(())
    }
}

/// Per-request accounting inside the shared pool.
#[derive(Debug, Clone)]
struct PoolAlloc {
    committed: usize,
    lookahead: usize,
    blocks: usize,
}

/// Multi-request block pool for continuous batching.
///
/// All in-flight requests draw KV blocks from one fixed budget of
/// `total_blocks` — the admission-control surface of `BatchEngine`.
/// Per-request accounting mirrors [`KvBlockManager`] (committed span +
/// speculative lookahead; rollback frees speculative-only blocks), but
/// block allocation is charged against the shared budget, so one request's
/// speculation can crowd out another's admission — the batching-era cache
/// pressure the paper's single-batch setting never sees.
#[derive(Debug, Clone)]
pub struct KvBlockPool {
    pub block_size: usize,
    total_blocks: usize,
    allocs: std::collections::BTreeMap<u64, PoolAlloc>,
    /// Stats for telemetry / tests.
    pub peak_blocks: usize,
    pub total_reserved: u64,
    pub total_rolled_back: u64,
    /// Eviction events across the run (victim accounting).
    pub total_evicted: u64,
    /// Blocks released by evictions across the run.
    pub total_evicted_blocks: u64,
    /// Per-request preemption counts. Survives release/re-admission cycles
    /// (unlike `allocs`), so the engine's `max_preemptions_per_req` cap has
    /// a durable source of truth.
    preemptions: std::collections::BTreeMap<u64, u32>,
}

impl KvBlockPool {
    pub fn new(total_blocks: usize, block_size: usize) -> Self {
        assert!(block_size > 0 && total_blocks > 0);
        Self {
            block_size,
            total_blocks,
            allocs: std::collections::BTreeMap::new(),
            peak_blocks: 0,
            total_reserved: 0,
            total_rolled_back: 0,
            total_evicted: 0,
            total_evicted_blocks: 0,
            preemptions: std::collections::BTreeMap::new(),
        }
    }

    fn blocks_for(&self, tokens: usize) -> usize {
        tokens.div_ceil(self.block_size)
    }

    pub fn total_blocks(&self) -> usize {
        self.total_blocks
    }

    pub fn blocks_in_use(&self) -> usize {
        self.allocs.values().map(|a| a.blocks).sum()
    }

    pub fn free_blocks(&self) -> usize {
        self.total_blocks - self.blocks_in_use()
    }

    pub fn active_requests(&self) -> usize {
        self.allocs.len()
    }

    /// Committed tokens of one request (0 if unknown).
    pub fn committed(&self, id: u64) -> usize {
        self.allocs.get(&id).map_or(0, |a| a.committed)
    }

    /// Blocks currently held by one request (0 if unknown) — what an
    /// eviction of it would free.
    pub fn blocks_of(&self, id: u64) -> usize {
        self.allocs.get(&id).map_or(0, |a| a.blocks)
    }

    /// Can a request with `prompt_tokens` committed tokens be admitted now?
    pub fn can_admit(&self, prompt_tokens: usize) -> bool {
        self.blocks_for(prompt_tokens.max(1)) <= self.free_blocks()
    }

    /// Admit a request, allocating blocks for its (already prefilled)
    /// prompt span.
    pub fn admit(&mut self, id: u64, prompt_tokens: usize) -> Result<()> {
        if self.allocs.contains_key(&id) {
            bail!("request {id} already admitted");
        }
        let blocks = self.blocks_for(prompt_tokens.max(1));
        if blocks > self.free_blocks() {
            bail!(
                "pool exhausted: request {id} needs {blocks} blocks, {} free of {}",
                self.free_blocks(),
                self.total_blocks
            );
        }
        self.allocs.insert(id, PoolAlloc { committed: prompt_tokens, lookahead: 0, blocks });
        self.peak_blocks = self.peak_blocks.max(self.blocks_in_use());
        Ok(())
    }

    /// Can request `id` reserve a step of `t` in-flight tokens?
    pub fn can_reserve(&self, id: u64, t: usize) -> bool {
        match self.allocs.get(&id) {
            None => false,
            Some(a) => {
                let needed = self.blocks_for(a.committed + t);
                needed.saturating_sub(a.blocks) <= self.free_blocks()
            }
        }
    }

    /// Blocks still missing before `can_reserve(id, t)` would hold: the
    /// eviction feasibility pre-check's demand signal. 0 means the
    /// reservation fits as-is; an unknown request reports `usize::MAX`
    /// because no amount of eviction admits a request that is not in the
    /// pool.
    pub fn reserve_shortfall(&self, id: u64, t: usize) -> usize {
        match self.allocs.get(&id) {
            None => usize::MAX,
            Some(a) => self
                .blocks_for(a.committed + t)
                .saturating_sub(a.blocks)
                .saturating_sub(self.free_blocks()),
        }
    }

    /// Reserve lookahead slots for one request's verify step.
    pub fn reserve(&mut self, id: u64, t: usize) -> Result<()> {
        if !self.can_reserve(id, t) {
            bail!(
                "pool reserve failed: request {id}, t={t}, {} blocks free",
                self.free_blocks()
            );
        }
        let needed = {
            let a = self.allocs.get(&id).expect("checked by can_reserve");
            self.blocks_for(a.committed + t).max(a.blocks)
        };
        let a = self.allocs.get_mut(&id).expect("checked by can_reserve");
        a.lookahead = t;
        a.blocks = needed;
        self.total_reserved += t as u64;
        self.peak_blocks = self.peak_blocks.max(self.blocks_in_use());
        Ok(())
    }

    /// Commit `advance` of the reserved tokens; roll the rest back and
    /// return speculative-only blocks to the shared budget.
    pub fn commit(&mut self, id: u64, advance: usize) -> Result<()> {
        let block_size = self.block_size;
        let a = self
            .allocs
            .get_mut(&id)
            .ok_or_else(|| anyhow::anyhow!("commit for unknown request {id}"))?;
        if advance > a.lookahead {
            bail!("commit {advance} exceeds reserved lookahead {}", a.lookahead);
        }
        self.total_rolled_back += (a.lookahead - advance) as u64;
        a.committed += advance;
        a.lookahead = 0;
        a.blocks = a.committed.max(1).div_ceil(block_size);
        Ok(())
    }

    /// Release a finished request's blocks.
    pub fn release(&mut self, id: u64) {
        self.allocs.remove(&id);
    }

    /// Evict a live request: release its blocks back to the shared budget
    /// and record the preemption. Returns the number of blocks freed. The
    /// caller owns the rest of the preemption protocol (parking the request,
    /// invalidating its lookahead, re-prefilling on re-admission).
    pub fn evict(&mut self, id: u64) -> Result<usize> {
        let a = self
            .allocs
            .remove(&id)
            .ok_or_else(|| anyhow::anyhow!("evict for unknown request {id}"))?;
        // Any outstanding speculative reservation dies with the victim:
        // credit the rollback ledger so `total_reserved − total_rolled_back`
        // keeps meaning "tokens that ended up committed".
        self.total_rolled_back += a.lookahead as u64;
        self.total_evicted += 1;
        self.total_evicted_blocks += a.blocks as u64;
        *self.preemptions.entry(id).or_insert(0) += 1;
        Ok(a.blocks)
    }

    /// How many times request `id` has been evicted so far (0 if never).
    pub fn preemptions(&self, id: u64) -> u32 {
        self.preemptions.get(&id).copied().unwrap_or(0)
    }

    /// Requests that were preempted at least once over the run.
    pub fn preempted_requests(&self) -> usize {
        self.preemptions.len()
    }

    /// Retarget the pool's capacity mid-run (fault injection's pool-shrink
    /// pressure spike, rust/docs/faults.md). Committed state is never
    /// revoked: the capacity is clamped to at least the blocks currently
    /// in use (and at least 1), so `free_blocks` cannot underflow and
    /// `check_invariants` keeps holding — a shrink below the working set
    /// takes effect progressively as requests finish or are evicted.
    /// Returns the capacity actually applied.
    pub fn set_capacity(&mut self, blocks: usize) -> usize {
        self.total_blocks = blocks.max(self.blocks_in_use()).max(1);
        self.total_blocks
    }

    /// Fraction of pool capacity in use (committed + lookahead tokens).
    pub fn utilization(&self) -> f64 {
        let used: usize = self.allocs.values().map(|a| a.committed + a.lookahead).sum();
        used as f64 / (self.total_blocks * self.block_size) as f64
    }

    /// Invariants the property tests drive: the shared budget is never
    /// exceeded, and every request's span is covered by its blocks.
    pub fn check_invariants(&self) -> Result<()> {
        if self.blocks_in_use() > self.total_blocks {
            bail!(
                "pool over budget: {} blocks in use of {}",
                self.blocks_in_use(),
                self.total_blocks
            );
        }
        for (id, a) in &self.allocs {
            if a.blocks < self.blocks_for(a.committed + a.lookahead) {
                bail!("request {id}: span not covered by blocks");
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn reserve_commit_cycle() {
        let mut kv = KvBlockManager::new(64, 16);
        kv.reserve(4).unwrap(); // 1 token + 3 drafts
        assert_eq!(kv.blocks_in_use(), 1);
        kv.commit(2).unwrap(); // 1 accepted draft + 1 corrected token
        assert_eq!(kv.committed(), 2);
        assert_eq!(kv.total_rolled_back, 2);
        kv.check_invariants().unwrap();
    }

    #[test]
    fn blocks_grow_with_span() {
        let mut kv = KvBlockManager::new(64, 16);
        for _ in 0..20 {
            kv.reserve(1).unwrap();
            kv.commit(1).unwrap();
        }
        assert_eq!(kv.committed(), 20);
        assert_eq!(kv.blocks_in_use(), 2); // ceil(20/16)
    }

    #[test]
    fn overflow_rejected() {
        let mut kv = KvBlockManager::new(32, 16);
        for _ in 0..32 {
            kv.reserve(1).unwrap();
            kv.commit(1).unwrap();
        }
        assert!(kv.reserve(1).is_err());
        assert!(!kv.can_reserve(1));
    }

    #[test]
    fn commit_more_than_reserved_rejected() {
        let mut kv = KvBlockManager::new(64, 16);
        kv.reserve(3).unwrap();
        assert!(kv.commit(4).is_err());
    }

    #[test]
    fn rollback_frees_speculative_blocks() {
        let mut kv = KvBlockManager::new(64, 16);
        // Commit 15 tokens, then reserve 8 speculative (crosses a block).
        for _ in 0..15 {
            kv.reserve(1).unwrap();
            kv.commit(1).unwrap();
        }
        kv.reserve(8).unwrap();
        assert_eq!(kv.blocks_in_use(), 2);
        kv.commit(1).unwrap(); // reject all drafts
        assert_eq!(kv.committed(), 16);
        assert_eq!(kv.blocks_in_use(), 1); // speculative-only block freed
        kv.check_invariants().unwrap();
    }

    #[test]
    fn release_resets() {
        let mut kv = KvBlockManager::new(64, 16);
        kv.reserve(4).unwrap();
        kv.commit(4).unwrap();
        kv.release();
        assert_eq!(kv.committed(), 0);
        assert_eq!(kv.blocks_in_use(), 0);
    }

    /// Property test (in-tree harness): random reserve/commit traces keep
    /// invariants and conserve token accounting; utilization reflects the
    /// full (committed + lookahead) span at every point.
    #[test]
    fn prop_random_traces_keep_invariants() {
        let mut rng = Rng::new(0x6B76);
        for case in 0..200 {
            let mut kv = KvBlockManager::new(384, 16);
            let mut committed = 0usize;
            for _ in 0..rng.range(1, 120) {
                let t = rng.range(1, 8);
                if !kv.can_reserve(t) {
                    break;
                }
                kv.reserve(t).unwrap();
                // Mid-speculation: utilization must count the reserved
                // lookahead, not just the committed span.
                let expect = (committed + t) as f64 / 384.0;
                assert!(
                    (kv.utilization() - expect).abs() < 1e-12,
                    "case {case}: utilization {} != {expect}",
                    kv.utilization()
                );
                let adv = rng.range(1, t);
                kv.commit(adv).unwrap();
                committed += adv;
                kv.check_invariants().unwrap_or_else(|e| panic!("case {case}: {e}"));
                assert_eq!(kv.committed(), committed);
                assert!((kv.utilization() - committed as f64 / 384.0).abs() < 1e-12);
                assert!(kv.utilization() <= 1.0);
            }
        }
    }

    #[test]
    fn utilization_counts_lookahead() {
        let mut kv = KvBlockManager::new(64, 16);
        kv.reserve(8).unwrap();
        kv.commit(8).unwrap();
        assert!((kv.utilization() - 8.0 / 64.0).abs() < 1e-12);
        kv.reserve(6).unwrap();
        assert_eq!(kv.lookahead(), 6);
        assert!((kv.utilization() - 14.0 / 64.0).abs() < 1e-12);
        kv.commit(1).unwrap();
        assert!((kv.utilization() - 9.0 / 64.0).abs() < 1e-12);
    }

    #[test]
    fn pool_admit_reserve_commit_release() {
        let mut pool = KvBlockPool::new(8, 16); // 128 token-slots shared
        pool.admit(1, 30).unwrap(); // 2 blocks
        pool.admit(2, 17).unwrap(); // 2 blocks
        assert_eq!(pool.blocks_in_use(), 4);
        assert_eq!(pool.active_requests(), 2);
        pool.reserve(1, 4).unwrap(); // 30+4 -> 3 blocks
        assert_eq!(pool.blocks_in_use(), 5);
        pool.commit(1, 1).unwrap(); // 31 -> back to 2 blocks
        assert_eq!(pool.blocks_in_use(), 4);
        assert_eq!(pool.committed(1), 31);
        pool.release(1);
        assert_eq!(pool.blocks_in_use(), 2);
        pool.check_invariants().unwrap();
    }

    #[test]
    fn pool_admission_bounded_by_budget() {
        let mut pool = KvBlockPool::new(4, 16);
        pool.admit(1, 33).unwrap(); // 3 blocks
        assert!(!pool.can_admit(17)); // would need 2 more
        assert!(pool.can_admit(16));
        assert!(pool.admit(2, 40).is_err());
        pool.admit(2, 10).unwrap();
        assert_eq!(pool.free_blocks(), 0);
        // No room left for lookahead growth past the current block.
        assert!(!pool.can_reserve(1, 16));
        assert!(pool.reserve(1, 16).is_err());
    }

    #[test]
    fn reserve_shortfall_measures_missing_blocks() {
        let mut pool = KvBlockPool::new(4, 16);
        pool.admit(1, 33).unwrap(); // 3 blocks
        pool.admit(2, 16).unwrap(); // 1 block, pool full
        // Request 2's next token spills into a new block: 1 short.
        assert_eq!(pool.reserve_shortfall(2, 1), 1);
        // A 17-token span needs two new blocks.
        assert_eq!(pool.reserve_shortfall(2, 17), 2);
        // An unknown request can never be satisfied by eviction.
        assert_eq!(pool.reserve_shortfall(99, 1), usize::MAX);
        pool.release(1);
        assert_eq!(pool.reserve_shortfall(2, 1), 0);
        assert!(pool.can_reserve(2, 1));
    }

    #[test]
    fn set_capacity_shrinks_without_revoking_committed_state() {
        let mut pool = KvBlockPool::new(8, 16);
        pool.admit(1, 33).unwrap(); // 3 blocks
        pool.admit(2, 17).unwrap(); // 2 blocks
        assert_eq!(pool.blocks_in_use(), 5);
        // Shrink below the working set: clamps to blocks_in_use, so
        // free_blocks cannot underflow and invariants keep holding.
        assert_eq!(pool.set_capacity(2), 5);
        assert_eq!(pool.total_blocks(), 5);
        assert_eq!(pool.free_blocks(), 0);
        pool.check_invariants().unwrap();
        assert!(!pool.can_admit(1));
        // The shrink tightens as requests drain…
        pool.release(1);
        assert_eq!(pool.set_capacity(2), 2);
        assert_eq!(pool.free_blocks(), 0);
        pool.check_invariants().unwrap();
        // …and growing back restores admission headroom.
        assert_eq!(pool.set_capacity(8), 8);
        assert!(pool.can_admit(16));
        assert_eq!(pool.free_blocks(), 6);
        // Capacity never drops to zero even on an empty pool.
        pool.release(2);
        assert_eq!(pool.set_capacity(0), 1);
        pool.check_invariants().unwrap();
    }

    #[test]
    fn pool_rejects_double_admit_and_unknown_ids() {
        let mut pool = KvBlockPool::new(8, 16);
        pool.admit(7, 5).unwrap();
        assert!(pool.admit(7, 5).is_err());
        assert!(pool.reserve(9, 1).is_err());
        assert!(pool.commit(9, 0).is_err());
    }

    /// Shared-pool property: random admit/reserve/commit/release/evict
    /// traces never exceed `total_blocks`, keep every request's span
    /// covered, and keep the victim accounting consistent.
    #[test]
    fn prop_pool_never_exceeds_budget() {
        let mut rng = Rng::new(0x100F);
        for case in 0..150 {
            let total_blocks = rng.range(4, 24);
            let mut pool = KvBlockPool::new(total_blocks, 16);
            let mut live: Vec<u64> = Vec::new();
            let mut next_id = 0u64;
            let mut evictions = 0u64;
            for _ in 0..rng.range(10, 200) {
                match rng.below(5) {
                    0 => {
                        let prompt = rng.range(1, 64);
                        if pool.can_admit(prompt) {
                            pool.admit(next_id, prompt).unwrap();
                            live.push(next_id);
                            next_id += 1;
                        }
                    }
                    1 | 2 if !live.is_empty() => {
                        let id = live[rng.below(live.len())];
                        let t = rng.range(1, 8);
                        // Shortfall and can_reserve must agree: 0 missing
                        // blocks iff the reservation fits right now.
                        assert_eq!(
                            pool.reserve_shortfall(id, t) == 0,
                            pool.can_reserve(id, t),
                            "case {case}: shortfall / can_reserve disagree"
                        );
                        if pool.can_reserve(id, t) {
                            pool.reserve(id, t).unwrap();
                            pool.commit(id, rng.range(0, t)).unwrap();
                        }
                    }
                    3 if !live.is_empty() => {
                        let idx = rng.below(live.len());
                        pool.release(live.swap_remove(idx));
                    }
                    4 if !live.is_empty() => {
                        // Evict a live request, then sometimes re-admit it
                        // immediately (the park/readmit cycle's pool view).
                        let idx = rng.below(live.len());
                        let id = live[idx];
                        let before = pool.preemptions(id);
                        let free_before = pool.free_blocks();
                        let freed = pool.evict(id).unwrap();
                        evictions += 1;
                        assert_eq!(pool.preemptions(id), before + 1);
                        assert_eq!(pool.free_blocks(), free_before + freed);
                        let committed = rng.range(1, 48);
                        if pool.can_admit(committed) && rng.chance(0.5) {
                            pool.admit(id, committed).unwrap();
                        } else {
                            live.swap_remove(idx);
                        }
                    }
                    _ => {}
                }
                assert!(
                    pool.blocks_in_use() <= pool.total_blocks(),
                    "case {case}: pool over budget"
                );
                assert!(pool.utilization() <= 1.0 + 1e-12);
                pool.check_invariants()
                    .unwrap_or_else(|e| panic!("case {case}: {e}"));
            }
            assert_eq!(pool.total_evicted, evictions, "case {case}: eviction count drift");
        }
    }

    #[test]
    fn evict_frees_blocks_and_counts_victims() {
        let mut pool = KvBlockPool::new(8, 16);
        pool.admit(1, 30).unwrap(); // 2 blocks
        pool.admit(2, 17).unwrap(); // 2 blocks
        assert_eq!(pool.blocks_in_use(), 4);
        let freed = pool.evict(1).unwrap();
        assert_eq!(freed, 2);
        assert_eq!(pool.blocks_in_use(), 2);
        assert_eq!(pool.total_evicted, 1);
        assert_eq!(pool.total_evicted_blocks, 2);
        assert_eq!(pool.preemptions(1), 1);
        assert_eq!(pool.preemptions(2), 0);
        assert_eq!(pool.preempted_requests(), 1);
        // An evicted request is gone from the live set…
        assert!(pool.evict(1).is_err());
        assert!(!pool.can_reserve(1, 1));
        // …but can be re-admitted with its committed span, and its
        // preemption count survives the cycle.
        pool.admit(1, 31).unwrap();
        assert_eq!(pool.preemptions(1), 1);
        pool.evict(1).unwrap();
        assert_eq!(pool.preemptions(1), 2);
        pool.check_invariants().unwrap();
    }

    #[test]
    fn evict_releases_lookahead_backed_blocks_too() {
        let mut pool = KvBlockPool::new(8, 16);
        pool.admit(1, 10).unwrap(); // 1 block
        pool.reserve(1, 8).unwrap(); // 10+8 crosses into block 2
        assert_eq!(pool.blocks_in_use(), 2);
        let freed = pool.evict(1).unwrap();
        assert_eq!(freed, 2, "speculative blocks must return with the victim");
        assert_eq!(pool.blocks_in_use(), 0);
        // The outstanding reservation died with the victim: the ledger
        // rolls it back, keeping reserved − rolled_back == committed mass.
        assert_eq!(pool.total_reserved, 8);
        assert_eq!(pool.total_rolled_back, 8);
    }

    #[test]
    fn prop_reserved_minus_rolled_back_equals_committed() {
        let mut rng = Rng::new(0x6B77);
        for _ in 0..100 {
            let mut kv = KvBlockManager::new(384, 16);
            loop {
                let t = rng.range(1, 8);
                if !kv.can_reserve(t) {
                    break;
                }
                kv.reserve(t).unwrap();
                kv.commit(rng.range(1, t)).unwrap();
            }
            assert_eq!(
                kv.total_reserved - kv.total_rolled_back,
                kv.committed() as u64
            );
        }
    }
}
