//! Block-granular prefix trie over committed token ids — the lookup
//! structure of the copy-on-write prefix cache (rust/docs/prefix_cache.md).
//!
//! Each node covers exactly one KV block (`block_size` token ids on its
//! edge) and pins one physical block of the sharing-mode [`KvBlockPool`]
//! via [`KvBlockPool::retain_block`], so cached prefixes stay resident
//! across request lifetimes: a request can release or be evicted and a
//! later identical prefix still re-attaches to the same blocks. Only
//! *full* blocks are ever inserted — a partial tail block will have decode
//! tokens appended in place, so it is never shareable.
//!
//! Children are keyed by the block's token ids in a `BTreeMap`, keeping
//! every walk deterministic (the repo-wide no-unordered-maps rule on the
//! serving path). Reclaim frees least-recently-used leaves whose block the
//! trie alone holds (refcount 1): dropping a pinned-elsewhere leaf would
//! free no memory, and dropping an interior node would orphan the cached
//! suffixes below it, so pruning cascades bottom-up instead.

use std::collections::BTreeMap;

use anyhow::Result;

use super::KvBlockPool;

#[derive(Debug, Clone)]
struct Node {
    /// Physical pool block holding this edge's token span.
    block: u64,
    /// Logical LRU stamp: the trie clock at the last lookup/insert that
    /// touched this node.
    stamp: u64,
    children: BTreeMap<Vec<u32>, Node>,
}

/// Prefix cache index over a sharing-mode [`KvBlockPool`].
#[derive(Debug, Clone)]
pub struct PrefixTrie {
    block_size: usize,
    children: BTreeMap<Vec<u32>, Node>,
    /// Logical clock for LRU stamps (bumped per lookup/insert — no host
    /// time on the serving path).
    clock: u64,
    /// Cumulative blocks reclaimed from the cache (telemetry).
    pub reclaimed_blocks: u64,
}

impl PrefixTrie {
    pub fn new(block_size: usize) -> Self {
        assert!(block_size > 0);
        Self { block_size, children: BTreeMap::new(), clock: 0, reclaimed_blocks: 0 }
    }

    /// Nodes (= pinned blocks) currently in the cache.
    pub fn len(&self) -> usize {
        fn count(children: &BTreeMap<Vec<u32>, Node>) -> usize {
            children.values().map(|n| 1 + count(&n.children)).sum()
        }
        count(&self.children)
    }

    pub fn is_empty(&self) -> bool {
        self.children.is_empty()
    }

    /// Read-only prefix match (admission feasibility): physical block ids
    /// covering the longest resident full-block prefix of `tokens`.
    pub fn peek(&self, tokens: &[u32]) -> Vec<u64> {
        let mut out = Vec::new();
        let mut cur = &self.children;
        for chunk in tokens.chunks_exact(self.block_size) {
            match cur.get(chunk) {
                Some(node) => {
                    out.push(node.block);
                    cur = &node.children;
                }
                None => break,
            }
        }
        out
    }

    /// Prefix match that also refreshes the LRU stamps along the matched
    /// path (the admission-time hit).
    pub fn lookup(&mut self, tokens: &[u32]) -> Vec<u64> {
        self.clock += 1;
        let clock = self.clock;
        let mut out = Vec::new();
        let mut cur = &mut self.children;
        for chunk in tokens.chunks_exact(self.block_size) {
            match cur.get_mut(chunk) {
                Some(node) => {
                    node.stamp = clock;
                    out.push(node.block);
                    cur = &mut node.children;
                }
                None => break,
            }
        }
        out
    }

    /// Record the full blocks of `tokens` in the trie: `mapped[i]` is the
    /// physical block the inserting request maps at block position `i`
    /// ([`KvBlockPool::mapped_blocks`]). Nodes already present are
    /// stamp-refreshed and keep their block id (the caller mapped exactly
    /// those ids for its matched prefix); each genuinely new node pins its
    /// block via [`KvBlockPool::retain_block`].
    pub fn insert(&mut self, tokens: &[u32], mapped: &[u64], pool: &mut KvBlockPool) -> Result<()> {
        self.clock += 1;
        let clock = self.clock;
        let mut cur = &mut self.children;
        for (i, chunk) in tokens.chunks_exact(self.block_size).enumerate() {
            let Some(&block) = mapped.get(i) else { break };
            if !cur.contains_key(chunk) {
                pool.retain_block(block)?;
                cur.insert(
                    chunk.to_vec(),
                    Node { block, stamp: clock, children: BTreeMap::new() },
                );
            }
            let node = cur.get_mut(chunk).expect("inserted above");
            node.stamp = clock;
            cur = &mut node.children;
        }
        Ok(())
    }

    /// Blocks an exhaustive reclaim could return to the free budget right
    /// now: nodes in subtrees held *only* by the trie (every block at
    /// refcount 1), excluding `protect`ed ids (a match about to be
    /// attached must not be counted as freeable and shareable at once).
    /// The engine's admission feasibility adds this to `free_blocks()`.
    pub fn reclaimable(&self, pool: &KvBlockPool, protect: &[u64]) -> usize {
        // Returns (freeable nodes in this forest, whole forest freeable).
        fn walk(
            children: &BTreeMap<Vec<u32>, Node>,
            pool: &KvBlockPool,
            protect: &[u64],
        ) -> (usize, bool) {
            let mut count = 0usize;
            let mut all_free = true;
            for node in children.values() {
                let (sub, sub_all) = walk(&node.children, pool, protect);
                let own =
                    sub_all && pool.refcount(node.block) == 1 && !protect.contains(&node.block);
                count += sub + usize::from(own);
                all_free &= own;
            }
            (count, all_free)
        }
        walk(&self.children, pool, protect).0
    }

    /// Free least-recently-used cache-only leaves (block refcount 1) until
    /// `need` blocks came back or nothing more is freeable. Pruning a leaf
    /// can expose its parent as the next candidate, so eviction cascades
    /// exactly over the [`Self::reclaimable`] set. Returns blocks freed.
    pub fn reclaim(&mut self, pool: &mut KvBlockPool, need: usize, protect: &[u64]) -> Result<usize> {
        let mut freed = 0usize;
        while freed < need {
            let Some(path) = self.oldest_free_leaf(pool, protect) else { break };
            if self.remove_leaf(&path, pool)? {
                freed += 1;
                self.reclaimed_blocks += 1;
            }
        }
        Ok(freed)
    }

    /// Path (edge keys root→leaf) of the oldest-stamped leaf whose block
    /// only the trie holds. Ties break on trie order (deterministic).
    fn oldest_free_leaf(&self, pool: &KvBlockPool, protect: &[u64]) -> Option<Vec<Vec<u32>>> {
        fn walk(
            children: &BTreeMap<Vec<u32>, Node>,
            pool: &KvBlockPool,
            protect: &[u64],
            path: &mut Vec<Vec<u32>>,
            best: &mut Option<(u64, Vec<Vec<u32>>)>,
        ) {
            for (key, node) in children {
                path.push(key.clone());
                if node.children.is_empty() {
                    if pool.refcount(node.block) == 1
                        && !protect.contains(&node.block)
                        && best.as_ref().is_none_or(|(stamp, _)| node.stamp < *stamp)
                    {
                        *best = Some((node.stamp, path.clone()));
                    }
                } else {
                    walk(&node.children, pool, protect, path, best);
                }
                path.pop();
            }
        }
        let mut best = None;
        walk(&self.children, pool, protect, &mut Vec::new(), &mut best);
        best.map(|(_, path)| path)
    }

    /// Remove the leaf at `path` and drop its pool pin; returns whether
    /// the block actually came back to the free budget.
    fn remove_leaf(&mut self, path: &[Vec<u32>], pool: &mut KvBlockPool) -> Result<bool> {
        let (last, parents) = path.split_last().expect("reclaim path is never empty");
        let mut cur = &mut self.children;
        for key in parents {
            cur = &mut cur
                .get_mut(key)
                .ok_or_else(|| anyhow::anyhow!("prefix trie reclaim path vanished"))?
                .children;
        }
        let node = cur
            .remove(last)
            .ok_or_else(|| anyhow::anyhow!("prefix trie reclaim leaf vanished"))?;
        anyhow::ensure!(node.children.is_empty(), "prefix trie reclaim removed a non-leaf");
        pool.release_block(node.block)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn block_tokens(tag: u32, block_size: usize) -> Vec<u32> {
        (0..block_size as u32).map(|i| tag * 1000 + i).collect()
    }

    fn pool() -> KvBlockPool {
        let mut p = KvBlockPool::new(16, 4);
        p.enable_sharing();
        p
    }

    #[test]
    fn lookup_matches_longest_full_block_prefix() {
        let mut pool = pool();
        let mut trie = PrefixTrie::new(4);
        let mut prompt = block_tokens(1, 4);
        prompt.extend(block_tokens(2, 4));
        prompt.extend([7, 8]); // partial tail: never cached
        pool.admit_shared(10, prompt.len(), &[]).unwrap();
        let mapped = pool.mapped_blocks(10);
        trie.insert(&prompt, &mapped, &mut pool).unwrap();
        assert_eq!(trie.len(), 2, "only full blocks are cached");
        pool.check_invariants().unwrap();

        // Identical prefix, divergent second block: one-block match.
        let mut other = block_tokens(1, 4);
        other.extend(block_tokens(9, 4));
        assert_eq!(trie.peek(&other), vec![mapped[0]]);
        // Full match including the partial tail's owner prompt.
        assert_eq!(trie.lookup(&prompt), vec![mapped[0], mapped[1]]);
        // Sub-block prompts can never match.
        assert!(trie.peek(&prompt[..3]).is_empty());
    }

    #[test]
    fn cache_survives_request_release_and_reattaches() {
        let mut pool = pool();
        let mut trie = PrefixTrie::new(4);
        let prompt = block_tokens(3, 4);
        pool.admit_shared(1, prompt.len(), &[]).unwrap();
        let mapped = pool.mapped_blocks(1);
        trie.insert(&prompt, &mapped, &mut pool).unwrap();
        pool.release(1);
        // The trie pin keeps the block resident…
        assert_eq!(pool.blocks_in_use(), 1);
        let shared = trie.lookup(&prompt);
        assert_eq!(shared, mapped);
        // …and a later request re-attaches without any fresh allocation.
        let free = pool.free_blocks();
        pool.admit_shared(2, prompt.len(), &shared).unwrap();
        assert_eq!(pool.free_blocks(), free);
        pool.check_invariants().unwrap();
    }

    #[test]
    fn reclaim_frees_lru_leaves_and_cascades() {
        let mut pool = pool();
        let mut trie = PrefixTrie::new(4);
        // Two chains: A = a0→a1 (older), B = b0 (newer).
        let mut chain_a = block_tokens(1, 4);
        chain_a.extend(block_tokens(2, 4));
        let chain_b = block_tokens(5, 4);
        pool.admit_shared(1, chain_a.len(), &[]).unwrap();
        trie.insert(&chain_a, &pool.mapped_blocks(1), &mut pool).unwrap();
        pool.release(1);
        pool.admit_shared(2, chain_b.len(), &[]).unwrap();
        trie.insert(&chain_b, &pool.mapped_blocks(2), &mut pool).unwrap();
        pool.release(2);
        assert_eq!(trie.len(), 3);
        assert_eq!(trie.reclaimable(&pool, &[]), 3);

        // Need 2: the A chain's leaf goes first (oldest), which exposes its
        // parent — the cascade frees the whole A chain before touching B.
        let freed = trie.reclaim(&mut pool, 2, &[]).unwrap();
        assert_eq!(freed, 2);
        assert_eq!(trie.len(), 1);
        assert!(trie.peek(&chain_a).is_empty());
        assert_eq!(trie.peek(&chain_b).len(), 1);
        assert_eq!(pool.blocks_in_use(), 1);
        pool.check_invariants().unwrap();
    }

    #[test]
    fn reclaim_skips_blocks_other_holders_map() {
        let mut pool = pool();
        let mut trie = PrefixTrie::new(4);
        let prompt = block_tokens(4, 4);
        pool.admit_shared(1, prompt.len(), &[]).unwrap();
        trie.insert(&prompt, &pool.mapped_blocks(1), &mut pool).unwrap();
        // Request 1 still maps the block (refcount 2): nothing to free.
        assert_eq!(trie.reclaimable(&pool, &[]), 0);
        assert_eq!(trie.reclaim(&mut pool, 8, &[]).unwrap(), 0);
        assert_eq!(trie.len(), 1);
        // Protecting a block behaves the same even once it is trie-only.
        pool.release(1);
        let id = trie.peek(&prompt)[0];
        assert_eq!(trie.reclaimable(&pool, &[id]), 0);
        assert_eq!(trie.reclaim(&mut pool, 8, &[id]).unwrap(), 0);
        // Unprotected, it finally goes.
        assert_eq!(trie.reclaim(&mut pool, 8, &[]).unwrap(), 1);
        assert!(trie.is_empty());
        assert_eq!(pool.blocks_in_use(), 0);
        pool.check_invariants().unwrap();
    }
}
