//! Trace-level simulation backend.
//!
//! Implements the same `Backend` interface as the real PJRT path but
//! without executing HLO: target tokens come from the same guided process
//! (reference stream + per-task deviation), and expert activations come
//! from an affinity-parameterized routing process — per layer, each of the
//! previous token's top-k expert slots is kept with probability `affinity`
//! and resampled uniformly otherwise, matching the EMA-router behaviour of
//! the L2 model in expectation.
//!
//! **Multi-request:** the backend holds one independent routing state
//! (rng stream, committed length, previous-token expert sets) per *slot*,
//! so a `BatchEngine` can keep several requests in flight and fuse their
//! verify spans into one `step_batch`. Because routing is id-attributable
//! here, the batched step de-duplicates expert fetches across requests —
//! the cross-request overlap the batch cost model charges for. Slot 0
//! doubles as the single-request state, so the legacy `begin`/`step`
//! surface (and every existing caller) behaves exactly as before.
//!
//! Used for: large parameter sweeps (Fig. 8's 120-point scatter), property
//! tests over the full engine, batched-serving experiments, and as a
//! cross-check against the real backend (rust/tests/engine_integration.rs).

use crate::coordinator::backend::{Backend, BackendStep, BatchStep, SlotStep, VerifySpan};
use crate::models::MiniConfig;
use crate::rng::Rng;
use crate::workload::Request;
use anyhow::Result;
use std::collections::BTreeSet;

/// Most in-flight requests the sim backend tracks.
pub const SIM_MAX_SLOTS: usize = 64;

/// Per-request routing state.
struct SimSlot {
    rng: Rng,
    cache_len: usize,
    /// Previous token's expert set per layer.
    prev_experts: Vec<Vec<usize>>,
    /// Per-token routing-state trajectory of the last step, so `advance`
    /// can roll the affinity state back to the accepted position (matching
    /// the real backend's rstate rollback).
    traj: Vec<Vec<Vec<usize>>>,
}

impl SimSlot {
    fn fresh(layers: usize) -> Self {
        Self {
            rng: Rng::new(0),
            cache_len: 0,
            prev_experts: vec![Vec::new(); layers],
            traj: Vec::new(),
        }
    }
}

pub struct SimBackend {
    mini: MiniConfig,
    seed: u64,
    /// Slot 0 always exists (the single-request state); higher slots are
    /// created on demand by `begin_slot`.
    slots: Vec<SimSlot>,
}

impl SimBackend {
    pub fn new(mini: MiniConfig, seed: u64) -> Self {
        let layers = mini.layers;
        Self { mini, seed, slots: vec![SimSlot::fresh(layers)] }
    }

    /// Advance one slot's routing process by one token on one layer.
    fn route_layer(mini: &MiniConfig, s: &mut SimSlot, layer: usize) -> Vec<usize> {
        let e = mini.n_experts;
        let k = mini.top_k;
        let a = mini.affinity;
        let prev = std::mem::take(&mut s.prev_experts[layer]);
        let mut set: Vec<usize> = Vec::with_capacity(k);
        for slot in 0..k {
            let reuse = slot < prev.len() && s.rng.chance(a);
            let pick = if reuse {
                prev[slot]
            } else {
                s.rng.below(e)
            };
            set.push(pick);
        }
        // Top-k picks are distinct in the real router: resample duplicates.
        for i in 0..set.len() {
            while set[..i].contains(&set[i]) {
                set[i] = s.rng.below(e);
            }
        }
        s.prev_experts[layer] = set.clone();
        set
    }

    /// Route one token across all layers on one slot.
    fn route_token(mini: &MiniConfig, s: &mut SimSlot) -> Vec<Vec<usize>> {
        (0..mini.layers).map(|l| Self::route_layer(mini, s, l)).collect()
    }

    /// Route + sample one span on one slot. Returns the per-layer unique
    /// expert-id sets (empty sets for dense) and the sampled tokens.
    fn step_slot(
        &mut self,
        slot: usize,
        t: usize,
        guides: &[Option<u32>],
        eps: f64,
    ) -> (Vec<BTreeSet<usize>>, Vec<u32>) {
        let mini = &self.mini;
        let s = &mut self.slots[slot];
        let mut unique: Vec<BTreeSet<usize>> = vec![Default::default(); mini.layers];
        s.traj.clear();
        if mini.is_moe {
            for _ in 0..t {
                let sets = Self::route_token(mini, s);
                for (l, set) in sets.iter().enumerate() {
                    unique[l].extend(set.iter().copied());
                }
                s.traj.push(sets);
            }
        }
        let sampled = guides
            .iter()
            .map(|g| match g {
                Some(g) if !s.rng.chance(eps) => *g,
                // Deviation: an arbitrary-but-deterministic "model" token.
                _ => s.rng.below(mini.vocab) as u32,
            })
            .collect();
        (unique, sampled)
    }
}

impl Backend for SimBackend {
    fn mini(&self) -> &MiniConfig {
        &self.mini
    }

    fn name(&self) -> &'static str {
        "sim"
    }

    fn begin(&mut self, req: &Request) -> Result<()> {
        self.begin_slot(0, req)
    }

    fn prefill(&mut self, prompt: &[u32], guide0: Option<u32>, eps: f64) -> Result<u32> {
        self.prefill_slot(0, prompt, guide0, eps)
    }

    fn step(&mut self, tokens: &[u32], guides: &[Option<u32>], eps: f64) -> Result<BackendStep> {
        let (unique, sampled) = self.step_slot(0, tokens.len(), guides, eps);
        Ok(BackendStep {
            sampled,
            unique_experts: if self.mini.is_moe {
                unique.into_iter().map(|s| s.len()).collect()
            } else {
                Vec::new()
            },
        })
    }

    fn advance(&mut self, n: usize) {
        self.advance_slot(0, n)
    }

    fn cache_len(&self) -> usize {
        self.slots[0].cache_len
    }

    // ---- Continuous-batching surface ------------------------------------

    fn max_slots(&self) -> usize {
        SIM_MAX_SLOTS
    }

    fn attributes_expert_ids(&self) -> bool {
        true
    }

    fn begin_slot(&mut self, slot: usize, req: &Request) -> Result<()> {
        anyhow::ensure!(slot < SIM_MAX_SLOTS, "sim backend: slot {slot} out of range");
        let layers = self.mini.layers;
        while self.slots.len() <= slot {
            self.slots.push(SimSlot::fresh(layers));
        }
        let s = &mut self.slots[slot];
        s.rng = Rng::new(self.seed ^ req.id.wrapping_mul(0xA24B_AED4_963E_E407));
        s.cache_len = 0;
        for p in &mut s.prev_experts {
            p.clear();
        }
        s.traj.clear();
        Ok(())
    }

    fn prefill_slot(
        &mut self,
        slot: usize,
        prompt: &[u32],
        guide0: Option<u32>,
        eps: f64,
    ) -> Result<u32> {
        // Advance the routing process over the prompt so affinity state is
        // warm, like the real model's EMA after prefill.
        let mini = &self.mini;
        let s = &mut self.slots[slot];
        for _ in 0..prompt.len().min(8) {
            Self::route_token(mini, s);
        }
        s.cache_len += prompt.len();
        Ok(match guide0 {
            Some(g) if !s.rng.chance(eps) => g,
            _ => s.rng.below(mini.vocab) as u32,
        })
    }

    fn advance_slot(&mut self, slot: usize, n: usize) {
        let s = &mut self.slots[slot];
        s.cache_len += n;
        // Roll the affinity state back to the last accepted token.
        if self.mini.is_moe && n >= 1 && n <= s.traj.len() {
            s.prev_experts = s.traj[n - 1].clone();
        }
    }

    fn cache_len_slot(&self, slot: usize) -> usize {
        self.slots[slot].cache_len
    }

    fn release_slot(&mut self, slot: usize) {
        if slot < self.slots.len() {
            self.slots[slot] = SimSlot::fresh(self.mini.layers);
        }
    }

    /// Native fused step: every span routes on its own slot state in one
    /// pass, and expert ids are unioned per layer across the whole batch —
    /// the de-duplicated fetch set a fused MoE verify kernel would move.
    /// Because routing is id-attributable here, each slot also gets its
    /// **marginal** expert counts — experts no other span touched — which
    /// feed the per-request utility signal of the batched Cascade policy.
    fn step_batch(&mut self, spans: &[VerifySpan]) -> Result<BatchStep> {
        let layers = self.mini.layers;
        let is_moe = self.mini.is_moe;
        let mut union: Vec<BTreeSet<usize>> = vec![Default::default(); layers];
        let mut summed = vec![0usize; layers];
        // Route every span first, keeping the per-slot id sets so marginal
        // contributions can be computed against the whole batch.
        let mut routed: Vec<(Vec<BTreeSet<usize>>, Vec<u32>)> = Vec::with_capacity(spans.len());
        for span in spans {
            anyhow::ensure!(
                span.slot < self.slots.len(),
                "sim backend: step on unbound slot {}",
                span.slot
            );
            let (sets, sampled) = self.step_slot(span.slot, span.tokens.len(), &span.guides, span.eps);
            if is_moe {
                for (l, set) in sets.iter().enumerate() {
                    summed[l] += set.len();
                    union[l].extend(set.iter().copied());
                }
            }
            routed.push((sets, sampled));
        }
        // Per layer, how many spans activated each expert; an expert with
        // multiplicity 1 is marginal to its sole activator.
        let mut multiplicity: Vec<std::collections::BTreeMap<usize, usize>> =
            vec![Default::default(); layers];
        if is_moe {
            for (sets, _) in &routed {
                for (l, set) in sets.iter().enumerate() {
                    for &e in set {
                        *multiplicity[l].entry(e).or_insert(0) += 1;
                    }
                }
            }
        }
        let mut slots = Vec::with_capacity(spans.len());
        for (span, (sets, sampled)) in spans.iter().zip(routed) {
            let (unique_experts, marginal_unique_experts, marginal_expert_ids) = if is_moe {
                let unique: Vec<usize> = sets.iter().map(|s| s.len()).collect();
                let marginal_ids: Vec<Vec<usize>> = sets
                    .iter()
                    .enumerate()
                    .map(|(l, set)| {
                        set.iter().copied().filter(|e| multiplicity[l][e] == 1).collect()
                    })
                    .collect();
                let marginal: Vec<usize> = marginal_ids.iter().map(|ids| ids.len()).collect();
                (unique, marginal, marginal_ids)
            } else {
                (Vec::new(), Vec::new(), Vec::new())
            };
            slots.push(SlotStep {
                slot: span.slot,
                step: BackendStep { sampled, unique_experts },
                marginal_unique_experts,
                marginal_expert_ids,
            });
        }
        let (batch_unique_experts, summed_unique_experts, expert_ids, shared_expert_ids) =
            if is_moe {
                // Ids activated by >= 2 slots: the shared mass the marginal
                // fairness floor amortizes (BTreeMap keeps them sorted).
                let shared: Vec<Vec<usize>> = multiplicity
                    .iter()
                    .map(|m| m.iter().filter(|&(_, &c)| c >= 2).map(|(&e, _)| e).collect())
                    .collect();
                let ids: Vec<Vec<usize>> =
                    union.iter().map(|s| s.iter().copied().collect()).collect();
                (union.into_iter().map(|s| s.len()).collect(), summed, ids, shared)
            } else {
                (Vec::new(), Vec::new(), Vec::new(), Vec::new())
            };
        Ok(BatchStep {
            slots,
            batch_unique_experts,
            summed_unique_experts,
            expert_ids,
            shared_expert_ids,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mini(affinity: f64, e: usize, k: usize) -> MiniConfig {
        MiniConfig {
            name: "sim-test".into(),
            mirrors: "test".into(),
            hidden: 64,
            layers: 2,
            heads: 4,
            head_dim: 16,
            vocab: 320,
            ffn: 64,
            n_experts: e,
            top_k: k,
            n_shared: 0,
            affinity,
            max_seq: 384,
            prefill_chunk: 64,
            is_moe: e > 0,
        }
    }

    fn req() -> Request {
        Request {
            id: 1,
            task: crate::workload::Task::Code,
            prompt: vec![1, 2, 3],
            reference: vec![4, 5, 6],
            eps: 0.0,
            max_new_tokens: 10,
        }
    }

    #[test]
    fn guided_tokens_follow_reference() {
        let mut b = SimBackend::new(mini(0.0, 8, 2), 1);
        b.begin(&req()).unwrap();
        let out = b.step(&[1, 2], &[Some(7), Some(9)], 0.0).unwrap();
        assert_eq!(out.sampled, vec![7, 9]);
    }

    #[test]
    fn unique_experts_bounded() {
        let mut b = SimBackend::new(mini(0.0, 8, 2), 2);
        b.begin(&req()).unwrap();
        let out = b.step(&[0; 8], &[None; 8], 1.0).unwrap();
        for &u in &out.unique_experts {
            assert!(u >= 2 && u <= 8, "{u}");
        }
    }

    #[test]
    fn affinity_reduces_unique_experts() {
        let run = |a: f64| {
            let mut b = SimBackend::new(mini(a, 64, 8), 3);
            b.begin(&req()).unwrap();
            let mut total = 0usize;
            for _ in 0..50 {
                let out = b.step(&[0; 8], &[None; 8], 1.0).unwrap();
                total += out.unique_experts.iter().sum::<usize>();
            }
            total
        };
        let low = run(0.0);
        let high = run(0.9);
        assert!(
            (high as f64) < low as f64 * 0.6,
            "affinity should cut unique experts: low={low} high={high}"
        );
    }

    #[test]
    fn dense_reports_no_experts() {
        let mut b = SimBackend::new(mini(0.0, 0, 0), 4);
        b.begin(&req()).unwrap();
        let out = b.step(&[0; 4], &[None; 4], 1.0).unwrap();
        assert!(out.unique_experts.is_empty());
    }

    #[test]
    fn deterministic_per_request() {
        let mut a = SimBackend::new(mini(0.3, 16, 2), 9);
        let mut b = SimBackend::new(mini(0.3, 16, 2), 9);
        a.begin(&req()).unwrap();
        b.begin(&req()).unwrap();
        let x = a.step(&[0; 4], &[None; 4], 0.5).unwrap();
        let y = b.step(&[0; 4], &[None; 4], 0.5).unwrap();
        assert_eq!(x.sampled, y.sampled);
        assert_eq!(x.unique_experts, y.unique_experts);
    }

    #[test]
    fn topk_sets_distinct() {
        let mut b = SimBackend::new(mini(0.5, 8, 8), 11);
        b.begin(&req()).unwrap();
        // top_k == n_experts: every token must activate all 8 distinct.
        let out = b.step(&[0], &[None], 1.0).unwrap();
        assert_eq!(out.unique_experts, vec![8, 8]);
    }

    fn req_id(id: u64) -> Request {
        Request { id, ..req() }
    }

    #[test]
    fn slots_are_independent_streams() {
        // A slot's stream must not depend on what other slots do: slot 1
        // alone vs slot 1 next to a busy slot 0 yields identical routing.
        let mut solo = SimBackend::new(mini(0.3, 16, 2), 9);
        solo.begin_slot(1, &req_id(7)).unwrap();
        let span = |slot: usize| VerifySpan {
            slot,
            tokens: vec![0; 4],
            guides: vec![None; 4],
            eps: 0.5,
        };
        let a = solo.step_batch(&[span(1)]).unwrap();

        let mut busy = SimBackend::new(mini(0.3, 16, 2), 9);
        busy.begin_slot(0, &req_id(3)).unwrap();
        busy.begin_slot(1, &req_id(7)).unwrap();
        let b = busy.step_batch(&[span(0), span(1)]).unwrap();

        assert_eq!(a.slots[0].step.sampled, b.slots[1].step.sampled);
        assert_eq!(a.slots[0].step.unique_experts, b.slots[1].step.unique_experts);
    }

    #[test]
    fn batched_step_matches_single_request_stream() {
        // Slot 0 driven through step_batch must reproduce the legacy
        // single-request `step` stream exactly.
        let mut single = SimBackend::new(mini(0.3, 16, 2), 9);
        single.begin(&req()).unwrap();
        let x = single.step(&[0; 4], &[None; 4], 0.5).unwrap();

        let mut batched = SimBackend::new(mini(0.3, 16, 2), 9);
        batched.begin_slot(0, &req()).unwrap();
        let out = batched
            .step_batch(&[VerifySpan { slot: 0, tokens: vec![0; 4], guides: vec![None; 4], eps: 0.5 }])
            .unwrap();
        assert_eq!(out.slots[0].step.sampled, x.sampled);
        assert_eq!(out.slots[0].step.unique_experts, x.unique_experts);
    }

    #[test]
    fn batch_dedup_below_sum() {
        // Mixtral-like topology (8 experts): four 4-token spans cannot
        // activate more than 8 unique per layer, so the union must fall
        // well below the per-slot sum.
        let mut b = SimBackend::new(mini(0.0, 8, 2), 5);
        let spans: Vec<VerifySpan> = (0..4)
            .map(|slot| {
                b.begin_slot(slot, &req_id(slot as u64 + 1)).unwrap();
                VerifySpan { slot, tokens: vec![0; 4], guides: vec![None; 4], eps: 1.0 }
            })
            .collect();
        let out = b.step_batch(&spans).unwrap();
        for l in 0..2 {
            assert!(out.batch_unique_experts[l] <= 8);
            assert!(out.batch_unique_experts[l] < out.summed_unique_experts[l]);
        }
    }

    #[test]
    fn marginal_attribution_consistent() {
        // Marginal counts: experts only one span activated. Per layer the
        // marginal sum can never exceed the batch union, and no slot's
        // marginal can exceed its own unique count.
        let mut b = SimBackend::new(mini(0.0, 8, 2), 5);
        let spans: Vec<VerifySpan> = (0..4)
            .map(|slot| {
                b.begin_slot(slot, &req_id(slot as u64 + 1)).unwrap();
                VerifySpan { slot, tokens: vec![0; 4], guides: vec![None; 4], eps: 1.0 }
            })
            .collect();
        let out = b.step_batch(&spans).unwrap();
        for l in 0..2 {
            let marginal_sum: usize =
                out.slots.iter().map(|s| s.marginal_unique_experts[l]).sum();
            assert!(marginal_sum <= out.batch_unique_experts[l]);
            for s in &out.slots {
                assert!(s.marginal_unique_experts[l] <= s.step.unique_experts[l]);
            }
        }
        // A lone span's marginal set is its full unique set.
        let mut solo = SimBackend::new(mini(0.0, 8, 2), 5);
        solo.begin_slot(0, &req_id(1)).unwrap();
        let out = solo
            .step_batch(&[VerifySpan { slot: 0, tokens: vec![0; 4], guides: vec![None; 4], eps: 1.0 }])
            .unwrap();
        assert_eq!(out.slots[0].marginal_unique_experts, out.slots[0].step.unique_experts);
    }

    #[test]
    fn expert_id_attribution_partitions_the_union() {
        // Per layer: every slot's marginal ids plus the shared ids must
        // partition the batch union exactly (ids sorted, no duplicates) —
        // the invariant the sharded cost path and fairness floor build on.
        let mut b = SimBackend::new(mini(0.0, 8, 2), 5);
        let spans: Vec<VerifySpan> = (0..4)
            .map(|slot| {
                b.begin_slot(slot, &req_id(slot as u64 + 1)).unwrap();
                VerifySpan { slot, tokens: vec![0; 4], guides: vec![None; 4], eps: 1.0 }
            })
            .collect();
        let out = b.step_batch(&spans).unwrap();
        for l in 0..2 {
            let union = &out.expert_ids[l];
            assert_eq!(union.len(), out.batch_unique_experts[l]);
            assert!(union.windows(2).all(|w| w[0] < w[1]), "union not sorted/deduped");
            let mut rebuilt: Vec<usize> = out.shared_expert_ids[l].clone();
            for s in &out.slots {
                assert_eq!(s.marginal_expert_ids[l].len(), s.marginal_unique_experts[l]);
                rebuilt.extend(s.marginal_expert_ids[l].iter().copied());
            }
            rebuilt.sort_unstable();
            assert_eq!(&rebuilt, union, "marginal + shared ids != union at layer {l}");
        }
    }

    #[test]
    fn dense_batch_reports_no_experts() {
        let mut b = SimBackend::new(mini(0.0, 0, 0), 4);
        b.begin_slot(0, &req_id(1)).unwrap();
        b.begin_slot(1, &req_id(2)).unwrap();
        let spans: Vec<VerifySpan> = (0..2)
            .map(|slot| VerifySpan { slot, tokens: vec![0; 2], guides: vec![None; 2], eps: 1.0 })
            .collect();
        let out = b.step_batch(&spans).unwrap();
        assert!(out.batch_unique_experts.is_empty());
        assert!(out.summed_unique_experts.is_empty());
    }
}
