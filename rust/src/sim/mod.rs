//! Trace-level simulation backend.
//!
//! Implements the same `Backend` interface as the real PJRT path but
//! without executing HLO: target tokens come from the same guided process
//! (reference stream + per-task deviation), and expert activations come
//! from an affinity-parameterized routing process — per layer, each of the
//! previous token's top-k expert slots is kept with probability `affinity`
//! and resampled uniformly otherwise, matching the EMA-router behaviour of
//! the L2 model in expectation.
//!
//! **Multi-request:** the backend holds one independent routing state
//! (rng stream, committed length, previous-token expert sets) per *slot*,
//! so a `BatchEngine` can keep several requests in flight and fuse their
//! verify spans into one `step_batch`. Because routing is id-attributable
//! here, the batched step de-duplicates expert fetches across requests —
//! the cross-request overlap the batch cost model charges for. Slot 0
//! doubles as the single-request state, so the legacy `begin`/`step`
//! surface (and every existing caller) behaves exactly as before.
//!
//! Used for: large parameter sweeps (Fig. 8's 120-point scatter), property
//! tests over the full engine, batched-serving experiments, and as a
//! cross-check against the real backend (rust/tests/engine_integration.rs).

use crate::coordinator::backend::{Backend, BackendStep, BatchStep, SlotStep, VerifySpan};
use crate::cost::ExpertBitmap;
use crate::models::MiniConfig;
use crate::rng::BufRng;
use crate::workload::Request;
use anyhow::Result;

/// Most in-flight requests the sim backend tracks.
pub const SIM_MAX_SLOTS: usize = 64;

/// Per-request routing state. All hot collections are flat and reused
/// across iterations: the previous token's top-k picks live in one
/// `layers × top_k` array (rewritten in place each token), and the
/// per-step trajectory is one `tokens × layers × top_k` array resized —
/// never reallocated once warm — per step.
struct SimSlot {
    rng: BufRng,
    cache_len: usize,
    /// Previous token's expert picks, flattened: layer `l` owns
    /// `[l*k, (l+1)*k)`. Slot positions are meaningful (the affinity
    /// process keeps *slot* `i`'s pick with probability `affinity`).
    prev_experts: Vec<usize>,
    /// Whether layer `l` has routed at least one token — gates the
    /// affinity reuse draw exactly like the old empty-set check did.
    prev_filled: Vec<bool>,
    /// Routing-state trajectory of the last step (token-major, same
    /// per-layer stride as `prev_experts`), so `advance` can roll the
    /// affinity state back to the accepted position (matching the real
    /// backend's rstate rollback).
    traj: Vec<usize>,
    /// Tokens recorded in `traj` by the last step.
    traj_tokens: usize,
}

impl SimSlot {
    fn fresh(layers: usize, top_k: usize) -> Self {
        Self {
            rng: BufRng::new(0),
            cache_len: 0,
            prev_experts: vec![0; layers * top_k],
            prev_filled: vec![false; layers],
            traj: Vec::new(),
            traj_tokens: 0,
        }
    }
}

pub struct SimBackend {
    mini: MiniConfig,
    seed: u64,
    /// Slot 0 always exists (the single-request state); higher slots are
    /// created on demand by `begin_slot`.
    slots: Vec<SimSlot>,
}

impl SimBackend {
    pub fn new(mini: MiniConfig, seed: u64) -> Self {
        let (layers, top_k) = (mini.layers, mini.top_k);
        Self { mini, seed, slots: vec![SimSlot::fresh(layers, top_k)] }
    }

    /// Advance one layer's routing process by one token, in place: `set`
    /// holds the previous token's picks on entry (when `filled`) and the
    /// new token's picks on exit. Draw order is exactly the historical
    /// sequence — per slot position, one `chance` draw iff `filled`, one
    /// `below` draw iff not reused, then the duplicate-resample draws —
    /// so the stream is bit-identical to the `Vec`-based router.
    fn route_layer(mini: &MiniConfig, rng: &mut BufRng, filled: bool, set: &mut [usize]) {
        let e = mini.n_experts;
        let a = mini.affinity;
        for i in 0..set.len() {
            let reuse = filled && rng.chance(a);
            if !reuse {
                set[i] = rng.below(e);
            }
        }
        // Top-k picks are distinct in the real router: resample duplicates.
        for i in 0..set.len() {
            while set[..i].contains(&set[i]) {
                set[i] = rng.below(e);
            }
        }
    }

    /// Route one token across all layers on one slot, updating the
    /// previous-token state in place.
    fn route_token(mini: &MiniConfig, s: &mut SimSlot) {
        let k = mini.top_k;
        let SimSlot { rng, prev_experts, prev_filled, .. } = s;
        for l in 0..mini.layers {
            Self::route_layer(mini, rng, prev_filled[l], &mut prev_experts[l * k..(l + 1) * k]);
            prev_filled[l] = true;
        }
    }

    /// Route + sample one span on one slot. Unions each routed set into
    /// `unique` (one bitmap per layer, caller-cleared; untouched for
    /// dense) and refills `sampled` with the span's tokens — both are
    /// caller-owned scratch so the batched step allocates nothing here.
    fn step_slot(
        &mut self,
        slot: usize,
        t: usize,
        guides: &[Option<u32>],
        eps: f64,
        unique: &mut [ExpertBitmap],
        sampled: &mut Vec<u32>,
    ) {
        let mini = &self.mini;
        let s = &mut self.slots[slot];
        let k = mini.top_k;
        let stride = mini.layers * k;
        s.traj.clear();
        s.traj_tokens = 0;
        if mini.is_moe && k > 0 {
            s.traj.resize(t * stride, 0);
            s.traj_tokens = t;
            for tok in 0..t {
                Self::route_token(mini, s);
                let base = tok * stride;
                s.traj[base..base + stride].copy_from_slice(&s.prev_experts);
                for (l, set) in s.prev_experts.chunks_exact(k).enumerate() {
                    for &e in set {
                        unique[l].insert(e);
                    }
                }
            }
        }
        sampled.clear();
        for g in guides {
            sampled.push(match g {
                Some(g) if !s.rng.chance(eps) => *g,
                // Deviation: an arbitrary-but-deterministic "model" token.
                _ => s.rng.below(mini.vocab) as u32,
            });
        }
    }
}

impl Backend for SimBackend {
    fn mini(&self) -> &MiniConfig {
        &self.mini
    }

    fn name(&self) -> &'static str {
        "sim"
    }

    fn begin(&mut self, req: &Request) -> Result<()> {
        self.begin_slot(0, req)
    }

    fn prefill(&mut self, prompt: &[u32], guide0: Option<u32>, eps: f64) -> Result<u32> {
        self.prefill_slot(0, prompt, guide0, eps)
    }

    fn step(&mut self, tokens: &[u32], guides: &[Option<u32>], eps: f64) -> Result<BackendStep> {
        let mut unique = vec![ExpertBitmap::new(); self.mini.layers];
        let mut sampled = Vec::with_capacity(tokens.len());
        self.step_slot(0, tokens.len(), guides, eps, &mut unique, &mut sampled);
        Ok(BackendStep {
            sampled,
            unique_experts: if self.mini.is_moe {
                unique.iter().map(|s| s.count()).collect()
            } else {
                Vec::new()
            },
        })
    }

    fn advance(&mut self, n: usize) {
        self.advance_slot(0, n)
    }

    fn cache_len(&self) -> usize {
        self.slots[0].cache_len
    }

    // ---- Continuous-batching surface ------------------------------------

    fn max_slots(&self) -> usize {
        SIM_MAX_SLOTS
    }

    fn attributes_expert_ids(&self) -> bool {
        true
    }

    fn begin_slot(&mut self, slot: usize, req: &Request) -> Result<()> {
        anyhow::ensure!(slot < SIM_MAX_SLOTS, "sim backend: slot {slot} out of range");
        let (layers, top_k) = (self.mini.layers, self.mini.top_k);
        while self.slots.len() <= slot {
            self.slots.push(SimSlot::fresh(layers, top_k));
        }
        let s = &mut self.slots[slot];
        s.rng.reseed(self.seed ^ req.id.wrapping_mul(0xA24B_AED4_963E_E407));
        s.cache_len = 0;
        s.prev_filled.iter_mut().for_each(|f| *f = false);
        s.traj.clear();
        s.traj_tokens = 0;
        Ok(())
    }

    fn prefill_slot(
        &mut self,
        slot: usize,
        prompt: &[u32],
        guide0: Option<u32>,
        eps: f64,
    ) -> Result<u32> {
        // Advance the routing process over the prompt so affinity state is
        // warm, like the real model's EMA after prefill.
        let mini = &self.mini;
        let s = &mut self.slots[slot];
        for _ in 0..prompt.len().min(8) {
            Self::route_token(mini, s);
        }
        s.cache_len += prompt.len();
        Ok(match guide0 {
            Some(g) if !s.rng.chance(eps) => g,
            _ => s.rng.below(mini.vocab) as u32,
        })
    }

    fn advance_slot(&mut self, slot: usize, n: usize) {
        let stride = self.mini.layers * self.mini.top_k;
        let is_moe = self.mini.is_moe;
        let s = &mut self.slots[slot];
        s.cache_len += n;
        // Roll the affinity state back to the last accepted token.
        if is_moe && n >= 1 && n <= s.traj_tokens {
            let base = (n - 1) * stride;
            s.prev_experts.copy_from_slice(&s.traj[base..base + stride]);
        }
    }

    fn cache_len_slot(&self, slot: usize) -> usize {
        self.slots[slot].cache_len
    }

    fn release_slot(&mut self, slot: usize) {
        if slot < self.slots.len() {
            self.slots[slot] = SimSlot::fresh(self.mini.layers, self.mini.top_k);
        }
    }

    /// Native fused step: every span routes on its own slot state in one
    /// pass, and expert ids are unioned per layer across the whole batch —
    /// the de-duplicated fetch set a fused MoE verify kernel would move.
    /// Because routing is id-attributable here, each slot also gets its
    /// **marginal** expert set — experts no other span touched — which
    /// feeds the per-request utility signal of the batched Cascade policy.
    fn step_batch(&mut self, spans: &[VerifySpan]) -> Result<BatchStep> {
        self.step_batch_reusing(spans, BatchStep::default())
    }

    /// The arena form of [`Backend::step_batch`]: refills `out`'s buffers
    /// in place. Union and shared sets are built with a once/twice
    /// accumulator pair — `twice |= once & routed; once |= routed` — so an
    /// expert sits in `twice` exactly when ≥ 2 spans activated it
    /// (multiplicity ≥ 2 in the old per-id counting), and each slot's
    /// marginal set is `routed & !twice` (multiplicity == 1). Word-ops
    /// only; no per-id maps, no allocation once the arena is warm.
    fn step_batch_reusing(&mut self, spans: &[VerifySpan], mut out: BatchStep) -> Result<BatchStep> {
        let layers = self.mini.layers;
        let is_moe = self.mini.is_moe;
        out.reset();
        // Recycle the previous iteration's SlotStep shells (and their
        // inner vectors) instead of allocating fresh ones.
        let mut stash = std::mem::take(&mut out.slots);
        if is_moe {
            // `expert_ids` doubles as the "once" accumulator and
            // `shared_expert_ids` as "twice"; both end up holding exactly
            // their documented final contents.
            out.expert_ids.resize(layers, ExpertBitmap::new());
            out.shared_expert_ids.resize(layers, ExpertBitmap::new());
            out.summed_unique_experts.resize(layers, 0);
        }
        for span in spans {
            anyhow::ensure!(
                span.slot < self.slots.len(),
                "sim backend: step on unbound slot {}",
                span.slot
            );
            let mut slot_step = stash.pop().unwrap_or_default();
            slot_step.slot = span.slot;
            slot_step.marginal_expert_ids.clear();
            if is_moe {
                slot_step.marginal_expert_ids.resize(layers, ExpertBitmap::new());
            }
            self.step_slot(
                span.slot,
                span.tokens.len(),
                &span.guides,
                span.eps,
                &mut slot_step.marginal_expert_ids,
                &mut slot_step.step.sampled,
            );
            slot_step.step.unique_experts.clear();
            if is_moe {
                // `marginal_expert_ids` holds the slot's *full* routed sets
                // until the post-pass below subtracts the shared mass.
                for (l, set) in slot_step.marginal_expert_ids.iter().enumerate() {
                    let unique = set.count();
                    slot_step.step.unique_experts.push(unique);
                    out.summed_unique_experts[l] += unique;
                    let overlap = out.expert_ids[l].and(set);
                    out.shared_expert_ids[l].union_with(&overlap);
                    out.expert_ids[l].union_with(set);
                }
            }
            out.slots.push(slot_step);
        }
        if is_moe {
            out.batch_unique_experts.extend(out.expert_ids.iter().map(|s| s.count()));
            for slot_step in &mut out.slots {
                slot_step.marginal_unique_experts.clear();
                for (l, set) in slot_step.marginal_expert_ids.iter_mut().enumerate() {
                    *set = set.and_not(&out.shared_expert_ids[l]);
                    slot_step.marginal_unique_experts.push(set.count());
                }
            }
        } else {
            for slot_step in &mut out.slots {
                slot_step.marginal_unique_experts.clear();
            }
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mini(affinity: f64, e: usize, k: usize) -> MiniConfig {
        MiniConfig {
            name: "sim-test".into(),
            mirrors: "test".into(),
            hidden: 64,
            layers: 2,
            heads: 4,
            head_dim: 16,
            vocab: 320,
            ffn: 64,
            n_experts: e,
            top_k: k,
            n_shared: 0,
            affinity,
            max_seq: 384,
            prefill_chunk: 64,
            is_moe: e > 0,
        }
    }

    fn req() -> Request {
        Request {
            id: 1,
            task: crate::workload::Task::Code,
            prompt: vec![1, 2, 3],
            reference: vec![4, 5, 6],
            eps: 0.0,
            max_new_tokens: 10,
        }
    }

    #[test]
    fn guided_tokens_follow_reference() {
        let mut b = SimBackend::new(mini(0.0, 8, 2), 1);
        b.begin(&req()).unwrap();
        let out = b.step(&[1, 2], &[Some(7), Some(9)], 0.0).unwrap();
        assert_eq!(out.sampled, vec![7, 9]);
    }

    #[test]
    fn unique_experts_bounded() {
        let mut b = SimBackend::new(mini(0.0, 8, 2), 2);
        b.begin(&req()).unwrap();
        let out = b.step(&[0; 8], &[None; 8], 1.0).unwrap();
        for &u in &out.unique_experts {
            assert!(u >= 2 && u <= 8, "{u}");
        }
    }

    #[test]
    fn affinity_reduces_unique_experts() {
        let run = |a: f64| {
            let mut b = SimBackend::new(mini(a, 64, 8), 3);
            b.begin(&req()).unwrap();
            let mut total = 0usize;
            for _ in 0..50 {
                let out = b.step(&[0; 8], &[None; 8], 1.0).unwrap();
                total += out.unique_experts.iter().sum::<usize>();
            }
            total
        };
        let low = run(0.0);
        let high = run(0.9);
        assert!(
            (high as f64) < low as f64 * 0.6,
            "affinity should cut unique experts: low={low} high={high}"
        );
    }

    #[test]
    fn dense_reports_no_experts() {
        let mut b = SimBackend::new(mini(0.0, 0, 0), 4);
        b.begin(&req()).unwrap();
        let out = b.step(&[0; 4], &[None; 4], 1.0).unwrap();
        assert!(out.unique_experts.is_empty());
    }

    #[test]
    fn deterministic_per_request() {
        let mut a = SimBackend::new(mini(0.3, 16, 2), 9);
        let mut b = SimBackend::new(mini(0.3, 16, 2), 9);
        a.begin(&req()).unwrap();
        b.begin(&req()).unwrap();
        let x = a.step(&[0; 4], &[None; 4], 0.5).unwrap();
        let y = b.step(&[0; 4], &[None; 4], 0.5).unwrap();
        assert_eq!(x.sampled, y.sampled);
        assert_eq!(x.unique_experts, y.unique_experts);
    }

    #[test]
    fn topk_sets_distinct() {
        let mut b = SimBackend::new(mini(0.5, 8, 8), 11);
        b.begin(&req()).unwrap();
        // top_k == n_experts: every token must activate all 8 distinct.
        let out = b.step(&[0], &[None], 1.0).unwrap();
        assert_eq!(out.unique_experts, vec![8, 8]);
    }

    fn req_id(id: u64) -> Request {
        Request { id, ..req() }
    }

    #[test]
    fn slots_are_independent_streams() {
        // A slot's stream must not depend on what other slots do: slot 1
        // alone vs slot 1 next to a busy slot 0 yields identical routing.
        let mut solo = SimBackend::new(mini(0.3, 16, 2), 9);
        solo.begin_slot(1, &req_id(7)).unwrap();
        let span = |slot: usize| VerifySpan {
            slot,
            tokens: vec![0; 4],
            guides: vec![None; 4],
            eps: 0.5,
        };
        let a = solo.step_batch(&[span(1)]).unwrap();

        let mut busy = SimBackend::new(mini(0.3, 16, 2), 9);
        busy.begin_slot(0, &req_id(3)).unwrap();
        busy.begin_slot(1, &req_id(7)).unwrap();
        let b = busy.step_batch(&[span(0), span(1)]).unwrap();

        assert_eq!(a.slots[0].step.sampled, b.slots[1].step.sampled);
        assert_eq!(a.slots[0].step.unique_experts, b.slots[1].step.unique_experts);
    }

    #[test]
    fn batched_step_matches_single_request_stream() {
        // Slot 0 driven through step_batch must reproduce the legacy
        // single-request `step` stream exactly.
        let mut single = SimBackend::new(mini(0.3, 16, 2), 9);
        single.begin(&req()).unwrap();
        let x = single.step(&[0; 4], &[None; 4], 0.5).unwrap();

        let mut batched = SimBackend::new(mini(0.3, 16, 2), 9);
        batched.begin_slot(0, &req()).unwrap();
        let out = batched
            .step_batch(&[VerifySpan { slot: 0, tokens: vec![0; 4], guides: vec![None; 4], eps: 0.5 }])
            .unwrap();
        assert_eq!(out.slots[0].step.sampled, x.sampled);
        assert_eq!(out.slots[0].step.unique_experts, x.unique_experts);
    }

    #[test]
    fn batch_dedup_below_sum() {
        // Mixtral-like topology (8 experts): four 4-token spans cannot
        // activate more than 8 unique per layer, so the union must fall
        // well below the per-slot sum.
        let mut b = SimBackend::new(mini(0.0, 8, 2), 5);
        let spans: Vec<VerifySpan> = (0..4)
            .map(|slot| {
                b.begin_slot(slot, &req_id(slot as u64 + 1)).unwrap();
                VerifySpan { slot, tokens: vec![0; 4], guides: vec![None; 4], eps: 1.0 }
            })
            .collect();
        let out = b.step_batch(&spans).unwrap();
        for l in 0..2 {
            assert!(out.batch_unique_experts[l] <= 8);
            assert!(out.batch_unique_experts[l] < out.summed_unique_experts[l]);
        }
    }

    #[test]
    fn marginal_attribution_consistent() {
        // Marginal counts: experts only one span activated. Per layer the
        // marginal sum can never exceed the batch union, and no slot's
        // marginal can exceed its own unique count.
        let mut b = SimBackend::new(mini(0.0, 8, 2), 5);
        let spans: Vec<VerifySpan> = (0..4)
            .map(|slot| {
                b.begin_slot(slot, &req_id(slot as u64 + 1)).unwrap();
                VerifySpan { slot, tokens: vec![0; 4], guides: vec![None; 4], eps: 1.0 }
            })
            .collect();
        let out = b.step_batch(&spans).unwrap();
        for l in 0..2 {
            let marginal_sum: usize =
                out.slots.iter().map(|s| s.marginal_unique_experts[l]).sum();
            assert!(marginal_sum <= out.batch_unique_experts[l]);
            for s in &out.slots {
                assert!(s.marginal_unique_experts[l] <= s.step.unique_experts[l]);
            }
        }
        // A lone span's marginal set is its full unique set.
        let mut solo = SimBackend::new(mini(0.0, 8, 2), 5);
        solo.begin_slot(0, &req_id(1)).unwrap();
        let out = solo
            .step_batch(&[VerifySpan { slot: 0, tokens: vec![0; 4], guides: vec![None; 4], eps: 1.0 }])
            .unwrap();
        assert_eq!(out.slots[0].marginal_unique_experts, out.slots[0].step.unique_experts);
    }

    #[test]
    fn expert_id_attribution_partitions_the_union() {
        // Per layer: every slot's marginal ids plus the shared ids must
        // partition the batch union exactly (ids sorted, no duplicates) —
        // the invariant the sharded cost path and fairness floor build on.
        let mut b = SimBackend::new(mini(0.0, 8, 2), 5);
        let spans: Vec<VerifySpan> = (0..4)
            .map(|slot| {
                b.begin_slot(slot, &req_id(slot as u64 + 1)).unwrap();
                VerifySpan { slot, tokens: vec![0; 4], guides: vec![None; 4], eps: 1.0 }
            })
            .collect();
        let out = b.step_batch(&spans).unwrap();
        for l in 0..2 {
            let union = out.expert_ids[l].to_ids();
            assert_eq!(union.len(), out.batch_unique_experts[l]);
            assert!(union.windows(2).all(|w| w[0] < w[1]), "union not sorted/deduped");
            let mut rebuilt: Vec<usize> = out.shared_expert_ids[l].to_ids();
            for s in &out.slots {
                assert_eq!(s.marginal_expert_ids[l].count(), s.marginal_unique_experts[l]);
                rebuilt.extend(s.marginal_expert_ids[l].iter());
            }
            rebuilt.sort_unstable();
            assert_eq!(rebuilt, union, "marginal + shared ids != union at layer {l}");
        }
    }

    #[test]
    fn dense_batch_reports_no_experts() {
        let mut b = SimBackend::new(mini(0.0, 0, 0), 4);
        b.begin_slot(0, &req_id(1)).unwrap();
        b.begin_slot(1, &req_id(2)).unwrap();
        let spans: Vec<VerifySpan> = (0..2)
            .map(|slot| VerifySpan { slot, tokens: vec![0; 2], guides: vec![None; 2], eps: 1.0 })
            .collect();
        let out = b.step_batch(&spans).unwrap();
        assert!(out.batch_unique_experts.is_empty());
        assert!(out.summed_unique_experts.is_empty());
    }
}
