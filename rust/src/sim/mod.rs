//! Trace-level simulation backend.
//!
//! Implements the same `Backend` interface as the real PJRT path but
//! without executing HLO: target tokens come from the same guided process
//! (reference stream + per-task deviation), and expert activations come
//! from an affinity-parameterized routing process — per layer, each of the
//! previous token's top-k expert slots is kept with probability `affinity`
//! and resampled uniformly otherwise, matching the EMA-router behaviour of
//! the L2 model in expectation.
//!
//! Used for: large parameter sweeps (Fig. 8's 120-point scatter), property
//! tests over the full engine, and as a cross-check against the real
//! backend (rust/tests/engine_integration.rs).

use crate::coordinator::backend::{Backend, BackendStep};
use crate::models::MiniConfig;
use crate::rng::Rng;
use crate::workload::Request;
use anyhow::Result;

/// Routing state: previous token's expert set per layer.
pub struct SimBackend {
    mini: MiniConfig,
    rng: Rng,
    seed: u64,
    cache_len: usize,
    prev_experts: Vec<Vec<usize>>,
    /// Per-token routing-state trajectory of the last step, so `advance`
    /// can roll the affinity state back to the accepted position (matching
    /// the real backend's rstate rollback).
    traj: Vec<Vec<Vec<usize>>>,
}

impl SimBackend {
    pub fn new(mini: MiniConfig, seed: u64) -> Self {
        let layers = mini.layers;
        Self {
            mini,
            rng: Rng::new(seed),
            seed,
            cache_len: 0,
            prev_experts: vec![Vec::new(); layers],
            traj: Vec::new(),
        }
    }

    /// Advance the routing process by one token on one layer.
    fn route_layer(&mut self, layer: usize) -> Vec<usize> {
        let e = self.mini.n_experts;
        let k = self.mini.top_k;
        let a = self.mini.affinity;
        let prev = std::mem::take(&mut self.prev_experts[layer]);
        let mut set: Vec<usize> = Vec::with_capacity(k);
        for slot in 0..k {
            let reuse = slot < prev.len() && self.rng.chance(a);
            let pick = if reuse {
                prev[slot]
            } else {
                self.rng.below(e)
            };
            set.push(pick);
        }
        // Top-k picks are distinct in the real router: resample duplicates.
        for i in 0..set.len() {
            while set[..i].contains(&set[i]) {
                set[i] = self.rng.below(e);
            }
        }
        self.prev_experts[layer] = set.clone();
        set
    }

    /// Route one token across all layers; returns per-layer sets.
    fn route_token(&mut self) -> Vec<Vec<usize>> {
        (0..self.mini.layers).map(|l| self.route_layer(l)).collect()
    }
}

impl Backend for SimBackend {
    fn mini(&self) -> &MiniConfig {
        &self.mini
    }

    fn name(&self) -> &'static str {
        "sim"
    }

    fn begin(&mut self, req: &Request) -> Result<()> {
        self.rng = Rng::new(self.seed ^ req.id.wrapping_mul(0xA24B_AED4_963E_E407));
        self.cache_len = 0;
        for p in &mut self.prev_experts {
            p.clear();
        }
        Ok(())
    }

    fn prefill(&mut self, prompt: &[u32], guide0: Option<u32>, eps: f64) -> Result<u32> {
        // Advance the routing process over the prompt so affinity state is
        // warm, like the real model's EMA after prefill.
        for _ in 0..prompt.len().min(8) {
            self.route_token();
        }
        self.cache_len += prompt.len();
        Ok(match guide0 {
            Some(g) if !self.rng.chance(eps) => g,
            _ => self.rng.below(self.mini.vocab) as u32,
        })
    }

    fn step(&mut self, tokens: &[u32], guides: &[Option<u32>], eps: f64) -> Result<BackendStep> {
        let t = tokens.len();
        let layers = self.mini.layers;
        let mut unique: Vec<std::collections::BTreeSet<usize>> = vec![Default::default(); layers];
        self.traj.clear();
        if self.mini.is_moe {
            for _ in 0..t {
                let sets = self.route_token();
                for (l, set) in sets.iter().enumerate() {
                    unique[l].extend(set.iter().copied());
                }
                self.traj.push(sets);
            }
        }
        let sampled = guides
            .iter()
            .map(|g| match g {
                Some(g) if !self.rng.chance(eps) => *g,
                // Deviation: an arbitrary-but-deterministic "model" token.
                _ => self.rng.below(self.mini.vocab) as u32,
            })
            .collect();
        Ok(BackendStep {
            sampled,
            unique_experts: if self.mini.is_moe {
                unique.into_iter().map(|s| s.len()).collect()
            } else {
                Vec::new()
            },
        })
    }

    fn advance(&mut self, n: usize) {
        self.cache_len += n;
        // Roll the affinity state back to the last accepted token.
        if self.mini.is_moe && n >= 1 && n <= self.traj.len() {
            self.prev_experts = self.traj[n - 1].clone();
        }
    }

    fn cache_len(&self) -> usize {
        self.cache_len
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mini(affinity: f64, e: usize, k: usize) -> MiniConfig {
        MiniConfig {
            name: "sim-test".into(),
            mirrors: "test".into(),
            hidden: 64,
            layers: 2,
            heads: 4,
            head_dim: 16,
            vocab: 320,
            ffn: 64,
            n_experts: e,
            top_k: k,
            n_shared: 0,
            affinity,
            max_seq: 384,
            prefill_chunk: 64,
            is_moe: e > 0,
        }
    }

    fn req() -> Request {
        Request {
            id: 1,
            task: crate::workload::Task::Code,
            prompt: vec![1, 2, 3],
            reference: vec![4, 5, 6],
            eps: 0.0,
            max_new_tokens: 10,
        }
    }

    #[test]
    fn guided_tokens_follow_reference() {
        let mut b = SimBackend::new(mini(0.0, 8, 2), 1);
        b.begin(&req()).unwrap();
        let out = b.step(&[1, 2], &[Some(7), Some(9)], 0.0).unwrap();
        assert_eq!(out.sampled, vec![7, 9]);
    }

    #[test]
    fn unique_experts_bounded() {
        let mut b = SimBackend::new(mini(0.0, 8, 2), 2);
        b.begin(&req()).unwrap();
        let out = b.step(&[0; 8], &[None; 8], 1.0).unwrap();
        for &u in &out.unique_experts {
            assert!(u >= 2 && u <= 8, "{u}");
        }
    }

    #[test]
    fn affinity_reduces_unique_experts() {
        let run = |a: f64| {
            let mut b = SimBackend::new(mini(a, 64, 8), 3);
            b.begin(&req()).unwrap();
            let mut total = 0usize;
            for _ in 0..50 {
                let out = b.step(&[0; 8], &[None; 8], 1.0).unwrap();
                total += out.unique_experts.iter().sum::<usize>();
            }
            total
        };
        let low = run(0.0);
        let high = run(0.9);
        assert!(
            (high as f64) < low as f64 * 0.6,
            "affinity should cut unique experts: low={low} high={high}"
        );
    }

    #[test]
    fn dense_reports_no_experts() {
        let mut b = SimBackend::new(mini(0.0, 0, 0), 4);
        b.begin(&req()).unwrap();
        let out = b.step(&[0; 4], &[None; 4], 1.0).unwrap();
        assert!(out.unique_experts.is_empty());
    }

    #[test]
    fn deterministic_per_request() {
        let mut a = SimBackend::new(mini(0.3, 16, 2), 9);
        let mut b = SimBackend::new(mini(0.3, 16, 2), 9);
        a.begin(&req()).unwrap();
        b.begin(&req()).unwrap();
        let x = a.step(&[0; 4], &[None; 4], 0.5).unwrap();
        let y = b.step(&[0; 4], &[None; 4], 0.5).unwrap();
        assert_eq!(x.sampled, y.sampled);
        assert_eq!(x.unique_experts, y.unique_experts);
    }

    #[test]
    fn topk_sets_distinct() {
        let mut b = SimBackend::new(mini(0.5, 8, 8), 11);
        b.begin(&req()).unwrap();
        // top_k == n_experts: every token must activate all 8 distinct.
        let out = b.step(&[0], &[None], 1.0).unwrap();
        assert_eq!(out.unique_experts, vec![8, 8]);
    }
}
