//! Serving, speculation, and Cascade configuration.
//!
//! `CascadeParams` carries the paper's only hyperparameters (§6): trial
//! duration `t`, max test length `T = M·t`, and set duration `S`. Everything
//! else is derived at runtime from measured utility.

/// Maximum speculation length supported by the AOT artifacts (K ≤ 7 ⇒
/// verify steps of T = K+1 ≤ 8 tokens, matching the paper's sweep).
pub const MAX_K: usize = 7;

/// Hyperparameters of the test-and-set policy (paper §5.3–§5.6, §6).
#[derive(Debug, Clone)]
pub struct CascadeParams {
    /// Trial duration in iterations (paper: t = 4).
    pub trial_iters: usize,
    /// Maximum trials per test phase (paper: M = 4, so T = M·t = 16).
    pub max_trials: usize,
    /// Set-phase duration in iterations (paper: S = 16).
    pub set_iters: usize,
    /// Adaptive back-off: multiply S by this on each transition to K = 0
    /// (paper §5.5: doubling).
    pub backoff_factor: usize,
    /// Upper bound on the backed-off set-phase length.
    pub max_set_iters: usize,
    /// Initial K for the first test phase when no history exists
    /// (paper §7.4: K_start = 3).
    pub k_start: usize,
    /// Iterations of forced K=0 at request start used to measure the
    /// no-speculation baseline (paper §5.3: "first few decode iterations",
    /// e.g. 4).
    pub baseline_iters: usize,
    /// Refresh the no-speculation baseline every this many iterations
    /// (paper §5.3: e.g. every 100).
    pub baseline_refresh: usize,
    /// Convergence early-exit: successive trial utilities within this
    /// relative band end the test phase (paper §5.6: 10%).
    pub converge_tol: f64,
    /// Ablation switches (paper Fig. 18). All true = full Cascade.
    pub enable_disable: bool,
    pub enable_backoff: bool,
    pub enable_hillclimb: bool,
}

impl Default for CascadeParams {
    fn default() -> Self {
        Self {
            trial_iters: 4,
            max_trials: 4,
            set_iters: 16,
            backoff_factor: 2,
            max_set_iters: 512,
            k_start: 3,
            baseline_iters: 4,
            baseline_refresh: 100,
            converge_tol: 0.10,
            enable_disable: true,
            enable_backoff: true,
            enable_hillclimb: true,
        }
    }
}

impl CascadeParams {
    /// Ablation level for Fig. 18: 0 = none (static K_start), 1 = +disable,
    /// 2 = +back-off, 3 = full (+hill-climb).
    pub fn ablation(level: usize) -> Self {
        Self {
            enable_disable: level >= 1,
            enable_backoff: level >= 2,
            enable_hillclimb: level >= 3,
            ..Self::default()
        }
    }

    /// §7.5 sensitivity variants: scale (t, S) keeping T = 4t.
    pub fn with_phases(trial_iters: usize, set_iters: usize) -> Self {
        Self { trial_iters, set_iters, ..Self::default() }
    }
}

/// Which drafter generates the speculative tokens.
/// `Ord` so the kind can key deterministic `BTreeMap` caches.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum DrafterKind {
    /// Prompt-lookup n-gram matching (paper's primary technique, [38]).
    Ngram,
    /// Draft-model speculation via the AOT `draft` model (paper §7.3;
    /// EAGLE stand-in, see DESIGN.md §Substitutions).
    EagleLite,
}

/// Expert→shard placement strategy under expert-parallel sharding
/// (`EngineConfig::shards` > 1). See rust/docs/sharding.md.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum PlacementKind {
    /// Round-robin: expert `e` lives on shard `e % shards`. Weight-balanced
    /// by construction, blind to which experts activate together.
    Balanced,
    /// Greedy co-activation-aware packer: experts that frequently activate
    /// in the same layer-step are spread across shards (their loads stack
    /// on the critical path), rebuilt online from the expert co-occurrence
    /// histogram the id-attributing backend feeds.
    CoActivation,
}

impl PlacementKind {
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        match s {
            "balanced" => Ok(PlacementKind::Balanced),
            "coactivation" => Ok(PlacementKind::CoActivation),
            other => anyhow::bail!("unknown placement {other:?} (want balanced|coactivation)"),
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            PlacementKind::Balanced => "balanced",
            PlacementKind::CoActivation => "coactivation",
        }
    }
}

/// Admission-ordering policy of the serving stack (`EngineConfig::admission`):
/// when a slot frees, who enters it — a fresh arrival or a parked eviction
/// victim, and in what order among the waiting arrivals. The policy objects
/// themselves live in `coordinator::admission`; see rust/docs/serving.md.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum AdmissionKind {
    /// First-come-first-served over arrived requests, with parked eviction
    /// victims re-admitted at iteration start (the engine's stage-0 drain).
    /// Fresh arrivals admitted in the same scheduler pass grab slots and
    /// pool blocks *before* that drain runs — the pre-refactor behavior,
    /// kept bit-exactly as the default.
    Fcfs,
    /// Parked eviction victims re-admit ahead of fresh arrivals: while any
    /// victim waits, fresh admission is held back so the stage-0 drain gets
    /// first pick of slots and pool blocks — closing the ROADMAP's
    /// "eviction-aware admission ordering" follow-on (less re-admission
    /// starvation, less thrash under bursty load).
    ParkedFirst,
    /// Earliest-deadline-first against the per-request latency SLO
    /// (deadline = arrival + `EngineConfig::slo_s`): waiting arrivals are
    /// admitted in deadline order, and parked victims (whose deadlines are
    /// the oldest outstanding) both drain first and re-admit in deadline
    /// order.
    Edf,
}

impl AdmissionKind {
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        match s {
            "fcfs" => Ok(AdmissionKind::Fcfs),
            "parked-first" => Ok(AdmissionKind::ParkedFirst),
            "edf" => Ok(AdmissionKind::Edf),
            other => anyhow::bail!("unknown admission {other:?} (want fcfs|parked-first|edf)"),
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            AdmissionKind::Fcfs => "fcfs",
            AdmissionKind::ParkedFirst => "parked-first",
            AdmissionKind::Edf => "edf",
        }
    }
}

/// Victim-selection policy for KV-pool preemption (`EngineConfig::eviction`).
/// See rust/docs/preemption.md.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum EvictionKind {
    /// No preemption: an oversubscribed pool keeps today's shrink-then-defer
    /// behavior and surfaces a deadlock error when nothing can progress.
    Off,
    /// Evict the least-recently-admitted slot first (admission-order FIFO).
    /// Re-admission re-stamps the clock, so a just-readmitted request is the
    /// *last* choice next time — damping evict/readmit ping-pong.
    Lru,
    /// Evict the slot with the largest speculative reservation planned this
    /// iteration (biggest K first): the request whose lookahead is costing
    /// the pool the most blocks per emitted token.
    MostLookahead,
    /// Evict the slot with the lowest marginal utility (emitted tokens per
    /// simulated second of its marginal iteration cost) as observed by its
    /// per-request Cascade/static policy feedback — the paper's
    /// utility-driven lens applied to victim selection.
    CostAware,
}

impl EvictionKind {
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        match s {
            "off" => Ok(EvictionKind::Off),
            "lru" => Ok(EvictionKind::Lru),
            "most-lookahead" => Ok(EvictionKind::MostLookahead),
            "cost-aware" => Ok(EvictionKind::CostAware),
            other => anyhow::bail!(
                "unknown eviction {other:?} (want off|lru|most-lookahead|cost-aware)"
            ),
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            EvictionKind::Off => "off",
            EvictionKind::Lru => "lru",
            EvictionKind::MostLookahead => "most-lookahead",
            EvictionKind::CostAware => "cost-aware",
        }
    }

    pub fn is_on(&self) -> bool {
        *self != EvictionKind::Off
    }
}

/// Degradation-controller policy (`EngineConfig::controller`): whether the
/// engine reacts to pressure — KV reserve shortfall, queue depth, and EDF
/// deadline slack — by throttling speculation, capping the verify expert
/// budget (MoE-Spec-style), and shedding already-unmeetable requests. The
/// controller logic lives in `coordinator::faults`; see rust/docs/faults.md.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ControllerKind {
    /// No reaction: today's behavior, bit-exact.
    Off,
    /// Pressure-adaptive degradation: cap K under moderate pressure,
    /// disable speculation and cap the verify expert budget under high
    /// pressure, shed waiting requests whose TTFT SLO is already missed.
    Adaptive,
}

impl ControllerKind {
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        match s {
            "off" => Ok(ControllerKind::Off),
            "adaptive" => Ok(ControllerKind::Adaptive),
            other => anyhow::bail!("unknown controller {other:?} (want off|adaptive)"),
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            ControllerKind::Off => "off",
            ControllerKind::Adaptive => "adaptive",
        }
    }

    pub fn is_on(&self) -> bool {
        *self != ControllerKind::Off
    }
}

/// Straggler self-healing policy (`EngineConfig::heal`): whether the
/// engine's per-shard health estimator (EWMA over observed verify-time
/// inflation) feeds a capacity-weighted placement rebuild that migrates
/// experts off a confirmed straggler — and back after recovery, behind a
/// hysteresis band so the placement never flaps. The detector lives in
/// `coordinator::batch`; see rust/docs/faults.md.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum HealKind {
    /// No detection, no healing rebuilds: today's behavior, bit-exact.
    Off,
    /// Detect stragglers and rebuild the placement with capacity caps
    /// proportional to shard health (migration bytes charged into
    /// `IterCost::migration_s`). Token streams are untouched — healing
    /// changes only where experts live, never what is sampled.
    Detect,
}

impl HealKind {
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        match s {
            "off" => Ok(HealKind::Off),
            "detect" => Ok(HealKind::Detect),
            other => anyhow::bail!("unknown heal policy {other:?} (want off|detect)"),
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            HealKind::Off => "off",
            HealKind::Detect => "detect",
        }
    }

    pub fn is_on(&self) -> bool {
        *self != HealKind::Off
    }
}

/// Per-task SLO classes (`--slo-ms code=250,math=400,default=300`): each
/// entry maps a task name to its TTFT deadline in seconds. A `default`
/// entry sets the catch-all `EngineConfig::slo_s`; tasks without a class
/// fall back to it. Entries keep spec order (first match wins), so the
/// label round-trips the flag.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct SloClasses {
    pub classes: Vec<(String, f64)>,
}

impl SloClasses {
    /// Parse the class clauses of a `--slo-ms` spec (everything of the
    /// form `name=ms`, excluding `default=` which callers route into
    /// `slo_s`). Milliseconds in the flag, seconds in the struct.
    pub fn parse(spec: &str) -> anyhow::Result<Self> {
        let mut classes = Vec::new();
        for clause in spec.split(',').map(str::trim).filter(|c| !c.is_empty()) {
            let (name, ms) = clause
                .split_once('=')
                .ok_or_else(|| anyhow::anyhow!("bad SLO class {clause:?} (want name=ms)"))?;
            let name = name.trim();
            let ms: f64 = ms
                .trim()
                .parse()
                .map_err(|e| anyhow::anyhow!("bad SLO ms in {clause:?}: {e}"))?;
            anyhow::ensure!(ms > 0.0, "SLO class {name:?} must be > 0 ms");
            anyhow::ensure!(!name.is_empty(), "empty SLO class name in {clause:?}");
            anyhow::ensure!(
                classes.iter().all(|(n, _): &(String, f64)| n != name),
                "duplicate SLO class {name:?}"
            );
            classes.push((name.to_string(), ms / 1e3));
        }
        Ok(Self { classes })
    }

    /// The class deadline for `task`, if one is configured.
    pub fn get(&self, task: &str) -> Option<f64> {
        self.classes.iter().find(|(n, _)| n == task).map(|&(_, s)| s)
    }

    pub fn is_empty(&self) -> bool {
        self.classes.is_empty()
    }

    /// Spec-order `name=ms` rendering (telemetry headers).
    pub fn label(&self) -> String {
        self.classes
            .iter()
            .map(|(n, s)| format!("{n}={}", s * 1e3))
            .collect::<Vec<_>>()
            .join(",")
    }
}

/// Engine-level configuration for one serving run.
#[derive(Debug, Clone)]
pub struct EngineConfig {
    /// Model zoo key (`mixtral`, `phi`, `olmoe`, `deepseek`, `qwen`, `llama`).
    pub model: String,
    pub drafter: DrafterKind,
    /// N-gram drafter: max context n-gram length to match.
    pub ngram_max: usize,
    /// N-gram drafter: minimum match length.
    pub ngram_min: usize,
    /// Guided-decoding bias strength (DESIGN.md §Substitutions): the target
    /// model's logits get `guide_strength` added at the reference token.
    pub guide_strength: f32,
    /// Per-request cap on generated tokens.
    pub max_new_tokens: usize,
    /// Deterministic seed for samplers and workloads.
    pub seed: u64,
    /// Requests kept in flight by the continuous-batching engine (1 =
    /// single-batch serving, the paper's setting). Clamped to what the
    /// backend supports (`Backend::max_slots`).
    pub max_batch: usize,
    /// Shared KV pool size in blocks for the batched engine. 0 = the
    /// aggregate worst case (`max_batch * max_seq / block_size`): no
    /// cross-request contention. Smaller values oversubscribe the pool so
    /// admission and speculative lookahead genuinely compete for blocks;
    /// `eviction` then decides whether the engine preempts victims to keep
    /// decoding or (when off) surfaces a deadlock once nothing can progress.
    pub kv_pool_blocks: usize,
    /// Preemption policy for an oversubscribed KV pool: when a slot cannot
    /// reserve its planned verify span, evict a victim (releasing its
    /// blocks, parking it for replay-based re-admission) or defer the whole
    /// span — never shrink it, which is what keeps evicted-then-readmitted
    /// token streams bit-exact with uncontended runs. `Off` (default)
    /// preserves the pre-preemption shrink/defer/deadlock behavior
    /// bit-exactly. See rust/docs/preemption.md.
    pub eviction: EvictionKind,
    /// Upper bound on how many times one request may be preempted; a
    /// request at the cap is never selected as a victim again (it is
    /// "pinned"), bounding re-prefill thrash at the price of possible
    /// deadlock when every candidate is pinned.
    pub max_preemptions_per_req: usize,
    /// Two-stage pipelined drafting (paper Fig. 14): draft iteration i+1's
    /// proposals while the backend verifies iteration i, reconciling (and
    /// recomputing) drafts whose acceptance assumption broke. Drafting
    /// cost is charged only where it exceeds the concurrent verify window
    /// (`IterCost::draft_hidden_s`). For a fixed K schedule token output
    /// is bit-identical to serial; Cascade observes the cheaper pipelined
    /// cost as its utility signal, so it may legitimately pick different K
    /// (that is the point — K decisions see pipeline-true utility).
    pub pipeline: bool,
    /// Expert-parallel shard count for the cost model (1 = single-GPU, the
    /// paper's setting). At `shards > 1` the routed-expert term of the
    /// fused verify cost becomes the **max over per-shard deduped expert
    /// loads** plus a per-step all-to-all latency term, so speculative
    /// expert mass partially hides behind parallel fetch — which raises
    /// utility and lets Cascade pick larger K. Clamped to the model's
    /// expert count; a no-op for dense models.
    pub shards: usize,
    /// Expert→shard placement strategy at `shards > 1`.
    pub placement: PlacementKind,
    /// Admission-ordering policy: who takes a freed slot — a fresh arrival
    /// or a parked eviction victim, and in what order among waiting
    /// arrivals. `Fcfs` (default) preserves the pre-refactor ordering
    /// bit-exactly. See `coordinator::admission` / rust/docs/serving.md.
    pub admission: AdmissionKind,
    /// Per-request latency SLO in simulated seconds, measured on TTFT
    /// (arrival → first token on the virtual clock). 0 = no SLO. Feeds the
    /// `edf` admission deadline (arrival + slo_s) and the SLO-goodput
    /// telemetry; it never changes token output.
    pub slo_s: f64,
    /// Fault-injection plan spec (`"off"`, a builtin name like `"chaos"`,
    /// `"file:<path>"`, or inline `;`-separated clauses) scheduling
    /// deterministic faults — shard stragglers, transient stalls, shard
    /// kills, KV-pool shrinks — against the virtual clock. Parsed by
    /// `coordinator::faults::FaultPlan`; `"off"` (default) injects nothing
    /// and is bit-exact with the fault-free engine. See rust/docs/faults.md.
    pub faults: String,
    /// Stochastic fault-process spec (`"off"` or
    /// `mtbf=<s>,mttr=<s>,kind=<fault>`): an MTBF/MTTR-driven renewal
    /// process materialized at engine build into a seed-deterministic
    /// fault schedule and merged with `faults`. `"off"` (default) merges
    /// nothing — bit-exact with a process-free run. Parsed by
    /// `coordinator::faults::FaultProcess`; see rust/docs/faults.md.
    pub fault_process: String,
    /// Straggler-aware self-healing placement (`Off` = bit-exact today's
    /// behavior). See rust/docs/faults.md §Self-healing.
    pub heal: HealKind,
    /// Per-task SLO classes layered over `slo_s` (empty = every task uses
    /// the catch-all). Deadlines, EDF ordering, controller shedding, and
    /// per-class goodput all read `slo_for(task)`.
    pub slo_classes: SloClasses,
    /// Graceful-degradation controller (`Off` = bit-exact today's behavior).
    pub controller: ControllerKind,
    /// Prefix sharing (`--prefix-share P`, rust/docs/prefix_cache.md):
    /// `> 0` switches the KV pool into copy-on-write sharing mode with a
    /// prefix trie over committed token ids, and — on the workload side —
    /// gives every generated request a fixed-length preamble drawn from a
    /// small shared template pool with probability `P` (else unique), so
    /// `P` sweeps the cache hit rate. Must be in `[0, 1]`. `0.0` (default)
    /// keeps the counts-only pool and the template-free workload
    /// bit-exactly. Sharing changes only block accounting and
    /// virtual-clock charges, never token output.
    pub prefix_share: f64,
    pub cascade: CascadeParams,
}

impl EngineConfig {
    /// The TTFT SLO for a task: its class deadline if one is configured,
    /// else the catch-all `slo_s`. ≤ 0 means "no deadline".
    pub fn slo_for(&self, task: &str) -> f64 {
        self.slo_classes.get(task).unwrap_or(self.slo_s)
    }

    /// Any SLO configured at all (catch-all or per-class) — the gate for
    /// deadline-driven shedding and goodput accounting.
    pub fn has_slo(&self) -> bool {
        self.slo_s > 0.0 || !self.slo_classes.is_empty()
    }
}

impl Default for EngineConfig {
    fn default() -> Self {
        Self {
            model: "mixtral".into(),
            drafter: DrafterKind::Ngram,
            ngram_max: 4,
            ngram_min: 1,
            guide_strength: 48.0,
            max_new_tokens: 200,
            seed: 0xCA5CADE,
            max_batch: 1,
            kv_pool_blocks: 0,
            eviction: EvictionKind::Off,
            max_preemptions_per_req: 8,
            pipeline: false,
            shards: 1,
            placement: PlacementKind::Balanced,
            admission: AdmissionKind::Fcfs,
            slo_s: 0.0,
            faults: "off".into(),
            fault_process: "off".into(),
            heal: HealKind::Off,
            slo_classes: SloClasses::default(),
            controller: ControllerKind::Off,
            prefix_share: 0.0,
            cascade: CascadeParams::default(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_match_paper() {
        let p = CascadeParams::default();
        assert_eq!(p.trial_iters, 4);
        assert_eq!(p.max_trials, 4);
        assert_eq!(p.set_iters, 16);
        assert_eq!(p.trial_iters * p.max_trials, 16); // T = 16
        assert_eq!(p.k_start, 3);
    }

    #[test]
    fn ablation_levels() {
        assert!(!CascadeParams::ablation(0).enable_disable);
        let l1 = CascadeParams::ablation(1);
        assert!(l1.enable_disable && !l1.enable_backoff);
        let l3 = CascadeParams::ablation(3);
        assert!(l3.enable_disable && l3.enable_backoff && l3.enable_hillclimb);
    }

    #[test]
    fn eviction_kinds_roundtrip_and_default_off() {
        for kind in [
            EvictionKind::Off,
            EvictionKind::Lru,
            EvictionKind::MostLookahead,
            EvictionKind::CostAware,
        ] {
            assert_eq!(EvictionKind::parse(kind.label()).unwrap(), kind);
            assert_eq!(kind.is_on(), kind != EvictionKind::Off);
        }
        assert!(EvictionKind::parse("fifo").is_err());
        let cfg = EngineConfig::default();
        assert_eq!(cfg.eviction, EvictionKind::Off, "preemption must be opt-in");
        assert!(cfg.max_preemptions_per_req > 0);
    }

    #[test]
    fn admission_kinds_roundtrip_and_default_fcfs() {
        for kind in [AdmissionKind::Fcfs, AdmissionKind::ParkedFirst, AdmissionKind::Edf] {
            assert_eq!(AdmissionKind::parse(kind.label()).unwrap(), kind);
        }
        assert!(AdmissionKind::parse("lifo").is_err());
        let cfg = EngineConfig::default();
        assert_eq!(cfg.admission, AdmissionKind::Fcfs, "legacy ordering must be the default");
        assert_eq!(cfg.slo_s, 0.0, "no SLO unless asked");
    }

    #[test]
    fn controller_kinds_roundtrip_and_default_off() {
        for kind in [ControllerKind::Off, ControllerKind::Adaptive] {
            assert_eq!(ControllerKind::parse(kind.label()).unwrap(), kind);
            assert_eq!(kind.is_on(), kind != ControllerKind::Off);
        }
        assert!(ControllerKind::parse("pid").is_err());
        let cfg = EngineConfig::default();
        assert_eq!(cfg.controller, ControllerKind::Off, "degradation must be opt-in");
        assert_eq!(cfg.faults, "off", "fault injection must be opt-in");
        assert_eq!(cfg.fault_process, "off", "stochastic faults must be opt-in");
    }

    #[test]
    fn heal_kinds_roundtrip_and_default_off() {
        for kind in [HealKind::Off, HealKind::Detect] {
            assert_eq!(HealKind::parse(kind.label()).unwrap(), kind);
            assert_eq!(kind.is_on(), kind != HealKind::Off);
        }
        assert!(HealKind::parse("repair").is_err());
        let cfg = EngineConfig::default();
        assert_eq!(cfg.heal, HealKind::Off, "self-healing must be opt-in");
        assert_eq!(cfg.prefix_share, 0.0, "prefix sharing must be opt-in");
    }

    #[test]
    fn slo_classes_parse_lookup_and_label() {
        let c = SloClasses::parse("code=250, math=400").unwrap();
        assert_eq!(c.classes.len(), 2);
        assert_eq!(c.get("code"), Some(0.25));
        assert_eq!(c.get("math"), Some(0.4));
        assert_eq!(c.get("qa"), None);
        assert_eq!(c.label(), "code=250,math=400");
        assert!(SloClasses::parse("").unwrap().is_empty());
        for bad in ["code", "code=0", "code=-5", "=250", "code=250,code=300", "code=abc"] {
            assert!(SloClasses::parse(bad).is_err(), "{bad:?} should fail");
        }
    }

    #[test]
    fn slo_for_prefers_class_over_catchall() {
        let mut cfg = EngineConfig::default();
        assert!(!cfg.has_slo());
        assert_eq!(cfg.slo_for("code"), 0.0);
        cfg.slo_s = 0.3;
        cfg.slo_classes = SloClasses::parse("code=250").unwrap();
        assert!(cfg.has_slo());
        assert_eq!(cfg.slo_for("code"), 0.25, "class wins");
        assert_eq!(cfg.slo_for("math"), 0.3, "catch-all fallback");
        // Classes alone (no catch-all) still count as an SLO being set.
        let classy = EngineConfig {
            slo_classes: SloClasses::parse("math=400").unwrap(),
            ..EngineConfig::default()
        };
        assert!(classy.has_slo());
        assert_eq!(classy.slo_for("code"), 0.0, "unclassed task has no deadline");
    }

    #[test]
    fn sensitivity_variants_keep_t_eq_4t() {
        let p = CascadeParams::with_phases(2, 8);
        assert_eq!(p.trial_iters, 2);
        assert_eq!(p.set_iters, 8);
        assert_eq!(p.max_trials, 4);
    }
}
