//! Expert-parallel placement: which expert lives on which shard.
//!
//! Under expert parallelism the fused verify step's critical path is the
//! **most-loaded shard** — per layer, every shard fetches only its own
//! resident experts, in parallel — so the mapping expert id → shard decides
//! how much of the speculative expert mass is hidden. Two strategies:
//!
//! * **balanced** (round-robin): weight-balanced by construction, blind to
//!   routing correlations;
//! * **co-activation-aware**: a greedy packer over an online expert
//!   co-occurrence histogram. Experts that frequently activate in the same
//!   layer-step *stack* on whichever shard holds them both, so the packer
//!   spreads high-co-occurrence pairs across shards (subject to a per-shard
//!   capacity so expert weights stay memory-balanced). MoE-Spec's expert
//!   budgeting and SP-MoE's prefetch/placement line (PAPERS.md) motivate
//!   making placement quality *measured*, not assumed.
//!
//! The histogram is fed by an id-attributing backend (the sim backend's
//! fused `step_batch` reports per-layer expert-id unions); all operations
//! are deterministic — placement may only move *cost*, never tokens, and
//! runs must replay bit-for-bit under a fixed seed.
//!
//! Per-layer expert sets arrive as [`ExpertBitmap`]s, so the per-shard
//! load query — the per-iteration hot path — is a masked popcount against
//! precomputed per-shard residency masks instead of a per-id hash/walk.

use crate::cost::bitmap::{ExpertBitmap, MAX_EXPERTS};

/// Immutable expert → shard map.
#[derive(Debug, Clone)]
pub struct ExpertPlacement {
    n_shards: usize,
    /// `assign[e]` = shard holding expert `e`.
    assign: Vec<usize>,
    /// `masks[s]` = the experts resident on shard `s`, precomputed from
    /// `assign` so `shard_loads` is one `count_and` per shard per layer.
    masks: Vec<ExpertBitmap>,
}

impl ExpertPlacement {
    /// Round-robin placement: expert `e` lives on shard `e % n_shards`.
    pub fn balanced(n_experts: usize, n_shards: usize) -> Self {
        let n_shards = n_shards.max(1);
        Self::from_assign((0..n_experts).map(|e| e % n_shards).collect(), n_shards)
    }

    /// Placement from an explicit assignment (greedy packer output).
    pub fn from_assign(assign: Vec<usize>, n_shards: usize) -> Self {
        let n_shards = n_shards.max(1);
        debug_assert!(assign.iter().all(|&s| s < n_shards));
        debug_assert!(assign.len() <= MAX_EXPERTS, "expert count exceeds bitmap capacity");
        let mut masks = vec![ExpertBitmap::new(); n_shards];
        for (e, &s) in assign.iter().enumerate() {
            masks[s].insert(e);
        }
        Self { n_shards, assign, masks }
    }

    pub fn n_shards(&self) -> usize {
        self.n_shards
    }

    pub fn n_experts(&self) -> usize {
        self.assign.len()
    }

    /// Shard holding expert `e` (out-of-range ids wrap, defensively).
    pub fn shard_of(&self, e: usize) -> usize {
        if self.assign.is_empty() {
            return 0;
        }
        self.assign[e % self.assign.len()]
    }

    /// Experts resident per shard (weight balance check).
    pub fn shard_sizes(&self) -> Vec<usize> {
        let mut sizes = vec![0usize; self.n_shards];
        for &s in &self.assign {
            sizes[s] += 1;
        }
        sizes
    }

    /// Group per-layer deduped expert-id sets into per-layer **per-shard
    /// unique counts**: `loads[l][s]` = experts of shard `s` that layer
    /// `l`'s fused step must fetch. The cost model's expert term is the
    /// per-layer max over shards; `Σ_s loads[l][s]` equals the unsharded
    /// union count (every expert lives on exactly one shard). One masked
    /// popcount per shard per layer against the residency masks.
    pub fn shard_loads(&self, per_layer_ids: &[ExpertBitmap]) -> Vec<Vec<usize>> {
        per_layer_ids
            .iter()
            .map(|ids| self.masks.iter().map(|m| m.count_and(ids)).collect())
            .collect()
    }

    /// Round-robin placement over the *surviving* shards only — the
    /// fault-injection recovery path (rust/docs/faults.md): when a shard
    /// dies, its experts must be re-hosted on the survivors so verify can
    /// continue (at a worse critical path — fewer shards hold the same
    /// union). `dead[s]` marks shard `s` failed; experts are dealt
    /// round-robin across the alive shards in index order, keeping weight
    /// balance among survivors. The shard *count* is preserved so
    /// `shard_loads` rows stay comparable across the failure window; dead
    /// shards simply end up with zero residents. With every shard dead (or
    /// an empty mask) this falls back to the fully balanced placement.
    pub fn balanced_surviving(n_experts: usize, n_shards: usize, dead: &[bool]) -> Self {
        let n_shards = n_shards.max(1);
        let alive: Vec<usize> =
            (0..n_shards).filter(|&s| !dead.get(s).copied().unwrap_or(false)).collect();
        if alive.is_empty() || alive.len() == n_shards {
            return Self::balanced(n_experts, n_shards);
        }
        let assign = (0..n_experts).map(|e| alive[e % alive.len()]).collect();
        Self::from_assign(assign, n_shards)
    }

    /// Per-layer max-over-shards load — the expert-parallel critical path
    /// the sharded cost model charges.
    pub fn max_loads(&self, per_layer_ids: &[ExpertBitmap]) -> Vec<usize> {
        let mut out = Vec::with_capacity(per_layer_ids.len());
        self.max_loads_into(per_layer_ids, &mut out);
        out
    }

    /// [`Self::max_loads`] into a caller-owned buffer (cleared first) —
    /// the allocation-free form the engine's per-slot marginal pricing
    /// loop uses with its arena scratch.
    pub fn max_loads_into(&self, per_layer_ids: &[ExpertBitmap], out: &mut Vec<usize>) {
        out.clear();
        for ids in per_layer_ids {
            out.push(self.masks.iter().map(|m| m.count_and(ids)).max().unwrap_or(0));
        }
    }

    /// Experts whose shard differs between `self` and `other` — the
    /// migration volume a placement rebuild must move over the
    /// interconnect (`CostModel::migration_s` prices it per expert per
    /// layer). Placements must cover the same expert count.
    pub fn moved_from(&self, other: &ExpertPlacement) -> usize {
        debug_assert_eq!(self.n_experts(), other.n_experts());
        (0..self.n_experts().min(other.n_experts()))
            .filter(|&e| self.shard_of(e) != other.shard_of(e))
            .count()
    }
}

/// Integer per-shard expert caps proportional to `weights` (a shard's
/// relative healthy capacity — the self-healing detector passes
/// `1/health[s]`, so a 4× straggler gets a quarter of the experts of a
/// healthy peer). Each cap is `ceil(E · w_s / Σw)`, so the caps always
/// cover all experts; non-positive or non-finite weights mean "place
/// nothing here" (cap 0). An all-degenerate weight vector falls back to
/// the uniform `ceil(E/S)` cap.
pub fn capacity_caps(n_experts: usize, weights: &[f64]) -> Vec<usize> {
    let n_shards = weights.len().max(1);
    let total: f64 = weights.iter().filter(|w| w.is_finite() && **w > 0.0).sum();
    if total <= 0.0 {
        return vec![n_experts.div_ceil(n_shards); n_shards];
    }
    weights
        .iter()
        .map(|&w| {
            if !w.is_finite() || w <= 0.0 {
                0
            } else {
                (n_experts as f64 * w / total).ceil() as usize
            }
        })
        .collect()
}

/// Online expert co-occurrence histogram: how often each expert pair was
/// activated in the same layer-step. Fed per fused iteration from the
/// backend's per-layer expert-id unions; read by the greedy packer.
#[derive(Debug, Clone)]
pub struct CoActivationStats {
    n_experts: usize,
    /// Activation count per expert (layer-steps it appeared in).
    acts: Vec<u64>,
    /// Symmetric pair counts, row-major `n_experts × n_experts`
    /// (diagonal unused). Dense is fine: the zoo tops out at 64 experts.
    pairs: Vec<u64>,
    /// Layer-steps observed.
    steps: u64,
}

impl CoActivationStats {
    pub fn new(n_experts: usize) -> Self {
        Self {
            n_experts,
            acts: vec![0; n_experts],
            pairs: vec![0; n_experts * n_experts],
            steps: 0,
        }
    }

    pub fn steps(&self) -> u64 {
        self.steps
    }

    fn pair(&self, a: usize, b: usize) -> u64 {
        self.pairs[a * self.n_experts + b]
    }

    /// Record one fused step: `per_layer_ids[l]` is the deduped expert-id
    /// set layer `l` activated (ids must be < `n_experts`; the sim backend
    /// guarantees this by construction). The bitmap is unpacked once into
    /// a stack buffer (ascending ids, the order the old sorted-set walk
    /// produced) so the pair loop stays a plain slice double-walk.
    pub fn observe(&mut self, per_layer_ids: &[ExpertBitmap]) {
        let mut buf = [0usize; MAX_EXPERTS];
        for set in per_layer_ids {
            self.steps += 1;
            let mut n = 0;
            for e in set.iter() {
                buf[n] = e;
                n += 1;
            }
            let ids = &buf[..n];
            for (i, &a) in ids.iter().enumerate() {
                self.acts[a] += 1;
                for &b in &ids[i + 1..] {
                    self.pairs[a * self.n_experts + b] += 1;
                    self.pairs[b * self.n_experts + a] += 1;
                }
            }
        }
    }

    /// Halve every count — an exponential decay applied at each placement
    /// rebuild so the histogram tracks the *recent* routing regime instead
    /// of accumulating forever. Without decay, counts from an early
    /// workload phase would permanently dominate and later rebuilds could
    /// never adapt to a shifted mix. Integer halving is deterministic.
    pub fn decay(&mut self) {
        for a in &mut self.acts {
            *a /= 2;
        }
        for p in &mut self.pairs {
            *p /= 2;
        }
        self.steps /= 2;
    }

    /// Greedy co-activation-aware packer. Experts are placed in order of
    /// activation frequency (hottest first — they constrain the most); each
    /// goes to the shard minimizing the summed co-occurrence with experts
    /// already resident there, under a `ceil(E/S)` per-shard capacity so
    /// expert *weights* stay memory-balanced across devices. Ties break
    /// toward the emptier, then lower-indexed shard — fully deterministic.
    /// With an empty histogram this degenerates to a balanced placement.
    pub fn greedy_placement(&self, n_shards: usize) -> ExpertPlacement {
        let n_shards = n_shards.max(1).min(self.n_experts.max(1));
        let cap = self.n_experts.div_ceil(n_shards);
        self.greedy_placement_capped(&vec![cap; n_shards])
    }

    /// The greedy packer under explicit per-shard capacities — the
    /// self-healing rebuild: `caps[s]` bounds how many experts shard `s`
    /// may hold (see [`capacity_caps`]; a detected straggler gets a small
    /// cap so its verify share shrinks to match its slowdown). Caps must
    /// cover all experts (`Σ caps >= E`; degenerate inputs fall back to
    /// the uniform cap). Same hottest-first / min-conflict / deterministic
    /// tie-break discipline as [`Self::greedy_placement`].
    pub fn greedy_placement_capped(&self, caps: &[usize]) -> ExpertPlacement {
        let n_shards = caps.len().max(1);
        let mut caps: Vec<usize> = caps.to_vec();
        caps.resize(n_shards, 0);
        if caps.iter().sum::<usize>() < self.n_experts {
            caps = vec![self.n_experts.div_ceil(n_shards); n_shards];
        }
        // Hottest-first order; ties by id for determinism.
        let mut order: Vec<usize> = (0..self.n_experts).collect();
        order.sort_by_key(|&e| (std::cmp::Reverse(self.acts[e]), e));

        let mut assign = vec![0usize; self.n_experts];
        let mut members: Vec<Vec<usize>> = vec![Vec::new(); n_shards];
        for &e in &order {
            let mut best: Option<(u64, usize, usize)> = None; // (conflict, size, shard)
            for (s, m) in members.iter().enumerate() {
                if m.len() >= caps[s] {
                    continue;
                }
                let conflict: u64 = m.iter().map(|&f| self.pair(e, f)).sum();
                let key = (conflict, m.len(), s);
                let better = match best {
                    None => true,
                    Some(b) => key < b,
                };
                if better {
                    best = Some(key);
                }
            }
            let (_, _, s) = best.expect("caps cover all experts");
            assign[e] = s;
            members[s].push(e);
        }
        ExpertPlacement::from_assign(assign, n_shards)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Per-layer id lists → per-layer bitmaps (test convenience).
    fn layers(ids: &[Vec<usize>]) -> Vec<ExpertBitmap> {
        ids.iter().map(|l| ExpertBitmap::from_ids(l)).collect()
    }

    #[test]
    fn balanced_round_robin_is_weight_balanced() {
        let p = ExpertPlacement::balanced(8, 4);
        assert_eq!(p.shard_sizes(), vec![2, 2, 2, 2]);
        assert_eq!(p.shard_of(5), 1);
        // Uneven division: sizes differ by at most one.
        let p = ExpertPlacement::balanced(10, 4);
        let sizes = p.shard_sizes();
        assert_eq!(sizes.iter().sum::<usize>(), 10);
        assert!(sizes.iter().max().unwrap() - sizes.iter().min().unwrap() <= 1);
    }

    #[test]
    fn shard_loads_partition_the_union() {
        let p = ExpertPlacement::balanced(8, 4);
        let raw = vec![vec![0, 1, 2, 5], vec![3, 7]];
        let ids = layers(&raw);
        let loads = p.shard_loads(&ids);
        assert_eq!(loads.len(), 2);
        for (l, ids_l) in loads.iter().zip(&raw) {
            assert_eq!(l.iter().sum::<usize>(), ids_l.len());
        }
        // layer0: shard1 holds {1,5}; layer1: shard3 holds {3,7}.
        assert_eq!(p.max_loads(&ids), vec![2, 2]);
        // The _into form matches and reuses its buffer.
        let mut scratch = vec![99; 7];
        p.max_loads_into(&ids, &mut scratch);
        assert_eq!(scratch, vec![2, 2]);
    }

    #[test]
    fn empty_histogram_placement_is_balanced_and_capped() {
        let stats = CoActivationStats::new(8);
        let p = stats.greedy_placement(4);
        assert_eq!(p.n_shards(), 4);
        assert_eq!(p.shard_sizes(), vec![2, 2, 2, 2]);
    }

    #[test]
    fn observe_counts_pairs_symmetrically() {
        let mut stats = CoActivationStats::new(4);
        stats.observe(&layers(&[vec![0, 2], vec![0, 2], vec![1, 3]]));
        assert_eq!(stats.steps(), 3);
        assert_eq!(stats.pair(0, 2), 2);
        assert_eq!(stats.pair(2, 0), 2);
        assert_eq!(stats.pair(1, 3), 1);
        assert_eq!(stats.pair(0, 1), 0);
        assert_eq!(stats.acts[0], 2);
    }

    #[test]
    fn packer_separates_coactivating_pairs() {
        // Adversarial pattern for round-robin at 4 shards over 8 experts:
        // the pairs (0,4), (1,5), (2,6), (3,7) always co-activate, and
        // e % 4 puts each pair on ONE shard (max load 2). The packer must
        // split every pair (max load 1) while keeping 2 experts per shard.
        let mut stats = CoActivationStats::new(8);
        let steps: Vec<ExpertBitmap> =
            (0..4).cycle().take(64).map(|g| ExpertBitmap::from_ids(&[g, g + 4])).collect();
        stats.observe(&steps);

        let balanced = ExpertPlacement::balanced(8, 4);
        let packed = stats.greedy_placement(4);
        assert_eq!(packed.shard_sizes(), vec![2; 4], "weight balance violated");
        let worst = |p: &ExpertPlacement| p.max_loads(&steps).iter().copied().max().unwrap();
        assert_eq!(worst(&balanced), 2);
        assert_eq!(worst(&packed), 1, "packer failed to separate co-activated pairs");
    }

    #[test]
    fn decay_lets_the_packer_track_a_phase_shift() {
        // Phase A: pairs (0,4),(1,5),(2,6),(3,7) co-activate. After a
        // rebuild + decay, an equally long phase B with the pairs rotated
        // — (0,5),(1,6),(2,7),(3,4) — must dominate the histogram, so the
        // next rebuild separates B's pairs.
        let mut stats = CoActivationStats::new(8);
        let phase = |rot: usize| -> Vec<ExpertBitmap> {
            (0..4)
                .cycle()
                .take(64)
                .map(|g| ExpertBitmap::from_ids(&[g, 4 + (g + rot) % 4]))
                .collect()
        };
        let a = phase(0);
        let b = phase(1);
        stats.observe(&a);
        stats.decay(); // what the engine does after a rebuild
        stats.observe(&b);
        stats.observe(&b); // recent phase outweighs the decayed old one
        let packed = stats.greedy_placement(4);
        let worst_b = packed.max_loads(&b).iter().copied().max().unwrap();
        assert_eq!(worst_b, 1, "placement still tuned to the old phase");
        // Halving really halves.
        let mut s = CoActivationStats::new(2);
        s.observe(&layers(&[vec![0, 1], vec![0, 1], vec![0]]));
        assert_eq!((s.acts[0], s.pair(0, 1), s.steps()), (3, 2, 3));
        s.decay();
        assert_eq!((s.acts[0], s.pair(0, 1), s.steps()), (1, 1, 1));
    }

    #[test]
    fn packer_is_deterministic() {
        let mut a = CoActivationStats::new(16);
        let mut b = CoActivationStats::new(16);
        let steps: Vec<ExpertBitmap> = (0..50)
            .map(|i| ExpertBitmap::from_ids(&[i % 16, (i * 7 + 3) % 16, (i * 5 + 1) % 16]))
            .collect();
        a.observe(&steps);
        b.observe(&steps);
        let pa = a.greedy_placement(4);
        let pb = b.greedy_placement(4);
        for e in 0..16 {
            assert_eq!(pa.shard_of(e), pb.shard_of(e));
        }
    }

    #[test]
    fn surviving_placement_rehosts_dead_shards_experts() {
        // Shard 1 of 4 dead: all 8 experts land on {0, 2, 3}, balanced.
        let p = ExpertPlacement::balanced_surviving(8, 4, &[false, true, false, false]);
        assert_eq!(p.n_shards(), 4, "topology width is preserved across the failure");
        let sizes = p.shard_sizes();
        assert_eq!(sizes[1], 0, "dead shard must hold no experts");
        assert_eq!(sizes.iter().sum::<usize>(), 8);
        assert!(sizes.iter().max().unwrap() - [sizes[0], sizes[2], sizes[3]].iter().min().unwrap() <= 1);
        // The survivors carry a worse critical path than the healthy map.
        let ids = vec![(0..8).collect::<ExpertBitmap>()];
        let healthy = ExpertPlacement::balanced(8, 4);
        assert!(p.max_loads(&ids)[0] > healthy.max_loads(&ids)[0]);
        // No dead shards (or an all-dead mask) degenerates to balanced.
        let none = ExpertPlacement::balanced_surviving(8, 4, &[false; 4]);
        let all = ExpertPlacement::balanced_surviving(8, 4, &[true; 4]);
        for e in 0..8 {
            assert_eq!(none.shard_of(e), healthy.shard_of(e));
            assert_eq!(all.shard_of(e), healthy.shard_of(e));
        }
        // A short mask treats unmentioned shards as alive.
        let short = ExpertPlacement::balanced_surviving(6, 3, &[true]);
        assert_eq!(short.shard_sizes(), vec![0, 3, 3]);
    }

    #[test]
    fn capacity_caps_track_relative_health() {
        // Healthy shards weight 1.0; a 4x straggler weighs 1/4 — it gets
        // at most ceil(8 * 0.25 / 2.25) = 1 expert of 8.
        let caps = capacity_caps(8, &[1.0, 0.25, 1.0]);
        assert!(caps.iter().sum::<usize>() >= 8, "caps must cover all experts");
        assert_eq!(caps[1], 1);
        assert!(caps[0] >= 3 && caps[2] >= 3);
        // Uniform weights reproduce the uniform cap.
        assert_eq!(capacity_caps(8, &[1.0; 4]), vec![2; 4]);
        // Degenerate weights: non-positive shards get nothing; an
        // all-degenerate vector falls back to uniform.
        assert_eq!(capacity_caps(6, &[1.0, 0.0, 1.0])[1], 0);
        assert_eq!(capacity_caps(6, &[0.0, f64::NAN]), vec![3, 3]);
    }

    #[test]
    fn capped_packer_respects_caps_and_generalizes_uniform() {
        let mut stats = CoActivationStats::new(8);
        let steps: Vec<ExpertBitmap> =
            (0..4).cycle().take(64).map(|g| ExpertBitmap::from_ids(&[g, g + 4])).collect();
        stats.observe(&steps);
        // Uniform caps == the plain packer.
        let uniform = stats.greedy_placement_capped(&vec![2; 4]);
        let plain = stats.greedy_placement(4);
        for e in 0..8 {
            assert_eq!(uniform.shard_of(e), plain.shard_of(e));
        }
        // A starved shard 1 (cap 0) holds nothing; survivors absorb all 8.
        let healed = stats.greedy_placement_capped(&[3, 0, 3, 3]);
        let sizes = healed.shard_sizes();
        assert_eq!(sizes[1], 0);
        assert_eq!(sizes.iter().sum::<usize>(), 8);
        assert!(sizes.iter().all(|&s| s <= 3));
        // Insufficient caps fall back to the uniform cap instead of
        // panicking.
        let fallback = stats.greedy_placement_capped(&[1, 0, 0, 0]);
        assert_eq!(fallback.shard_sizes().iter().sum::<usize>(), 8);
    }

    #[test]
    fn moved_from_counts_the_migration_volume() {
        let a = ExpertPlacement::balanced(8, 4);
        assert_eq!(a.moved_from(&a), 0);
        let b = ExpertPlacement::from_assign(vec![0, 1, 2, 3, 0, 1, 2, 0], 4);
        // balanced assigns e % 4 = [0,1,2,3,0,1,2,3]; only expert 7 moved.
        assert_eq!(b.moved_from(&a), 1);
        assert_eq!(a.moved_from(&b), 1, "migration volume is symmetric");
    }

    #[test]
    fn single_shard_placement_is_identity_load() {
        let p = ExpertPlacement::balanced(8, 1);
        let ids = layers(&[vec![0, 3, 7], vec![1]]);
        assert_eq!(p.max_loads(&ids), vec![3, 1]);
        assert_eq!(p.shard_sizes(), vec![8]);
    }
}
