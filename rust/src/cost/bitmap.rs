//! Fixed-size expert-set bitmaps for the per-iteration hot path.
//!
//! Every expert-set operation the engine performs per iteration — routing
//! dedup, cross-request union, marginal/shared attribution, per-shard load
//! counting — is a set operation over expert ids drawn from `[0, E)` where
//! `E` is tiny (the model zoo tops out at 64 experts/layer; Table 1 of the
//! paper). A `BTreeSet<usize>` pays an allocation and pointer-chasing tax
//! per element; a fixed `[u64; 4]` word array answers the same queries with
//! a handful of OR/AND/POPCNT instructions and lives happily on the stack
//! or inside a reusable arena. Iteration order is ascending expert id, so
//! anything that used to consume a `BTreeSet`'s sorted order is unchanged.
//!
//! See rust/docs/perf.md for the layout and the ownership rules of the
//! structures that embed these bitmaps.

/// Hard cap on experts per layer representable by [`ExpertBitmap`].
/// `256 = 4 x 64` covers every model in the zoo (max 64) with headroom;
/// inserting an id `>= MAX_EXPERTS` panics in debug and is masked off in
/// release via the debug assertion contract below.
pub const MAX_EXPERTS: usize = 256;

const WORDS: usize = MAX_EXPERTS / 64;

/// A set of expert ids in `[0, MAX_EXPERTS)` as a fixed word array.
///
/// `Copy` and allocation-free: 32 bytes, so cloning one per layer per
/// iteration is a register move, not a heap round-trip.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct ExpertBitmap {
    words: [u64; WORDS],
}

impl ExpertBitmap {
    /// The empty set.
    pub const fn new() -> Self {
        Self { words: [0; WORDS] }
    }

    /// Build from a slice of expert ids (duplicates collapse, any order).
    pub fn from_ids(ids: &[usize]) -> Self {
        let mut b = Self::new();
        for &id in ids {
            b.insert(id);
        }
        b
    }

    /// Insert `id`; returns true when the id was not already present.
    #[inline]
    pub fn insert(&mut self, id: usize) -> bool {
        debug_assert!(id < MAX_EXPERTS, "expert id {id} exceeds bitmap capacity");
        let w = id / 64;
        let bit = 1u64 << (id % 64);
        let fresh = self.words[w] & bit == 0;
        self.words[w] |= bit;
        fresh
    }

    /// Membership test.
    #[inline]
    pub fn contains(&self, id: usize) -> bool {
        debug_assert!(id < MAX_EXPERTS, "expert id {id} exceeds bitmap capacity");
        self.words[id / 64] & (1u64 << (id % 64)) != 0
    }

    /// Number of ids present (popcount over the words).
    #[inline]
    pub fn count(&self) -> usize {
        self.words.iter().map(|w| w.count_ones() as usize).sum()
    }

    /// True when no id is present.
    #[inline]
    pub fn is_empty(&self) -> bool {
        self.words.iter().all(|&w| w == 0)
    }

    /// Remove every id (the arena-reuse reset).
    #[inline]
    pub fn clear(&mut self) {
        self.words = [0; WORDS];
    }

    /// `self |= other` — the cross-request union accumulator.
    #[inline]
    pub fn union_with(&mut self, other: &Self) {
        for (a, b) in self.words.iter_mut().zip(other.words.iter()) {
            *a |= *b;
        }
    }

    /// `self &= other`.
    #[inline]
    pub fn intersect_with(&mut self, other: &Self) {
        for (a, b) in self.words.iter_mut().zip(other.words.iter()) {
            *a &= *b;
        }
    }

    /// `self & other` without mutation.
    #[inline]
    pub fn and(&self, other: &Self) -> Self {
        let mut out = *self;
        out.intersect_with(other);
        out
    }

    /// `self & !other` — the marginal-attribution kernel (ids of `self`
    /// not claimed by `other`).
    #[inline]
    pub fn and_not(&self, other: &Self) -> Self {
        let mut out = Self::new();
        for ((o, a), b) in out.words.iter_mut().zip(self.words.iter()).zip(other.words.iter()) {
            *o = *a & !*b;
        }
        out
    }

    /// `|self & other|` without materialising the intersection — the
    /// per-shard load count (`placement mask & activated set`).
    #[inline]
    pub fn count_and(&self, other: &Self) -> usize {
        self.words
            .iter()
            .zip(other.words.iter())
            .map(|(a, b)| (a & b).count_ones() as usize)
            .sum()
    }

    /// Ascending iteration over the ids present — identical order to the
    /// sorted iteration of the `BTreeSet<usize>` these bitmaps replaced.
    pub fn iter(&self) -> BitmapIter<'_> {
        BitmapIter { words: &self.words, word: 0, rest: self.words[0] }
    }

    /// Collect the ids into a fresh `Vec` (cold paths / tests).
    pub fn to_ids(&self) -> Vec<usize> {
        self.iter().collect()
    }

    /// Append the ids (ascending) into `out` without allocating here.
    pub fn fill(&self, out: &mut Vec<usize>) {
        out.extend(self.iter());
    }
}

impl FromIterator<usize> for ExpertBitmap {
    fn from_iter<T: IntoIterator<Item = usize>>(iter: T) -> Self {
        let mut b = Self::new();
        for id in iter {
            b.insert(id);
        }
        b
    }
}

/// Ascending-id iterator over an [`ExpertBitmap`].
pub struct BitmapIter<'a> {
    words: &'a [u64; WORDS],
    word: usize,
    rest: u64,
}

impl Iterator for BitmapIter<'_> {
    type Item = usize;

    #[inline]
    fn next(&mut self) -> Option<usize> {
        while self.rest == 0 {
            self.word += 1;
            if self.word >= WORDS {
                return None;
            }
            self.rest = self.words[self.word];
        }
        let bit = self.rest.trailing_zeros() as usize;
        self.rest &= self.rest - 1;
        Some(self.word * 64 + bit)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;
    use std::collections::BTreeSet;

    fn random_ids(rng: &mut Rng, n: usize, universe: usize) -> Vec<usize> {
        (0..n).map(|_| rng.below(universe)).collect()
    }

    #[test]
    fn insert_contains_count() {
        let mut b = ExpertBitmap::new();
        assert!(b.is_empty());
        assert!(b.insert(3));
        assert!(!b.insert(3));
        assert!(b.insert(64));
        assert!(b.insert(255));
        assert!(b.contains(3) && b.contains(64) && b.contains(255));
        assert!(!b.contains(4));
        assert_eq!(b.count(), 3);
        b.clear();
        assert!(b.is_empty());
    }

    #[test]
    fn iter_is_ascending_and_matches_btreeset() {
        let mut rng = Rng::new(0xB17);
        for universe in [8, 64, 100, 256] {
            for n in [0, 1, 5, 40, 300] {
                let ids = random_ids(&mut rng, n, universe);
                let reference: BTreeSet<usize> = ids.iter().copied().collect();
                let bitmap = ExpertBitmap::from_ids(&ids);
                let got: Vec<usize> = bitmap.iter().collect();
                let want: Vec<usize> = reference.iter().copied().collect();
                assert_eq!(got, want, "universe {universe} n {n}");
                assert_eq!(bitmap.count(), reference.len());
                assert_eq!(bitmap.to_ids(), want);
            }
        }
    }

    #[test]
    fn union_intersection_difference_match_btreeset() {
        let mut rng = Rng::new(0xB18);
        for _ in 0..200 {
            let xs = random_ids(&mut rng, rng.below(40), 200);
            let ys = random_ids(&mut rng, rng.below(40), 200);
            let sx: BTreeSet<usize> = xs.iter().copied().collect();
            let sy: BTreeSet<usize> = ys.iter().copied().collect();
            let bx = ExpertBitmap::from_ids(&xs);
            let by = ExpertBitmap::from_ids(&ys);

            let mut u = bx;
            u.union_with(&by);
            let su: Vec<usize> = sx.union(&sy).copied().collect();
            assert_eq!(u.to_ids(), su);

            let si: Vec<usize> = sx.intersection(&sy).copied().collect();
            assert_eq!(bx.and(&by).to_ids(), si);
            assert_eq!(bx.count_and(&by), si.len());

            let sd: Vec<usize> = sx.difference(&sy).copied().collect();
            assert_eq!(bx.and_not(&by).to_ids(), sd);
        }
    }

    #[test]
    fn fill_appends_without_clearing() {
        let b = ExpertBitmap::from_ids(&[9, 2, 9, 70]);
        let mut out = vec![42];
        b.fill(&mut out);
        assert_eq!(out, vec![42, 2, 9, 70]);
    }

    #[test]
    fn from_iterator_collects() {
        let b: ExpertBitmap = [5usize, 1, 5, 63].into_iter().collect();
        assert_eq!(b.to_ids(), vec![1, 5, 63]);
    }
}
