//! Hardware parameters of the simulated GPU (RTX 6000 Ada, the paper's
//! testbed) plus CPU-side overheads.
//!
//! Calibration (see EXPERIMENTS.md §Calibration): `bw_efficiency` and
//! `iter_overhead_s` are jointly fit so the analytic no-speculation
//! baselines reproduce the iteration times the paper reports in §6 —
//! Mixtral ≈ 28 ms and OLMoE ≈ 6 ms. All other models are *derived*, not
//! fit.

/// Simulated-hardware parameters.
#[derive(Debug, Clone, Copy)]
pub struct HwParams {
    /// Peak HBM bandwidth in bytes/s (RTX 6000 Ada: 960 GB/s).
    pub hbm_bytes_per_s: f64,
    /// Achieved fraction of peak bandwidth for weight streaming.
    pub bw_efficiency: f64,
    /// Fixed per-iteration overhead (kernel launches, framework).
    pub iter_overhead_s: f64,
    /// N-gram drafting cost per iteration (CPU context scan).
    pub ngram_draft_s: f64,
    /// Draft-model bytes moved per drafted token (EAGLE-lite, ~0.33B FP16).
    pub eagle_draft_bytes: f64,
    /// Rejection-sampling fixed cost when speculation is on.
    pub reject_fixed_s: f64,
    /// Rejection-sampling cost per draft token.
    pub reject_per_token_s: f64,
    /// Expert-parallel all-to-all (dispatch + combine) fixed latency per
    /// MoE layer when experts are sharded across devices (NVLink-class
    /// interconnect, small-message regime). Charged only at shards > 1.
    pub alltoall_layer_s: f64,
    /// Additional all-to-all cost per in-flight token per MoE layer
    /// (activation bytes crossing the interconnect).
    pub alltoall_token_s: f64,
    /// Effective inter-device link bandwidth for bulk expert-weight
    /// movement (bytes/s) — what a self-healing placement rebuild pays to
    /// relocate an expert (`IterCost::migration_s`). NVLink-class peer
    /// copy, well below HBM streaming bandwidth.
    pub migrate_bytes_per_s: f64,
}

impl Default for HwParams {
    fn default() -> Self {
        Self {
            hbm_bytes_per_s: 960e9,
            bw_efficiency: 0.53,
            iter_overhead_s: 3.6e-3,
            ngram_draft_s: 0.25e-3,
            eagle_draft_bytes: 0.66e9, // 0.33B params * FP16
            reject_fixed_s: 0.10e-3,
            reject_per_token_s: 0.06e-3,
            alltoall_layer_s: 8e-6,
            alltoall_token_s: 0.2e-6,
            migrate_bytes_per_s: 250e9,
        }
    }
}

impl HwParams {
    /// Effective achievable bandwidth (bytes/s).
    pub fn eff_bw(&self) -> f64 {
        self.hbm_bytes_per_s * self.bw_efficiency
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_sane() {
        let hw = HwParams::default();
        assert!(hw.eff_bw() > 400e9 && hw.eff_bw() < 960e9);
        assert!(hw.iter_overhead_s < 0.01);
        // Per-layer all-to-all must stay far below a per-layer expert fetch
        // or sharding could never win.
        assert!(hw.alltoall_layer_s > 0.0 && hw.alltoall_layer_s < 1e-4);
        assert!(hw.alltoall_token_s > 0.0 && hw.alltoall_token_s < hw.alltoall_layer_s);
        // Migration moves weights over the interconnect: slower than HBM
        // streaming (or migrating would beat fetching) but nonzero.
        assert!(hw.migrate_bytes_per_s > 0.0 && hw.migrate_bytes_per_s < hw.eff_bw());
    }
}
