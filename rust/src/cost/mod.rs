//! GPU memory-traffic cost model.
//!
//! The paper's phenomenon is *"MoE verification time scales with the number
//! of unique experts activated by the in-flight tokens"* (§2.4). We keep
//! that causal chain intact: the real router (executed HLO) produces expert
//! activations; this module converts them into bytes moved at **paper
//! scale** (Table 1 parameter counts) over RTX-6000-Ada-class bandwidth,
//! yielding a simulated iteration time. Calibrated against the baseline
//! iteration times the paper reports in §6: ≈6 ms (OLMoE) … ≈28 ms
//! (Mixtral). See DESIGN.md §Substitutions.

pub mod bitmap;
mod hw;
mod placement;

pub use bitmap::ExpertBitmap;
pub use hw::HwParams;
pub use placement::{capacity_caps, CoActivationStats, ExpertPlacement};

use crate::config::DrafterKind;
use crate::models::PaperScaleSpec;

/// Per-iteration cost breakdown (seconds, simulated GPU clock). The
/// components mirror the paper's Fig. 4 iteration-time breakdown.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct IterCost {
    /// Always-fetched weights: attention, embeddings, router, shared experts.
    pub base_s: f64,
    /// Routed-expert fetch — the part that grows with speculation length.
    pub expert_s: f64,
    /// Drafting (n-gram CPU scan or draft-model execution).
    pub draft_s: f64,
    /// Rejection sampling.
    pub reject_s: f64,
    /// Fixed kernel-launch / framework overhead.
    pub overhead_s: f64,
    /// Portion of `draft_s` hidden by pipelined execution: with the
    /// two-stage pipeline, draft(i+1) runs on the CPU while the target
    /// model verifies iteration i, so drafting only costs wall time where
    /// it exceeds the concurrent verify window (`max(draft, verify)`
    /// semantics). Always 0 in serial mode; never exceeds `draft_s`.
    pub draft_hidden_s: f64,
    /// Expert-parallel all-to-all latency (token dispatch + combine across
    /// shards). Always 0 at shards = 1 — single-GPU runs are bit-identical
    /// to the unsharded cost model.
    pub alltoall_s: f64,
    /// Re-prefill time charged to this iteration: chunked full-parallel
    /// recompute of the committed context of requests re-admitted after a
    /// KV-pool eviction (rust/docs/preemption.md). Unlike admission prefill
    /// (excluded from TPOT as the paper's decode-latency focus dictates),
    /// re-prefill is *caused by* decode-time pool pressure, so it is billed
    /// on the decode clock — TPOT and utility honestly reflect the thrash.
    /// Always 0 with `eviction = off`.
    pub reprefill_s: f64,
    /// Transient-stall retry time charged to this iteration: when a fault
    /// plan injects a backend stall (rust/docs/faults.md), the failed step
    /// is retried with exponential backoff and every wasted attempt — the
    /// lost verify windows plus the backoff sleeps — is billed here. Like
    /// `reprefill_s` it extends the decode clock (TPOT sees the outage
    /// honestly) without polluting the verify term the utility signal
    /// prices speculation against. Always 0 with `--faults off`.
    pub stall_s: f64,
    /// Expert-migration time charged to this iteration: when the straggler
    /// detector triggers a self-healing placement rebuild
    /// (rust/docs/faults.md), the experts that changed shard must move over
    /// the inter-device link. Like `reprefill_s` it extends the decode
    /// clock without entering the verify term. With the pipeline on, the
    /// transfer overlaps the draft window SP-MoE-style, so only the slice
    /// exceeding that window is charged (the engine pre-subtracts it);
    /// serial mode pays the full transfer. Always 0 with detection off.
    pub migration_s: f64,
}

impl IterCost {
    /// Effective iteration time on the simulated clock. Drafting is charged
    /// only for its *exposed* part — the overlap-aware accounting of the
    /// pipelined serving path (serial runs have `draft_hidden_s == 0`, so
    /// this stays the plain component sum).
    pub fn total(&self) -> f64 {
        self.base_s
            + self.expert_s
            + self.exposed_draft_s()
            + self.reject_s
            + self.overhead_s
            + self.alltoall_s
            + self.reprefill_s
            + self.stall_s
            + self.migration_s
    }

    /// Drafting time that actually extends the iteration (not hidden under
    /// the previous iteration's verify window).
    pub fn exposed_draft_s(&self) -> f64 {
        (self.draft_s - self.draft_hidden_s).max(0.0)
    }

    /// Verification-only time (what the target model spends, including the
    /// expert-parallel all-to-all when sharded).
    pub fn verify_s(&self) -> f64 {
        self.base_s + self.expert_s + self.overhead_s + self.alltoall_s
    }
}

/// Cost model for one paper-scale model on one GPU.
#[derive(Debug, Clone)]
pub struct GpuCostModel {
    pub spec: PaperScaleSpec,
    pub hw: HwParams,
    /// Layer count of the mini model producing the activation measurements.
    pub mini_layers: usize,
}

impl GpuCostModel {
    pub fn new(spec: PaperScaleSpec, mini_layers: usize) -> Self {
        Self { spec, hw: HwParams::default(), mini_layers }
    }

    /// Cost of a verification step over `t` in-flight tokens, given the
    /// measured unique-expert counts per *mini* layer. The mini model's
    /// per-layer statistics are extrapolated to the paper-scale layer count
    /// (routing statistics are per-layer i.i.d. in expectation).
    pub fn verify_cost(
        &self,
        unique_experts_per_mini_layer: &[usize],
        t: usize,
        drafted: usize,
        drafter: DrafterKind,
    ) -> IterCost {
        let expert_s = if self.spec.is_moe() {
            let mean_unique = if unique_experts_per_mini_layer.is_empty() {
                self.spec.top_k as f64 // analytic fallback: T=1 activates top_k
            } else {
                unique_experts_per_mini_layer.iter().sum::<usize>() as f64
                    / unique_experts_per_mini_layer.len() as f64
            };
            // Physical bound: can't activate more experts than exist, nor
            // more than t·top_k.
            let cap = (self.spec.n_experts as f64).min(t as f64 * self.spec.top_k as f64);
            let unique = mean_unique.min(cap).max(0.0);
            self.spec.layers as f64 * unique * self.spec.expert_bytes() / self.hw.eff_bw()
        } else {
            0.0
        };
        IterCost {
            base_s: self.spec.base_bytes() / self.hw.eff_bw(),
            expert_s,
            draft_s: self.draft_cost(drafted, drafter),
            reject_s: if drafted > 0 {
                self.hw.reject_fixed_s + self.hw.reject_per_token_s * drafted as f64
            } else {
                0.0
            },
            overhead_s: self.hw.iter_overhead_s,
            draft_hidden_s: 0.0,
            alltoall_s: 0.0,
            reprefill_s: 0.0,
            stall_s: 0.0,
            migration_s: 0.0,
        }
    }

    /// Cost of one *fused* verification step over a batch of requests
    /// (continuous batching). Base weights — attention, embeddings, router,
    /// shared experts — are fetched once per iteration regardless of batch
    /// size, and routed experts are charged for the unique set activated
    /// across *all* in-flight tokens of *all* requests: the cross-request
    /// de-duplication that makes batched MoE verification sub-linear in
    /// batch size (the paper's §2.4 mechanism at serving scale).
    ///
    /// `batch_unique_per_mini_layer` is the per-layer unique-expert count
    /// de-duplicated across the whole batch; `total_tokens` / `total_drafted`
    /// sum over requests; `drafting_requests` counts requests that actually
    /// drafted this iteration (the n-gram scan is a per-request CPU cost).
    /// With one request this reduces exactly to [`Self::verify_cost`].
    pub fn batch_verify_cost(
        &self,
        batch_unique_per_mini_layer: &[usize],
        total_tokens: usize,
        total_drafted: usize,
        drafting_requests: usize,
        drafter: DrafterKind,
    ) -> IterCost {
        let expert_s = if self.spec.is_moe() {
            let mean_unique = if batch_unique_per_mini_layer.is_empty() {
                self.spec.top_k as f64
            } else {
                batch_unique_per_mini_layer.iter().sum::<usize>() as f64
                    / batch_unique_per_mini_layer.len() as f64
            };
            let cap = (self.spec.n_experts as f64)
                .min(total_tokens as f64 * self.spec.top_k as f64);
            let unique = mean_unique.min(cap).max(0.0);
            self.spec.layers as f64 * unique * self.spec.expert_bytes() / self.hw.eff_bw()
        } else {
            0.0
        };
        let draft_s = self.draft_cost_batch(total_drafted, drafting_requests, drafter);
        IterCost {
            base_s: self.spec.base_bytes() / self.hw.eff_bw(),
            expert_s,
            draft_s,
            reject_s: if total_drafted > 0 {
                self.hw.reject_fixed_s + self.hw.reject_per_token_s * total_drafted as f64
            } else {
                0.0
            },
            overhead_s: self.hw.iter_overhead_s,
            draft_hidden_s: 0.0,
            alltoall_s: 0.0,
            reprefill_s: 0.0,
            stall_s: 0.0,
            migration_s: 0.0,
        }
    }

    /// Per-step all-to-all latency of an expert-parallel fused step:
    /// dispatch + combine per MoE layer, plus a per-token activation term.
    /// Zero at `n_shards <= 1` and for dense models.
    pub fn alltoall_s(&self, n_shards: usize, total_tokens: usize) -> f64 {
        if n_shards <= 1 || !self.spec.is_moe() {
            return 0.0;
        }
        self.spec.layers as f64
            * (self.hw.alltoall_layer_s + total_tokens as f64 * self.hw.alltoall_token_s)
    }

    /// Expert-parallel variant of [`Self::batch_verify_cost`]: the expert
    /// set is sharded across `n_shards` devices, each shard fetches only
    /// its resident experts, and per layer the shards run **in parallel**
    /// — so the expert-movement term is priced at the per-layer **max over
    /// per-shard deduped loads** (`shard_max_per_mini_layer`, from
    /// [`ExpertPlacement::max_loads`] over the backend's id attribution),
    /// plus the per-step all-to-all that routes tokens between shards.
    ///
    /// With `n_shards == 1` this delegates to `batch_verify_cost` and is
    /// bit-exact with the single-GPU model (property-tested). Base weights
    /// (attention/embeddings/router/shared experts) are replicated across
    /// shards, so `base_s` is unchanged.
    pub fn sharded_batch_verify_cost(
        &self,
        shard_max_per_mini_layer: &[usize],
        n_shards: usize,
        total_tokens: usize,
        total_drafted: usize,
        drafting_requests: usize,
        drafter: DrafterKind,
    ) -> IterCost {
        if n_shards <= 1 {
            return self.batch_verify_cost(
                shard_max_per_mini_layer,
                total_tokens,
                total_drafted,
                drafting_requests,
                drafter,
            );
        }
        let expert_s = if self.spec.is_moe() {
            let mean_max = if shard_max_per_mini_layer.is_empty() {
                // Analytic fallback: top_k experts spread over the shards.
                (self.spec.top_k as f64 / n_shards as f64).ceil()
            } else {
                shard_max_per_mini_layer.iter().sum::<usize>() as f64
                    / shard_max_per_mini_layer.len() as f64
            };
            // A shard cannot fetch more experts than it holds, nor more
            // than the batch's tokens can activate.
            let cap = (self.spec.n_experts.div_ceil(n_shards) as f64)
                .min(total_tokens as f64 * self.spec.top_k as f64);
            let unique = mean_max.min(cap).max(0.0);
            self.spec.layers as f64 * unique * self.spec.expert_bytes() / self.hw.eff_bw()
        } else {
            0.0
        };
        IterCost {
            base_s: self.spec.base_bytes() / self.hw.eff_bw(),
            expert_s,
            draft_s: self.draft_cost_batch(total_drafted, drafting_requests, drafter),
            reject_s: if total_drafted > 0 {
                self.hw.reject_fixed_s + self.hw.reject_per_token_s * total_drafted as f64
            } else {
                0.0
            },
            overhead_s: self.hw.iter_overhead_s,
            draft_hidden_s: 0.0,
            alltoall_s: self.alltoall_s(n_shards, total_tokens),
            reprefill_s: 0.0,
            stall_s: 0.0,
            migration_s: 0.0,
        }
    }

    /// Straggler-degraded variant of [`Self::sharded_batch_verify_cost`]
    /// for fault injection (rust/docs/faults.md): a straggling shard runs
    /// its per-layer expert fetch `factor`× slower, so the per-layer
    /// critical path is `max_s(load[l][s] × scale[s])` — a *time* scale,
    /// not extra experts. The caller therefore pre-applies the capacity and
    /// activation caps to the raw per-shard loads **before** scaling and
    /// passes the effective per-layer maxima as `f64`; no cap is re-applied
    /// here (clipping a slowdown at the shard's expert capacity would
    /// silently erase the fault). Dense models have no expert term to
    /// degrade. Only called while a straggler window is active, so the
    /// fault-free path is bit-exact by construction.
    pub fn degraded_sharded_batch_verify_cost(
        &self,
        effective_max_per_mini_layer: &[f64],
        n_shards: usize,
        total_tokens: usize,
        total_drafted: usize,
        drafting_requests: usize,
        drafter: DrafterKind,
    ) -> IterCost {
        let expert_s = if self.spec.is_moe() && !effective_max_per_mini_layer.is_empty() {
            let mean_max = effective_max_per_mini_layer.iter().sum::<f64>()
                / effective_max_per_mini_layer.len() as f64;
            self.spec.layers as f64 * mean_max.max(0.0) * self.spec.expert_bytes()
                / self.hw.eff_bw()
        } else {
            0.0
        };
        IterCost {
            base_s: self.spec.base_bytes() / self.hw.eff_bw(),
            expert_s,
            draft_s: self.draft_cost_batch(total_drafted, drafting_requests, drafter),
            reject_s: if total_drafted > 0 {
                self.hw.reject_fixed_s + self.hw.reject_per_token_s * total_drafted as f64
            } else {
                0.0
            },
            overhead_s: self.hw.iter_overhead_s,
            draft_hidden_s: 0.0,
            alltoall_s: self.alltoall_s(n_shards, total_tokens),
            reprefill_s: 0.0,
            stall_s: 0.0,
            migration_s: 0.0,
        }
    }

    /// Aggregate drafting cost of a (sub)set of a batch's requests:
    /// `drafting_requests` of them ran the per-request n-gram CPU scan, or
    /// together they proposed `drafted_tokens` draft-model tokens. Used for
    /// the fused charge and, by the pipelined engine, to price the slice of
    /// drafting that ran hidden under the previous verify window.
    pub fn draft_cost_batch(
        &self,
        drafted_tokens: usize,
        drafting_requests: usize,
        drafter: DrafterKind,
    ) -> f64 {
        match drafter {
            DrafterKind::Ngram => drafting_requests as f64 * self.hw.ngram_draft_s,
            DrafterKind::EagleLite => {
                drafted_tokens as f64 * self.hw.eagle_draft_bytes / self.hw.eff_bw()
            }
        }
    }

    /// One request's **marginal** share of a fused batched iteration — the
    /// utility signal the batched Cascade policy observes (ROADMAP "batched
    /// Cascade policy study"). Charging every request the whole fused cost
    /// biases utility below 1 as the batch grows (the request is billed for
    /// its neighbours' experts), making Cascade disable speculation exactly
    /// where batching made it cheap. Instead:
    ///
    /// * base weights + fixed overhead are **amortized** over the
    ///   `n_active` requests that shared the fused step;
    /// * routed experts are charged at the request's **marginal**
    ///   contribution — the experts *only* its tokens activated
    ///   (`marginal_unique_per_mini_layer`, from the backend's fused
    ///   routing attribution) — **plus a fairness floor**: a `1/n_active`
    ///   amortized share of the batch's *shared* expert mass
    ///   (`shared_unique_per_mini_layer`, experts ≥ 2 requests activated).
    ///   Without the floor a free-riding request whose experts are all
    ///   shared observed near-zero cost (the ROADMAP fairness follow-on);
    ///   with it, unsharded per-request expert charges sum to the fused
    ///   expert total (every exclusive expert billed once, every shared
    ///   expert split `1/n` ways — under sharding the max-over-shards
    ///   slices make the sum an overshooting critical-path view instead).
    ///   Pass an empty `shared` slice to disable the floor (no attribution
    ///   available);
    /// * under expert-parallel sharding both slices carry per-layer
    ///   **max-over-shards** counts (the request's critical-path
    ///   contribution), so utility sees the same max-over-shards law as
    ///   the fused charge;
    /// * drafting and rejection are the request's own.
    ///
    /// With `n_active == 1` the marginal set is the request's full unique
    /// set, the shared mass is empty, and this reduces exactly to
    /// [`Self::verify_cost`]. (The expert-parallel all-to-all is a batch
    /// term; the engine amortizes it onto requests separately.)
    pub fn marginal_request_cost(
        &self,
        marginal_unique_per_mini_layer: &[usize],
        shared_unique_per_mini_layer: &[usize],
        n_active: usize,
        tokens: usize,
        drafted: usize,
        drafter: DrafterKind,
    ) -> IterCost {
        let n = n_active.max(1) as f64;
        let expert_s = if self.spec.is_moe() {
            let mean_marginal = if marginal_unique_per_mini_layer.is_empty() {
                // Analytic fallback (no routing attribution): a lone token
                // activates top_k; at batch > 1 assume full overlap decay.
                self.spec.top_k as f64 / n
            } else {
                marginal_unique_per_mini_layer.iter().sum::<usize>() as f64
                    / marginal_unique_per_mini_layer.len() as f64
            };
            let mean_shared = if shared_unique_per_mini_layer.is_empty() {
                0.0
            } else {
                shared_unique_per_mini_layer.iter().sum::<usize>() as f64
                    / shared_unique_per_mini_layer.len() as f64
            };
            // The activation cap bounds what the request's OWN tokens can
            // touch; the amortized shared slice is a share of neighbours'
            // real fetches and must not be clipped by it (clipping would
            // undercharge exactly the short-span free-riders the floor
            // targets, and break the sum-to-fused partition).
            let cap = (self.spec.n_experts as f64).min(tokens as f64 * self.spec.top_k as f64);
            let unique = (mean_marginal.min(cap) + mean_shared / n).max(0.0);
            self.spec.layers as f64 * unique * self.spec.expert_bytes() / self.hw.eff_bw()
        } else {
            0.0
        };
        IterCost {
            base_s: self.spec.base_bytes() / self.hw.eff_bw() / n,
            expert_s,
            draft_s: self.draft_cost(drafted, drafter),
            reject_s: if drafted > 0 {
                self.hw.reject_fixed_s + self.hw.reject_per_token_s * drafted as f64
            } else {
                0.0
            },
            overhead_s: self.hw.iter_overhead_s / n,
            draft_hidden_s: 0.0,
            alltoall_s: 0.0,
            reprefill_s: 0.0,
            stall_s: 0.0,
            migration_s: 0.0,
        }
    }

    /// Drafting cost for `k` proposed tokens.
    pub fn draft_cost(&self, k: usize, drafter: DrafterKind) -> f64 {
        if k == 0 {
            return 0.0;
        }
        match drafter {
            // Prompt-lookup n-gram: a CPU context scan, independent of model
            // size (paper Fig. 4: 1–2% of a MoE iteration).
            DrafterKind::Ngram => self.hw.ngram_draft_s,
            // Draft-model speculation: K sequential forward passes of the
            // ~0.33B drafter (paper §7.3: ≈5% of a Mixtral baseline
            // iteration per unit K).
            DrafterKind::EagleLite => k as f64 * self.hw.eagle_draft_bytes / self.hw.eff_bw(),
        }
    }

    /// Transfer time for moving `experts_moved` routed experts to a new
    /// shard (self-healing placement, rust/docs/faults.md). An expert's
    /// weights exist in every MoE layer, so the bill is
    /// `layers · moved · expert_bytes / migrate_bw` — the inter-device
    /// link, not HBM, is the bottleneck. Zero moves are free, and dense
    /// models have no routed experts to migrate.
    pub fn migration_s(&self, experts_moved: usize) -> f64 {
        if experts_moved == 0 || !self.spec.is_moe() {
            return 0.0;
        }
        self.spec.layers as f64 * experts_moved as f64 * self.spec.expert_bytes()
            / self.hw.migrate_bytes_per_s
    }

    /// Analytic no-speculation baseline (K=0, T=1): exactly `top_k` experts
    /// per layer are fetched, by construction of top-k routing.
    pub fn baseline_cost(&self) -> IterCost {
        let unique = vec![self.spec.top_k; self.mini_layers.max(1)];
        self.verify_cost(&unique, 1, 0, DrafterKind::Ngram)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::paper_spec;

    fn model(name: &str) -> GpuCostModel {
        GpuCostModel::new(paper_spec(name).unwrap(), 2)
    }

    #[test]
    fn mixtral_baseline_matches_section6() {
        // Paper §6: a Mixtral iteration is ~28 ms on the RTX 6000 Ada.
        let t = model("mixtral").baseline_cost().total();
        assert!((0.024..0.032).contains(&t), "mixtral baseline {t}");
    }

    #[test]
    fn olmoe_baseline_matches_section6() {
        // Paper §6: an OLMoE iteration is ~6 ms.
        let t = model("olmoe").baseline_cost().total();
        assert!((0.004..0.008).contains(&t), "olmoe baseline {t}");
    }

    #[test]
    fn more_unique_experts_cost_more() {
        let m = model("mixtral");
        let lo = m.verify_cost(&[2, 2], 1, 0, DrafterKind::Ngram).total();
        let hi = m.verify_cost(&[6, 6], 4, 3, DrafterKind::Ngram).total();
        assert!(hi > lo * 1.5, "lo={lo} hi={hi}");
    }

    #[test]
    fn verification_overhead_2_to_3x_at_k7() {
        // Paper abstract: draft tokens increase verification time 2–3x.
        // At K=7 (8 tokens) with low affinity, Mixtral activates ~7/8 experts.
        let m = model("mixtral");
        let base = m.baseline_cost().verify_s();
        let spec = m.verify_cost(&[7, 7], 8, 7, DrafterKind::Ngram).verify_s();
        let ratio = spec / base;
        assert!((1.8..3.5).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    fn dense_cost_flat_in_tokens() {
        let m = model("llama");
        let a = m.verify_cost(&[0, 0], 1, 0, DrafterKind::Ngram).verify_s();
        let b = m.verify_cost(&[0, 0], 8, 7, DrafterKind::Ngram).verify_s();
        assert!((a - b).abs() < 1e-9);
    }

    #[test]
    fn dense_spec_overhead_small() {
        // Paper Fig. 4: dense speculation adds only a few % (draft+reject).
        let m = model("llama");
        let base = m.verify_cost(&[], 1, 0, DrafterKind::Ngram).total();
        let spec = m.verify_cost(&[], 8, 7, DrafterKind::Ngram).total();
        let overhead = spec / base - 1.0;
        assert!(overhead < 0.12, "dense overhead {overhead}");
    }

    #[test]
    fn unique_capped_by_expert_count() {
        let m = model("mixtral"); // 8 experts
        let a = m.verify_cost(&[200, 200], 8, 7, DrafterKind::Ngram).expert_s;
        let b = m.verify_cost(&[8, 8], 8, 7, DrafterKind::Ngram).expert_s;
        assert!((a - b).abs() < 1e-12);
    }

    #[test]
    fn eagle_draft_about_5pct_per_k_of_mixtral() {
        let m = model("mixtral");
        let base = m.baseline_cost().total();
        let per_k = m.draft_cost(1, DrafterKind::EagleLite);
        let frac = per_k / base;
        assert!((0.02..0.08).contains(&frac), "eagle draft frac {frac}");
    }

    #[test]
    fn breakdown_sums_to_total() {
        let m = model("phi");
        let c = m.verify_cost(&[4, 5], 4, 3, DrafterKind::Ngram);
        let sum = c.base_s + c.expert_s + c.draft_s + c.reject_s + c.overhead_s + c.alltoall_s;
        assert!((sum - c.total()).abs() < 1e-15);
        // The all-to-all term is part of both total() and verify_s().
        let sharded = IterCost { alltoall_s: 1e-3, ..c };
        assert!((sharded.total() - (c.total() + 1e-3)).abs() < 1e-15);
        assert!((sharded.verify_s() - (c.verify_s() + 1e-3)).abs() < 1e-15);
    }

    #[test]
    fn batch_of_one_equals_single_request_cost() {
        // With a single in-flight request, the fused-batch charge must be
        // identical to the per-request charge, for both drafters.
        let m = model("mixtral");
        for (unique, t, drafted) in [(vec![4, 5], 4usize, 3usize), (vec![2, 2], 1, 0)] {
            for drafter in [DrafterKind::Ngram, DrafterKind::EagleLite] {
                let single = m.verify_cost(&unique, t, drafted, drafter);
                let reqs = usize::from(drafted > 0);
                let batch = m.batch_verify_cost(&unique, t, drafted, reqs, drafter);
                assert!((single.total() - batch.total()).abs() < 1e-15, "{drafter:?}");
                assert!((single.expert_s - batch.expert_s).abs() < 1e-15);
                assert!((single.draft_s - batch.draft_s).abs() < 1e-15);
            }
        }
    }

    #[test]
    fn batch_dedup_makes_experts_sublinear() {
        // Four requests whose tokens activate heavily-overlapping experts:
        // the fused charge must be far below four independent verify steps.
        let m = model("deepseek"); // 64 experts, top-6
        let per_request = m.verify_cost(&[12, 12], 4, 3, DrafterKind::Ngram);
        // Union across 4 requests deduplicates to 18 unique (vs 48 summed).
        let fused = m.batch_verify_cost(&[18, 18], 16, 12, 4, DrafterKind::Ngram);
        assert!(
            fused.expert_s < 4.0 * per_request.expert_s * 0.5,
            "fused {} vs 4x {}",
            fused.expert_s,
            4.0 * per_request.expert_s
        );
        // Base weights are charged once per fused iteration, not per request.
        assert!((fused.base_s - per_request.base_s).abs() < 1e-15);
    }

    #[test]
    fn batch_unique_capped_by_architecture() {
        let m = model("mixtral"); // 8 experts
        let a = m.batch_verify_cost(&[100, 100], 32, 24, 4, DrafterKind::Ngram);
        let b = m.batch_verify_cost(&[8, 8], 32, 24, 4, DrafterKind::Ngram);
        assert!((a.expert_s - b.expert_s).abs() < 1e-15);
    }

    #[test]
    fn baseline_equals_topk_analytic() {
        // With T=1 the router activates exactly top_k experts per layer, so
        // the measured and analytic baselines must coincide.
        let m = model("qwen");
        let measured = m.verify_cost(&[4, 4], 1, 0, DrafterKind::Ngram);
        assert!((measured.total() - m.baseline_cost().total()).abs() < 1e-12);
    }

    #[test]
    fn reprefill_charges_the_decode_clock_not_verify() {
        // Re-prefill after an eviction extends the iteration (TPOT-visible)
        // but is not verification work: total() grows by exactly the charge,
        // verify_s() is untouched, and the default is free.
        let m = model("mixtral");
        let plain = m.verify_cost(&[6, 6], 4, 3, DrafterKind::Ngram);
        assert_eq!(plain.reprefill_s, 0.0);
        let charged = IterCost { reprefill_s: 2e-3, ..plain };
        assert!((charged.total() - (plain.total() + 2e-3)).abs() < 1e-15);
        assert!((charged.verify_s() - plain.verify_s()).abs() < 1e-15);
    }

    #[test]
    fn stalls_charge_the_decode_clock_not_verify() {
        // A transient-stall retry extends the iteration (TPOT-visible) but
        // is not verification work: total() grows by exactly the charge,
        // verify_s() is untouched, and the fault-free default is free.
        let m = model("mixtral");
        let plain = m.verify_cost(&[6, 6], 4, 3, DrafterKind::Ngram);
        assert_eq!(plain.stall_s, 0.0);
        let stalled = IterCost { stall_s: 5e-3, ..plain };
        assert!((stalled.total() - (plain.total() + 5e-3)).abs() < 1e-15);
        assert!((stalled.verify_s() - plain.verify_s()).abs() < 1e-15);
    }

    #[test]
    fn migration_charges_the_decode_clock_not_verify() {
        // A self-healing expert migration extends the iteration
        // (TPOT-visible) but is not verification work: total() grows by
        // exactly the charge, verify_s() is untouched, the healthy default
        // is free, and dense models have nothing to move.
        let m = model("mixtral");
        let plain = m.verify_cost(&[6, 6], 4, 3, DrafterKind::Ngram);
        assert_eq!(plain.migration_s, 0.0);
        let mig = m.migration_s(3);
        assert!(mig > 0.0);
        let charged = IterCost { migration_s: mig, ..plain };
        assert!((charged.total() - (plain.total() + mig)).abs() < 1e-15);
        assert!((charged.verify_s() - plain.verify_s()).abs() < 1e-15);
        // Linear in experts moved; zero moves are free.
        assert!((m.migration_s(6) - 2.0 * mig).abs() < 1e-15);
        assert_eq!(m.migration_s(0), 0.0);
        assert_eq!(model("llama").migration_s(3), 0.0);
    }

    #[test]
    fn degraded_sharded_cost_scales_expert_term_only() {
        let m = model("mixtral"); // 8 experts, 2/shard at 4 shards
        let healthy = m.sharded_batch_verify_cost(&[2, 2], 4, 16, 12, 4, DrafterKind::Ngram);
        // Unit scale reproduces the healthy sharded charge bit-for-bit
        // (loads already below every cap, so no clipping differs).
        let unit =
            m.degraded_sharded_batch_verify_cost(&[2.0, 2.0], 4, 16, 12, 4, DrafterKind::Ngram);
        assert_eq!(healthy, unit, "unit-scale degraded cost diverged");
        // A 4x straggler on the critical shard quadruples the expert term
        // and nothing else — the fault slows fetches, it adds no experts.
        let slow =
            m.degraded_sharded_batch_verify_cost(&[8.0, 8.0], 4, 16, 12, 4, DrafterKind::Ngram);
        assert!((slow.expert_s - 4.0 * healthy.expert_s).abs() < 1e-15);
        assert!((slow.base_s - healthy.base_s).abs() < 1e-15);
        assert!((slow.alltoall_s - healthy.alltoall_s).abs() < 1e-15);
        // The scaled load may exceed the shard's expert capacity: that is
        // the point (time, not fetch count), so no cap clips it.
        let way_over = m.degraded_sharded_batch_verify_cost(
            &[80.0, 80.0],
            4,
            16,
            12,
            4,
            DrafterKind::Ngram,
        );
        assert!(way_over.expert_s > slow.expert_s);
        // Dense models have no expert term to degrade.
        let dense = model("llama").degraded_sharded_batch_verify_cost(
            &[8.0, 8.0],
            4,
            16,
            12,
            4,
            DrafterKind::Ngram,
        );
        assert_eq!(dense.expert_s, 0.0);
    }

    #[test]
    fn hidden_draft_reduces_total_but_never_below_verify() {
        // Overlap rule: total() charges only the exposed draft slice.
        let m = model("mixtral");
        let serial = m.verify_cost(&[6, 6], 4, 3, DrafterKind::Ngram);
        let pipelined = IterCost { draft_hidden_s: serial.draft_s, ..serial };
        assert!(pipelined.total() < serial.total());
        assert!((pipelined.total() - (serial.total() - serial.draft_s)).abs() < 1e-15);
        assert_eq!(pipelined.exposed_draft_s(), 0.0);
        // Hidden beyond draft_s must clamp, not go negative.
        let over = IterCost { draft_hidden_s: serial.draft_s * 2.0, ..serial };
        assert!(over.exposed_draft_s() == 0.0 && over.total() >= over.verify_s());
    }

    #[test]
    fn marginal_of_one_equals_single_request_cost() {
        // Alone in the batch, a request's marginal set is its full unique
        // set and the marginal charge is exactly the single-request charge.
        let m = model("mixtral");
        for (unique, t, drafted) in [(vec![4, 5], 4usize, 3usize), (vec![2, 2], 1, 0)] {
            for drafter in [DrafterKind::Ngram, DrafterKind::EagleLite] {
                let single = m.verify_cost(&unique, t, drafted, drafter);
                let marginal = m.marginal_request_cost(&unique, &[], 1, t, drafted, drafter);
                assert!((single.total() - marginal.total()).abs() < 1e-15, "{drafter:?}");
                assert!((single.expert_s - marginal.expert_s).abs() < 1e-15);
            }
        }
    }

    #[test]
    fn marginal_share_shrinks_with_batch_and_overlap() {
        // In a 4-deep batch with heavy expert overlap, the request's
        // marginal charge must fall well below the full fused charge.
        let m = model("deepseek");
        let fused = m.batch_verify_cost(&[18, 18], 16, 12, 4, DrafterKind::Ngram);
        // This request exclusively activates 3 experts per layer; 6 more
        // per layer are shared with neighbours (floored at a 1/4 share).
        let marginal = m.marginal_request_cost(&[3, 3], &[6, 6], 4, 4, 3, DrafterKind::Ngram);
        assert!(marginal.total() < fused.total() * 0.5, "{} vs {}", marginal.total(), fused.total());
        // Base + overhead amortize across the batch.
        assert!((marginal.base_s - fused.base_s / 4.0).abs() < 1e-15);
        assert!((marginal.overhead_s - fused.overhead_s / 4.0).abs() < 1e-15);
    }

    #[test]
    fn fairness_floor_charges_free_riders_a_shared_slice() {
        // Regression (ROADMAP fairness follow-on): a request whose experts
        // are ALL shared with neighbours used to observe near-zero expert
        // cost — speculating for free off the batch's fetch set. The floor
        // charges it a 1/B amortized share of the shared mass instead.
        let m = model("deepseek");
        let free_rider = m.marginal_request_cost(&[0, 0], &[12, 12], 4, 4, 3, DrafterKind::Ngram);
        let expected = m.spec.layers as f64 * (12.0 / 4.0) * m.spec.expert_bytes() / m.hw.eff_bw();
        assert!(free_rider.expert_s > 0.0, "free rider still rides free");
        assert!((free_rider.expert_s - expected).abs() < 1e-15);
        // Without attribution (empty shared slice) the floor is inert —
        // the pre-floor behavior, still > 0 total via the base share.
        let no_attr = m.marginal_request_cost(&[0, 0], &[], 4, 4, 3, DrafterKind::Ngram);
        assert!(no_attr.expert_s == 0.0 && no_attr.total() > 0.0);
        // The per-request activation cap (tokens * top_k) bounds only the
        // request's OWN marginal term, never its amortized share of the
        // neighbours' shared fetches: a 1-token free-rider (cap = 6) in a
        // batch whose shared mass is 40/layer still owes 40/4 = 10.
        let short = m.marginal_request_cost(&[0, 0], &[40, 40], 4, 1, 0, DrafterKind::Ngram);
        let expected_short =
            m.spec.layers as f64 * (40.0 / 4.0) * m.spec.expert_bytes() / m.hw.eff_bw();
        assert!((short.expert_s - expected_short).abs() < 1e-15, "floor clipped by span cap");
    }

    #[test]
    fn marginal_plus_shared_shares_sum_to_fused_expert_cost() {
        // The floor makes per-request expert charges a partition of the
        // fused expert term: Σ_r (exclusive_r + shared/B) = union.
        let m = model("deepseek");
        let (excl, shared) = ([vec![3usize, 2], vec![1, 4], vec![0, 0], vec![2, 1]], [6usize, 5]);
        let union: Vec<usize> = (0..2)
            .map(|l| excl.iter().map(|e| e[l]).sum::<usize>() + shared[l])
            .collect();
        let fused = m.batch_verify_cost(&union, 16, 12, 4, DrafterKind::Ngram);
        let sum: f64 = excl
            .iter()
            .map(|e| {
                m.marginal_request_cost(e, &shared, 4, 4, 3, DrafterKind::Ngram).expert_s
            })
            .sum();
        assert!((sum - fused.expert_s).abs() < 1e-12, "sum {sum} vs fused {}", fused.expert_s);
    }

    #[test]
    fn sharded_one_shard_is_bitexact_with_batch_cost() {
        // Property (ISSUE): shards=1 reproduces batch_verify_cost exactly.
        for name in ["mixtral", "deepseek", "llama"] {
            let m = model(name);
            for (unique, t, d, r) in
                [(vec![4, 5], 4usize, 3usize, 1usize), (vec![18, 18], 16, 12, 4)]
            {
                let a = m.batch_verify_cost(&unique, t, d, r, DrafterKind::Ngram);
                let b = m.sharded_batch_verify_cost(&unique, 1, t, d, r, DrafterKind::Ngram);
                assert_eq!(a, b, "{name}: shards=1 diverged from the unsharded cost");
            }
        }
    }

    #[test]
    fn sharding_trades_expert_mass_for_alltoall() {
        // 4-way sharding of a balanced load: the expert term drops ~4x,
        // the all-to-all term appears, and the net verify time falls.
        let m = model("mixtral"); // 8 experts
        let unsharded = m.sharded_batch_verify_cost(&[8, 8], 1, 16, 12, 4, DrafterKind::Ngram);
        let sharded = m.sharded_batch_verify_cost(&[2, 2], 4, 16, 12, 4, DrafterKind::Ngram);
        assert_eq!(unsharded.alltoall_s, 0.0);
        assert!(sharded.alltoall_s > 0.0);
        assert!((sharded.expert_s - unsharded.expert_s / 4.0).abs() < 1e-15);
        assert!(sharded.verify_s() < unsharded.verify_s());
        // Base weights are replicated, not sharded.
        assert!((sharded.base_s - unsharded.base_s).abs() < 1e-15);
    }

    #[test]
    fn sharded_load_capped_by_shard_capacity() {
        let m = model("mixtral"); // 8 experts, 2/shard at 4 shards
        let a = m.sharded_batch_verify_cost(&[100, 100], 4, 32, 24, 4, DrafterKind::Ngram);
        let b = m.sharded_batch_verify_cost(&[2, 2], 4, 32, 24, 4, DrafterKind::Ngram);
        assert!((a.expert_s - b.expert_s).abs() < 1e-15);
    }

    #[test]
    fn dense_sharding_is_a_noop() {
        let m = model("llama");
        let a = m.sharded_batch_verify_cost(&[], 4, 8, 7, 1, DrafterKind::Ngram);
        let b = m.batch_verify_cost(&[], 8, 7, 1, DrafterKind::Ngram);
        assert!((a.total() - b.total()).abs() < 1e-15);
        assert_eq!(a.alltoall_s, 0.0);
    }
}
