//! Stochastic speculative sampling (Leviathan et al., the paper's [27]).
//!
//! The greedy-match rule in `rejection.rs` is what vLLM uses for n-gram
//! drafting under greedy decoding. With temperature sampling and a drafter
//! that exposes a distribution (the draft-model path), the correct rule is
//! the accept/resample scheme that provably preserves the target
//! distribution:
//!
//! * accept draft token `d` with probability `min(1, p_t(d) / p_d(d))`;
//! * on rejection, resample from the residual `norm(max(p_t − p_d, 0))`.
//!
//! `prop_preserves_target_distribution` below checks the theorem
//! empirically — the output distribution of (draft ~ p_d → accept/resample)
//! must equal p_t regardless of how bad the drafter is.

use crate::rng::Rng;

/// Temperature softmax over logits.
pub fn softmax_t(logits: &[f32], temperature: f32) -> Vec<f32> {
    assert!(temperature > 0.0);
    let max = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let mut probs: Vec<f32> = logits
        .iter()
        .map(|&l| ((l - max) / temperature).exp())
        .collect();
    let sum: f32 = probs.iter().sum();
    for p in &mut probs {
        *p /= sum;
    }
    probs
}

/// Sample an index from a probability vector.
pub fn sample_categorical(probs: &[f32], rng: &mut Rng) -> u32 {
    let mut u = rng.f64() as f32;
    for (i, &p) in probs.iter().enumerate() {
        if u < p {
            return i as u32;
        }
        u -= p;
    }
    (probs.len() - 1) as u32 // numerical tail
}

/// Outcome of one accept/resample decision.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Verdict {
    /// Draft accepted verbatim.
    Accepted,
    /// Draft rejected; the carried token is the residual resample.
    Resampled(u32),
}

/// The speculative-sampling accept/resample rule for one position.
/// `p_target` and `p_draft` are the two distributions over the vocabulary;
/// `draft` was sampled from `p_draft`.
pub fn speculative_accept(
    p_target: &[f32],
    p_draft: &[f32],
    draft: u32,
    rng: &mut Rng,
) -> Verdict {
    debug_assert_eq!(p_target.len(), p_draft.len());
    let d = draft as usize;
    let pt = p_target[d];
    let pd = p_draft[d].max(1e-30);
    if (rng.f64() as f32) < (pt / pd).min(1.0) {
        return Verdict::Accepted;
    }
    // Residual distribution: norm(max(p_t - p_d, 0)).
    let mut residual: Vec<f32> = p_target
        .iter()
        .zip(p_draft)
        .map(|(&t, &q)| (t - q).max(0.0))
        .collect();
    let sum: f32 = residual.iter().sum();
    if sum <= 0.0 {
        // p_t <= p_d everywhere can only happen via rounding; fall back.
        return Verdict::Resampled(sample_categorical(p_target, rng));
    }
    for r in &mut residual {
        *r /= sum;
    }
    Verdict::Resampled(sample_categorical(&residual, rng))
}

/// Verify a draft chain: apply the rule causally; the first rejection ends
/// acceptance and contributes the resampled correction; full acceptance
/// appends a bonus token from `p_bonus` (the target's K+1-th distribution).
pub fn stochastic_verify(
    p_targets: &[Vec<f32>],
    p_drafts: &[Vec<f32>],
    drafts: &[u32],
    p_bonus: &[f32],
    rng: &mut Rng,
) -> crate::spec::rejection::VerifyResult {
    debug_assert_eq!(p_targets.len(), drafts.len());
    debug_assert_eq!(p_drafts.len(), drafts.len());
    let mut emitted = Vec::with_capacity(drafts.len() + 1);
    for i in 0..drafts.len() {
        match speculative_accept(&p_targets[i], &p_drafts[i], drafts[i], rng) {
            Verdict::Accepted => emitted.push(drafts[i]),
            Verdict::Resampled(tok) => {
                emitted.push(tok);
                return crate::spec::rejection::VerifyResult { accepted: i, emitted };
            }
        }
    }
    emitted.push(sample_categorical(p_bonus, rng));
    crate::spec::rejection::VerifyResult { accepted: drafts.len(), emitted }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn softmax_sums_to_one_and_orders() {
        let p = softmax_t(&[1.0, 3.0, 2.0], 1.0);
        assert!((p.iter().sum::<f32>() - 1.0).abs() < 1e-6);
        assert!(p[1] > p[2] && p[2] > p[0]);
    }

    #[test]
    fn low_temperature_sharpens() {
        let hot = softmax_t(&[1.0, 2.0], 2.0);
        let cold = softmax_t(&[1.0, 2.0], 0.25);
        assert!(cold[1] > hot[1]);
    }

    #[test]
    fn identical_distributions_always_accept() {
        let p = vec![0.25f32; 4];
        let mut rng = Rng::new(1);
        for _ in 0..500 {
            let d = sample_categorical(&p, &mut rng);
            assert_eq!(speculative_accept(&p, &p, d, &mut rng), Verdict::Accepted);
        }
    }

    #[test]
    fn impossible_draft_always_rejected() {
        // Target puts zero mass on token 0; drafter always proposes it.
        let pt = vec![0.0f32, 1.0];
        let pd = vec![1.0f32, 0.0];
        let mut rng = Rng::new(2);
        for _ in 0..200 {
            match speculative_accept(&pt, &pd, 0, &mut rng) {
                Verdict::Resampled(tok) => assert_eq!(tok, 1),
                Verdict::Accepted => panic!("accepted zero-probability draft"),
            }
        }
    }

    /// The speculative-sampling theorem: output ~ p_target exactly, for an
    /// arbitrary (mismatched) drafter.
    #[test]
    fn prop_preserves_target_distribution() {
        let mut rng = Rng::new(0x5A3B);
        for case in 0..20 {
            let v = rng.range(2, 6);
            let mk = |rng: &mut Rng| {
                let mut p: Vec<f32> = (0..v).map(|_| rng.f64() as f32 + 0.01).collect();
                let s: f32 = p.iter().sum();
                p.iter_mut().for_each(|x| *x /= s);
                p
            };
            let pt = mk(&mut rng);
            let pd = mk(&mut rng);
            let n = 60_000;
            let mut counts = vec![0usize; v];
            for _ in 0..n {
                let d = sample_categorical(&pd, &mut rng);
                let tok = match speculative_accept(&pt, &pd, d, &mut rng) {
                    Verdict::Accepted => d,
                    Verdict::Resampled(t) => t,
                };
                counts[tok as usize] += 1;
            }
            for i in 0..v {
                let emp = counts[i] as f64 / n as f64;
                let want = pt[i] as f64;
                assert!(
                    (emp - want).abs() < 0.012,
                    "case {case}: token {i} empirical {emp:.4} vs target {want:.4}"
                );
            }
        }
    }

    #[test]
    fn chain_verification_is_causal() {
        // Draft 1 impossible => acceptance stops at 0 even if draft 2 is
        // perfect.
        let pt = vec![vec![0.0, 1.0], vec![1.0, 0.0]];
        let pd = vec![vec![1.0, 0.0], vec![1.0, 0.0]];
        let mut rng = Rng::new(3);
        let r = stochastic_verify(&pt, &pd, &[0, 0], &[0.5, 0.5], &mut rng);
        assert_eq!(r.accepted, 0);
        assert_eq!(r.emitted.len(), 1);
        assert_eq!(r.emitted[0], 1); // residual forced to token 1
    }

    #[test]
    fn full_acceptance_adds_bonus() {
        let p = vec![vec![0.5, 0.5]; 3];
        let mut rng = Rng::new(4);
        let r = stochastic_verify(&p, &p, &[0, 1, 0], &[1.0, 0.0], &mut rng);
        assert_eq!(r.accepted, 3);
        assert_eq!(r.emitted.len(), 4);
        assert_eq!(*r.emitted.last().unwrap(), 0); // bonus from p_bonus
    }

    #[test]
    fn categorical_matches_probs() {
        let p = vec![0.7f32, 0.2, 0.1];
        let mut rng = Rng::new(5);
        let n = 50_000;
        let mut c = [0usize; 3];
        for _ in 0..n {
            c[sample_categorical(&p, &mut rng) as usize] += 1;
        }
        assert!((c[0] as f64 / n as f64 - 0.7).abs() < 0.01);
        assert!((c[2] as f64 / n as f64 - 0.1).abs() < 0.01);
    }
}
