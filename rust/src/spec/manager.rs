//! The Cascade speculation manager (paper §5, Fig. 9 left half).
//!
//! A per-request state machine driving the speculation length K:
//!
//! * **Baseline** — the first `baseline_iters` decode iterations run with
//!   K=0 to measure the no-speculation iteration time (§5.3); re-measured
//!   every `baseline_refresh` iterations.
//! * **Test** — up to `max_trials` trials of `trial_iters` iterations each,
//!   exploring K values with hill-climbing (§5.6). Early exits: utility < 1
//!   at K=1 (§5.4), two consecutive utility decreases, convergence within
//!   `converge_tol`, or trial budget exhausted.
//! * **Set** — the utility-maximizing K (or K=0 when best utility < 1,
//!   §5.4) runs for `set_iters` iterations. Adaptive back-off (§5.5):
//!   every transition *into* K=0 doubles the effective set length
//!   (capped), so hopeless requests are probed exponentially less often;
//!   any transition back to K>0 resets it.
//!
//! The ablation switches in `CascadeParams` (Fig. 18) degrade this machine
//! gracefully: with everything off it is exactly "static K = K_start".

use crate::config::{CascadeParams, MAX_K};
use crate::metrics::IterPhase;
use crate::spec::utility::UtilityAnalyzer;

/// A finished test-phase trial.
#[derive(Debug, Clone, Copy)]
pub struct Trial {
    pub k: usize,
    pub utility: f64,
}

#[derive(Debug, Clone)]
enum Phase {
    Baseline { done: usize, refresh: bool },
    Test(TestState),
    Set { k: usize, remaining: usize },
}

#[derive(Debug, Clone)]
struct TestState {
    trials: Vec<Trial>,
    cur_k: usize,
    cur_iters: usize,
    etr_sum: f64,
    cost_sum: f64,
    /// Consecutive utility decreases (early-exit rule 1 of §5.6).
    decreases: usize,
}

/// Event log entry for the utility-trace figures (15/16).
#[derive(Debug, Clone, Copy)]
pub struct ManagerEvent {
    pub iter: usize,
    pub phase: IterPhase,
    pub k: usize,
    /// Utility of the just-finished trial (test phases only).
    pub trial_utility: Option<f64>,
}

/// Per-request Cascade state machine.
#[derive(Debug, Clone)]
pub struct CascadeManager {
    pub params: CascadeParams,
    pub analyzer: UtilityAnalyzer,
    phase: Phase,
    /// Effective set length (grows under back-off).
    set_len: usize,
    iters: usize,
    iters_since_refresh: usize,
    /// Best (k, utility) seen in recent test phases (K_start source, §5.3).
    best_seen: Option<Trial>,
    last_set_k: usize,
    pub events: Vec<ManagerEvent>,
}

impl CascadeManager {
    pub fn new(params: CascadeParams) -> Self {
        let set_len = params.set_iters;
        Self {
            params,
            analyzer: UtilityAnalyzer::default(),
            phase: Phase::Baseline { done: 0, refresh: false },
            set_len,
            iters: 0,
            iters_since_refresh: 0,
            best_seen: None,
            last_set_k: usize::MAX, // sentinel: no set phase yet
            events: Vec::new(),
        }
    }

    /// Is this manager just a static-K policy? (Fig. 18 "no optimizations".)
    fn is_static(&self) -> bool {
        !self.params.enable_disable && !self.params.enable_hillclimb
    }

    /// The speculation length to use for the next iteration.
    pub fn next_k(&self) -> usize {
        match &self.phase {
            Phase::Baseline { .. } => 0,
            Phase::Test(t) => t.cur_k,
            Phase::Set { k, .. } => *k,
        }
    }

    /// Phase label for telemetry.
    pub fn phase_label(&self) -> IterPhase {
        match &self.phase {
            Phase::Baseline { .. } => IterPhase::Baseline,
            Phase::Test(_) => IterPhase::Test,
            Phase::Set { .. } => IterPhase::Set,
        }
    }

    /// Starting K for a test phase (§5.3 / §5.4).
    fn k_test_start(&self) -> usize {
        if self.last_set_k == 0 {
            // After a disabled set phase, probe from the most conservative
            // speculative state (§5.4).
            1
        } else {
            match self.best_seen {
                Some(t) if t.k > 0 => t.k,
                _ => self.params.k_start.clamp(1, MAX_K),
            }
        }
    }

    fn enter_test(&mut self) {
        let k = self.k_test_start();
        self.phase = Phase::Test(TestState {
            trials: Vec::new(),
            cur_k: k,
            cur_iters: 0,
            etr_sum: 0.0,
            cost_sum: 0.0,
            decreases: 0,
        });
    }

    fn enter_set(&mut self, k: usize) {
        if k == 0 {
            // Adaptive back-off (§5.5): every transition to K=0 lengthens
            // the quiet period exponentially.
            if self.params.enable_backoff {
                self.set_len =
                    (self.set_len * self.params.backoff_factor).min(self.params.max_set_iters);
            }
        } else {
            self.set_len = self.params.set_iters;
        }
        self.last_set_k = k;
        self.phase = Phase::Set { k, remaining: self.set_len };
    }

    /// Record one finished decode iteration. `etr` = tokens emitted,
    /// `iter_s` = simulated iteration time.
    pub fn observe(&mut self, etr: f64, iter_s: f64) {
        self.iters += 1;
        self.iters_since_refresh += 1;
        self.analyzer.observe(etr, iter_s);

        let mut trial_utility = None;
        let phase_label = self.phase_label();
        let k_used = self.next_k();

        match &mut self.phase {
            Phase::Baseline { done, refresh } => {
                self.analyzer.observe_baseline(iter_s);
                *done += 1;
                if *done >= self.params.baseline_iters {
                    let was_refresh = *refresh;
                    self.iters_since_refresh = 0;
                    if self.is_static() {
                        // Fig. 18 level 0: static K_start forever.
                        let k = self.params.k_start;
                        self.phase = Phase::Set { k, remaining: usize::MAX };
                    } else if was_refresh && self.last_set_k == 0 {
                        // Resume the backed-off quiet period after a refresh.
                        self.enter_test();
                    } else {
                        self.enter_test();
                    }
                }
            }
            Phase::Test(t) => {
                t.cur_iters += 1;
                t.etr_sum += etr;
                t.cost_sum += iter_s;
                if t.cur_iters >= self.params.trial_iters {
                    let mean_etr = t.etr_sum / t.cur_iters as f64;
                    let mean_cost = t.cost_sum / t.cur_iters as f64;
                    let u = self
                        .analyzer
                        .utility_of(mean_etr, mean_cost)
                        .unwrap_or(1.0);
                    trial_utility = Some(u);
                    let finished = Trial { k: t.cur_k, utility: u };
                    let prev = t.trials.last().copied();
                    t.trials.push(finished);
                    if let Some(p) = prev {
                        if u < p.utility {
                            t.decreases += 1;
                        } else {
                            t.decreases = 0;
                        }
                    }
                    self.after_trial();
                }
            }
            Phase::Set { remaining, .. } => {
                if *remaining != usize::MAX {
                    *remaining -= 1;
                    if *remaining == 0 {
                        if self.iters_since_refresh >= self.params.baseline_refresh {
                            // Infrequent baseline re-measurement (§5.3).
                            self.phase = Phase::Baseline { done: 0, refresh: true };
                        } else {
                            self.enter_test();
                        }
                    }
                }
            }
        }

        self.events.push(ManagerEvent {
            iter: self.iters,
            phase: phase_label,
            k: k_used,
            trial_utility,
        });
    }

    /// Decide what follows a finished trial: another trial (hill-climbing)
    /// or a set phase.
    fn after_trial(&mut self) {
        let t = match &self.phase {
            Phase::Test(t) => t.clone(),
            _ => unreachable!("after_trial outside test phase"),
        };
        let last = *t.trials.last().expect("at least one finished trial");
        let best = t
            .trials
            .iter()
            .copied()
            .max_by(|a, b| a.utility.total_cmp(&b.utility))
            .unwrap();

        // Track history for future K_start selection (§5.3).
        if best.utility >= self.best_seen.map(|b| b.utility).unwrap_or(f64::NEG_INFINITY) {
            self.best_seen = Some(best);
        }

        let decide = |mgr: &mut Self, best: Trial| {
            let k = if mgr.params.enable_disable && best.utility < 1.0 { 0 } else { best.k };
            mgr.enter_set(k);
        };

        // §5.4: utility below 1 at the most conservative K=1 — stop testing
        // immediately and disable.
        if self.params.enable_disable && last.k == 1 && last.utility < 1.0 {
            return decide(self, Trial { k: 1, utility: last.utility });
        }

        // Without hill-climbing, a single trial decides (Fig. 18 level 1/2).
        if !self.params.enable_hillclimb {
            return decide(self, last);
        }

        // Early exits (§5.6).
        if t.trials.len() >= self.params.max_trials {
            return decide(self, best);
        }
        if t.decreases >= 2 {
            return decide(self, best);
        }
        if t.trials.len() >= 2 {
            let prev = t.trials[t.trials.len() - 2];
            let denom = prev.utility.abs().max(1e-9);
            if (last.utility - prev.utility).abs() / denom < self.params.converge_tol {
                return decide(self, best);
            }
        }

        // Hill-climbing step (§5.6): follow the utility gradient in K.
        let next_k = if t.trials.len() == 1 {
            if last.utility >= 1.0 {
                (last.k + 1).min(MAX_K)
            } else {
                last.k.saturating_sub(1)
            }
        } else {
            let prev = t.trials[t.trials.len() - 2];
            let dir_up = if last.utility > prev.utility {
                last.k > prev.k // keep going the way that helped
            } else {
                last.k < prev.k // reverse
            };
            if dir_up {
                (last.k + 1).min(MAX_K)
            } else {
                last.k.saturating_sub(1)
            }
        };

        // K reached 0 (early-exit rule 2) or the climb is stuck at a bound.
        if next_k == 0 {
            return decide(self, best);
        }
        if t.trials.iter().any(|tr| tr.k == next_k) {
            return decide(self, best);
        }

        self.phase = Phase::Test(TestState {
            trials: t.trials,
            cur_k: next_k,
            cur_iters: 0,
            etr_sum: 0.0,
            cost_sum: 0.0,
            decreases: t.decreases,
        });
    }

    /// Current effective set length (tests back-off behaviour).
    pub fn current_set_len(&self) -> usize {
        self.set_len
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Drive the manager with a synthetic utility landscape: iteration time
    /// and ETR as functions of K.
    fn drive(mgr: &mut CascadeManager, iters: usize, etr_of: impl Fn(usize) -> f64, cost_of: impl Fn(usize) -> f64) {
        for _ in 0..iters {
            let k = mgr.next_k();
            mgr.observe(etr_of(k), cost_of(k));
        }
    }

    /// Landscape where speculation always hurts (math-like): ETR ≈ 1,
    /// cost grows with K.
    fn hostile(k: usize) -> (f64, f64) {
        (1.0 + 0.05 * k as f64, 0.01 * (1.0 + 0.8 * k as f64))
    }

    /// Landscape where utility peaks at K=3 (code-like).
    fn friendly(k: usize) -> (f64, f64) {
        let etr = 1.0 + 0.9 * (k.min(4) as f64);
        let cost = 0.01 * (1.0 + 0.25 * k as f64);
        (etr, cost)
    }

    #[test]
    fn baseline_first() {
        let mgr = CascadeManager::new(CascadeParams::default());
        assert_eq!(mgr.next_k(), 0);
        assert_eq!(mgr.phase_label(), IterPhase::Baseline);
    }

    #[test]
    fn hostile_landscape_disables() {
        let mut mgr = CascadeManager::new(CascadeParams::default());
        drive(&mut mgr, 60, |k| hostile(k).0, |k| hostile(k).1);
        // After testing, the manager must be parked at K=0.
        assert_eq!(mgr.next_k(), 0, "events: {:?}", mgr.events.len());
    }

    #[test]
    fn hostile_landscape_backs_off() {
        let mut mgr = CascadeManager::new(CascadeParams::default());
        let s0 = mgr.current_set_len();
        drive(&mut mgr, 400, |k| hostile(k).0, |k| hostile(k).1);
        assert!(mgr.current_set_len() > s0 * 2, "set_len {}", mgr.current_set_len());
        // Test iterations must be a small fraction under back-off (§5.5).
        let test_iters = mgr
            .events
            .iter()
            .filter(|e| e.phase == IterPhase::Test)
            .count();
        assert!(test_iters * 5 < mgr.events.len(), "test {} of {}", test_iters, mgr.events.len());
    }

    #[test]
    fn friendly_landscape_climbs_to_high_k() {
        let mut mgr = CascadeManager::new(CascadeParams::default());
        drive(&mut mgr, 120, |k| friendly(k).0, |k| friendly(k).1);
        // Utility peaks at K=4; hill climbing should settle at K >= 3.
        let set_ks: Vec<usize> = mgr
            .events
            .iter()
            .filter(|e| e.phase == IterPhase::Set)
            .map(|e| e.k)
            .collect();
        let late = &set_ks[set_ks.len().saturating_sub(10)..];
        assert!(late.iter().all(|&k| k >= 3), "late set Ks: {late:?}");
    }

    #[test]
    fn no_backoff_ablation_keeps_set_len() {
        let mut mgr = CascadeManager::new(CascadeParams::ablation(1));
        drive(&mut mgr, 300, |k| hostile(k).0, |k| hostile(k).1);
        assert_eq!(mgr.current_set_len(), mgr.params.set_iters);
    }

    #[test]
    fn static_ablation_never_tests() {
        let mut mgr = CascadeManager::new(CascadeParams::ablation(0));
        drive(&mut mgr, 100, |k| hostile(k).0, |k| hostile(k).1);
        assert!(mgr.events.iter().all(|e| e.phase != IterPhase::Test));
        assert_eq!(mgr.next_k(), mgr.params.k_start);
    }

    #[test]
    fn k0_set_phase_restarts_with_k1() {
        let mut mgr = CascadeManager::new(CascadeParams::default());
        drive(&mut mgr, 200, |k| hostile(k).0, |k| hostile(k).1);
        // Find a test iteration that follows a K=0 set phase; it must probe
        // K=1 (§5.4).
        let mut seen_zero_set = false;
        for e in &mgr.events {
            match e.phase {
                IterPhase::Set if e.k == 0 => seen_zero_set = true,
                IterPhase::Test if seen_zero_set => {
                    assert_eq!(e.k, 1);
                    return;
                }
                _ => {}
            }
        }
        panic!("never observed test-after-disable");
    }

    #[test]
    fn k_stays_in_bounds() {
        for seed in 0..5u64 {
            let mut mgr = CascadeManager::new(CascadeParams::default());
            let mut rng = crate::rng::Rng::new(seed);
            for _ in 0..300 {
                let k = mgr.next_k();
                assert!(k <= MAX_K);
                // random landscape
                mgr.observe(1.0 + rng.f64() * k as f64, 0.01 * (1.0 + rng.f64()));
            }
        }
    }

    #[test]
    fn baseline_refresh_happens() {
        let mut mgr = CascadeManager::new(CascadeParams::default());
        drive(&mut mgr, 400, |k| friendly(k).0, |k| friendly(k).1);
        let baseline_iters = mgr
            .events
            .iter()
            .filter(|e| e.phase == IterPhase::Baseline)
            .count();
        // initial 4 + at least one refresh of 4
        assert!(baseline_iters >= 8, "{baseline_iters}");
    }

    #[test]
    fn theorem_guided_decision_quality() {
        // On the friendly landscape, Cascade's average utility in set phases
        // must beat static K=1.
        let mut mgr = CascadeManager::new(CascadeParams::default());
        drive(&mut mgr, 200, |k| friendly(k).0, |k| friendly(k).1);
        let u = |k: usize| {
            let (e, c) = friendly(k);
            e / (c / friendly(0).1)
        };
        let set_util: Vec<f64> = mgr
            .events
            .iter()
            .filter(|e| e.phase == IterPhase::Set)
            .map(|e| u(e.k))
            .collect();
        let mean = set_util.iter().sum::<f64>() / set_util.len() as f64;
        assert!(mean > u(1) * 1.2, "mean set utility {mean} vs k1 {}", u(1));
    }
}
