//! The utility analyzer (paper §4, Fig. 9 right half).
//!
//! Tracks the no-speculation baseline iteration time (measured over the
//! request's first few decode iterations and refreshed infrequently, §5.3)
//! and recent (ETR, cost) observations, and computes *speculation utility*:
//!
//! > utility = benefit / cost = ETR / (t_iter_spec / t_iter_base)   (Def. 4.1)
//!
//! Theorem 4.2: TPOT_spec = TPOT_base / utility — so maximizing utility
//! minimizes TPOT. `theorem_4_2_holds` below checks the identity on random
//! traces.

use std::collections::VecDeque;

/// Rolling utility analyzer for one request.
#[derive(Debug, Clone)]
pub struct UtilityAnalyzer {
    /// EMA of the measured K=0 iteration time.
    baseline_s: Option<f64>,
    /// EMA weight for baseline refreshes (first measurement seeds it).
    ema_alpha: f64,
    /// Recent speculative iterations: (etr, iteration seconds).
    window: VecDeque<(f64, f64)>,
    cap: usize,
}

impl Default for UtilityAnalyzer {
    fn default() -> Self {
        Self::new(64)
    }
}

impl UtilityAnalyzer {
    pub fn new(cap: usize) -> Self {
        Self { baseline_s: None, ema_alpha: 0.5, window: VecDeque::new(), cap }
    }

    /// Record a measured K=0 iteration (baseline phase or refresh).
    pub fn observe_baseline(&mut self, iter_s: f64) {
        self.baseline_s = Some(match self.baseline_s {
            None => iter_s,
            Some(prev) => prev * (1.0 - self.ema_alpha) + iter_s * self.ema_alpha,
        });
    }

    /// Record a (speculative or not) decode iteration.
    pub fn observe(&mut self, etr: f64, iter_s: f64) {
        if self.window.len() == self.cap {
            self.window.pop_front();
        }
        self.window.push_back((etr, iter_s));
    }

    pub fn baseline_s(&self) -> Option<f64> {
        self.baseline_s
    }

    pub fn has_baseline(&self) -> bool {
        self.baseline_s.is_some()
    }

    /// Utility of an explicit (mean-ETR, mean-iteration-time) pair.
    pub fn utility_of(&self, mean_etr: f64, mean_iter_s: f64) -> Option<f64> {
        let base = self.baseline_s?;
        if mean_iter_s <= 0.0 || base <= 0.0 {
            return None;
        }
        Some(mean_etr / (mean_iter_s / base))
    }

    /// Utility over the recent observation window (telemetry).
    pub fn window_utility(&self) -> Option<f64> {
        if self.window.is_empty() {
            return None;
        }
        let n = self.window.len() as f64;
        let etr = self.window.iter().map(|(e, _)| e).sum::<f64>() / n;
        let t = self.window.iter().map(|(_, s)| s).sum::<f64>() / n;
        self.utility_of(etr, t)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn baseline_seeds_then_ema() {
        let mut a = UtilityAnalyzer::default();
        assert!(!a.has_baseline());
        a.observe_baseline(0.02);
        assert_eq!(a.baseline_s(), Some(0.02));
        a.observe_baseline(0.04);
        assert!((a.baseline_s().unwrap() - 0.03).abs() < 1e-12);
    }

    #[test]
    fn utility_definition() {
        let mut a = UtilityAnalyzer::default();
        a.observe_baseline(0.01);
        // ETR 1.5x at 2x cost => utility 0.75 (the paper's own example).
        let u = a.utility_of(1.5, 0.02).unwrap();
        assert!((u - 0.75).abs() < 1e-12);
    }

    #[test]
    fn no_baseline_no_utility() {
        let a = UtilityAnalyzer::default();
        assert!(a.utility_of(2.0, 0.02).is_none());
        assert!(a.window_utility().is_none());
    }

    #[test]
    fn window_rolls() {
        let mut a = UtilityAnalyzer::new(4);
        a.observe_baseline(0.01);
        for _ in 0..4 {
            a.observe(1.0, 0.01);
        }
        assert!((a.window_utility().unwrap() - 1.0).abs() < 1e-12);
        for _ in 0..4 {
            a.observe(3.0, 0.015); // displaces all old entries
        }
        assert!((a.window_utility().unwrap() - 2.0).abs() < 1e-12);
    }

    /// Theorem 4.2 on random traces: TPOT_spec == TPOT_base / utility when
    /// utility is computed from the same trace means.
    #[test]
    fn theorem_4_2_holds() {
        let mut rng = Rng::new(0x7407);
        for _ in 0..200 {
            let base = 0.005 + rng.f64() * 0.05;
            let n = rng.range(5, 60);
            let mut tok = 0.0;
            let mut time = 0.0;
            let mut a = UtilityAnalyzer::default();
            a.observe_baseline(base);
            for _ in 0..n {
                let etr = 1.0 + rng.f64() * 4.0;
                let t = base * (0.8 + rng.f64() * 2.5);
                tok += etr;
                time += t;
            }
            let n = n as f64;
            let u = a.utility_of(tok / n, time / n).unwrap();
            let tpot_spec = time / tok;
            assert!((tpot_spec - base / u).abs() < 1e-12);
        }
    }
}
