//! Speculative decoding: drafters, rejection sampling, and the paper's
//! contribution — the utility analyzer (§4) and the Cascade speculation
//! manager (§5: test-and-set, adaptive back-off, hill-climbing).

pub mod drafter;
pub mod manager;
pub mod policy;
pub mod rejection;
pub mod stochastic;
pub mod utility;

pub use drafter::NgramDrafter;
pub use manager::CascadeManager;
pub use policy::{IterObs, PolicyKind, SpecPolicy, StaticK};
pub use rejection::greedy_verify;
pub use utility::UtilityAnalyzer;
