//! Speculation policies: the interface between the serving engine and the
//! K-selection logic, with static-K baselines (the paper's comparison
//! points) and Cascade as implementations.

use crate::config::{CascadeParams, MAX_K};
use crate::metrics::IterPhase;
use crate::spec::manager::CascadeManager;

/// What the engine reports back to the policy after each decode iteration.
#[derive(Debug, Clone, Copy)]
pub struct IterObs {
    pub k_chosen: usize,
    pub drafted: usize,
    pub accepted: usize,
    /// Tokens emitted (= ETR of this iteration).
    pub emitted: usize,
    /// Simulated iteration time (GPU clock).
    pub iter_s: f64,
}

/// A speculation policy decides K before each iteration.
pub trait SpecPolicy {
    /// Speculation length for the next iteration (0 = no speculation).
    fn next_k(&mut self) -> usize;
    /// Feed back the outcome of the iteration.
    fn observe(&mut self, obs: &IterObs);
    /// Telemetry label for the current phase.
    fn phase(&self) -> IterPhase;
    fn name(&self) -> String;
    /// Reset per-request state (Cascade is per-request, §5).
    fn reset(&mut self);
    /// Best-effort forecast of what `next_k` will return once `predicted`
    /// — the in-flight iteration's outcome, guessed *before* verification
    /// completes — has been observed. The pipelined engine drafts
    /// iteration i+1 under iteration i's verify window with this K; a
    /// wrong forecast costs a draft recompute (a pipeline bubble), never
    /// correctness. `None` means the policy cannot forecast and the
    /// engine skips speculative drafting for the slot.
    fn predict_next_k(&self, _predicted: &IterObs) -> Option<usize> {
        None
    }
    /// Access the Cascade manager, if this policy has one (trace figures).
    fn manager(&self) -> Option<&CascadeManager> {
        None
    }
}

/// Always-K baseline (the paper's static-K comparison; K=0 disables
/// speculation entirely).
#[derive(Debug, Clone)]
pub struct StaticK {
    pub k: usize,
}

impl StaticK {
    pub fn new(k: usize) -> Self {
        assert!(k <= MAX_K);
        Self { k }
    }
}

impl SpecPolicy for StaticK {
    fn next_k(&mut self) -> usize {
        self.k
    }

    fn observe(&mut self, _obs: &IterObs) {}

    fn phase(&self) -> IterPhase {
        IterPhase::Set
    }

    fn name(&self) -> String {
        format!("static-k{}", self.k)
    }

    fn reset(&mut self) {}

    fn predict_next_k(&self, _predicted: &IterObs) -> Option<usize> {
        // Static K is exactly predictable: pipelined drafting never bubbles
        // on a K change.
        Some(self.k)
    }
}

/// Cascade: utility-driven dynamic speculation (paper §5).
pub struct CascadePolicy {
    params: CascadeParams,
    mgr: CascadeManager,
}

impl CascadePolicy {
    pub fn new(params: CascadeParams) -> Self {
        Self { mgr: CascadeManager::new(params.clone()), params }
    }
}

impl SpecPolicy for CascadePolicy {
    fn next_k(&mut self) -> usize {
        self.mgr.next_k()
    }

    fn observe(&mut self, obs: &IterObs) {
        self.mgr.observe(obs.emitted as f64, obs.iter_s);
    }

    fn phase(&self) -> IterPhase {
        self.mgr.phase_label()
    }

    fn name(&self) -> String {
        "cascade".into()
    }

    fn reset(&mut self) {
        self.mgr = CascadeManager::new(self.params.clone());
    }

    fn predict_next_k(&self, predicted: &IterObs) -> Option<usize> {
        // Run the observation the engine *expects* this iteration to
        // produce through a scratch copy of the state machine. Exact
        // whenever the guess (full acceptance, last iteration's cost)
        // holds and the machine does not cross a trial/phase boundary on
        // a cost surprise — mid set-phase, where Cascade spends most
        // iterations, K is constant and the forecast is trivially right.
        let mut mgr = self.mgr.clone();
        mgr.observe(predicted.emitted as f64, predicted.iter_s);
        Some(mgr.next_k())
    }

    fn manager(&self) -> Option<&CascadeManager> {
        Some(&self.mgr)
    }
}

/// Policy constructor, usable from CLI strings and experiment specs.
#[derive(Debug, Clone)]
pub enum PolicyKind {
    Static(usize),
    Cascade(CascadeParams),
}

impl PolicyKind {
    pub fn build(&self) -> Box<dyn SpecPolicy> {
        match self {
            PolicyKind::Static(k) => Box::new(StaticK::new(*k)),
            PolicyKind::Cascade(p) => Box::new(CascadePolicy::new(p.clone())),
        }
    }

    pub fn label(&self) -> String {
        match self {
            PolicyKind::Static(k) => format!("static-k{k}"),
            PolicyKind::Cascade(p) => {
                if p.enable_disable && p.enable_backoff && p.enable_hillclimb {
                    "cascade".into()
                } else {
                    format!(
                        "cascade[d={},b={},h={}]",
                        p.enable_disable as u8, p.enable_backoff as u8, p.enable_hillclimb as u8
                    )
                }
            }
        }
    }

    /// Parse CLI forms: "k0".."k7", "cascade", "cascade:t=2,s=8",
    /// "ablation0".."ablation3".
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        if let Some(k) = s.strip_prefix('k') {
            let k: usize = k.parse()?;
            anyhow::ensure!(k <= MAX_K, "k must be <= {MAX_K}");
            return Ok(PolicyKind::Static(k));
        }
        if let Some(level) = s.strip_prefix("ablation") {
            return Ok(PolicyKind::Cascade(CascadeParams::ablation(level.parse()?)));
        }
        if s == "cascade" {
            return Ok(PolicyKind::Cascade(CascadeParams::default()));
        }
        if let Some(rest) = s.strip_prefix("cascade:") {
            let mut p = CascadeParams::default();
            for kv in rest.split(',') {
                let (key, val) = kv
                    .split_once('=')
                    .ok_or_else(|| anyhow::anyhow!("bad cascade param {kv:?}"))?;
                match key {
                    "t" => p.trial_iters = val.parse()?,
                    "s" => p.set_iters = val.parse()?,
                    "kstart" => p.k_start = val.parse()?,
                    other => anyhow::bail!("unknown cascade param {other:?}"),
                }
            }
            return Ok(PolicyKind::Cascade(p));
        }
        anyhow::bail!("unknown policy {s:?} (want k0..k7, cascade, ablation0..3)")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn static_k_is_constant() {
        let mut p = StaticK::new(3);
        for _ in 0..10 {
            assert_eq!(p.next_k(), 3);
            p.observe(&IterObs { k_chosen: 3, drafted: 3, accepted: 1, emitted: 2, iter_s: 0.01 });
        }
    }

    #[test]
    fn cascade_resets_per_request() {
        let mut p = CascadePolicy::new(CascadeParams::default());
        for _ in 0..40 {
            let k = p.next_k();
            p.observe(&IterObs { k_chosen: k, drafted: k, accepted: 0, emitted: 1, iter_s: 0.02 });
        }
        p.reset();
        assert_eq!(p.phase(), IterPhase::Baseline);
        assert_eq!(p.next_k(), 0);
    }

    #[test]
    fn parse_forms() {
        assert!(matches!(PolicyKind::parse("k0").unwrap(), PolicyKind::Static(0)));
        assert!(matches!(PolicyKind::parse("k7").unwrap(), PolicyKind::Static(7)));
        assert!(PolicyKind::parse("k9").is_err());
        assert!(matches!(PolicyKind::parse("cascade").unwrap(), PolicyKind::Cascade(_)));
        match PolicyKind::parse("cascade:t=2,s=8").unwrap() {
            PolicyKind::Cascade(p) => {
                assert_eq!(p.trial_iters, 2);
                assert_eq!(p.set_iters, 8);
            }
            _ => panic!(),
        }
        match PolicyKind::parse("ablation1").unwrap() {
            PolicyKind::Cascade(p) => assert!(p.enable_disable && !p.enable_backoff),
            _ => panic!(),
        }
        assert!(PolicyKind::parse("wat").is_err());
    }

    #[test]
    fn labels() {
        assert_eq!(PolicyKind::Static(2).label(), "static-k2");
        assert_eq!(PolicyKind::Cascade(CascadeParams::default()).label(), "cascade");
        assert_eq!(
            PolicyKind::Cascade(CascadeParams::ablation(1)).label(),
            "cascade[d=1,b=0,h=0]"
        );
    }
}
