//! Drafters: prompt-lookup n-gram matching (the paper's primary technique,
//! [38] in the paper) — model-free, no probability distribution, which is
//! exactly why prior dynamic-K schemes (§2.6) cannot drive it and Cascade
//! can. The draft-model drafter (EAGLE-lite) lives in
//! `coordinator::eagle` because it owns a `ModelRuntime`.

/// Prompt-lookup n-gram drafter: find the longest recent n-gram suffix of
/// the context that occurred earlier, and propose the tokens that followed
/// that earlier occurrence.
#[derive(Debug, Clone)]
pub struct NgramDrafter {
    /// Longest suffix n-gram length to try.
    pub max_n: usize,
    /// Shortest acceptable match.
    pub min_n: usize,
}

impl NgramDrafter {
    pub fn new(min_n: usize, max_n: usize) -> Self {
        assert!(min_n >= 1 && max_n >= min_n);
        Self { max_n, min_n }
    }

    /// Propose up to `k` draft tokens given the full context
    /// (prompt + generated so far). Returns fewer (possibly zero) tokens if
    /// no n-gram match exists — the caller then runs a plain decode step.
    pub fn propose(&self, context: &[u32], k: usize) -> Vec<u32> {
        if k == 0 || context.len() < self.min_n + 1 {
            return Vec::new();
        }
        for n in (self.min_n..=self.max_n.min(context.len() - 1)).rev() {
            let suffix = &context[context.len() - n..];
            // Most recent earlier occurrence with a *full* k-token
            // continuation wins (recency bias, as in prompt-lookup
            // decoding); occurrences too close to the end only provide a
            // truncated draft, kept as a fallback.
            let mut best: Option<(usize, usize)> = None; // (start, len)
            let mut i = context.len() - n;
            while i > 0 {
                i -= 1;
                if &context[i..i + n] == suffix {
                    let start = i + n;
                    let len = k.min(context.len() - start);
                    if len == k {
                        best = Some((start, len));
                        break;
                    }
                    if len > best.map_or(0, |(_, l)| l) {
                        best = Some((start, len));
                    }
                }
            }
            if let Some((start, len)) = best {
                return context[start..start + len].to_vec();
            }
        }
        Vec::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn finds_repeat_continuation() {
        // context: a b c d X ... a b c d -> propose X...
        let ctx = [1, 2, 3, 4, 9, 8, 7, 1, 2, 3, 4];
        let d = NgramDrafter::new(2, 4);
        assert_eq!(d.propose(&ctx, 3), vec![9, 8, 7]);
    }

    #[test]
    fn prefers_longest_match() {
        // suffix [5,1,2] occurred earlier (-> 7); shorter [1,2] also
        // occurred with a different continuation (-> 9). Longest wins.
        let ctx = [5, 1, 2, 7, 0, 1, 2, 9, 3, 5, 1, 2];
        let d = NgramDrafter::new(2, 3);
        assert_eq!(d.propose(&ctx, 1), vec![7]);
    }

    #[test]
    fn prefers_recent_occurrence() {
        let ctx = [1, 2, 7, 0, 1, 2, 9, 3, 1, 2];
        let d = NgramDrafter::new(2, 2);
        // suffix [1,2]: occurrences at 0 (->7) and 4 (->9); recency picks 9.
        assert_eq!(d.propose(&ctx, 1), vec![9]);
    }

    #[test]
    fn no_match_returns_empty() {
        let ctx = [1, 2, 3, 4, 5, 6];
        let d = NgramDrafter::new(2, 4);
        assert!(d.propose(&ctx, 3).is_empty());
    }

    #[test]
    fn truncated_continuation() {
        // Match exists but fewer than k tokens follow it before the suffix.
        let ctx = [1, 2, 9, 1, 2];
        let d = NgramDrafter::new(2, 2);
        assert_eq!(d.propose(&ctx, 5), vec![9, 1, 2]);
    }

    #[test]
    fn k_zero_and_short_context() {
        let d = NgramDrafter::new(2, 4);
        assert!(d.propose(&[1, 2, 3], 0).is_empty());
        assert!(d.propose(&[1], 3).is_empty());
    }

    #[test]
    fn repetitive_code_like_text_drafts_well() {
        // Byte-encode two similar "functions"; after seeing one, the drafter
        // should predict large chunks of the second.
        let text = "def f(x):\n    return x\n\ndef g(x):\n    return x\n";
        let ctx = crate::tokenizer::encode(text);
        let d = NgramDrafter::new(2, 4);
        // At the end of the text the suffix "x\n" repeats; expect a proposal.
        assert!(!d.propose(&ctx, 4).is_empty());
    }

    /// Property: proposals are always a verbatim copy of a context span that
    /// followed an occurrence of the current suffix.
    #[test]
    fn prop_proposals_come_from_context() {
        let mut rng = Rng::new(0xD2AF7);
        let d = NgramDrafter::new(2, 4);
        for _ in 0..500 {
            let len = rng.range(4, 60);
            let ctx: Vec<u32> = (0..len).map(|_| rng.below(6) as u32).collect();
            let k = rng.range(1, 7);
            let prop = d.propose(&ctx, k);
            assert!(prop.len() <= k);
            if prop.is_empty() {
                continue;
            }
            // must appear somewhere in the context as a contiguous span
            let found = ctx.windows(prop.len()).any(|w| w == &prop[..]);
            assert!(found, "proposal {prop:?} not a context span of {ctx:?}");
        }
    }
}
