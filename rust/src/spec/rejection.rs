//! Rejection sampling for speculative decoding.
//!
//! Greedy-match acceptance (the deterministic form used with greedy target
//! sampling, as in vLLM's n-gram path): draft token `i` is accepted iff it
//! equals the target model's token at that position **and** all earlier
//! drafts were accepted — acceptance is causal (paper §5.4). The step
//! always emits at least one token (the target's own continuation), so an
//! iteration yields between 1 and K+1 tokens.

/// Outcome of verifying K draft tokens against K+1 target samples.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct VerifyResult {
    /// Number of draft tokens accepted (prefix length).
    pub accepted: usize,
    /// Tokens emitted this iteration: the accepted drafts are confirmed as
    /// `targets[0..accepted]`, plus the bonus/correction `targets[accepted]`.
    pub emitted: Vec<u32>,
}

/// Verify `drafts` against `targets` (`targets.len() == drafts.len() + 1`;
/// `targets[i]` is the target model's token sampled after consuming the
/// prefix ending at draft `i`).
pub fn greedy_verify(drafts: &[u32], targets: &[u32]) -> VerifyResult {
    debug_assert_eq!(targets.len(), drafts.len() + 1);
    let mut accepted = 0;
    for (d, t) in drafts.iter().zip(targets.iter()) {
        if d == t {
            accepted += 1;
        } else {
            break;
        }
    }
    VerifyResult { accepted, emitted: targets[..=accepted].to_vec() }
}

/// Truncate an emission at the first EOS (inclusive). Returns the cut list
/// and whether EOS was hit.
pub fn truncate_at_eos(emitted: &[u32], eos: u32) -> (Vec<u32>, bool) {
    if let Some(pos) = emitted.iter().position(|&t| t == eos) {
        (emitted[..=pos].to_vec(), true)
    } else {
        (emitted.to_vec(), false)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn all_accepted_emits_k_plus_1() {
        let r = greedy_verify(&[5, 6, 7], &[5, 6, 7, 8]);
        assert_eq!(r.accepted, 3);
        assert_eq!(r.emitted, vec![5, 6, 7, 8]);
    }

    #[test]
    fn first_mismatch_stops() {
        let r = greedy_verify(&[5, 9, 7], &[5, 6, 7, 8]);
        assert_eq!(r.accepted, 1);
        assert_eq!(r.emitted, vec![5, 6]); // accepted draft + correction
    }

    #[test]
    fn no_drafts_emit_one() {
        let r = greedy_verify(&[], &[3]);
        assert_eq!(r.accepted, 0);
        assert_eq!(r.emitted, vec![3]);
    }

    #[test]
    fn later_match_after_mismatch_ignored() {
        // Causality: draft 2 "matches" positionally but draft 1 failed.
        let r = greedy_verify(&[1, 2, 3], &[9, 2, 3, 4]);
        assert_eq!(r.accepted, 0);
        assert_eq!(r.emitted, vec![9]);
    }

    #[test]
    fn eos_truncation() {
        let (cut, hit) = truncate_at_eos(&[1, 2, 258, 4], 258);
        assert_eq!(cut, vec![1, 2, 258]);
        assert!(hit);
        let (cut, hit) = truncate_at_eos(&[1, 2], 258);
        assert_eq!(cut, vec![1, 2]);
        assert!(!hit);
    }

    /// Property: acceptance is causal — the accepted prefix matches targets
    /// exactly, and emitted = accepted + 1 tokens (before EOS handling).
    #[test]
    fn prop_causal_acceptance() {
        let mut rng = Rng::new(0x7E57);
        for _ in 0..2000 {
            let k = rng.below(8);
            let drafts: Vec<u32> = (0..k).map(|_| rng.below(16) as u32).collect();
            let targets: Vec<u32> = (0..k + 1).map(|_| rng.below(16) as u32).collect();
            let r = greedy_verify(&drafts, &targets);
            assert!(r.accepted <= k);
            assert_eq!(r.emitted.len(), r.accepted + 1);
            for i in 0..r.accepted {
                assert_eq!(drafts[i], targets[i]);
            }
            if r.accepted < k {
                assert_ne!(drafts[r.accepted], targets[r.accepted]);
            }
            assert_eq!(r.emitted, &targets[..=r.accepted]);
        }
    }
}
