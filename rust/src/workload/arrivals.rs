//! Arrival processes: who shows up when, on the virtual clock.
//!
//! The paper's premise is a *serving* system under bursty traffic, but a
//! closed-loop scheduler (pull a fresh request the instant a slot frees)
//! can never observe queueing delay, TTFT, or tail latency — the offered
//! load is always exactly the service rate. An [`ArrivalProcess`] breaks
//! that loop: requests are stamped with an **arrival time on the engine's
//! virtual clock** (summed simulated iteration seconds, see
//! `BatchEngine::clock_s`) and become admissible only once the clock
//! reaches them. Slots may idle under low rate; queues build under bursts.
//!
//! Four processes:
//! * `closed` — the legacy closed loop (arrival == admission instant);
//!   kept as the default and bit-exact with the pre-arrival scheduler.
//! * `poisson(rate)` — memoryless arrivals at a constant mean rate.
//! * `bursty` — an on/off modulated Poisson process (phases of high and
//!   low rate), the standard bursty-traffic stand-in.
//! * `trace` — JSONL replay: one object per line,
//!   `{"t": <seconds>, "task": "code|math|extract", "max_new": <opt>}`.
//!
//! All randomness comes from the crate's deterministic [`Rng`], so a given
//! (process, seed) pair always produces the identical arrival sequence.

use crate::rng::Rng;
use crate::workload::{Request, RequestStream, Task};
use anyhow::{Context, Result};
use std::collections::VecDeque;

/// Which arrival process drives the run.
#[derive(Debug, Clone, PartialEq)]
pub enum ArrivalKind {
    /// Closed loop: a request "arrives" the instant the scheduler wants
    /// one. Queueing delay is structurally zero at admission time.
    Closed,
    /// Poisson arrivals at `rate` requests per simulated second.
    Poisson { rate: f64 },
    /// On/off modulated Poisson: `on_s` seconds at `rate_on`, then `off_s`
    /// seconds at `rate_off`, repeating. `rate_off` may be 0 (silent gaps).
    Bursty { rate_on: f64, rate_off: f64, on_s: f64, off_s: f64 },
    /// JSONL trace replay (arrival times fixed by the file).
    Trace { path: String },
}

impl ArrivalKind {
    /// Parse the CLI spec: `closed`, `poisson`, `bursty` (both rate-driven
    /// via `--rate`), or `trace:<path>`.
    pub fn parse(spec: &str, rate: f64) -> Result<Self> {
        if let Some(path) = spec.strip_prefix("trace:") {
            anyhow::ensure!(!path.is_empty(), "trace spec needs a path (trace:<file>)");
            return Ok(ArrivalKind::Trace { path: path.to_string() });
        }
        match spec {
            "closed" => Ok(ArrivalKind::Closed),
            "poisson" => {
                anyhow::ensure!(
                    rate > 0.0 && rate.is_finite(),
                    "--arrivals poisson needs a positive finite --rate"
                );
                Ok(ArrivalKind::Poisson { rate })
            }
            "bursty" => {
                anyhow::ensure!(
                    rate > 0.0 && rate.is_finite(),
                    "--arrivals bursty needs a positive finite --rate"
                );
                Ok(ArrivalKind::bursty(rate))
            }
            other => anyhow::bail!(
                "unknown arrivals {other:?} (want closed|poisson|bursty|trace:<path>)"
            ),
        }
    }

    /// Canonical bursty shape at a given *mean* rate: 2-second phases
    /// alternating 1.8x and 0.2x the mean (so the long-run rate is `rate`,
    /// but admission sees 9:1 load swings).
    pub fn bursty(rate: f64) -> Self {
        ArrivalKind::Bursty {
            rate_on: 1.8 * rate,
            rate_off: 0.2 * rate,
            on_s: 2.0,
            off_s: 2.0,
        }
    }

    pub fn is_closed(&self) -> bool {
        *self == ArrivalKind::Closed
    }

    /// Display label for tables and run summaries.
    pub fn label(&self) -> String {
        match self {
            ArrivalKind::Closed => "closed".into(),
            ArrivalKind::Poisson { rate } => format!("poisson({rate:.2}/s)"),
            ArrivalKind::Bursty { rate_on, rate_off, on_s, off_s } => {
                format!("bursty({rate_on:.2}/{rate_off:.2}/s, {on_s:.0}s/{off_s:.0}s)")
            }
            ArrivalKind::Trace { path } => format!("trace:{path}"),
        }
    }
}

/// One pre-parsed trace line.
struct TraceEntry {
    t: f64,
    task: Task,
    max_new: Option<usize>,
}

/// A request stream with arrival times: wraps the deterministic
/// [`RequestStream`] (request *content*) with an [`ArrivalKind`] (request
/// *timing*). Closed mode bypasses timing entirely via [`pull_closed`].
///
/// [`pull_closed`]: ArrivalProcess::pull_closed
pub struct ArrivalProcess {
    kind: ArrivalKind,
    stream: RequestStream,
    rng: Rng,
    /// Time of the last generated arrival (the generator cursor).
    cursor_s: f64,
    /// Generated but not yet released arrival (peek buffer).
    pending: Option<(f64, Request)>,
    // Bursty phase state.
    phase_on: bool,
    phase_end_s: f64,
    trace: VecDeque<TraceEntry>,
}

/// Inverse-CDF exponential sample; `1 - u` lies in (0, 1] so the log is
/// finite and the delta non-negative.
fn exp_sample(rng: &mut Rng, rate: f64) -> f64 {
    -(1.0 - rng.f64()).ln() / rate
}

impl ArrivalProcess {
    /// The legacy closed loop over a request stream.
    pub fn closed(stream: RequestStream) -> Self {
        Self::build(ArrivalKind::Closed, stream, 0, VecDeque::new())
    }

    /// An open-loop process. Trace files are loaded (and validated) here.
    pub fn new(kind: ArrivalKind, stream: RequestStream, seed: u64) -> Result<Self> {
        if let ArrivalKind::Poisson { rate } = kind {
            anyhow::ensure!(
                rate > 0.0 && rate.is_finite(),
                "poisson arrivals need a positive finite rate"
            );
        }
        if let ArrivalKind::Bursty { rate_on, rate_off, on_s, off_s } = kind {
            anyhow::ensure!(
                rate_on > 0.0 || rate_off > 0.0,
                "bursty arrivals need a positive rate in at least one phase"
            );
            anyhow::ensure!(
                on_s > 0.0 && off_s > 0.0 && on_s.is_finite() && off_s.is_finite(),
                "bursty phases need positive finite durations"
            );
            anyhow::ensure!(
                rate_on >= 0.0 && rate_off >= 0.0 && rate_on.is_finite() && rate_off.is_finite(),
                "bursty rates must be non-negative and finite"
            );
        }
        let trace = match &kind {
            ArrivalKind::Trace { path } => Self::load_trace(path)?,
            _ => VecDeque::new(),
        };
        Ok(Self::build(kind, stream, seed, trace))
    }

    fn build(
        kind: ArrivalKind,
        stream: RequestStream,
        seed: u64,
        trace: VecDeque<TraceEntry>,
    ) -> Self {
        let phase_end_s = match kind {
            ArrivalKind::Bursty { on_s, .. } => on_s,
            _ => 0.0,
        };
        Self {
            kind,
            stream,
            rng: Rng::new(seed ^ 0xA881_7AA1),
            cursor_s: 0.0,
            pending: None,
            phase_on: true,
            phase_end_s,
            trace,
        }
    }

    /// Parse a JSONL trace: one `{"t": seconds, "task": name, "max_new":
    /// optional}` object per line (blank lines skipped). Entries are sorted
    /// by `t`, so out-of-order traces replay in arrival order. Lines with a
    /// `"stream"` key — the completed-output records `--capture-trace`
    /// appends for `diff-trace` — are not arrivals and are skipped, so a
    /// captured file replays as-is.
    fn load_trace(path: &str) -> Result<VecDeque<TraceEntry>> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading arrival trace {path}"))?;
        let mut entries: Vec<TraceEntry> = Vec::new();
        for (lineno, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() {
                continue;
            }
            let v = crate::util::json::parse(line)
                .with_context(|| format!("{path}:{}: bad JSON", lineno + 1))?;
            if v.get("stream").is_some() {
                continue;
            }
            let t = v.req("t")?.as_f64()?;
            anyhow::ensure!(
                t.is_finite() && t >= 0.0,
                "{path}:{}: arrival time {t} must be finite and >= 0",
                lineno + 1
            );
            let task = Task::parse(v.req("task")?.as_str()?)
                .with_context(|| format!("{path}:{}", lineno + 1))?;
            let max_new = match v.get("max_new") {
                Some(m) => Some(m.as_usize()?),
                None => None,
            };
            entries.push(TraceEntry { t, task, max_new });
        }
        anyhow::ensure!(!entries.is_empty(), "arrival trace {path} is empty");
        entries.sort_by(|a, b| a.t.total_cmp(&b.t));
        Ok(entries.into())
    }

    pub fn is_closed(&self) -> bool {
        self.kind.is_closed()
    }

    /// Closed-loop pull: the next request, arriving "now" by definition.
    /// Must not be called on an open-loop process (requests would skip the
    /// arrival clock).
    pub fn pull_closed(&mut self) -> Request {
        debug_assert!(self.is_closed(), "pull_closed on an open-loop arrival process");
        self.stream.next_request()
    }

    /// Generate the next arrival (time, request); `None` when the process
    /// is closed or a trace is exhausted.
    fn gen_next(&mut self) -> Option<(f64, Request)> {
        // Match on the place, not a clone: every binding is Copy, so the
        // enum (which carries a heap path in trace mode) is never moved.
        match self.kind {
            ArrivalKind::Closed => None,
            ArrivalKind::Poisson { rate } => {
                self.cursor_s += exp_sample(&mut self.rng, rate);
                let req = self.stream.next_request();
                Some((self.cursor_s, req))
            }
            ArrivalKind::Bursty { rate_on, rate_off, on_s, off_s } => {
                loop {
                    let rate = if self.phase_on { rate_on } else { rate_off };
                    let remaining = (self.phase_end_s - self.cursor_s).max(0.0);
                    if rate > 0.0 {
                        let dt = exp_sample(&mut self.rng, rate);
                        if dt <= remaining {
                            self.cursor_s += dt;
                            let req = self.stream.next_request();
                            return Some((self.cursor_s, req));
                        }
                    }
                    // No arrival in this phase's remainder: jump to the
                    // boundary and flip. Redrawing in the next phase is
                    // exact (exponentials are memoryless).
                    self.cursor_s = self.phase_end_s;
                    self.phase_on = !self.phase_on;
                    self.phase_end_s += if self.phase_on { on_s } else { off_s };
                }
            }
            ArrivalKind::Trace { .. } => {
                let e = self.trace.pop_front()?;
                let mut req = self.stream.next_request_for(e.task);
                if let Some(m) = e.max_new {
                    req.max_new_tokens = m.max(1);
                }
                self.cursor_s = e.t;
                Some((e.t, req))
            }
        }
    }

    fn refill(&mut self) {
        if self.pending.is_none() {
            self.pending = self.gen_next();
        }
    }

    /// Time of the next arrival not yet released (`None` for closed mode or
    /// an exhausted trace). The scheduler advances the engine's idle clock
    /// to this when every slot is empty and nothing has arrived.
    pub fn next_arrival_s(&mut self) -> Option<f64> {
        self.refill();
        self.pending.as_ref().map(|(t, _)| *t)
    }

    /// Release every arrival with time <= `now_s`, in order.
    pub fn due(&mut self, now_s: f64) -> Vec<(f64, Request)> {
        let mut out = Vec::new();
        loop {
            self.refill();
            let is_due = matches!(&self.pending, Some((t, _)) if *t <= now_s);
            if !is_due {
                break;
            }
            out.push(self.pending.take().expect("checked due above"));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::Workload;

    fn stream() -> RequestStream {
        RequestStream::new(Workload::by_name("code+math").unwrap(), 7, 100)
    }

    fn take_times(p: &mut ArrivalProcess, n: usize) -> Vec<f64> {
        (0..n).map(|_| p.gen_next().expect("open process never exhausts").0).collect()
    }

    #[test]
    fn parse_specs() {
        assert!(ArrivalKind::parse("closed", 0.0).unwrap().is_closed());
        assert_eq!(
            ArrivalKind::parse("poisson", 2.0).unwrap(),
            ArrivalKind::Poisson { rate: 2.0 }
        );
        assert!(ArrivalKind::parse("poisson", 0.0).is_err());
        assert!(ArrivalKind::parse("bursty", 0.0).is_err());
        assert!(matches!(
            ArrivalKind::parse("bursty", 1.0).unwrap(),
            ArrivalKind::Bursty { .. }
        ));
        assert_eq!(
            ArrivalKind::parse("trace:/tmp/x.jsonl", 0.0).unwrap(),
            ArrivalKind::Trace { path: "/tmp/x.jsonl".into() }
        );
        assert!(ArrivalKind::parse("trace:", 0.0).is_err());
        assert!(ArrivalKind::parse("uniform", 1.0).is_err());
    }

    #[test]
    fn poisson_deterministic_and_monotone() {
        let mk = || {
            ArrivalProcess::new(ArrivalKind::Poisson { rate: 3.0 }, stream(), 42).unwrap()
        };
        let (mut a, mut b) = (mk(), mk());
        let (ta, tb) = (take_times(&mut a, 50), take_times(&mut b, 50));
        assert_eq!(ta, tb, "same seed must give the identical arrival sequence");
        for w in ta.windows(2) {
            assert!(w[1] >= w[0], "arrival times must be non-decreasing");
        }
        assert!(ta[49] > 0.0);
    }

    #[test]
    fn bursty_silent_phases_are_silent() {
        // rate_off = 0 with 1s/1s phases: every arrival must land in an
        // on-phase, i.e. t mod 2 in [0, 1].
        let kind =
            ArrivalKind::Bursty { rate_on: 5.0, rate_off: 0.0, on_s: 1.0, off_s: 1.0 };
        let mut p = ArrivalProcess::new(kind, stream(), 9).unwrap();
        let times = take_times(&mut p, 80);
        for t in &times {
            let phase = t.rem_euclid(2.0);
            assert!(phase <= 1.0 + 1e-9, "arrival at {t} fell in a silent phase");
        }
        for w in times.windows(2) {
            assert!(w[1] >= w[0]);
        }
    }

    #[test]
    fn due_releases_in_order_and_peek_matches() {
        let mut p =
            ArrivalProcess::new(ArrivalKind::Poisson { rate: 10.0 }, stream(), 1).unwrap();
        let first = p.next_arrival_s().unwrap();
        let batch = p.due(first + 1.0);
        assert!(!batch.is_empty());
        assert!((batch[0].0 - first).abs() < 1e-12, "peeked time must be released first");
        for w in batch.windows(2) {
            assert!(w[1].0 >= w[0].0);
        }
        // Everything released is due; the next peek is beyond the horizon.
        assert!(batch.iter().all(|(t, _)| *t <= first + 1.0));
        assert!(p.next_arrival_s().unwrap() > first + 1.0);
    }

    #[test]
    fn closed_never_generates() {
        let mut p = ArrivalProcess::closed(stream());
        assert!(p.next_arrival_s().is_none());
        assert!(p.due(1e9).is_empty());
        let r = p.pull_closed();
        assert!(!r.prompt.is_empty());
    }

    #[test]
    fn trace_replay_roundtrip() {
        let path = std::env::temp_dir().join("cascade_arrivals_test_trace.jsonl");
        let text = "\
{\"t\": 0.5, \"task\": \"math\", \"max_new\": 32}\n\
{\"t\": 0.1, \"task\": \"code\"}\n\
\n\
{\"t\": 2.0, \"task\": \"extract\", \"max_new\": 64}\n";
        std::fs::write(&path, text).unwrap();
        let kind = ArrivalKind::Trace { path: path.to_string_lossy().into_owned() };
        let mut p = ArrivalProcess::new(kind, stream(), 0).unwrap();
        let a = p.gen_next().unwrap();
        let b = p.gen_next().unwrap();
        let c = p.gen_next().unwrap();
        assert!(p.gen_next().is_none(), "trace must exhaust");
        // Sorted by t: code@0.1, math@0.5 (max_new 32), extract@2.0.
        assert_eq!((a.0, a.1.task), (0.1, Task::Code));
        assert_eq!((b.0, b.1.task), (0.5, Task::Math));
        assert_eq!(b.1.max_new_tokens, 32);
        assert_eq!((c.0, c.1.task), (2.0, Task::Extract));
        assert_eq!(c.1.max_new_tokens, 64);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn stream_lines_are_skipped_on_replay() {
        // A --capture-trace file carries completed-output "stream" lines
        // after the arrivals; the replayer must ignore them.
        let path = std::env::temp_dir().join("cascade_arrivals_stream_lines.jsonl");
        let text = "\
{\"t\": 0.1, \"task\": \"code\"}\n\
{\"stream\": 0, \"task\": \"code\", \"tokens\": [1, 2, 3]}\n\
{\"t\": 0.7, \"task\": \"math\", \"max_new\": 16}\n\
{\"stream\": 1, \"task\": \"math\", \"tokens\": []}\n";
        std::fs::write(&path, text).unwrap();
        let kind = ArrivalKind::Trace { path: path.to_string_lossy().into_owned() };
        let mut p = ArrivalProcess::new(kind, stream(), 0).unwrap();
        let a = p.gen_next().unwrap();
        let b = p.gen_next().unwrap();
        assert!(p.gen_next().is_none());
        assert_eq!((a.0, a.1.task), (0.1, Task::Code));
        assert_eq!((b.0, b.1.task), (0.7, Task::Math));
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn bad_traces_are_errors() {
        let dir = std::env::temp_dir();
        let empty = dir.join("cascade_arrivals_empty.jsonl");
        std::fs::write(&empty, "\n\n").unwrap();
        let kind = ArrivalKind::Trace { path: empty.to_string_lossy().into_owned() };
        assert!(ArrivalProcess::new(kind, stream(), 0).is_err());
        let _ = std::fs::remove_file(&empty);

        let bad = dir.join("cascade_arrivals_bad.jsonl");
        std::fs::write(&bad, "{\"t\": -1.0, \"task\": \"code\"}\n").unwrap();
        let kind = ArrivalKind::Trace { path: bad.to_string_lossy().into_owned() };
        assert!(ArrivalProcess::new(kind, stream(), 0).is_err());
        let _ = std::fs::remove_file(&bad);
    }
}
