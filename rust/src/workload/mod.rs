//! Workloads: tasks, mixed request streams, and request construction.
//!
//! Mirrors the paper's §3 evaluation setup: three decode-heavy tasks —
//! `code` (HumanEval-like), `math` (GSM8K-like chain-of-thought), `extract`
//! (MT-Bench extraction) — plus four mixes with equal request shares
//! (code+math, math+extract, code+extract, all-3). Corpus text is
//! synthesized (`corpus.rs`) with the drafter-relevant statistics of each
//! task; see DESIGN.md §Substitutions.

pub mod arrivals;
pub mod corpus;

use crate::rng::Rng;
use crate::tokenizer;

/// A single-task workload.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Task {
    Code,
    Math,
    Extract,
}

impl Task {
    pub fn parse(s: &str) -> anyhow::Result<Task> {
        match s {
            "code" => Ok(Task::Code),
            "math" => Ok(Task::Math),
            "extract" => Ok(Task::Extract),
            other => anyhow::bail!("unknown task {other:?} (want code|math|extract)"),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Task::Code => "code",
            Task::Math => "math",
            Task::Extract => "extract",
        }
    }

    /// Per-task guided-decoding deviation rate (see `sampling`): how often
    /// the model "disagrees" with the reference — the knob that makes
    /// drafter accuracy task-dependent (code predictable, math digits not).
    pub fn deviation_eps(&self) -> f64 {
        match self {
            Task::Code => 0.015,
            Task::Math => 0.15,
            Task::Extract => 0.04,
        }
    }
}

/// A task mix (the paper's seven workloads).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Workload {
    pub name: String,
    pub tasks: Vec<Task>,
}

impl Workload {
    pub fn single(task: Task) -> Self {
        Self { name: task.name().to_string(), tasks: vec![task] }
    }

    pub fn mix(name: &str, tasks: Vec<Task>) -> Self {
        Self { name: name.to_string(), tasks }
    }

    /// The paper's seven evaluated workloads (§3, Fig. 5/13).
    pub fn all_seven() -> Vec<Workload> {
        use Task::*;
        vec![
            Workload::single(Code),
            Workload::single(Math),
            Workload::single(Extract),
            Workload::mix("code+math", vec![Code, Math]),
            Workload::mix("math+extract", vec![Math, Extract]),
            Workload::mix("code+extract", vec![Code, Extract]),
            Workload::mix("all-3", vec![Code, Math, Extract]),
        ]
    }

    pub fn by_name(name: &str) -> Option<Workload> {
        Self::all_seven().into_iter().find(|w| w.name == name)
    }
}

/// One serving request: prompt tokens + the reference continuation that
/// guided decoding follows (DESIGN.md §Substitutions).
#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    pub task: Task,
    pub prompt: Vec<u32>,
    pub reference: Vec<u32>,
    /// Guided-decoding deviation rate for this request.
    pub eps: f64,
    pub max_new_tokens: usize,
}

/// Preamble length of the template-heavy prefix-sharing mode, in tokens:
/// 8 KV blocks of 16 and exactly 2 prefill chunks of 64, so a template hit
/// skips whole blocks *and* whole chunk charges
/// (rust/docs/prefix_cache.md).
pub const PREFIX_PREAMBLE_TOKENS: usize = 128;

/// Number of distinct shared templates the prefix-sharing mode draws from.
pub const PREFIX_TEMPLATE_COUNT: usize = 4;

/// The fixed token body of shared template `idx` (taken modulo
/// [`PREFIX_TEMPLATE_COUNT`]): a deterministic printable-byte sequence, so
/// every stream — whatever its seed — agrees on what "template 2" is and
/// the prefix trie can share it across requests and runs.
pub fn template_preamble(idx: usize) -> Vec<u32> {
    let idx = idx % PREFIX_TEMPLATE_COUNT;
    (0..PREFIX_PREAMBLE_TOKENS)
        .map(|i| (32 + (idx * 53 + i * 17 + (i * i) % 31) % 95) as u32)
        .collect()
}

/// Deterministic request stream over a workload (round-robin across the
/// mix's tasks, per the paper's equal-share mixes).
pub struct RequestStream {
    workload: Workload,
    rng: Rng,
    next_id: u64,
    max_new_tokens: usize,
    /// Template-heavy preamble mode (`with_prefix_templates`); off for
    /// [`Self::new`] streams, which stay preamble-free.
    preamble: bool,
    /// Probability that a request's preamble is drawn from the shared
    /// template pool rather than being request-unique.
    prefix_share: f64,
}

impl RequestStream {
    pub fn new(workload: Workload, seed: u64, max_new_tokens: usize) -> Self {
        Self {
            workload,
            rng: Rng::new(seed),
            next_id: 0,
            max_new_tokens,
            preamble: false,
            prefix_share: 0.0,
        }
    }

    /// A template-heavy stream for prefix-sharing runs: **every** request
    /// gets a [`PREFIX_PREAMBLE_TOKENS`]-token preamble prepended to its
    /// prompt — with probability `share` one of the
    /// [`PREFIX_TEMPLATE_COUNT`] shared templates, otherwise a
    /// request-unique preamble of the same length. Prompt-length and
    /// corpus-content distributions are therefore identical across `share`
    /// values — `share == 0` still prepends (all-unique) preambles — so
    /// TTFT differences between two shares are attributable to cache hits
    /// alone. Preamble draws come after corpus generation on the request's
    /// forked rng, so the corpus content itself is share-independent.
    pub fn with_prefix_templates(
        workload: Workload,
        seed: u64,
        max_new_tokens: usize,
        share: f64,
    ) -> Self {
        let mut s = Self::new(workload, seed, max_new_tokens);
        s.preamble = true;
        s.prefix_share = share.clamp(0.0, 1.0);
        s
    }

    /// Generate the next request (round-robin task per the workload mix).
    pub fn next_request(&mut self) -> Request {
        let task = self.workload.tasks[(self.next_id as usize) % self.workload.tasks.len()];
        self.next_request_for(task)
    }

    /// Generate the next request with an explicit task (trace replay picks
    /// the task per trace line; the id/rng stream advances identically to
    /// `next_request`, so mixing the two stays deterministic).
    pub fn next_request_for(&mut self, task: Task) -> Request {
        let mut rng = self.rng.fork(self.next_id);
        let (prompt_text, reference_text) = corpus::generate(task, &mut rng);
        let mut prompt = tokenizer::encode(&prompt_text);
        if self.preamble {
            // Preamble draws come *after* corpus generation on the
            // request's forked rng, so enabling the mode never perturbs
            // the corpus content (and other requests fork fresh).
            let mut preamble = if rng.chance(self.prefix_share) {
                template_preamble(rng.below(PREFIX_TEMPLATE_COUNT))
            } else {
                (0..PREFIX_PREAMBLE_TOKENS).map(|_| (32 + rng.below(95)) as u32).collect()
            };
            preamble.append(&mut prompt);
            prompt = preamble;
        }
        let req = Request {
            id: self.next_id,
            task,
            prompt,
            reference: tokenizer::encode(&reference_text),
            eps: task.deviation_eps(),
            max_new_tokens: self.max_new_tokens,
        };
        self.next_id += 1;
        req
    }

    /// Generate `n` requests.
    pub fn take(&mut self, n: usize) -> Vec<Request> {
        (0..n).map(|_| self.next_request()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn seven_workloads_match_paper() {
        let all = Workload::all_seven();
        assert_eq!(all.len(), 7);
        let names: Vec<&str> = all.iter().map(|w| w.name.as_str()).collect();
        assert!(names.contains(&"code"));
        assert!(names.contains(&"math+extract"));
        assert!(names.contains(&"all-3"));
    }

    #[test]
    fn mixes_round_robin() {
        let w = Workload::by_name("code+math").unwrap();
        let mut s = RequestStream::new(w, 1, 100);
        let reqs = s.take(4);
        assert_eq!(reqs[0].task, Task::Code);
        assert_eq!(reqs[1].task, Task::Math);
        assert_eq!(reqs[2].task, Task::Code);
        assert_eq!(reqs[3].task, Task::Math);
    }

    #[test]
    fn streams_deterministic() {
        let w = Workload::single(Task::Code);
        let a = RequestStream::new(w.clone(), 9, 100).take(3);
        let b = RequestStream::new(w, 9, 100).take(3);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.prompt, y.prompt);
            assert_eq!(x.reference, y.reference);
        }
    }

    #[test]
    fn requests_nonempty_and_in_vocab() {
        for task in [Task::Code, Task::Math, Task::Extract] {
            let mut s = RequestStream::new(Workload::single(task), 3, 100);
            let r = s.next_request();
            assert!(r.prompt.len() > 20, "{task:?} prompt too short");
            assert!(r.reference.len() > 80, "{task:?} reference too short");
            assert!(r.prompt.iter().all(|&t| (t as usize) < tokenizer::VOCAB));
            assert!(r.reference.iter().all(|&t| (t as usize) < tokenizer::VOCAB));
        }
    }

    #[test]
    fn requests_vary_between_ids() {
        let mut s = RequestStream::new(Workload::single(Task::Math), 5, 100);
        let a = s.next_request();
        let b = s.next_request();
        assert_ne!(a.reference, b.reference);
    }

    #[test]
    fn preamble_mode_wraps_the_plain_stream_and_share_zero_is_all_unique() {
        let w = Workload::by_name("code+math").unwrap();
        let plain = RequestStream::new(w.clone(), 11, 80).take(6);
        let wrapped = RequestStream::with_prefix_templates(w, 11, 80, 0.0).take(6);
        let templates: Vec<Vec<u32>> =
            (0..PREFIX_TEMPLATE_COUNT).map(template_preamble).collect();
        for (x, y) in plain.iter().zip(&wrapped) {
            // The corpus suffix is exactly the plain stream's prompt: the
            // mode only prepends, never rewrites.
            assert_eq!(y.prompt.len(), x.prompt.len() + PREFIX_PREAMBLE_TOKENS);
            assert_eq!(y.prompt[PREFIX_PREAMBLE_TOKENS..], x.prompt[..]);
            assert_eq!(x.reference, y.reference);
            let head = y.prompt[..PREFIX_PREAMBLE_TOKENS].to_vec();
            assert!(
                !templates.contains(&head),
                "share 0 preambles must be request-unique, not templates"
            );
        }
        // Unique preambles really are unique across requests.
        let heads: Vec<&[u32]> =
            wrapped.iter().map(|r| &r.prompt[..PREFIX_PREAMBLE_TOKENS]).collect();
        for (i, h) in heads.iter().enumerate() {
            assert!(!heads[..i].contains(h), "unique preambles collided");
        }
    }

    #[test]
    fn template_preambles_are_shared_deterministic_and_in_vocab() {
        let w = Workload::single(Task::Code);
        let a = RequestStream::with_prefix_templates(w.clone(), 4, 80, 1.0).take(8);
        let b = RequestStream::with_prefix_templates(w, 4, 80, 1.0).take(8);
        let templates: Vec<Vec<u32>> =
            (0..PREFIX_TEMPLATE_COUNT).map(template_preamble).collect();
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.prompt, y.prompt, "template streams must be deterministic");
            let head = &x.prompt[..PREFIX_PREAMBLE_TOKENS];
            assert!(
                templates.iter().any(|t| t == head),
                "share=1 preamble must come from the shared pool"
            );
            assert!(x.prompt.iter().all(|&t| (t as usize) < tokenizer::VOCAB));
        }
        // With 8 draws over 4 templates at least two requests collide —
        // the whole point of the mode (pigeonhole, no randomness needed).
        let heads: Vec<&[u32]> =
            a.iter().map(|r| &r.prompt[..PREFIX_PREAMBLE_TOKENS]).collect();
        assert!(
            heads.iter().enumerate().any(|(i, h)| heads[..i].contains(h)),
            "8 template draws over 4 templates must repeat one"
        );
    }

    #[test]
    fn share_changes_cacheability_not_length_or_corpus() {
        let w = Workload::single(Task::Math);
        let lo = RequestStream::with_prefix_templates(w.clone(), 6, 80, 0.3).take(5);
        let hi = RequestStream::with_prefix_templates(w, 6, 80, 0.9).take(5);
        for (x, y) in lo.iter().zip(&hi) {
            assert_eq!(x.prompt.len(), y.prompt.len(), "length distribution must match");
            assert_eq!(
                x.prompt[PREFIX_PREAMBLE_TOKENS..],
                y.prompt[PREFIX_PREAMBLE_TOKENS..],
                "corpus suffix must be share-independent"
            );
            assert_eq!(x.reference, y.reference);
        }
    }

    #[test]
    fn preamble_is_whole_blocks_and_whole_chunks() {
        // 16-token KV blocks and 64-token prefill chunks both divide the
        // preamble, so a template hit frees whole blocks and whole chunk
        // charges (rust/docs/prefix_cache.md).
        assert_eq!(PREFIX_PREAMBLE_TOKENS % 16, 0);
        assert_eq!(PREFIX_PREAMBLE_TOKENS % 64, 0);
        for i in 0..PREFIX_TEMPLATE_COUNT {
            for j in 0..PREFIX_TEMPLATE_COUNT {
                assert_eq!(
                    template_preamble(i) == template_preamble(j),
                    i == j,
                    "templates must be distinct exactly when indices differ"
                );
            }
        }
    }
}
