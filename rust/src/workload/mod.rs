//! Workloads: tasks, mixed request streams, and request construction.
//!
//! Mirrors the paper's §3 evaluation setup: three decode-heavy tasks —
//! `code` (HumanEval-like), `math` (GSM8K-like chain-of-thought), `extract`
//! (MT-Bench extraction) — plus four mixes with equal request shares
//! (code+math, math+extract, code+extract, all-3). Corpus text is
//! synthesized (`corpus.rs`) with the drafter-relevant statistics of each
//! task; see DESIGN.md §Substitutions.

pub mod arrivals;
pub mod corpus;

use crate::rng::Rng;
use crate::tokenizer;

/// A single-task workload.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Task {
    Code,
    Math,
    Extract,
}

impl Task {
    pub fn parse(s: &str) -> anyhow::Result<Task> {
        match s {
            "code" => Ok(Task::Code),
            "math" => Ok(Task::Math),
            "extract" => Ok(Task::Extract),
            other => anyhow::bail!("unknown task {other:?} (want code|math|extract)"),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Task::Code => "code",
            Task::Math => "math",
            Task::Extract => "extract",
        }
    }

    /// Per-task guided-decoding deviation rate (see `sampling`): how often
    /// the model "disagrees" with the reference — the knob that makes
    /// drafter accuracy task-dependent (code predictable, math digits not).
    pub fn deviation_eps(&self) -> f64 {
        match self {
            Task::Code => 0.015,
            Task::Math => 0.15,
            Task::Extract => 0.04,
        }
    }
}

/// A task mix (the paper's seven workloads).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Workload {
    pub name: String,
    pub tasks: Vec<Task>,
}

impl Workload {
    pub fn single(task: Task) -> Self {
        Self { name: task.name().to_string(), tasks: vec![task] }
    }

    pub fn mix(name: &str, tasks: Vec<Task>) -> Self {
        Self { name: name.to_string(), tasks }
    }

    /// The paper's seven evaluated workloads (§3, Fig. 5/13).
    pub fn all_seven() -> Vec<Workload> {
        use Task::*;
        vec![
            Workload::single(Code),
            Workload::single(Math),
            Workload::single(Extract),
            Workload::mix("code+math", vec![Code, Math]),
            Workload::mix("math+extract", vec![Math, Extract]),
            Workload::mix("code+extract", vec![Code, Extract]),
            Workload::mix("all-3", vec![Code, Math, Extract]),
        ]
    }

    pub fn by_name(name: &str) -> Option<Workload> {
        Self::all_seven().into_iter().find(|w| w.name == name)
    }
}

/// One serving request: prompt tokens + the reference continuation that
/// guided decoding follows (DESIGN.md §Substitutions).
#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    pub task: Task,
    pub prompt: Vec<u32>,
    pub reference: Vec<u32>,
    /// Guided-decoding deviation rate for this request.
    pub eps: f64,
    pub max_new_tokens: usize,
}

/// Deterministic request stream over a workload (round-robin across the
/// mix's tasks, per the paper's equal-share mixes).
pub struct RequestStream {
    workload: Workload,
    rng: Rng,
    next_id: u64,
    max_new_tokens: usize,
}

impl RequestStream {
    pub fn new(workload: Workload, seed: u64, max_new_tokens: usize) -> Self {
        Self { workload, rng: Rng::new(seed), next_id: 0, max_new_tokens }
    }

    /// Generate the next request (round-robin task per the workload mix).
    pub fn next_request(&mut self) -> Request {
        let task = self.workload.tasks[(self.next_id as usize) % self.workload.tasks.len()];
        self.next_request_for(task)
    }

    /// Generate the next request with an explicit task (trace replay picks
    /// the task per trace line; the id/rng stream advances identically to
    /// `next_request`, so mixing the two stays deterministic).
    pub fn next_request_for(&mut self, task: Task) -> Request {
        let mut rng = self.rng.fork(self.next_id);
        let (prompt_text, reference_text) = corpus::generate(task, &mut rng);
        let req = Request {
            id: self.next_id,
            task,
            prompt: tokenizer::encode(&prompt_text),
            reference: tokenizer::encode(&reference_text),
            eps: task.deviation_eps(),
            max_new_tokens: self.max_new_tokens,
        };
        self.next_id += 1;
        req
    }

    /// Generate `n` requests.
    pub fn take(&mut self, n: usize) -> Vec<Request> {
        (0..n).map(|_| self.next_request()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn seven_workloads_match_paper() {
        let all = Workload::all_seven();
        assert_eq!(all.len(), 7);
        let names: Vec<&str> = all.iter().map(|w| w.name.as_str()).collect();
        assert!(names.contains(&"code"));
        assert!(names.contains(&"math+extract"));
        assert!(names.contains(&"all-3"));
    }

    #[test]
    fn mixes_round_robin() {
        let w = Workload::by_name("code+math").unwrap();
        let mut s = RequestStream::new(w, 1, 100);
        let reqs = s.take(4);
        assert_eq!(reqs[0].task, Task::Code);
        assert_eq!(reqs[1].task, Task::Math);
        assert_eq!(reqs[2].task, Task::Code);
        assert_eq!(reqs[3].task, Task::Math);
    }

    #[test]
    fn streams_deterministic() {
        let w = Workload::single(Task::Code);
        let a = RequestStream::new(w.clone(), 9, 100).take(3);
        let b = RequestStream::new(w, 9, 100).take(3);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.prompt, y.prompt);
            assert_eq!(x.reference, y.reference);
        }
    }

    #[test]
    fn requests_nonempty_and_in_vocab() {
        for task in [Task::Code, Task::Math, Task::Extract] {
            let mut s = RequestStream::new(Workload::single(task), 3, 100);
            let r = s.next_request();
            assert!(r.prompt.len() > 20, "{task:?} prompt too short");
            assert!(r.reference.len() > 80, "{task:?} reference too short");
            assert!(r.prompt.iter().all(|&t| (t as usize) < tokenizer::VOCAB));
            assert!(r.reference.iter().all(|&t| (t as usize) < tokenizer::VOCAB));
        }
    }

    #[test]
    fn requests_vary_between_ids() {
        let mut s = RequestStream::new(Workload::single(Task::Math), 5, 100);
        let a = s.next_request();
        let b = s.next_request();
        assert_ne!(a.reference, b.reference);
    }
}
