//! Synthetic task corpora with the drafter-relevant text statistics of the
//! paper's datasets (§3: HumanEval, GSM8K, MT-Bench extraction).
//!
//! What matters for speculation is not semantic quality but *predictability
//! structure*:
//! * **code** — heavy boilerplate repetition (signatures, indentation,
//!   `return` patterns) ⇒ long n-gram matches, high acceptance (the paper's
//!   best case: ETR up to ≈3x at K=7).
//! * **math** — natural-language scaffolding is repetitive but every
//!   arithmetic step introduces fresh digits ⇒ frequent n-gram misses (the
//!   paper's worst case).
//! * **extract** — answers copy spans from the prompt passage ⇒ *phases* of
//!   very high acceptance during span copies separated by misses (the
//!   phased ETR of paper Fig. 6).

use crate::rng::Rng;

/// Generate a `(prompt, reference_continuation)` pair for `task`.
pub fn generate(task: super::Task, rng: &mut Rng) -> (String, String) {
    match task {
        super::Task::Code => code(rng),
        super::Task::Math => math(rng),
        super::Task::Extract => extract(rng),
    }
}

const VERBS: &[&str] = &["scale", "shift", "clamp", "fold", "merge", "rank"];
const NAMES: &[&str] = &["alice", "tom", "maria", "chen", "ravi", "lena"];
const ITEMS: &[&str] = &["apples", "marbles", "tickets", "coins", "books", "stamps"];

/// HumanEval-like: a request to implement several similar helpers. The
/// reference is boilerplate-heavy Python.
fn code(rng: &mut Rng) -> (String, String) {
    let verb = VERBS[rng.below(VERBS.len())];
    let n_funcs = rng.range(3, 5);
    let prompt = format!(
        "# Task: implement {n_funcs} helpers that {verb} integer lists.\n\
         # Follow the house style used below.\n\
         def {verb}_base(xs):\n    out = []\n    for x in xs:\n        out.append(x)\n    return out\n\n"
    );
    let mut body = String::new();
    for i in 0..n_funcs {
        let c = rng.range(2, 9);
        body.push_str(&format!(
            "def {verb}_{i}(xs):\n    out = []\n    for x in xs:\n        y = x + {c}\n        out.append(y)\n    return out\n\n"
        ));
    }
    (prompt, body)
}

/// GSM8K-like chain-of-thought: repetitive sentence scaffolding around
/// unpredictable numbers.
fn math(rng: &mut Rng) -> (String, String) {
    let name = NAMES[rng.below(NAMES.len())];
    let item = ITEMS[rng.below(ITEMS.len())];
    let start = rng.range(12, 97);
    // Like GSM8K, the question lists the quantities the chain-of-thought
    // will reuse; the *results* of each arithmetic step are fresh digits,
    // which is what breaks n-gram drafting on math (paper §2.5).
    let n_trades = rng.range(6, 9);
    let deltas: Vec<i64> = (0..n_trades).map(|_| rng.range(3, 48) as i64).collect();
    let gives: Vec<bool> = (0..n_trades).map(|_| rng.chance(0.5)).collect();
    let trades: Vec<String> = deltas
        .iter()
        .zip(&gives)
        .map(|(d, g)| format!("{} {d}", if *g { "gives away" } else { "buys" }))
        .collect();
    let prompt = format!(
        "Q: {name} has {start} {item}. Trades: {}. \
         Work out how many {item} {name} ends with, step by step.\nA: ",
        trades.join(", ")
    );
    let mut total = start as i64;
    let mut body = format!("{name} starts with {total} {item}. ");
    for (delta, give) in deltas.iter().zip(&gives) {
        if *give && total > *delta {
            body.push_str(&format!(
                "Then {name} gives away {delta} {item}. {total} - {delta} = {}. ",
                total - delta
            ));
            total -= delta;
        } else {
            body.push_str(&format!(
                "Then {name} buys {delta} more {item}. {total} + {delta} = {}. ",
                total + delta
            ));
            total += delta;
        }
    }
    body.push_str(&format!("The answer is {total}.\n"));
    (prompt, body)
}

/// MT-Bench-extraction-like: a passage of facts; the answer copies spans
/// back out as a bullet list.
fn extract(rng: &mut Rng) -> (String, String) {
    let quarter = rng.range(1, 4);
    let revenue = rng.range(10, 99);
    let growth = rng.range(2, 19);
    let name = NAMES[rng.below(NAMES.len())];
    let year = rng.range(2019, 2025);
    let facts = [
        format!("revenue for quarter {quarter} reached {revenue}.{growth} million dollars"),
        format!("the lead engineer, {name}, joined the team in {year}"),
        format!("customer count grew by {growth} percent over the quarter"),
        format!("the platform migration finished {quarter} weeks ahead of schedule"),
        format!("operating costs fell to {revenue} thousand dollars per month"),
    ];
    let mut prompt = String::from("Passage: ");
    for f in &facts {
        prompt.push_str(f);
        prompt.push_str(". ");
    }
    prompt.push_str("\nQ: Extract every fact from the passage as a bullet list.\nA:\n");
    let mut body = String::new();
    for f in &facts {
        body.push_str("- ");
        body.push_str(f);
        body.push('\n');
    }
    (prompt, body)
}

/// Repetition score used by tests: fraction of 4-grams that repeat.
#[cfg(test)]
fn repeat_fraction(text: &str, n: usize) -> f64 {
    use std::collections::BTreeMap;
    let b = text.as_bytes();
    if b.len() <= n {
        return 0.0;
    }
    let mut counts: BTreeMap<&[u8], usize> = BTreeMap::new();
    for w in b.windows(n) {
        *counts.entry(w).or_default() += 1;
    }
    let repeated: usize = counts.values().filter(|&&c| c > 1).map(|&c| c).sum();
    repeated as f64 / (b.len() - n + 1) as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::Task;

    #[test]
    fn code_is_most_repetitive() {
        let mut rng = Rng::new(1);
        let (_, code_ref) = code(&mut rng);
        let (_, math_ref) = math(&mut rng);
        let code_rep = repeat_fraction(&code_ref, 8);
        let math_rep = repeat_fraction(&math_ref, 8);
        assert!(code_rep > math_rep, "code {code_rep} vs math {math_rep}");
        assert!(code_rep > 0.6, "code should be heavily boilerplate: {code_rep}");
    }

    #[test]
    fn extract_answer_copies_prompt_spans() {
        let mut rng = Rng::new(2);
        let (prompt, body) = extract(&mut rng);
        // Every bullet (minus "- " and newline) must be a prompt substring —
        // this is what makes prompt-lookup n-gram drafting effective.
        for line in body.lines() {
            let span = line.trim_start_matches("- ").trim_end();
            assert!(prompt.contains(span), "span not in prompt: {span}");
        }
    }

    #[test]
    fn math_numbers_are_consistent() {
        let mut rng = Rng::new(3);
        let (_, body) = math(&mut rng);
        // The final answer must equal the last arithmetic result.
        let answer: i64 = body
            .rsplit("The answer is ")
            .next()
            .unwrap()
            .trim_end_matches(['.', '\n'])
            .parse()
            .unwrap();
        let last_eq: i64 = body
            .rsplit("= ")
            .next()
            .map(|_| {
                body.match_indices("= ")
                    .last()
                    .map(|(i, _)| {
                        body[i + 2..]
                            .split('.')
                            .next()
                            .unwrap()
                            .trim()
                            .parse()
                            .unwrap()
                    })
                    .unwrap()
            })
            .unwrap();
        assert_eq!(answer, last_eq);
    }

    #[test]
    fn generate_dispatches() {
        let mut rng = Rng::new(4);
        for t in [Task::Code, Task::Math, Task::Extract] {
            let (p, r) = generate(t, &mut rng);
            assert!(!p.is_empty() && !r.is_empty());
        }
    }
}
