//! Serving telemetry: per-iteration records, per-request summaries, and the
//! windowed statistics the paper's figures are built from (ETR, cost,
//! utility over 16-iteration windows; TPOT; throughput).

use crate::cost::IterCost;

/// What phase of the speculation policy an iteration belonged to.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum IterPhase {
    /// Forced K=0 while measuring the no-speculation baseline.
    Baseline,
    /// Test-phase trial iteration.
    Test,
    /// Set-phase iteration.
    Set,
}

/// One decode iteration of one request.
#[derive(Debug, Clone, Copy)]
pub struct IterRecord {
    /// Speculation length the policy chose.
    pub k_chosen: usize,
    /// Draft tokens actually proposed (n-gram may find fewer than K).
    pub drafted: usize,
    /// Draft tokens accepted by the rejection sampler.
    pub accepted: usize,
    /// Output tokens emitted (= accepted + 1 = ETR of this iteration).
    pub emitted: usize,
    /// Simulated GPU cost breakdown.
    pub cost: IterCost,
    /// Wall-clock of the full iteration on this host (ns).
    pub wall_ns: u64,
    /// Mean unique experts per layer activated by the verify step.
    pub unique_experts: f64,
    pub phase: IterPhase,
}

impl IterRecord {
    /// Effective token rate of this iteration.
    pub fn etr(&self) -> f64 {
        self.emitted as f64
    }
}

/// Full decode trace of one request.
#[derive(Debug, Clone, Default)]
pub struct RequestMetrics {
    pub id: u64,
    pub task: String,
    pub iters: Vec<IterRecord>,
    pub prompt_tokens: usize,
    /// Simulated prefill time (not counted in TPOT, per the paper's
    /// decode-latency focus).
    pub prefill_s: f64,
    pub wall_total_ns: u64,
    /// The emitted token stream (first token + every decode emission) —
    /// what losslessness and batch-determinism tests compare.
    pub output: Vec<u32>,
    /// How many times this request was preempted (evicted from the shared
    /// KV pool and later re-admitted). 0 with `eviction = off`.
    pub preemptions: usize,
    /// Simulated seconds spent re-prefilling this request's committed
    /// context after evictions (charged to the decode clock, unlike
    /// `prefill_s`).
    pub reprefill_s: f64,
    /// Arrival stamp on the engine's virtual clock (simulated seconds).
    /// Closed-loop serving stamps arrival at the pull instant, so queueing
    /// delay is nonzero only when pool pressure deferred admission.
    pub arrival_s: f64,
    /// First admission instant on the virtual clock.
    pub admitted_s: f64,
    /// Instant the first output token existed (prefill end) — TTFT's
    /// endpoint.
    pub first_token_s: f64,
    /// Instant the request finished (finalized) on the virtual clock.
    pub finish_s: f64,
    /// Cumulative out-of-service wait on the virtual clock: arrival →
    /// first admission, plus every parked interval between an eviction and
    /// its re-admission. The queueing-delay figure of merit — unlike
    /// `admitted_s - arrival_s` it keeps counting when a victim waits to
    /// get back in.
    pub queue_wait_s: f64,
}

impl RequestMetrics {
    pub fn tokens_emitted(&self) -> usize {
        self.iters.iter().map(|r| r.emitted).sum()
    }

    /// Time to first token on the virtual clock: arrival → prefill end
    /// (includes queueing delay, unlike TPOT's decode-only view).
    pub fn ttft_s(&self) -> f64 {
        self.first_token_s - self.arrival_s
    }

    /// End-to-end latency on the virtual clock: arrival → finalize.
    pub fn e2e_s(&self) -> f64 {
        self.finish_s - self.arrival_s
    }

    /// Simulated decode time.
    pub fn decode_s(&self) -> f64 {
        self.iters.iter().map(|r| r.cost.total()).sum()
    }

    /// Time per output token (simulated GPU clock) — the paper's key metric.
    pub fn tpot_s(&self) -> f64 {
        let toks = self.tokens_emitted();
        if toks == 0 {
            return f64::NAN;
        }
        self.decode_s() / toks as f64
    }

    /// Mean effective token rate (tokens per iteration).
    pub fn etr(&self) -> f64 {
        if self.iters.is_empty() {
            return f64::NAN;
        }
        self.tokens_emitted() as f64 / self.iters.len() as f64
    }

    /// Mean iteration cost (simulated seconds).
    pub fn mean_iter_s(&self) -> f64 {
        if self.iters.is_empty() {
            return f64::NAN;
        }
        self.decode_s() / self.iters.len() as f64
    }

    /// Windowed (ETR, relative cost, utility) series — the quantity plotted
    /// in the paper's Figs. 6/7/15/16. `baseline_iter_s` normalizes cost.
    pub fn utility_windows(&self, window: usize, baseline_iter_s: f64) -> Vec<WindowStat> {
        assert!(window > 0);
        self.iters
            .chunks(window)
            .enumerate()
            .map(|(i, chunk)| {
                let etr = chunk.iter().map(|r| r.etr()).sum::<f64>() / chunk.len() as f64;
                let iter_s =
                    chunk.iter().map(|r| r.cost.total()).sum::<f64>() / chunk.len() as f64;
                let cost = iter_s / baseline_iter_s;
                WindowStat { window: i, etr, cost, utility: etr / cost }
            })
            .collect()
    }
}

/// One window of the utility trace.
#[derive(Debug, Clone, Copy)]
pub struct WindowStat {
    pub window: usize,
    pub etr: f64,
    /// Iteration time relative to the no-speculation baseline.
    pub cost: f64,
    pub utility: f64,
}

/// Aggregate over a full serving run (many requests).
#[derive(Debug, Clone, Default)]
pub struct RunMetrics {
    pub requests: Vec<RequestMetrics>,
}

impl RunMetrics {
    pub fn push(&mut self, m: RequestMetrics) {
        self.requests.push(m);
    }

    pub fn total_tokens(&self) -> usize {
        self.requests.iter().map(|r| r.tokens_emitted()).sum()
    }

    pub fn total_decode_s(&self) -> f64 {
        self.requests.iter().map(|r| r.decode_s()).sum()
    }

    /// Aggregate TPOT (simulated): total decode time / total tokens.
    pub fn tpot_s(&self) -> f64 {
        let toks = self.total_tokens();
        if toks == 0 {
            return f64::NAN;
        }
        self.total_decode_s() / toks as f64
    }

    /// Output-token throughput (tokens per simulated second) — the paper's
    /// figure of merit (inverse TPOT for single-batch serving).
    pub fn throughput(&self) -> f64 {
        1.0 / self.tpot_s()
    }

    pub fn mean_etr(&self) -> f64 {
        let iters: usize = self.requests.iter().map(|r| r.iters.len()).sum();
        if iters == 0 {
            return f64::NAN;
        }
        self.total_tokens() as f64 / iters as f64
    }

    /// Harmonic mean of per-request utilities relative to `baseline_iter_s`
    /// (the paper plots harmonic-mean utility across requests, Fig. 7).
    pub fn harmonic_mean_utility(&self, baseline_iter_s: f64) -> f64 {
        let utils: Vec<f64> = self
            .requests
            .iter()
            .filter(|r| !r.iters.is_empty())
            .map(|r| r.etr() / (r.mean_iter_s() / baseline_iter_s))
            .collect();
        if utils.is_empty() {
            return f64::NAN;
        }
        utils.len() as f64 / utils.iter().map(|u| 1.0 / u).sum::<f64>()
    }

    /// TPOT percentile across requests (SLO view, paper 7.1: deployments
    /// "require tight latency bounds per request").
    pub fn tpot_percentile(&self, p: f64) -> f64 {
        percentile(
            self.requests
                .iter()
                .filter(|r| !r.iters.is_empty())
                .map(|r| r.tpot_s())
                .collect(),
            p,
        )
    }

    /// TTFT percentile across requests (arrival → first token, virtual
    /// clock) — the open-loop latency SLO's usual target.
    pub fn ttft_percentile(&self, p: f64) -> f64 {
        percentile(self.requests.iter().map(|r| r.ttft_s()).collect(), p)
    }

    /// End-to-end latency percentile (arrival → finalize, virtual clock).
    pub fn e2e_percentile(&self, p: f64) -> f64 {
        percentile(self.requests.iter().map(|r| r.e2e_s()).collect(), p)
    }

    /// Queueing-delay percentile: cumulative out-of-service wait
    /// (`RequestMetrics::queue_wait_s` — initial wait plus parked
    /// intervals).
    pub fn queue_wait_percentile(&self, p: f64) -> f64 {
        percentile(self.requests.iter().map(|r| r.queue_wait_s).collect(), p)
    }

    /// SLO goodput: fraction of completed requests whose TTFT met the SLO.
    /// NaN with no completed requests.
    pub fn slo_goodput(&self, slo_s: f64) -> f64 {
        if self.requests.is_empty() {
            return f64::NAN;
        }
        let met = self.requests.iter().filter(|r| r.ttft_s() <= slo_s).count();
        met as f64 / self.requests.len() as f64
    }

    /// Per-class SLO goodput: fraction of `task`'s completed requests whose
    /// TTFT met that class's deadline (`--slo-ms code=250,…`). NaN when the
    /// run completed no request of that task.
    pub fn slo_goodput_for(&self, task: &str, slo_s: f64) -> f64 {
        let mut total = 0usize;
        let mut met = 0usize;
        for r in self.requests.iter().filter(|r| r.task == task) {
            total += 1;
            if r.ttft_s() <= slo_s {
                met += 1;
            }
        }
        if total == 0 {
            return f64::NAN;
        }
        met as f64 / total as f64
    }

    /// Distinct task names among completed requests, in first-completion
    /// order (deterministic — no hashing on the reporting path).
    pub fn task_names(&self) -> Vec<String> {
        let mut names: Vec<String> = Vec::new();
        for r in &self.requests {
            if !names.contains(&r.task) {
                names.push(r.task.clone());
            }
        }
        names
    }

    /// Worst windowed slowdown across all requests relative to a baseline
    /// iteration time (paper Fig. 15: Cascade's max in-request loss).
    pub fn worst_window_slowdown(&self, window: usize, baseline_iter_s: f64) -> f64 {
        self.requests
            .iter()
            .flat_map(|r| r.utility_windows(window, baseline_iter_s))
            .map(|w| 1.0 / w.utility) // slowdown factor of that window
            .fold(0.0, f64::max)
    }

    /// Median chosen speculation length across every decode iteration of
    /// every request — the policy's typical K (the sharding experiment's
    /// K-vs-shards axis).
    pub fn k_chosen_p50(&self) -> f64 {
        let mut ks: Vec<usize> = self
            .requests
            .iter()
            .flat_map(|r| &r.iters)
            .map(|i| i.k_chosen)
            .collect();
        if ks.is_empty() {
            return f64::NAN;
        }
        ks.sort_unstable();
        ks[(ks.len() - 1) / 2] as f64
    }

    /// Fraction of iterations spent in test phases (policy overhead).
    pub fn test_phase_fraction(&self) -> f64 {
        let total: usize = self.requests.iter().map(|r| r.iters.len()).sum();
        if total == 0 {
            return 0.0;
        }
        let test: usize = self
            .requests
            .iter()
            .flat_map(|r| &r.iters)
            .filter(|r| r.phase == IterPhase::Test)
            .count();
        test as f64 / total as f64
    }
}

/// Nearest-rank percentile over an unsorted sample (NaN when empty) — the
/// same convention `tpot_percentile` has always used.
fn percentile(mut vals: Vec<f64>, p: f64) -> f64 {
    if vals.is_empty() {
        return f64::NAN;
    }
    vals.sort_by(|a, b| a.total_cmp(b));
    vals[((vals.len() - 1) as f64 * p).round() as usize]
}

/// One fused iteration of the continuous-batching engine: a single verify
/// step over the concatenated spans of all in-flight requests.
#[derive(Debug, Clone)]
pub struct BatchIterRecord {
    /// Requests that participated in this fused step.
    pub n_active: usize,
    /// Total in-flight verify tokens across the batch (Σ 1 + drafted).
    pub total_tokens: usize,
    /// Total draft tokens across the batch.
    pub total_drafted: usize,
    /// Output tokens emitted across the batch this iteration.
    pub emitted: usize,
    /// Fused iteration cost (base charged once, experts de-duplicated).
    pub cost: IterCost,
    /// Mean per-layer unique experts *de-duplicated across the batch* —
    /// what the fused step actually fetches.
    pub batch_unique_experts: f64,
    /// Mean per-layer sum of per-request unique counts (the no-dedup upper
    /// bound); the gap to `batch_unique_experts` is cross-request overlap.
    pub summed_unique_experts: f64,
    /// Expert-parallel telemetry: mean per-layer unique experts fetched by
    /// each shard (len = shard count; empty when unsharded/dense).
    pub shard_unique: Vec<f64>,
    /// Mean per-layer load of the **most-loaded** shard — the sharded
    /// expert term's critical path. Equals `batch_unique_experts` when
    /// unsharded (one shard holds everything).
    pub max_shard_unique: f64,
    /// Placement quality: max-shard load over the perfectly-balanced load
    /// (`union / shards`). 1.0 = balanced; higher = hot shard. 1.0 when
    /// unsharded.
    pub shard_imbalance: f64,
    /// Spans whose drafts came from the pipelined lookahead (drafting ran
    /// hidden under the previous verify window). 0 in serial mode.
    pub pipeline_hits: usize,
    /// Spans that needed a fresh scan with the pipeline on — bubbles,
    /// where drafting sat on the critical path. 0 in serial mode.
    pub pipeline_misses: usize,
    /// Lookahead entries discarded because an assumption broke (rejection,
    /// sampler deviation, K change). 0 in serial mode.
    pub draft_recomputes: usize,
    /// Host wall time spent drafting this iteration's spans (all of it on
    /// the critical path in serial mode — the baseline the pipeline's
    /// hidden split is judged against).
    pub draft_wall_ns: u64,
    /// The slice of `draft_wall_ns` that ran hidden under the previous
    /// verify window (pipeline hits).
    pub draft_wall_hidden_ns: u64,
    /// Requests evicted from the shared KV pool since the last committed
    /// iteration (preemption pressure telemetry). 0 with `eviction = off`.
    pub evictions: usize,
    /// Evicted requests re-admitted (re-prefilled) since the last committed
    /// iteration; their recompute time is in `cost.reprefill_s`.
    pub readmissions: usize,
    /// Requests waiting for a slot when this iteration committed: arrived
    /// but unadmitted (the scheduler's wait queue) plus parked eviction
    /// victims. 0 in closed-loop serving unless pool pressure defers
    /// admission.
    pub queue_depth: usize,
    /// Injected-stall retry attempts this iteration burned before the step
    /// went through; their wasted time is in `cost.stall_s`. 0 with
    /// `--faults off`.
    pub stall_retries: usize,
    /// The degradation controller held this iteration below the policy's
    /// ask (K throttled or speculation halted under pressure). Always
    /// false with `--controller off`.
    pub degraded: bool,
    /// Experts the self-healing placement rebuild moved between shards at
    /// this commit (a detector mark/unmark edge fired); their transfer
    /// time is in `cost.migration_s`. 0 with `--heal off` and on every
    /// iteration without an edge.
    pub migrated_experts: usize,
}

/// Aggregate over a continuous-batching run: per-request traces (latency
/// view — each request is charged the full fused iteration it waited on)
/// plus the per-iteration batch records (throughput view).
#[derive(Debug, Clone, Default)]
pub struct BatchRunMetrics {
    pub run: RunMetrics,
    pub iters: Vec<BatchIterRecord>,
    pub max_batch: usize,
    /// Expert-parallel shard count the run was priced under (1 = unsharded).
    pub n_shards: usize,
    /// Final virtual-clock reading: Σ prefill charges + Σ iteration costs +
    /// idle time. The denominator of open-loop rate/duration views.
    pub clock_s: f64,
    /// Virtual seconds the engine sat fully idle (no slot occupied, clock
    /// advanced to the next arrival). 0 in closed-loop serving.
    pub idle_s: f64,
    /// Queued requests shed by the degradation controller because their
    /// TTFT deadline was already unmeetable at admission time. Shed
    /// requests never start, so they appear in no per-request metrics —
    /// this counter is the only trace they leave. 0 with `--controller
    /// off`.
    pub sheds: usize,
    /// Fault-plan events that actually fired during the run (straggler
    /// windows entered, stalls injected, shard kills applied, pool shrinks
    /// applied). 0 with `--faults off`.
    pub fault_events: usize,
    /// Virtual seconds between each shard kill and the instant every
    /// evicted victim of that kill was back in a slot (replay re-prefill
    /// complete) — the recovery-time telemetry of rust/docs/faults.md.
    pub recovery_s: f64,
    /// Placement rebuilds the straggler detector triggered (mark + unmark
    /// edges). A clean straggle-then-recover cycle costs exactly 2; more
    /// means the hysteresis bands are flapping. 0 with `--heal off`.
    pub heal_rebuilds: usize,
    /// Admissions (fresh + re-admissions after eviction) that attached at
    /// least one cached prefix block copy-on-write instead of prefilling
    /// it (rust/docs/prefix_cache.md). 0 with `--prefix-share 0`.
    pub prefix_hits: usize,
    /// Admissions that found no cached prefix block. With sharing on,
    /// `prefix_hits + prefix_misses` counts every admission; 0 with
    /// `--prefix-share 0`.
    pub prefix_misses: usize,
    /// Committed tokens served from the prefix cache — prompt (and
    /// replayed-context) tokens whose prefill charge was skipped on the
    /// virtual clock. 0 with `--prefix-share 0`.
    pub prefix_hit_tokens: u64,
    /// Peak count of KV blocks mapped by two or more holders at once
    /// (requests plus trie pins). 0 with `--prefix-share 0`.
    pub shared_blocks_peak: usize,
    /// Cache-only (trie-pinned, refcount-1) blocks reclaimed LRU-first
    /// under pool pressure. 0 with `--prefix-share 0`.
    pub prefix_reclaimed_blocks: u64,
}

impl BatchRunMetrics {
    /// Prefix-cache hit rate over all admissions (fresh + re-admissions):
    /// hits / (hits + misses), 0.0 when sharing never admitted anything.
    pub fn prefix_hit_rate(&self) -> f64 {
        let total = self.prefix_hits + self.prefix_misses;
        if total == 0 {
            return 0.0;
        }
        self.prefix_hits as f64 / total as f64
    }

    /// Batch-clock TPOT: total fused iteration time over total tokens —
    /// the throughput figure of merit for batched serving. (Per-request
    /// `run.tpot_s()` is the *latency* each request observed.)
    pub fn tpot_s(&self) -> f64 {
        let toks: usize = self.iters.iter().map(|r| r.emitted).sum();
        if toks == 0 {
            return f64::NAN;
        }
        self.iters.iter().map(|r| r.cost.total()).sum::<f64>() / toks as f64
    }

    /// Mean batch occupancy (active requests / max_batch).
    pub fn mean_occupancy(&self) -> f64 {
        if self.iters.is_empty() || self.max_batch == 0 {
            return 0.0;
        }
        self.iters.iter().map(|r| r.n_active as f64).sum::<f64>()
            / (self.iters.len() * self.max_batch) as f64
    }

    /// Mean per-layer unique experts actually fetched per fused iteration.
    pub fn mean_batch_unique(&self) -> f64 {
        if self.iters.is_empty() {
            return 0.0;
        }
        self.iters.iter().map(|r| r.batch_unique_experts).sum::<f64>() / self.iters.len() as f64
    }

    /// Mean per-layer unique experts the same iterations would fetch with
    /// per-request (non-de-duplicated) accounting.
    pub fn mean_summed_unique(&self) -> f64 {
        if self.iters.is_empty() {
            return 0.0;
        }
        self.iters.iter().map(|r| r.summed_unique_experts).sum::<f64>() / self.iters.len() as f64
    }

    /// Fraction of expert fetches saved by cross-request de-duplication:
    /// 1 − Σ dedup / Σ summed. Zero for dense models or batch=1.
    pub fn overlap_savings(&self) -> f64 {
        let summed: f64 = self.iters.iter().map(|r| r.summed_unique_experts).sum();
        if summed == 0.0 {
            return 0.0;
        }
        let dedup: f64 = self.iters.iter().map(|r| r.batch_unique_experts).sum();
        1.0 - dedup / summed
    }

    /// Mean routed-expert fetch time per fused iteration (sub-linearity of
    /// this in batch size is the batching win).
    pub fn mean_expert_s(&self) -> f64 {
        if self.iters.is_empty() {
            return 0.0;
        }
        self.iters.iter().map(|r| r.cost.expert_s).sum::<f64>() / self.iters.len() as f64
    }

    /// Mean fused verify-span width: in-flight tokens (Σ 1 + drafted across
    /// the batch) per committed iteration.
    pub fn mean_span_tokens(&self) -> f64 {
        if self.iters.is_empty() {
            return 0.0;
        }
        self.iters.iter().map(|r| r.total_tokens as f64).sum::<f64>() / self.iters.len() as f64
    }

    /// Fraction of the fused verify span that was speculative: Σ drafted /
    /// Σ in-flight tokens. 0 at K=0 (every span is the single bonus token).
    pub fn draft_share(&self) -> f64 {
        let toks: usize = self.iters.iter().map(|r| r.total_tokens).sum();
        if toks == 0 {
            return 0.0;
        }
        let drafted: usize = self.iters.iter().map(|r| r.total_drafted).sum();
        drafted as f64 / toks as f64
    }

    // ---- Open-loop occupancy telemetry ----------------------------------

    /// Mean wait-queue depth over committed iterations (arrived-but-
    /// unadmitted + parked victims, sampled at each commit).
    pub fn mean_queue_depth(&self) -> f64 {
        if self.iters.is_empty() {
            return 0.0;
        }
        self.iters.iter().map(|r| r.queue_depth as f64).sum::<f64>() / self.iters.len() as f64
    }

    /// Fraction of slot-time spent idle on the decode clock: empty slots
    /// during iterations plus whole-engine idle gaps, over
    /// `max_batch × (Σ iteration time + idle time)`. Prefill time is
    /// outside both numerator and denominator (it occupies exactly the
    /// admitting slot). 0.0 for a fully-occupied closed-loop run.
    pub fn slot_idle_fraction(&self) -> f64 {
        if self.max_batch == 0 {
            return 0.0;
        }
        let iter_s: f64 = self.iters.iter().map(|r| r.cost.total()).sum();
        let span = iter_s + self.idle_s;
        if span <= 0.0 {
            return 0.0;
        }
        let busy: f64 = self.iters.iter().map(|r| r.n_active as f64 * r.cost.total()).sum();
        1.0 - busy / (self.max_batch as f64 * span)
    }

    // ---- Pipelined-drafting telemetry -----------------------------------

    /// Spans drafted off the critical path (pipelined lookahead hits).
    pub fn pipeline_hits(&self) -> usize {
        self.iters.iter().map(|r| r.pipeline_hits).sum()
    }

    /// Spans drafted on the critical path with the pipeline on (bubbles).
    pub fn pipeline_misses(&self) -> usize {
        self.iters.iter().map(|r| r.pipeline_misses).sum()
    }

    /// Speculative drafts discarded because an assumption broke.
    pub fn draft_recomputes(&self) -> usize {
        self.iters.iter().map(|r| r.draft_recomputes).sum()
    }

    /// Fraction of drafting spans the pipeline failed to hide:
    /// misses / (hits + misses). 0.0 when nothing drafted (or serial mode,
    /// where no span is ever counted as a hit or miss).
    pub fn bubble_fraction(&self) -> f64 {
        let hits = self.pipeline_hits();
        let misses = self.pipeline_misses();
        if hits + misses == 0 {
            return 0.0;
        }
        misses as f64 / (hits + misses) as f64
    }

    /// Simulated drafting seconds hidden under verify windows (Σ per-iter
    /// `IterCost::draft_hidden_s`) — the pipeline's simulated-clock win.
    pub fn draft_hidden_s(&self) -> f64 {
        self.iters.iter().map(|r| r.cost.draft_hidden_s).sum()
    }

    /// Total host wall time spent drafting across the run.
    pub fn draft_wall_ns(&self) -> u64 {
        self.iters.iter().map(|r| r.draft_wall_ns).sum()
    }

    /// Host drafting wall time that ran overlapped with verification.
    pub fn draft_wall_hidden_ns(&self) -> u64 {
        self.iters.iter().map(|r| r.draft_wall_hidden_ns).sum()
    }

    // ---- Preemption / eviction telemetry --------------------------------

    /// Requests evicted from the shared KV pool across the run.
    pub fn evictions(&self) -> usize {
        self.iters.iter().map(|r| r.evictions).sum()
    }

    /// Evicted requests re-admitted (re-prefilled) across the run.
    pub fn readmissions(&self) -> usize {
        self.iters.iter().map(|r| r.readmissions).sum()
    }

    /// Simulated seconds spent re-prefilling evicted requests' committed
    /// context across the run (Σ per-iteration `IterCost::reprefill_s`).
    pub fn reprefill_s(&self) -> f64 {
        self.iters.iter().map(|r| r.cost.reprefill_s).sum()
    }

    /// Fraction of the batch clock spent re-prefilling after evictions:
    /// Σ reprefill / Σ total iteration time. 0.0 with `eviction = off` (or
    /// an uncontended pool); high values mean the pool is thrashing and
    /// either the cap or the pool size should grow.
    pub fn thrash_fraction(&self) -> f64 {
        let total: f64 = self.iters.iter().map(|r| r.cost.total()).sum();
        if total == 0.0 {
            return 0.0;
        }
        self.reprefill_s() / total
    }

    // ---- Fault-injection / degradation telemetry ------------------------

    /// Injected-stall retry attempts across the run (each burned a verify
    /// window plus a backoff sleep, billed into `IterCost::stall_s`).
    pub fn total_stall_retries(&self) -> usize {
        self.iters.iter().map(|r| r.stall_retries).sum()
    }

    /// Simulated seconds lost to injected transient stalls across the run
    /// (Σ per-iteration `IterCost::stall_s`). 0.0 with `--faults off`.
    pub fn stall_s(&self) -> f64 {
        self.iters.iter().map(|r| r.cost.stall_s).sum()
    }

    /// Fraction of committed iterations the degradation controller held
    /// below the policy's ask (K throttled or speculation halted). 0.0
    /// with `--controller off`; a chronically high value means the
    /// deployment is underprovisioned, not just unlucky.
    pub fn degraded_fraction(&self) -> f64 {
        if self.iters.is_empty() {
            return 0.0;
        }
        let n = self.iters.iter().filter(|r| r.degraded).count();
        n as f64 / self.iters.len() as f64
    }

    /// Experts moved between shards by self-healing placement rebuilds
    /// across the run (Σ per-iteration `migrated_experts`). 0 with
    /// `--heal off`.
    pub fn migrated_experts(&self) -> usize {
        self.iters.iter().map(|r| r.migrated_experts).sum()
    }

    /// Simulated seconds spent relocating expert weights for self-healing
    /// rebuilds (Σ per-iteration `IterCost::migration_s` — the exposed
    /// charge, after any pipeline hiding). 0.0 with `--heal off`.
    pub fn migration_s(&self) -> f64 {
        self.iters.iter().map(|r| r.cost.migration_s).sum()
    }

    // ---- Expert-parallel sharding telemetry -----------------------------

    /// Mean simulated verify time per fused iteration (base + experts +
    /// overhead + all-to-all) — the quantity sharding must lower.
    pub fn mean_verify_s(&self) -> f64 {
        if self.iters.is_empty() {
            return f64::NAN;
        }
        self.iters.iter().map(|r| r.cost.verify_s()).sum::<f64>() / self.iters.len() as f64
    }

    /// Mean per-layer unique experts on the most-loaded shard (the sharded
    /// critical path; equals `mean_batch_unique` when unsharded).
    pub fn mean_max_shard_unique(&self) -> f64 {
        if self.iters.is_empty() {
            return 0.0;
        }
        self.iters.iter().map(|r| r.max_shard_unique).sum::<f64>() / self.iters.len() as f64
    }

    /// Mean shard imbalance (max shard load / balanced load; 1.0 = ideal).
    pub fn mean_shard_imbalance(&self) -> f64 {
        if self.iters.is_empty() {
            return 1.0;
        }
        self.iters.iter().map(|r| r.shard_imbalance).sum::<f64>() / self.iters.len() as f64
    }

    /// Per-shard mean per-layer expert load across the run (empty when
    /// unsharded).
    pub fn per_shard_mean_unique(&self) -> Vec<f64> {
        let n = self.iters.iter().map(|r| r.shard_unique.len()).max().unwrap_or(0);
        if n == 0 {
            return Vec::new();
        }
        let mut acc = vec![0.0f64; n];
        let mut count = 0usize;
        for r in self.iters.iter().filter(|r| !r.shard_unique.is_empty()) {
            for (a, &v) in acc.iter_mut().zip(&r.shard_unique) {
                *a += v;
            }
            count += 1;
        }
        if count > 0 {
            for a in &mut acc {
                *a /= count as f64;
            }
        }
        acc
    }

    /// All-to-all share of total verify time: Σ all-to-all / Σ verify.
    /// Zero when unsharded.
    pub fn alltoall_share(&self) -> f64 {
        let verify: f64 = self.iters.iter().map(|r| r.cost.verify_s()).sum();
        if verify == 0.0 {
            return 0.0;
        }
        self.iters.iter().map(|r| r.cost.alltoall_s).sum::<f64>() / verify
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(emitted: usize, total_s: f64, phase: IterPhase) -> IterRecord {
        IterRecord {
            k_chosen: emitted.saturating_sub(1),
            drafted: emitted.saturating_sub(1),
            accepted: emitted.saturating_sub(1),
            emitted,
            cost: IterCost { base_s: total_s, ..Default::default() },
            wall_ns: 1000,
            unique_experts: 2.0,
            phase,
        }
    }

    #[test]
    fn tpot_is_time_over_tokens() {
        let mut m = RequestMetrics::default();
        m.iters.push(rec(2, 0.02, IterPhase::Set));
        m.iters.push(rec(1, 0.01, IterPhase::Set));
        assert!((m.tpot_s() - 0.01).abs() < 1e-12);
        assert!((m.etr() - 1.5).abs() < 1e-12);
    }

    #[test]
    fn theorem_4_2_identity() {
        // t_spec = t_base / U  (paper Theorem 4.2): with baseline iteration
        // time b and speculative iterations of time c emitting e tokens,
        // utility = e/(c/b) and TPOT = c/e = b/utility.
        let (b, c, e) = (0.01, 0.025, 2.0);
        let mut m = RequestMetrics::default();
        for _ in 0..10 {
            m.iters.push(rec(e as usize, c, IterPhase::Set));
        }
        let u = m.etr() / (m.mean_iter_s() / b);
        assert!((m.tpot_s() - b / u).abs() < 1e-12);
    }

    #[test]
    fn windows_chunk_correctly() {
        let mut m = RequestMetrics::default();
        for i in 0..40 {
            m.iters.push(rec(if i < 16 { 2 } else { 1 }, 0.02, IterPhase::Set));
        }
        let w = m.utility_windows(16, 0.02);
        assert_eq!(w.len(), 3); // 16 + 16 + 8
        assert!((w[0].etr - 2.0).abs() < 1e-12);
        assert!((w[0].utility - 2.0).abs() < 1e-12);
        assert!((w[1].etr - 1.0).abs() < 1e-12);
    }

    #[test]
    fn run_aggregates() {
        let mut run = RunMetrics::default();
        let mut a = RequestMetrics::default();
        a.iters.push(rec(2, 0.02, IterPhase::Set));
        let mut b = RequestMetrics::default();
        b.iters.push(rec(1, 0.01, IterPhase::Test));
        run.push(a);
        run.push(b);
        assert_eq!(run.total_tokens(), 3);
        assert!((run.tpot_s() - 0.01).abs() < 1e-12);
        assert!((run.test_phase_fraction() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn harmonic_mean_dominated_by_low_utility() {
        let mut run = RunMetrics::default();
        for (e, c) in [(2usize, 0.01), (1usize, 0.04)] {
            let mut m = RequestMetrics::default();
            m.iters.push(rec(e, c, IterPhase::Set));
            run.push(m);
        }
        let h = run.harmonic_mean_utility(0.01);
        // utilities: 2.0 and 0.25 -> harmonic mean 2/(0.5+4) ≈ 0.444
        assert!((h - 0.4444).abs() < 1e-3, "{h}");
    }

    #[test]
    fn percentiles_ordered() {
        let mut run = RunMetrics::default();
        for (e, c) in [(1usize, 0.01), (1, 0.02), (1, 0.03)] {
            let mut m = RequestMetrics::default();
            m.iters.push(rec(e, c, IterPhase::Set));
            run.push(m);
        }
        assert!(run.tpot_percentile(0.0) <= run.tpot_percentile(0.5));
        assert!(run.tpot_percentile(0.5) <= run.tpot_percentile(1.0));
        assert!((run.tpot_percentile(1.0) - 0.03).abs() < 1e-12);
    }

    #[test]
    fn worst_window_tracks_max_loss() {
        let mut run = RunMetrics::default();
        let mut m = RequestMetrics::default();
        for _ in 0..16 {
            m.iters.push(rec(1, 0.02, IterPhase::Set)); // utility 0.5
        }
        for _ in 0..16 {
            m.iters.push(rec(2, 0.02, IterPhase::Set)); // utility 1.0
        }
        run.push(m);
        let worst = run.worst_window_slowdown(16, 0.01);
        assert!((worst - 2.0).abs() < 1e-9, "{worst}");
    }

    #[test]
    fn empty_metrics_are_nan_not_panic() {
        let m = RequestMetrics::default();
        assert!(m.tpot_s().is_nan());
        assert!(m.etr().is_nan());
        let r = RunMetrics::default();
        assert!(r.tpot_s().is_nan());
    }

    fn batch_rec(n_active: usize, emitted: usize, dedup: f64, summed: f64) -> BatchIterRecord {
        BatchIterRecord {
            n_active,
            total_tokens: n_active * 4,
            total_drafted: n_active * 3,
            emitted,
            cost: IterCost { base_s: 0.01, expert_s: dedup * 1e-3, ..Default::default() },
            batch_unique_experts: dedup,
            summed_unique_experts: summed,
            shard_unique: Vec::new(),
            max_shard_unique: dedup,
            shard_imbalance: 1.0,
            pipeline_hits: 0,
            pipeline_misses: 0,
            draft_recomputes: 0,
            draft_wall_ns: 0,
            draft_wall_hidden_ns: 0,
            evictions: 0,
            readmissions: 0,
            queue_depth: 0,
            stall_retries: 0,
            degraded: false,
            migrated_experts: 0,
        }
    }

    #[test]
    fn batch_metrics_aggregate() {
        let mut b = BatchRunMetrics { max_batch: 4, ..Default::default() };
        b.iters.push(batch_rec(4, 8, 6.0, 12.0));
        b.iters.push(batch_rec(2, 4, 4.0, 6.0));
        assert!((b.mean_occupancy() - 0.75).abs() < 1e-12);
        assert!((b.mean_batch_unique() - 5.0).abs() < 1e-12);
        assert!((b.mean_summed_unique() - 9.0).abs() < 1e-12);
        // savings = 1 - 10/18
        assert!((b.overlap_savings() - (1.0 - 10.0 / 18.0)).abs() < 1e-12);
        // tpot = (0.016 + 0.014) / 12
        assert!((b.tpot_s() - 0.030 / 12.0).abs() < 1e-12);
    }

    #[test]
    fn batch_metrics_empty_safe() {
        let b = BatchRunMetrics::default();
        assert!(b.tpot_s().is_nan());
        assert_eq!(b.mean_occupancy(), 0.0);
        assert_eq!(b.overlap_savings(), 0.0);
        assert_eq!(b.bubble_fraction(), 0.0);
        assert_eq!(b.draft_hidden_s(), 0.0);
    }

    #[test]
    fn per_class_goodput_splits_by_task() {
        let mut run = RunMetrics::default();
        for (task, ttft) in
            [("code", 0.1), ("code", 0.6), ("math", 0.2), ("math", 0.3)]
        {
            let mut m = RequestMetrics::default();
            m.task = task.to_string();
            m.arrival_s = 1.0;
            m.first_token_s = 1.0 + ttft;
            m.iters.push(rec(1, 0.01, IterPhase::Set));
            run.push(m);
        }
        // Class deadlines: code 0.25s (1 of 2 met), math 0.25s (1 of 2 met
        // — ttft 0.2 meets, 0.3 misses).
        assert!((run.slo_goodput_for("code", 0.25) - 0.5).abs() < 1e-12);
        assert!((run.slo_goodput_for("math", 0.25) - 0.5).abs() < 1e-12);
        // A looser math class flips its goodput without touching code's.
        assert!((run.slo_goodput_for("math", 0.4) - 1.0).abs() < 1e-12);
        assert!(run.slo_goodput_for("extract", 0.25).is_nan(), "no such task completed");
        assert_eq!(run.task_names(), vec!["code".to_string(), "math".to_string()]);
        // The catch-all view still counts everyone.
        assert!((run.slo_goodput(0.25) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn migration_aggregates_sum_over_iterations() {
        let mut b = BatchRunMetrics { max_batch: 2, heal_rebuilds: 2, ..Default::default() };
        let mut r1 = batch_rec(2, 4, 4.0, 6.0);
        r1.migrated_experts = 3;
        r1.cost.migration_s = 0.002;
        let r2 = batch_rec(2, 4, 4.0, 6.0);
        b.iters.push(r1);
        b.iters.push(r2);
        assert_eq!(b.migrated_experts(), 3);
        assert!((b.migration_s() - 0.002).abs() < 1e-15);
        assert_eq!(b.heal_rebuilds, 2);
        // Default-off: a heal-free run reports exact zeros.
        let clean = BatchRunMetrics { max_batch: 2, ..Default::default() };
        assert_eq!(clean.migrated_experts(), 0);
        assert_eq!(clean.migration_s(), 0.0);
        assert_eq!(clean.heal_rebuilds, 0);
    }

    #[test]
    fn k_p50_is_the_median_iteration_k() {
        let mut run = RunMetrics::default();
        let mut m = RequestMetrics::default();
        for e in [1usize, 2, 2, 3, 4] {
            m.iters.push(rec(e, 0.02, IterPhase::Set)); // k = e - 1
        }
        run.push(m);
        assert!((run.k_chosen_p50() - 1.0).abs() < 1e-12); // ks: 0,1,1,2,3
        assert!(RunMetrics::default().k_chosen_p50().is_nan());
    }

    #[test]
    fn sharding_telemetry_aggregates() {
        let mut b = BatchRunMetrics { max_batch: 4, n_shards: 2, ..Default::default() };
        let mut r1 = batch_rec(4, 8, 6.0, 12.0);
        r1.shard_unique = vec![4.0, 2.0];
        r1.max_shard_unique = 4.0;
        r1.shard_imbalance = 4.0 / 3.0;
        r1.cost.alltoall_s = 0.5e-3;
        let mut r2 = batch_rec(2, 4, 4.0, 6.0);
        r2.shard_unique = vec![2.0, 2.0];
        r2.max_shard_unique = 2.0;
        r2.shard_imbalance = 1.0;
        r2.cost.alltoall_s = 0.5e-3;
        b.iters.push(r1);
        b.iters.push(r2);
        assert!((b.mean_max_shard_unique() - 3.0).abs() < 1e-12);
        assert!((b.mean_shard_imbalance() - (4.0 / 3.0 + 1.0) / 2.0).abs() < 1e-12);
        assert_eq!(b.per_shard_mean_unique(), vec![3.0, 2.0]);
        let verify: f64 = b.iters.iter().map(|r| r.cost.verify_s()).sum();
        assert!((b.alltoall_share() - 1e-3 / verify).abs() < 1e-12);
        // Unsharded runs degrade gracefully.
        let plain = BatchRunMetrics { max_batch: 1, ..Default::default() };
        assert_eq!(plain.alltoall_share(), 0.0);
        assert!(plain.per_shard_mean_unique().is_empty());
        assert_eq!(plain.mean_shard_imbalance(), 1.0);
    }

    #[test]
    fn preemption_telemetry_aggregates() {
        let mut b = BatchRunMetrics { max_batch: 4, ..Default::default() };
        let mut r1 = batch_rec(4, 8, 6.0, 12.0);
        r1.evictions = 2;
        r1.readmissions = 1;
        r1.cost.reprefill_s = 3e-3;
        let r2 = batch_rec(2, 4, 4.0, 6.0);
        b.iters.push(r1);
        b.iters.push(r2);
        assert_eq!(b.evictions(), 2);
        assert_eq!(b.readmissions(), 1);
        assert!((b.reprefill_s() - 3e-3).abs() < 1e-15);
        let total: f64 = b.iters.iter().map(|r| r.cost.total()).sum();
        assert!((b.thrash_fraction() - 3e-3 / total).abs() < 1e-12);
        // Re-prefill extends the batch clock: TPOT must see it.
        let mut without = b.clone();
        without.iters[0].cost.reprefill_s = 0.0;
        assert!(b.tpot_s() > without.tpot_s());
        // Eviction-free runs degrade to zeros.
        let plain = BatchRunMetrics::default();
        assert_eq!(plain.evictions(), 0);
        assert_eq!(plain.thrash_fraction(), 0.0);
    }

    #[test]
    fn fault_telemetry_aggregates() {
        let mut b = BatchRunMetrics { max_batch: 4, ..Default::default() };
        let mut r1 = batch_rec(4, 8, 6.0, 12.0);
        r1.stall_retries = 2;
        r1.cost.stall_s = 4e-3;
        r1.degraded = true;
        let r2 = batch_rec(2, 4, 4.0, 6.0);
        b.iters.push(r1);
        b.iters.push(r2);
        b.sheds = 3;
        b.fault_events = 5;
        b.recovery_s = 0.25;
        assert_eq!(b.total_stall_retries(), 2);
        assert!((b.stall_s() - 4e-3).abs() < 1e-15);
        assert!((b.degraded_fraction() - 0.5).abs() < 1e-12);
        // Stall time extends the batch clock: TPOT must see the outage.
        let mut without = b.clone();
        without.iters[0].cost.stall_s = 0.0;
        assert!(b.tpot_s() > without.tpot_s());
        // Fault-free runs degrade to zeros.
        let plain = BatchRunMetrics::default();
        assert_eq!(plain.total_stall_retries(), 0);
        assert_eq!(plain.stall_s(), 0.0);
        assert_eq!(plain.degraded_fraction(), 0.0);
        assert_eq!((plain.sheds, plain.fault_events), (0, 0));
    }

    #[test]
    fn latency_percentiles_and_goodput() {
        let mut run = RunMetrics::default();
        for (arr, adm, first, fin) in
            [(0.0, 0.0, 0.1, 1.0), (1.0, 1.5, 1.7, 3.0), (2.0, 4.0, 4.5, 9.0)]
        {
            let mut m = RequestMetrics {
                arrival_s: arr,
                admitted_s: adm,
                first_token_s: first,
                finish_s: fin,
                queue_wait_s: adm - arr,
                ..Default::default()
            };
            m.iters.push(rec(1, 0.01, IterPhase::Set));
            run.push(m);
        }
        // TTFTs: 0.1, 0.7, 2.5 — E2Es: 1.0, 2.0, 7.0 — waits: 0.0, 0.5, 2.0.
        assert!((run.ttft_percentile(0.5) - 0.7).abs() < 1e-12);
        assert!((run.ttft_percentile(1.0) - 2.5).abs() < 1e-12);
        assert!((run.e2e_percentile(0.0) - 1.0).abs() < 1e-12);
        assert!((run.queue_wait_percentile(1.0) - 2.0).abs() < 1e-12);
        // SLO at 1.0s TTFT: 2 of 3 met.
        assert!((run.slo_goodput(1.0) - 2.0 / 3.0).abs() < 1e-12);
        assert!((run.slo_goodput(0.05) - 0.0).abs() < 1e-12);
        assert!(RunMetrics::default().ttft_percentile(0.5).is_nan());
        assert!(RunMetrics::default().slo_goodput(1.0).is_nan());
    }

    #[test]
    fn queue_depth_and_idle_aggregates() {
        let mut b = BatchRunMetrics { max_batch: 4, ..Default::default() };
        let mut r1 = batch_rec(4, 8, 6.0, 12.0); // cost.total() = 0.016
        r1.queue_depth = 3;
        let mut r2 = batch_rec(2, 4, 4.0, 6.0); // cost.total() = 0.014
        r2.queue_depth = 1;
        b.iters.push(r1);
        b.iters.push(r2);
        b.idle_s = 0.010;
        b.clock_s = 0.040;
        assert!((b.mean_queue_depth() - 2.0).abs() < 1e-12);
        // busy = 4*0.016 + 2*0.014 = 0.092; span = 0.030 + 0.010 = 0.040.
        let expect = 1.0 - 0.092 / (4.0 * 0.040);
        assert!((b.slot_idle_fraction() - expect).abs() < 1e-12, "{}", b.slot_idle_fraction());
        // Empty and fully-busy runs degrade sensibly.
        assert_eq!(BatchRunMetrics::default().slot_idle_fraction(), 0.0);
        assert_eq!(BatchRunMetrics::default().mean_queue_depth(), 0.0);
        let mut full = BatchRunMetrics { max_batch: 1, ..Default::default() };
        full.iters.push(batch_rec(1, 2, 2.0, 2.0));
        assert!(full.slot_idle_fraction().abs() < 1e-12);
    }

    #[test]
    fn pipeline_telemetry_aggregates() {
        let mut b = BatchRunMetrics { max_batch: 4, ..Default::default() };
        let mut r1 = batch_rec(4, 8, 6.0, 12.0);
        r1.pipeline_hits = 3;
        r1.pipeline_misses = 1;
        r1.draft_recomputes = 1;
        r1.draft_wall_ns = 1000;
        r1.draft_wall_hidden_ns = 750;
        r1.cost.draft_s = 1.0e-3;
        r1.cost.draft_hidden_s = 0.75e-3;
        let mut r2 = batch_rec(2, 4, 4.0, 6.0);
        r2.pipeline_hits = 2;
        r2.draft_wall_ns = 400;
        r2.draft_wall_hidden_ns = 400;
        r2.cost.draft_s = 0.5e-3;
        r2.cost.draft_hidden_s = 0.5e-3;
        b.iters.push(r1);
        b.iters.push(r2);
        assert_eq!(b.pipeline_hits(), 5);
        assert_eq!(b.pipeline_misses(), 1);
        assert_eq!(b.draft_recomputes(), 1);
        assert!((b.bubble_fraction() - 1.0 / 6.0).abs() < 1e-12);
        assert!((b.draft_hidden_s() - 1.25e-3).abs() < 1e-15);
        assert_eq!(b.draft_wall_ns(), 1400);
        assert_eq!(b.draft_wall_hidden_ns(), 1150);
        // The overlap rule feeds TPOT: hidden drafting lowers Σ cost.
        let hidden_total: f64 = b.iters.iter().map(|r| r.cost.total()).sum();
        let serial_total: f64 =
            b.iters.iter().map(|r| r.cost.total() + r.cost.draft_hidden_s).sum();
        assert!(hidden_total < serial_total);
    }
}
