//! Request scheduler: admission control over a request stream.
//!
//! The paper's setting is single-batch, low-latency serving: one request
//! decodes at a time; mixed workloads interleave tasks *across* requests
//! (§3: "mixed workloads … comprise request streams from 2 or 3 tasks with
//! equal sharing"). The scheduler owns admission (token budget / request
//! count) and drains the stream through an engine — either the FIFO
//! single-request [`Engine`] or the continuous-batching [`BatchEngine`],
//! where it keeps every free slot fed.
//!
//! Budget law: the **tail request is clamped** to the remaining token
//! budget, so a run can never overshoot `max_tokens` by a full
//! `max_new_tokens` — overshoot would skew task sharing in mixed
//! workloads (the last-admitted task would get up to an extra request's
//! worth of tokens).

use crate::coordinator::batch::BatchEngine;
use crate::coordinator::engine::Engine;
use crate::metrics::{BatchRunMetrics, RunMetrics};
use crate::workload::{Request, RequestStream};
use anyhow::Result;
use std::collections::VecDeque;

/// Admission limits for a serving run.
#[derive(Debug, Clone, Copy)]
pub struct Budget {
    /// Stop admitting once this many output tokens were generated
    /// (the paper's mixed runs generate ≥ 20k tokens; scaled here).
    pub max_tokens: usize,
    /// Hard cap on requests (safety).
    pub max_requests: usize,
}

impl Default for Budget {
    fn default() -> Self {
        Self { max_tokens: 2_000, max_requests: 1_000 }
    }
}

/// FIFO scheduler over a request stream.
pub struct Scheduler {
    queue: VecDeque<Request>,
    stream: RequestStream,
    budget: Budget,
}

impl Scheduler {
    pub fn new(stream: RequestStream, budget: Budget) -> Self {
        Self { queue: VecDeque::new(), stream, budget }
    }

    /// Admit the next request (from queue, else freshly generated).
    fn next_request(&mut self) -> Request {
        self.queue.pop_front().unwrap_or_else(|| self.stream.next_request())
    }

    /// Enqueue an explicit request (tests / replay).
    pub fn enqueue(&mut self, req: Request) {
        self.queue.push_back(req);
    }

    /// Drain the stream through `engine` until the token budget is spent.
    pub fn run(&mut self, engine: &mut Engine) -> Result<RunMetrics> {
        let mut metrics = RunMetrics::default();
        let mut tokens = 0usize;
        let mut served = 0usize;
        while tokens < self.budget.max_tokens && served < self.budget.max_requests {
            let mut req = self.next_request();
            // Clamp the tail request to the remaining budget so the run
            // cannot overshoot max_tokens. A request with max_new_tokens=n
            // contributes at most n-1 counted tokens (the prefill token is
            // not an iteration emission), hence the +1.
            let remaining = self.budget.max_tokens - tokens;
            req.max_new_tokens = req.max_new_tokens.min(remaining + 1);
            let m = engine.serve_request(&req)?;
            tokens += m.tokens_emitted();
            served += 1;
            metrics.push(m);
        }
        Ok(metrics)
    }

    /// Drain the stream through a continuous-batching engine: keep every
    /// free slot fed until the token budget is fully allocated, then let
    /// the in-flight requests finish. Admission is charged against
    /// [`BatchEngine::output_bound`] — the worst-case total the admitted
    /// requests can still emit — so the bound both prevents overshoot and
    /// self-corrects when a request finishes early (its unused headroom
    /// returns to the budget and admission resumes).
    pub fn run_batched(&mut self, engine: &mut BatchEngine) -> Result<BatchRunMetrics> {
        let mut served = 0usize;
        loop {
            loop {
                let bound = engine.output_bound();
                if !engine.has_free_slot()
                    || bound >= self.budget.max_tokens
                    || served >= self.budget.max_requests
                {
                    break;
                }
                let mut req = self.next_request();
                // Clamp the tail request (a request emits at most
                // max_new_tokens - 1 counted tokens, hence the +1).
                let remaining = self.budget.max_tokens - bound;
                req.max_new_tokens = req.max_new_tokens.min(remaining + 1);
                if !engine.can_admit(&req) {
                    // Pool pressure: requeue and decode to free blocks.
                    self.queue.push_front(req);
                    break;
                }
                served += 1;
                engine.admit(req)?;
            }
            if !engine.step_iteration()? {
                // An idle step means every slot was swept.
                debug_assert_eq!(engine.active(), 0, "idle step left active slots");
                if engine.output_bound() >= self.budget.max_tokens
                    || served >= self.budget.max_requests
                {
                    break;
                }
                // Engine idle with budget left: the head request must be
                // admittable next pass, otherwise it can never fit.
                if let Some(req) = self.queue.front() {
                    anyhow::ensure!(
                        engine.can_admit(req),
                        "request {} cannot fit the KV pool",
                        req.id
                    );
                }
            }
        }
        Ok(engine.finish())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::EngineConfig;
    use crate::models::{default_artifacts_dir, Registry};
    use crate::spec::policy::PolicyKind;
    use crate::workload::{Task, Workload};

    #[test]
    fn budget_defaults() {
        let b = Budget::default();
        assert!(b.max_tokens > 0 && b.max_requests > 0);
    }

    #[test]
    fn queue_priority_over_stream() {
        let stream = RequestStream::new(Workload::single(Task::Code), 1, 50);
        let mut s = Scheduler::new(stream, Budget::default());
        let mut req = RequestStream::new(Workload::single(Task::Math), 2, 50).next_request();
        req.id = 999;
        s.enqueue(req);
        assert_eq!(s.next_request().id, 999);
        // subsequent requests come from the stream
        assert_ne!(s.next_request().id, 999);
    }

    #[test]
    fn token_budget_never_overshoots() {
        // Regression: the tail request used to run with its full
        // max_new_tokens, overshooting the budget by up to a request.
        let reg = Registry::load_or_builtin(default_artifacts_dir());
        for budget_tokens in [130usize, 250, 777] {
            let cfg = EngineConfig { model: "mixtral".into(), ..Default::default() };
            let mut engine = Engine::sim(&reg, cfg, PolicyKind::Static(2).build()).unwrap();
            let stream = RequestStream::new(Workload::single(Task::Code), 5, 100);
            let mut sched = Scheduler::new(
                stream,
                Budget { max_tokens: budget_tokens, max_requests: 1_000 },
            );
            let m = sched.run(&mut engine).unwrap();
            assert!(
                m.total_tokens() <= budget_tokens,
                "budget {budget_tokens} overshot: {}",
                m.total_tokens()
            );
            assert!(m.total_tokens() >= budget_tokens.saturating_sub(1));
        }
    }

    #[test]
    fn batched_run_respects_budget() {
        let reg = Registry::load_or_builtin(default_artifacts_dir());
        let cfg = EngineConfig { model: "mixtral".into(), max_batch: 4, ..Default::default() };
        let mut engine =
            BatchEngine::sim(&reg, cfg, PolicyKind::Static(2)).unwrap();
        let stream = RequestStream::new(Workload::single(Task::Code), 5, 100);
        let mut sched =
            Scheduler::new(stream, Budget { max_tokens: 300, max_requests: 1_000 });
        let m = sched.run_batched(&mut engine).unwrap();
        assert!(m.run.total_tokens() <= 300, "batched overshoot: {}", m.run.total_tokens());
        assert!(m.run.total_tokens() > 0);
        assert!(m.run.requests.len() >= 3);
    }
}
