//! Request scheduler: a thin event loop over arrivals, admission, and the
//! engine clock.
//!
//! The paper's setting is single-batch, low-latency serving: one request
//! decodes at a time; mixed workloads interleave tasks *across* requests
//! (§3: "mixed workloads … comprise request streams from 2 or 3 tasks with
//! equal sharing"). The scheduler owns the run budget and drives an engine
//! — either the FIFO single-request [`Engine`] or the continuous-batching
//! [`BatchEngine`] — but the *ordering* decisions live elsewhere:
//!
//! * **when requests exist** is the [`ArrivalProcess`]'s call (closed-loop
//!   legacy, Poisson, bursty, trace replay), stamped on the engine's
//!   virtual clock;
//! * **who takes a freed slot** is the engine's
//!   [`AdmissionPolicy`](crate::coordinator::admission::AdmissionPolicy)'s
//!   call (fcfs / parked-first / edf), applied to the [`AdmissionQueue`]
//!   of arrived-but-unadmitted requests;
//! * the scheduler itself only loops: release due arrivals → admit per
//!   policy → step the engine → idle the clock forward when open-loop
//!   slots have nothing to do (a state the old closed loop could not
//!   express).
//!
//! Budget law (PR 1, now enforced in [`AdmissionQueue::clamp`]): the
//! **tail request is clamped** to the remaining token budget, so a run can
//! never overshoot `max_tokens` by a full `max_new_tokens` — overshoot
//! would skew task sharing in mixed workloads.
//!
//! With `--arrivals closed --admission fcfs` (the defaults) this loop is
//! bit-exact with the pre-refactor closed-loop scheduler: identical stream
//! pulls, identical clamp points, identical admission order
//! (rust/tests/arrivals.rs guards this token-for-token).

use crate::coordinator::admission::AdmissionQueue;
use crate::coordinator::batch::BatchEngine;
use crate::coordinator::engine::Engine;
use crate::metrics::{BatchRunMetrics, RunMetrics};
use crate::workload::arrivals::ArrivalProcess;
use crate::workload::{Request, RequestStream};
use anyhow::Result;

/// Admission limits for a serving run.
#[derive(Debug, Clone, Copy)]
pub struct Budget {
    /// Stop admitting once this many output tokens were generated
    /// (the paper's mixed runs generate ≥ 20k tokens; scaled here).
    pub max_tokens: usize,
    /// Hard cap on requests (safety).
    pub max_requests: usize,
}

impl Default for Budget {
    fn default() -> Self {
        Self { max_tokens: 2_000, max_requests: 1_000 }
    }
}

/// Event-loop scheduler over an arrival process.
pub struct Scheduler {
    queue: AdmissionQueue,
    arrivals: ArrivalProcess,
    budget: Budget,
    /// When set (`serve --capture-trace <path>`), every arrival released
    /// into the wait queue is recorded pre-clamp and written at the end of
    /// `run_batched` as an [`ArrivalKind::Trace`]-replayable JSONL file —
    /// turn any stochastic arrival run into a frozen regression workload.
    /// Completed requests' token streams are appended as `"stream"` lines
    /// (skipped by the trace replayer), so `diff-trace` can pinpoint the
    /// first divergence between a healthy and a chaos run of the same
    /// arrivals.
    ///
    /// [`ArrivalKind::Trace`]: crate::workload::arrivals::ArrivalKind::Trace
    capture_path: Option<String>,
    captured: Vec<(f64, &'static str, usize)>,
}

impl Scheduler {
    /// Closed-loop scheduler over a request stream (the legacy default:
    /// a request "arrives" the instant a slot wants one).
    pub fn new(stream: RequestStream, budget: Budget) -> Self {
        Self::with_arrivals(ArrivalProcess::closed(stream), budget)
    }

    /// Scheduler over an explicit arrival process (open-loop serving).
    pub fn with_arrivals(arrivals: ArrivalProcess, budget: Budget) -> Self {
        Self {
            queue: AdmissionQueue::new(),
            arrivals,
            budget,
            capture_path: None,
            captured: Vec::new(),
        }
    }

    /// Record every arrival this run releases and write them to `path` as
    /// a replayable arrival trace when `run_batched` completes.
    pub fn capture_trace(&mut self, path: &str) {
        self.capture_path = Some(path.to_string());
    }

    /// Note one queue entry in the capture buffer (no-op unless
    /// `capture_trace` armed it). Entries carry the *pre-clamp*
    /// `max_new_tokens`: the trace records what arrived, not what the
    /// run's token budget happened to leave of it.
    fn record_arrival(&mut self, arrival_s: f64, req: &Request) {
        if self.capture_path.is_some() {
            self.captured.push((arrival_s, req.task.name(), req.max_new_tokens));
        }
    }

    /// Write the captured arrivals (sorted by time; the capture order is
    /// already chronological per arrival site, but closed-loop pulls can
    /// interleave with due-arrival releases) in the `ArrivalKind::Trace`
    /// line format: `{"t": <s>, "task": "<name>", "max_new": <n>}`, then
    /// every completed request's token stream as
    /// `{"stream": <id>, "task": "<name>", "tokens": [..]}` — ignored by
    /// the trace replayer, consumed by the `diff-trace` subcommand.
    fn write_capture(&mut self, metrics: &BatchRunMetrics) -> Result<()> {
        let Some(path) = self.capture_path.as_ref() else {
            return Ok(());
        };
        let mut entries = std::mem::take(&mut self.captured);
        entries.sort_by(|a, b| a.0.total_cmp(&b.0));
        let mut out = String::new();
        for (t, task, max_new) in &entries {
            out.push_str(&format!(
                "{{\"t\": {t}, \"task\": \"{task}\", \"max_new\": {max_new}}}\n"
            ));
        }
        // Completed streams, in id order (metrics.run.requests are sorted
        // by id in BatchEngine::finish), so two captures of the same
        // workload line up request-for-request.
        for r in &metrics.run.requests {
            let tokens: Vec<String> = r.output.iter().map(|t| t.to_string()).collect();
            out.push_str(&format!(
                "{{\"stream\": {}, \"task\": \"{}\", \"tokens\": [{}]}}\n",
                r.id,
                r.task,
                tokens.join(", ")
            ));
        }
        std::fs::write(path, out)
            .map_err(|e| anyhow::anyhow!("writing arrival trace {path}: {e}"))
    }

    /// Enqueue an explicit request (tests / replay); it is treated as
    /// having arrived at clock 0.
    pub fn enqueue(&mut self, req: Request) {
        self.queue.push(req, 0.0, 0.0);
    }

    /// Closed-loop pull: the oldest queued request, else a fresh one from
    /// the stream.
    fn next_closed(&mut self) -> Request {
        if self.queue.is_empty() {
            self.arrivals.pull_closed()
        } else {
            self.queue.remove(0).req
        }
    }

    /// Drain the stream through `engine` until the token budget is spent.
    /// Closed-loop only: the single-request engine has no virtual clock for
    /// arrivals to land on.
    pub fn run(&mut self, engine: &mut Engine) -> Result<RunMetrics> {
        anyhow::ensure!(
            self.arrivals.is_closed(),
            "open-loop arrivals need the batched serving path (serve --batch / BatchEngine)"
        );
        let mut metrics = RunMetrics::default();
        let mut tokens = 0usize;
        let mut served = 0usize;
        while tokens < self.budget.max_tokens && served < self.budget.max_requests {
            let mut req = self.next_closed();
            // The PR-1 budget law (see AdmissionQueue::clamp): a request
            // with max_new_tokens = n contributes at most n-1 counted
            // tokens, hence the +1.
            let remaining = self.budget.max_tokens - tokens;
            req.max_new_tokens = req.max_new_tokens.min(remaining + 1);
            let m = engine.serve_request(&req)?;
            tokens += m.tokens_emitted();
            served += 1;
            metrics.push(m);
        }
        Ok(metrics)
    }

    /// Admission pass: admit policy-selected arrived requests while slots,
    /// pool blocks, and the token budget allow. Admission is charged
    /// against [`BatchEngine::output_bound`] — the worst-case total the
    /// admitted requests can still emit — so the bound both prevents
    /// overshoot and self-corrects when a request finishes early (its
    /// unused headroom returns to the budget and admission resumes).
    fn admit_phase(&mut self, engine: &mut BatchEngine, served: &mut usize) -> Result<()> {
        if engine.fresh_admission_blocked() {
            // Parked-priority policy with eviction victims still waiting:
            // the engine's stage-0 drain gets first pick of slots/blocks.
            return Ok(());
        }
        loop {
            let bound = engine.output_bound();
            if !engine.has_free_slot()
                || bound >= self.budget.max_tokens
                || *served >= self.budget.max_requests
            {
                return Ok(());
            }
            // Candidate: the policy's pick among arrived requests; in
            // closed-loop mode an empty queue pulls a fresh request from
            // the stream, arriving "now" by definition.
            let idx = match self.queue.select(engine.admission()) {
                Some(i) => i,
                None => {
                    if !self.arrivals.is_closed() {
                        return Ok(()); // nothing has arrived yet
                    }
                    let req = self.arrivals.pull_closed();
                    self.record_arrival(engine.clock_s(), &req);
                    let slo = engine.cfg.slo_for(req.task.name());
                    self.queue.push(req, engine.clock_s(), slo)
                }
            };
            // Clamp the tail request to the remaining budget (in place, so
            // a pool-deferred entry stays clamped — the legacy
            // pull-clamp-requeue semantics).
            let remaining = self.budget.max_tokens - bound;
            self.queue.clamp(idx, remaining);
            if !engine.can_admit(self.queue.req(idx)) {
                // Pool pressure: the entry stays queued; decode to free
                // blocks.
                return Ok(());
            }
            let entry = self.queue.remove(idx);
            *served += 1;
            engine.admit_at(entry.req, entry.arrival_s)?;
        }
    }

    /// Drain the arrival process through a continuous-batching engine:
    /// release arrivals due on the virtual clock, keep admissible slots
    /// fed per the admission policy until the token budget is fully
    /// allocated, then let the in-flight requests finish. Under open-loop
    /// arrivals the engine may sit idle between requests (the clock jumps
    /// to the next arrival); under the closed loop this reproduces the
    /// legacy pull-the-stream behavior bit-exactly.
    pub fn run_batched(&mut self, engine: &mut BatchEngine) -> Result<BatchRunMetrics> {
        let mut served = 0usize;
        loop {
            // Release due arrivals into the wait queue (no-op closed-loop).
            // Skipped once the budget is fully allocated: late arrivals
            // could never be admitted anyway.
            if engine.output_bound() < self.budget.max_tokens
                && served < self.budget.max_requests
            {
                for (arrival_s, req) in self.arrivals.due(engine.clock_s()) {
                    self.record_arrival(arrival_s, &req);
                    let slo = engine.cfg.slo_for(req.task.name());
                    self.queue.push(req, arrival_s, slo);
                }
            }
            // Load shedding (degradation controller, rust/docs/faults.md):
            // with an SLO configured — catch-all or per-task class —
            // entries whose TTFT deadline already passed can only be
            // served as goodput misses — drop them before they burn a
            // slot. Opt-in: `--controller off` (the default) never sheds,
            // keeping admission bit-exact.
            if engine.cfg.controller.is_on() && engine.cfg.has_slo() {
                let shed = self.queue.shed_overdue(engine.clock_s());
                engine.note_shed(shed);
            }
            self.admit_phase(engine, &mut served)?;
            engine.set_queue_depth(self.queue.len());
            engine.set_queue_deadline(
                self.queue.min_deadline_s().unwrap_or(f64::INFINITY),
            );
            if !engine.step_iteration()? {
                // An idle step means every slot was swept.
                debug_assert_eq!(engine.active(), 0, "idle step left active slots");
                if engine.output_bound() >= self.budget.max_tokens
                    || served >= self.budget.max_requests
                {
                    break;
                }
                // Engine idle with budget left: the policy's next pick must
                // be admittable against an empty pool, otherwise it can
                // never fit.
                if let Some(i) = self.queue.select(engine.admission()) {
                    anyhow::ensure!(
                        engine.can_admit(self.queue.req(i)),
                        "request {} cannot fit the KV pool",
                        self.queue.req(i).id
                    );
                } else if !self.arrivals.is_closed() {
                    // Open loop with nothing arrived: idle the slots
                    // forward to the next arrival — or end the run when
                    // the trace is exhausted.
                    match self.arrivals.next_arrival_s() {
                        Some(t) => engine.idle_until(t),
                        None => break,
                    }
                }
            }
        }
        let metrics = engine.finish();
        self.write_capture(&metrics)?;
        Ok(metrics)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::EngineConfig;
    use crate::models::{default_artifacts_dir, Registry};
    use crate::spec::policy::PolicyKind;
    use crate::workload::arrivals::ArrivalKind;
    use crate::workload::{Task, Workload};

    #[test]
    fn budget_defaults() {
        let b = Budget::default();
        assert!(b.max_tokens > 0 && b.max_requests > 0);
    }

    #[test]
    fn queue_priority_over_stream() {
        let stream = RequestStream::new(Workload::single(Task::Code), 1, 50);
        let mut s = Scheduler::new(stream, Budget::default());
        let mut req = RequestStream::new(Workload::single(Task::Math), 2, 50).next_request();
        req.id = 999;
        s.enqueue(req);
        assert_eq!(s.next_closed().id, 999);
        // subsequent requests come from the stream
        assert_ne!(s.next_closed().id, 999);
    }

    #[test]
    fn open_loop_rejects_single_request_engine() {
        let reg = Registry::load_or_builtin(default_artifacts_dir());
        let cfg = EngineConfig { model: "mixtral".into(), ..Default::default() };
        let mut engine = Engine::sim(&reg, cfg, PolicyKind::Static(2).build()).unwrap();
        let stream = RequestStream::new(Workload::single(Task::Code), 1, 50);
        let arrivals =
            ArrivalProcess::new(ArrivalKind::Poisson { rate: 1.0 }, stream, 1).unwrap();
        let mut sched = Scheduler::with_arrivals(arrivals, Budget::default());
        assert!(sched.run(&mut engine).is_err());
    }

    #[test]
    fn token_budget_never_overshoots() {
        // Regression: the tail request used to run with its full
        // max_new_tokens, overshooting the budget by up to a request.
        let reg = Registry::load_or_builtin(default_artifacts_dir());
        for budget_tokens in [130usize, 250, 777] {
            let cfg = EngineConfig { model: "mixtral".into(), ..Default::default() };
            let mut engine = Engine::sim(&reg, cfg, PolicyKind::Static(2).build()).unwrap();
            let stream = RequestStream::new(Workload::single(Task::Code), 5, 100);
            let mut sched = Scheduler::new(
                stream,
                Budget { max_tokens: budget_tokens, max_requests: 1_000 },
            );
            let m = sched.run(&mut engine).unwrap();
            assert!(
                m.total_tokens() <= budget_tokens,
                "budget {budget_tokens} overshot: {}",
                m.total_tokens()
            );
            assert!(m.total_tokens() >= budget_tokens.saturating_sub(1));
        }
    }

    #[test]
    fn batched_run_respects_budget() {
        let reg = Registry::load_or_builtin(default_artifacts_dir());
        let cfg = EngineConfig { model: "mixtral".into(), max_batch: 4, ..Default::default() };
        let mut engine =
            BatchEngine::sim(&reg, cfg, PolicyKind::Static(2)).unwrap();
        let stream = RequestStream::new(Workload::single(Task::Code), 5, 100);
        let mut sched =
            Scheduler::new(stream, Budget { max_tokens: 300, max_requests: 1_000 });
        let m = sched.run_batched(&mut engine).unwrap();
        assert!(m.run.total_tokens() <= 300, "batched overshoot: {}", m.run.total_tokens());
        assert!(m.run.total_tokens() > 0);
        assert!(m.run.requests.len() >= 3);
    }

    #[test]
    fn captured_trace_is_replayable() {
        let reg = Registry::load_or_builtin(default_artifacts_dir());
        let path = std::env::temp_dir().join("cascade_capture_test.jsonl");
        let path = path.to_string_lossy().into_owned();
        let cfg = EngineConfig { model: "mixtral".into(), max_batch: 2, ..Default::default() };
        let mut engine = BatchEngine::sim(&reg, cfg, PolicyKind::Static(2)).unwrap();
        let stream = RequestStream::new(Workload::single(Task::Code), 5, 100);
        let arrivals =
            ArrivalProcess::new(ArrivalKind::Poisson { rate: 50.0 }, stream, 7).unwrap();
        let mut sched = Scheduler::with_arrivals(
            arrivals,
            Budget { max_tokens: 200, max_requests: 4 },
        );
        sched.capture_trace(&path);
        let m = sched.run_batched(&mut engine).unwrap();
        assert!(!m.run.requests.is_empty());
        let text = std::fs::read_to_string(&path).unwrap();
        let arrival_lines = text.lines().filter(|l| l.contains("\"t\":")).count();
        let stream_lines = text.lines().filter(|l| l.contains("\"stream\":")).count();
        assert!(arrival_lines > 0, "capture recorded no arrivals");
        assert_eq!(
            stream_lines,
            m.run.requests.len(),
            "every completed request leaves a stream line"
        );
        assert!(
            text.lines().all(|l| l.contains("\"t\":") || l.contains("\"stream\":")),
            "unexpected capture line"
        );
        // The capture loads as a replayable trace with the same arrivals
        // (stream lines are skipped by the replayer).
        let stream2 = RequestStream::new(Workload::single(Task::Code), 5, 100);
        let mut replay =
            ArrivalProcess::new(ArrivalKind::Trace { path: path.clone() }, stream2, 7)
                .unwrap();
        let due = replay.due(f64::INFINITY);
        assert_eq!(due.len(), arrival_lines);
        let _ = std::fs::remove_file(&path);
    }
}
