//! FIFO request scheduler for single-batch serving.
//!
//! The paper's setting is single-batch, low-latency serving: one request
//! decodes at a time; mixed workloads interleave tasks *across* requests
//! (§3: "mixed workloads … comprise request streams from 2 or 3 tasks with
//! equal sharing"). The scheduler owns admission (token budget / request
//! count) and drains the stream through an engine.

use crate::coordinator::engine::Engine;
use crate::metrics::RunMetrics;
use crate::workload::{Request, RequestStream};
use anyhow::Result;
use std::collections::VecDeque;

/// Admission limits for a serving run.
#[derive(Debug, Clone, Copy)]
pub struct Budget {
    /// Stop admitting once this many output tokens were generated
    /// (the paper's mixed runs generate ≥ 20k tokens; scaled here).
    pub max_tokens: usize,
    /// Hard cap on requests (safety).
    pub max_requests: usize,
}

impl Default for Budget {
    fn default() -> Self {
        Self { max_tokens: 2_000, max_requests: 1_000 }
    }
}

/// FIFO scheduler over a request stream.
pub struct Scheduler {
    queue: VecDeque<Request>,
    stream: RequestStream,
    budget: Budget,
}

impl Scheduler {
    pub fn new(stream: RequestStream, budget: Budget) -> Self {
        Self { queue: VecDeque::new(), stream, budget }
    }

    /// Admit the next request (from queue, else freshly generated).
    fn next_request(&mut self) -> Request {
        self.queue.pop_front().unwrap_or_else(|| self.stream.next_request())
    }

    /// Enqueue an explicit request (tests / replay).
    pub fn enqueue(&mut self, req: Request) {
        self.queue.push_back(req);
    }

    /// Drain the stream through `engine` until the token budget is spent.
    pub fn run(&mut self, engine: &mut Engine) -> Result<RunMetrics> {
        let mut metrics = RunMetrics::default();
        let mut tokens = 0usize;
        let mut served = 0usize;
        while tokens < self.budget.max_tokens && served < self.budget.max_requests {
            let req = self.next_request();
            let m = engine.serve_request(&req)?;
            tokens += m.tokens_emitted();
            served += 1;
            metrics.push(m);
        }
        Ok(metrics)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::{Task, Workload};

    #[test]
    fn budget_defaults() {
        let b = Budget::default();
        assert!(b.max_tokens > 0 && b.max_requests > 0);
    }

    #[test]
    fn queue_priority_over_stream() {
        let stream = RequestStream::new(Workload::single(Task::Code), 1, 50);
        let mut s = Scheduler::new(stream, Budget::default());
        let mut req = RequestStream::new(Workload::single(Task::Math), 2, 50).next_request();
        req.id = 999;
        s.enqueue(req);
        assert_eq!(s.next_request().id, 999);
        // subsequent requests come from the stream
        assert_ne!(s.next_request().id, 999);
    }
}
