//! Target-model backend abstraction.
//!
//! The engine drives either the **real** backend (AOT HLO via PJRT — the
//! production path) or the **sim** backend (`sim::SimBackend`, a trace-level
//! model sharing the same interface, used for fast sweeps and property
//! tests; cross-validated against the real backend in integration tests).

use crate::cost::ExpertBitmap;
use crate::models::MiniConfig;
use crate::rng::Rng;
use crate::runtime::{ModelRuntime, RequestState};
use crate::sampling::sample_guided;
use crate::tokenizer::PAD;
use crate::workload::Request;
use anyhow::Result;
use std::cell::RefCell;
use std::rc::Rc;

/// Runtimes are shared across engines (one compile per model per process):
/// PJRT executables and device-resident weights are expensive; request
/// state is per-backend.
pub type SharedRuntime = Rc<RefCell<ModelRuntime>>;

/// Outputs of one target-model step over T in-flight tokens.
#[derive(Debug, Clone, Default)]
pub struct BackendStep {
    /// The target model's (guided-greedy) token for each position.
    pub sampled: Vec<u32>,
    /// Unique experts activated per mini layer across all T tokens — the
    /// cost model's input. Empty for dense models.
    pub unique_experts: Vec<usize>,
}

/// One request's span in a fused batched verify step: slot id + the
/// in-flight tokens `[last emitted, drafts…]` with their sampling guides.
#[derive(Debug, Clone)]
pub struct VerifySpan {
    pub slot: usize,
    pub tokens: Vec<u32>,
    pub guides: Vec<Option<u32>>,
    pub eps: f64,
}

/// One slot's share of a batched step's outputs.
#[derive(Debug, Clone, Default)]
pub struct SlotStep {
    pub slot: usize,
    pub step: BackendStep,
    /// Experts per mini layer that **only** this slot's tokens activated —
    /// the slot's marginal contribution to the fused fetch set (the
    /// batched-utility signal). When the backend cannot attribute expert
    /// identities (sequential fallback) this equals the slot's own unique
    /// counts: with no de-duplication every fetch is marginal.
    pub marginal_unique_experts: Vec<usize>,
    /// Per mini layer, the expert *id set* only this slot activated —
    /// the id-level view of `marginal_unique_experts`, which the engine
    /// groups by shard for the max-over-shards marginal charge under
    /// expert parallelism. Empty without id attribution.
    pub marginal_expert_ids: Vec<ExpertBitmap>,
}

/// Outputs of one fused verify step over several requests.
///
/// The engine owns one `BatchStep` as a reusable iteration arena: it hands
/// the previous iteration's buffers back to the backend through
/// [`Backend::submit_batch_reusing`], which clears and refills them in
/// place. `Default` is the empty arena.
#[derive(Debug, Clone, Default)]
pub struct BatchStep {
    pub slots: Vec<SlotStep>,
    /// Unique experts per mini layer across **all** slots' tokens,
    /// de-duplicated when the backend can attribute expert identities
    /// (SimBackend); otherwise the per-slot sums (sequential fallback).
    pub batch_unique_experts: Vec<usize>,
    /// Per-layer sum of per-slot unique counts — the no-dedup upper bound;
    /// the gap to `batch_unique_experts` is cross-request expert overlap.
    pub summed_unique_experts: Vec<usize>,
    /// Per mini layer, the deduped expert id set across the whole batch —
    /// the id-level view of `batch_unique_experts`, which the engine
    /// groups by shard under expert parallelism and feeds to the
    /// co-activation histogram. Only id-attributing backends (SimBackend)
    /// populate this; empty otherwise and for dense models.
    pub expert_ids: Vec<ExpertBitmap>,
    /// Per mini layer, the id set activated by **two or more** slots —
    /// the shared expert mass the marginal-cost fairness floor amortizes.
    /// Empty without id attribution.
    pub shared_expert_ids: Vec<ExpertBitmap>,
}

impl BatchStep {
    /// Reset for arena reuse: empties every collection while keeping their
    /// allocations (including each recycled `SlotStep`'s inner vectors,
    /// which the backend harvests via `slots.pop()` when refilling).
    pub fn reset(&mut self) {
        self.batch_unique_experts.clear();
        self.summed_unique_experts.clear();
        self.expert_ids.clear();
        self.shared_expert_ids.clear();
    }
}

/// A target model the engine can serve with.
///
/// The single-request methods (`begin`/`prefill`/`step`/`advance`) are the
/// original serving surface. The `_slot` family extends it to continuous
/// batching: multi-request backends (SimBackend) hold one routing/cache
/// state per slot; single-request backends (RealBackend) keep their default
/// impls, which accept only slot 0 — `BatchEngine` clamps its batch size to
/// [`Backend::max_slots`], so the real path degrades to sequential batch=1
/// serving instead of breaking.
pub trait Backend {
    fn mini(&self) -> &MiniConfig;
    fn name(&self) -> &'static str;

    /// Reset state for a new request.
    fn begin(&mut self, req: &Request) -> Result<()>;

    /// Process the prompt and sample the first output token (guided by
    /// `guide0`). Advances the committed cache past the prompt.
    fn prefill(&mut self, prompt: &[u32], guide0: Option<u32>, eps: f64) -> Result<u32>;

    /// Run one verify/decode step over `tokens` (1 original + K drafts).
    /// `guides[i]` is the reference token the sampler is biased toward at
    /// position `i`. Does **not** commit cache positions.
    fn step(&mut self, tokens: &[u32], guides: &[Option<u32>], eps: f64) -> Result<BackendStep>;

    /// Commit `n` in-flight positions (accepted prefix + correction).
    fn advance(&mut self, n: usize);

    /// Committed cache length.
    fn cache_len(&self) -> usize;

    // ---- Continuous-batching surface ------------------------------------

    /// How many requests this backend can hold in flight.
    fn max_slots(&self) -> usize {
        1
    }

    /// Whether `step_batch` attributes expert *identities* (per-layer id
    /// unions, per-slot exclusive ids) rather than just counts. Expert-
    /// parallel cost sharding needs identities to group loads by shard;
    /// the engine prices unsharded on backends that return false.
    fn attributes_expert_ids(&self) -> bool {
        false
    }

    /// Bind a new request to `slot`.
    fn begin_slot(&mut self, slot: usize, req: &Request) -> Result<()> {
        anyhow::ensure!(slot == 0, "backend {} is single-request (slot {slot})", self.name());
        self.begin(req)
    }

    /// Prefill `slot`'s prompt and sample its first output token.
    fn prefill_slot(
        &mut self,
        slot: usize,
        prompt: &[u32],
        guide0: Option<u32>,
        eps: f64,
    ) -> Result<u32> {
        anyhow::ensure!(slot == 0, "backend {} is single-request (slot {slot})", self.name());
        self.prefill(prompt, guide0, eps)
    }

    /// Commit `n` in-flight positions of `slot`.
    fn advance_slot(&mut self, slot: usize, n: usize) {
        debug_assert_eq!(slot, 0, "single-request backend");
        self.advance(n)
    }

    /// Committed cache length of `slot`.
    fn cache_len_slot(&self, slot: usize) -> usize {
        debug_assert_eq!(slot, 0, "single-request backend");
        self.cache_len()
    }

    /// Drop a finished request's slot state.
    fn release_slot(&mut self, _slot: usize) {}

    /// One fused verify step over the concatenated spans of all active
    /// requests. The default is a **sequential fallback** for single-slot
    /// backends: each span runs through `step` one at a time (so RealBackend
    /// keeps working at batch=1), and expert counts are summed without
    /// cross-request de-duplication because `step` reports counts, not ids.
    /// Natively-batched backends override this to route every span in one
    /// pass and de-duplicate expert fetches across the batch.
    fn step_batch(&mut self, spans: &[VerifySpan]) -> Result<BatchStep> {
        let mut slots = Vec::with_capacity(spans.len());
        let mut summed: Vec<usize> = Vec::new();
        for span in spans {
            anyhow::ensure!(
                span.slot == 0,
                "sequential fallback: backend {} holds one request (got slot {})",
                self.name(),
                span.slot
            );
            let step = self.step(&span.tokens, &span.guides, span.eps)?;
            if summed.len() < step.unique_experts.len() {
                summed.resize(step.unique_experts.len(), 0);
            }
            for (l, u) in step.unique_experts.iter().enumerate() {
                summed[l] += u;
            }
            let marginal_unique_experts = step.unique_experts.clone();
            slots.push(SlotStep {
                slot: span.slot,
                step,
                marginal_unique_experts,
                marginal_expert_ids: Vec::new(),
            });
        }
        Ok(BatchStep {
            slots,
            batch_unique_experts: summed.clone(),
            summed_unique_experts: summed,
            expert_ids: Vec::new(),
            shared_expert_ids: Vec::new(),
        })
    }

    // ---- Pipelined-verify surface ---------------------------------------

    /// Issue a fused verify step without consuming its results, so the
    /// engine can overlap iteration i+1's drafting with iteration i's
    /// verification (the paper's Fig. 14 worker pipeline). The default —
    /// correct for every synchronous backend — executes eagerly and parks
    /// the outputs in the returned handle; a genuinely asynchronous
    /// backend would enqueue device work here and block in
    /// [`Backend::wait_batch`]. Either way the engine's stage order
    /// (submit → draft ahead → wait) is what the overlap-aware cost model
    /// prices, so the simulated clock models concurrency even where the
    /// host execution is sequential.
    fn submit_batch(&mut self, spans: &[VerifySpan]) -> Result<PendingBatch> {
        Ok(PendingBatch { step: self.step_batch(spans)? })
    }

    /// [`Backend::step_batch`] with a recycled [`BatchStep`] arena: the
    /// engine hands back the previous iteration's buffers so an
    /// arena-aware backend (SimBackend) can refill them in place instead
    /// of reallocating. The default simply drops the scratch and steps
    /// fresh — correct for every backend, merely not allocation-free.
    fn step_batch_reusing(&mut self, spans: &[VerifySpan], scratch: BatchStep) -> Result<BatchStep> {
        drop(scratch);
        self.step_batch(spans)
    }

    /// [`Backend::submit_batch`] through the arena path — what the engine
    /// calls every iteration.
    fn submit_batch_reusing(
        &mut self,
        spans: &[VerifySpan],
        scratch: BatchStep,
    ) -> Result<PendingBatch> {
        Ok(PendingBatch { step: self.step_batch_reusing(spans, scratch)? })
    }

    /// Block on a verify step issued by [`Backend::submit_batch`].
    fn wait_batch(&mut self, pending: PendingBatch) -> Result<BatchStep> {
        Ok(pending.step)
    }
}

/// Handle to an in-flight fused verify step (see [`Backend::submit_batch`]).
/// Opaque so backends can later carry device futures instead of computed
/// results without touching the engine.
#[derive(Debug)]
pub struct PendingBatch {
    step: BatchStep,
}

/// Production backend: executes the AOT-compiled step HLO through PJRT.
pub struct RealBackend {
    pub runtime: SharedRuntime,
    mini: MiniConfig,
    state: RequestState,
    guide_strength: f32,
    rng: Rng,
    seed: u64,
    /// Last step's outputs, held until `advance` commits the router state
    /// at the accepted position.
    last_out: Option<crate::runtime::StepOutput>,
}

impl RealBackend {
    pub fn new(runtime: ModelRuntime, guide_strength: f32, seed: u64) -> Self {
        Self::shared(Rc::new(RefCell::new(runtime)), guide_strength, seed)
    }

    pub fn shared(runtime: SharedRuntime, guide_strength: f32, seed: u64) -> Self {
        let state = runtime.borrow().fresh_state();
        let mini = runtime.borrow().model.mini.clone();
        Self { runtime, mini, state, guide_strength, rng: Rng::new(seed), seed, last_out: None }
    }

    /// Mean unique experts/layer over a step (telemetry convenience).
    fn count_unique(&self, out: &crate::runtime::StepOutput, t: usize) -> Vec<usize> {
        if self.mini.is_moe {
            out.unique_experts_per_layer(t)
        } else {
            Vec::new()
        }
    }
}

impl Backend for RealBackend {
    fn mini(&self) -> &MiniConfig {
        &self.mini
    }

    fn name(&self) -> &'static str {
        "real"
    }

    fn begin(&mut self, req: &Request) -> Result<()> {
        self.state = self.runtime.borrow().fresh_state();
        self.rng = Rng::new(self.seed ^ req.id.wrapping_mul(0x9E37_79B9_7F4A_7C15));
        self.last_out = None;
        Ok(())
    }

    fn prefill(&mut self, prompt: &[u32], guide0: Option<u32>, eps: f64) -> Result<u32> {
        let chunk = self.mini().prefill_chunk;
        let mut last_logits: Option<Vec<f32>> = None;
        for piece in prompt.chunks(chunk) {
            let valid = piece.len();
            let mut tokens = piece.to_vec();
            // Pad the trailing chunk: padded positions are written past the
            // committed span and harmlessly overwritten later (the causal
            // mask keeps them invisible to valid queries).
            tokens.resize(chunk, PAD);
            let out = self.runtime.borrow_mut().step(&mut self.state, &tokens)?;
            self.runtime.borrow().commit_rstate(&mut self.state, &out, valid)?;
            self.state.cache_len += valid;
            last_logits = Some(out.logits_row(valid - 1).to_vec());
        }
        let logits = last_logits.expect("non-empty prompt");
        Ok(sample_guided(&logits, guide0, self.guide_strength, eps, &mut self.rng))
    }

    fn step(&mut self, tokens: &[u32], guides: &[Option<u32>], eps: f64) -> Result<BackendStep> {
        debug_assert_eq!(tokens.len(), guides.len());
        let out = self.runtime.borrow_mut().step(&mut self.state, tokens)?;
        let sampled = (0..tokens.len())
            .map(|i| {
                sample_guided(out.logits_row(i), guides[i], self.guide_strength, eps, &mut self.rng)
            })
            .collect();
        let unique_experts = self.count_unique(&out, tokens.len());
        self.last_out = Some(out);
        Ok(BackendStep { sampled, unique_experts })
    }

    fn advance(&mut self, n: usize) {
        self.state.cache_len += n;
        // Commit the router-affinity state at the accepted position so
        // rejected drafts cannot pollute future routing.
        if let Some(out) = self.last_out.take() {
            self.runtime
                .borrow()
                .commit_rstate(&mut self.state, &out, n)
                .expect("rstate commit");
        }
    }

    fn cache_len(&self) -> usize {
        self.state.cache_len
    }
}
