//! Target-model backend abstraction.
//!
//! The engine drives either the **real** backend (AOT HLO via PJRT — the
//! production path) or the **sim** backend (`sim::SimBackend`, a trace-level
//! model sharing the same interface, used for fast sweeps and property
//! tests; cross-validated against the real backend in integration tests).

use crate::models::MiniConfig;
use crate::rng::Rng;
use crate::runtime::{ModelRuntime, RequestState};
use crate::sampling::sample_guided;
use crate::tokenizer::PAD;
use crate::workload::Request;
use anyhow::Result;
use std::cell::RefCell;
use std::rc::Rc;

/// Runtimes are shared across engines (one compile per model per process):
/// PJRT executables and device-resident weights are expensive; request
/// state is per-backend.
pub type SharedRuntime = Rc<RefCell<ModelRuntime>>;

/// Outputs of one target-model step over T in-flight tokens.
#[derive(Debug, Clone)]
pub struct BackendStep {
    /// The target model's (guided-greedy) token for each position.
    pub sampled: Vec<u32>,
    /// Unique experts activated per mini layer across all T tokens — the
    /// cost model's input. Empty for dense models.
    pub unique_experts: Vec<usize>,
}

/// A target model the engine can serve with.
pub trait Backend {
    fn mini(&self) -> &MiniConfig;
    fn name(&self) -> &'static str;

    /// Reset state for a new request.
    fn begin(&mut self, req: &Request) -> Result<()>;

    /// Process the prompt and sample the first output token (guided by
    /// `guide0`). Advances the committed cache past the prompt.
    fn prefill(&mut self, prompt: &[u32], guide0: Option<u32>, eps: f64) -> Result<u32>;

    /// Run one verify/decode step over `tokens` (1 original + K drafts).
    /// `guides[i]` is the reference token the sampler is biased toward at
    /// position `i`. Does **not** commit cache positions.
    fn step(&mut self, tokens: &[u32], guides: &[Option<u32>], eps: f64) -> Result<BackendStep>;

    /// Commit `n` in-flight positions (accepted prefix + correction).
    fn advance(&mut self, n: usize);

    /// Committed cache length.
    fn cache_len(&self) -> usize;
}

/// Production backend: executes the AOT-compiled step HLO through PJRT.
pub struct RealBackend {
    pub runtime: SharedRuntime,
    mini: MiniConfig,
    state: RequestState,
    guide_strength: f32,
    rng: Rng,
    seed: u64,
    /// Last step's outputs, held until `advance` commits the router state
    /// at the accepted position.
    last_out: Option<crate::runtime::StepOutput>,
}

impl RealBackend {
    pub fn new(runtime: ModelRuntime, guide_strength: f32, seed: u64) -> Self {
        Self::shared(Rc::new(RefCell::new(runtime)), guide_strength, seed)
    }

    pub fn shared(runtime: SharedRuntime, guide_strength: f32, seed: u64) -> Self {
        let state = runtime.borrow().fresh_state();
        let mini = runtime.borrow().model.mini.clone();
        Self { runtime, mini, state, guide_strength, rng: Rng::new(seed), seed, last_out: None }
    }

    /// Mean unique experts/layer over a step (telemetry convenience).
    fn count_unique(&self, out: &crate::runtime::StepOutput, t: usize) -> Vec<usize> {
        if self.mini.is_moe {
            out.unique_experts_per_layer(t)
        } else {
            Vec::new()
        }
    }
}

impl Backend for RealBackend {
    fn mini(&self) -> &MiniConfig {
        &self.mini
    }

    fn name(&self) -> &'static str {
        "real"
    }

    fn begin(&mut self, req: &Request) -> Result<()> {
        self.state = self.runtime.borrow().fresh_state();
        self.rng = Rng::new(self.seed ^ req.id.wrapping_mul(0x9E37_79B9_7F4A_7C15));
        self.last_out = None;
        Ok(())
    }

    fn prefill(&mut self, prompt: &[u32], guide0: Option<u32>, eps: f64) -> Result<u32> {
        let chunk = self.mini().prefill_chunk;
        let mut last_logits: Option<Vec<f32>> = None;
        for piece in prompt.chunks(chunk) {
            let valid = piece.len();
            let mut tokens = piece.to_vec();
            // Pad the trailing chunk: padded positions are written past the
            // committed span and harmlessly overwritten later (the causal
            // mask keeps them invisible to valid queries).
            tokens.resize(chunk, PAD);
            let out = self.runtime.borrow_mut().step(&mut self.state, &tokens)?;
            self.runtime.borrow().commit_rstate(&mut self.state, &out, valid)?;
            self.state.cache_len += valid;
            last_logits = Some(out.logits_row(valid - 1).to_vec());
        }
        let logits = last_logits.expect("non-empty prompt");
        Ok(sample_guided(&logits, guide0, self.guide_strength, eps, &mut self.rng))
    }

    fn step(&mut self, tokens: &[u32], guides: &[Option<u32>], eps: f64) -> Result<BackendStep> {
        debug_assert_eq!(tokens.len(), guides.len());
        let out = self.runtime.borrow_mut().step(&mut self.state, tokens)?;
        let sampled = (0..tokens.len())
            .map(|i| {
                sample_guided(out.logits_row(i), guides[i], self.guide_strength, eps, &mut self.rng)
            })
            .collect();
        let unique_experts = self.count_unique(&out, tokens.len());
        self.last_out = Some(out);
        Ok(BackendStep { sampled, unique_experts })
    }

    fn advance(&mut self, n: usize) {
        self.state.cache_len += n;
        // Commit the router-affinity state at the accepted position so
        // rejected drafts cannot pollute future routing.
        if let Some(out) = self.last_out.take() {
            self.runtime
                .borrow()
                .commit_rstate(&mut self.state, &out, n)
                .expect("rstate commit");
        }
    }

    fn cache_len(&self) -> usize {
        self.state.cache_len
    }
}
