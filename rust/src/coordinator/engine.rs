//! The spec-decode serving engine (paper Fig. 14's worker, in Rust).
//!
//! Per decode iteration: ask the policy for K → draft K tokens → reserve
//! lookahead KV slots → run one verify step over [last token, drafts…] →
//! rejection-sample → commit accepted positions, roll back the rest →
//! charge the cost model with the *measured* expert activations → feed the
//! outcome back to the policy (Cascade's utility analyzer).

use crate::config::{DrafterKind, EngineConfig, MAX_K};
use crate::coordinator::backend::Backend;
use crate::coordinator::eagle::{draft_eps, EagleLite};
use crate::coordinator::pipeline::{
    plan_spec_task, reconcile_entry, run_spec_task, DrafterSnapshot, SpecDraft,
};
use crate::cost::{GpuCostModel, IterCost};
use crate::kv::KvBlockManager;
use crate::metrics::{IterRecord, RequestMetrics, RunMetrics};
use crate::models::Registry;
use crate::rng::Rng;
use crate::runtime::ModelRuntime;
use crate::spec::policy::{IterObs, SpecPolicy};
use crate::spec::rejection::{greedy_verify, truncate_at_eos};
use crate::spec::NgramDrafter;
use crate::tokenizer::EOS;
use crate::workload::Request;
use anyhow::{Context, Result};
use std::time::Instant;

/// The drafter wired into the engine.
pub enum EngineDrafter {
    /// Prompt-lookup n-gram (model-free).
    Ngram(NgramDrafter),
    /// Draft-model speculation over the AOT `draft` model.
    Eagle(EagleLite),
    /// Trace-level draft model for sim-backend sweeps: proposes the
    /// reference token with per-task accuracy; once it deviates, the rest
    /// of the proposal is noise (a real drafter continues from its own
    /// wrong token).
    SimEagle { rng: Rng, seed: u64 },
}

impl EngineDrafter {
    pub fn kind(&self) -> DrafterKind {
        match self {
            EngineDrafter::Ngram(_) => DrafterKind::Ngram,
            _ => DrafterKind::EagleLite,
        }
    }

    /// Reset per-request state and feed the first emitted token.
    pub fn begin_request(&mut self, req: &Request, first: u32) -> Result<()> {
        match self {
            EngineDrafter::Eagle(e) => {
                e.begin(req)?;
                e.ingest(&[first])?;
            }
            EngineDrafter::SimEagle { rng, seed } => {
                *rng = Rng::new(*seed ^ req.id.wrapping_mul(0xD6E8_FEB8_6659_FD93));
            }
            EngineDrafter::Ngram(_) => {}
        }
        Ok(())
    }

    /// Propose up to `k` draft tokens continuing output index `out_idx`.
    /// Positions past the end of `reference` are unguided — the drafter
    /// emits noise there, matching `sample_guided`'s fallback (it must NOT
    /// steer toward EOS, which would truncate long generations).
    pub fn propose(
        &mut self,
        context: &[u32],
        reference: &[u32],
        out_idx: usize,
        k: usize,
        d_eps: f64,
    ) -> Result<Vec<u32>> {
        if k == 0 {
            return Ok(Vec::new());
        }
        Ok(match self {
            EngineDrafter::Ngram(d) => d.propose(context, k),
            EngineDrafter::Eagle(e) => {
                let guides: Vec<Option<u32>> =
                    (0..k).map(|i| reference.get(out_idx + i).copied()).collect();
                e.propose(k, &guides, d_eps)?
            }
            EngineDrafter::SimEagle { rng, .. } => {
                crate::coordinator::pipeline::sim_eagle_propose(rng, reference, out_idx, k, d_eps)
            }
        })
    }

    /// Adopt the post-proposal state of a pipelined speculative draft that
    /// hit: the speculative scan already consumed exactly the draws serial
    /// drafting would have, so the authoritative drafter fast-forwards to
    /// that state instead of re-proposing.
    pub fn adopt(&mut self, snapshot: DrafterSnapshot) {
        if let (EngineDrafter::SimEagle { rng, .. }, DrafterSnapshot::SimEagle(r)) =
            (self, snapshot)
        {
            *rng = r;
        }
        // Ngram is stateless; Eagle never produces snapshots.
    }

    /// Keep model-based drafters' KV in sync with the emitted tokens (runs
    /// even when speculation is off — the dynamic-disable requirement the
    /// paper implements in vLLM, §6).
    pub fn ingest(&mut self, emitted: &[u32]) -> Result<()> {
        if let EngineDrafter::Eagle(e) = self {
            e.ingest(emitted)?;
        }
        Ok(())
    }
}

/// Serving engine for one model + policy + drafter.
pub struct Engine {
    pub cfg: EngineConfig,
    pub backend: Box<dyn Backend>,
    pub drafter: EngineDrafter,
    pub cost: GpuCostModel,
    pub policy: Box<dyn SpecPolicy>,
    /// KV block size (vLLM-style pages).
    pub kv_block: usize,
    /// Pipelined-drafting telemetry, cumulative across served requests
    /// (mirrors the batched engine's per-iteration records).
    pub pipeline_hits: usize,
    pub pipeline_misses: usize,
    pub draft_recomputes: usize,
}

impl Engine {
    pub fn new(
        cfg: EngineConfig,
        backend: Box<dyn Backend>,
        drafter: EngineDrafter,
        cost: GpuCostModel,
        policy: Box<dyn SpecPolicy>,
    ) -> Self {
        Self {
            cfg,
            backend,
            drafter,
            cost,
            policy,
            kv_block: 16,
            pipeline_hits: 0,
            pipeline_misses: 0,
            draft_recomputes: 0,
        }
    }

    /// Build a real-backend engine from the artifact registry.
    pub fn real(
        registry: &Registry,
        cfg: EngineConfig,
        policy: Box<dyn SpecPolicy>,
    ) -> Result<Self> {
        let runtime = ModelRuntime::load(registry, &cfg.model)
            .with_context(|| format!("loading model {}", cfg.model))?;
        let client = runtime.client();
        let mini_layers = runtime.model.mini.layers;
        let cost = GpuCostModel::new(runtime.model.paper.clone(), mini_layers);
        let backend = Box::new(crate::coordinator::backend::RealBackend::new(
            runtime,
            cfg.guide_strength,
            cfg.seed,
        ));
        let drafter = match cfg.drafter {
            DrafterKind::Ngram => {
                EngineDrafter::Ngram(NgramDrafter::new(cfg.ngram_min, cfg.ngram_max))
            }
            DrafterKind::EagleLite => {
                let draft_rt = ModelRuntime::with_client(registry, "draft", client)
                    .context("loading draft model")?;
                EngineDrafter::Eagle(EagleLite::new(draft_rt, cfg.guide_strength, cfg.seed ^ 0xE1))
            }
        };
        Ok(Self::new(cfg, backend, drafter, cost, policy))
    }

    /// Build a sim-backend engine (no HLO execution).
    pub fn sim(registry: &Registry, cfg: EngineConfig, policy: Box<dyn SpecPolicy>) -> Result<Self> {
        let model = registry.model(&cfg.model)?;
        let cost = GpuCostModel::new(model.paper.clone(), model.mini.layers);
        let backend = Box::new(crate::sim::SimBackend::new(model.mini.clone(), cfg.seed));
        let drafter = match cfg.drafter {
            DrafterKind::Ngram => {
                EngineDrafter::Ngram(NgramDrafter::new(cfg.ngram_min, cfg.ngram_max))
            }
            DrafterKind::EagleLite => {
                EngineDrafter::SimEagle { rng: Rng::new(cfg.seed ^ 0xE1), seed: cfg.seed ^ 0xE1 }
            }
        };
        Ok(Self::new(cfg, backend, drafter, cost, policy))
    }

    /// Serve one request to completion; returns its full decode trace.
    pub fn serve_request(&mut self, req: &Request) -> Result<RequestMetrics> {
        let wall_start = Instant::now(); // lint:allow(wall-clock): host-wall request telemetry, never the virtual clock
        self.policy.reset();
        self.backend.begin(req)?;

        let max_seq = self.backend.mini().max_seq;
        let mut kv = KvBlockManager::new(max_seq, self.kv_block);
        let mut metrics = RequestMetrics {
            id: req.id,
            task: req.task.name().into(),
            prompt_tokens: req.prompt.len(),
            ..Default::default()
        };

        // ---- Prefill ----------------------------------------------------
        anyhow::ensure!(
            req.prompt.len() + 2 <= max_seq,
            "prompt ({}) does not fit the {} window",
            req.prompt.len(),
            max_seq
        );
        kv.reserve(req.prompt.len())?;
        kv.commit(req.prompt.len())?;
        let guide0 = req.reference.first().copied();
        let first = self.backend.prefill(&req.prompt, guide0, req.eps)?;
        // Prefill charge: chunked full-parallel steps (excluded from TPOT).
        let chunks = req.prompt.len().div_ceil(self.backend.mini().prefill_chunk);
        metrics.prefill_s = chunks as f64 * self.cost.baseline_cost().total();

        // Drafter request setup.
        self.drafter.begin_request(req, first)?;

        let mut output: Vec<u32> = vec![first];
        let mut context: Vec<u32> = req.prompt.clone();
        context.push(first);
        let d_eps = draft_eps(req.task);
        let mut finished = first == EOS;

        // Pipelined drafting state (parity with `BatchEngine`'s stages at
        // batch=1): the one-iteration lookahead (stamped with the verify
        // window its scan ran under — the budget a hit can hide inside)
        // and the last observed iteration cost (seeds the policy's K
        // forecast).
        let pipeline = self.cfg.pipeline;
        let mut lookahead: Option<SpecDraft> = None;
        let mut last_iter_s = 0.0f64;

        // ---- Decode loop -------------------------------------------------
        while !finished && output.len() < req.max_new_tokens {
            let out_idx = output.len(); // next output index to produce
            // ---- Plan: policy decision, capped by KV capacity, variant
            // set, and the remaining output budget.
            let mut k = self.policy.next_k().min(MAX_K);
            let room = max_seq.saturating_sub(self.backend.cache_len() + 1);
            k = k.min(room);
            k = k.min(req.max_new_tokens.saturating_sub(out_idx).saturating_sub(1));
            if room == 0 {
                break; // window exhausted
            }

            // Reference guides for draft positions (draft i continues output
            // index out_idx + i). Past the reference end the guide is None —
            // unguided sampling — NOT a forced EOS, which would silently
            // truncate generations longer than the reference.
            let ref_at = |j: usize| -> Option<u32> { req.reference.get(j).copied() };

            // ---- Draft: reconcile the lookahead, else scan now -----------
            // (Shared rule with `BatchEngine::draft_stage` — batch=1
            // parity depends on both engines reconciling identically.)
            let rec = reconcile_entry(lookahead.take(), req.id, k, &context, &mut self.drafter);
            let pipelined_hit = rec.hit;
            let hit_window_s = rec.hidden_window_s;
            if rec.hit {
                self.pipeline_hits += 1;
            }
            if rec.recompute {
                self.draft_recomputes += 1;
            }
            let (drafts, draft_wall_ns) = match rec.taken {
                Some(d) => d,
                None => {
                    if pipeline && k > 0 {
                        self.pipeline_misses += 1; // a bubble: draft on the critical path
                    }
                    let draft_wall = Instant::now(); // lint:allow(wall-clock): measures draft_wall_ns telemetry
                    let d = self.drafter.propose(&context, &req.reference, out_idx, k, d_eps)?;
                    (d, draft_wall.elapsed().as_nanos() as u64)
                }
            };
            let drafted = drafts.len();

            // ---- Verify --------------------------------------------------
            let t = 1 + drafted;
            kv.reserve(t)?;
            let mut tokens = Vec::with_capacity(t);
            tokens.push(*output.last().unwrap());
            tokens.extend_from_slice(&drafts);
            let guides: Vec<Option<u32>> = (0..t).map(|i| ref_at(out_idx + i)).collect();

            let iter_wall = Instant::now(); // lint:allow(wall-clock): host-wall verify telemetry, never the virtual clock
            let step = self.backend.step(&tokens, &guides, req.eps)?;

            // Speculatively draft the *next* iteration — conceptually under
            // this verify step (the task only uses pre-verify knowledge:
            // the in-flight drafts and the full-acceptance prediction).
            // Its wall time is charged to the overlap window, not the
            // iteration (see `spec_wall_ns` below).
            let mut spec_wall_ns = 0u64;
            if pipeline {
                let spec_wall = Instant::now(); // lint:allow(wall-clock): measures spec_wall_ns overlap telemetry
                lookahead = plan_spec_task(
                    0,
                    req,
                    self.policy.as_ref(),
                    &self.drafter,
                    &context,
                    out_idx,
                    self.backend.cache_len(),
                    max_seq,
                    &drafts,
                    k,
                    last_iter_s,
                    d_eps,
                )
                .map(run_spec_task);
                spec_wall_ns = spec_wall.elapsed().as_nanos() as u64;
            }

            // ---- Rejection sampling ---------------------------------------
            let vr = greedy_verify(&drafts, &step.sampled);
            let (emitted, eos_hit) = truncate_at_eos(&vr.emitted, EOS);
            let advance = 1 + vr.accepted;
            kv.commit(advance)?;
            self.backend.advance(advance);

            // Drafter stays in sync (even when speculation was off).
            self.drafter.ingest(&emitted)?;

            output.extend_from_slice(&emitted);
            context.extend_from_slice(&emitted);
            finished = eos_hit;

            // ---- Cost + policy feedback ----------------------------------
            // Overlap rule: a hit's drafting ran while an earlier
            // iteration verified, so it is charged only where it exceeds
            // the window it drafted under (max(draft, verify) semantics).
            let cost_full = self
                .cost
                .verify_cost(&step.unique_experts, t, drafted, self.drafter.kind());
            let draft_hidden_s = if pipelined_hit {
                cost_full.draft_s.min(hit_window_s)
            } else {
                0.0
            };
            let cost = IterCost { draft_hidden_s, ..cost_full };
            // Stamp the fresh lookahead entry with the verify window its
            // scan ran under (mirrors the batched engine's stamping).
            if let Some(e) = lookahead.as_mut() {
                e.window_s.get_or_insert(cost.verify_s());
            }
            let mean_unique = if step.unique_experts.is_empty() {
                0.0
            } else {
                step.unique_experts.iter().sum::<usize>() as f64
                    / step.unique_experts.len() as f64
            };
            let phase = self.policy.phase();
            let obs = IterObs {
                k_chosen: k,
                drafted,
                accepted: vr.accepted,
                emitted: emitted.len(),
                iter_s: cost.total(),
            };
            last_iter_s = obs.iter_s;
            self.policy.observe(&obs);
            metrics.iters.push(IterRecord {
                k_chosen: k,
                drafted,
                accepted: vr.accepted,
                emitted: emitted.len(),
                cost,
                wall_ns: (iter_wall.elapsed().as_nanos() as u64).saturating_sub(spec_wall_ns)
                    + if pipelined_hit { 0 } else { draft_wall_ns },
                unique_experts: mean_unique,
                phase,
            });
        }

        metrics.wall_total_ns = wall_start.elapsed().as_nanos() as u64;
        metrics.output = output;
        Ok(metrics)
    }

    /// Serve a request list back-to-back (single-batch, FIFO).
    pub fn serve_all(&mut self, reqs: &[Request]) -> Result<RunMetrics> {
        let mut run = RunMetrics::default();
        for req in reqs {
            run.push(self.serve_request(req)?);
        }
        Ok(run)
    }

    /// Name for experiment tables.
    pub fn label(&self) -> String {
        format!("{}/{}", self.cfg.model, self.policy.name())
    }
}

/// Compact result of one serving run (for experiment tables).
#[derive(Debug, Clone)]
pub struct RunSummary {
    pub model: String,
    pub task: String,
    pub policy: String,
    pub tokens: usize,
    pub tpot_s: f64,
    pub etr: f64,
    pub mean_iter_s: f64,
    pub test_fraction: f64,
    pub wall_s: f64,
}

impl RunSummary {
    pub fn from_run(model: &str, task: &str, policy: &str, run: &RunMetrics) -> Self {
        let iters: usize = run.requests.iter().map(|r| r.iters.len()).sum();
        Self {
            model: model.into(),
            task: task.into(),
            policy: policy.into(),
            tokens: run.total_tokens(),
            tpot_s: run.tpot_s(),
            etr: run.mean_etr(),
            mean_iter_s: if iters == 0 {
                f64::NAN
            } else {
                run.total_decode_s() / iters as f64
            },
            test_fraction: run.test_phase_fraction(),
            wall_s: run
                .requests
                .iter()
                .map(|r| r.wall_total_ns as f64 / 1e9)
                .sum(),
        }
    }
}
