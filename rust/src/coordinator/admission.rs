//! Admission policies: the ordering layer between arrivals and slots.
//!
//! Before this module, admission ordering was split across two ad-hoc
//! mechanisms: the scheduler's `push_front` requeue (a pool-pressured
//! request went back to the head of a hidden FIFO) and the batch engine's
//! internal FIFO of parked eviction victims (always drained at iteration
//! start, *after* the scheduler's fresh admissions had already grabbed
//! slots and blocks). Both decisions now live behind one
//! [`AdmissionPolicy`]:
//!
//! * **ordering among waiting arrivals** — [`AdmissionPolicy::select`]
//!   picks the next entry of the [`AdmissionQueue`] (FCFS by arrival
//!   sequence, or EDF by `arrival + SLO` deadline);
//! * **parked victims vs fresh arrivals** — [`AdmissionPolicy::parked_first`]
//!   decides whether fresh admission is held back while evicted requests
//!   wait for re-admission (the ROADMAP's "eviction-aware admission
//!   ordering" follow-on);
//! * **the PR-1 budget law** — [`AdmissionQueue::clamp`] clamps the tail
//!   request to the remaining token budget, exactly as the scheduler used
//!   to inline it (a request emits at most `max_new_tokens - 1` counted
//!   tokens, hence the `+ 1`).
//!
//! `fcfs` (the default) reproduces the pre-refactor ordering bit-exactly;
//! see rust/docs/serving.md for the policy semantics and the losslessness
//! argument.

use crate::config::AdmissionKind;
use crate::workload::Request;
use std::collections::VecDeque;

/// One arrived-but-not-admitted request.
#[derive(Debug, Clone)]
pub struct QueuedRequest {
    pub req: Request,
    /// Arrival stamp on the engine's virtual clock (simulated seconds).
    pub arrival_s: f64,
    /// TTFT deadline (`arrival_s + slo`), stamped at push from the
    /// request's own SLO — per-task classes make deadlines a per-entry
    /// fact, not a queue-wide constant. `f64::INFINITY` when the request
    /// has no SLO.
    pub deadline_s: f64,
    /// Monotone arrival sequence number (FCFS order, EDF tie-break).
    pub seq: u64,
}

/// The per-entry facts a policy may order by.
#[derive(Debug, Clone, Copy)]
pub struct WaitingView {
    /// The entry's stamped TTFT deadline (`f64::INFINITY` without an SLO).
    pub deadline_s: f64,
    pub seq: u64,
}

/// Admission-ordering policy. Implementations are stateless orderings; the
/// queue itself (and the budget accounting) stays in the scheduler layer.
pub trait AdmissionPolicy {
    fn kind(&self) -> AdmissionKind;

    /// Fresh admissions are held back while parked eviction victims wait
    /// (the engine's stage-0 re-admission drain then gets first pick of
    /// slots and pool blocks).
    fn parked_first(&self) -> bool;

    /// Index of the next entry to admit, or `None` when nothing waits.
    fn select(&self, waiting: &[WaitingView]) -> Option<usize>;
}

/// First-come-first-served (the legacy ordering, bit-exact default).
struct Fcfs;

/// FCFS among arrivals, but parked victims re-admit ahead of fresh ones.
struct ParkedFirst;

/// Earliest-deadline-first against the per-request SLO; parked victims
/// (the oldest outstanding deadlines) also drain first.
struct Edf;

fn min_by_seq(waiting: &[WaitingView]) -> Option<usize> {
    waiting
        .iter()
        .enumerate()
        .min_by_key(|(_, w)| w.seq)
        .map(|(i, _)| i)
}

impl AdmissionPolicy for Fcfs {
    fn kind(&self) -> AdmissionKind {
        AdmissionKind::Fcfs
    }
    fn parked_first(&self) -> bool {
        false
    }
    fn select(&self, waiting: &[WaitingView]) -> Option<usize> {
        min_by_seq(waiting)
    }
}

impl AdmissionPolicy for ParkedFirst {
    fn kind(&self) -> AdmissionKind {
        AdmissionKind::ParkedFirst
    }
    fn parked_first(&self) -> bool {
        true
    }
    fn select(&self, waiting: &[WaitingView]) -> Option<usize> {
        min_by_seq(waiting)
    }
}

impl AdmissionPolicy for Edf {
    fn kind(&self) -> AdmissionKind {
        AdmissionKind::Edf
    }
    fn parked_first(&self) -> bool {
        true
    }
    fn select(&self, waiting: &[WaitingView]) -> Option<usize> {
        let mut best: Option<usize> = None;
        for (i, w) in waiting.iter().enumerate() {
            let better = match best {
                None => true,
                Some(j) => {
                    let b = &waiting[j];
                    match w.deadline_s.total_cmp(&b.deadline_s) {
                        std::cmp::Ordering::Less => true,
                        std::cmp::Ordering::Equal => w.seq < b.seq,
                        std::cmp::Ordering::Greater => false,
                    }
                }
            };
            if better {
                best = Some(i);
            }
        }
        best
    }
}

/// Instantiate the policy for a configured kind.
pub fn build_policy(kind: AdmissionKind) -> Box<dyn AdmissionPolicy> {
    match kind {
        AdmissionKind::Fcfs => Box::new(Fcfs),
        AdmissionKind::ParkedFirst => Box::new(ParkedFirst),
        AdmissionKind::Edf => Box::new(Edf),
    }
}

/// The wait queue of arrived requests, held in arrival order. Selection is
/// policy-driven; entries leave only on admission (`remove`) — a
/// pool-pressured candidate simply stays queued, replacing the old
/// `push_front` requeue hack.
#[derive(Default)]
pub struct AdmissionQueue {
    entries: VecDeque<QueuedRequest>,
    next_seq: u64,
}

impl AdmissionQueue {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Append an arrival with its TTFT SLO (`slo_s ≤ 0` = no deadline);
    /// returns its index (always the back). The deadline is stamped here —
    /// once, from the SLO the *request's task* carries — so every later
    /// ordering/shedding decision is a pure read of per-entry facts.
    pub fn push(&mut self, req: Request, arrival_s: f64, slo_s: f64) -> usize {
        let seq = self.next_seq;
        self.next_seq += 1;
        let deadline_s = if slo_s > 0.0 { arrival_s + slo_s } else { f64::INFINITY };
        self.entries.push_back(QueuedRequest { req, arrival_s, deadline_s, seq });
        self.entries.len() - 1
    }

    pub fn req(&self, i: usize) -> &Request {
        &self.entries[i].req
    }

    pub fn remove(&mut self, i: usize) -> QueuedRequest {
        self.entries.remove(i).expect("admission queue index in range")
    }

    /// The PR-1 budget law, folded in from the scheduler: clamp entry `i`
    /// to the remaining token budget so the run can never overshoot
    /// `max_tokens`. A request with `max_new_tokens = n` contributes at
    /// most `n - 1` counted tokens (the prefill token is not an iteration
    /// emission), hence the `+ 1`. Destructive on the queued entry — like
    /// the legacy pull-clamp-requeue, a re-attempt re-clamps against the
    /// then-current remaining budget.
    pub fn clamp(&mut self, i: usize, remaining: usize) {
        let req = &mut self.entries[i].req;
        req.max_new_tokens = req.max_new_tokens.min(remaining + 1);
    }

    /// Load shedding for the degradation controller (rust/docs/faults.md):
    /// drop every waiting entry whose stamped deadline has already passed
    /// at `now_s` — the request cannot possibly meet its TTFT SLO, so
    /// admitting it would burn pool blocks and verify time on work the
    /// goodput metric must count as a miss anyway. Entries without an SLO
    /// (infinite deadline) are never shed. Returns how many entries were
    /// shed. Only the scheduler calls this, and only with `--controller
    /// adaptive` under a configured SLO; shed requests never reach the
    /// engine, so they appear in no per-request metrics.
    pub fn shed_overdue(&mut self, now_s: f64) -> usize {
        let before = self.entries.len();
        self.entries.retain(|e| e.deadline_s > now_s);
        before - self.entries.len()
    }

    /// The tightest waiting deadline, or `None` when the queue is empty —
    /// the degradation controller's EDF slack signal.
    pub fn min_deadline_s(&self) -> Option<f64> {
        self.entries.iter().map(|e| e.deadline_s).min_by(|a, b| a.total_cmp(b))
    }

    /// Policy-ordered pick among the waiting entries.
    pub fn select(&self, policy: &dyn AdmissionPolicy) -> Option<usize> {
        let views: Vec<WaitingView> = self
            .entries
            .iter()
            .map(|e| WaitingView { deadline_s: e.deadline_s, seq: e.seq })
            .collect();
        policy.select(&views)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::{RequestStream, Workload};

    fn reqs(n: usize) -> Vec<Request> {
        let w = Workload::by_name("code+math").unwrap();
        RequestStream::new(w, 3, 50).take(n)
    }

    #[test]
    fn fcfs_selects_in_arrival_order() {
        let mut q = AdmissionQueue::new();
        for (i, r) in reqs(3).into_iter().enumerate() {
            q.push(r, i as f64, 0.0);
        }
        let p = build_policy(AdmissionKind::Fcfs);
        assert!(!p.parked_first());
        let i = q.select(p.as_ref()).unwrap();
        assert_eq!(i, 0, "FCFS admits the oldest arrival");
        let first = q.remove(i);
        assert_eq!(first.seq, 0);
        assert_eq!(q.select(p.as_ref()).unwrap(), 0);
        assert_eq!(q.len(), 2);
    }

    #[test]
    fn parked_first_is_fcfs_plus_priority() {
        let p = build_policy(AdmissionKind::ParkedFirst);
        assert!(p.parked_first());
        let mut q = AdmissionQueue::new();
        for (i, r) in reqs(2).into_iter().enumerate() {
            q.push(r, i as f64, 0.0);
        }
        assert_eq!(q.select(p.as_ref()).unwrap(), 0);
    }

    #[test]
    fn edf_selects_earliest_deadline() {
        let mut q = AdmissionQueue::new();
        // Arrivals at t = 0, 1, 2 with a uniform SLO: deadlines follow
        // arrival order, so EDF == FCFS here…
        for (i, r) in reqs(3).into_iter().enumerate() {
            q.push(r, i as f64, 0.5);
        }
        let p = build_policy(AdmissionKind::Edf);
        assert!(p.parked_first());
        assert_eq!(q.select(p.as_ref()).unwrap(), 0);
        // …but an explicit earlier deadline wins regardless of queue
        // position (simulate by giving a later entry an earlier arrival).
        let mut q2 = AdmissionQueue::new();
        let rs = reqs(3);
        q2.push(rs[0].clone(), 5.0, 2.0);
        q2.push(rs[1].clone(), 1.0, 2.0);
        q2.push(rs[2].clone(), 3.0, 2.0);
        assert_eq!(q2.select(p.as_ref()).unwrap(), 1);
        // Per-entry SLOs (task classes): a later arrival with a tighter
        // class deadline overtakes, and a no-SLO entry (infinite
        // deadline) always yields to any deadlined one.
        let mut q4 = AdmissionQueue::new();
        q4.push(rs[0].clone(), 0.0, 0.0); // no SLO → infinite deadline
        q4.push(rs[1].clone(), 1.0, 5.0); // deadline 6
        q4.push(rs[2].clone(), 2.0, 1.0); // deadline 3 — tightest
        assert_eq!(q4.select(p.as_ref()).unwrap(), 2);
        // Deadline ties break by arrival sequence.
        let mut q3 = AdmissionQueue::new();
        q3.push(rs[0].clone(), 2.0, 1.0);
        q3.push(rs[1].clone(), 2.0, 1.0);
        assert_eq!(q3.select(p.as_ref()).unwrap(), 0);
    }

    #[test]
    fn clamp_is_the_pr1_budget_law() {
        let mut q = AdmissionQueue::new();
        let mut r = reqs(1).remove(0);
        r.max_new_tokens = 100;
        q.push(r, 0.0, 0.0);
        // remaining + 1, never widening.
        q.clamp(0, 40);
        assert_eq!(q.req(0).max_new_tokens, 41);
        q.clamp(0, 70);
        assert_eq!(q.req(0).max_new_tokens, 41, "re-clamp must never widen");
        q.clamp(0, 10);
        assert_eq!(q.req(0).max_new_tokens, 11);
    }

    #[test]
    fn shed_overdue_drops_only_unmeetable_deadlines() {
        let mut q = AdmissionQueue::new();
        for (i, r) in reqs(3).into_iter().enumerate() {
            q.push(r, i as f64, 0.5); // arrivals at t = 0, 1, 2
        }
        // SLO 0.5s at now = 1.6: deadlines 0.5 and 1.5 are past, 2.5 holds.
        assert_eq!(q.shed_overdue(1.6), 2);
        assert_eq!(q.len(), 1);
        let p = build_policy(AdmissionKind::Fcfs);
        let i = q.select(p.as_ref()).unwrap();
        assert_eq!(q.remove(i).arrival_s, 2.0, "the survivor is the freshest arrival");
        // A deadline exactly at `now` is already missed (strict >).
        let mut q2 = AdmissionQueue::new();
        q2.push(reqs(1).remove(0), 1.0, 0.5);
        assert_eq!(q2.shed_overdue(1.5), 1);
        assert!(q2.is_empty());
        // Nothing overdue: no-op.
        let mut q3 = AdmissionQueue::new();
        q3.push(reqs(1).remove(0), 1.0, 0.5);
        assert_eq!(q3.shed_overdue(1.0), 0);
        assert_eq!(q3.len(), 1);
        // The controller's slack signal: tightest waiting deadline.
        assert_eq!(q3.min_deadline_s(), Some(1.5));
        assert_eq!(AdmissionQueue::new().min_deadline_s(), None);
        // No-SLO entries are never shed, and never set a deadline.
        let mut q5 = AdmissionQueue::new();
        q5.push(reqs(1).remove(0), 1.0, 0.0);
        assert_eq!(q5.shed_overdue(1e9), 0, "no deadline, nothing to miss");
        assert_eq!(q5.min_deadline_s(), Some(f64::INFINITY));
    }

    #[test]
    fn empty_queue_selects_nothing() {
        let q = AdmissionQueue::new();
        for kind in [AdmissionKind::Fcfs, AdmissionKind::ParkedFirst, AdmissionKind::Edf] {
            assert!(q.select(build_policy(kind).as_ref()).is_none());
        }
    }
}
