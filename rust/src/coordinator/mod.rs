//! The L3 serving coordinator (paper Fig. 14): request scheduling, the
//! spec-decode worker loop, drafter orchestration, KV management, and the
//! Cascade policy integration. Two serving paths share the stack: the
//! paper's single-batch low-latency engine (`engine`) and the
//! continuous-batching engine (`batch`) that fuses the verify spans of all
//! in-flight requests into one step with batch-deduplicated expert cost.
//! Both paths optionally run the two-stage drafting pipeline (`pipeline`):
//! draft iteration i+1 under iteration i's verify, reconcile on commit.

pub mod admission;
pub mod backend;
pub mod batch;
pub mod eagle;
pub mod eviction;
pub mod engine;
pub mod pipeline;
pub mod scheduler;

pub use backend::{Backend, BackendStep, BatchStep, PendingBatch, RealBackend, SlotStep, VerifySpan};
pub use batch::BatchEngine;
pub use engine::{Engine, RunSummary};
pub use scheduler::Scheduler;
