//! The L3 serving coordinator (paper Fig. 14): request scheduling, the
//! spec-decode worker loop, drafter orchestration, KV management, and the
//! Cascade policy integration. Two serving paths share the stack: the
//! paper's single-batch low-latency engine (`engine`) and the
//! continuous-batching engine (`batch`) that fuses the verify spans of all
//! in-flight requests into one step with batch-deduplicated expert cost.
//! Both paths optionally run the two-stage drafting pipeline (`pipeline`):
//! draft iteration i+1 under iteration i's verify, reconcile on commit.

pub mod admission;
pub mod backend;
pub mod batch;
pub mod eagle;
pub mod eviction;
pub mod engine;
pub mod faults;
pub mod pipeline;
pub mod scheduler;

pub use backend::{Backend, BackendStep, BatchStep, PendingBatch, RealBackend, SlotStep, VerifySpan};
pub use batch::BatchEngine;
pub use engine::{Engine, RunSummary};
pub use scheduler::Scheduler;

/// Structured serve-path failure. The batched engine's hot loops used to
/// surface scheduling dead-ends as ad-hoc `anyhow::bail!` strings (and a
/// few hard `panic!`s); callers could neither distinguish a deadlock from
/// an I/O error nor salvage the partial run. Every non-bug engine failure
/// now carries this type (via `anyhow::Error`, so existing `?` plumbing is
/// untouched) — `main` downcasts it to emit partial metrics and a distinct
/// exit code instead of discarding the run. See rust/docs/faults.md.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum EngineError {
    /// KV-pool deadlock with eviction off (or no feasible victim set):
    /// nothing in flight can reserve its span and nothing can progress.
    Deadlock { waiting: usize },
    /// Every eviction candidate is pinned at `max_preemptions_per_req`:
    /// the preemption cap turned pool pressure into a dead-end.
    CappedDeadlock { cap: usize, waiting: usize },
}

impl std::fmt::Display for EngineError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            EngineError::Deadlock { waiting } => write!(
                f,
                "KV pool deadlock: {waiting} request(s) deferred and no slot can \
                 reserve its span (grow --kv-pool-blocks or enable --eviction)"
            ),
            EngineError::CappedDeadlock { cap, waiting } => write!(
                f,
                "KV pool deadlock: {waiting} request(s) deferred and every eviction \
                 candidate is pinned at the --max-preemptions cap ({cap})"
            ),
        }
    }
}

impl std::error::Error for EngineError {}
