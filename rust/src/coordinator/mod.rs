//! The L3 serving coordinator (paper Fig. 14): request scheduling, the
//! spec-decode worker loop, drafter orchestration, KV management, and the
//! Cascade policy integration. Single-batch serving, per the paper's
//! low-latency focus.

pub mod backend;
pub mod eagle;
pub mod engine;
pub mod scheduler;

pub use backend::{Backend, BackendStep, RealBackend};
pub use engine::{Engine, RunSummary};
pub use scheduler::Scheduler;
