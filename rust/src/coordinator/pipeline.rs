//! Two-stage pipelined drafting (paper Fig. 14's worker pipeline).
//!
//! While the backend verifies iteration *i*'s fused spans, the engine
//! speculatively drafts iteration *i+1*'s proposals for every live slot.
//! The speculation assumes the **full-acceptance continuation**: every
//! in-flight draft token lands, and the bonus/correction token is the
//! reference continuation (the guided sampler's 1−ε outcome). When the
//! verify step confirms exactly that tail, the pre-computed draft is used
//! as-is — its CPU time ran hidden under the verify window — and the
//! drafter's post-proposal state is adopted so the token stream is
//! bit-identical to serial drafting. Any broken assumption (rejection, a
//! sampler deviation, a policy K change, pool pressure) discards the
//! speculative draft and recomputes it serially: a pipeline bubble.
//!
//! Losslessness is the invariant: a hit replays precisely the draft the
//! serial engine would have produced (same context, same K, same drafter
//! state), so pipelining changes *when* drafting work happens, never what
//! tokens come out.
//!
//! The per-slot speculative scans are independent CPU work (the n-gram
//! drafter is a context scan), so they fan out across `std::thread::scope`
//! threads — which is why the drafter state travels as the `Send`-able
//! [`DrafterSnapshot`] rather than as `EngineDrafter` (whose draft-model
//! variant holds an `Rc`'d runtime and cannot cross threads; it reports
//! `None` and simply never pipelines).

use crate::config::MAX_K;
use crate::coordinator::engine::EngineDrafter;
use crate::rng::Rng;
use crate::spec::policy::{IterObs, SpecPolicy};
use crate::spec::NgramDrafter;
use crate::tokenizer::EOS;
use crate::workload::Request;
use std::time::Instant;

/// Trace-level draft-model proposal (shared by the live drafter and its
/// pipelined snapshot — both must consume the rng stream identically, or a
/// pipeline hit would diverge from serial drafting).
pub(crate) fn sim_eagle_propose(
    rng: &mut Rng,
    reference: &[u32],
    out_idx: usize,
    k: usize,
    d_eps: f64,
) -> Vec<u32> {
    let mut out = Vec::with_capacity(k);
    let mut broken = false;
    for i in 0..k {
        match reference.get(out_idx + i) {
            Some(&g) if !broken && !rng.chance(d_eps) => out.push(g),
            _ => {
                broken = true;
                out.push(rng.below(320) as u32);
            }
        }
    }
    out
}

/// `Send`-able snapshot of a drafter's mutable state, so speculative
/// proposals can run on worker threads and, on a hit, hand the advanced
/// state back to the authoritative drafter.
#[derive(Debug, Clone)]
pub enum DrafterSnapshot {
    /// The n-gram scan is stateless: the snapshot is just the config.
    Ngram(NgramDrafter),
    /// The trace-level draft model's entire state is its rng stream.
    SimEagle(Rng),
}

impl DrafterSnapshot {
    /// Snapshot a drafter, or `None` when its state cannot cross threads
    /// (the real draft-model drafter) — that drafter never pipelines.
    pub fn of(drafter: &EngineDrafter) -> Option<Self> {
        match drafter {
            EngineDrafter::Ngram(d) => Some(DrafterSnapshot::Ngram(d.clone())),
            EngineDrafter::SimEagle { rng, .. } => Some(DrafterSnapshot::SimEagle(rng.clone())),
            EngineDrafter::Eagle(_) => None,
        }
    }

    /// Mirror of [`EngineDrafter::propose`] over the snapshot state.
    pub fn propose(
        &mut self,
        context: &[u32],
        reference: &[u32],
        out_idx: usize,
        k: usize,
        d_eps: f64,
    ) -> Vec<u32> {
        if k == 0 {
            return Vec::new();
        }
        match self {
            DrafterSnapshot::Ngram(d) => d.propose(context, k),
            DrafterSnapshot::SimEagle(rng) => sim_eagle_propose(rng, reference, out_idx, k, d_eps),
        }
    }
}

/// One slot's speculative next-iteration draft, produced under the current
/// iteration's verify window and held in the engine's one-iteration
/// lookahead buffer.
#[derive(Debug, Clone)]
pub struct SpecDraft {
    pub slot: usize,
    /// Guards against slot reuse: a finished request's slot can be rebound
    /// to a new request between iterations.
    pub req_id: u64,
    /// Context length (prompt + output) the draft assumed.
    pub expected_ctx_len: usize,
    /// The tokens the in-flight iteration was assumed to emit: its drafts
    /// (all accepted) plus the reference bonus token.
    pub expected_tail: Vec<u32>,
    /// The K the policy was forecast to choose for the next iteration.
    pub k_assumed: usize,
    /// The speculative proposal itself (may be shorter than `k_assumed` —
    /// the n-gram scan proposes what it finds).
    pub drafts: Vec<u32>,
    /// Host wall time the speculative scan took (hidden on a hit).
    pub draft_wall_ns: u64,
    /// Drafter state after proposing; adopted on a hit so the drafter
    /// stream is exactly what serial drafting would have produced.
    pub snapshot_after: DrafterSnapshot,
    /// The verify window (simulated seconds) this scan ran under — the
    /// overlap budget a hit can hide inside. Stamped by the engine once
    /// the iteration's fused cost is known; `None` until then.
    pub window_s: Option<f64>,
}

/// Owned inputs of one slot's speculative draft (everything a worker
/// thread needs — no borrows into engine state).
#[derive(Debug)]
pub struct SpecTask {
    slot: usize,
    req_id: u64,
    /// Predicted post-iteration context: current context + expected tail.
    ctx: Vec<u32>,
    expected_tail: Vec<u32>,
    reference: Vec<u32>,
    /// Next output index under the prediction.
    out_idx: usize,
    k: usize,
    d_eps: f64,
    snapshot: DrafterSnapshot,
}

/// Build the speculative draft task for one slot, or `None` when the next
/// iteration is unpredictable or not worth speculating on: the request is
/// predicted to finish (EOS in the tail, budget or window exhaustion), the
/// bonus token is past the reference (unguided), the policy cannot
/// forecast its K, or the forecast K is 0 (an empty draft is free to
/// recompute).
///
/// `out_len` / `cache_len` are the slot's output length and committed
/// cache *before* the in-flight iteration commits; `drafts` / `k_chosen`
/// are the in-flight iteration's proposal; `last_iter_s` seeds the
/// forecast observation's cost (the policy's utility signal — a stale
/// value can only mispredict K, costing a bubble).
#[allow(clippy::too_many_arguments)]
pub fn plan_spec_task(
    slot: usize,
    req: &Request,
    policy: &dyn SpecPolicy,
    drafter: &EngineDrafter,
    context: &[u32],
    out_len: usize,
    cache_len: usize,
    max_seq: usize,
    drafts: &[u32],
    k_chosen: usize,
    last_iter_s: f64,
    d_eps: f64,
) -> Option<SpecTask> {
    let snapshot = DrafterSnapshot::of(drafter)?;
    let drafted = drafts.len();
    // Full-acceptance prediction: every draft lands and the bonus token is
    // the reference continuation. Past the reference end sampling is
    // unguided — unpredictable, skip.
    let bonus = *req.reference.get(out_len + drafted)?;
    if bonus == EOS || drafts.contains(&EOS) {
        return None; // predicted to finish: nothing to draft for
    }
    let out_next = out_len + drafted + 1;
    if out_next >= req.max_new_tokens {
        return None; // output budget will be exhausted this iteration
    }
    // Committed cache after a full-acceptance advance (1 + drafted).
    let cache_next = cache_len + 1 + drafted;
    let room = max_seq.saturating_sub(cache_next + 1);
    if room == 0 {
        return None; // KV window will be exhausted
    }
    let predicted = IterObs {
        k_chosen,
        drafted,
        accepted: drafted,
        emitted: drafted + 1,
        iter_s: last_iter_s,
    };
    // Same K caps the plan stage will apply next iteration (the shared
    // pool cannot be forecast — pool-shrunk K surfaces as a mismatch).
    let mut k = policy.predict_next_k(&predicted)?.min(MAX_K);
    k = k.min(room);
    k = k.min(req.max_new_tokens.saturating_sub(out_next).saturating_sub(1));
    if k == 0 {
        return None;
    }
    let mut expected_tail = Vec::with_capacity(drafted + 1);
    expected_tail.extend_from_slice(drafts);
    expected_tail.push(bonus);
    let mut ctx = Vec::with_capacity(context.len() + expected_tail.len());
    ctx.extend_from_slice(context);
    ctx.extend_from_slice(&expected_tail);
    // Only the trace-level draft model reads the reference while
    // proposing; the n-gram scan is context-only, so skip the copy.
    let reference = match &snapshot {
        DrafterSnapshot::SimEagle(_) => req.reference.clone(),
        DrafterSnapshot::Ngram(_) => Vec::new(),
    };
    Some(SpecTask {
        slot,
        req_id: req.id,
        ctx,
        expected_tail,
        reference,
        out_idx: out_next,
        k,
        d_eps,
        snapshot,
    })
}

/// Outcome of reconciling one slot's lookahead entry against the K the
/// plan stage actually chose.
pub struct Reconciled {
    /// The speculative drafts + their scan wall time, when the entry hit.
    pub taken: Option<(Vec<u32>, u64)>,
    pub hit: bool,
    /// An entry existed but an assumption broke while drafting is still
    /// needed (K > 0): the speculation must be recomputed.
    pub recompute: bool,
    /// On a hit, the verify window the scan ran under (its hiding budget
    /// for the overlap cost rule); 0.0 otherwise.
    pub hidden_window_s: f64,
}

/// The reconcile rule, shared verbatim by both engines (their batch=1
/// parity depends on it): a lookahead entry is usable iff the slot still
/// holds the same request, the committed context is exactly the predicted
/// one (length + tail — contexts are append-only, so that implies full
/// equality), and the planned K equals the forecast K. On a hit the
/// drafter adopts the post-proposal snapshot, making the token stream
/// bit-identical to serial drafting.
pub fn reconcile_entry(
    entry: Option<SpecDraft>,
    req_id: u64,
    k: usize,
    context: &[u32],
    drafter: &mut EngineDrafter,
) -> Reconciled {
    let mut out = Reconciled { taken: None, hit: false, recompute: false, hidden_window_s: 0.0 };
    if let Some(e) = entry {
        let valid = k > 0
            && e.req_id == req_id
            && e.k_assumed == k
            && context.len() == e.expected_ctx_len
            && context.ends_with(&e.expected_tail);
        if valid {
            drafter.adopt(e.snapshot_after);
            out.hidden_window_s = e.window_s.unwrap_or(0.0);
            out.taken = Some((e.drafts, e.draft_wall_ns));
            out.hit = true;
        } else if k > 0 {
            out.recompute = true;
        }
    }
    out
}

/// Execute one speculative draft (on whatever thread it lands on).
pub fn run_spec_task(task: SpecTask) -> SpecDraft {
    let mut snapshot = task.snapshot;
    let t0 = Instant::now(); // lint:allow(wall-clock): measures draft_wall_ns telemetry
    let drafts = snapshot.propose(&task.ctx, &task.reference, task.out_idx, task.k, task.d_eps);
    SpecDraft {
        slot: task.slot,
        req_id: task.req_id,
        expected_ctx_len: task.ctx.len(),
        expected_tail: task.expected_tail,
        k_assumed: task.k,
        drafts,
        draft_wall_ns: t0.elapsed().as_nanos() as u64,
        snapshot_after: snapshot,
        window_s: None,
    }
}

/// Fan speculative drafts out across scoped threads — per-slot n-gram
/// scans are independent CPU work. A single task runs inline (thread
/// spawn overhead would dwarf the scan). The serial reference the
/// persistent [`DraftPool`] must match token-for-token; kept as the
/// fallback for engines without a pool and as the equivalence oracle.
pub fn run_spec_tasks(tasks: Vec<SpecTask>) -> Vec<SpecDraft> {
    if tasks.len() <= 1 {
        return tasks.into_iter().map(run_spec_task).collect();
    }
    std::thread::scope(|scope| {
        let handles: Vec<_> = tasks
            .into_iter()
            .map(|t| scope.spawn(move || run_spec_task(t)))
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("speculative draft thread panicked"))
            .collect()
    })
}

/// Persistent draft worker pool: threads are spawned **once** (at
/// `BatchEngine::new`) and fed per-iteration through channels, replacing
/// the `thread::scope` respawn that previously paid thread start-up cost
/// every step.
///
/// Determinism argument (rust/docs/perf.md): each [`SpecTask`] owns its
/// entire input (context, reference, drafter snapshot) and every proposal
/// is a pure function of that input, so *which* worker executes a task
/// cannot change its output. Tasks are tagged with their submission index
/// and results are re-ordered by that tag before returning, so
/// [`DraftPool::run`] returns exactly what [`run_spec_tasks`] returns, in
/// the same order — only `draft_wall_ns` (host telemetry, never part of
/// the simulated clock or metrics) may differ.
#[derive(Debug)]
pub struct DraftPool {
    /// `None` only during drop (closing the channel stops the workers).
    tx: Option<std::sync::mpsc::Sender<(usize, SpecTask)>>,
    rx: std::sync::mpsc::Receiver<(usize, SpecDraft)>,
    workers: Vec<std::thread::JoinHandle<()>>,
}

impl DraftPool {
    /// Spawn a pool of `max_workers.min(available_parallelism)` threads
    /// (at least one).
    pub fn new(max_workers: usize) -> Self {
        let hw = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
        let n = max_workers.clamp(1, hw.max(1));
        let (tx, task_rx) = std::sync::mpsc::channel::<(usize, SpecTask)>();
        let (done_tx, rx) = std::sync::mpsc::channel::<(usize, SpecDraft)>();
        let task_rx = std::sync::Arc::new(std::sync::Mutex::new(task_rx));
        let workers = (0..n)
            .map(|_| {
                let task_rx = std::sync::Arc::clone(&task_rx);
                let done_tx = done_tx.clone();
                std::thread::spawn(move || loop {
                    // Hold the lock only for the dequeue, not the scan.
                    let next = task_rx.lock().expect("draft pool task queue poisoned").recv();
                    match next {
                        Ok((idx, task)) => {
                            // The engine may drop the pool with results in
                            // flight; a closed result channel just means
                            // shutdown.
                            let _ = done_tx.send((idx, run_spec_task(task)));
                        }
                        Err(_) => break, // task channel closed: shut down
                    }
                })
            })
            .collect();
        Self { tx: Some(tx), rx, workers }
    }

    pub fn workers(&self) -> usize {
        self.workers.len()
    }

    /// Execute `tasks` across the pool and return the drafts **in task
    /// order** — the same order serial execution produces, so callers are
    /// agnostic to which worker ran what. A single task runs inline, like
    /// [`run_spec_tasks`].
    pub fn run(&self, tasks: Vec<SpecTask>) -> Vec<SpecDraft> {
        if tasks.len() <= 1 {
            return tasks.into_iter().map(run_spec_task).collect();
        }
        let n = tasks.len();
        let tx = self.tx.as_ref().expect("draft pool already shut down");
        for (idx, task) in tasks.into_iter().enumerate() {
            tx.send((idx, task)).expect("draft pool workers gone");
        }
        let mut out: Vec<Option<SpecDraft>> = (0..n).map(|_| None).collect();
        for _ in 0..n {
            let (idx, draft) = self.rx.recv().expect("draft pool workers gone");
            out[idx] = Some(draft);
        }
        out.into_iter().map(|d| d.expect("every submitted task reports back")).collect()
    }
}

impl Drop for DraftPool {
    fn drop(&mut self) {
        drop(self.tx.take()); // close the task channel: workers exit
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::spec::policy::StaticK;
    use crate::workload::Task;

    fn req(reference: Vec<u32>, max_new: usize) -> Request {
        Request {
            id: 7,
            task: Task::Code,
            prompt: vec![1, 2, 3],
            reference,
            eps: 0.0,
            max_new_tokens: max_new,
        }
    }

    fn ngram_drafter() -> EngineDrafter {
        EngineDrafter::Ngram(NgramDrafter::new(1, 4))
    }

    #[test]
    fn spec_task_predicts_full_acceptance_tail() {
        let r = req(vec![10, 11, 12, 13, 14, 15, 16, 17], 50);
        let policy = StaticK::new(3);
        let drafter = ngram_drafter();
        // In-flight iteration: out_len 2, drafting [12, 13] → bonus is
        // reference[4] = 14.
        let ctx = vec![1, 2, 3, 10, 11];
        let task =
            plan_spec_task(0, &r, &policy, &drafter, &ctx, 2, 5, 384, &[12, 13], 2, 0.01, 0.0)
                .expect("predictable");
        assert_eq!(task.expected_tail, vec![12, 13, 14]);
        assert_eq!(task.out_idx, 5);
        assert_eq!(task.k, 3);
        assert_eq!(task.ctx.len(), ctx.len() + 3);
        let draft = run_spec_task(task);
        assert_eq!(draft.k_assumed, 3);
        assert_eq!(draft.expected_ctx_len, 8);
    }

    #[test]
    fn spec_task_skips_unpredictable_futures() {
        let policy = StaticK::new(3);
        let drafter = ngram_drafter();
        let ctx = vec![1, 2, 3, 10, 11];
        // Bonus past the reference end: unguided, unpredictable.
        let r = req(vec![10, 11], 50);
        assert!(
            plan_spec_task(0, &r, &policy, &drafter, &ctx, 2, 5, 384, &[12, 13], 2, 0.0, 0.0)
                .is_none()
        );
        // Predicted EOS bonus: request finishes.
        let r = req(vec![10, 11, 12, 13, crate::tokenizer::EOS], 50);
        assert!(
            plan_spec_task(0, &r, &policy, &drafter, &ctx, 2, 5, 384, &[12, 13], 2, 0.0, 0.0)
                .is_none()
        );
        // Output budget exhausted by the in-flight iteration.
        let r = req(vec![10, 11, 12, 13, 14, 15], 5);
        assert!(
            plan_spec_task(0, &r, &policy, &drafter, &ctx, 2, 5, 384, &[12, 13], 2, 0.0, 0.0)
                .is_none()
        );
    }

    #[test]
    fn fanned_out_tasks_match_inline_execution() {
        // Thread fan-out must not change any proposal: run the same tasks
        // inline and scoped, compare bit-for-bit.
        let r = req((0..40).map(|i| 20 + (i % 7)).collect(), 100);
        let policy = StaticK::new(4);
        let drafter = ngram_drafter();
        let mk = |slot: usize| {
            let ctx: Vec<u32> = (0..30).map(|i| 20 + ((i + slot) % 7) as u32).collect();
            plan_spec_task(slot, &r, &policy, &drafter, &ctx, 10, 30, 384, &[21, 22], 2, 0.01, 0.0)
                .expect("predictable")
        };
        let inline: Vec<SpecDraft> = (0..6).map(|s| run_spec_task(mk(s))).collect();
        let fanned = run_spec_tasks((0..6).map(mk).collect());
        assert_eq!(inline.len(), fanned.len());
        for (a, b) in inline.iter().zip(&fanned) {
            assert_eq!(a.slot, b.slot);
            assert_eq!(a.drafts, b.drafts);
            assert_eq!(a.expected_tail, b.expected_tail);
            assert_eq!(a.k_assumed, b.k_assumed);
        }
    }

    #[test]
    fn persistent_pool_matches_serial_execution() {
        // The pool must be a drop-in for run_spec_tasks: same drafts, same
        // order, across repeated submissions (reused workers) and batch
        // sizes including 0, 1, and more tasks than workers.
        let r = req((0..60).map(|i| 20 + (i % 9)).collect(), 200);
        let policy = StaticK::new(4);
        let drafter = ngram_drafter();
        let mk = |slot: usize| {
            let ctx: Vec<u32> = (0..30).map(|i| 20 + ((i + slot) % 9) as u32).collect();
            plan_spec_task(slot, &r, &policy, &drafter, &ctx, 10, 30, 384, &[21, 22], 2, 0.01, 0.0)
                .expect("predictable")
        };
        let pool = DraftPool::new(3);
        assert!(pool.workers() >= 1);
        for batch in [0usize, 1, 2, 3, 7, 12] {
            let serial = run_spec_tasks((0..batch).map(mk).collect());
            let pooled = pool.run((0..batch).map(mk).collect());
            assert_eq!(serial.len(), pooled.len());
            for (a, b) in serial.iter().zip(&pooled) {
                assert_eq!(a.slot, b.slot, "batch {batch}");
                assert_eq!(a.drafts, b.drafts, "batch {batch}");
                assert_eq!(a.expected_tail, b.expected_tail, "batch {batch}");
                assert_eq!(a.expected_ctx_len, b.expected_ctx_len, "batch {batch}");
                assert_eq!(a.k_assumed, b.k_assumed, "batch {batch}");
            }
        }
    }
}
