//! EAGLE-lite: draft-model speculation (paper §7.3).
//!
//! A small dense LM (the AOT `draft` model) proposes K tokens by K
//! sequential single-token steps over its own KV cache. Accuracy comes from
//! a noisy view of the reference stream (per-task `draft_eps`), standing in
//! for a trained EAGLE head — see DESIGN.md §Substitutions. Like vLLM's
//! model-based drafters (paper §6), the drafter keeps its KV cache in sync
//! by ingesting every emitted token *even when speculation is off*, which
//! is the 2–3% overhead the paper measures for dynamic disable support.
//!
//! Speculative draft steps write KV past the drafter's committed length and
//! are rolled back by resetting `cache_len` (the dense draft model carries
//! no router state, so rollback is exact).

use crate::coordinator::backend::SharedRuntime;
use crate::rng::Rng;
use crate::runtime::{ModelRuntime, RequestState};
use crate::sampling::sample_guided;
use crate::workload::{Request, Task};
use anyhow::Result;

/// Per-task drafter deviation rate. Calibrated so acceptance matches the
/// paper's §7.3 observations (EAGLE ETR ≈ 1.7 at K=1 on math, vs 1.3 for
/// n-gram).
pub fn draft_eps(task: Task) -> f64 {
    match task {
        Task::Code => 0.04,
        Task::Math => 0.20,
        Task::Extract => 0.10,
    }
}

/// Draft-model drafter state.
pub struct EagleLite {
    runtime: SharedRuntime,
    state: RequestState,
    guide_strength: f32,
    rng: Rng,
    seed: u64,
    /// Last emitted target token, not yet in the drafter's cache.
    pending: Option<u32>,
    /// Wall time spent drafting (profiling).
    pub draft_wall_ns: u128,
}

impl EagleLite {
    pub fn new(runtime: ModelRuntime, guide_strength: f32, seed: u64) -> Self {
        Self::shared(std::rc::Rc::new(std::cell::RefCell::new(runtime)), guide_strength, seed)
    }

    pub fn shared(runtime: SharedRuntime, guide_strength: f32, seed: u64) -> Self {
        let state = runtime.borrow().fresh_state();
        Self {
            runtime,
            state,
            guide_strength,
            rng: Rng::new(seed),
            seed,
            pending: None,
            draft_wall_ns: 0,
        }
    }

    /// Reset for a new request and ingest its prompt.
    pub fn begin(&mut self, req: &Request) -> Result<()> {
        self.state = self.runtime.borrow().fresh_state();
        self.rng = Rng::new(self.seed ^ req.id.wrapping_mul(0xD6E8_FEB8_6659_FD93));
        self.pending = None;
        let chunk = self.runtime.borrow().model.mini.prefill_chunk;
        for piece in req.prompt.chunks(chunk) {
            let valid = piece.len();
            let mut tokens = piece.to_vec();
            tokens.resize(chunk, crate::tokenizer::PAD);
            let t0 = std::time::Instant::now(); // lint:allow(wall-clock): measures draft_wall_ns telemetry
            self.runtime.borrow_mut().step(&mut self.state, &tokens)?;
            self.draft_wall_ns += t0.elapsed().as_nanos();
            self.state.cache_len += valid;
        }
        Ok(())
    }

    /// Propose up to `k` draft tokens continuing after the last emitted
    /// token. `guides[i]` is the (noisy-access) reference for draft `i`.
    pub fn propose(&mut self, k: usize, guides: &[Option<u32>], eps: f64) -> Result<Vec<u32>> {
        let Some(first) = self.pending else {
            return Ok(Vec::new());
        };
        let saved_len = self.state.cache_len;
        let mut drafts = Vec::with_capacity(k);
        let mut cur = first;
        for i in 0..k {
            if self.state.cache_len + 1 > self.state.max_seq {
                break;
            }
            let t0 = std::time::Instant::now(); // lint:allow(wall-clock): measures draft_wall_ns telemetry
            let out = self.runtime.borrow_mut().step(&mut self.state, &[cur])?;
            self.draft_wall_ns += t0.elapsed().as_nanos();
            self.state.cache_len += 1;
            let tok = sample_guided(
                out.logits_row(0),
                guides.get(i).copied().flatten(),
                self.guide_strength,
                eps,
                &mut self.rng,
            );
            drafts.push(tok);
            cur = tok;
        }
        // Roll back speculative KV writes: positions past the committed
        // length get overwritten on the next committed step.
        self.state.cache_len = saved_len;
        Ok(drafts)
    }

    /// Ingest the tokens the target emitted this iteration (keeps the
    /// drafter's KV in sync; runs even when speculation was off).
    pub fn ingest(&mut self, emitted: &[u32]) -> Result<()> {
        if emitted.is_empty() {
            return Ok(());
        }
        // Inputs: previous pending token + all but the last emitted token.
        let mut inputs = Vec::with_capacity(emitted.len());
        if let Some(p) = self.pending {
            inputs.push(p);
            inputs.extend_from_slice(&emitted[..emitted.len() - 1]);
        } else {
            // First ingest after prefill: the first output token becomes
            // pending without a step (prompt already in cache).
            inputs.extend_from_slice(&emitted[..emitted.len() - 1]);
        }
        self.pending = Some(*emitted.last().unwrap());
        if inputs.is_empty() {
            return Ok(());
        }
        // Ingest in chunks the AOT variants support (1..=8 tokens).
        for piece in inputs.chunks(8) {
            if self.state.cache_len + piece.len() > self.state.max_seq {
                break; // drafter window exhausted; proposals will stop
            }
            let t0 = std::time::Instant::now(); // lint:allow(wall-clock): measures draft_wall_ns telemetry
            self.runtime.borrow_mut().step(&mut self.state, piece)?;
            self.draft_wall_ns += t0.elapsed().as_nanos();
            self.state.cache_len += piece.len();
        }
        Ok(())
    }

    pub fn cache_len(&self) -> usize {
        self.state.cache_len
    }
}
