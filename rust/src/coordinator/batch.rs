//! Continuous-batching serving engine.
//!
//! Extends the paper's single-batch worker (`engine::Engine`) to keep up to
//! `max_batch` requests in flight. Each decode iteration runs **one fused
//! verify step** over the concatenated `[last token, drafts…]` spans of all
//! active requests (`Backend::step_batch`), then rejection-samples, commits
//! and rolls back per request. Three things make this more than a loop:
//!
//! * **Shared KV pool** — all requests draw blocks from one
//!   [`KvBlockPool`]; admission and speculative lookahead compete for the
//!   same budget, so one request's speculation is real cache pressure for
//!   the others.
//! * **Batch-aware cost** — the fused step is charged with
//!   [`GpuCostModel::batch_verify_cost`]: base weights once per iteration,
//!   routed experts de-duplicated across the *whole batch*. Per-request
//!   utility decisions therefore interact through expert overlap — the
//!   paper's §2.4 mechanism at serving scale.
//! * **Per-request policies** — every request carries its own Cascade
//!   state machine (baseline → test → set), observing the fused iteration
//!   latency it actually experienced.
//!
//! Per-request `RequestMetrics` keep the *latency* view (each iteration's
//! full fused cost — that is what the request waited for); the
//! [`BatchRunMetrics`] iteration records keep the *throughput* view
//! (fused cost charged once per iteration).

use crate::config::{DrafterKind, EngineConfig, MAX_K};
use crate::coordinator::backend::{Backend, VerifySpan};
use crate::coordinator::engine::EngineDrafter;
use crate::cost::GpuCostModel;
use crate::kv::KvBlockPool;
use crate::metrics::{BatchIterRecord, BatchRunMetrics, IterRecord, RequestMetrics, RunMetrics};
use crate::models::Registry;
use crate::rng::Rng;
use crate::spec::policy::{IterObs, PolicyKind, SpecPolicy};
use crate::spec::rejection::{greedy_verify, truncate_at_eos};
use crate::spec::NgramDrafter;
use crate::tokenizer::EOS;
use crate::workload::Request;
use anyhow::{Context, Result};
use std::collections::VecDeque;
use std::time::Instant;

/// One in-flight request's state.
struct SlotState {
    req: Request,
    policy: Box<dyn SpecPolicy>,
    drafter: EngineDrafter,
    output: Vec<u32>,
    context: Vec<u32>,
    d_eps: f64,
    finished: bool,
    metrics: RequestMetrics,
    wall_start: Instant,
}

/// Drafting decisions taken for one slot before the fused step.
struct PlannedSpan {
    slot: usize,
    k_chosen: usize,
    drafted: usize,
    draft_wall_ns: u64,
}

/// Continuous-batching engine: one backend (multi-slot where supported),
/// one shared KV pool, per-request policies and drafters.
pub struct BatchEngine {
    pub cfg: EngineConfig,
    pub backend: Box<dyn Backend>,
    pub cost: GpuCostModel,
    policy_kind: PolicyKind,
    /// KV block size (vLLM-style pages).
    pub kv_block: usize,
    pub pool: KvBlockPool,
    max_batch: usize,
    slots: Vec<Option<SlotState>>,
    done: Vec<RequestMetrics>,
    batch_iters: Vec<BatchIterRecord>,
}

impl BatchEngine {
    /// Build over an explicit backend. `cfg.max_batch` is clamped to what
    /// the backend supports, so single-request backends serve batch=1
    /// through the sequential `step_batch` fallback.
    pub fn new(
        cfg: EngineConfig,
        backend: Box<dyn Backend>,
        cost: GpuCostModel,
        policy_kind: PolicyKind,
    ) -> Self {
        let kv_block = 16;
        let max_batch = cfg.max_batch.max(1).min(backend.max_slots());
        let blocks_per_request = backend.mini().max_seq / kv_block;
        // Pool sizing: the aggregate worst case by default (no
        // cross-request contention); `cfg.kv_pool_blocks` oversubscribes
        // it so admission and speculation genuinely compete. Never below
        // one full window, so a lone request can always reach max_seq.
        let auto = max_batch * blocks_per_request;
        let total_blocks = if cfg.kv_pool_blocks > 0 {
            cfg.kv_pool_blocks.clamp(blocks_per_request, auto)
        } else {
            auto
        };
        let pool = KvBlockPool::new(total_blocks, kv_block);
        let mut slots = Vec::with_capacity(max_batch);
        slots.resize_with(max_batch, || None);
        Self {
            cfg,
            backend,
            cost,
            policy_kind,
            kv_block,
            pool,
            max_batch,
            slots,
            done: Vec::new(),
            batch_iters: Vec::new(),
        }
    }

    /// Sim-backend batched engine (native fused routing, full batching).
    pub fn sim(registry: &Registry, cfg: EngineConfig, policy_kind: PolicyKind) -> Result<Self> {
        let model = registry.model(&cfg.model)?;
        let cost = GpuCostModel::new(model.paper.clone(), model.mini.layers);
        let backend = Box::new(crate::sim::SimBackend::new(model.mini.clone(), cfg.seed));
        Ok(Self::new(cfg, backend, cost, policy_kind))
    }

    /// Real-backend batched engine. The PJRT backend holds one request, so
    /// the batch clamps to 1 (sequential fallback); draft-model speculation
    /// is not supported on this path — use the single-request engine.
    pub fn real(registry: &Registry, cfg: EngineConfig, policy_kind: PolicyKind) -> Result<Self> {
        anyhow::ensure!(
            cfg.drafter == DrafterKind::Ngram,
            "the batched engine supports draft-model speculation only on the sim backend"
        );
        let runtime = crate::runtime::ModelRuntime::load(registry, &cfg.model)
            .with_context(|| format!("loading model {}", cfg.model))?;
        let mini_layers = runtime.model.mini.layers;
        let cost = GpuCostModel::new(runtime.model.paper.clone(), mini_layers);
        let backend = Box::new(crate::coordinator::backend::RealBackend::new(
            runtime,
            cfg.guide_strength,
            cfg.seed,
        ));
        Ok(Self::new(cfg, backend, cost, policy_kind))
    }

    /// Effective batch size after clamping to the backend.
    pub fn max_batch(&self) -> usize {
        self.max_batch
    }

    /// Worst-case total output tokens this engine's admitted requests can
    /// reach: tokens already emitted by finished requests plus every active
    /// request's remaining-capable maximum (`max_new_tokens - 1` counted
    /// emissions). Admission control charges against this bound; it
    /// self-corrects when a request finishes early (EOS), unlike a
    /// pre-charged grant that would never be refunded.
    pub fn output_bound(&self) -> usize {
        let done: usize = self.done.iter().map(|m| m.tokens_emitted()).sum();
        let active: usize = self
            .slots
            .iter()
            .flatten()
            .map(|s| s.req.max_new_tokens.saturating_sub(1))
            .sum();
        done + active
    }

    pub fn active(&self) -> usize {
        self.slots.iter().flatten().filter(|s| !s.finished).count()
    }

    pub fn has_free_slot(&self) -> bool {
        self.slots.iter().any(|s| s.is_none())
    }

    /// Would `admit` succeed for this request right now?
    pub fn can_admit(&self, req: &Request) -> bool {
        self.has_free_slot()
            && req.prompt.len() + 2 <= self.backend.mini().max_seq
            && self.pool.can_admit(req.prompt.len())
    }

    /// Fresh per-request drafter mirroring `Engine`'s wiring.
    fn build_drafter(&self) -> Result<EngineDrafter> {
        Ok(match self.cfg.drafter {
            DrafterKind::Ngram => {
                EngineDrafter::Ngram(NgramDrafter::new(self.cfg.ngram_min, self.cfg.ngram_max))
            }
            DrafterKind::EagleLite => {
                anyhow::ensure!(
                    self.backend.name() == "sim",
                    "batched draft-model speculation requires the sim backend"
                );
                EngineDrafter::SimEagle {
                    rng: Rng::new(self.cfg.seed ^ 0xE1),
                    seed: self.cfg.seed ^ 0xE1,
                }
            }
        })
    }

    /// Admit one request: bind a slot, prefill, charge the pool.
    pub fn admit(&mut self, req: Request) -> Result<()> {
        let slot = self
            .slots
            .iter()
            .position(|s| s.is_none())
            .ok_or_else(|| anyhow::anyhow!("no free slot (batch {})", self.max_batch))?;
        let max_seq = self.backend.mini().max_seq;
        anyhow::ensure!(
            req.prompt.len() + 2 <= max_seq,
            "prompt ({}) does not fit the {} window",
            req.prompt.len(),
            max_seq
        );
        // Build per-request machinery before taking any backend/pool side
        // effects, so a config error (e.g. an unsupported drafter) cannot
        // leak a bound slot or pool blocks.
        let mut drafter = self.build_drafter()?;
        let mut policy = self.policy_kind.build();
        policy.reset();

        self.backend.begin_slot(slot, &req)?;
        self.pool.admit(req.id, req.prompt.len())?;

        let mut metrics = RequestMetrics {
            id: req.id,
            task: req.task.name().into(),
            prompt_tokens: req.prompt.len(),
            ..Default::default()
        };
        let wall_start = Instant::now();
        let guide0 = req.reference.first().copied();
        let prefilled = self
            .backend
            .prefill_slot(slot, &req.prompt, guide0, req.eps)
            .and_then(|first| drafter.begin_request(&req, first).map(|()| first));
        let first = match prefilled {
            Ok(t) => t,
            Err(e) => {
                self.pool.release(req.id);
                self.backend.release_slot(slot);
                return Err(e);
            }
        };
        // Prefill charge: chunked full-parallel steps (excluded from TPOT).
        let chunks = req.prompt.len().div_ceil(self.backend.mini().prefill_chunk);
        metrics.prefill_s = chunks as f64 * self.cost.baseline_cost().total();

        let mut context = req.prompt.clone();
        context.push(first);
        let finished = first == EOS || req.max_new_tokens <= 1;
        let d_eps = crate::coordinator::eagle::draft_eps(req.task);
        let state = SlotState {
            d_eps,
            policy,
            drafter,
            output: vec![first],
            context,
            finished,
            metrics,
            wall_start,
            req,
        };
        if state.finished {
            // EOS at prefill (or a 1-token budget): finalize immediately.
            self.finalize(slot, state);
        } else {
            self.slots[slot] = Some(state);
        }
        Ok(())
    }

    fn finalize(&mut self, slot: usize, mut state: SlotState) {
        self.pool.release(state.req.id);
        self.backend.release_slot(slot);
        state.metrics.wall_total_ns = state.wall_start.elapsed().as_nanos() as u64;
        state.metrics.output = std::mem::take(&mut state.output);
        self.done.push(state.metrics);
    }

    /// Run one fused decode iteration over all active slots. Returns false
    /// when nothing is in flight (the caller should admit or stop).
    pub fn step_iteration(&mut self) -> Result<bool> {
        let max_seq = self.backend.mini().max_seq;
        let drafter_kind = self.cfg.drafter;

        // ---- Plan + draft per slot --------------------------------------
        let mut spans: Vec<VerifySpan> = Vec::new();
        let mut planned: Vec<PlannedSpan> = Vec::new();
        let mut deferred = 0usize;
        for slot in 0..self.slots.len() {
            let Some(state) = self.slots[slot].as_mut() else { continue };
            if state.finished {
                continue;
            }
            let out_idx = state.output.len();
            // Policy decision, capped by the KV window, the shared pool,
            // and the remaining output budget — same laws as the
            // single-request engine, plus pool pressure.
            let mut k = state.policy.next_k().min(MAX_K);
            let room = max_seq.saturating_sub(self.backend.cache_len_slot(slot) + 1);
            k = k.min(room);
            k = k.min(state.req.max_new_tokens.saturating_sub(out_idx).saturating_sub(1));
            if room == 0 {
                // Window exhausted: the request cannot decode further.
                state.finished = true;
                continue;
            }
            // Shared-pool pressure: shrink speculation until the span
            // fits; if even the next token cannot be reserved, defer this
            // request for one iteration — the other spans' commits and
            // releases free blocks (preemption/eviction is future work).
            while k > 0 && !self.pool.can_reserve(state.req.id, 1 + k) {
                k -= 1;
            }
            if !self.pool.can_reserve(state.req.id, 1) {
                deferred += 1;
                continue;
            }

            let draft_wall = Instant::now();
            let drafts = state.drafter.propose(
                &state.context,
                &state.req.reference,
                out_idx,
                k,
                state.d_eps,
            )?;
            let draft_wall_ns = draft_wall.elapsed().as_nanos() as u64;
            let drafted = drafts.len();

            let t = 1 + drafted;
            self.pool.reserve(state.req.id, t)?;
            let mut tokens = Vec::with_capacity(t);
            tokens.push(*state.output.last().unwrap());
            tokens.extend_from_slice(&drafts);
            let guides: Vec<Option<u32>> = (0..t)
                .map(|i| state.req.reference.get(out_idx + i).copied())
                .collect();
            spans.push(VerifySpan { slot, tokens, guides, eps: state.req.eps });
            planned.push(PlannedSpan { slot, k_chosen: k, drafted, draft_wall_ns });
        }

        if spans.is_empty() {
            // Nothing to verify; finalize any slots that just ran out of
            // window room. Their released blocks may unblock a deferred
            // request, so that still counts as progress.
            let swept = self.sweep_finished();
            if deferred > 0 && swept > 0 {
                return Ok(true);
            }
            // Deferred slots with no progressing neighbour can never be
            // unblocked (nothing will free pool blocks): a genuine
            // deadlock of an oversubscribed pool, surfaced rather than
            // spun on.
            anyhow::ensure!(
                deferred == 0,
                "KV pool deadlock: {deferred} request(s) cannot reserve their next token and \
                 nothing else is decoding; increase kv_pool_blocks (eviction is not implemented)"
            );
            return Ok(false);
        }

        // ---- Fused verify step ------------------------------------------
        let iter_wall = Instant::now();
        let batch = self.backend.step_batch(&spans)?;

        // ---- Batch-aware cost -------------------------------------------
        let total_tokens: usize = spans.iter().map(|s| s.tokens.len()).sum();
        let total_drafted: usize = planned.iter().map(|p| p.drafted).sum();
        let drafting_requests = planned.iter().filter(|p| p.drafted > 0).count();
        let cost = self.cost.batch_verify_cost(
            &batch.batch_unique_experts,
            total_tokens,
            total_drafted,
            drafting_requests,
            drafter_kind,
        );
        let layer_mean = |v: &[usize]| -> f64 {
            if v.is_empty() {
                0.0
            } else {
                v.iter().sum::<usize>() as f64 / v.len() as f64
            }
        };

        // ---- Per-request rejection sampling + commit --------------------
        // `planned`, `spans`, and `batch.slots` are index-aligned.
        let mut emitted_total = 0usize;
        for (i, plan) in planned.iter().enumerate() {
            let slot_step = &batch.slots[i];
            let span = &spans[i];
            debug_assert_eq!(plan.slot, slot_step.slot);
            let state = self.slots[plan.slot].as_mut().expect("planned slot is live");
            let drafts = &span.tokens[1..];
            let vr = greedy_verify(drafts, &slot_step.step.sampled);
            let (emitted, eos_hit) = truncate_at_eos(&vr.emitted, EOS);
            let advance = 1 + vr.accepted;
            self.pool.commit(state.req.id, advance)?;
            self.backend.advance_slot(plan.slot, advance);
            state.drafter.ingest(&emitted)?;

            state.output.extend_from_slice(&emitted);
            state.context.extend_from_slice(&emitted);
            emitted_total += emitted.len();

            let mean_unique = layer_mean(&slot_step.step.unique_experts);
            let phase = state.policy.phase();
            let obs = IterObs {
                k_chosen: plan.k_chosen,
                drafted: plan.drafted,
                accepted: vr.accepted,
                emitted: emitted.len(),
                iter_s: cost.total(),
            };
            state.policy.observe(&obs);
            state.metrics.iters.push(IterRecord {
                k_chosen: plan.k_chosen,
                drafted: plan.drafted,
                accepted: vr.accepted,
                emitted: emitted.len(),
                cost,
                wall_ns: iter_wall.elapsed().as_nanos() as u64 + plan.draft_wall_ns,
                unique_experts: mean_unique,
                phase,
            });
            if eos_hit || state.output.len() >= state.req.max_new_tokens {
                state.finished = true;
            }
        }

        self.batch_iters.push(BatchIterRecord {
            n_active: spans.len(),
            total_tokens,
            total_drafted,
            emitted: emitted_total,
            cost,
            batch_unique_experts: layer_mean(&batch.batch_unique_experts),
            summed_unique_experts: layer_mean(&batch.summed_unique_experts),
        });

        self.sweep_finished();
        Ok(true)
    }

    /// Move finished slots into the done list, freeing pool + backend
    /// state. Returns how many slots were finalized.
    fn sweep_finished(&mut self) -> usize {
        let mut swept = 0;
        for slot in 0..self.slots.len() {
            if self.slots[slot].as_ref().is_some_and(|s| s.finished) {
                let state = self.slots[slot].take().unwrap();
                self.finalize(slot, state);
                swept += 1;
            }
        }
        swept
    }

    /// Collect the run's metrics (requests ordered by id).
    pub fn finish(&mut self) -> BatchRunMetrics {
        let mut reqs = std::mem::take(&mut self.done);
        reqs.sort_by_key(|m| m.id);
        let mut run = RunMetrics::default();
        for m in reqs {
            run.push(m);
        }
        BatchRunMetrics {
            run,
            iters: std::mem::take(&mut self.batch_iters),
            max_batch: self.max_batch,
        }
    }

    /// Serve an explicit request list to completion with continuous
    /// admission (tests and deterministic comparisons). Deliberately a
    /// separate drive loop from [`Scheduler::run_batched`], which owns
    /// token-budget clamping and grant accounting over an unbounded
    /// stream; changes to admission semantics must touch both.
    ///
    /// [`Scheduler::run_batched`]: crate::coordinator::scheduler::Scheduler::run_batched
    pub fn serve_all(&mut self, reqs: &[Request]) -> Result<BatchRunMetrics> {
        let mut queue: VecDeque<Request> = reqs.iter().cloned().collect();
        loop {
            while self.has_free_slot() {
                match queue.front() {
                    Some(req) if self.can_admit(req) => {
                        let req = queue.pop_front().unwrap();
                        self.admit(req)?;
                    }
                    _ => break,
                }
            }
            if !self.step_iteration()? {
                if queue.is_empty() {
                    break;
                }
                // Engine drained but the head request still does not fit:
                // with an empty engine the whole pool is free, so this can
                // only mean the request can never fit.
                anyhow::ensure!(
                    self.active() == 0 && self.can_admit(queue.front().unwrap()),
                    "request {} cannot fit the KV pool",
                    queue.front().unwrap().id
                );
            }
        }
        Ok(self.finish())
    }

    /// Name for experiment tables.
    pub fn label(&self) -> String {
        format!("{}/{}@b{}", self.cfg.model, self.policy_kind.label(), self.max_batch)
    }
}
