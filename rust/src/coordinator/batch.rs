//! Continuous-batching serving engine.
//!
//! Extends the paper's single-batch worker (`engine::Engine`) to keep up to
//! `max_batch` requests in flight. Each decode iteration runs **one fused
//! verify step** over the concatenated `[last token, drafts…]` spans of all
//! active requests (`Backend::step_batch`), then rejection-samples, commits
//! and rolls back per request. Three things make this more than a loop:
//!
//! * **Shared KV pool** — all requests draw blocks from one
//!   [`KvBlockPool`]; admission and speculative lookahead compete for the
//!   same budget, so one request's speculation is real cache pressure for
//!   the others.
//! * **Batch-aware cost** — the fused step is charged with
//!   [`GpuCostModel::batch_verify_cost`]: base weights once per iteration,
//!   routed experts de-duplicated across the *whole batch*. Per-request
//!   utility decisions therefore interact through expert overlap — the
//!   paper's §2.4 mechanism at serving scale.
//! * **Per-request policies** — every request carries its own Cascade
//!   state machine (baseline → test → set), observing its **marginal**
//!   share of the fused iteration (base amortized, experts at the
//!   request's exclusive contribution) — the batch-aware utility signal.
//! * **Pipelined drafting** (`EngineConfig::pipeline`) — the iteration is
//!   a plan → draft → verify → commit pipeline with a one-iteration
//!   lookahead: while the backend verifies iteration i, iteration i+1's
//!   proposals are drafted on scoped threads under the full-acceptance
//!   prediction and reconciled at the next draft stage
//!   (`coordinator::pipeline`). Token output is bit-identical to serial;
//!   only the cost accounting changes (`IterCost::draft_hidden_s`).
//! * **Preemption / eviction** (`EngineConfig::eviction`) — under an
//!   oversubscribed pool, a slot that cannot reserve its full planned
//!   verify span (1 + K tokens) selects a victim
//!   (`coordinator::eviction`), releases the victim's blocks, invalidates
//!   its lookahead entry by `req_id`, and parks it on a re-admission
//!   queue; on re-admission the victim's committed context
//!   is re-prefilled (and its decode history replayed, so the backend's
//!   per-slot state is reconstructed exactly) and the recompute is charged
//!   into `IterCost::reprefill_s`. With eviction on, pool pressure is
//!   **all-or-nothing** per slot (defer the whole span rather than shrink
//!   K): only span-preserving responses keep an evicted-then-readmitted
//!   request's token stream bit-exact with an uncontended run — the
//!   losslessness guarantee (rust/docs/preemption.md,
//!   rust/tests/preemption.rs). `eviction = off` (the default) keeps the
//!   legacy shrink-then-defer behavior and the deadlock bail bit-exactly.
//! * **Prefix sharing** (`EngineConfig::prefix_share`) — the pool runs in
//!   copy-on-write sharing mode with a prefix trie over committed token
//!   ids: an admission whose leading full blocks are cached attaches them
//!   instead of allocating, charging only the novel suffix's prefill on
//!   the virtual clock; eviction is refcount-aware end to end (victims
//!   are scored and feasibility-checked at *exclusive* blocks, and
//!   preemption replay re-attaches to surviving shared blocks). Token
//!   output is untouched — sharing changes only block accounting and
//!   clock charges (rust/docs/prefix_cache.md). Off (the default) keeps
//!   the counts-only pool bit-exactly.
//!
//! Per-request `RequestMetrics` keep the *latency* view (each iteration's
//! full fused cost — that is what the request waited for); the
//! [`BatchRunMetrics`] iteration records keep the *throughput* view
//! (fused cost charged once per iteration), including pipeline hit/bubble
//! telemetry.

use crate::config::{AdmissionKind, DrafterKind, EngineConfig, EvictionKind, PlacementKind, MAX_K};
use crate::coordinator::admission::{build_policy, AdmissionPolicy};
use crate::coordinator::backend::{Backend, BatchStep, VerifySpan};
use crate::coordinator::engine::EngineDrafter;
use crate::coordinator::eviction::{select_victim, VictimCandidate};
use crate::coordinator::faults::{
    degrade_level, DegradeLevel, FaultPlan, FaultProcess, PressureSignal, THROTTLE_K_CAP,
};
use crate::coordinator::pipeline::{
    plan_spec_task, reconcile_entry, run_spec_tasks, DraftPool, SpecDraft,
};
use crate::coordinator::EngineError;
use crate::cost::{capacity_caps, CoActivationStats, ExpertPlacement, GpuCostModel, IterCost};
use crate::kv::prefix::PrefixTrie;
use crate::kv::KvBlockPool;
use crate::metrics::{BatchIterRecord, BatchRunMetrics, IterRecord, RequestMetrics, RunMetrics};
use crate::models::Registry;
use crate::rng::Rng;
use crate::spec::policy::{IterObs, PolicyKind, SpecPolicy};
use crate::spec::rejection::{greedy_verify, truncate_at_eos};
use crate::spec::NgramDrafter;
use crate::tokenizer::EOS;
use crate::workload::Request;
use anyhow::{Context, Result};
use std::collections::VecDeque;
use std::time::Instant;

/// One in-flight request's state.
struct SlotState {
    req: Request,
    policy: Box<dyn SpecPolicy>,
    drafter: EngineDrafter,
    output: Vec<u32>,
    context: Vec<u32>,
    d_eps: f64,
    finished: bool,
    metrics: RequestMetrics,
    wall_start: Instant,
    /// Last marginal iteration cost this request observed — seeds the
    /// policy-K forecast of the pipelined draft stage.
    last_iter_s: f64,
    /// Monotone admission stamp (re-stamped on re-admission after an
    /// eviction) — the `lru` victim ordering.
    admitted_seq: u64,
    /// Marginal utility (emitted tokens per simulated second) last observed
    /// by this request's policy feedback; `f64::INFINITY` before the first
    /// decode iteration — the `cost-aware` victim ordering.
    last_utility: f64,
    /// Backend-visible decode history (verify spans + committed advances),
    /// recorded only under an eviction-enabled pool so an evicted request's
    /// backend state can be replayed exactly on re-admission. Empty (and
    /// never pushed to) with `eviction = off`.
    history: Vec<ReplayStep>,
    /// Virtual-clock instant this request was parked (evicted); the wait
    /// until re-admission accrues into `RequestMetrics::queue_wait_s`.
    parked_since: f64,
}

/// One recorded verify step of a request's decode history: enough to
/// re-issue the identical backend call sequence after an eviction, which
/// reconstructs a history-dependent backend state (the sim's per-slot rng
/// process) bit-exactly — the foundation of the losslessness guarantee.
struct ReplayStep {
    tokens: Vec<u32>,
    guides: Vec<Option<u32>>,
    /// Positions committed after the step (1 + accepted drafts).
    advance: usize,
}

/// Plan-stage decision for one slot: the K the policy chose after the
/// window and budget caps (the shared-pool cap is applied in the draft
/// stage, interleaved with earlier slots' reservations).
struct SlotPlan {
    slot: usize,
    k: usize,
    out_idx: usize,
}

/// Drafting decisions taken for one slot before the fused step.
struct PlannedSpan {
    slot: usize,
    k_chosen: usize,
    drafted: usize,
    draft_wall_ns: u64,
    /// Drafts came from the pipelined lookahead (their scan time ran
    /// hidden under an earlier iteration's verify window).
    pipelined: bool,
    /// The verify window that scan ran under (its hiding budget); 0.0 for
    /// non-pipelined spans.
    hidden_window_s: f64,
}

/// Outcome tally of one draft stage's lookahead reconciliation.
#[derive(Debug, Clone, Copy, Default)]
struct ReconcileTally {
    /// Spans served from the lookahead (drafting off the critical path).
    hits: usize,
    /// Spans that needed a fresh scan with the pipeline on (bubbles).
    misses: usize,
    /// Lookahead entries discarded because an assumption broke.
    recomputes: usize,
}

/// Reusable per-iteration buffers owned by the engine (rust/docs/perf.md):
/// after the first iteration the hot serving loop allocates nothing
/// proportional to batch size. Every recycled buffer is cleared before
/// reuse — recycling only trades allocator traffic for retained capacity
/// and is bit-invisible to serving semantics.
#[derive(Default)]
struct IterArena {
    /// Last iteration's `BatchStep`, threaded back into the backend via
    /// `submit_batch_reusing` so its slot-step buffers and per-layer
    /// bitmap vectors are recycled instead of reallocated.
    step: BatchStep,
    /// Retired span token buffers, refilled by the next draft stage.
    token_bufs: Vec<Vec<u32>>,
    /// Retired span guide buffers, refilled by the next draft stage.
    guide_bufs: Vec<Vec<Option<u32>>>,
    /// Plan / span / planned vector shells recycled across iterations.
    plans: Vec<SlotPlan>,
    spans: Vec<VerifySpan>,
    planned: Vec<PlannedSpan>,
    /// Scratch for the sharded per-request marginal load maxima
    /// (`ExpertPlacement::max_loads_into`) — replaces the per-span clone
    /// of `SlotStep::marginal_unique_experts`.
    marginal_scratch: Vec<usize>,
    /// Scratch for the iteration's shared-tier expert counts.
    shared_scratch: Vec<usize>,
}

/// Continuous-batching engine: one backend (multi-slot where supported),
/// one shared KV pool, per-request policies and drafters.
pub struct BatchEngine {
    pub cfg: EngineConfig,
    pub backend: Box<dyn Backend>,
    pub cost: GpuCostModel,
    policy_kind: PolicyKind,
    /// KV block size (vLLM-style pages).
    pub kv_block: usize,
    pub pool: KvBlockPool,
    max_batch: usize,
    slots: Vec<Option<SlotState>>,
    done: Vec<RequestMetrics>,
    batch_iters: Vec<BatchIterRecord>,
    /// One-iteration lookahead buffer: iteration i+1's speculative drafts,
    /// produced while iteration i verified (pipelined mode only). At most
    /// one entry per slot; entries for slots that sat an iteration out
    /// (pool-deferred) survive until consumed or invalidated. Each entry
    /// is stamped with the verify window it drafted under — the hiding
    /// budget of the overlap cost rule.
    lookahead: Vec<SpecDraft>,
    /// Effective expert-parallel shard count (cfg.shards clamped to the
    /// model's expert count; 1 for dense models).
    n_shards: usize,
    /// Current expert → shard map. Starts balanced; under the
    /// co-activation strategy it is rebuilt every
    /// [`PLACEMENT_REFRESH`] fused iterations from `coact`.
    placement: ExpertPlacement,
    /// Online expert co-occurrence histogram (fed from the backend's
    /// per-layer id unions when it attributes ids).
    coact: CoActivationStats,
    iters_since_placement: usize,
    /// Evicted requests awaiting re-admission (preemption queue, FIFO).
    /// They hold no pool blocks and no backend slot while parked.
    parked: VecDeque<SlotState>,
    /// Monotone admission counter feeding `SlotState::admitted_seq`.
    admit_seq: u64,
    /// Re-prefill seconds accrued since the last committed iteration;
    /// drained into that iteration's `IterCost::reprefill_s`.
    pending_reprefill_s: f64,
    /// Evictions / re-admissions since the last committed iteration;
    /// drained into its `BatchIterRecord`.
    pending_evictions: usize,
    pending_readmissions: usize,
    /// Admission-ordering policy (`cfg.admission`): consulted by the
    /// scheduler for waiting-arrival order and by stage-0 re-admission for
    /// parked-victim priority/order. `fcfs` reproduces the pre-policy
    /// behavior bit-exactly.
    admission: Box<dyn AdmissionPolicy>,
    /// Virtual clock (simulated seconds): Σ prefill charges + Σ committed
    /// iteration costs + explicit idle advances. Arrival stamps, TTFT, and
    /// queueing delay are measured on this clock; it never influences
    /// token output.
    clock_s: f64,
    /// Clock time spent fully idle (open-loop low rate).
    idle_s: f64,
    /// Arrived-but-unadmitted requests the driving loop reported before
    /// this iteration (stamped into `BatchIterRecord::queue_depth` along
    /// with the parked count).
    queue_depth_hint: usize,
    /// Tightest deadline (`arrival + slo`) among the driving loop's waiting
    /// arrivals, reported alongside `queue_depth_hint`; `f64::INFINITY`
    /// when nothing waits. Feeds the controller's EDF slack signal.
    queue_min_deadline_s: f64,
    /// The fault schedule (`cfg.faults`, rust/docs/faults.md). Empty with
    /// `--faults off` — every fault query then short-circuits, keeping the
    /// default path bit-exact.
    faults: FaultPlan,
    /// `faults.stalls()` (sorted by t0) and the monotone cursor of stalls
    /// already injected.
    stall_schedule: Vec<(f64, u32, f64)>,
    stalls_fired: usize,
    /// Which shards are currently fault-killed (all-false when healthy).
    dead_shards: Vec<bool>,
    /// Pool capacity with no shrink active — the target `set_capacity`
    /// restores when a shrink window closes.
    normal_pool_blocks: usize,
    /// A pool-shrink window is currently applied (edge-detects the
    /// `fault_events` count).
    pool_shrunk: bool,
    /// A straggler window was active at the last commit (edge-detects the
    /// `fault_events` count).
    straggler_active: bool,
    /// Requests evicted by shard kills and not yet re-admitted; when the
    /// set drains, the elapsed virtual time since `kill_started_s` accrues
    /// into `recovery_s`.
    kill_victims: Vec<u64>,
    kill_started_s: f64,
    /// Fault-plan events that actually fired (stall injections, straggler
    /// window entries, shard kills, pool shrink entries).
    fault_events: usize,
    /// Virtual seconds from each shard kill until its victims were all
    /// re-admitted.
    recovery_s: f64,
    /// Requests the driving loop shed as unmeetable (`note_shed`).
    sheds: usize,
    /// The degradation controller's verdict for the current iteration
    /// (always `Normal` with `--controller off` — planning is then
    /// bit-exact with pre-controller builds).
    degrade: DegradeLevel,
    /// Pool-block shortfall summed over the previous iteration's deferred
    /// slots — the controller's admission-starvation signal.
    last_shortfall_blocks: usize,
    /// Per-shard EWMA of the observed verify-time inflation factor (1.0 =
    /// nominal). Fed by the straggler detector (`--heal detect`) from each
    /// committed iteration's per-shard scales; drives the
    /// capacity-weighted placement rebuild.
    health: Vec<f64>,
    /// Consecutive iterations each shard's health sat above
    /// [`HEAL_HIGH`] / below [`HEAL_LOW`] — the hysteresis confirmation
    /// streaks that gate marking/unmarking a shard degraded.
    hot_streak: Vec<u32>,
    cool_streak: Vec<u32>,
    /// Which shards the detector currently treats as degraded (capacity
    /// down-weighted in the healing rebuild).
    healing: Vec<bool>,
    /// Placement rebuilds the self-healing detector triggered (mark or
    /// unmark edges) — the hysteresis quality metric.
    heal_rebuilds: usize,
    /// Prefix cache (`--prefix-share`, rust/docs/prefix_cache.md): `Some`
    /// iff `cfg.prefix_share > 0`, in which case the pool runs in
    /// copy-on-write sharing mode and admissions attach any resident
    /// prefix instead of re-prefilling it. `None` keeps the counts-only
    /// pool and every pre-sharing code path bit-exactly.
    prefix: Option<PrefixTrie>,
    /// Admissions (fresh + re-admissions) that attached ≥ 1 cached block.
    prefix_hits: usize,
    /// Admissions that found no cached prefix block.
    prefix_misses: usize,
    /// Prompt tokens served from the cache instead of the prefill path.
    prefix_hit_tokens: u64,
    /// Iteration-scoped buffers recycled across the serving loop
    /// (rust/docs/perf.md).
    arena: IterArena,
    /// Persistent speculative-draft workers, spawned once here and fed per
    /// iteration over channels — `Some` iff `cfg.pipeline`. Replaces the
    /// scoped-threads-per-iteration drafting; results are re-sequenced by
    /// submission index, so output order (and therefore every downstream
    /// byte) matches the serial `run_spec_tasks` path exactly.
    draft_pool: Option<DraftPool>,
}

/// Fused iterations between co-activation placement rebuilds. Small enough
/// to adapt within a serving run, large enough that the histogram has
/// signal before the first rebuild.
const PLACEMENT_REFRESH: usize = 32;

/// Virtual-clock horizon (seconds) a stochastic fault process is
/// materialized over. Well past any serving run this repo's budgets reach;
/// the [`crate::coordinator::faults::MAX_PROCESS_EVENTS`] cap bounds the
/// schedule long before a short-MTBF spec fills the horizon.
pub const PROCESS_HORIZON_S: f64 = 30.0;

/// EWMA smoothing weight of the per-shard health estimator: each committed
/// iteration's observed inflation factor moves the estimate a quarter of
/// the way — fast enough to confirm a straggler within a handful of
/// iterations, slow enough that a single stall does not.
const HEAL_ALPHA: f64 = 0.25;
/// A shard whose health EWMA exceeds this factor is a straggler candidate…
const HEAL_HIGH: f64 = 2.0;
/// …and one back under this factor is a recovery candidate. The gap
/// between the bands is the hysteresis: a shard hovering between them
/// keeps its current designation, so the placement never flaps.
const HEAL_LOW: f64 = 1.25;
/// Consecutive iterations the EWMA must sit past a band edge before the
/// detector acts on it (confirmation streak).
const HEAL_CONFIRM: u32 = 3;

/// KV page size (tokens per block) of the batched engine's shared pool —
/// the one source of truth for anything sizing pools in blocks (the
/// preemption experiment derives its half-working-set pool from it).
pub const KV_BLOCK: usize = 16;

impl BatchEngine {
    /// Build over an explicit backend. `cfg.max_batch` is clamped to what
    /// the backend supports, so single-request backends serve batch=1
    /// through the sequential `step_batch` fallback.
    pub fn new(
        cfg: EngineConfig,
        backend: Box<dyn Backend>,
        cost: GpuCostModel,
        policy_kind: PolicyKind,
    ) -> Self {
        let kv_block = KV_BLOCK;
        let max_batch = cfg.max_batch.max(1).min(backend.max_slots());
        let blocks_per_request = backend.mini().max_seq / kv_block;
        // Pool sizing: the aggregate worst case by default (no
        // cross-request contention); `cfg.kv_pool_blocks` oversubscribes
        // it so admission and speculation genuinely compete. Never below
        // one full window, so a lone request can always reach max_seq.
        let auto = max_batch * blocks_per_request;
        let total_blocks = if cfg.kv_pool_blocks > 0 {
            cfg.kv_pool_blocks.clamp(blocks_per_request, auto)
        } else {
            auto
        };
        let mut pool = KvBlockPool::new(total_blocks, kv_block);
        // Prefix cache: sharing mode must be on before the first admission
        // maps a block, so the decision is taken here, once, from the
        // config knob (rust/docs/prefix_cache.md).
        let prefix = if cfg.prefix_share > 0.0 {
            pool.enable_sharing();
            Some(PrefixTrie::new(kv_block))
        } else {
            None
        };
        let mut slots = Vec::with_capacity(max_batch);
        slots.resize_with(max_batch, || None);
        // Expert-parallel setup: shards beyond the expert count cannot hold
        // a full expert each; dense models have nothing to shard, and a
        // backend that cannot attribute expert ids (sequential fallback)
        // is priced unsharded — clamp so telemetry never claims otherwise.
        let n_experts = backend.mini().n_experts;
        let n_shards = if backend.mini().is_moe && backend.attributes_expert_ids() {
            cfg.shards.max(1).min(n_experts.max(1))
        } else {
            1
        };
        let placement = ExpertPlacement::balanced(n_experts, n_shards);
        let coact = CoActivationStats::new(n_experts);
        let admission = build_policy(cfg.admission);
        // The CLI validates the fault spec before building an engine, so a
        // parse failure here is a programming error (a test passing a bad
        // inline spec): fail loudly in debug builds, degrade to fault-free
        // serving in release rather than panicking mid-serve.
        debug_assert!(
            FaultPlan::parse(&cfg.faults).is_ok(),
            "invalid fault spec {:?}",
            cfg.faults
        );
        let mut faults = FaultPlan::parse(&cfg.faults).unwrap_or_default();
        // Stochastic fault process (`--fault-process mtbf=..,mttr=..`): the
        // MTBF/MTTR spec is materialized into a concrete, seed-deterministic
        // schedule up front and merged with the explicit plan, so everything
        // downstream (stall cursor, straggler windows, kill transitions)
        // sees one ordinary FaultPlan. `off` (the default) merges nothing —
        // bit-exact with a process-free build.
        debug_assert!(
            FaultProcess::parse(&cfg.fault_process).is_ok(),
            "invalid fault process spec {:?}",
            cfg.fault_process
        );
        if let Ok(Some(process)) = FaultProcess::parse(&cfg.fault_process) {
            faults = faults.merged(process.materialize(
                cfg.seed,
                n_shards,
                PROCESS_HORIZON_S,
            ));
        }
        let stall_schedule = faults.stalls();
        // Spawn the persistent draft workers once, before the serving loop:
        // pipelined engines fan each iteration's speculative scans out to
        // them instead of spawning scoped threads per iteration.
        let draft_pool = if cfg.pipeline { Some(DraftPool::new(max_batch)) } else { None };
        Self {
            cfg,
            backend,
            cost,
            policy_kind,
            kv_block,
            pool,
            max_batch,
            slots,
            done: Vec::new(),
            batch_iters: Vec::new(),
            lookahead: Vec::new(),
            n_shards,
            placement,
            coact,
            iters_since_placement: 0,
            parked: VecDeque::new(),
            admit_seq: 0,
            pending_reprefill_s: 0.0,
            pending_evictions: 0,
            pending_readmissions: 0,
            admission,
            clock_s: 0.0,
            idle_s: 0.0,
            queue_depth_hint: 0,
            queue_min_deadline_s: f64::INFINITY,
            faults,
            stall_schedule,
            stalls_fired: 0,
            dead_shards: vec![false; n_shards],
            normal_pool_blocks: total_blocks,
            pool_shrunk: false,
            straggler_active: false,
            kill_victims: Vec::new(),
            kill_started_s: 0.0,
            fault_events: 0,
            recovery_s: 0.0,
            sheds: 0,
            degrade: DegradeLevel::Normal,
            last_shortfall_blocks: 0,
            health: vec![1.0; n_shards],
            hot_streak: vec![0; n_shards],
            cool_streak: vec![0; n_shards],
            healing: vec![false; n_shards],
            heal_rebuilds: 0,
            prefix,
            prefix_hits: 0,
            prefix_misses: 0,
            prefix_hit_tokens: 0,
            arena: IterArena::default(),
            draft_pool,
        }
    }

    /// The virtual clock: simulated seconds of prefill + decode + idle so
    /// far. Arrival processes and latency telemetry read this; tokens
    /// never depend on it.
    pub fn clock_s(&self) -> f64 {
        self.clock_s
    }

    /// Advance the clock across an idle gap (no slot occupied, the next
    /// arrival is in the future). No-op when `t` is in the past.
    pub fn idle_until(&mut self, t: f64) {
        if t > self.clock_s {
            self.idle_s += t - self.clock_s;
            self.clock_s = t;
        }
    }

    /// The configured admission-ordering policy.
    pub fn admission(&self) -> &dyn AdmissionPolicy {
        self.admission.as_ref()
    }

    /// Fresh admissions are currently held back: a parked-priority policy
    /// with eviction victims still waiting (they get first pick of slots
    /// and pool blocks at the next stage-0 drain).
    pub fn fresh_admission_blocked(&self) -> bool {
        self.admission.parked_first() && !self.parked.is_empty()
    }

    /// Report how many arrived requests wait unadmitted; stamped (plus the
    /// parked count) into the next committed `BatchIterRecord`.
    pub fn set_queue_depth(&mut self, waiting: usize) {
        self.queue_depth_hint = waiting;
    }

    /// Report the tightest deadline (`arrival + slo`) among waiting
    /// arrivals, or `f64::INFINITY` when none wait. Feeds the degradation
    /// controller's EDF slack signal.
    pub fn set_queue_deadline(&mut self, deadline_s: f64) {
        self.queue_min_deadline_s = deadline_s;
    }

    /// Record `n` requests the driving loop shed before admission because
    /// their SLO deadline already passed (rust/docs/faults.md). Shed
    /// requests never produce a `RequestMetrics`, so they can never count
    /// toward `slo_goodput`.
    pub fn note_shed(&mut self, n: usize) {
        self.sheds += n;
    }

    /// The active fault schedule (empty with `--faults off`).
    pub fn faults(&self) -> &FaultPlan {
        &self.faults
    }

    /// Fault-plan events that actually fired so far.
    pub fn fault_events(&self) -> usize {
        self.fault_events
    }

    /// The degradation controller's verdict for the iteration being
    /// planned (always `Normal` with `--controller off`).
    pub fn degrade_state(&self) -> DegradeLevel {
        self.degrade
    }

    /// Snapshot the pressure signals the degradation controller reads.
    /// Pure observation: building the signal never mutates engine state.
    fn pressure_signal(&self) -> PressureSignal {
        let min_slack_s = if self.queue_min_deadline_s.is_finite() {
            self.queue_min_deadline_s - self.clock_s
        } else {
            f64::INFINITY
        };
        PressureSignal {
            pool_util: self.pool.utilization(),
            shortfall_blocks: self.last_shortfall_blocks,
            queue_depth: self.queue_depth_hint + self.parked.len(),
            max_batch: self.max_batch,
            slo_s: self.cfg.slo_s,
            min_slack_s,
        }
    }

    /// Apply fault-plan transitions for the iteration starting at the
    /// current clock: pool-shrink windows (re-applied every iteration so
    /// freed blocks cannot sneak past an active window), and shard
    /// kill/recovery edges. Killing a shard evicts its striped requests
    /// (KV striping modeled as `request id % n_shards`) through the same
    /// lossless park/replay path as pool preemption, then rebuilds the
    /// expert placement on the survivors; recovery restores the balanced
    /// placement. Both rebuilds reset the co-activation refresh window so
    /// a stale greedy placement is never carried across a topology change.
    fn apply_fault_transitions(&mut self) -> Result<()> {
        if self.faults.is_off() {
            return Ok(());
        }
        // Pool shrink: clamp-to-committed semantics live in
        // `KvBlockPool::set_capacity`; re-applying each iteration ratchets
        // the capacity down as slots release blocks during the window.
        if self.faults.has_pool_shrink() {
            let frac = self.faults.pool_frac(self.clock_s);
            if frac < 1.0 {
                let target = ((self.normal_pool_blocks as f64 * frac).floor() as usize).max(1);
                self.pool.set_capacity(target);
                if !self.pool_shrunk {
                    self.pool_shrunk = true;
                    self.fault_events += 1;
                }
            } else if self.pool_shrunk {
                self.pool.set_capacity(self.normal_pool_blocks);
                self.pool_shrunk = false;
            }
        }
        // Shard kill / recovery edges.
        let mask = self
            .faults
            .dead_shards(self.clock_s, self.n_shards)
            .unwrap_or_else(|| vec![false; self.n_shards]);
        let mut mask = mask;
        if mask.iter().all(|&d| d) {
            // Never kill the last survivor: the fault model degrades
            // service, it does not halt it.
            mask[0] = false;
        }
        if mask != self.dead_shards {
            let newly_dead: Vec<usize> = (0..self.n_shards)
                .filter(|&s| mask[s] && !self.dead_shards[s])
                .collect();
            for &shard in &newly_dead {
                self.fault_events += 1;
                if self.kill_victims.is_empty() {
                    self.kill_started_s = self.clock_s;
                }
                let victims: Vec<usize> = self
                    .slots
                    .iter()
                    .enumerate()
                    .filter_map(|(slot, entry)| {
                        let state = entry.as_ref()?;
                        (!state.finished && (state.req.id as usize) % self.n_shards == shard)
                            .then_some(slot)
                    })
                    .collect();
                for slot in victims {
                    let id = self.slots[slot]
                        .as_ref()
                        .map(|s| s.req.id)
                        .expect("victim slot selected while occupied");
                    self.kill_victims.push(id);
                    self.evict_slot(slot)?;
                }
            }
            self.dead_shards = mask;
            let n_experts = self.backend.mini().n_experts;
            self.placement = if self.dead_shards.iter().any(|&d| d) {
                ExpertPlacement::balanced_surviving(n_experts, self.n_shards, &self.dead_shards)
            } else {
                ExpertPlacement::balanced(n_experts, self.n_shards)
            };
            self.iters_since_placement = 0;
        }
        Ok(())
    }

    /// Effective expert-parallel shard count (1 = unsharded).
    pub fn n_shards(&self) -> usize {
        self.n_shards
    }

    /// Current expert → shard map (telemetry / tests).
    pub fn placement(&self) -> &ExpertPlacement {
        &self.placement
    }

    /// Sim-backend batched engine (native fused routing, full batching).
    pub fn sim(registry: &Registry, cfg: EngineConfig, policy_kind: PolicyKind) -> Result<Self> {
        let model = registry.model(&cfg.model)?;
        let cost = GpuCostModel::new(model.paper.clone(), model.mini.layers);
        let backend = Box::new(crate::sim::SimBackend::new(model.mini.clone(), cfg.seed));
        Ok(Self::new(cfg, backend, cost, policy_kind))
    }

    /// Real-backend batched engine. The PJRT backend holds one request, so
    /// the batch clamps to 1 (sequential fallback); draft-model speculation
    /// is not supported on this path — use the single-request engine.
    pub fn real(registry: &Registry, cfg: EngineConfig, policy_kind: PolicyKind) -> Result<Self> {
        anyhow::ensure!(
            cfg.drafter == DrafterKind::Ngram,
            "the batched engine supports draft-model speculation only on the sim backend"
        );
        let runtime = crate::runtime::ModelRuntime::load(registry, &cfg.model)
            .with_context(|| format!("loading model {}", cfg.model))?;
        let mini_layers = runtime.model.mini.layers;
        let cost = GpuCostModel::new(runtime.model.paper.clone(), mini_layers);
        let backend = Box::new(crate::coordinator::backend::RealBackend::new(
            runtime,
            cfg.guide_strength,
            cfg.seed,
        ));
        Ok(Self::new(cfg, backend, cost, policy_kind))
    }

    /// Effective batch size after clamping to the backend.
    pub fn max_batch(&self) -> usize {
        self.max_batch
    }

    /// Worst-case total output tokens this engine's admitted requests can
    /// reach: tokens already emitted by finished requests plus every active
    /// request's remaining-capable maximum (`max_new_tokens - 1` counted
    /// emissions). Admission control charges against this bound; it
    /// self-corrects when a request finishes early (EOS), unlike a
    /// pre-charged grant that would never be refunded.
    pub fn output_bound(&self) -> usize {
        let done: usize = self.done.iter().map(|m| m.tokens_emitted()).sum();
        let active: usize = self
            .slots
            .iter()
            .flatten()
            .map(|s| s.req.max_new_tokens.saturating_sub(1))
            .sum();
        // Parked (evicted) requests are admitted work: they re-enter a slot
        // and finish their budget, so admission control must keep charging
        // for them while they wait.
        let parked: usize = self
            .parked
            .iter()
            .map(|s| s.req.max_new_tokens.saturating_sub(1))
            .sum();
        done + active + parked
    }

    pub fn active(&self) -> usize {
        self.slots.iter().flatten().filter(|s| !s.finished).count()
    }

    /// Evicted requests currently waiting for re-admission.
    pub fn parked_requests(&self) -> usize {
        self.parked.len()
    }

    pub fn has_free_slot(&self) -> bool {
        self.slots.iter().any(|s| s.is_none())
    }

    /// Would `admit` succeed for this request right now?
    pub fn can_admit(&self, req: &Request) -> bool {
        if !self.has_free_slot() || req.prompt.len() + 2 > self.backend.mini().max_seq {
            return false;
        }
        match &self.prefix {
            None => self.pool.can_admit(req.prompt.len()),
            Some(trie) => {
                // Resident prefix blocks attach for free; the fresh
                // remainder can additionally draw on cache-only
                // (trie-pinned, refcount-1) blocks, which admission
                // reclaims LRU-first before allocating.
                let shared = trie.peek(&req.prompt);
                let total = req.prompt.len().max(1).div_ceil(self.kv_block);
                total - shared.len()
                    <= self.pool.free_blocks() + trie.reclaimable(&self.pool, &shared)
            }
        }
    }

    /// Fresh per-request drafter mirroring `Engine`'s wiring.
    fn build_drafter(&self) -> Result<EngineDrafter> {
        Ok(match self.cfg.drafter {
            DrafterKind::Ngram => {
                EngineDrafter::Ngram(NgramDrafter::new(self.cfg.ngram_min, self.cfg.ngram_max))
            }
            DrafterKind::EagleLite => {
                anyhow::ensure!(
                    self.backend.name() == "sim",
                    "batched draft-model speculation requires the sim backend"
                );
                EngineDrafter::SimEagle {
                    rng: Rng::new(self.cfg.seed ^ 0xE1),
                    seed: self.cfg.seed ^ 0xE1,
                }
            }
        })
    }

    /// Admit one request arriving "now" (closed-loop semantics: arrival ==
    /// admission instant, so queueing delay is zero unless the scheduler
    /// deferred the stamped entry).
    pub fn admit(&mut self, req: Request) -> Result<()> {
        let now = self.clock_s;
        self.admit_at(req, now)
    }

    /// Admit one request that arrived at `arrival_s` on the virtual clock:
    /// bind a slot, prefill, charge the pool, stamp the latency telemetry
    /// (arrival, admission, first token).
    pub fn admit_at(&mut self, req: Request, arrival_s: f64) -> Result<()> {
        let slot = self
            .slots
            .iter()
            .position(|s| s.is_none())
            .ok_or_else(|| anyhow::anyhow!("no free slot (batch {})", self.max_batch))?;
        let max_seq = self.backend.mini().max_seq;
        anyhow::ensure!(
            req.prompt.len() + 2 <= max_seq,
            "prompt ({}) does not fit the {} window",
            req.prompt.len(),
            max_seq
        );
        // Build per-request machinery before taking any backend/pool side
        // effects, so a config error (e.g. an unsupported drafter) cannot
        // leak a bound slot or pool blocks.
        let mut drafter = self.build_drafter()?;
        let mut policy = self.policy_kind.build();
        policy.reset();

        self.backend.begin_slot(slot, &req)?;
        let hit_tokens = self.attach_prefix(req.id, &req.prompt)?;

        let mut metrics = RequestMetrics {
            id: req.id,
            task: req.task.name().into(),
            prompt_tokens: req.prompt.len(),
            arrival_s,
            admitted_s: self.clock_s,
            queue_wait_s: (self.clock_s - arrival_s).max(0.0),
            ..Default::default()
        };
        let wall_start = Instant::now(); // lint:allow(wall-clock): host-wall prefill telemetry, never the virtual clock
        let guide0 = req.reference.first().copied();
        let prefilled = self
            .backend
            .prefill_slot(slot, &req.prompt, guide0, req.eps)
            .and_then(|first| drafter.begin_request(&req, first).map(|()| first));
        let first = match prefilled {
            Ok(t) => t,
            Err(e) => {
                self.pool.release(req.id);
                self.backend.release_slot(slot);
                return Err(e);
            }
        };
        // Record the prompt's full blocks in the prefix cache only after
        // the prefill succeeded: the error path above released the pool
        // mapping, so inserting earlier would pin blocks of a request that
        // never served.
        self.note_prefix(&req.prompt, req.id)?;
        // Prefill charge: chunked full-parallel steps (excluded from TPOT,
        // but on the virtual clock — the first token exists only after it).
        // Cached prefix tokens are the bytes never re-fetched: only the
        // novel suffix is charged, so TTFT collapses for cache hits.
        metrics.prefill_s = self.prefill_charge(req.prompt.len() - hit_tokens);
        self.clock_s += metrics.prefill_s;
        metrics.first_token_s = self.clock_s;

        let mut context = req.prompt.clone();
        context.push(first);
        let finished = first == EOS || req.max_new_tokens <= 1;
        let d_eps = crate::coordinator::eagle::draft_eps(req.task);
        self.admit_seq += 1;
        let state = SlotState {
            d_eps,
            policy,
            drafter,
            output: vec![first],
            context,
            finished,
            metrics,
            wall_start,
            req,
            last_iter_s: 0.0,
            admitted_seq: self.admit_seq,
            last_utility: f64::INFINITY,
            history: Vec::new(),
            parked_since: 0.0,
        };
        if state.finished {
            // EOS at prefill (or a 1-token budget): finalize immediately.
            self.finalize(slot, state);
        } else {
            self.slots[slot] = Some(state);
        }
        Ok(())
    }

    fn finalize(&mut self, slot: usize, mut state: SlotState) {
        // Purge the slot's buffered speculation: the request is gone, and
        // a new request rebound to this slot must start clean (the
        // reconcile `req_id` guard would also catch it, but would miscount
        // the dead entry as a recompute).
        self.lookahead.retain(|e| e.slot != slot);
        self.pool.release(state.req.id);
        self.backend.release_slot(slot);
        state.metrics.finish_s = self.clock_s;
        state.metrics.wall_total_ns = state.wall_start.elapsed().as_nanos() as u64;
        state.metrics.output = std::mem::take(&mut state.output);
        self.done.push(state.metrics);
    }

    /// Run one fused decode iteration over all active slots through the
    /// four-stage pipeline — **plan** (per-slot K under every cap),
    /// **draft** (reconcile the pipelined lookahead or scan now),
    /// **verify** (submit the fused step; while it runs, speculatively
    /// draft the *next* iteration), **commit** (rejection-sample, charge
    /// overlap-aware costs, feed policies). Returns false when nothing is
    /// in flight (the caller should admit or stop).
    pub fn step_iteration(&mut self) -> Result<bool> {
        // ---- Stage 0: faults, controller verdict, re-admission ----------
        // Fault-plan transitions (pool shrink, shard kill/recovery) apply
        // on the virtual clock before anything is planned, and the
        // degradation controller takes its verdict from the pre-plan
        // pressure snapshot. Both are no-ops with
        // `--faults off --controller off`, keeping that path bit-exact
        // with pre-fault builds.
        self.apply_fault_transitions()?;
        self.degrade = if self.cfg.controller.is_on() {
            degrade_level(&self.pressure_signal())
        } else {
            DegradeLevel::Normal
        };
        // Bring evicted requests back in while slots and blocks allow; each
        // re-admission re-prefills (and replays) the victim's committed
        // context and charges `pending_reprefill_s`.
        self.readmit_parked()?;

        // ---- Stage 1: plan ----------------------------------------------
        let mut plans = self.plan_stage();

        // ---- Stage 2: draft ---------------------------------------------
        let (mut spans, mut planned, reconcile, deferred, evicted) = self.draft_stage(&plans)?;
        plans.clear();
        self.arena.plans = plans;

        if spans.is_empty() {
            self.arena.spans = spans;
            planned.clear();
            self.arena.planned = planned;
            // Nothing to verify; finalize any slots that just ran out of
            // window room. Their released blocks — like any blocks evicted
            // this pass — may unblock a deferred request, so both count as
            // progress.
            let swept = self.sweep_finished();
            if deferred > 0 && (swept > 0 || evicted > 0) {
                return Ok(true);
            }
            // Deferred slots with no progressing neighbour and no evictable
            // victim can never be unblocked (nothing will free pool
            // blocks): a genuine deadlock of an oversubscribed pool,
            // surfaced rather than spun on.
            if deferred > 0 {
                // Structured, not a bare bail: the serve path downcasts
                // `EngineError` to emit the partial metrics collected so
                // far and exit with a distinct code instead of a panic or
                // an opaque error string.
                return Err(match self.cfg.eviction {
                    EvictionKind::Off => EngineError::Deadlock { waiting: deferred },
                    _ => EngineError::CappedDeadlock {
                        cap: self.cfg.max_preemptions_per_req,
                        waiting: deferred,
                    },
                }
                .into());
            }
            if !self.parked.is_empty() {
                // All slots drained but evicted requests still wait: the
                // freed slots/blocks let the next pass re-admit them.
                return Ok(true);
            }
            return Ok(false);
        }

        // ---- Stage 3: verify (+ pipelined draft of iteration i+1) -------
        let iter_wall = Instant::now(); // lint:allow(wall-clock): host-wall verify telemetry, never the virtual clock
        // Hand last iteration's `BatchStep` back to the backend as scratch:
        // its slot buffers are reused in place instead of reallocated.
        let scratch = std::mem::take(&mut self.arena.step);
        let pending = self.backend.submit_batch_reusing(&spans, scratch)?;
        let mut spec_wall_ns = 0u64;
        if self.cfg.pipeline {
            // While the backend verifies, draft next iteration's proposals
            // for every live slot on scoped threads (per-request CPU work).
            // Its wall time is measured so the iteration telemetry can
            // charge it to the overlap window rather than the critical
            // path (both current backends execute the verify eagerly in
            // submit_batch, so on this host the scans run after it).
            let spec_wall = Instant::now(); // lint:allow(wall-clock): measures spec_wall_ns overlap telemetry
            self.spec_draft_next(&planned, &spans);
            spec_wall_ns = spec_wall.elapsed().as_nanos() as u64;
        }
        let batch = self.backend.wait_batch(pending)?;

        // ---- Stage 4: commit --------------------------------------------
        let cost =
            self.commit_stage(&spans, &planned, &batch, iter_wall, spec_wall_ns, reconcile)?;

        // Stamp the just-created lookahead entries with the verify window
        // their scans ran under — the hiding budget a future hit can
        // claim. Entries surviving from earlier iterations (deferred
        // slots) keep their original stamp.
        if self.cfg.pipeline {
            let window = cost.verify_s();
            for e in &mut self.lookahead {
                e.window_s.get_or_insert(window);
            }
        }

        self.sweep_finished();

        // Recycle the iteration's buffers into the arena: the committed
        // BatchStep becomes next iteration's backend scratch, and the span
        // token/guide vectors return to the draft-stage pools.
        self.arena.step = batch;
        for span in spans.drain(..) {
            let VerifySpan { mut tokens, mut guides, .. } = span;
            tokens.clear();
            guides.clear();
            self.arena.token_bufs.push(tokens);
            self.arena.guide_bufs.push(guides);
        }
        self.arena.spans = spans;
        planned.clear();
        self.arena.planned = planned;
        Ok(true)
    }

    /// Plan stage: per-slot K decisions under the KV window and the
    /// remaining output budget — same laws as the single-request engine.
    /// Pool caps are deliberately **not** applied here: they must be
    /// interleaved with the reservations of earlier slots (draft stage),
    /// or two slots could both be planned against the same free blocks.
    fn plan_stage(&mut self) -> Vec<SlotPlan> {
        let max_seq = self.backend.mini().max_seq;
        // Degradation controller: under Throttle, speculation is capped
        // (shorter spans reserve fewer pool blocks and verify fewer
        // tokens); under Halt it is disabled outright — K=0 steps still
        // emit one token each, so service degrades instead of stopping.
        // The policy keeps driving (`next_k` runs, and it observes the
        // executed K like any other cap), so control returns to it the
        // moment pressure clears.
        let k_cap = match self.degrade {
            DegradeLevel::Normal => MAX_K,
            DegradeLevel::Throttle => THROTTLE_K_CAP,
            DegradeLevel::Halt => 0,
        };
        let mut plans: Vec<SlotPlan> = std::mem::take(&mut self.arena.plans);
        plans.clear();
        for slot in 0..self.slots.len() {
            let Some(state) = self.slots[slot].as_mut() else { continue };
            if state.finished {
                continue;
            }
            let out_idx = state.output.len();
            let mut k = state.policy.next_k().min(MAX_K).min(k_cap);
            let room = max_seq.saturating_sub(self.backend.cache_len_slot(slot) + 1);
            k = k.min(room);
            k = k.min(state.req.max_new_tokens.saturating_sub(out_idx).saturating_sub(1));
            if room == 0 {
                // Window exhausted: the request cannot decode further.
                state.finished = true;
                continue;
            }
            plans.push(SlotPlan { slot, k, out_idx });
        }
        plans
    }

    /// Draft stage: per planned slot, apply the shared-pool caps against
    /// the pool state earlier slots' reservations already mutated, then
    /// use the pipelined lookahead draft if its assumptions held (same
    /// request, same context tail, same K) — its scan already ran hidden
    /// under the previous verify — otherwise scan now (a pipeline
    /// bubble). Returns spans, per-span bookkeeping, the reconcile tally
    /// (hits, misses, recomputes), how many slots were deferred by pool
    /// pressure, and how many victims were evicted to relieve it.
    #[allow(clippy::type_complexity)]
    fn draft_stage(
        &mut self,
        plans: &[SlotPlan],
    ) -> Result<(Vec<VerifySpan>, Vec<PlannedSpan>, ReconcileTally, usize, usize)> {
        let pipeline = self.cfg.pipeline;
        let mut spans: Vec<VerifySpan> = std::mem::take(&mut self.arena.spans);
        spans.clear();
        let mut planned: Vec<PlannedSpan> = std::mem::take(&mut self.arena.planned);
        planned.clear();
        let mut tally = ReconcileTally::default();
        let mut deferred = 0usize;
        let mut evicted = 0usize;
        // Blocks the deferred slots fell short by — the controller's
        // admission-starvation signal for the *next* iteration's verdict.
        let mut shortfall_blocks = 0usize;
        // Slots whose span is already built this pass: their reservations
        // are live inputs of the fused step, so they are never victims.
        let mut in_spans = vec![false; self.slots.len()];
        for plan in plans {
            // The slot may have been evicted by an earlier stuck slot in
            // this very pass — skip it (it is parked, not deferred).
            let Some(state_ref) = self.slots[plan.slot].as_ref() else { continue };
            let req_id = state_ref.req.id;
            let mut k = plan.k;
            if self.cfg.eviction.is_on() {
                // Preemption mode: pool pressure is all-or-nothing per
                // slot. Shrinking K under pressure would change this
                // request's span sequence — and with it the sampled token
                // stream — versus an uncontended run; deferring or
                // evicting preserves it (the losslessness guarantee,
                // rust/docs/preemption.md). So: evict victims until the
                // full planned span fits, else defer the whole iteration.
                //
                // Feasibility pre-check (ROADMAP: pressure-signal plumbing):
                // before paying any eviction, compare the reservation's
                // block shortfall against what the whole eligible victim
                // set could free. When no victim set can satisfy the
                // reservation, evicting would trash other requests' state
                // and still defer — skip straight to defer/deadlock.
                //
                // With the prefix cache on, cache-only (trie-pinned,
                // refcount-1) blocks are cheaper relief than any
                // preemption: reclaim LRU leaves first and re-measure. And
                // the victim set is priced at *exclusive* blocks — a block
                // another slot (or the trie) also maps merely loses one
                // reference when its holder is evicted, freeing nothing.
                let mut shortfall = self.pool.reserve_shortfall(req_id, 1 + k);
                if shortfall > 0 {
                    if let Some(trie) = self.prefix.as_mut() {
                        trie.reclaim(&mut self.pool, shortfall, &[])?;
                        shortfall = self.pool.reserve_shortfall(req_id, 1 + k);
                    }
                }
                if shortfall > 0 {
                    let evictable: usize = self
                        .victim_candidates(plan.slot, &in_spans, plans)
                        .iter()
                        .filter(|c| (c.preemptions as usize) < self.cfg.max_preemptions_per_req)
                        .map(|c| c.blocks)
                        .sum();
                    if evictable < shortfall {
                        deferred += 1;
                        shortfall_blocks += shortfall;
                        continue;
                    }
                }
                while !self.pool.can_reserve(req_id, 1 + k) {
                    let Some(victim) = self.pick_victim(plan.slot, &in_spans, plans) else {
                        break;
                    };
                    self.evict_slot(victim)?;
                    evicted += 1;
                }
                if !self.pool.can_reserve(req_id, 1 + k) {
                    deferred += 1;
                    shortfall_blocks += self.pool.reserve_shortfall(req_id, 1 + k);
                    continue;
                }
            } else {
                // Legacy pressure response (bit-exact with `eviction=off`
                // builds): shrink speculation until the span fits; if even
                // the next token cannot be reserved, defer this request
                // for one iteration — the other spans' commits and
                // releases free blocks. A deferred slot's lookahead entry
                // stays buffered: its context has not moved, so it may
                // still hit next iteration.
                if let Some(trie) = self.prefix.as_mut() {
                    // Sharing under `eviction=off`: cache-only blocks are
                    // the only relief valve — return LRU trie pins before
                    // shrinking this slot's speculation.
                    let need = self.pool.reserve_shortfall(req_id, 1 + k);
                    if need > 0 {
                        trie.reclaim(&mut self.pool, need, &[])?;
                    }
                }
                while k > 0 && !self.pool.can_reserve(req_id, 1 + k) {
                    k -= 1;
                }
                if !self.pool.can_reserve(req_id, 1) {
                    deferred += 1;
                    shortfall_blocks += self.pool.reserve_shortfall(req_id, 1);
                    continue;
                }
            }
            let state = self.slots[plan.slot].as_mut().expect("slot checked above");
            // Consume this slot's lookahead entry, valid or not: a stale
            // speculation is useless once the real iteration diverged.
            let entry_pos = self.lookahead.iter().position(|e| e.slot == plan.slot);
            let entry = entry_pos.map(|i| self.lookahead.swap_remove(i));
            let rec = reconcile_entry(entry, state.req.id, k, &state.context, &mut state.drafter);
            let pipelined = rec.hit;
            let hidden_window_s = rec.hidden_window_s;
            if rec.hit {
                tally.hits += 1;
            }
            if rec.recompute {
                tally.recomputes += 1;
            }
            let (drafts, draft_wall_ns) = match rec.taken {
                Some(d) => d,
                None => {
                    if pipeline && k > 0 {
                        tally.misses += 1; // bubble: drafting on the critical path
                    }
                    let draft_wall = Instant::now(); // lint:allow(wall-clock): measures draft_wall_ns telemetry
                    let d = state.drafter.propose(
                        &state.context,
                        &state.req.reference,
                        plan.out_idx,
                        k,
                        state.d_eps,
                    )?;
                    (d, draft_wall.elapsed().as_nanos() as u64)
                }
            };
            let drafted = drafts.len();

            let t = 1 + drafted;
            self.pool.reserve(state.req.id, t)?;
            // Span buffers come from the arena pools (cleared on retire),
            // so steady-state iterations build spans allocation-free.
            let mut tokens = self.arena.token_bufs.pop().unwrap_or_default();
            debug_assert!(tokens.is_empty());
            tokens.reserve(t);
            // Every admitted slot owns at least its prefill token; a bare
            // output here means slot bookkeeping corrupted — surface it as
            // an error, not a serve-path panic.
            let Some(&head_token) = state.output.last() else {
                anyhow::bail!("slot {} (request {}) lost its output head", plan.slot, req_id);
            };
            tokens.push(head_token);
            tokens.extend_from_slice(&drafts);
            let mut guides = self.arena.guide_bufs.pop().unwrap_or_default();
            debug_assert!(guides.is_empty());
            guides.extend((0..t).map(|i| state.req.reference.get(plan.out_idx + i).copied()));
            spans.push(VerifySpan { slot: plan.slot, tokens, guides, eps: state.req.eps });
            planned.push(PlannedSpan {
                slot: plan.slot,
                k_chosen: k,
                drafted,
                draft_wall_ns,
                pipelined,
                hidden_window_s,
            });
            in_spans[plan.slot] = true;
        }
        self.last_shortfall_blocks = shortfall_blocks;
        Ok((spans, planned, tally, deferred, evicted))
    }

    /// The victim-candidate view for `stuck` slot's eviction request:
    /// live, unfinished slots other than the stuck one that are not
    /// already part of this iteration's fused step. The feasibility
    /// pre-check sums this set's blocks; [`select_victim`] picks from it
    /// (filtering requests at the preemption cap). With one active request
    /// there are no candidates — the sole slot is never evicted. Blocks
    /// are priced *exclusive* ([`KvBlockPool::exclusive_blocks_of`]): with
    /// the prefix cache on, evicting a slot whose blocks others share
    /// frees nothing, and both scoring and feasibility must know it.
    fn victim_candidates(
        &self,
        stuck: usize,
        in_spans: &[bool],
        plans: &[SlotPlan],
    ) -> Vec<VictimCandidate> {
        let planned_k =
            |slot: usize| plans.iter().find(|p| p.slot == slot).map_or(0, |p| p.k);
        let mut cands: Vec<VictimCandidate> = Vec::new();
        for (slot, entry) in self.slots.iter().enumerate() {
            let Some(s) = entry else { continue };
            if slot == stuck || s.finished || in_spans[slot] {
                continue;
            }
            cands.push(VictimCandidate {
                slot,
                req_id: s.req.id,
                admitted_seq: s.admitted_seq,
                planned_k: planned_k(slot),
                blocks: self.pool.exclusive_blocks_of(s.req.id),
                last_utility: s.last_utility,
                preemptions: self.pool.preemptions(s.req.id),
            });
        }
        cands
    }

    /// Select an eviction victim per the configured policy.
    fn pick_victim(&self, stuck: usize, in_spans: &[bool], plans: &[SlotPlan]) -> Option<usize> {
        let cands = self.victim_candidates(stuck, in_spans, plans);
        select_victim(self.cfg.eviction, &cands, self.cfg.max_preemptions_per_req)
    }

    /// Evict one slot: release its pool blocks and backend state,
    /// invalidate its buffered lookahead by `req_id`, and park the request
    /// (policy, drafter, output, and replay history intact) for
    /// re-admission.
    fn evict_slot(&mut self, slot: usize) -> Result<()> {
        let mut state = self.slots[slot]
            .take()
            .ok_or_else(|| anyhow::anyhow!("evicting empty slot {slot}"))?;
        // Invalidate the victim's buffered speculation by request id (the
        // reconcile rule would also reject it on req_id mismatch, but a
        // dead entry must not linger on a slot about to be rebound).
        self.lookahead.retain(|e| e.req_id != state.req.id);
        self.pool.evict(state.req.id)?;
        self.backend.release_slot(slot);
        state.metrics.preemptions += 1;
        state.parked_since = self.clock_s;
        self.pending_evictions += 1;
        self.parked.push_back(state);
        Ok(())
    }

    /// Simulated time to (re)compute `tokens` context positions through the
    /// chunked full-parallel prefill path — the one pricing law shared by
    /// admission prefill (`RequestMetrics::prefill_s`, outside TPOT) and
    /// post-eviction re-prefill (`IterCost::reprefill_s`, inside TPOT).
    fn prefill_charge(&self, tokens: usize) -> f64 {
        let chunks = tokens.div_ceil(self.backend.mini().prefill_chunk);
        chunks as f64 * self.cost.baseline_cost().total()
    }

    /// Bind request `id`'s committed span to the pool, attaching any
    /// cached prefix: trie-hit blocks are mapped copy-on-write (charging
    /// nothing against the free budget), cache-only LRU blocks are
    /// reclaimed when the fresh remainder does not fit, and the hit/miss
    /// telemetry is stamped. Returns the token count served from the
    /// cache — 0 without `--prefix-share`, where this is a plain
    /// [`KvBlockPool::admit`].
    fn attach_prefix(&mut self, id: u64, committed: &[u32]) -> Result<usize> {
        let Some(trie) = self.prefix.as_mut() else {
            self.pool.admit(id, committed.len())?;
            return Ok(0);
        };
        let shared = trie.lookup(committed);
        let total = committed.len().max(1).div_ceil(self.kv_block);
        let fresh = total - shared.len();
        if fresh > self.pool.free_blocks() {
            let need = fresh - self.pool.free_blocks();
            trie.reclaim(&mut self.pool, need, &shared)?;
        }
        self.pool.admit_shared(id, committed.len(), &shared)?;
        if shared.is_empty() {
            self.prefix_misses += 1;
        } else {
            self.prefix_hits += 1;
            self.prefix_hit_tokens += (shared.len() * self.kv_block) as u64;
        }
        Ok(shared.len() * self.kv_block)
    }

    /// Record the full blocks of a just-(re)prefilled span in the prefix
    /// trie, pinning any genuinely new block so the cached prefix survives
    /// this request's lifetime. No-op with sharing off.
    fn note_prefix(&mut self, committed: &[u32], id: u64) -> Result<()> {
        if self.prefix.is_none() {
            return Ok(());
        }
        let mapped = self.pool.mapped_blocks(id);
        let trie = self.prefix.as_mut().expect("checked above");
        trie.insert(committed, &mapped, &mut self.pool)
    }

    /// Re-admit parked (evicted) requests while free slots and pool blocks
    /// allow: re-prefill the committed context through the prefill path,
    /// replay the recorded decode history so a history-dependent backend
    /// lands in exactly its pre-eviction state, and charge the simulated
    /// recompute time to `pending_reprefill_s` (drained into the next
    /// committed iteration's `IterCost::reprefill_s`). Returns how many
    /// requests came back.
    fn readmit_parked(&mut self) -> Result<usize> {
        if self.admission.kind() == AdmissionKind::Edf && self.parked.len() > 1 {
            // EDF re-admits victims in deadline order (deadline = arrival +
            // the uniform SLO, so arrival order; stable on ties). Fcfs /
            // parked-first keep the legacy eviction-order FIFO bit-exactly.
            let mut v: Vec<SlotState> = std::mem::take(&mut self.parked).into();
            v.sort_by(|a, b| a.metrics.arrival_s.total_cmp(&b.metrics.arrival_s));
            self.parked = v.into();
        }
        let mut readmitted = 0usize;
        while !self.parked.is_empty() {
            let Some(slot) = self.slots.iter().position(|s| s.is_none()) else { break };
            let committed = {
                let s = self.parked.front().expect("checked non-empty");
                s.req.prompt.len() + s.history.iter().map(|h| h.advance).sum::<usize>()
            };
            // Sharing mode: the victim's cached prefix re-attaches for
            // free, so feasibility charges only the fresh remainder (and
            // can draw on trie-reclaimable blocks for it). `context` holds
            // exactly `committed + 1` tokens — the newest emitted token is
            // not yet pool-committed — so the committed span is a prefix
            // slice of it.
            let feasible = match &self.prefix {
                None => self.pool.can_admit(committed),
                Some(trie) => {
                    let s = self.parked.front().expect("checked non-empty");
                    let shared = trie.peek(&s.context[..committed]);
                    let total = committed.max(1).div_ceil(self.kv_block);
                    total - shared.len()
                        <= self.pool.free_blocks() + trie.reclaimable(&self.pool, &shared)
                }
            };
            if !feasible {
                break;
            }
            let mut state = self.parked.pop_front().expect("checked non-empty");
            let hit_tokens = self.attach_prefix(state.req.id, &state.context[..committed])?;
            self.backend.begin_slot(slot, &state.req)?;
            // Identical call sequence as the original admission + decode:
            // prefill the prompt, then replay every recorded verify span
            // and its committed advance. The sim backend's per-slot rng
            // process is a pure function of this sequence, so the slot
            // state after replay is bit-exact with the state at eviction —
            // the losslessness guarantee (rust/tests/preemption.rs).
            let guide0 = state.req.reference.first().copied();
            let first =
                self.backend.prefill_slot(slot, &state.req.prompt, guide0, state.req.eps)?;
            anyhow::ensure!(
                state.output.first() == Some(&first),
                "re-prefill diverged for request {}: first token {first} != {:?}",
                state.req.id,
                state.output.first(),
            );
            for h in &state.history {
                let span = VerifySpan {
                    slot,
                    tokens: h.tokens.clone(),
                    guides: h.guides.clone(),
                    eps: state.req.eps,
                };
                self.backend.step_batch(std::slice::from_ref(&span))?;
                self.backend.advance_slot(slot, h.advance);
            }
            // Re-attached blocks survived the eviction resident, so the
            // replay above reconstructs backend state without re-fetching
            // them: the trie pins are what make preemption cheaper under
            // sharing. Cache the re-prefilled span for the next victim.
            self.note_prefix(&state.context[..committed], state.req.id)?;
            // The honest price of the thrash: the same chunked prefill law
            // as admission, but over the whole committed span — minus the
            // cache-resident prefix — and billed on the decode clock
            // because decode-time pool pressure caused it.
            let charge = self.prefill_charge(committed - hit_tokens);
            self.pending_reprefill_s += charge;
            state.metrics.reprefill_s += charge;
            // The parked interval is out-of-service wait: queueing delay on
            // the virtual clock, same ledger as the pre-admission wait.
            state.metrics.queue_wait_s += (self.clock_s - state.parked_since).max(0.0);
            self.admit_seq += 1;
            state.admitted_seq = self.admit_seq;
            self.pending_readmissions += 1;
            readmitted += 1;
            // Shard-kill recovery bookkeeping: when the last kill victim
            // re-enters service, the outage window closes and its span
            // lands in `recovery_s` (time-to-recover telemetry).
            if !self.kill_victims.is_empty() {
                let id = state.req.id;
                self.kill_victims.retain(|&v| v != id);
                if self.kill_victims.is_empty() {
                    self.recovery_s += (self.clock_s - self.kill_started_s).max(0.0);
                }
            }
            self.slots[slot] = Some(state);
        }
        Ok(readmitted)
    }

    /// Speculatively draft iteration i+1 for every span of iteration i,
    /// fanning the per-slot scans across scoped threads while the backend
    /// verifies. Only pre-verify knowledge feeds the tasks (the in-flight
    /// drafts plus the full-acceptance prediction); broken assumptions
    /// surface as reconcile misses next iteration, never as wrong tokens.
    fn spec_draft_next(&mut self, planned: &[PlannedSpan], spans: &[VerifySpan]) {
        let max_seq = self.backend.mini().max_seq;
        let mut tasks = Vec::new();
        for (plan, span) in planned.iter().zip(spans) {
            let state = self.slots[plan.slot].as_ref().expect("planned slot is live");
            let drafts = &span.tokens[1..];
            if let Some(task) = plan_spec_task(
                plan.slot,
                &state.req,
                state.policy.as_ref(),
                &state.drafter,
                &state.context,
                state.output.len(),
                self.backend.cache_len_slot(plan.slot),
                max_seq,
                drafts,
                plan.k_chosen,
                state.last_iter_s,
                state.d_eps,
            ) {
                tasks.push(task);
            }
        }
        // Entries for slots that sat this iteration out (pool-deferred)
        // stay valid and are kept; planned slots consumed theirs in the
        // draft stage, so this extend cannot duplicate a slot.
        //
        // The persistent pool returns drafts in submission order — the
        // same order the serial fallback produces — so which path runs is
        // bit-invisible downstream (rust/docs/perf.md).
        let fresh = match &self.draft_pool {
            Some(pool) => pool.run(tasks),
            None => run_spec_tasks(tasks),
        };
        self.lookahead.extend(fresh);
    }

    /// Commit stage: batch-aware overlap-adjusted cost, per-request
    /// rejection sampling, marginal-utility policy feedback, telemetry.
    /// Returns the fused iteration cost (the caller stamps new lookahead
    /// entries with its verify window). `spec_wall_ns` is the host time
    /// the speculative next-iteration scans took inside the verify stage;
    /// it is charged to the overlap window, not the iteration wall.
    fn commit_stage(
        &mut self,
        spans: &[VerifySpan],
        planned: &[PlannedSpan],
        batch: &BatchStep,
        iter_wall: Instant,
        spec_wall_ns: u64,
        reconcile: ReconcileTally,
    ) -> Result<IterCost> {
        let drafter_kind = self.cfg.drafter;
        let total_tokens: usize = spans.iter().map(|s| s.tokens.len()).sum();
        let total_drafted: usize = planned.iter().map(|p| p.drafted).sum();
        let drafting_requests = planned.iter().filter(|p| p.drafted > 0).count();
        // Expert-parallel path: group the batch's deduped id sets by shard
        // and price the per-layer **max-over-shards** load plus the
        // all-to-all. Falls back to the unsharded charge at shards=1 or
        // without id attribution — bit-exact with the single-GPU model.
        let sharded = self.n_shards > 1 && !batch.expert_ids.is_empty();
        // Per-layer per-shard loads plus their per-layer maxes, computed
        // once — the same maxes price the fused step AND feed the
        // telemetry, so the charged and reported critical path cannot
        // diverge.
        let shard_loads: Option<(Vec<Vec<usize>>, Vec<usize>)> = if sharded {
            let loads = self.placement.shard_loads(&batch.expert_ids);
            let maxes: Vec<usize> =
                loads.iter().map(|l| l.iter().copied().max().unwrap_or(0)).collect();
            Some((loads, maxes))
        } else {
            None
        };
        // Fault/degradation cost routing. A straggler window, a dead
        // shard, or the controller's Halt expert budget all change the
        // *effective* per-layer verify load. The healthy pricing paths cap
        // the per-layer mean at physical bounds (div_ceil(E/S) per shard,
        // E unsharded) that hold for balanced placements — but a
        // survivors-only placement concentrates experts past div_ceil(E/S),
        // and a straggler's slowdown is not an expert count at all, so the
        // healthy caps would silently clip the degradation. Those
        // iterations are therefore priced through the cap-free
        // `degraded_sharded_batch_verify_cost` on engine-computed effective
        // loads: per layer, max over shards of min(load, budget) × scale.
        // Telemetry keeps reporting the *real* expert counts; only the
        // charge changes. Without expert attribution (dense model or the
        // sequential fallback) there is no per-layer load to scale, so the
        // healthy charge stands.
        let straggler = self.faults.straggler_scales(self.clock_s, self.n_shards);
        if straggler.is_some() && !self.straggler_active {
            self.fault_events += 1;
        }
        self.straggler_active = straggler.is_some();
        // Detector input (`--heal detect`): this iteration's observed
        // per-shard verify-time inflation. The simulated observable is the
        // straggler scale vector itself — exactly what a real engine would
        // estimate from per-shard verify timestamps — so the detector sees
        // the same signal, EWMA-smoothed, without a second timing channel.
        let heal_obs: Option<Vec<f64>> = if self.cfg.heal.is_on() && sharded {
            Some(straggler.clone().unwrap_or_else(|| vec![1.0; self.n_shards]))
        } else {
            None
        };
        let any_dead = self.dead_shards.iter().any(|&d| d);
        let expert_budget = if self.degrade == DegradeLevel::Halt {
            // MoE-Spec-style verify expert budget: under Halt, charge at
            // most top_k experts per layer per shard — the floor a plain
            // K=0 decode step of one request needs anyway.
            self.backend.mini().top_k.max(1)
        } else {
            usize::MAX
        };
        let degraded_pricing = straggler.is_some() || any_dead || expert_budget != usize::MAX;
        let eff_loads: Option<Vec<f64>> = if degraded_pricing {
            let scales = straggler.unwrap_or_else(|| vec![1.0; self.n_shards]);
            match &shard_loads {
                Some((loads, _)) => Some(
                    loads
                        .iter()
                        .map(|l| {
                            l.iter()
                                .enumerate()
                                .map(|(s, &c)| c.min(expert_budget) as f64 * scales[s])
                                .fold(0.0f64, f64::max)
                        })
                        .collect(),
                ),
                None if !batch.batch_unique_experts.is_empty() => Some(
                    batch
                        .batch_unique_experts
                        .iter()
                        .map(|&u| u.min(expert_budget) as f64 * scales[0])
                        .collect(),
                ),
                None => None,
            }
        } else {
            None
        };
        let cost_full = match (&eff_loads, &shard_loads) {
            (Some(eff), _) => self.cost.degraded_sharded_batch_verify_cost(
                eff,
                self.n_shards,
                total_tokens,
                total_drafted,
                drafting_requests,
                drafter_kind,
            ),
            (None, Some((_, maxes))) => self.cost.sharded_batch_verify_cost(
                maxes,
                self.n_shards,
                total_tokens,
                total_drafted,
                drafting_requests,
                drafter_kind,
            ),
            (None, None) => self.cost.batch_verify_cost(
                &batch.batch_unique_experts,
                total_tokens,
                total_drafted,
                drafting_requests,
                drafter_kind,
            ),
        };
        // Overlap rule: a lookahead hit's scan ran while an earlier fused
        // step verified (the per-slot scans run concurrently on threads),
        // so each hit's own draft cost is charged only where it exceeds
        // the verify window it drafted under — max(draft, verify)
        // semantics, per slot, priced with the same model as the fused
        // charge.
        let mut draft_hidden_s = 0.0f64;
        for p in planned.iter().filter(|p| p.pipelined) {
            let d = self.cost.draft_cost(p.drafted, drafter_kind);
            draft_hidden_s += d.min(p.hidden_window_s);
        }
        let draft_hidden_s = draft_hidden_s.min(cost_full.draft_s);
        // Drain the re-prefill time accrued by re-admissions since the last
        // committed iteration into this iteration's fused cost: the batch
        // clock (and every waiting request's latency view) honestly pays
        // for the preemption thrash.
        let reprefill_s = std::mem::take(&mut self.pending_reprefill_s);
        let mut cost = IterCost { draft_hidden_s, reprefill_s, ..cost_full };
        // Transient stall: the next scheduled stall whose trigger time
        // falls inside this iteration fires here. Each of its `retries`
        // failed attempts re-pays the verify pass plus an exponential
        // backoff sleep (base · 2^attempt), charged into the lint-audited
        // `stall_s` lane — cost conservation holds because the retries are
        // wasted *time*, not extra committed work. The cursor is monotone,
        // so each scheduled stall fires at most once, in order.
        let mut stall_retries = 0usize;
        let mut migrated_experts = 0usize;
        if let Some(&(t0, retries, base_s)) = self.stall_schedule.get(self.stalls_fired) {
            if t0 <= self.clock_s + cost.total() {
                let verify_s = cost.verify_s();
                let mut stall_s = 0.0;
                for attempt in 0..retries {
                    stall_s += verify_s + base_s * f64::powi(2.0, attempt as i32);
                }
                cost.stall_s = stall_s;
                stall_retries = retries as usize;
                self.stalls_fired += 1;
                self.fault_events += 1;
            }
        }
        // ---- Straggler detector + self-healing placement (--heal) -------
        // Hysteresis protocol (rust/docs/faults.md): the per-shard health
        // EWMA must sit above HEAL_HIGH for HEAL_CONFIRM consecutive
        // iterations before a shard is marked degraded, and back below
        // HEAL_LOW for HEAL_CONFIRM before it is unmarked — the dead band
        // between the thresholds means a shard hovering near either edge
        // never flaps the placement. Each mark/unmark edge triggers ONE
        // capacity-weighted rebuild (a degraded shard keeps capacity in
        // inverse proportion to its slowdown; all-healthy restores uniform
        // caps), and the expert weights that actually move are charged into
        // `IterCost::migration_s` — hidden under this iteration's draft
        // window when the pipeline overlaps it, paid in full serially.
        // Kill-recovery rebuilds stay out of this path: dead shards are the
        // fault plan's jurisdiction (`apply_fault_transitions`) and already
        // pay re-prefill + recovery time.
        if let Some(obs) = heal_obs {
            if !any_dead {
                let mut edge = false;
                for s in 0..self.n_shards {
                    self.health[s] = (1.0 - HEAL_ALPHA) * self.health[s] + HEAL_ALPHA * obs[s];
                    if self.health[s] > HEAL_HIGH {
                        self.hot_streak[s] += 1;
                        self.cool_streak[s] = 0;
                    } else if self.health[s] < HEAL_LOW {
                        self.cool_streak[s] += 1;
                        self.hot_streak[s] = 0;
                    } else {
                        self.hot_streak[s] = 0;
                        self.cool_streak[s] = 0;
                    }
                    if !self.healing[s] && self.hot_streak[s] >= HEAL_CONFIRM {
                        self.healing[s] = true;
                        edge = true;
                    } else if self.healing[s] && self.cool_streak[s] >= HEAL_CONFIRM {
                        self.healing[s] = false;
                        edge = true;
                    }
                }
                if edge {
                    let caps = self.heal_caps();
                    let old = std::mem::replace(
                        &mut self.placement,
                        self.coact.greedy_placement_capped(&caps),
                    );
                    migrated_experts = self.placement.moved_from(&old);
                    let raw = self.cost.migration_s(migrated_experts);
                    cost.migration_s = if self.cfg.pipeline {
                        (raw - cost.draft_s).max(0.0)
                    } else {
                        raw
                    };
                    self.heal_rebuilds += 1;
                    self.iters_since_placement = 0;
                }
            }
        }
        // Advance the virtual clock by the fused iteration, so finalize
        // stamps (`finish_s`, taken in the sweep after this commit) see the
        // post-iteration instant. Evictions stamped `parked_since` earlier
        // in this pass carry the PRE-iteration clock: a victim's queue wait
        // deliberately includes the iteration it was evicted during — it
        // spent that iteration out of service.
        self.clock_s += cost.total();

        let layer_mean = |v: &[usize]| -> f64 {
            if v.is_empty() {
                0.0
            } else {
                v.iter().sum::<usize>() as f64 / v.len() as f64
            }
        };

        // ---- Per-request rejection sampling + commit --------------------
        // `planned`, `spans`, and `batch.slots` are index-aligned.
        let n_active = spans.len();
        // Shared expert mass per layer for the marginal fairness floor
        // (each request is charged at least a 1/n_active slice of it).
        // Sharded, both the marginal and shared slices carry per-layer
        // max-over-shards counts; unsharded, shared is derived as
        // union − Σ marginals (zero under the no-dedup fallback, where
        // every fetch is marginal — so the floor is inert there).
        // Both count buffers are arena scratch (taken as locals so the
        // loop's `self` borrows stay disjoint) — no per-iteration or
        // per-span allocation, and no clone of the slot-step counts.
        let mut shared_scratch = std::mem::take(&mut self.arena.shared_scratch);
        if sharded {
            self.placement.max_loads_into(&batch.shared_expert_ids, &mut shared_scratch);
        } else {
            shared_scratch.clear();
            shared_scratch.extend(batch.batch_unique_experts.iter().enumerate().map(
                |(l, &u)| {
                    let excl: usize = batch
                        .slots
                        .iter()
                        .map(|s| s.marginal_unique_experts.get(l).copied().unwrap_or(0))
                        .sum();
                    u.saturating_sub(excl)
                },
            ));
        }
        let shared_counts: &[usize] = &shared_scratch;
        let mut marginal_scratch = std::mem::take(&mut self.arena.marginal_scratch);
        let mut emitted_total = 0usize;
        // Host wall of the verify+commit window, excluding the speculative
        // next-iteration scans that ran inside it (they belong to the
        // overlap budget, and on a genuinely async backend they would not
        // extend the iteration at all).
        let iter_wall_ns =
            (iter_wall.elapsed().as_nanos() as u64).saturating_sub(spec_wall_ns);
        for (i, plan) in planned.iter().enumerate() {
            let slot_step = &batch.slots[i];
            let span = &spans[i];
            debug_assert_eq!(plan.slot, slot_step.slot);
            let state = self.slots[plan.slot].as_mut().expect("planned slot is live");
            let drafts = &span.tokens[1..];
            let vr = greedy_verify(drafts, &slot_step.step.sampled);
            let (emitted, eos_hit) = truncate_at_eos(&vr.emitted, EOS);
            let advance = 1 + vr.accepted;
            self.pool.commit(state.req.id, advance)?;
            self.backend.advance_slot(plan.slot, advance);
            if self.cfg.eviction.is_on() || self.faults.has_kills() {
                // Record the step for the replay-based re-prefill an
                // eviction of this request would need (off mode records
                // nothing — no memory cost). A fault plan with shard kills
                // needs the history even with eviction off: kill victims
                // take the same lossless park/replay path.
                state.history.push(ReplayStep {
                    tokens: span.tokens.clone(),
                    guides: span.guides.clone(),
                    advance,
                });
            }
            state.drafter.ingest(&emitted)?;

            state.output.extend_from_slice(&emitted);
            state.context.extend_from_slice(&emitted);
            emitted_total += emitted.len();

            let mean_unique = layer_mean(&slot_step.step.unique_experts);
            let phase = state.policy.phase();
            // The policy observes the request's **marginal** share of the
            // fused cost (base amortized, experts at the request's
            // exclusive contribution) — the batched Cascade utility
            // signal — with its own draft slice discounted when it ran
            // hidden in the pipeline.
            let marginal_counts: &[usize] = if sharded {
                // Max-over-shards view of the request's exclusive experts:
                // its contribution to the expert-parallel critical path —
                // computed into reusable scratch, not a fresh Vec.
                self.placement
                    .max_loads_into(&slot_step.marginal_expert_ids, &mut marginal_scratch);
                &marginal_scratch
            } else {
                // Unsharded: borrow the arena-owned counts directly.
                &slot_step.marginal_unique_experts
            };
            let req_cost_full = self.cost.marginal_request_cost(
                marginal_counts,
                shared_counts,
                n_active,
                span.tokens.len(),
                plan.drafted,
                drafter_kind,
            );
            let req_hidden = if plan.pipelined {
                req_cost_full.draft_s.min(plan.hidden_window_s)
            } else {
                0.0
            };
            let req_cost = IterCost {
                draft_hidden_s: req_hidden,
                // The fused step's all-to-all is a batch-shared term.
                alltoall_s: cost.alltoall_s / n_active.max(1) as f64,
                ..req_cost_full
            };
            let obs = IterObs {
                k_chosen: plan.k_chosen,
                drafted: plan.drafted,
                accepted: vr.accepted,
                emitted: emitted.len(),
                iter_s: req_cost.total(),
            };
            state.last_iter_s = obs.iter_s;
            // The cost-aware victim ordering reads the same signal the
            // policy observes: marginal tokens-per-second of this request.
            state.last_utility = if obs.iter_s > 0.0 {
                obs.emitted as f64 / obs.iter_s
            } else {
                f64::INFINITY
            };
            state.policy.observe(&obs);
            state.metrics.iters.push(IterRecord {
                k_chosen: plan.k_chosen,
                drafted: plan.drafted,
                accepted: vr.accepted,
                emitted: emitted.len(),
                // Latency view: the full fused iteration this request
                // waited on (overlap-adjusted).
                cost,
                wall_ns: iter_wall_ns + if plan.pipelined { 0 } else { plan.draft_wall_ns },
                unique_experts: mean_unique,
                phase,
            });
            if eos_hit || state.output.len() >= state.req.max_new_tokens {
                state.finished = true;
            }
        }
        self.arena.shared_scratch = shared_scratch;
        self.arena.marginal_scratch = marginal_scratch;

        // Per-shard telemetry: mean per-layer load per shard, the critical
        // path (max shard), and imbalance = max / (union / shards) — 1.0 is
        // perfectly balanced. Unsharded iterations report the single-shard
        // view so shard analysis composes with the PR 2 overlap telemetry.
        let (shard_unique, max_shard_unique, shard_imbalance) = match &shard_loads {
            Some((loads, maxes)) if !loads.is_empty() => {
                let layers = loads.len() as f64;
                let mut per_shard = vec![0.0f64; self.n_shards];
                for l in loads {
                    for (s, &c) in l.iter().enumerate() {
                        per_shard[s] += c as f64;
                    }
                }
                for v in &mut per_shard {
                    *v /= layers;
                }
                let max_mean = maxes.iter().map(|&m| m as f64).sum::<f64>() / layers;
                let union_mean = layer_mean(&batch.batch_unique_experts);
                let imbalance = if union_mean > 0.0 {
                    max_mean / (union_mean / self.n_shards as f64)
                } else {
                    1.0
                };
                (per_shard, max_mean, imbalance)
            }
            _ => (Vec::new(), layer_mean(&batch.batch_unique_experts), 1.0),
        };

        // Feed the co-activation histogram and periodically rebuild the
        // placement — only under the co-activation strategy (balanced
        // never reads the histogram, so it skips the pair counting on the
        // hot path). A rebuild only affects *future* iterations' costs —
        // this iteration was priced under the placement it actually ran
        // with.
        if self.n_shards > 1 && !batch.expert_ids.is_empty() {
            // The healing rebuild packs hottest-first from this histogram,
            // so `--heal detect` feeds it even under the balanced strategy
            // (which never triggers periodic rebuilds of its own).
            if self.cfg.placement == PlacementKind::CoActivation || self.cfg.heal.is_on() {
                self.coact.observe(&batch.expert_ids);
            }
            if self.cfg.placement == PlacementKind::CoActivation {
                self.iters_since_placement += 1;
                if self.iters_since_placement >= PLACEMENT_REFRESH {
                    // A periodic refresh while shards are marked degraded
                    // must keep the healing caps, or it would silently
                    // migrate experts back onto the straggler between heal
                    // edges. Periodic refreshes stay migration-free either
                    // way — only detector edges charge `migration_s`.
                    self.placement = if self.healing.iter().any(|&h| h) {
                        self.coact.greedy_placement_capped(&self.heal_caps())
                    } else {
                        self.coact.greedy_placement(self.n_shards)
                    };
                    // Decay after each rebuild so the next one weighs recent
                    // routing over history (adapts to workload phase shifts).
                    self.coact.decay();
                    self.iters_since_placement = 0;
                }
            }
        }

        self.batch_iters.push(BatchIterRecord {
            n_active: spans.len(),
            total_tokens,
            total_drafted,
            emitted: emitted_total,
            cost,
            batch_unique_experts: layer_mean(&batch.batch_unique_experts),
            summed_unique_experts: layer_mean(&batch.summed_unique_experts),
            shard_unique,
            max_shard_unique,
            shard_imbalance,
            pipeline_hits: reconcile.hits,
            pipeline_misses: reconcile.misses,
            draft_recomputes: reconcile.recomputes,
            draft_wall_ns: planned.iter().map(|p| p.draft_wall_ns).sum(),
            draft_wall_hidden_ns: planned
                .iter()
                .filter(|p| p.pipelined)
                .map(|p| p.draft_wall_ns)
                .sum(),
            evictions: std::mem::take(&mut self.pending_evictions),
            readmissions: std::mem::take(&mut self.pending_readmissions),
            queue_depth: self.queue_depth_hint + self.parked.len(),
            stall_retries,
            degraded: self.degrade != DegradeLevel::Normal,
            migrated_experts,
        });
        Ok(cost)
    }

    /// Capacity caps of a healing placement rebuild: a healthy shard
    /// weighs 1.0, a degraded shard the inverse of its health inflation
    /// (a confirmed 4× straggler keeps ≈ a quarter of uniform capacity).
    /// All-healthy collapses to uniform caps, so the recovery rebuild
    /// restores the pre-fault packing shape.
    fn heal_caps(&self) -> Vec<usize> {
        let weights: Vec<f64> = (0..self.n_shards)
            .map(|s| if self.healing[s] { 1.0 / self.health[s].max(1.0) } else { 1.0 })
            .collect();
        capacity_caps(self.placement.n_experts(), &weights)
    }

    /// Move finished slots into the done list, freeing pool + backend
    /// state. Returns how many slots were finalized.
    fn sweep_finished(&mut self) -> usize {
        let mut swept = 0;
        for slot in 0..self.slots.len() {
            if self.slots[slot].as_ref().is_some_and(|s| s.finished) {
                if let Some(state) = self.slots[slot].take() {
                    self.finalize(slot, state);
                    swept += 1;
                }
            }
        }
        swept
    }

    /// Collect the run's metrics (requests ordered by id).
    pub fn finish(&mut self) -> BatchRunMetrics {
        let mut reqs = std::mem::take(&mut self.done);
        reqs.sort_by_key(|m| m.id);
        let mut run = RunMetrics::default();
        for m in reqs {
            run.push(m);
        }
        BatchRunMetrics {
            run,
            iters: std::mem::take(&mut self.batch_iters),
            max_batch: self.max_batch,
            n_shards: self.n_shards,
            clock_s: self.clock_s,
            idle_s: self.idle_s,
            sheds: self.sheds,
            fault_events: self.fault_events,
            recovery_s: self.recovery_s,
            heal_rebuilds: self.heal_rebuilds,
            prefix_hits: self.prefix_hits,
            prefix_misses: self.prefix_misses,
            prefix_hit_tokens: self.prefix_hit_tokens,
            shared_blocks_peak: self.pool.shared_blocks_peak,
            prefix_reclaimed_blocks: self.prefix.as_ref().map_or(0, |t| t.reclaimed_blocks),
        }
    }

    /// Serve an explicit request list to completion with continuous
    /// admission (tests and deterministic comparisons). Deliberately a
    /// separate drive loop from [`Scheduler::run_batched`], which owns
    /// token-budget clamping and grant accounting over an unbounded
    /// stream; changes to admission semantics must touch both.
    ///
    /// [`Scheduler::run_batched`]: crate::coordinator::scheduler::Scheduler::run_batched
    pub fn serve_all(&mut self, reqs: &[Request]) -> Result<BatchRunMetrics> {
        let mut queue: VecDeque<Request> = reqs.iter().cloned().collect();
        loop {
            // Parked-priority policies hold fresh admissions while eviction
            // victims wait (inert under the default fcfs).
            while self.has_free_slot() && !self.fresh_admission_blocked() {
                match queue.front() {
                    Some(req) if self.can_admit(req) => {
                        let Some(req) = queue.pop_front() else { break };
                        self.admit(req)?;
                    }
                    _ => break,
                }
            }
            self.set_queue_depth(queue.len());
            if !self.step_iteration()? {
                let Some(head) = queue.front() else { break };
                // Engine drained but the head request still does not fit:
                // with an empty engine the whole pool is free, so this can
                // only mean the request can never fit.
                anyhow::ensure!(
                    self.active() == 0 && self.can_admit(head),
                    "request {} cannot fit the KV pool",
                    head.id
                );
            }
        }
        Ok(self.finish())
    }

    /// Name for experiment tables.
    pub fn label(&self) -> String {
        let pipe = if self.cfg.pipeline { "+pipe" } else { "" };
        let shard = if self.n_shards > 1 {
            format!("+ep{}/{}", self.n_shards, self.cfg.placement.label())
        } else {
            String::new()
        };
        let ev = if self.cfg.eviction.is_on() {
            format!("+ev/{}", self.cfg.eviction.label())
        } else {
            String::new()
        };
        let faults = if self.faults.is_off() { "" } else { "+faults" };
        let ctl = if self.cfg.controller.is_on() { "+ctl" } else { "" };
        let heal = if self.cfg.heal.is_on() { "+heal" } else { "" };
        let px = if self.prefix.is_some() { "+px" } else { "" };
        format!(
            "{}/{}@b{}{pipe}{shard}{ev}{faults}{ctl}{heal}{px}",
            self.cfg.model,
            self.policy_kind.label(),
            self.max_batch
        )
    }
}
