//! Victim selection for KV-pool preemption.
//!
//! When a slot of the continuous-batching engine cannot reserve its full
//! planned verify span from the shared [`KvBlockPool`] (pool pressure is
//! all-or-nothing per slot when eviction is on — spans are never shrunk,
//! see rust/docs/preemption.md), the engine asks this module
//! which *other* in-flight request to evict (release its blocks, park it
//! for replay-based re-admission — see `coordinator::batch` and
//! rust/docs/preemption.md). Three pluggable policies
//! ([`EvictionKind`]):
//!
//! * **lru** — least-recently-admitted first. Re-admission re-stamps the
//!   admission clock, so a just-readmitted request is deprioritized,
//!   damping evict/readmit ping-pong.
//! * **most-lookahead** — the slot with the largest speculative
//!   reservation planned this iteration (biggest K). Speculation is the
//!   discretionary share of pool pressure; shedding the biggest speculator
//!   frees the most "optional" blocks per victim.
//! * **cost-aware** — the slot with the lowest observed marginal utility
//!   (emitted tokens per simulated second of its marginal iteration cost):
//!   the paper's utility lens applied to victim selection — preempt the
//!   request whose decoding is currently buying the fewest tokens per unit
//!   cost. Slots with no observation yet (just admitted) report infinite
//!   utility and are only evicted when every observed candidate is
//!   exhausted.
//!
//! Selection never returns the stuck slot itself, never a slot already at
//! the `max_preemptions_per_req` cap (a "pinned" request), and therefore
//! **never the sole active slot** — with one request in flight there are
//! no candidates, the engine defers instead, and (because a lone request
//! always fits a pool clamped to at least one full window) a sole slot can
//! never be stuck in the first place. All orderings are deterministic with
//! a slot-index tie-break, so serving stays reproducible.
//!
//! [`KvBlockPool`]: crate::kv::KvBlockPool

use crate::config::EvictionKind;

/// One eviction candidate: a live, not-yet-verifying slot other than the
/// stuck one. The engine builds these from its slot table + pool stats.
#[derive(Debug, Clone, Copy)]
pub struct VictimCandidate {
    pub slot: usize,
    pub req_id: u64,
    /// Monotone admission stamp (re-stamped on re-admission).
    pub admitted_seq: u64,
    /// Speculation length planned for this slot this iteration.
    pub planned_k: usize,
    /// KV blocks evicting the slot would actually free: its *exclusive*
    /// blocks (refcount 1 under prefix sharing — a block another slot or
    /// the trie also maps merely loses one reference). Without sharing
    /// every held block is exclusive, so this is simply the slot's block
    /// count.
    pub blocks: usize,
    /// Marginal utility last observed by the slot's policy feedback
    /// (tokens per simulated second); `f64::INFINITY` before the first
    /// observation.
    pub last_utility: f64,
    /// How many times this request was already preempted.
    pub preemptions: u32,
}

/// Pick the victim among `candidates` under `kind`, or `None` when no
/// candidate is evictable (empty list, or everyone is at the
/// `max_preemptions` cap). `EvictionKind::Off` never selects.
pub fn select_victim(
    kind: EvictionKind,
    candidates: &[VictimCandidate],
    max_preemptions: usize,
) -> Option<usize> {
    if !kind.is_on() {
        return None;
    }
    let eligible = candidates.iter().filter(|c| (c.preemptions as usize) < max_preemptions);
    let best = match kind {
        EvictionKind::Off => unreachable!("checked by is_on"),
        // Oldest admission stamp wins; tie-break on slot index for
        // determinism.
        EvictionKind::Lru => eligible.min_by_key(|c| (c.admitted_seq, c.slot)),
        // Largest planned speculation wins; among equals prefer the one
        // holding more blocks (frees more), then lowest slot index.
        EvictionKind::MostLookahead => {
            eligible.max_by_key(|c| (c.planned_k, c.blocks, std::cmp::Reverse(c.slot)))
        }
        // Lowest marginal utility wins. `total_cmp` gives a total order
        // (infinities sort last, so unobserved slots are a last resort);
        // tie-break on slot index.
        EvictionKind::CostAware => eligible.min_by(|a, b| {
            a.last_utility.total_cmp(&b.last_utility).then(a.slot.cmp(&b.slot))
        }),
    };
    best.map(|c| c.slot)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cand(slot: usize, seq: u64, k: usize, util: f64, pre: u32) -> VictimCandidate {
        VictimCandidate {
            slot,
            req_id: slot as u64 + 100,
            admitted_seq: seq,
            planned_k: k,
            blocks: 4,
            last_utility: util,
            preemptions: pre,
        }
    }

    #[test]
    fn off_and_empty_select_nothing() {
        let cands = [cand(0, 1, 3, 50.0, 0)];
        assert_eq!(select_victim(EvictionKind::Off, &cands, 8), None);
        for kind in [EvictionKind::Lru, EvictionKind::MostLookahead, EvictionKind::CostAware] {
            // No candidates — the sole-active-slot case: never evict.
            assert_eq!(select_victim(kind, &[], 8), None);
        }
    }

    #[test]
    fn lru_picks_oldest_admission() {
        let cands = [cand(0, 7, 1, 10.0, 0), cand(1, 2, 5, 90.0, 0), cand(2, 9, 3, 1.0, 0)];
        assert_eq!(select_victim(EvictionKind::Lru, &cands, 8), Some(1));
    }

    #[test]
    fn most_lookahead_picks_biggest_speculator() {
        let cands = [cand(0, 1, 2, 10.0, 0), cand(1, 2, 6, 90.0, 0), cand(2, 3, 4, 1.0, 0)];
        assert_eq!(select_victim(EvictionKind::MostLookahead, &cands, 8), Some(1));
        // Tie on K: the slot holding more blocks frees more.
        let mut a = cand(0, 1, 4, 10.0, 0);
        a.blocks = 2;
        let mut b = cand(1, 2, 4, 10.0, 0);
        b.blocks = 6;
        assert_eq!(select_victim(EvictionKind::MostLookahead, &[a, b], 8), Some(1));
    }

    #[test]
    fn cost_aware_picks_lowest_utility_and_spares_unobserved() {
        let cands = [
            cand(0, 1, 3, 40.0, 0),
            cand(1, 2, 3, 5.0, 0),
            cand(2, 3, 3, f64::INFINITY, 0), // just admitted, no signal yet
        ];
        assert_eq!(select_victim(EvictionKind::CostAware, &cands, 8), Some(1));
        // Only unobserved candidates left: they are still evictable (last
        // resort), deterministically by slot index.
        let fresh = [cand(4, 1, 3, f64::INFINITY, 0), cand(3, 2, 3, f64::INFINITY, 0)];
        assert_eq!(select_victim(EvictionKind::CostAware, &fresh, 8), Some(3));
    }

    #[test]
    fn preemption_cap_pins_requests() {
        let cands = [cand(0, 1, 3, 1.0, 2), cand(1, 2, 5, 99.0, 0)];
        // Cap 2: slot 0 is pinned, the worse-on-paper slot 1 is taken.
        for kind in [EvictionKind::Lru, EvictionKind::MostLookahead, EvictionKind::CostAware] {
            assert_eq!(select_victim(kind, &cands, 2), Some(1), "{kind:?}");
        }
        // Everyone pinned: no victim, the engine must defer (and possibly
        // surface the capped-deadlock error).
        let pinned = [cand(0, 1, 3, 1.0, 2), cand(1, 2, 5, 99.0, 2)];
        for kind in [EvictionKind::Lru, EvictionKind::MostLookahead, EvictionKind::CostAware] {
            assert_eq!(select_victim(kind, &pinned, 2), None, "{kind:?}");
        }
    }

    #[test]
    fn deterministic_tie_breaks() {
        let cands = [cand(2, 5, 3, 7.0, 0), cand(1, 5, 3, 7.0, 0)];
        assert_eq!(select_victim(EvictionKind::Lru, &cands, 8), Some(1));
        assert_eq!(select_victim(EvictionKind::CostAware, &cands, 8), Some(1));
        assert_eq!(select_victim(EvictionKind::MostLookahead, &cands, 8), Some(1));
    }
}
