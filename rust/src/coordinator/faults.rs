//! Deterministic fault injection & graceful degradation.
//!
//! A production engine is judged by what happens when the machine stops
//! being uniform and fault-free: a shard straggles, a backend step times
//! out, a device dies, the KV pool loses headroom to a neighbour. This
//! module supplies both halves of that story:
//!
//! * [`FaultPlan`] — a parsed schedule of faults pinned to the **virtual
//!   clock** (never the wall clock; the determinism lints ban host time
//!   from the serving path), so a fault scenario is as reproducible as a
//!   seed. Four fault kinds: per-shard *stragglers* (that shard's expert
//!   fetches run `factor`× slower for a window), transient *stalls* (a
//!   verify step fails and is retried under exponential backoff, the lost
//!   time charged to `IterCost::stall_s`), *shard kills* (placement is
//!   rebuilt on the survivors, KV state striped to the dead shard is
//!   recovered through the preemption subsystem's replay re-prefill), and
//!   *pool shrinks* (KV capacity drops to a fraction — a pressure spike).
//! * the degradation **controller** ([`degrade_level`]) — the system-level
//!   Cascade of the ROADMAP: fold KV reserve shortfall, queue depth, and
//!   EDF deadline slack into one pressure verdict that throttles K, then
//!   disables speculation and caps the verify expert budget
//!   (MoE-Spec-style, arXiv 2602.16052), while the scheduler sheds queued
//!   requests whose TTFT SLO is already unmeetable.
//!
//! The headline property is **losslessness under chaos**: faults and
//! degradation move *time and scheduling*, never token values — every
//! request that completes under any plan emits a stream bit-exact with the
//! fault-free run (rust/tests/chaos.rs; see rust/docs/faults.md for the
//! spec grammar and the recovery protocols).

use crate::rng::Rng;
use anyhow::{Context, Result};

/// A correlated fault domain: one physical host carrying several shards.
/// Declared in the `--faults` grammar as `host=<h>:shards=a,b,c`; a
/// subsequent `shard-kill`/`straggler` clause may then target `host=<h>`
/// and the parser expands it into one event per member shard — a
/// whole-host outage is several simultaneous shard faults, which is
/// exactly how correlated failures present to the engine.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FaultDomain {
    pub host: usize,
    pub shards: Vec<usize>,
}

/// One scheduled fault. Times are virtual-clock seconds.
#[derive(Debug, Clone, PartialEq)]
pub enum FaultEvent {
    /// Shard `shard`'s per-layer expert fetch runs `factor`× slower while
    /// `t0 <= t < t0 + dur_s` (a slow device: more time, not more experts).
    Straggler { t0: f64, dur_s: f64, shard: usize, factor: f64 },
    /// The first verify step whose window reaches `t0` fails `retries`
    /// times before succeeding; attempt `i` sleeps `base_s * 2^i` before
    /// retrying. The wasted verify windows plus the backoff sleeps are
    /// charged to `IterCost::stall_s`. Token output is unchanged — the
    /// retried step re-runs the identical computation.
    Stall { t0: f64, retries: u32, base_s: f64 },
    /// Shard `shard` is dead while `t0 <= t < t0 + dur_s`: its resident
    /// experts are re-placed on the survivors and every in-flight request
    /// whose KV is striped to it is evicted for replay re-admission.
    ShardKill { t0: f64, dur_s: f64, shard: usize },
    /// KV pool capacity is multiplied by `frac` while `t0 <= t < t0 + dur_s`
    /// (committed blocks are never revoked — the clamp happens in
    /// `KvBlockPool::set_capacity`).
    PoolShrink { t0: f64, dur_s: f64, frac: f64 },
}

impl FaultEvent {
    /// Start of the event's window (stalls are instants).
    pub fn t0(&self) -> f64 {
        match self {
            FaultEvent::Straggler { t0, .. }
            | FaultEvent::Stall { t0, .. }
            | FaultEvent::ShardKill { t0, .. }
            | FaultEvent::PoolShrink { t0, .. } => *t0,
        }
    }
}

/// A parsed, validated fault schedule. Constructed once per run from the
/// `--faults` spec; every query is a pure function of the virtual clock,
/// so identical (plan, seed) pairs replay identically.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct FaultPlan {
    pub events: Vec<FaultEvent>,
    /// Declared correlated fault domains (`host=<h>:shards=...`). Host-
    /// targeted clauses are expanded into per-shard events at parse time;
    /// the declarations are kept so [`FaultPlan::to_spec`] round-trips.
    pub domains: Vec<FaultDomain>,
}

/// Built-in plan names accepted by `--faults` and their expansions
/// (see [`FaultPlan::parse`]). `chaos` — one of everything — is the
/// canonical bench plan behind `BENCH_faults.json`.
pub const BUILTIN_PLANS: &[(&str, &str)] = &[
    ("straggler", "straggler@0.3+2:shard=1,factor=4"),
    ("stall", "stall@0.2:retries=2,base-ms=5;stall@1.2:retries=3,base-ms=5"),
    ("shard-kill", "shard-kill@0.4+1:shard=1"),
    ("pool-shrink", "pool-shrink@0.3+2:frac=0.5"),
    (
        "chaos",
        "straggler@0.3+2:shard=1,factor=4;stall@0.2:retries=2,base-ms=5;\
         shard-kill@0.6+1:shard=1;pool-shrink@0.4+2:frac=0.6",
    ),
];

impl FaultPlan {
    /// The empty plan (`--faults off`): injects nothing, queries are inert.
    pub fn off() -> Self {
        Self::default()
    }

    pub fn is_off(&self) -> bool {
        self.events.is_empty()
    }

    /// Parse a `--faults` spec: `off`, a builtin name (`straggler`,
    /// `stall`, `shard-kill`, `pool-shrink`, `chaos`), `file:<path>` (a
    /// file whose contents are a spec, `;`- or newline-separated, `#`
    /// comments allowed), or inline `;`-separated clauses:
    ///
    /// ```text
    /// host=<h>:shards=<a>,<b>,...              (correlated domain decl)
    /// straggler@<t0>+<dur>:shard=<s>,factor=<f>
    /// straggler@<t0>+<dur>:host=<h>,factor=<f>
    /// stall@<t0>:retries=<n>,base-ms=<ms>
    /// shard-kill@<t0>+<dur>:shard=<s>          (or host=<h>)
    /// pool-shrink@<t0>+<dur>:frac=<f>
    /// ```
    ///
    /// A `host=` declaration names a correlated fault domain; a later
    /// `straggler`/`shard-kill` clause targeting `host=<h>` expands into
    /// one event per member shard (the whole host slows or dies at once).
    /// Domains must be declared before use. Shard indices wrap modulo the
    /// run's shard count (like `ExpertPlacement::shard_of`), so one plan
    /// is valid under any topology. Events are sorted by `t0` on load.
    pub fn parse(spec: &str) -> Result<Self> {
        let spec = spec.trim();
        if spec.is_empty() || spec == "off" {
            return Ok(Self::off());
        }
        for (name, expansion) in BUILTIN_PLANS {
            if spec == *name {
                return Self::parse_clauses(expansion);
            }
        }
        if let Some(path) = spec.strip_prefix("file:") {
            anyhow::ensure!(!path.is_empty(), "fault spec needs a path (file:<path>)");
            let text = std::fs::read_to_string(path)
                .with_context(|| format!("reading fault plan {path}"))?;
            let clauses: Vec<&str> = text
                .lines()
                .map(|l| l.split('#').next().unwrap_or("").trim())
                .filter(|l| !l.is_empty())
                .collect();
            anyhow::ensure!(!clauses.is_empty(), "fault plan {path} is empty");
            return Self::parse_clauses(&clauses.join(";"));
        }
        Self::parse_clauses(spec)
    }

    fn parse_clauses(spec: &str) -> Result<Self> {
        let mut events = Vec::new();
        let mut domains: Vec<FaultDomain> = Vec::new();
        for clause in spec.split(';') {
            let clause: String = clause.split_whitespace().collect::<Vec<_>>().join("");
            if clause.is_empty() {
                continue;
            }
            if let Some(rest) = clause.strip_prefix("host=") {
                let d = parse_domain(rest).with_context(|| format!("domain clause {clause:?}"))?;
                anyhow::ensure!(
                    domains.iter().all(|x| x.host != d.host),
                    "host {} declared twice",
                    d.host
                );
                domains.push(d);
                continue;
            }
            events.extend(
                parse_clause(&clause, &domains)
                    .with_context(|| format!("fault clause {clause:?}"))?,
            );
        }
        anyhow::ensure!(!events.is_empty(), "fault spec has no events (use 'off' to disable)");
        events.sort_by(|a, b| a.t0().total_cmp(&b.t0()));
        Ok(Self { events, domains })
    }

    /// Canonical, re-parseable spec of this plan: domain declarations
    /// first, then every event as an inline clause (host-targeted clauses
    /// appear *resolved* — one per-shard clause each), `;`-joined. The
    /// round-trip law `parse(to_spec(p)) == p` is property-tested over the
    /// builtin plans, and `figure faults` prints this so chaos configs are
    /// copy-pasteable from output.
    pub fn to_spec(&self) -> String {
        if self.is_off() {
            return "off".to_string();
        }
        let mut clauses: Vec<String> = self
            .domains
            .iter()
            .map(|d| {
                let shards: Vec<String> = d.shards.iter().map(|s| s.to_string()).collect();
                format!("host={}:shards={}", d.host, shards.join(","))
            })
            .collect();
        for e in &self.events {
            clauses.push(match e {
                FaultEvent::Straggler { t0, dur_s, shard, factor } => {
                    format!("straggler@{t0}+{dur_s}:shard={shard},factor={factor}")
                }
                FaultEvent::Stall { t0, retries, base_s } => {
                    format!("stall@{t0}:retries={retries},base-ms={}", base_s * 1e3)
                }
                FaultEvent::ShardKill { t0, dur_s, shard } => {
                    format!("shard-kill@{t0}+{dur_s}:shard={shard}")
                }
                FaultEvent::PoolShrink { t0, dur_s, frac } => {
                    format!("pool-shrink@{t0}+{dur_s}:frac={frac}")
                }
            });
        }
        clauses.join(";")
    }

    /// Merge another plan's events into this one (stochastic-process
    /// events joining a scripted plan); the result stays `t0`-sorted.
    pub fn merged(mut self, other: FaultPlan) -> FaultPlan {
        self.events.extend(other.events);
        self.domains.extend(other.domains);
        self.events.sort_by(|a, b| a.t0().total_cmp(&b.t0()));
        self
    }

    /// Per-shard slowdown scales at clock `t`, or `None` when every shard
    /// is healthy (the bit-exact fast path). Overlapping stragglers on one
    /// shard multiply.
    pub fn straggler_scales(&self, t: f64, n_shards: usize) -> Option<Vec<f64>> {
        let mut scales: Option<Vec<f64>> = None;
        for e in &self.events {
            if let FaultEvent::Straggler { t0, dur_s, shard, factor } = e {
                if *t0 <= t && t < t0 + dur_s {
                    let s = scales.get_or_insert_with(|| vec![1.0; n_shards.max(1)]);
                    s[shard % n_shards.max(1)] *= factor;
                }
            }
        }
        scales
    }

    /// Dead-shard mask at clock `t` (`mask[s]` = shard `s` is down), or
    /// `None` when every shard is up. All-dead plans are clamped by the
    /// engine (the last survivor is never killed — a cluster with zero
    /// shards cannot make progress or recover).
    pub fn dead_shards(&self, t: f64, n_shards: usize) -> Option<Vec<bool>> {
        let mut mask: Option<Vec<bool>> = None;
        for e in &self.events {
            if let FaultEvent::ShardKill { t0, dur_s, shard } = e {
                if *t0 <= t && t < t0 + dur_s {
                    let m = mask.get_or_insert_with(|| vec![false; n_shards.max(1)]);
                    m[shard % n_shards.max(1)] = true;
                }
            }
        }
        mask
    }

    /// KV-pool capacity fraction at clock `t` (1.0 = full capacity).
    /// Overlapping shrinks take the tightest.
    pub fn pool_frac(&self, t: f64) -> f64 {
        let mut frac: f64 = 1.0;
        for e in &self.events {
            if let FaultEvent::PoolShrink { t0, dur_s, frac: f } = e {
                if *t0 <= t && t < t0 + dur_s {
                    frac = frac.min(*f);
                }
            }
        }
        frac
    }

    /// The stall schedule, sorted by `t0`: `(t0, retries, base_s)`. The
    /// engine consumes this with a monotone cursor (each stall fires on
    /// the first verify step whose window reaches its `t0`).
    pub fn stalls(&self) -> Vec<(f64, u32, f64)> {
        self.events
            .iter()
            .filter_map(|e| match e {
                FaultEvent::Stall { t0, retries, base_s } => Some((*t0, *retries, *base_s)),
                _ => None,
            })
            .collect()
    }

    /// Whether the plan can kill a shard — the engine must then record
    /// replay history even with `eviction = off`, so kill victims can be
    /// re-admitted losslessly.
    pub fn has_kills(&self) -> bool {
        self.events.iter().any(|e| matches!(e, FaultEvent::ShardKill { .. }))
    }

    /// Whether the plan can shrink the pool.
    pub fn has_pool_shrink(&self) -> bool {
        self.events.iter().any(|e| matches!(e, FaultEvent::PoolShrink { .. }))
    }
}

impl std::fmt::Display for FaultPlan {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.to_spec())
    }
}

/// Parse the tail of a `host=<h>:shards=a,b,c` domain declaration (the
/// `host=` prefix is already stripped).
fn parse_domain(rest: &str) -> Result<FaultDomain> {
    let (host, tail) = rest
        .split_once(':')
        .ok_or_else(|| anyhow::anyhow!("expected host=<h>:shards=a,b,c"))?;
    let host: usize = host.parse().with_context(|| format!("host {host:?}"))?;
    let list = tail
        .strip_prefix("shards=")
        .ok_or_else(|| anyhow::anyhow!("expected shards=a,b,c after host={host}:"))?;
    let mut shards = Vec::new();
    for s in list.split(',').filter(|s| !s.is_empty()) {
        let s: usize = s.parse().with_context(|| format!("shard {s:?}"))?;
        anyhow::ensure!(!shards.contains(&s), "shard {s} listed twice in host {host}");
        shards.push(s);
    }
    anyhow::ensure!(!shards.is_empty(), "host {host} declares no shards");
    Ok(FaultDomain { host, shards })
}

fn parse_clause(clause: &str, domains: &[FaultDomain]) -> Result<Vec<FaultEvent>> {
    let (kind, rest) = clause
        .split_once('@')
        .ok_or_else(|| anyhow::anyhow!("expected <kind>@<t0>[+<dur>][:k=v,...]"))?;
    let (when, params) = match rest.split_once(':') {
        Some((w, p)) => (w, p),
        None => (rest, ""),
    };
    let (t0, dur_s) = match when.split_once('+') {
        Some((a, b)) => (parse_f64(a, "t0")?, Some(parse_f64(b, "dur")?)),
        None => (parse_f64(when, "t0")?, None),
    };
    anyhow::ensure!(t0 >= 0.0, "t0 must be >= 0");
    if let Some(d) = dur_s {
        anyhow::ensure!(d > 0.0, "window duration must be > 0");
    }
    let mut shard = 0usize;
    let mut host: Option<usize> = None;
    let mut factor = 4.0f64;
    let mut retries = 2u32;
    let mut base_s = 5e-3f64;
    let mut frac = 0.5f64;
    for kv in params.split(',').filter(|s| !s.is_empty()) {
        let (k, v) = kv.split_once('=').ok_or_else(|| anyhow::anyhow!("bad param {kv:?}"))?;
        match k {
            "shard" => shard = v.parse().with_context(|| format!("shard {v:?}"))?,
            "host" => host = Some(v.parse().with_context(|| format!("host {v:?}"))?),
            "factor" => factor = parse_f64(v, "factor")?,
            "retries" => retries = v.parse().with_context(|| format!("retries {v:?}"))?,
            "base-ms" => base_s = parse_f64(v, "base-ms")? / 1e3,
            "frac" => frac = parse_f64(v, "frac")?,
            other => anyhow::bail!("unknown param {other:?} for {kind:?}"),
        }
    }
    // Resolve the target set: an explicit host expands to every shard of
    // the declared domain (the correlated-failure semantics), a bare
    // `shard=` stays a singleton.
    let targets: Vec<usize> = match host {
        Some(h) => domains
            .iter()
            .find(|d| d.host == h)
            .ok_or_else(|| {
                anyhow::anyhow!("host {h} not declared (add 'host={h}:shards=...' first)")
            })?
            .shards
            .clone(),
        None => vec![shard],
    };
    let dur = dur_s.unwrap_or(1.0);
    match kind {
        "straggler" => {
            anyhow::ensure!(factor >= 1.0 && factor.is_finite(), "factor must be >= 1");
            Ok(targets
                .into_iter()
                .map(|shard| FaultEvent::Straggler { t0, dur_s: dur, shard, factor })
                .collect())
        }
        "stall" => {
            anyhow::ensure!(host.is_none(), "stall is host-agnostic (no host= target)");
            anyhow::ensure!(dur_s.is_none(), "stall is an instant (no +dur window)");
            anyhow::ensure!(retries >= 1, "stall needs retries >= 1");
            anyhow::ensure!(base_s > 0.0 && base_s.is_finite(), "base-ms must be > 0");
            Ok(vec![FaultEvent::Stall { t0, retries, base_s }])
        }
        "shard-kill" => Ok(targets
            .into_iter()
            .map(|shard| FaultEvent::ShardKill { t0, dur_s: dur, shard })
            .collect()),
        "pool-shrink" => {
            anyhow::ensure!(host.is_none(), "pool-shrink is host-agnostic (no host= target)");
            anyhow::ensure!(frac > 0.0 && frac <= 1.0, "frac must be in (0, 1]");
            Ok(vec![FaultEvent::PoolShrink { t0, dur_s: dur, frac }])
        }
        other => anyhow::bail!(
            "unknown fault kind {other:?} (want straggler|stall|shard-kill|pool-shrink)"
        ),
    }
}

fn parse_f64(s: &str, what: &str) -> Result<f64> {
    let v: f64 = s.parse().with_context(|| format!("{what} {s:?}"))?;
    anyhow::ensure!(v.is_finite(), "{what} must be finite");
    Ok(v)
}

// ---------------------------------------------------------------------------
// Stochastic fault processes
// ---------------------------------------------------------------------------

/// Which fault kind an MTBF process emits.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ProcessKind {
    Straggler,
    Stall,
    ShardKill,
    PoolShrink,
}

impl ProcessKind {
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "straggler" => Ok(Self::Straggler),
            "stall" => Ok(Self::Stall),
            "shard-kill" => Ok(Self::ShardKill),
            "pool-shrink" => Ok(Self::PoolShrink),
            other => anyhow::bail!(
                "unknown process kind {other:?} (want straggler|stall|shard-kill|pool-shrink)"
            ),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Self::Straggler => "straggler",
            Self::Stall => "stall",
            Self::ShardKill => "shard-kill",
            Self::PoolShrink => "pool-shrink",
        }
    }
}

/// Cap on events one process materializes — sustained unreliability, not an
/// unbounded schedule (a pathological `mtbf=1e-9` must still terminate).
pub const MAX_PROCESS_EVENTS: usize = 64;

/// An MTBF/MTTR-driven stochastic fault process (`--fault-process`):
/// instead of hand-scripted `t0`s, fault onsets arrive as a Poisson process
/// with exponential inter-arrival of mean `mtbf_s`, and each outage lasts
/// an exponential duration of mean `mttr_s` — the standard renewal model of
/// sustained unreliability. The schedule is drawn **once up front** from
/// the crate PRNG ([`FaultProcess::materialize`]) and pinned to the virtual
/// clock, so a (spec, seed) pair replays bit-identically: same fault
/// schedule, same token streams, on any machine.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultProcess {
    /// Mean time between fault onsets, virtual-clock seconds (> 0).
    pub mtbf_s: f64,
    /// Mean time to repair — mean outage window (> 0; ignored by the
    /// `stall` kind, whose events are instants).
    pub mttr_s: f64,
    /// Fault kind every event of this process carries.
    pub kind: ProcessKind,
}

impl FaultProcess {
    /// Parse a `--fault-process` spec: `off` (or empty) disables, else
    /// comma-joined `mtbf=<s>,mttr=<s>,kind=<k>`. `mtbf` is required;
    /// `mttr` defaults to 0.5 s and `kind` to `straggler`.
    pub fn parse(spec: &str) -> Result<Option<Self>> {
        let spec: String = spec.split_whitespace().collect::<Vec<_>>().join("");
        if spec.is_empty() || spec == "off" {
            return Ok(None);
        }
        let mut mtbf_s: Option<f64> = None;
        let mut mttr_s = 0.5f64;
        let mut kind = ProcessKind::Straggler;
        for kv in spec.split(',').filter(|s| !s.is_empty()) {
            let (k, v) = kv.split_once('=').ok_or_else(|| anyhow::anyhow!("bad param {kv:?}"))?;
            match k {
                "mtbf" => mtbf_s = Some(parse_f64(v, "mtbf")?),
                "mttr" => mttr_s = parse_f64(v, "mttr")?,
                "kind" => kind = ProcessKind::parse(v)?,
                other => anyhow::bail!("unknown param {other:?} for fault process"),
            }
        }
        let mtbf_s = mtbf_s.ok_or_else(|| {
            anyhow::anyhow!("fault process needs mtbf=<s> (mean time between faults)")
        })?;
        anyhow::ensure!(mtbf_s > 0.0, "mtbf must be > 0");
        anyhow::ensure!(mttr_s > 0.0, "mttr must be > 0");
        Ok(Some(Self { mtbf_s, mttr_s, kind }))
    }

    /// Canonical re-parseable spec (`parse(label(p)) == Some(p)`).
    pub fn label(&self) -> String {
        format!("mtbf={},mttr={},kind={}", self.mtbf_s, self.mttr_s, self.kind.name())
    }

    /// Draw the concrete fault schedule: exponential inter-arrivals of mean
    /// `mtbf_s` walk the virtual clock from 0 until `horizon_s` (or
    /// [`MAX_PROCESS_EVENTS`]); each onset gets an exponential outage of
    /// mean `mttr_s` (clamped to ≥ 1 ms so windows are never degenerate)
    /// and a uniformly random target shard. The PRNG stream is forked off
    /// the run seed with a dedicated tag, so the schedule is independent of
    /// every other consumer of the seed — adding a fault process cannot
    /// perturb token sampling.
    pub fn materialize(&self, seed: u64, n_shards: usize, horizon_s: f64) -> FaultPlan {
        let mut rng = Rng::new(seed).fork(0xFA17);
        let mut events = Vec::new();
        let mut t = 0.0f64;
        while events.len() < MAX_PROCESS_EVENTS {
            // Exponential inter-arrival: -mtbf * ln(1 - U), U in [0, 1).
            t += -self.mtbf_s * (1.0 - rng.f64()).ln();
            if t >= horizon_s {
                break;
            }
            let dur = (-self.mttr_s * (1.0 - rng.f64()).ln()).max(1e-3);
            let shard = rng.below(n_shards.max(1));
            events.push(match self.kind {
                ProcessKind::Straggler => {
                    FaultEvent::Straggler { t0: t, dur_s: dur, shard, factor: 4.0 }
                }
                ProcessKind::Stall => FaultEvent::Stall { t0: t, retries: 2, base_s: 5e-3 },
                ProcessKind::ShardKill => FaultEvent::ShardKill { t0: t, dur_s: dur, shard },
                ProcessKind::PoolShrink => {
                    FaultEvent::PoolShrink { t0: t, dur_s: dur, frac: 0.5 }
                }
            });
        }
        FaultPlan { events, domains: Vec::new() }
    }
}

// ---------------------------------------------------------------------------
// Degradation controller
// ---------------------------------------------------------------------------

/// The pressure facts the controller folds, sampled once per iteration at
/// plan time. All on the virtual clock / current pool state — nothing here
/// can desynchronize two identically-seeded runs.
#[derive(Debug, Clone, Copy)]
pub struct PressureSignal {
    /// KV pool block utilization in [0, 1] (committed + lookahead).
    pub pool_util: f64,
    /// Blocks the deferred slots are short of (`KvBlockPool::reserve_shortfall`
    /// summed over last iteration's deferrals); 0 when everything fit.
    pub shortfall_blocks: usize,
    /// Waiting requests: arrived-but-unadmitted plus parked victims.
    pub queue_depth: usize,
    /// Engine batch width (queue depth is judged relative to it).
    pub max_batch: usize,
    /// Per-request TTFT SLO in seconds; 0 = no SLO configured.
    pub slo_s: f64,
    /// Tightest EDF slack among waiting requests, `deadline − now`
    /// (`f64::INFINITY` when nothing waits or no SLO is set).
    pub min_slack_s: f64,
}

/// The controller's verdict for one iteration.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum DegradeLevel {
    /// No pressure: the speculation policy's K stands.
    Normal,
    /// Moderate pressure: cap K at [`THROTTLE_K_CAP`] — lookahead blocks
    /// are exactly the blocks admission is starved for.
    Throttle,
    /// High pressure: disable speculation (K = 0) and cap the verify
    /// expert budget at the no-speculation activation (MoE-Spec-style).
    Halt,
}

/// K cap under [`DegradeLevel::Throttle`].
pub const THROTTLE_K_CAP: usize = 2;

/// Fold the pressure signal into a verdict. Thresholds are deliberately
/// simple step functions of deterministic inputs (documented in
/// rust/docs/faults.md):
///
/// * **Halt** when the pool is effectively exhausted (reserve shortfall
///   with > 90% utilization), or the tightest waiting deadline has less
///   than 25% of the SLO left;
/// * **Throttle** when the pool runs hot (> 75% utilization), any
///   shortfall was observed, the queue backs up past 2× the batch width,
///   or the tightest waiting deadline is inside 75% of the SLO;
/// * **Normal** otherwise — and the engine's planning path is bit-exact
///   with the controller off.
pub fn degrade_level(sig: &PressureSignal) -> DegradeLevel {
    let slack_frac = if sig.slo_s > 0.0 && sig.min_slack_s.is_finite() {
        sig.min_slack_s / sig.slo_s
    } else {
        f64::INFINITY
    };
    if (sig.shortfall_blocks > 0 && sig.pool_util > 0.90) || slack_frac < 0.25 {
        return DegradeLevel::Halt;
    }
    if sig.pool_util > 0.75
        || sig.shortfall_blocks > 0
        || sig.queue_depth > 2 * sig.max_batch.max(1)
        || slack_frac < 0.75
    {
        return DegradeLevel::Throttle;
    }
    DegradeLevel::Normal
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn off_specs_are_inert() {
        for spec in ["off", "", "  off  "] {
            let p = FaultPlan::parse(spec).unwrap();
            assert!(p.is_off());
            assert!(p.straggler_scales(1.0, 2).is_none());
            assert!(p.dead_shards(1.0, 2).is_none());
            assert_eq!(p.pool_frac(1.0), 1.0);
            assert!(p.stalls().is_empty());
            assert!(!p.has_kills());
        }
    }

    #[test]
    fn inline_clauses_parse_and_sort() {
        let p = FaultPlan::parse(
            "stall@2:retries=3,base-ms=10; straggler@0.5+1:shard=1,factor=2.5",
        )
        .unwrap();
        assert_eq!(p.events.len(), 2);
        // Sorted by t0: straggler first.
        assert_eq!(
            p.events[0],
            FaultEvent::Straggler { t0: 0.5, dur_s: 1.0, shard: 1, factor: 2.5 }
        );
        assert_eq!(p.events[1], FaultEvent::Stall { t0: 2.0, retries: 3, base_s: 0.01 });
        assert_eq!(p.stalls(), vec![(2.0, 3, 0.01)]);
    }

    #[test]
    fn builtins_parse_and_chaos_has_everything() {
        for (name, _) in BUILTIN_PLANS {
            let p = FaultPlan::parse(name).unwrap();
            assert!(!p.is_off(), "builtin {name} is empty");
        }
        let chaos = FaultPlan::parse("chaos").unwrap();
        assert!(chaos.has_kills());
        assert!(chaos.has_pool_shrink());
        assert!(!chaos.stalls().is_empty());
        assert!(chaos.straggler_scales(0.4, 2).is_some());
    }

    #[test]
    fn windows_are_half_open_and_scales_multiply() {
        let p = FaultPlan::parse("straggler@1+2:shard=0,factor=3").unwrap();
        assert!(p.straggler_scales(0.999, 2).is_none());
        assert_eq!(p.straggler_scales(1.0, 2).unwrap(), vec![3.0, 1.0]);
        assert_eq!(p.straggler_scales(2.999, 2).unwrap(), vec![3.0, 1.0]);
        assert!(p.straggler_scales(3.0, 2).is_none(), "window end is exclusive");
        // Overlapping stragglers on one shard compound.
        let q = FaultPlan::parse("straggler@0+2:shard=0,factor=2;straggler@1+2:shard=0,factor=3")
            .unwrap();
        assert_eq!(q.straggler_scales(1.5, 2).unwrap(), vec![6.0, 1.0]);
    }

    #[test]
    fn shard_indices_wrap_modulo_topology() {
        let p = FaultPlan::parse("shard-kill@0+1:shard=3").unwrap();
        // 2-shard run: shard 3 wraps to shard 1.
        assert_eq!(p.dead_shards(0.5, 2).unwrap(), vec![false, true]);
        // 1-shard run: wraps to the only shard (the engine clamps the
        // last-survivor case; the plan just reports the mask).
        assert_eq!(p.dead_shards(0.5, 1).unwrap(), vec![true]);
        assert!(p.dead_shards(1.5, 2).is_none(), "recovered after the window");
    }

    #[test]
    fn pool_frac_takes_the_tightest_active_shrink() {
        let p = FaultPlan::parse("pool-shrink@0+2:frac=0.6;pool-shrink@1+2:frac=0.3").unwrap();
        assert_eq!(p.pool_frac(0.5), 0.6);
        assert_eq!(p.pool_frac(1.5), 0.3);
        assert_eq!(p.pool_frac(2.5), 0.3);
        assert_eq!(p.pool_frac(3.5), 1.0);
        assert!(p.has_pool_shrink());
    }

    #[test]
    fn file_specs_roundtrip() {
        let path = std::env::temp_dir().join("cascade_fault_plan_test.txt");
        std::fs::write(
            &path,
            "# canonical two-fault plan\nstraggler@0.5+1:shard=1,factor=2\n\nstall@1:retries=1,base-ms=2 # inline comment\n",
        )
        .unwrap();
        let p = FaultPlan::parse(&format!("file:{}", path.display())).unwrap();
        assert_eq!(p.events.len(), 2);
        assert_eq!(p.stalls(), vec![(1.0, 1, 2e-3)]);
        let _ = std::fs::remove_file(&path);
        assert!(FaultPlan::parse("file:").is_err());
        assert!(FaultPlan::parse("file:/nonexistent/plan.txt").is_err());
    }

    #[test]
    fn host_clauses_expand_to_every_member_shard() {
        let p = FaultPlan::parse(
            "host=0:shards=0,2; straggler@0.5+1:host=0,factor=3; shard-kill@2+1:host=0",
        )
        .unwrap();
        assert_eq!(p.domains, vec![FaultDomain { host: 0, shards: vec![0, 2] }]);
        // One event per member shard, same window.
        assert_eq!(p.straggler_scales(0.5, 3).unwrap(), vec![3.0, 1.0, 3.0]);
        assert_eq!(p.dead_shards(2.5, 3).unwrap(), vec![true, false, true]);
        // A bare shard= clause still works alongside domains.
        let q = FaultPlan::parse("host=1:shards=1,2;shard-kill@0+1:shard=0").unwrap();
        assert_eq!(q.dead_shards(0.5, 3).unwrap(), vec![true, false, false]);
    }

    #[test]
    fn domain_errors_are_caught() {
        for bad in [
            "host=0:shards=0,1;host=0:shards=2",      // duplicate host
            "host=0:shards=1,1",                      // duplicate shard in domain
            "host=0:shards=",                         // empty domain
            "host=0",                                 // missing shards
            "straggler@0+1:host=3,factor=2",          // undeclared host
            "host=0:shards=0,1;stall@1:host=0",       // stall is host-agnostic
            "host=0:shards=0,1;pool-shrink@0+1:host=0", // pool-shrink too
        ] {
            assert!(FaultPlan::parse(bad).is_err(), "accepted bad spec {bad:?}");
        }
    }

    #[test]
    fn to_spec_roundtrips_parse() {
        // parse ∘ to_spec = id over every builtin plan …
        for (name, _) in BUILTIN_PLANS {
            let p = FaultPlan::parse(name).unwrap();
            let back = FaultPlan::parse(&p.to_spec()).unwrap();
            assert_eq!(p, back, "builtin {name} failed to round-trip: {}", p.to_spec());
        }
        // … over a domain plan (host-targeted clauses come back resolved
        // per-shard, which re-parses to the same event set) …
        let p = FaultPlan::parse("host=2:shards=0,1;shard-kill@1+0.5:host=2").unwrap();
        let back = FaultPlan::parse(&p.to_spec()).unwrap();
        assert_eq!(p, back, "{}", p.to_spec());
        // … over a materialized stochastic schedule, and Display agrees.
        let proc = FaultProcess::parse("mtbf=0.7,mttr=0.3,kind=straggler").unwrap().unwrap();
        let plan = proc.materialize(42, 2, 10.0);
        assert_eq!(plan, FaultPlan::parse(&plan.to_spec()).unwrap(), "{}", plan.to_spec());
        assert_eq!(format!("{plan}"), plan.to_spec());
        assert_eq!(FaultPlan::off().to_spec(), "off");
    }

    #[test]
    fn merged_plans_stay_sorted() {
        let a = FaultPlan::parse("stall@2:retries=1,base-ms=5").unwrap();
        let b = FaultPlan::parse("straggler@0.5+1:shard=0,factor=2").unwrap();
        let m = a.merged(b);
        assert_eq!(m.events.len(), 2);
        assert!(m.events[0].t0() <= m.events[1].t0());
    }

    #[test]
    fn fault_process_parses_and_is_seed_deterministic() {
        assert!(FaultProcess::parse("off").unwrap().is_none());
        assert!(FaultProcess::parse("").unwrap().is_none());
        let p = FaultProcess::parse("mtbf=2,mttr=0.4,kind=shard-kill").unwrap().unwrap();
        assert_eq!(p.kind, ProcessKind::ShardKill);
        assert_eq!(FaultProcess::parse(&p.label()).unwrap(), Some(p), "label round-trips");
        // Defaults: mttr 0.5, kind straggler.
        let d = FaultProcess::parse("mtbf=1").unwrap().unwrap();
        assert_eq!((d.mttr_s, d.kind), (0.5, ProcessKind::Straggler));
        // Same seed ⇒ identical schedule; different seed ⇒ different.
        let s1 = d.materialize(7, 4, 20.0);
        assert_eq!(s1, d.materialize(7, 4, 20.0));
        assert_ne!(s1, d.materialize(8, 4, 20.0));
        assert!(!s1.events.is_empty(), "mtbf=1 over 20 s should fire");
        assert!(s1.events.len() <= MAX_PROCESS_EVENTS);
        assert!(s1.events.iter().all(|e| e.t0() < 20.0));
        assert!(s1.events.windows(2).all(|w| w[0].t0() <= w[1].t0()), "sorted by construction");
        // A pathological rate is bounded by the event cap.
        assert_eq!(d.materialize(7, 4, 1e12).events.len(), MAX_PROCESS_EVENTS);
        // Bad specs.
        for bad in ["mtbf=0", "mttr=1", "mtbf=1,mttr=0", "mtbf=1,kind=quake", "mtbf=1,zap=2"] {
            assert!(FaultProcess::parse(bad).is_err(), "accepted bad spec {bad:?}");
        }
    }

    #[test]
    fn bad_specs_are_errors() {
        for bad in [
            "straggler@-1+2:shard=0",       // negative t0
            "straggler@0+0:shard=0",        // zero window
            "straggler@0+1:factor=0.5",     // speedup, not a fault
            "stall@1+2:retries=2",          // stalls are instants
            "stall@1:retries=0",            // no retries = no fault
            "pool-shrink@0+1:frac=0",       // empty pool can't hold state
            "pool-shrink@0+1:frac=1.5",     // growth is not a fault
            "quake@0+1:shard=0",            // unknown kind
            "straggler@0+1:zap=3",          // unknown param
            "straggler",                    // missing @t0
        ] {
            assert!(FaultPlan::parse(bad).is_err(), "accepted bad spec {bad:?}");
        }
    }

    fn calm() -> PressureSignal {
        PressureSignal {
            pool_util: 0.2,
            shortfall_blocks: 0,
            queue_depth: 0,
            max_batch: 4,
            slo_s: 0.0,
            min_slack_s: f64::INFINITY,
        }
    }

    #[test]
    fn controller_is_monotone_in_pressure() {
        assert_eq!(degrade_level(&calm()), DegradeLevel::Normal);
        // Hot pool throttles.
        let hot = PressureSignal { pool_util: 0.8, ..calm() };
        assert_eq!(degrade_level(&hot), DegradeLevel::Throttle);
        // Any observed shortfall throttles; with an exhausted pool it halts.
        let short = PressureSignal { shortfall_blocks: 3, ..calm() };
        assert_eq!(degrade_level(&short), DegradeLevel::Throttle);
        let exhausted = PressureSignal { shortfall_blocks: 3, pool_util: 0.95, ..calm() };
        assert_eq!(degrade_level(&exhausted), DegradeLevel::Halt);
        // Deep queues throttle.
        let backed_up = PressureSignal { queue_depth: 9, ..calm() };
        assert_eq!(degrade_level(&backed_up), DegradeLevel::Throttle);
        assert_eq!(
            degrade_level(&PressureSignal { queue_depth: 8, ..calm() }),
            DegradeLevel::Normal,
            "threshold is strictly more than 2x batch"
        );
        // Deadline slack: tight throttles, critical halts.
        let tight = PressureSignal { slo_s: 1.0, min_slack_s: 0.5, ..calm() };
        assert_eq!(degrade_level(&tight), DegradeLevel::Throttle);
        let critical = PressureSignal { slo_s: 1.0, min_slack_s: 0.1, ..calm() };
        assert_eq!(degrade_level(&critical), DegradeLevel::Halt);
        // No SLO => slack never triggers.
        let no_slo = PressureSignal { slo_s: 0.0, min_slack_s: 0.0, ..calm() };
        assert_eq!(degrade_level(&no_slo), DegradeLevel::Normal);
        assert!(DegradeLevel::Normal < DegradeLevel::Throttle);
        assert!(DegradeLevel::Throttle < DegradeLevel::Halt);
    }
}
