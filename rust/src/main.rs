//! `cascade` — the serving coordinator CLI.
//!
//! Subcommands:
//!   list-models                       show the model zoo + artifact status
//!   serve   --model M --task T ...    serve a request stream, print summary
//!                                     (--batch N enables continuous batching,
//!                                      --pipeline on overlaps draft with verify)
//!   sweep                             batch=1 vs batch=4 comparison table
//!   bench                             serial vs pipelined TPOT benchmark
//!                                     (emits BENCH_pipeline.json)
//!   figure  <id|all> [--backend B]    regenerate a paper table/figure
//!   golden-check                      validate artifacts against JAX goldens
//!
//! Arg parsing is in-tree (the offline vendor set has no clap); see
//! `Args` below for the tiny flag grammar.

use anyhow::{bail, Context, Result};
use cascade::config::{ControllerKind, EngineConfig};
use cascade::coordinator::batch::BatchEngine;
use cascade::coordinator::engine::Engine;
use cascade::coordinator::scheduler::{Budget, Scheduler};
use cascade::cost::ExpertBitmap;
use cascade::experiments::{self, BackendKind, ExpCtx};
use cascade::models::{default_artifacts_dir, Registry};
use cascade::spec::policy::PolicyKind;
use cascade::util::table::{ms, Table};
use cascade::workload::{RequestStream, Workload};
use std::collections::{BTreeMap, BTreeSet};

/// Tiny `--flag value` parser: positional args + string flags.
struct Args {
    positional: Vec<String>,
    flags: BTreeMap<String, String>,
}

impl Args {
    fn parse(argv: &[String]) -> Result<Self> {
        let mut positional = Vec::new();
        let mut flags = BTreeMap::new();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(name) = a.strip_prefix("--") {
                let val = argv
                    .get(i + 1)
                    .with_context(|| format!("flag --{name} needs a value"))?;
                flags.insert(name.to_string(), val.clone());
                i += 2;
            } else {
                positional.push(a.clone());
                i += 1;
            }
        }
        Ok(Self { positional, flags })
    }

    fn get(&self, name: &str, default: &str) -> String {
        self.flags.get(name).cloned().unwrap_or_else(|| default.to_string())
    }

    fn get_usize(&self, name: &str, default: usize) -> Result<usize> {
        match self.flags.get(name) {
            Some(v) => v.parse().with_context(|| format!("--{name} {v:?} not a number")),
            None => Ok(default),
        }
    }

    fn get_f64(&self, name: &str, default: f64) -> Result<f64> {
        match self.flags.get(name) {
            Some(v) => v.parse().with_context(|| format!("--{name} {v:?} not a number")),
            None => Ok(default),
        }
    }
}

fn usage() -> ! {
    eprintln!(
        "cascade — utility-driven speculative decoding for MoE serving

USAGE:
  cascade list-models
  cascade golden-check
  cascade serve  [--model mixtral] [--task code|math|extract|code+math|math+extract|code+extract|all-3]
                 [--policy k0..k7|cascade|ablation0..3] [--drafter ngram|eagle]
                 [--tokens 400] [--backend real|sim] [--seed N] [--batch 1]
                 [--pipeline on|off] [--shards 1] [--placement balanced|coactivation]
                 [--kv-pool-blocks N] [--eviction off|lru|most-lookahead|cost-aware]
                 [--prefix-share P]
                 [--max-preemptions 8] [--ngram-max 4] [--ngram-min 1]
                 [--guide-strength 48] [--max-new 200]
                 [--arrivals closed|poisson|bursty|trace:<path>] [--rate R]
                 [--admission fcfs|parked-first|edf]
                 [--slo-ms MS | --slo-ms code=250,math=400,default=300]
                 [--faults off|straggler|stall|shard-kill|pool-shrink|chaos|file:<path>|<spec>]
                 [--fault-process off|mtbf=<s>,mttr=<s>,kind=<k>]
                 [--heal off|detect]
                 [--controller off|adaptive] [--capture-trace out.jsonl]
  cascade sweep  [--tokens 300] [--out-dir results] [--shards 1,2,4] [--rate 0.5,1,2]
                 (continuous-batching comparison: batch=1 vs 4, static-K vs Cascade;
                  --shards runs the expert-parallel K-vs-shards axis instead;
                  --rate runs the open-loop Poisson saturation sweep instead)
  cascade bench  [--tokens 2000] [--quick 1] [--out BENCH_pipeline.json]
                 [--out-sharding BENCH_sharding.json]
                 [--out-preemption BENCH_preemption.json]
                 [--out-arrivals BENCH_arrivals.json]
                 [--out-faults BENCH_faults.json]
                 [--out-saturation BENCH_saturation.json]
                 [--out-prefix BENCH_prefix.json]
                 [--out-simspeed BENCH_simspeed.json]
                 (serial vs pipelined TPOT/bubble-fraction table at batch 1/4,
                  sharded TPOT at shards 1/2/4 x batch 1/4, eviction-policy
                  throughput under a half-working-set pool, per-admission
                  p95 queueing delay under bursty arrivals, chaos-plan
                  goodput with the degradation controller on vs off, a
                  goodput-vs-offered-load rate sweep under a stochastic
                  MTBF fault process, and TTFT vs prefix-sharing template
                  share ratio, as JSON for CI)
  cascade figure <table1|fig1c|fig4|fig5|fig6|fig7|fig8|fig13|fig15|fig16|fig17|fig18|sens|batch|pipeline|sharding|preemption|prefix|arrivals|faults|all>
                 [--backend real|sim] [--tokens 300] [--out-dir results]
  cascade diff-trace <healthy.jsonl> <chaos.jsonl>
                 (compare completed token streams of two --capture-trace
                  files request-by-request; reports the first divergence
                  point of each and exits 1 on any token mismatch)

  --batch N > 1 serves through the continuous-batching engine: one fused
  verify step per iteration over all in-flight requests, a shared KV block
  pool, and expert fetches de-duplicated across the batch (sim backend;
  the real backend is single-slot and clamps to batch=1).

  --pipeline on drafts iteration i+1 while iteration i verifies (paper
  Fig. 14's worker pipeline): drafting cost is hidden under the verify
  window wherever the acceptance prediction holds (bubbles are recomputed
  and reported). Token output is bit-identical to serial for a fixed K
  schedule (static-K policies); Cascade observes the cheaper pipelined
  cost and may legitimately choose different K.

  --shards N > 1 prices the fused verify under expert parallelism: the
  routed-expert term becomes the max over per-shard deduped expert loads
  plus an all-to-all term, with --placement choosing how experts map to
  shards (balanced round-robin, or an online co-activation-aware packer).
  Sharding moves cost only, never tokens (sim backend; see
  rust/docs/sharding.md).

  --kv-pool-blocks N oversubscribes the shared KV pool (0 = the
  uncontended aggregate worst case); --eviction picks the preemption
  policy for it: off keeps the legacy shrink/defer behavior and surfaces
  a deadlock error when nothing can progress, lru / most-lookahead /
  cost-aware evict a victim instead (its blocks are released, its
  committed context re-prefilled on re-admission, the recompute charged
  into TPOT). An evicted-then-readmitted request's token stream is
  bit-exact with an uncontended run (see rust/docs/preemption.md).

  --prefix-share P > 0 turns on copy-on-write prefix sharing: KV blocks
  are refcounted, committed prompts are published to a prefix trie, and a
  new request whose prompt prefix is resident maps the shared blocks
  instead of re-prefilling them (only the novel suffix is charged on the
  virtual clock, so TTFT collapses for hits). The request stream switches
  to a template-heavy shape: every prompt opens with a 128-token preamble,
  shared with probability P. P = 0 (the default) disables both and is
  bit-exact with pre-sharing builds. See rust/docs/prefix_cache.md.

  --arrivals opens the serving loop: requests arrive on the engine's
  virtual clock (poisson / bursty at --rate req/s, or a JSONL trace) and
  wait in an admission queue, so TTFT / queueing delay / E2E tails and
  slot idleness become observable. --admission orders that queue (fcfs,
  parked-first = eviction victims re-admit ahead of fresh arrivals, edf =
  earliest deadline first against --slo-ms). closed + fcfs (the default)
  is bit-exact with the legacy closed-loop scheduler (see
  rust/docs/serving.md).

  --faults injects a deterministic fault plan on the virtual clock:
  per-shard stragglers, transient verify stalls with backoff retries,
  shard kills (placement rebuilt on survivors, victim KV replayed back),
  KV-pool shrinks, and correlated host domains (host=<h>:shards=a,b —
  one event takes out every shard of the host). --fault-process layers a
  stochastic MTBF/MTTR renewal process on top: exponential inter-arrival
  and repair times drawn seed-deterministically, materialized into the
  same plan grammar. --controller adaptive turns on graceful
  degradation: pool/queue/deadline pressure throttles K, then disables
  speculation and caps the verify expert budget, while arrivals whose
  --slo-ms deadline already passed are shed before admission. --slo-ms
  also accepts per-task classes (code=250,math=400,default=300): EDF
  deadlines, shedding, and goodput become per-class. --heal detect turns
  on straggler-aware self-healing placement: a per-shard health EWMA
  with hysteresis detects slow shards and rebuilds the expert placement
  capacity-weighted away from them (migration priced, hidden under the
  draft window when pipelined), migrating back after recovery. Completed
  requests stay bit-exact with the fault-free run; --capture-trace
  records the run's arrivals plus its completed token streams (replay
  skips the stream lines; diff-trace compares them). Defaults (off /
  off / off) are bit-exact with pre-fault builds. See rust/docs/faults.md.
"
    );
    std::process::exit(2)
}

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.is_empty() {
        usage();
    }
    let cmd = argv[0].clone();
    let args = Args::parse(&argv[1..])?;
    match cmd.as_str() {
        "list-models" => list_models(),
        "golden-check" => golden_check(),
        "serve" => serve(&args),
        "sweep" => sweep(&args),
        "bench" => bench(&args),
        "figure" => figure(&args),
        "diff-trace" => diff_trace(&args),
        _ => usage(),
    }
}

/// Load the completed-stream records (`{"stream": id, ...}` lines) from a
/// `--capture-trace` file: request id -> (task, output tokens). Arrival
/// lines are skipped, mirroring the replayer's filter.
fn load_streams(path: &str) -> Result<BTreeMap<usize, (String, Vec<u64>)>> {
    let text =
        std::fs::read_to_string(path).with_context(|| format!("reading trace {path}"))?;
    let mut streams = BTreeMap::new();
    for (lineno, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        let v = cascade::util::json::parse(line)
            .with_context(|| format!("{path}:{}: not a JSON record", lineno + 1))?;
        let Some(id) = v.get("stream") else { continue };
        let id = id.as_usize()?;
        let task = v.req("task")?.as_str()?.to_string();
        let tokens = v
            .req("tokens")?
            .as_arr()?
            .iter()
            .map(|t| t.as_f64().map(|f| f as u64))
            .collect::<Result<Vec<_>>>()?;
        streams.insert(id, (task, tokens));
    }
    Ok(streams)
}

/// `diff-trace <healthy> <chaos>`: compare the completed token streams of
/// two captured runs request-by-request and report the first divergence
/// point of each. The losslessness contract says completed streams are
/// bit-exact under faults — this is the field tool for checking it. Exits
/// 1 when any shared stream's tokens diverge (requests missing from one
/// side — shed or unfinished under chaos — are reported but are not a
/// token divergence).
fn diff_trace(args: &Args) -> Result<()> {
    let (Some(healthy), Some(chaos)) = (args.positional.first(), args.positional.get(1))
    else {
        bail!("usage: cascade diff-trace <healthy.jsonl> <chaos.jsonl> (two --capture-trace files)");
    };
    let a = load_streams(healthy)?;
    let b = load_streams(chaos)?;
    anyhow::ensure!(!a.is_empty(), "{healthy} holds no completed-stream records");
    anyhow::ensure!(!b.is_empty(), "{chaos} holds no completed-stream records");
    let mut t = Table::new(
        format!("diff-trace: {healthy} vs {chaos}"),
        &["stream", "task", "tokens A", "tokens B", "first divergence"],
    );
    let mut diverged = 0usize;
    let mut missing = 0usize;
    let ids: Vec<usize> = a.keys().chain(b.keys()).copied().collect();
    let mut seen = Vec::new();
    for id in ids {
        if seen.contains(&id) {
            continue;
        }
        seen.push(id);
        match (a.get(&id), b.get(&id)) {
            (Some((task, ta)), Some((_, tb))) => {
                let common = ta.iter().zip(tb.iter()).take_while(|(x, y)| x == y).count();
                let verdict = if ta == tb {
                    "identical".to_string()
                } else {
                    diverged += 1;
                    if common < ta.len().min(tb.len()) {
                        format!("token {common}: {} vs {}", ta[common], tb[common])
                    } else {
                        format!("length (prefix of {common} matches)")
                    }
                };
                t.row(vec![
                    id.to_string(),
                    task.clone(),
                    ta.len().to_string(),
                    tb.len().to_string(),
                    verdict,
                ]);
            }
            (Some((task, ta)), None) => {
                missing += 1;
                t.row(vec![
                    id.to_string(),
                    task.clone(),
                    ta.len().to_string(),
                    "-".into(),
                    "missing in B (shed or unfinished)".into(),
                ]);
            }
            (None, Some((task, tb))) => {
                missing += 1;
                t.row(vec![
                    id.to_string(),
                    task.clone(),
                    "-".into(),
                    tb.len().to_string(),
                    "missing in A (shed or unfinished)".into(),
                ]);
            }
            (None, None) => unreachable!("id came from one of the maps"),
        }
    }
    println!("{}", t.render());
    println!(
        "diff-trace: {} shared stream(s), {diverged} divergent, {missing} one-sided",
        seen.len() - missing
    );
    if diverged > 0 {
        std::process::exit(1);
    }
    Ok(())
}

/// The manifest when artifacts are built, else the builtin zoo (enough for
/// the sim backend; the real backend errors cleanly without artifacts). A
/// present-but-invalid manifest is a real error, not a fallback.
fn registry() -> Result<Registry> {
    Registry::try_load_or_builtin(default_artifacts_dir())
}

fn list_models() -> Result<()> {
    let reg = registry()?;
    let mut t = Table::new(
        "model zoo",
        &["model", "mirrors", "experts", "top-k", "shared", "affinity", "variants", "impl"],
    );
    for name in reg.model_names() {
        let m = reg.model(&name)?;
        t.row(vec![
            name.clone(),
            m.mini.mirrors.clone(),
            m.mini.n_experts.to_string(),
            m.mini.top_k.to_string(),
            m.mini.n_shared.to_string(),
            format!("{:.2}", m.mini.affinity),
            m.token_variants().len().to_string(),
            reg.manifest.models[&name].impl_name.clone(),
        ]);
    }
    println!("{}", t.render());
    Ok(())
}

/// Execute each model's golden input through the PJRT path and compare
/// against the eager-JAX outputs recorded in the manifest.
fn golden_check() -> Result<()> {
    let reg = registry()?;
    let client = xla::PjRtClient::cpu().map_err(|e| anyhow::anyhow!("{e:?}"))?;
    let mut ok = 0;
    for name in reg.model_names() {
        let mut rt = cascade::runtime::ModelRuntime::with_client(&reg, &name, client.clone())?;
        let golden = rt.model.golden.clone();
        let mut state = rt.fresh_state();
        let out = rt.step(&mut state, &golden.tokens)?;
        let head = out.logits_row(0)[..8].to_vec();
        for (i, (a, b)) in head.iter().zip(&golden.logits_row0_head).enumerate() {
            if (a - b).abs() > 1e-3 * (1.0 + b.abs()) {
                bail!("{name}: logits[0][{i}] {a} != golden {b}");
            }
        }
        let argmax: Vec<usize> = (0..golden.t)
            .map(|i| cascade::sampling::argmax(out.logits_row(i)) as usize)
            .collect();
        if argmax != golden.argmax {
            bail!("{name}: argmax {argmax:?} != golden {:?}", golden.argmax);
        }
        println!("  {name}: OK (logits head + argmax match eager JAX)");
        ok += 1;
    }
    println!("golden-check: {ok} models verified");
    Ok(())
}

fn serve(args: &Args) -> Result<()> {
    let reg = registry()?;
    let model = args.get("model", "mixtral");
    let task = args.get("task", "code");
    let workload =
        Workload::by_name(&task).with_context(|| format!("unknown task {task:?}"))?;
    let policy = PolicyKind::parse(&args.get("policy", "cascade"))?;
    let backend = BackendKind::parse(&args.get("backend", "real"))?;
    let tokens = args.get_usize("tokens", 400)?;
    let seed = args.get_usize("seed", 0xCA5CADE)? as u64;
    let batch = args.get_usize("batch", 1)?;
    let drafter = match args.get("drafter", "ngram").as_str() {
        "ngram" => cascade::config::DrafterKind::Ngram,
        "eagle" => cascade::config::DrafterKind::EagleLite,
        other => bail!("unknown drafter {other:?}"),
    };
    let pipeline = match args.get("pipeline", "off").as_str() {
        "on" => true,
        "off" => false,
        other => bail!("unknown --pipeline {other:?} (want on|off)"),
    };
    let shards = args.get_usize("shards", 1)?;
    let placement = cascade::config::PlacementKind::parse(&args.get("placement", "balanced"))?;
    let kv_pool_blocks = args.get_usize("kv-pool-blocks", 0)?;
    let eviction = cascade::config::EvictionKind::parse(&args.get("eviction", "off"))?;
    let prefix_share = args.get_f64("prefix-share", 0.0)?;
    anyhow::ensure!(
        (0.0..=1.0).contains(&prefix_share),
        "--prefix-share must lie in [0, 1]"
    );
    anyhow::ensure!(
        prefix_share == 0.0 || !workload.tasks.contains(&cascade::workload::Task::Extract),
        "--prefix-share needs a code/math workload: extract's long passages leave no \
         room for the {}-token shared preamble within the model's context window",
        cascade::workload::PREFIX_PREAMBLE_TOKENS
    );
    let max_preemptions = args.get_usize("max-preemptions", 8)?;
    let rate = args.get_f64("rate", 0.0)?;
    let arrival_kind =
        cascade::workload::arrivals::ArrivalKind::parse(&args.get("arrivals", "closed"), rate)?;
    let admission = cascade::config::AdmissionKind::parse(&args.get("admission", "fcfs"))?;
    // --slo-ms takes either a single catch-all number of milliseconds, or
    // per-task classes (`code=250,math=400,default=300`): a `default=`
    // entry becomes the catch-all `slo_s`, the rest become per-class
    // deadlines resolved by `EngineConfig::slo_for`.
    let slo_spec = args.get("slo-ms", "0");
    let (slo_s, slo_classes) = if slo_spec.contains('=') {
        let parsed = cascade::config::SloClasses::parse(&slo_spec)
            .with_context(|| format!("--slo-ms {slo_spec:?}"))?;
        let mut catch_all = 0.0;
        let mut classes = Vec::new();
        for (name, s) in parsed.classes {
            if name == "default" {
                catch_all = s;
            } else {
                classes.push((name, s));
            }
        }
        (catch_all, cascade::config::SloClasses { classes })
    } else {
        let s = args.get_f64("slo-ms", 0.0)? / 1e3;
        anyhow::ensure!(s >= 0.0, "--slo-ms cannot be negative");
        (s, cascade::config::SloClasses::default())
    };
    let has_slo = slo_s > 0.0 || !slo_classes.is_empty();
    // Fault plan + degradation controller (rust/docs/faults.md). The spec
    // is validated here, at the CLI boundary — the engine constructor is
    // infallible and treats an unparseable spec as fault-free.
    let faults_spec = args.get("faults", "off");
    let fault_plan = cascade::coordinator::faults::FaultPlan::parse(&faults_spec)
        .with_context(|| format!("--faults {faults_spec:?}"))?;
    // Stochastic fault process (MTBF/MTTR): validated here, materialized
    // seed-deterministically inside the engine and merged into the plan.
    let fault_process = args.get("fault-process", "off");
    let fault_process_on = cascade::coordinator::faults::FaultProcess::parse(&fault_process)
        .with_context(|| format!("--fault-process {fault_process:?}"))?
        .is_some();
    let heal = cascade::config::HealKind::parse(&args.get("heal", "off"))?;
    let controller = cascade::config::ControllerKind::parse(&args.get("controller", "off"))?;
    let capture_trace = args.get("capture-trace", "");
    let d = EngineConfig::default();
    let ngram_max = args.get_usize("ngram-max", d.ngram_max)?;
    let ngram_min = args.get_usize("ngram-min", d.ngram_min)?;
    anyhow::ensure!(
        ngram_min >= 1 && ngram_min <= ngram_max,
        "--ngram-min must satisfy 1 <= min <= max ({ngram_min} vs {ngram_max})"
    );
    let guide_strength = args.get_f64("guide-strength", d.guide_strength as f64)? as f32;
    let max_new_tokens = args.get_usize("max-new", d.max_new_tokens)?;
    anyhow::ensure!(max_new_tokens >= 1, "--max-new must be at least 1");
    let backend_name = match backend {
        BackendKind::Real => "real",
        BackendKind::Sim => "sim",
    };
    if shards > 1 && backend == BackendKind::Real {
        eprintln!(
            "note: sharded expert cost needs expert-id attribution (sim backend); \
             the real backend serves with the unsharded cost model"
        );
    }
    // Sharded serving lands on the batched engine even at batch=1 (it owns
    // the placement and reproduces the single-request engine token-for-
    // token) — but only where the backend can attribute expert ids; the
    // real backend keeps its unsharded single-request path. A constrained
    // pool / eviction policy also belongs to the batched engine (the shared
    // pool is its admission surface).
    let use_batch_engine = batch > 1
        || (shards > 1 && backend == BackendKind::Sim)
        || kv_pool_blocks > 0
        || eviction.is_on()
        || prefix_share > 0.0
        || !arrival_kind.is_closed()
        || admission != cascade::config::AdmissionKind::Fcfs
        || has_slo
        || !fault_plan.is_off()
        || fault_process_on
        || heal.is_on()
        || controller.is_on()
        || !capture_trace.is_empty();
    let cfg = EngineConfig {
        model: model.clone(),
        drafter,
        ngram_max,
        ngram_min,
        guide_strength,
        max_new_tokens,
        seed,
        max_batch: batch,
        pipeline,
        shards,
        placement,
        kv_pool_blocks,
        eviction,
        prefix_share,
        max_preemptions_per_req: max_preemptions,
        admission,
        slo_s,
        slo_classes: slo_classes.clone(),
        faults: faults_spec.clone(),
        fault_process: fault_process.clone(),
        heal,
        controller,
        ..EngineConfig::default()
    };
    let budget = Budget { max_tokens: tokens, max_requests: 10_000 };
    // --prefix-share 0 (the default) keeps the plain preamble-free stream:
    // bit-exact with builds that predate prefix sharing. Any positive
    // share switches to the template-heavy stream AND enables the engine's
    // prefix trie via cfg.prefix_share.
    let stream = if prefix_share > 0.0 {
        RequestStream::with_prefix_templates(
            workload.clone(),
            seed,
            cfg.max_new_tokens,
            prefix_share,
        )
    } else {
        RequestStream::new(workload.clone(), seed, cfg.max_new_tokens)
    };
    let mut sched = if arrival_kind.is_closed() {
        Scheduler::new(stream, budget)
    } else {
        let arrivals =
            cascade::workload::arrivals::ArrivalProcess::new(arrival_kind.clone(), stream, seed)?;
        Scheduler::with_arrivals(arrivals, budget)
    };
    if !capture_trace.is_empty() {
        sched.capture_trace(&capture_trace);
    }

    if use_batch_engine {
        // Continuous-batching path: fused verify steps, shared KV pool,
        // batch-deduplicated expert cost (and expert-parallel pricing at
        // --shards > 1).
        let mut engine = match backend {
            BackendKind::Sim => BatchEngine::sim(&reg, cfg, policy.clone())?,
            BackendKind::Real => BatchEngine::real(&reg, cfg, policy.clone())?,
        };
        if engine.max_batch() < batch {
            eprintln!(
                "note: {backend_name} backend supports {} slot(s); batch clamped from {batch}",
                engine.max_batch()
            );
        }
        let t0 = std::time::Instant::now(); // lint:allow(wall-clock): host wall-time table row only
        let m = match sched.run_batched(&mut engine) {
            Ok(m) => m,
            // A structured engine dead-end (KV pool deadlock) is not a
            // crash: salvage the partial run — completed requests and
            // iteration telemetry are intact in the engine — and exit with
            // a distinct code so harnesses can tell "stuck" from "broken".
            Err(err) => match err.downcast_ref::<cascade::coordinator::EngineError>() {
                Some(engine_err) => {
                    eprintln!("error: {engine_err}");
                    let partial = engine.finish();
                    eprintln!(
                        "partial run before deadlock: {} request(s) completed, \
                         {} iteration(s), {} output tokens, clock {:.3}s",
                        partial.run.requests.len(),
                        partial.iters.len(),
                        partial.run.total_tokens(),
                        partial.clock_s
                    );
                    std::process::exit(3);
                }
                None => return Err(err),
            },
        };
        let wall = t0.elapsed();

        let mut t = Table::new(
            format!(
                "serve: {model} + {task} + {} (batch {} on {backend_name} backend)",
                policy.label(),
                engine.max_batch()
            ),
            &["metric", "value"],
        );
        t.row(vec!["requests".into(), m.run.requests.len().to_string()]);
        t.row(vec!["output tokens".into(), m.run.total_tokens().to_string()]);
        t.row(vec!["TPOT (batch clock)".into(), ms(m.tpot_s())]);
        t.row(vec![
            "throughput (sim)".into(),
            format!("{:.1} tok/s", 1.0 / m.tpot_s()),
        ]);
        t.row(vec!["mean ETR".into(), format!("{:.2} tok/iter", m.run.mean_etr())]);
        t.row(vec![
            "verify span tokens/iter".into(),
            format!("{:.2}", m.mean_span_tokens()),
        ]);
        t.row(vec![
            "draft share of span".into(),
            format!("{:.1}%", 100.0 * m.draft_share()),
        ]);
        t.row(vec!["batch occupancy".into(), format!("{:.2}", m.mean_occupancy())]);
        t.row(vec![
            "unique experts/iter (dedup)".into(),
            format!("{:.1}", m.mean_batch_unique()),
        ]);
        t.row(vec![
            "unique experts/iter (summed)".into(),
            format!("{:.1}", m.mean_summed_unique()),
        ]);
        t.row(vec![
            "cross-request overlap saved".into(),
            format!("{:.1}%", 100.0 * m.overlap_savings()),
        ]);
        // Always printed so sharded and unsharded runs of the same command
        // can be compared side by side.
        t.row(vec!["mean verify/iter".into(), format!("{:.2}ms", 1e3 * m.mean_verify_s())]);
        if m.n_shards > 1 {
            t.row(vec![
                "expert-parallel shards".into(),
                format!("{} ({})", m.n_shards, placement.label()),
            ]);
            t.row(vec![
                "max-shard experts/iter".into(),
                format!("{:.1}", m.mean_max_shard_unique()),
            ]);
            t.row(vec![
                "per-shard experts/iter".into(),
                m.per_shard_mean_unique()
                    .iter()
                    .map(|u| format!("{u:.1}"))
                    .collect::<Vec<_>>()
                    .join(" / "),
            ]);
            t.row(vec![
                "shard imbalance (max/mean)".into(),
                format!("{:.2}", m.mean_shard_imbalance()),
            ]);
            t.row(vec![
                "all-to-all share of verify".into(),
                format!("{:.1}%", 100.0 * m.alltoall_share()),
            ]);
        }
        if eviction.is_on() || kv_pool_blocks > 0 {
            t.row(vec![
                "kv pool".into(),
                format!(
                    "{} blocks, eviction={}",
                    engine.pool.total_blocks(),
                    eviction.label()
                ),
            ]);
            t.row(vec![
                "evictions / readmissions".into(),
                format!("{} / {}", m.evictions(), m.readmissions()),
            ]);
            t.row(vec![
                "re-prefill (sim)".into(),
                format!("{:.2}ms", 1e3 * m.reprefill_s()),
            ]);
            t.row(vec![
                "thrash fraction".into(),
                format!("{:.1}%", 100.0 * m.thrash_fraction()),
            ]);
        }
        if prefix_share > 0.0 {
            t.row(vec![
                "prefix sharing".into(),
                format!(
                    "share {prefix_share:.2}, {} templates",
                    cascade::workload::PREFIX_TEMPLATE_COUNT
                ),
            ]);
            t.row(vec![
                "prefix_hits / prefix_misses".into(),
                format!(
                    "{} / {} ({:.0}% hit rate)",
                    m.prefix_hits,
                    m.prefix_misses,
                    100.0 * m.prefix_hit_rate()
                ),
            ]);
            t.row(vec!["prefix_hit_tokens".into(), m.prefix_hit_tokens.to_string()]);
            t.row(vec!["shared_blocks_peak".into(), m.shared_blocks_peak.to_string()]);
            t.row(vec![
                "prefix_reclaimed_blocks".into(),
                m.prefix_reclaimed_blocks.to_string(),
            ]);
        }
        t.row(vec!["admission".into(), admission.label().into()]);
        if !engine.faults().is_off() || fault_process_on || controller.is_on() {
            t.row(vec!["faults".into(), faults_spec.clone()]);
            if fault_process_on {
                t.row(vec!["fault process".into(), fault_process.clone()]);
            }
            t.row(vec!["controller".into(), controller.label().into()]);
            t.row(vec!["fault events fired".into(), m.fault_events.to_string()]);
            t.row(vec![
                "stall retries / time".into(),
                format!("{} / {:.2}ms", m.total_stall_retries(), 1e3 * m.stall_s()),
            ]);
            t.row(vec![
                "degraded iterations".into(),
                format!("{:.1}%", 100.0 * m.degraded_fraction()),
            ]);
            t.row(vec!["shed requests".into(), m.sheds.to_string()]);
            t.row(vec![
                "kill recovery (sim)".into(),
                format!("{:.2}s", m.recovery_s),
            ]);
        }
        if heal.is_on() {
            t.row(vec!["self-heal".into(), heal.label().into()]);
            t.row(vec!["heal rebuilds".into(), m.heal_rebuilds.to_string()]);
            t.row(vec![
                "experts migrated".into(),
                m.migrated_experts().to_string(),
            ]);
            t.row(vec![
                "migration (sim)".into(),
                format!("{:.2}ms", 1e3 * m.migration_s()),
            ]);
        }
        if !arrival_kind.is_closed() {
            t.row(vec!["arrivals".into(), arrival_kind.label()]);
            t.row(vec![
                "virtual duration".into(),
                format!("{:.2}s ({:.2}s idle)", m.clock_s, m.idle_s),
            ]);
            t.row(vec![
                "TTFT p50/p95/p99".into(),
                format!(
                    "{} / {} / {}",
                    ms(m.run.ttft_percentile(0.50)),
                    ms(m.run.ttft_percentile(0.95)),
                    ms(m.run.ttft_percentile(0.99))
                ),
            ]);
            t.row(vec![
                "queue delay p50/p95/p99".into(),
                format!(
                    "{} / {} / {}",
                    ms(m.run.queue_wait_percentile(0.50)),
                    ms(m.run.queue_wait_percentile(0.95)),
                    ms(m.run.queue_wait_percentile(0.99))
                ),
            ]);
            t.row(vec![
                "E2E p50/p95/p99".into(),
                format!(
                    "{} / {} / {}",
                    ms(m.run.e2e_percentile(0.50)),
                    ms(m.run.e2e_percentile(0.95)),
                    ms(m.run.e2e_percentile(0.99))
                ),
            ]);
            t.row(vec![
                "mean queue depth".into(),
                format!("{:.1}", m.mean_queue_depth()),
            ]);
            t.row(vec![
                "slot idle fraction".into(),
                format!("{:.1}%", 100.0 * m.slot_idle_fraction()),
            ]);
        }
        if slo_s > 0.0 {
            t.row(vec![
                format!("SLO goodput (TTFT <= {:.0}ms)", 1e3 * slo_s),
                format!("{:.1}%", 100.0 * m.run.slo_goodput(slo_s)),
            ]);
        }
        if !slo_classes.is_empty() {
            // Per-class goodput against each task's own deadline (classes
            // without completions print nothing; tasks outside every class
            // fall back to the catch-all when one is set).
            for name in m.run.task_names() {
                let class_slo = slo_classes.get(&name).unwrap_or(slo_s);
                if class_slo > 0.0 {
                    t.row(vec![
                        format!("goodput[{name}] (TTFT <= {:.0}ms)", 1e3 * class_slo),
                        format!("{:.1}%", 100.0 * m.run.slo_goodput_for(&name, class_slo)),
                    ]);
                }
            }
        }
        t.row(vec![
            "test-phase fraction".into(),
            format!("{:.1}%", 100.0 * m.run.test_phase_fraction()),
        ]);
        if pipeline {
            t.row(vec![
                "pipeline hits / bubbles".into(),
                format!("{} / {}", m.pipeline_hits(), m.pipeline_misses()),
            ]);
            t.row(vec![
                "bubble fraction".into(),
                format!("{:.1}%", 100.0 * m.bubble_fraction()),
            ]);
            t.row(vec![
                "draft hidden (sim)".into(),
                format!("{:.2}ms", 1e3 * m.draft_hidden_s()),
            ]);
            t.row(vec!["draft recomputes".into(), m.draft_recomputes().to_string()]);
        }
        t.row(vec!["host wall time".into(), format!("{:.2}s", wall.as_secs_f64())]);
        println!("{}", t.render());
        return Ok(());
    }

    let mut engine = match backend {
        BackendKind::Real => Engine::real(&reg, cfg, policy.build())?,
        BackendKind::Sim => Engine::sim(&reg, cfg, policy.build())?,
    };
    let t0 = std::time::Instant::now(); // lint:allow(wall-clock): host wall-time table row only
    let run = sched.run(&mut engine)?;
    let wall = t0.elapsed();

    let mut t = Table::new(
        format!("serve: {model} + {task} + {} ({backend_name} backend)", policy.label()),
        &["metric", "value"],
    );
    t.row(vec!["requests".into(), run.requests.len().to_string()]);
    t.row(vec!["output tokens".into(), run.total_tokens().to_string()]);
    t.row(vec!["TPOT (sim GPU)".into(), ms(run.tpot_s())]);
    t.row(vec!["throughput (sim)".into(), format!("{:.1} tok/s", run.throughput())]);
    t.row(vec!["mean ETR".into(), format!("{:.2} tok/iter", run.mean_etr())]);
    t.row(vec![
        "test-phase fraction".into(),
        format!("{:.1}%", 100.0 * run.test_phase_fraction()),
    ]);
    if pipeline {
        t.row(vec![
            "pipeline hits / bubbles".into(),
            format!("{} / {}", engine.pipeline_hits, engine.pipeline_misses),
        ]);
        t.row(vec!["draft recomputes".into(), engine.draft_recomputes.to_string()]);
        let hidden_s: f64 = run
            .requests
            .iter()
            .flat_map(|r| &r.iters)
            .map(|i| i.cost.draft_hidden_s)
            .sum();
        t.row(vec!["draft hidden (sim)".into(), format!("{:.2}ms", 1e3 * hidden_s)]);
    }
    t.row(vec!["host wall time".into(), format!("{:.2}s", wall.as_secs_f64())]);
    t.row(vec![
        "host tok/s".into(),
        format!("{:.1}", run.total_tokens() as f64 / wall.as_secs_f64()),
    ]);
    println!("{}", t.render());
    Ok(())
}

/// Write one bench JSON artifact (creating parent dirs) and announce it.
fn write_json_artifact(path: &str, doc: &cascade::util::json::Value) -> Result<()> {
    if let Some(parent) = std::path::Path::new(path).parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)?;
        }
    }
    std::fs::write(path, cascade::util::json::write(doc))
        .with_context(|| format!("writing bench artifact {path}"))?;
    println!("  -> {path}");
    Ok(())
}

/// Print an experiment's tables and optionally write them as CSV.
fn emit_tables(id: &str, tables: &[Table], out_dir: &str) -> Result<()> {
    for (i, t) in tables.iter().enumerate() {
        println!("{}", t.render());
        if !out_dir.is_empty() {
            std::fs::create_dir_all(out_dir)?;
            let path = format!("{out_dir}/{id}-{i}.csv");
            std::fs::write(&path, t.to_csv())?;
            println!("  -> {path}");
        }
    }
    Ok(())
}

/// Serial vs pipelined TPOT benchmark (the repo's perf-trajectory seed):
/// static-K n-gram serving on the sim backend at batch 1 and 4, with and
/// without the drafting pipeline. Prints the table and writes
/// `BENCH_pipeline.json` for CI artifact tracking. `--quick 1` shrinks the
/// token budget for CI smoke runs.
fn bench(args: &Args) -> Result<()> {
    use cascade::util::json;

    let quick = args.get("quick", "0") != "0";
    let tokens = args.get_usize("tokens", if quick { 400 } else { 2_000 })?;
    let out_path = args.get("out", "BENCH_pipeline.json");
    let seed = args.get_usize("seed", 0xCA5CADE)? as u64;
    let reg = registry()?;
    let task = "code+math";
    let workload = Workload::by_name(task).expect("known mix");
    let policy = PolicyKind::Static(3);
    // One experiment context drives every section: its cell runners are
    // shared with `figure pipeline|sharding|preemption|arrivals`, so bench
    // axes can never drift from the experiments'.
    let mut ctx = ExpCtx::new(reg, BackendKind::Sim, tokens);
    ctx.seed = seed;

    let mut t = Table::new(
        format!("pipeline bench: mixtral/{task}/static-k3 (sim, {tokens} tokens)"),
        &[
            "batch",
            "mode",
            "tokens",
            "TPOT",
            "tok/s",
            "speedup",
            "bubble",
            "hidden draft ms",
            "recomputes",
        ],
    );
    let mut rows: Vec<json::Value> = Vec::new();
    let mut speedups: Vec<(&str, json::Value)> = Vec::new();
    for batch in [1usize, 4] {
        let mut tpot_serial = f64::NAN;
        for pipeline in [false, true] {
            let mut cfg = ctx.batch_cfg("mixtral", batch);
            cfg.pipeline = pipeline;
            let t0 = std::time::Instant::now(); // lint:allow(wall-clock): host wall-time bench column only
            let m = ctx.run_batch_cell(cfg, &policy, &workload)?;
            let host_s = t0.elapsed().as_secs_f64();

            let mode = if pipeline { "pipelined" } else { "serial" };
            let tpot = m.tpot_s();
            if !pipeline {
                tpot_serial = tpot;
            }
            let speedup = tpot_serial / tpot;
            t.row(vec![
                batch.to_string(),
                mode.into(),
                m.run.total_tokens().to_string(),
                ms(tpot),
                format!("{:.1}", 1.0 / tpot),
                format!("{speedup:.3}x"),
                format!("{:.1}%", 100.0 * m.bubble_fraction()),
                format!("{:.2}", 1e3 * m.draft_hidden_s()),
                m.draft_recomputes().to_string(),
            ]);
            rows.push(json::obj(vec![
                ("batch", json::num(batch as f64)),
                ("mode", json::str(mode)),
                ("tokens", json::num(m.run.total_tokens() as f64)),
                ("tpot_ms", json::num(1e3 * tpot)),
                ("tokens_per_s", json::num(1.0 / tpot)),
                ("bubble_fraction", json::num(m.bubble_fraction())),
                ("draft_hidden_ms", json::num(1e3 * m.draft_hidden_s())),
                ("draft_wall_ms", json::num(m.draft_wall_ns() as f64 / 1e6)),
                ("draft_wall_hidden_ms", json::num(m.draft_wall_hidden_ns() as f64 / 1e6)),
                ("pipeline_hits", json::num(m.pipeline_hits() as f64)),
                ("pipeline_misses", json::num(m.pipeline_misses() as f64)),
                ("draft_recomputes", json::num(m.draft_recomputes() as f64)),
                ("host_wall_s", json::num(host_s)),
            ]));
            if pipeline {
                speedups.push((
                    if batch == 1 { "b1" } else { "b4" },
                    json::num(speedup),
                ));
            }
        }
    }
    println!("{}", t.render());

    let doc = json::obj(vec![
        ("bench", json::str("pipeline")),
        ("model", json::str("mixtral")),
        ("task", json::str(task)),
        ("policy", json::str("static-k3")),
        ("drafter", json::str("ngram")),
        ("backend", json::str("sim")),
        ("token_budget", json::num(tokens as f64)),
        ("quick", json::Value::Bool(quick)),
        ("rows", json::arr(rows)),
        ("speedup_pipelined_over_serial", json::obj(speedups)),
    ]);
    write_json_artifact(&out_path, &doc)?;

    // ---- Expert-parallel sharding bench (BENCH_sharding.json) -----------
    let shard_out = args.get("out-sharding", "BENCH_sharding.json");
    let mut st = Table::new(
        format!("sharding bench: mixtral/{task}/static-k3 (sim, {tokens} tokens)"),
        &[
            "batch",
            "shards",
            "placement",
            "tokens",
            "TPOT",
            "tok/s",
            "speedup",
            "verify ms/iter",
            "max-shard experts",
            "imbalance",
            "a2a share",
        ],
    );
    let mut shard_rows: Vec<json::Value> = Vec::new();
    for batch in [1usize, 4] {
        let mut tpot_unsharded = f64::NAN;
        for shards in experiments::sharding::DEFAULT_SHARDS {
            for &placement in experiments::sharding::placement_axis(shards) {
                let m = experiments::sharding::run_cell(
                    &mut ctx,
                    "mixtral",
                    &policy,
                    batch,
                    shards,
                    placement,
                )?;
                let tpot = m.tpot_s();
                if shards == 1 {
                    tpot_unsharded = tpot;
                }
                let place_label = experiments::sharding::placement_cell_label(shards, placement);
                st.row(vec![
                    batch.to_string(),
                    shards.to_string(),
                    place_label.into(),
                    m.run.total_tokens().to_string(),
                    ms(tpot),
                    format!("{:.1}", 1.0 / tpot),
                    format!("{:.3}x", tpot_unsharded / tpot),
                    format!("{:.2}", 1e3 * m.mean_verify_s()),
                    format!("{:.1}", m.mean_max_shard_unique()),
                    format!("{:.2}", m.mean_shard_imbalance()),
                    format!("{:.1}%", 100.0 * m.alltoall_share()),
                ]);
                shard_rows.push(json::obj(vec![
                    ("batch", json::num(batch as f64)),
                    ("shards", json::num(shards as f64)),
                    ("placement", json::str(place_label)),
                    ("tokens", json::num(m.run.total_tokens() as f64)),
                    ("tpot_ms", json::num(1e3 * tpot)),
                    ("tokens_per_s", json::num(1.0 / tpot)),
                    ("speedup_vs_1_shard", json::num(tpot_unsharded / tpot)),
                    ("mean_verify_ms", json::num(1e3 * m.mean_verify_s())),
                    ("max_shard_unique", json::num(m.mean_max_shard_unique())),
                    ("shard_imbalance", json::num(m.mean_shard_imbalance())),
                    ("alltoall_share", json::num(m.alltoall_share())),
                ]));
            }
        }
    }
    println!("{}", st.render());
    let shard_doc = json::obj(vec![
        ("bench", json::str("sharding")),
        ("model", json::str("mixtral")),
        ("task", json::str(task)),
        ("policy", json::str("static-k3")),
        ("drafter", json::str("ngram")),
        ("backend", json::str("sim")),
        ("token_budget", json::num(tokens as f64)),
        ("quick", json::Value::Bool(quick)),
        ("rows", json::arr(shard_rows)),
    ]);
    write_json_artifact(&shard_out, &shard_doc)?;

    // ---- Preemption bench (BENCH_preemption.json) -----------------------
    // Completed-request throughput at batch 4 under a half-working-set KV
    // pool, per eviction policy (off = the deadlock baseline). Shares its
    // cell runner with `figure preemption` so the two can never drift.
    let preempt_out = args.get("out-preemption", "BENCH_preemption.json");
    let preempt_reqs =
        experiments::preemption::cell_requests(if quick { 6 } else { 8 }, 200, seed);
    let pool_blocks = experiments::preemption::constrained_pool_blocks(&preempt_reqs, 4);
    let mut pt = Table::new(
        format!(
            "preemption bench: mixtral/{task}/static-k3 (sim, batch 4, pool {pool_blocks} blocks)"
        ),
        &[
            "eviction",
            "done",
            "tokens",
            "TPOT",
            "tok/s done",
            "evictions",
            "readmits",
            "reprefill ms",
            "thrash",
            "status",
        ],
    );
    let mut preempt_rows: Vec<json::Value> = Vec::new();
    for eviction in experiments::preemption::EVICTIONS {
        let out = experiments::preemption::run_cell(
            &mut ctx,
            "mixtral",
            &policy,
            4,
            pool_blocks,
            eviction,
            &preempt_reqs,
        )?;
        let m = &out.metrics;
        pt.row(vec![
            eviction.label().into(),
            format!("{}/{}", m.run.requests.len(), preempt_reqs.len()),
            m.run.total_tokens().to_string(),
            ms(m.tpot_s()),
            format!("{:.1}", out.completed_tokens_per_s()),
            m.evictions().to_string(),
            m.readmissions().to_string(),
            format!("{:.2}", 1e3 * m.reprefill_s()),
            format!("{:.1}%", 100.0 * m.thrash_fraction()),
            if out.deadlock.is_some() { "deadlock".into() } else { "ok".to_string() },
        ]);
        preempt_rows.push(json::obj(vec![
            ("eviction", json::str(eviction.label())),
            ("pool_blocks", json::num(pool_blocks as f64)),
            ("requests_completed", json::num(m.run.requests.len() as f64)),
            ("requests_total", json::num(preempt_reqs.len() as f64)),
            ("tokens", json::num(m.run.total_tokens() as f64)),
            ("tpot_ms", json::num(1e3 * m.tpot_s())),
            ("completed_tokens_per_s", json::num(out.completed_tokens_per_s())),
            ("evictions", json::num(m.evictions() as f64)),
            ("readmissions", json::num(m.readmissions() as f64)),
            ("reprefill_ms", json::num(1e3 * m.reprefill_s())),
            ("thrash_fraction", json::num(m.thrash_fraction())),
            ("total_evicted", json::num(out.total_evicted as f64)),
            ("deadlock", json::Value::Bool(out.deadlock.is_some())),
        ]));
    }
    println!("{}", pt.render());
    let preempt_doc = json::obj(vec![
        ("bench", json::str("preemption")),
        ("model", json::str("mixtral")),
        ("task", json::str("code+math")),
        ("policy", json::str("static-k3")),
        ("drafter", json::str("ngram")),
        ("backend", json::str("sim")),
        ("batch", json::num(4.0)),
        ("pool_blocks", json::num(pool_blocks as f64)),
        ("quick", json::Value::Bool(quick)),
        ("rows", json::arr(preempt_rows)),
    ]);
    write_json_artifact(&preempt_out, &preempt_doc)?;

    // ---- Arrivals bench (BENCH_arrivals.json) ---------------------------
    // Queueing-delay tail per admission policy under bursty open-loop
    // arrivals into a half-working-set KV pool (LRU eviction). Shares its
    // cell runner with `figure arrivals` so the two can never drift. The
    // headline comparison: fcfs vs parked-first — priority re-admission of
    // eviction victims cuts the p95 queueing delay (and the re-prefill
    // thrash that causes it). Budget is fixed per cell (independent of
    // --tokens) so the percentiles always see a full request population.
    let arrivals_out = args.get("out-arrivals", "BENCH_arrivals.json");
    let arr_rate = 2.0;
    let probe = experiments::arrivals::contended_cell(
        cascade::config::AdmissionKind::Fcfs,
        arr_rate,
        seed,
    );
    let mut at = Table::new(
        format!(
            "arrivals bench: mixtral/{task}/static-k3 (sim, batch 4, {}, pool {} blocks)",
            probe.arrivals.label(),
            probe.pool_blocks
        ),
        &[
            "admission",
            "reqs",
            "tokens",
            "TTFT p95",
            "queue p50",
            "queue p95",
            "E2E p95",
            "goodput",
            "evict",
            "readmit",
            "thrash",
            "depth",
            "idle",
        ],
    );
    let mut arr_rows: Vec<json::Value> = Vec::new();
    for admission in experiments::arrivals::ADMISSIONS {
        let cell = experiments::arrivals::contended_cell(admission, arr_rate, seed);
        let m = experiments::arrivals::run_cell(&ctx, "mixtral", &policy, &cell)?;
        at.row(vec![
            admission.label().into(),
            m.run.requests.len().to_string(),
            m.run.total_tokens().to_string(),
            ms(m.run.ttft_percentile(0.95)),
            ms(m.run.queue_wait_percentile(0.50)),
            ms(m.run.queue_wait_percentile(0.95)),
            ms(m.run.e2e_percentile(0.95)),
            format!("{:.0}%", 100.0 * m.run.slo_goodput(cell.slo_s)),
            m.evictions().to_string(),
            m.readmissions().to_string(),
            format!("{:.1}%", 100.0 * m.thrash_fraction()),
            format!("{:.1}", m.mean_queue_depth()),
            format!("{:.0}%", 100.0 * m.slot_idle_fraction()),
        ]);
        arr_rows.push(json::obj(vec![
            ("admission", json::str(admission.label())),
            ("pool_blocks", json::num(cell.pool_blocks as f64)),
            ("requests_completed", json::num(m.run.requests.len() as f64)),
            ("tokens", json::num(m.run.total_tokens() as f64)),
            ("ttft_p50_ms", json::num(1e3 * m.run.ttft_percentile(0.50))),
            ("ttft_p95_ms", json::num(1e3 * m.run.ttft_percentile(0.95))),
            ("ttft_p99_ms", json::num(1e3 * m.run.ttft_percentile(0.99))),
            ("queue_delay_p50_ms", json::num(1e3 * m.run.queue_wait_percentile(0.50))),
            ("queue_delay_p95_ms", json::num(1e3 * m.run.queue_wait_percentile(0.95))),
            ("queue_delay_p99_ms", json::num(1e3 * m.run.queue_wait_percentile(0.99))),
            ("e2e_p95_ms", json::num(1e3 * m.run.e2e_percentile(0.95))),
            ("slo_ms", json::num(1e3 * cell.slo_s)),
            ("slo_goodput", json::num(m.run.slo_goodput(cell.slo_s))),
            ("evictions", json::num(m.evictions() as f64)),
            ("readmissions", json::num(m.readmissions() as f64)),
            ("reprefill_ms", json::num(1e3 * m.reprefill_s())),
            ("thrash_fraction", json::num(m.thrash_fraction())),
            ("mean_queue_depth", json::num(m.mean_queue_depth())),
            ("slot_idle_fraction", json::num(m.slot_idle_fraction())),
            ("virtual_duration_s", json::num(m.clock_s)),
        ]));
    }
    println!("{}", at.render());
    let arr_doc = json::obj(vec![
        ("bench", json::str("arrivals")),
        ("model", json::str("mixtral")),
        ("task", json::str(task)),
        ("policy", json::str("static-k3")),
        ("drafter", json::str("ngram")),
        ("backend", json::str("sim")),
        ("batch", json::num(4.0)),
        ("arrivals", json::str(probe.arrivals.label())),
        ("rate_mean_per_s", json::num(arr_rate)),
        ("pool_blocks", json::num(probe.pool_blocks as f64)),
        ("quick", json::Value::Bool(quick)),
        ("rows", json::arr(arr_rows)),
    ]);
    write_json_artifact(&arrivals_out, &arr_doc)?;

    // ---- Fault-injection bench (BENCH_faults.json) ----------------------
    // The chaos plan (one of everything: straggler, stall, shard kill,
    // pool shrink) under the arrivals bench's contended open-loop shape,
    // served fault-free, with faults and the controller off, and with
    // faults and the adaptive degradation controller. The controller
    // cannot un-fail hardware — chaos always costs goodput — but it bounds
    // the slowdown: throttled speculation relieves the shrunken pool and
    // unmeetable arrivals are shed before they burn verify time. Shares
    // its cell runner with `figure faults`.
    let faults_out = args.get("out-faults", "BENCH_faults.json");
    let fprobe = experiments::faults::chaos_cell("off", ControllerKind::Off, seed);
    let mut ft = Table::new(
        format!(
            "faults bench: mixtral/{task}/static-k3 (sim, batch 4, 2 shards, {}, pool {} blocks)",
            fprobe.arrivals.label(),
            fprobe.pool_blocks
        ),
        &[
            "faults",
            "controller",
            "reqs",
            "tokens",
            "TPOT",
            "goodput",
            "E2E p99",
            "shed",
            "events",
            "stall retries",
            "degraded",
            "recovery s",
        ],
    );
    let mut fault_rows: Vec<json::Value> = Vec::new();
    let mut tpot_fault_free = f64::NAN;
    for (plan, controller) in [
        ("off", ControllerKind::Off),
        ("chaos", ControllerKind::Off),
        ("chaos", ControllerKind::Adaptive),
    ] {
        let cell = experiments::faults::chaos_cell(plan, controller, seed);
        let m = experiments::faults::run_cell(&ctx, "mixtral", &policy, &cell)?;
        let tpot = m.tpot_s();
        if plan == "off" {
            tpot_fault_free = tpot;
        }
        ft.row(vec![
            plan.into(),
            controller.label().into(),
            m.run.requests.len().to_string(),
            m.run.total_tokens().to_string(),
            ms(tpot),
            format!("{:.0}%", 100.0 * m.run.slo_goodput(cell.slo_s)),
            ms(m.run.e2e_percentile(0.99)),
            m.sheds.to_string(),
            m.fault_events.to_string(),
            m.total_stall_retries().to_string(),
            format!("{:.0}%", 100.0 * m.degraded_fraction()),
            format!("{:.2}", m.recovery_s),
        ]);
        fault_rows.push(json::obj(vec![
            ("faults", json::str(plan)),
            ("controller", json::str(controller.label())),
            ("requests_completed", json::num(m.run.requests.len() as f64)),
            ("tokens", json::num(m.run.total_tokens() as f64)),
            ("tpot_ms", json::num(1e3 * tpot)),
            ("tpot_slowdown_vs_fault_free", json::num(tpot / tpot_fault_free)),
            ("slo_ms", json::num(1e3 * cell.slo_s)),
            ("slo_goodput", json::num(m.run.slo_goodput(cell.slo_s))),
            ("ttft_p95_ms", json::num(1e3 * m.run.ttft_percentile(0.95))),
            ("e2e_p99_ms", json::num(1e3 * m.run.e2e_percentile(0.99))),
            ("sheds", json::num(m.sheds as f64)),
            ("fault_events", json::num(m.fault_events as f64)),
            ("stall_retries", json::num(m.total_stall_retries() as f64)),
            ("stall_ms", json::num(1e3 * m.stall_s())),
            ("degraded_fraction", json::num(m.degraded_fraction())),
            ("recovery_s", json::num(m.recovery_s)),
            ("evictions", json::num(m.evictions() as f64)),
            ("readmissions", json::num(m.readmissions() as f64)),
            ("virtual_duration_s", json::num(m.clock_s)),
        ]));
    }
    println!("{}", ft.render());

    // ---- Saturation bench (BENCH_saturation.json) -----------------------
    // Goodput vs offered load: Poisson arrival-rate sweep with the
    // degradation controller off vs adaptive, every cell under the same
    // stochastic MTBF straggler process. Shares its cell constructor with
    // `figure faults`' saturation table so the axes can never drift. The
    // headline: the saturation knee (goodput falling away from offered
    // load) sits at a higher rate with the controller on.
    let saturation_out = args.get("out-saturation", "BENCH_saturation.json");
    let mut sat_rows: Vec<json::Value> = Vec::new();
    let mut satt = Table::new(
        format!(
            "saturation bench: mixtral/{task}/static-k3 (sim, batch 4, 2 shards, \
             fault process {})",
            experiments::faults::SATURATION_PROCESS
        ),
        &[
            "rate /s",
            "controller",
            "reqs",
            "tokens",
            "tok/s",
            "TPOT",
            "TTFT p95",
            "goodput",
            "shed",
            "events",
            "degraded",
        ],
    );
    for &rate in experiments::faults::SATURATION_RATES {
        for controller in [ControllerKind::Off, ControllerKind::Adaptive] {
            let cell = experiments::faults::saturation_cell(rate, controller, seed);
            let m = experiments::faults::run_cell(&ctx, "mixtral", &policy, &cell)?;
            satt.row(vec![
                format!("{rate:.1}"),
                controller.label().into(),
                m.run.requests.len().to_string(),
                m.run.total_tokens().to_string(),
                format!("{:.1}", m.run.total_tokens() as f64 / m.clock_s),
                ms(m.tpot_s()),
                ms(m.run.ttft_percentile(0.95)),
                format!("{:.0}%", 100.0 * m.run.slo_goodput(cell.slo_s)),
                m.sheds.to_string(),
                m.fault_events.to_string(),
                format!("{:.0}%", 100.0 * m.degraded_fraction()),
            ]);
            sat_rows.push(json::obj(vec![
                ("rate_per_s", json::num(rate)),
                ("controller", json::str(controller.label())),
                ("requests_completed", json::num(m.run.requests.len() as f64)),
                ("tokens", json::num(m.run.total_tokens() as f64)),
                ("tokens_per_s_virtual", json::num(m.run.total_tokens() as f64 / m.clock_s)),
                ("tpot_ms", json::num(1e3 * m.tpot_s())),
                ("ttft_p95_ms", json::num(1e3 * m.run.ttft_percentile(0.95))),
                ("e2e_p99_ms", json::num(1e3 * m.run.e2e_percentile(0.99))),
                ("slo_ms", json::num(1e3 * cell.slo_s)),
                ("slo_goodput", json::num(m.run.slo_goodput(cell.slo_s))),
                ("sheds", json::num(m.sheds as f64)),
                ("fault_events", json::num(m.fault_events as f64)),
                ("degraded_fraction", json::num(m.degraded_fraction())),
                ("virtual_duration_s", json::num(m.clock_s)),
            ]));
        }
    }
    println!("{}", satt.render());
    let sat_doc = json::obj(vec![
        ("bench", json::str("saturation")),
        ("model", json::str("mixtral")),
        ("task", json::str(task)),
        ("policy", json::str("static-k3")),
        ("drafter", json::str("ngram")),
        ("backend", json::str("sim")),
        ("batch", json::num(4.0)),
        ("shards", json::num(2.0)),
        ("arrivals", json::str("poisson")),
        ("fault_process", json::str(experiments::faults::SATURATION_PROCESS)),
        ("quick", json::Value::Bool(quick)),
        ("rows", json::arr(sat_rows)),
    ]);
    write_json_artifact(&saturation_out, &sat_doc)?;

    // ---- Prefix-sharing bench (BENCH_prefix.json) -----------------------
    // Throughput and p50 TTFT vs the template share ratio at batch 1 and 4,
    // under open-loop Poisson arrivals fast enough to keep a queue standing
    // (each trie hit then shortens the backlog for everyone behind it, so
    // p50 TTFT falls as share rises). Shares its cell runner with
    // `figure prefix` so the two can never drift.
    let prefix_out = args.get("out-prefix", "BENCH_prefix.json");
    let pprobe = experiments::prefix::cell(0.0, 1);
    let mut pxt = Table::new(
        format!(
            "prefix bench: mixtral/{task}/static-k3 (sim, poisson {:.0}/s open-loop)",
            pprobe.rate
        ),
        &[
            "batch",
            "share",
            "reqs",
            "tokens",
            "tok/s",
            "TTFT p50",
            "TTFT p95",
            "hits",
            "misses",
            "hit tokens",
            "shared peak",
            "reclaimed",
        ],
    );
    let mut prefix_rows: Vec<json::Value> = Vec::new();
    for &pbatch in &experiments::prefix::BATCHES {
        for &share in &experiments::prefix::SHARES {
            let cell = experiments::prefix::cell(share, pbatch);
            let m = experiments::prefix::run_cell(&ctx, "mixtral", &policy, &cell)?;
            pxt.row(vec![
                pbatch.to_string(),
                format!("{share:.1}"),
                m.run.requests.len().to_string(),
                m.run.total_tokens().to_string(),
                format!("{:.1}", m.run.total_tokens() as f64 / m.clock_s),
                ms(m.run.ttft_percentile(0.50)),
                ms(m.run.ttft_percentile(0.95)),
                m.prefix_hits.to_string(),
                m.prefix_misses.to_string(),
                m.prefix_hit_tokens.to_string(),
                m.shared_blocks_peak.to_string(),
                m.prefix_reclaimed_blocks.to_string(),
            ]);
            prefix_rows.push(json::obj(vec![
                ("batch", json::num(pbatch as f64)),
                ("share", json::num(share)),
                ("requests_completed", json::num(m.run.requests.len() as f64)),
                ("tokens", json::num(m.run.total_tokens() as f64)),
                ("tokens_per_s_virtual", json::num(m.run.total_tokens() as f64 / m.clock_s)),
                ("ttft_p50_ms", json::num(1e3 * m.run.ttft_percentile(0.50))),
                ("ttft_p95_ms", json::num(1e3 * m.run.ttft_percentile(0.95))),
                ("prefix_hits", json::num(m.prefix_hits as f64)),
                ("prefix_misses", json::num(m.prefix_misses as f64)),
                ("prefix_hit_rate", json::num(m.prefix_hit_rate())),
                ("prefix_hit_tokens", json::num(m.prefix_hit_tokens as f64)),
                ("shared_blocks_peak", json::num(m.shared_blocks_peak as f64)),
                ("prefix_reclaimed_blocks", json::num(m.prefix_reclaimed_blocks as f64)),
                ("virtual_duration_s", json::num(m.clock_s)),
            ]));
        }
    }
    println!("{}", pxt.render());
    let prefix_doc = json::obj(vec![
        ("bench", json::str("prefix")),
        ("model", json::str("mixtral")),
        ("task", json::str(task)),
        ("policy", json::str("static-k3")),
        ("drafter", json::str("ngram")),
        ("backend", json::str("sim")),
        ("arrivals", json::str("poisson")),
        ("rate_per_s", json::num(pprobe.rate)),
        ("quick", json::Value::Bool(quick)),
        ("rows", json::arr(prefix_rows)),
    ]);
    write_json_artifact(&prefix_out, &prefix_doc)?;

    // ---- Hot-path simspeed bench (BENCH_simspeed.json) ------------------
    // Two views of the hot-path rebuild (rust/docs/perf.md):
    //
    // 1. `kernel`: the per-iteration expert-set algebra (per-layer union
    //    plus the shared/marginal partition) timed on identical synthetic
    //    routing data under both representations. The legacy tree-set
    //    kernel is re-implemented here — main.rs sits outside the
    //    hot-path-set lint scope precisely so the pre-refactor baseline
    //    can live on as a measurable artifact.
    // 2. `engine`: end-to-end simulated iterations/sec of an open-loop
    //    batch-4 × shards-2 × pipelined serving cell on the rebuilt path
    //    (same shape as the `expert_set`/`sim` cells in
    //    rust/benches/hot_paths.rs).
    let simspeed_out = args.get("out-simspeed", "BENCH_simspeed.json");
    let kernel_iters = if quick { 2_000 } else { 20_000 };
    // Synthetic routing data: 8 layers × 4 slots × 16 draws in [0, 64),
    // fixed seed — both kernels consume the exact same id streams.
    let kernel_sets: Vec<Vec<Vec<usize>>> = {
        let mut krng = cascade::rng::Rng::new(0x51A5_9EED_u64 ^ seed);
        (0..8)
            .map(|_| (0..4).map(|_| (0..16).map(|_| krng.below(64)).collect()).collect())
            .collect()
    };
    let legacy_pass = |sets: &[Vec<Vec<usize>>]| -> usize {
        let mut acc = 0usize;
        for layer in sets {
            let slot_sets: Vec<BTreeSet<usize>> =
                layer.iter().map(|ids| ids.iter().copied().collect()).collect();
            let mut mult: BTreeMap<usize, u32> = BTreeMap::new();
            for s in &slot_sets {
                for &e in s {
                    *mult.entry(e).or_insert(0) += 1;
                }
            }
            let shared: BTreeSet<usize> =
                mult.iter().filter(|&(_, &c)| c >= 2).map(|(&e, _)| e).collect();
            for s in &slot_sets {
                acc += s.difference(&shared).count();
            }
            acc += mult.len() + shared.len();
        }
        acc
    };
    let bitmap_pass = |sets: &[Vec<Vec<usize>>]| -> usize {
        let mut acc = 0usize;
        for layer in sets {
            let mut once = ExpertBitmap::new();
            let mut twice = ExpertBitmap::new();
            let slot_sets: Vec<ExpertBitmap> =
                layer.iter().map(|ids| ExpertBitmap::from_ids(ids)).collect();
            for s in &slot_sets {
                twice.union_with(&s.and(&once));
                once.union_with(s);
            }
            for s in &slot_sets {
                acc += s.and_not(&twice).count();
            }
            acc += once.count() + twice.count();
        }
        acc
    };
    // Same inputs must mean same answers before the timings mean anything.
    anyhow::ensure!(
        legacy_pass(&kernel_sets) == bitmap_pass(&kernel_sets),
        "expert-set kernels disagree on identical inputs"
    );
    let time_kernel = |f: &dyn Fn(&[Vec<Vec<usize>>]) -> usize| -> f64 {
        let mut sink = 0usize;
        let t0 = std::time::Instant::now(); // lint:allow(wall-clock): host wall-time kernel timing only
        for _ in 0..kernel_iters {
            sink = sink.wrapping_add(std::hint::black_box(f(&kernel_sets)));
        }
        let per_iter = t0.elapsed().as_nanos() as f64 / kernel_iters as f64;
        std::hint::black_box(sink);
        per_iter
    };
    let legacy_ns = time_kernel(&legacy_pass);
    let bitmap_ns = time_kernel(&bitmap_pass);
    let kernel_speedup = legacy_ns / bitmap_ns.max(1e-9);

    // End-to-end open-loop cell on the rebuilt path.
    let mut sim_cfg = ctx.batch_cfg("mixtral", 4);
    sim_cfg.shards = 2;
    sim_cfg.pipeline = true;
    let sim_budget = if quick { 600 } else { 2_400 };
    let t0 = std::time::Instant::now(); // lint:allow(wall-clock): host wall-time bench column only
    let sim_m = {
        let mut engine = ctx.batch_engine(sim_cfg, &policy)?;
        let stream = RequestStream::new(workload.clone(), seed, ctx.max_new_tokens);
        let arrivals = cascade::workload::arrivals::ArrivalProcess::new(
            cascade::workload::arrivals::ArrivalKind::Poisson { rate: 64.0 },
            stream,
            seed,
        )?;
        let mut sched = Scheduler::with_arrivals(
            arrivals,
            Budget { max_tokens: sim_budget, max_requests: 10_000 },
        );
        sched.run_batched(&mut engine)?
    };
    let sim_host_s = t0.elapsed().as_secs_f64();
    let sim_iters = sim_m.iters.len();
    let iters_per_sec = sim_iters as f64 / sim_host_s.max(1e-9);

    let mut sst = Table::new(
        "simspeed bench: expert-set kernel + open-loop engine (host wall time)",
        &["cell", "value", "unit"],
    );
    sst.row(vec!["kernel_btreeset".into(), format!("{legacy_ns:.0}"), "ns/pass".into()]);
    sst.row(vec!["kernel_bitmap".into(), format!("{bitmap_ns:.0}"), "ns/pass".into()]);
    sst.row(vec!["kernel_speedup".into(), format!("{kernel_speedup:.2}x"), "".into()]);
    sst.row(vec!["engine_iterations".into(), sim_iters.to_string(), "iters".into()]);
    sst.row(vec![
        "engine_iterations_per_sec".into(),
        format!("{iters_per_sec:.0}"),
        "iters/s".into(),
    ]);
    println!("{}", sst.render());

    let simspeed_doc = json::obj(vec![
        ("bench", json::str("simspeed")),
        ("model", json::str("mixtral")),
        ("task", json::str(task)),
        ("policy", json::str("static-k3")),
        ("backend", json::str("sim")),
        ("batch", json::num(4.0)),
        ("shards", json::num(2.0)),
        ("pipeline", json::Value::Bool(true)),
        ("arrivals", json::str("poisson")),
        ("rate_per_s", json::num(64.0)),
        ("quick", json::Value::Bool(quick)),
        (
            "kernel",
            json::obj(vec![
                ("passes", json::num(kernel_iters as f64)),
                ("btreeset_ns_per_pass", json::num(legacy_ns)),
                ("bitmap_ns_per_pass", json::num(bitmap_ns)),
                ("speedup_bitmap_over_btreeset", json::num(kernel_speedup)),
            ]),
        ),
        (
            "engine",
            json::obj(vec![
                ("iterations", json::num(sim_iters as f64)),
                ("host_wall_s", json::num(sim_host_s)),
                ("iterations_per_sec_host", json::num(iters_per_sec)),
                ("tokens", json::num(sim_m.run.total_tokens() as f64)),
                (
                    "tokens_per_sec_host",
                    json::num(sim_m.run.total_tokens() as f64 / sim_host_s.max(1e-9)),
                ),
                ("virtual_duration_s", json::num(sim_m.clock_s)),
            ]),
        ),
    ]);
    write_json_artifact(&simspeed_out, &simspeed_doc)?;

    let faults_doc = json::obj(vec![
        ("bench", json::str("faults")),
        ("model", json::str("mixtral")),
        ("task", json::str(task)),
        ("policy", json::str("static-k3")),
        ("drafter", json::str("ngram")),
        ("backend", json::str("sim")),
        ("batch", json::num(4.0)),
        ("shards", json::num(2.0)),
        ("arrivals", json::str("bursty")),
        ("pool_blocks", json::num(fprobe.pool_blocks as f64)),
        ("quick", json::Value::Bool(quick)),
        ("rows", json::arr(fault_rows)),
    ]);
    write_json_artifact(&faults_out, &faults_doc)?;
    Ok(())
}

/// The continuous-batching comparison sweep (the `batch` experiment on the
/// sim backend), or — with `--shards a,b,c` — the expert-parallel
/// K-vs-shards axis (the `sharding` experiment over an explicit axis), or —
/// with `--rate a,b,c` — the open-loop Poisson saturation sweep (the
/// `arrivals` experiment's rate axis).
fn sweep(args: &Args) -> Result<()> {
    let tokens = args.get_usize("tokens", 300)?;
    let out_dir = args.get("out-dir", "");
    anyhow::ensure!(
        !(args.flags.contains_key("rate") && args.flags.contains_key("shards")),
        "--rate and --shards are mutually exclusive sweep axes; pick one"
    );
    let reg = registry()?;
    let mut ctx = ExpCtx::new(reg, BackendKind::Sim, tokens);
    if let Some(axis) = args.flags.get("rate") {
        let rates: Vec<f64> = axis
            .split(',')
            .map(|s| s.trim().parse().with_context(|| format!("--rate piece {s:?}")))
            .collect::<Result<_>>()?;
        anyhow::ensure!(!rates.is_empty(), "--rate needs at least one arrival rate");
        if !args.flags.contains_key("tokens") {
            // An explicit --tokens is honored exactly; the 300-token sweep
            // default is too small for stable latency percentiles, so the
            // rate axis defaults to a dozen 120-token requests per cell.
            ctx.tokens_per_cell = 12 * 120;
        }
        println!("\n### arrivals — open-loop Poisson saturation sweep over rates {rates:?}\n");
        let tables = experiments::arrivals::rate_sweep_table(&mut ctx, &rates)?;
        return emit_tables("arrivals-rate", &tables, &out_dir);
    }
    if let Some(axis) = args.flags.get("shards") {
        let shard_counts: Vec<usize> = axis
            .split(',')
            .map(|s| s.trim().parse().with_context(|| format!("--shards piece {s:?}")))
            .collect::<Result<_>>()?;
        anyhow::ensure!(!shard_counts.is_empty(), "--shards needs at least one count");
        println!("\n### sharding — expert-parallel sweep over shards {shard_counts:?}\n");
        let tables = experiments::sharding::sharding_table(&mut ctx, &shard_counts)?;
        return emit_tables("sharding", &tables, &out_dir);
    }
    let exp = experiments::by_id("batch").expect("batch experiment registered");
    println!("\n### {} — {}\n", exp.id, exp.caption);
    let tables = (exp.run)(&mut ctx)?;
    emit_tables(exp.id, &tables, &out_dir)
}

fn figure(args: &Args) -> Result<()> {
    let id = args.positional.first().map(String::as_str).unwrap_or("all");
    let backend = BackendKind::parse(&args.get("backend", "real"))?;
    let tokens = args.get_usize("tokens", 300)?;
    let out_dir = args.get("out-dir", "");

    let reg = registry()?;
    let mut ctx = ExpCtx::new(reg, backend, tokens);

    let experiments: Vec<_> = if id == "all" {
        experiments::all()
    } else {
        vec![experiments::by_id(id).with_context(|| format!("unknown figure {id:?}"))?]
    };

    for exp in experiments {
        println!("\n### {} — {}\n", exp.id, exp.caption);
        let t0 = std::time::Instant::now(); // lint:allow(wall-clock): host wall-time progress line only
        let tables = (exp.run)(&mut ctx)?;
        emit_tables(exp.id, &tables, &out_dir)?;
        println!("[{} done in {:.1}s]", exp.id, t0.elapsed().as_secs_f64());
    }
    Ok(())
}
