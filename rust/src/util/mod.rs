//! Small in-tree substrates (the build is fully offline; see DESIGN.md):
//! a JSON parser/writer and text-table formatting.

pub mod json;
pub mod table;
