//! Aligned text tables for experiment output (paper-style rows) + CSV.

/// A simple column-aligned table.
#[derive(Debug, Clone, Default)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: impl Into<String>, headers: &[&str]) -> Self {
        Self {
            title: title.into(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        debug_assert_eq!(cells.len(), self.headers.len());
        self.rows.push(cells);
    }

    /// Render with aligned columns.
    pub fn render(&self) -> String {
        let ncol = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate().take(ncol) {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("== {} ==\n", self.title));
        }
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:<w$}", c, w = widths[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        out.push_str(&fmt_row(&self.headers, &widths));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (ncol - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }

    /// CSV rendering (for results/ files).
    pub fn to_csv(&self) -> String {
        let esc = |s: &str| {
            if s.contains(',') || s.contains('"') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        };
        let mut out = self.headers.iter().map(|h| esc(h)).collect::<Vec<_>>().join(",");
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
            out.push('\n');
        }
        out
    }
}

/// Format a ratio as "1.23x".
pub fn ratio(x: f64) -> String {
    format!("{x:.2}x")
}

/// Format seconds as milliseconds.
pub fn ms(s: f64) -> String {
    format!("{:.2}ms", s * 1e3)
}

/// Format a percentage delta ("+12.3%" / "-4.5%").
pub fn pct(x: f64) -> String {
    format!("{:+.1}%", x * 100.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("demo", &["model", "tpot"]);
        t.row(vec!["mixtral".into(), "28.0ms".into()]);
        t.row(vec!["olmoe".into(), "6.1ms".into()]);
        let r = t.render();
        assert!(r.contains("mixtral"));
        assert!(r.lines().count() >= 4);
    }

    #[test]
    fn csv_escapes() {
        let mut t = Table::new("", &["a", "b"]);
        t.row(vec!["x,y".into(), "q\"z".into()]);
        let csv = t.to_csv();
        assert!(csv.contains("\"x,y\""));
        assert!(csv.contains("\"q\"\"z\""));
    }

    #[test]
    fn formatters() {
        assert_eq!(ratio(1.234), "1.23x");
        assert_eq!(ms(0.0281), "28.10ms");
        assert_eq!(pct(0.123), "+12.3%");
    }
}
