//! Minimal JSON parser + writer (RFC 8259 subset sufficient for
//! `artifacts/manifest.json` and results files).
//!
//! In-tree because the build is fully offline (no serde in the vendor set).
//! Numbers are kept as f64 — the manifest never exceeds 2^53.

use anyhow::{anyhow, bail, Result};
use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(BTreeMap<String, Value>),
}

impl Value {
    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// `get` that errors with the key name (for manifest parsing).
    pub fn req(&self, key: &str) -> Result<&Value> {
        self.get(key).ok_or_else(|| anyhow!("missing key {key:?}"))
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Value::Num(n) => Ok(*n),
            _ => bail!("expected number, got {self:?}"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        Ok(self.as_f64()? as usize)
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Value::Bool(b) => Ok(*b),
            _ => bail!("expected bool"),
        }
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Value::Str(s) => Ok(s),
            _ => bail!("expected string, got {self:?}"),
        }
    }

    pub fn as_arr(&self) -> Result<&[Value]> {
        match self {
            Value::Arr(v) => Ok(v),
            _ => bail!("expected array"),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Value>> {
        match self {
            Value::Obj(m) => Ok(m),
            _ => bail!("expected object"),
        }
    }
}

/// Parse a JSON document.
pub fn parse(src: &str) -> Result<Value> {
    let mut p = Parser { b: src.as_bytes(), i: 0 };
    let v = p.value()?;
    p.skip_ws();
    if p.i != p.b.len() {
        bail!("trailing characters at offset {}", p.i);
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b
            .get(self.i)
            .copied()
            .ok_or_else(|| anyhow!("unexpected end of input"))
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!("expected {:?} at offset {}", c as char, self.i);
        }
        self.i += 1;
        Ok(())
    }

    fn lit(&mut self, s: &str, v: Value) -> Result<Value> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            bail!("invalid literal at offset {}", self.i)
        }
    }

    fn value(&mut self) -> Result<Value> {
        self.skip_ws();
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Value::Str(self.string()?)),
            b't' => self.lit("true", Value::Bool(true)),
            b'f' => self.lit("false", Value::Bool(false)),
            b'n' => self.lit("null", Value::Null),
            _ => self.number(),
        }
    }

    fn object(&mut self) -> Result<Value> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Value::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Value::Obj(m));
                }
                c => bail!("expected ',' or '}}', got {:?}", c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Value> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Value::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Value::Arr(v));
                }
                c => bail!("expected ',' or ']', got {:?}", c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            let hex = std::str::from_utf8(
                                self.b
                                    .get(self.i..self.i + 4)
                                    .ok_or_else(|| anyhow!("bad \\u escape"))?,
                            )?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            // Surrogate pairs are not needed for our files;
                            // map unpaired surrogates to U+FFFD.
                            s.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                        }
                        _ => bail!("bad escape at offset {}", self.i),
                    }
                }
                _ => {
                    // Collect the full UTF-8 sequence.
                    let start = self.i - 1;
                    let len = utf8_len(c);
                    self.i = start + len;
                    let chunk = self
                        .b
                        .get(start..self.i)
                        .ok_or_else(|| anyhow!("truncated utf-8"))?;
                    s.push_str(std::str::from_utf8(chunk)?);
                }
            }
        }
    }

    fn number(&mut self) -> Result<Value> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'-' | b'+' | b'.' | b'e' | b'E' | b'0'..=b'9')
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Value::Num(s.parse::<f64>().map_err(|_| anyhow!("bad number {s:?}"))?))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

/// Serialize a value (stable key order; floats in shortest round-trip form).
pub fn write(v: &Value) -> String {
    let mut s = String::new();
    write_into(&mut s, v);
    s
}

fn write_into(s: &mut String, v: &Value) {
    match v {
        Value::Null => s.push_str("null"),
        Value::Bool(b) => {
            s.push_str(if *b { "true" } else { "false" });
        }
        Value::Num(n) => {
            if n.fract() == 0.0 && n.abs() < 9e15 {
                let _ = write!(s, "{}", *n as i64);
            } else {
                let _ = write!(s, "{n}");
            }
        }
        Value::Str(t) => write_str(s, t),
        Value::Arr(a) => {
            s.push('[');
            for (i, x) in a.iter().enumerate() {
                if i > 0 {
                    s.push(',');
                }
                write_into(s, x);
            }
            s.push(']');
        }
        Value::Obj(m) => {
            s.push('{');
            for (i, (k, x)) in m.iter().enumerate() {
                if i > 0 {
                    s.push(',');
                }
                write_str(s, k);
                s.push(':');
                write_into(s, x);
            }
            s.push('}');
        }
    }
}

fn write_str(s: &mut String, t: &str) {
    s.push('"');
    for c in t.chars() {
        match c {
            '"' => s.push_str("\\\""),
            '\\' => s.push_str("\\\\"),
            '\n' => s.push_str("\\n"),
            '\r' => s.push_str("\\r"),
            '\t' => s.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(s, "\\u{:04x}", c as u32);
            }
            c => s.push(c),
        }
    }
    s.push('"');
}

/// Convenience builders for results files.
pub fn obj(pairs: Vec<(&str, Value)>) -> Value {
    Value::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn num(n: f64) -> Value {
    Value::Num(n)
}

pub fn str(s: impl Into<String>) -> Value {
    Value::Str(s.into())
}

pub fn arr(v: Vec<Value>) -> Value {
    Value::Arr(v)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(parse("42").unwrap(), Value::Num(42.0));
        assert_eq!(parse("-1.5e2").unwrap(), Value::Num(-150.0));
        assert_eq!(parse("true").unwrap(), Value::Bool(true));
        assert_eq!(parse("null").unwrap(), Value::Null);
        assert_eq!(parse("\"hi\"").unwrap(), Value::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let v = parse(r#"{"a": [1, {"b": "c"}], "d": false}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap()[0], Value::Num(1.0));
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[1].get("b").unwrap(),
            &Value::Str("c".into())
        );
    }

    #[test]
    fn escapes_roundtrip() {
        let v = parse(r#""a\nb\t\"q\" A""#).unwrap();
        assert_eq!(v, Value::Str("a\nb\t\"q\" A".into()));
        let out = write(&v);
        assert_eq!(parse(&out).unwrap(), v);
    }

    #[test]
    fn unicode_passthrough() {
        let v = parse("\"héllo ☃\"").unwrap();
        assert_eq!(v, Value::Str("héllo ☃".into()));
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("1 2").is_err());
        assert!(parse("nope").is_err());
    }

    #[test]
    fn writer_roundtrips() {
        let src = r#"{"m":{"x":[1,2,3],"y":{"z":null}},"n":1.25,"s":"\"esc\""}"#;
        let v = parse(src).unwrap();
        assert_eq!(parse(&write(&v)).unwrap(), v);
    }

    #[test]
    fn parses_real_manifest() {
        let dir = crate::models::default_artifacts_dir();
        let path = dir.join("manifest.json");
        if let Ok(txt) = std::fs::read_to_string(path) {
            let v = parse(&txt).unwrap();
            assert!(v.get("models").is_some());
        }
    }
}
